//! Deadlock rescue: the Fig. 1 comparison, live.
//!
//! A Vitis-style "keep doubling until it stops deadlocking" hunter finds
//! ONE feasible configuration by brute force; FIFOAdvisor finds the whole
//! frontier — including a zero-BRAM un-deadlocked point — in one run.
//!
//! Run: `cargo run --release --example deadlock_rescue`

use fifoadvisor::bench_suite;
use fifoadvisor::bram;
use fifoadvisor::dse::{drive, Evaluator};
use fifoadvisor::opt::{self, vitis_hunter::VitisHunter, Space};
use fifoadvisor::trace::collect_trace;
use std::sync::Arc;

fn rescue(design: &str) -> anyhow::Result<()> {
    let bd = bench_suite::try_build(design).unwrap();
    let trace = Arc::new(collect_trace(&bd.design, &bd.args)?);
    let space = Space::from_trace(&trace);

    let mut ev = Evaluator::parallel(trace.clone(), 4);
    let (maxp, minp) = ev.eval_baselines();
    println!("== {design} ==");
    println!(
        "  Baseline-Max: {} cycles / {} BRAM",
        maxp.latency.unwrap(),
        maxp.bram
    );
    assert!(!minp.is_feasible(), "{design} should deadlock at Baseline-Min");
    println!("  Baseline-Min: DEADLOCK — needs rescuing");

    // The Vitis way: re-simulate with doubled sizes until feasible.
    ev.reset_run(true);
    let hunter_cfg = VitisHunter::new().hunt(&mut ev, &space, 100).unwrap();
    let hunter_sims = ev.n_sim;
    let hunter_bram = bram::bram_total(&hunter_cfg, &ev.widths);
    let (hl, _) = ev.eval(&hunter_cfg);
    println!(
        "  Vitis-style hunter : feasible after {hunter_sims} sims → {} cycles / {} BRAM (one point, oversized)",
        hl.unwrap(),
        hunter_bram
    );

    // The FIFOAdvisor way: a full frontier (grouped SA + NSGA-II pool).
    ev.reset_run(true);
    drive(&mut *opt::by_name("grouped_sa", 11).unwrap(), &mut ev, &space, 600);
    drive(&mut *opt::by_name("nsga2", 13).unwrap(), &mut ev, &space, 400);
    let front = ev.pareto();
    let cheapest = front.iter().min_by_key(|p| p.bram).unwrap();
    let fastest = front.iter().min_by_key(|p| p.latency.unwrap()).unwrap();
    println!(
        "  FIFOAdvisor        : frontier of {} points; cheapest rescue {} cycles / {} BRAM; fastest {} cycles / {} BRAM",
        front.len(),
        cheapest.latency.unwrap(),
        cheapest.bram,
        fastest.latency.unwrap(),
        fastest.bram
    );
    // The hunter yields one blind point; the frontier always offers a
    // strictly faster rescue (and usually a cheaper one too).
    assert!(fastest.latency.unwrap() <= hl.unwrap());
    let _ = hunter_bram;
    println!();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    // Both designs whose Baseline-Min deadlocks (the ×→✓ rows of Fig. 4b)
    // plus the runtime-dependent Fig. 2 example.
    for design in ["fig2", "k15mmtree", "ResidualBlock"] {
        rescue(design)?;
    }
    Ok(())
}
