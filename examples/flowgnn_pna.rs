//! §IV-D case study: optimizing the FlowGNN-PNA accelerator, whose FIFO
//! deadlock thresholds depend on the runtime graph — plus the paper's
//! proposed future-work extension, joint optimization over a suite of
//! input stimuli (implemented here).
//!
//! Run: `cargo run --release --example flowgnn_pna`

use fifoadvisor::bench_suite::flowgnn::{self, LANES};
use fifoadvisor::dse::{drive, Evaluator};
use fifoadvisor::opt::{self, Space};
use fifoadvisor::sim::fast::FastSim;
use fifoadvisor::trace::collect_trace;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // --- Single-stimulus optimization (the paper's flow, 5000 samples) ---
    let bd = flowgnn::pna_default();
    let trace = Arc::new(collect_trace(&bd.design, &bd.args)?);
    println!(
        "PNA: {} FIFOs, {} trace ops, graph = {} nodes / {} edges (seed {})",
        trace.num_fifos(),
        trace.total_ops(),
        bd.args[0],
        bd.args[1],
        bd.args[2]
    );
    let lane_bursts: Vec<u64> = trace.channels[..LANES].iter().map(|c| c.writes).collect();
    println!("per-lane message bursts (data-dependent): {lane_bursts:?}");

    let space = Space::from_trace(&trace);
    let mut ev = Evaluator::parallel(trace.clone(), 4);
    let (designer, minp) = ev.eval_baselines();
    println!(
        "designer sizes: latency {} cycles / {} BRAM;  all-min: {}",
        designer.latency.unwrap(),
        designer.bram,
        if minp.is_feasible() { "feasible" } else { "DEADLOCK" }
    );

    let t0 = std::time::Instant::now();
    drive(&mut *opt::by_name("grouped_sa", 7).unwrap(), &mut ev, &space, 5000);
    println!(
        "grouped SA, 5000 samples in {:.2}s → frontier:",
        t0.elapsed().as_secs_f64()
    );
    for p in ev.pareto() {
        println!(
            "  lat {:>6} ({:.4}x)   bram {:>3}   msg depths {:?}",
            p.latency.unwrap(),
            p.latency.unwrap() as f64 / designer.latency.unwrap() as f64,
            p.bram,
            &p.depths[..LANES]
        );
    }

    // --- Multi-stimulus joint optimization (future-work extension) ---
    println!("\njoint optimization over 4 runtime graphs:");
    let seeds = [7i64, 99, 1234, 31415];
    let traces: Vec<Arc<_>> = seeds
        .iter()
        .map(|&s| {
            let bd = flowgnn::pna(64, 512, s);
            Arc::new(collect_trace(&bd.design, &bd.args).unwrap())
        })
        .collect();
    for (s, t) in seeds.iter().zip(&traces) {
        let bursts: Vec<u64> = t.channels[..LANES].iter().map(|c| c.writes).collect();
        println!("  seed {s:>6}: lane bursts {bursts:?}");
    }
    // Joint feasibility = feasible under every stimulus; joint latency =
    // worst case. Size each msg FIFO to the max burst across stimuli.
    let mut joint = traces[0].baseline_max();
    for l in 0..LANES {
        joint[l] = traces
            .iter()
            .map(|t| t.channels[l].writes as u32)
            .max()
            .unwrap();
    }
    let mut worst = 0u64;
    for t in &traces {
        let mut sim = FastSim::new(t.clone());
        let out = sim.simulate(&joint);
        assert!(!out.is_deadlock(), "joint sizing must be safe on all stimuli");
        worst = worst.max(out.latency().unwrap());
    }
    let joint_bram = fifoadvisor::bram::bram_total(&joint, &ev.widths);
    println!(
        "  joint msg sizing {:?} → worst-case latency {} cycles, {} BRAM",
        &joint[..LANES],
        worst,
        joint_bram
    );
    println!(
        "  (single-stimulus sizing would deadlock on the other graphs — \
         see tests/integration.rs::multi_stimulus_optimization_tightens_feasibility)"
    );
    Ok(())
}
