// timing driver for §Perf iteration: N feasible-leaning sims, prints mean
use fifoadvisor::bench_suite;
use fifoadvisor::sim::fast::FastSim;
use fifoadvisor::trace::collect_trace;
use fifoadvisor::util::Rng;
use std::sync::Arc;
fn main() {
    let name = std::env::args().nth(1).unwrap_or_else(|| "gemm".into());
    let bd = bench_suite::build(&name);
    let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
    let mut sim = FastSim::new(t.clone());
    let ub = t.upper_bounds();
    let mut rng = Rng::new(1);
    let configs: Vec<Vec<u32>> = (0..200)
        .map(|_| ub.iter().map(|&u| rng.range_u32((u / 2).max(2), u.max(2))).collect())
        .collect();
    for c in &configs[..20] { let _ = sim.simulate(c); } // warm
    let t0 = std::time::Instant::now();
    let mut acc = 0u64;
    for c in &configs { acc ^= sim.simulate(c).latency().unwrap_or(0); }
    let dt = t0.elapsed().as_secs_f64() / configs.len() as f64;
    println!("{name}: {:.1} µs/sim ({} ops, {:.0} Mops/s, acc {acc})", dt * 1e6, t.total_ops(), t.total_ops() as f64 / dt / 1e6);
}
