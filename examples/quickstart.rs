//! Quickstart: size the FIFOs of the paper's Fig. 2 design end-to-end.
//!
//! Demonstrates the full public API surface on a design small enough to
//! reason about by hand: build a dataflow design with data-dependent
//! control flow, collect its trace, evaluate the baselines, run an
//! optimizer, and inspect the Pareto frontier.
//!
//! Run: `cargo run --example quickstart`

use fifoadvisor::dse::{drive, Evaluator};
use fifoadvisor::ir::{DesignBuilder, Expr};
use fifoadvisor::opt::{self, Space};
use fifoadvisor::trace::collect_trace;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    // 1. Describe the HLS design (paper Fig. 2): a producer that writes n
    //    tokens to x then n to y, and a consumer that alternates reads.
    //    The deadlock threshold of x is n-1 — knowable only at runtime.
    let n = 40i64;
    let mut b = DesignBuilder::new("mult_by_2", 1);
    let x = b.channel("x", 32);
    let y = b.channel("y", 32);
    b.process("producer", |p| {
        p.for_expr(Expr::arg(0), |p, _| p.write(x, Expr::c(1)));
        p.for_expr(Expr::arg(0), |p, _| p.write(y, Expr::c(1)));
    });
    b.process("consumer", |p| {
        let sum = p.var();
        p.set(sum, Expr::c(0));
        p.for_expr(Expr::arg(0), |p, _| {
            let a = p.read(x);
            let c = p.read(y);
            p.set(sum, Expr::var(sum).add(Expr::var(a)).add(Expr::var(c)));
        });
    });
    let design = b.build();

    // 2. "Software execution": collect the trace once (LightningSim
    //    phase 1). The trace is FIFO-size-independent.
    let trace = Arc::new(collect_trace(&design, &[n])?);
    println!(
        "trace: {} FIFO ops across {} processes",
        trace.total_ops(),
        trace.process_names.len()
    );

    // 3. Baselines.
    let mut ev = Evaluator::new(trace.clone());
    let (maxp, minp) = ev.eval_baselines();
    println!(
        "Baseline-Max (x={}, y={}): latency {} cycles, {} BRAM",
        trace.baseline_max()[0],
        trace.baseline_max()[1],
        maxp.latency.unwrap(),
        maxp.bram
    );
    println!(
        "Baseline-Min (2, 2):      {}",
        if minp.is_feasible() { "feasible" } else { "DEADLOCK (as the paper predicts)" }
    );

    // 4. Optimize: exhaustive is tractable here (pruned space is tiny).
    let space = Space::from_trace(&trace);
    drive(&mut opt::exhaustive::Exhaustive::new(), &mut ev, &space, 10_000);
    println!("\npruned space exhausted in {} evaluations:", ev.n_evals());
    for p in ev.pareto() {
        println!(
            "  depths {:?} -> latency {} cycles, {} BRAM",
            &p.depths[..],
            p.latency.unwrap(),
            p.bram
        );
    }

    // 5. The runtime-analysis argument: the minimal safe depth for x is
    //    exactly n-1, which no static analysis could know.
    let mut probe = trace.baseline_min();
    probe[0] = (n - 1) as u32;
    let (lat, bram) = ev.eval(&probe);
    println!(
        "\ndepth(x) = n-1 = {}: latency {:?}, {} BRAM (feasible; n-2 deadlocks)",
        n - 1,
        lat.unwrap(),
        bram
    );
    Ok(())
}
