//! Stream-HLS DSE: run all five paper optimizers on one suite design and
//! compare their frontiers — a one-design slice of Fig. 3 / Fig. 4.
//!
//! Run: `cargo run --release --example streamhls_dse [design] [budget]`
//! (default: k15mmseq, 1000 samples — the paper's budget)

use fifoadvisor::bench_suite;
use fifoadvisor::dse::{drive, Evaluator};
use fifoadvisor::opt::objective::select_highlight;
use fifoadvisor::opt::{self, Space};
use fifoadvisor::report::ascii;
use fifoadvisor::trace::collect_trace;
use fifoadvisor::util::stats::fmt_duration;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let design = args.first().map(|s| s.as_str()).unwrap_or("k15mmseq");
    let budget: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(1000);

    let bd = bench_suite::try_build(design)
        .unwrap_or_else(|| panic!("unknown design '{design}'"));
    let trace = Arc::new(collect_trace(&bd.design, &bd.args)?);
    let space = Space::from_trace(&trace);
    println!(
        "{design}: {} FIFOs in {} groups, pruned space 10^{:.1}, budget {budget}",
        trace.num_fifos(),
        space.groups.len(),
        space.log10_size()
    );

    let mut ev = Evaluator::parallel(trace.clone(), 8);
    let (base, minp) = ev.eval_baselines();
    let base_lat = base.latency.unwrap();
    println!(
        "Baseline-Max: {} cycles / {} BRAM    Baseline-Min: {}\n",
        base_lat,
        base.bram,
        match minp.latency {
            Some(l) => format!("{l} cycles / {} BRAM", minp.bram),
            None => "DEADLOCK".into(),
        }
    );

    println!(
        "{:<16} {:>7} {:>9} {:>7} | highlighted ★ (α=0.7): {:>10} {:>8} {:>7}",
        "optimizer", "evals", "time", "front", "latency", "lat×", "BRAM"
    );
    let mut plot_series: Vec<(char, Vec<(f64, f64)>)> = Vec::new();
    for (label, name) in [
        ('g', "greedy"),
        ('r', "random"),
        ('R', "grouped_random"),
        ('s', "sa"),
        ('S', "grouped_sa"),
    ] {
        ev.reset_run(true); // cold cache per optimizer: fair timing
        let mut o = opt::by_name(name, 1).unwrap();
        let t0 = std::time::Instant::now();
        drive(&mut *o, &mut ev, &space, budget);
        let dt = t0.elapsed().as_secs_f64();
        let front = ev.pareto();
        let pts: Vec<(u64, u32)> = front.iter().map(|p| (p.latency.unwrap(), p.bram)).collect();
        let star_idx = select_highlight(&pts, 0.7, base_lat, base.bram).unwrap();
        let (sl, sb) = pts[star_idx];
        println!(
            "{:<16} {:>7} {:>9} {:>7} |                        {:>10} {:>8.4} {:>7}",
            name,
            ev.n_evals(),
            fmt_duration(dt),
            front.len(),
            sl,
            sl as f64 / base_lat as f64,
            sb
        );
        plot_series.push((
            label,
            pts.iter().map(|&(l, b)| (l as f64, b as f64)).collect(),
        ));
    }

    println!("\nfrontiers (g=greedy r=random R=grouped-random s=SA S=grouped-SA M=Baseline-Max):");
    let base_pt = [(base_lat as f64, base.bram as f64)];
    let mut series: Vec<ascii::Series> = plot_series
        .iter()
        .map(|(label, pts)| ascii::Series {
            label: *label,
            points: pts,
        })
        .collect();
    series.push(ascii::Series {
        label: 'M',
        points: &base_pt,
    });
    println!("{}", ascii::scatter(&series, 72, 20, "latency (cycles)", "FIFO BRAM"));
    Ok(())
}
