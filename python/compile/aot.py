"""AOT export: lower the L2 analytics graph to HLO **text** artifacts the
Rust runtime loads via the `xla` crate's PJRT CPU client.

HLO text (not `.serialize()`d protos) is the interchange format: jax>=0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

One artifact is exported per FIFO-count bucket (fixed batch B and beta
grid K; F in F_BUCKETS). The Rust side pads any design to the next bucket.
Python runs only here, at build time -- never on the DSE path.

Usage: python -m compile.aot [--out-dir ../artifacts]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Fixed export shapes. B must be a multiple of the pareto kernel tile
# (128) and the bram kernel tile (64); F buckets cover every design in the
# suite (FeedForward peaks at 848 FIFOs).
BATCH = 256
BETAS = 16
F_BUCKETS = (64, 256, 1024)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_bucket(f: int, out_dir: str) -> dict:
    spec = lambda shape, dtype: jax.ShapeDtypeStruct(shape, dtype)  # noqa: E731
    lowered = jax.jit(model.evaluate_batch).lower(
        spec((BATCH, f), jnp.int32),
        spec((f,), jnp.int32),
        spec((BATCH,), jnp.float32),
        spec((BETAS,), jnp.float32),
    )
    text = to_hlo_text(lowered)
    name = f"analytics_f{f}.hlo.txt"
    path = os.path.join(out_dir, name)
    with open(path, "w") as fh:
        fh.write(text)
    return {"fifos": f, "batch": BATCH, "betas": BETAS, "file": name}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"buckets": [export_bucket(f, args.out_dir) for f in F_BUCKETS]}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as fh:
        json.dump(manifest, fh, indent=2)
    print(f"exported {len(F_BUCKETS)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
