"""L1 Pallas kernel: batched BRAM18K allocation (paper Algorithm 1).

Computes, for a (B, F) tile of candidate FIFO depths and an (F,) vector of
FIFO bitwidths, the BRAM_18K count of every FIFO in every candidate
configuration — the `f_bram` objective evaluated for a whole optimizer
batch at once.

TPU-adaptation notes (DESIGN.md §Hardware-Adaptation): Algorithm 1 is
branchy scalar code; here the fixed five-rung BRAM shape ladder
(1K x 18 ... 16K x 1) is fully unrolled and every data-dependent branch is
replaced by a predicated `jnp.where` select, so the whole (B, F) tile stays
resident in VMEM and the computation is pure VPU element-wise work. The
kernel runs `interpret=True` (CPU PJRT cannot execute Mosaic custom calls);
the BlockSpec tiling below is the schedule a real TPU lowering would use.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The BRAM_18K (depth, width) configuration ladder, widest first.
BRAM18K_SHAPES = ((1024, 18), (2048, 9), (4096, 4), (8192, 2), (16384, 1))

# Total bits at or below which Vitis maps the FIFO to a shift register.
SRL_THRESHOLD_BITS = 1024

# Rows per grid step: sized so a (TILE_B, F<=1024) int32 tile plus its
# output stays well under VMEM (~0.5 MiB per operand at F=1024).
TILE_B = 64


def _bram_counts_tile(depths, widths_row):
    """Algorithm 1, vectorized: depths (tb, F) int32, widths (1|tb, F)."""
    d = depths
    w = jnp.broadcast_to(widths_row, d.shape).astype(jnp.int32)
    srl = (d <= 2) | (d * w <= SRL_THRESHOLD_BITS)
    n = jnp.zeros_like(d)
    rem = w
    for di, wi in BRAM18K_SHAPES:
        cols = rem // wi
        rows = (d + (di - 1)) // di  # ceil(d / di)
        n = n + cols * rows
        rem = rem % wi
        fire = (rem > 0) & (d <= di)
        n = jnp.where(fire, n + 1, n)
        rem = jnp.where(fire, 0, rem)
    return jnp.where(srl, 0, n)


def _bram_kernel(depths_ref, widths_ref, out_ref):
    out_ref[...] = _bram_counts_tile(depths_ref[...], widths_ref[...])


@functools.partial(jax.jit, static_argnames=())
def bram_counts(depths, widths):
    """Per-FIFO BRAM counts via the Pallas kernel.

    Args:
      depths: (B, F) int32 candidate depths.
      widths: (F,) int32 FIFO bitwidths.
    Returns:
      (B, F) int32 BRAM counts.
    """
    b, f = depths.shape
    tile_b = min(TILE_B, b)
    assert b % tile_b == 0, f"batch {b} not a multiple of tile {tile_b}"
    grid = (b // tile_b,)
    return pl.pallas_call(
        _bram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, f), lambda i: (i, 0)),
            pl.BlockSpec((1, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_b, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, f), jnp.int32),
        interpret=True,
    )(depths, widths.reshape(1, f))


def bram_totals(depths, widths):
    """Per-configuration total BRAM: (B,) int32."""
    return bram_counts(depths, widths).sum(axis=1, dtype=jnp.int32)
