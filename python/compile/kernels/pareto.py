"""L1 Pallas kernel: Pareto dominance mask over a batch of evaluated
configurations.

`dominated[i] = 1` iff some j has `lat[j] <= lat[i] and bram[j] <= bram[i]`
with at least one strict inequality. Infeasible (deadlocked) and padding
entries are encoded as `lat = +inf` by the Rust caller: +inf entries never
dominate anything (no finite latency is >= +inf on the strict side in a
way that matters) and are reported undominated, which the caller masks
off.

TPU-adaptation: the O(B^2) pairwise comparison is tiled by output rows
(TILE_B = 128, matched to the 8x128 VPU lane layout rather than MXU tiles
-- this is compare/reduce work, not matmul); the full (B,) latency/BRAM
vectors are tiny (<= 8 KiB) and stay VMEM-resident across all row tiles.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_B = 128


def _dominance_kernel(lat_row_ref, bram_row_ref, lat_all_ref, bram_all_ref, out_ref):
    li = lat_row_ref[...][:, None]  # (tb, 1)
    bi = bram_row_ref[...][:, None]
    lj = lat_all_ref[...][None, :]  # (1, B)
    bj = bram_all_ref[...][None, :]
    no_worse = (lj <= li) & (bj <= bi)
    strictly_better = (lj < li) | (bj < bi)
    dom = no_worse & strictly_better  # (tb, B)
    out_ref[...] = dom.any(axis=1).astype(jnp.int32)


@jax.jit
def dominated_mask(latency, bram):
    """(B,) int32 mask of dominated points.

    Args:
      latency: (B,) float32 (use +inf for infeasible/padding entries).
      bram: (B,) float32 total BRAM per configuration.
    """
    (b,) = latency.shape
    tile_b = min(TILE_B, b)
    assert b % tile_b == 0, f"batch {b} not a multiple of tile {tile_b}"
    grid = (b // tile_b,)
    return pl.pallas_call(
        _dominance_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b,), lambda i: (i,)),
            pl.BlockSpec((tile_b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile_b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.int32),
        interpret=True,
    )(latency, bram, latency, bram)
