"""Pure-jnp/numpy oracles for the Pallas kernels — the build-time
correctness reference (pytest compares kernel outputs against these)."""

import numpy as np

BRAM18K_SHAPES = ((1024, 18), (2048, 9), (4096, 4), (8192, 2), (16384, 1))
SRL_THRESHOLD_BITS = 1024


def bram_for_fifo_scalar(depth: int, width: int) -> int:
    """Paper Algorithm 1, scalar (mirrors the Rust implementation)."""
    if depth <= 2 or depth * width <= SRL_THRESHOLD_BITS:
        return 0
    n = 0
    w = width
    for di, wi in BRAM18K_SHAPES:
        n += (w // wi) * -(-depth // di)
        w %= wi
        if w > 0 and depth <= di:
            n += 1
            w = 0
    return n


def bram_counts_ref(depths: np.ndarray, widths: np.ndarray) -> np.ndarray:
    """(B, F) int32 BRAM counts via the scalar oracle."""
    b, f = depths.shape
    out = np.zeros((b, f), dtype=np.int32)
    for i in range(b):
        for j in range(f):
            out[i, j] = bram_for_fifo_scalar(int(depths[i, j]), int(widths[j]))
    return out


def bram_totals_ref(depths: np.ndarray, widths: np.ndarray) -> np.ndarray:
    return bram_counts_ref(depths, widths).sum(axis=1, dtype=np.int32)


def dominated_mask_ref(latency: np.ndarray, bram: np.ndarray) -> np.ndarray:
    """(B,) int32 dominated flags, O(B^2) loops."""
    b = latency.shape[0]
    out = np.zeros(b, dtype=np.int32)
    for i in range(b):
        for j in range(b):
            no_worse = latency[j] <= latency[i] and bram[j] <= bram[i]
            strict = latency[j] < latency[i] or bram[j] < bram[i]
            if no_worse and strict:
                out[i] = 1
                break
    return out


def weighted_scores_ref(
    betas: np.ndarray, latency: np.ndarray, bram: np.ndarray
) -> np.ndarray:
    """(K, B) float32: (1-beta)*lat + beta*bram (paper SA scalarization)."""
    return (1.0 - betas)[:, None] * latency[None, :] + betas[:, None] * bram[None, :]
