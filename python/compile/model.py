"""L2 JAX model: the batched DSE analytics graph.

`evaluate_batch` is the computation the Rust coordinator executes (via the
AOT-compiled PJRT artifact) after simulating an optimizer batch: given B
candidate FIFO configurations, the FIFO bitwidths, and the B simulated
latencies, it produces in one fused XLA module

  1. per-configuration total BRAM usage        (L1 `bram` Pallas kernel),
  2. the beta-grid weighted SA objectives       (paper SS III-D),
  3. the Pareto non-domination mask             (L1 `pareto` Pallas kernel).

Padding conventions (enforced by the Rust caller):
  - unused batch rows:   depths = 2, latency = +inf  -> bram 0, undominated
    (masked off by the caller via the valid count);
  - unused FIFO columns: depth = 2, width = 1        -> bram 0;
  - deadlocked configs:  latency = +inf              -> never dominate.
"""

import jax.numpy as jnp

from .kernels import bram as bram_kernel
from .kernels import pareto as pareto_kernel


def evaluate_batch(depths, widths, latencies, betas):
    """The full analytics graph.

    Args:
      depths:    (B, F) int32 candidate FIFO depths.
      widths:    (F,)   int32 FIFO bitwidths.
      latencies: (B,)   float32 simulated latencies (+inf = deadlock/pad).
      betas:     (K,)   float32 scalarization grid.

    Returns:
      bram_totals: (B,)  int32
      scores:      (K, B) float32  -- (1-beta)*lat + beta*bram
      dominated:   (B,)  int32
    """
    totals = bram_kernel.bram_totals(depths, widths)  # (B,)
    totals_f = totals.astype(jnp.float32)
    scores = (1.0 - betas)[:, None] * latencies[None, :] + betas[:, None] * totals_f[None, :]
    dominated = pareto_kernel.dominated_mask(latencies, totals_f)
    return totals, scores, dominated
