"""L1 bram Pallas kernel vs the scalar Algorithm-1 oracle."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import bram as bram_kernel
from compile.kernels import ref


def test_worked_examples():
    # Mirrors the Rust unit test cases (cross-language agreement).
    cases = [
        ((2, 512), 0),
        ((32, 32), 0),
        ((1024, 32), 2),
        ((1024, 18), 1),
        ((2048, 18), 2),
        ((2048, 9), 1),
        ((4096, 14), 4),
        ((16384, 1), 1),
        ((512, 36), 2),
        ((10000, 9), 5),
        ((10000, 8), 6),
    ]
    for (d, w), expect in cases:
        assert ref.bram_for_fifo_scalar(d, w) == expect, (d, w)
    depths = np.array([[d for (d, _), _ in cases]], dtype=np.int32)
    widths = np.array([w for (_, w), _ in cases], dtype=np.int32)
    got = np.asarray(bram_kernel.bram_counts(depths, widths))
    assert got.tolist() == [[e for _, e in cases]]


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([64, 128, 256]),
    f=st.integers(min_value=1, max_value=40),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_oracle(b, f, seed):
    rng = np.random.default_rng(seed)
    depths = rng.integers(1, 70_000, size=(b, f), dtype=np.int32)
    # Mix in boundary depths.
    depths[0, :] = 2
    if b > 1:
        depths[1, :] = np.minimum(1024 // np.maximum(rng.integers(1, 64, f), 1), 2**15)
    widths = rng.integers(1, 129, size=(f,), dtype=np.int32)
    got = np.asarray(bram_kernel.bram_counts(depths, widths))
    want = ref.bram_counts_ref(depths, widths)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_totals_match(seed):
    rng = np.random.default_rng(seed)
    depths = rng.integers(2, 5000, size=(64, 17), dtype=np.int32)
    widths = rng.integers(1, 64, size=(17,), dtype=np.int32)
    got = np.asarray(bram_kernel.bram_totals(depths, widths))
    np.testing.assert_array_equal(got, ref.bram_totals_ref(depths, widths))


def test_batch_must_tile():
    depths = np.zeros((100, 4), dtype=np.int32)  # 100 % 64 != 0
    widths = np.ones(4, dtype=np.int32)
    with pytest.raises(AssertionError):
        bram_kernel.bram_counts(depths, widths)
