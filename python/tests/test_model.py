"""L2 analytics graph: shapes, padding semantics, scalarization."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import model
from compile.kernels import ref


def _inputs(b=256, f=64, seed=0):
    rng = np.random.default_rng(seed)
    depths = rng.integers(2, 4096, size=(b, f)).astype(np.int32)
    widths = rng.integers(1, 65, size=(f,)).astype(np.int32)
    lat = rng.integers(100, 100_000, size=(b,)).astype(np.float32)
    betas = np.linspace(0.0, 1.0, 16).astype(np.float32)
    return depths, widths, lat, betas


def test_shapes_and_dtypes():
    depths, widths, lat, betas = _inputs()
    totals, scores, dominated = model.evaluate_batch(depths, widths, lat, betas)
    assert totals.shape == (256,) and str(totals.dtype) == "int32"
    assert scores.shape == (16, 256) and str(scores.dtype) == "float32"
    assert dominated.shape == (256,) and str(dominated.dtype) == "int32"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_composed_graph_matches_oracles(seed):
    depths, widths, lat, betas = _inputs(b=128, f=20, seed=seed)
    totals, scores, dominated = model.evaluate_batch(depths, widths, lat, betas)
    want_totals = ref.bram_totals_ref(depths, widths)
    np.testing.assert_array_equal(np.asarray(totals), want_totals)
    want_scores = ref.weighted_scores_ref(betas, lat, want_totals.astype(np.float32))
    np.testing.assert_allclose(np.asarray(scores), want_scores, rtol=1e-6)
    want_dom = ref.dominated_mask_ref(lat, want_totals.astype(np.float32))
    np.testing.assert_array_equal(np.asarray(dominated), want_dom)


def test_padding_rows_are_inert():
    depths, widths, lat, betas = _inputs(b=256, f=16, seed=3)
    # Mark rows >= 100 as padding per the convention.
    depths[100:] = 2
    lat[100:] = np.inf
    totals, _, dominated = model.evaluate_batch(depths, widths, lat, betas)
    totals = np.asarray(totals)
    assert (totals[100:] == 0).all(), "padding rows must cost 0 BRAM"
    # Real rows' dominance must be unaffected by padding: recompute with
    # only the valid prefix.
    want = ref.dominated_mask_ref(lat[:100], totals[:100].astype(np.float32))
    np.testing.assert_array_equal(np.asarray(dominated)[:100], want)


def test_beta_endpoints():
    depths, widths, lat, betas = _inputs(b=64, f=8, seed=5)
    totals, scores, _ = model.evaluate_batch(depths, widths, lat, betas)
    np.testing.assert_allclose(np.asarray(scores)[0], lat, rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(scores)[-1], np.asarray(totals).astype(np.float32), rtol=1e-6
    )
