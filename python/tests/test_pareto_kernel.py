"""L1 pareto dominance Pallas kernel vs the O(B^2) numpy oracle."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import pareto as pareto_kernel
from compile.kernels import ref


def test_simple_front():
    lat = np.array([10, 8, 12, 10], dtype=np.float32)
    bram = np.array([5, 7, 3, 7], dtype=np.float32)
    lat = np.pad(lat, (0, 124), constant_values=np.inf)
    bram = np.pad(bram, (0, 124))
    got = np.asarray(pareto_kernel.dominated_mask(lat, bram))
    # (10,7) is dominated by (10,5) and (8,7); the rest of the real points
    # are non-dominated; +inf padding rows are undominated.
    assert got[:4].tolist() == [0, 0, 0, 1]
    assert got[4:].tolist() == [0] * 124


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([128, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    dup_heavy=st.booleans(),
)
def test_kernel_matches_oracle(b, seed, dup_heavy):
    rng = np.random.default_rng(seed)
    hi = 8 if dup_heavy else 10_000  # duplicates stress the tie rules
    lat = rng.integers(1, hi, size=b).astype(np.float32)
    bram = rng.integers(0, hi, size=b).astype(np.float32)
    # Sprinkle infeasible entries.
    lat[rng.random(b) < 0.1] = np.inf
    got = np.asarray(pareto_kernel.dominated_mask(lat, bram))
    want = ref.dominated_mask_ref(lat, bram)
    np.testing.assert_array_equal(got, want)


def test_inf_never_dominates():
    lat = np.full(128, np.inf, dtype=np.float32)
    lat[0] = 5.0
    bram = np.zeros(128, dtype=np.float32)
    got = np.asarray(pareto_kernel.dominated_mask(lat, bram))
    # The one feasible point is undominated; the +inf points are dominated
    # by the feasible one (same bram, smaller latency) -- which is fine,
    # the caller masks padding by index.
    assert got[0] == 0
    assert got[1:].all()
