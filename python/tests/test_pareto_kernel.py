"""L1 pareto dominance Pallas kernel vs the O(B^2) numpy oracle."""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import pareto as pareto_kernel
from compile.kernels import ref


def test_simple_front():
    lat = np.array([10, 8, 12, 10], dtype=np.float32)
    bram = np.array([5, 7, 3, 7], dtype=np.float32)
    lat = np.pad(lat, (0, 124), constant_values=np.inf)
    bram = np.pad(bram, (0, 124))
    got = np.asarray(pareto_kernel.dominated_mask(lat, bram))
    # (10,7) is dominated by (10,5) and (8,7); the rest of the real points
    # are non-dominated; +inf padding rows are undominated.
    assert got[:4].tolist() == [0, 0, 0, 1]
    assert got[4:].tolist() == [0] * 124


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([128, 256]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    dup_heavy=st.booleans(),
)
def test_kernel_matches_oracle(b, seed, dup_heavy):
    rng = np.random.default_rng(seed)
    hi = 8 if dup_heavy else 10_000  # duplicates stress the tie rules
    lat = rng.integers(1, hi, size=b).astype(np.float32)
    bram = rng.integers(0, hi, size=b).astype(np.float32)
    # Sprinkle infeasible entries.
    lat[rng.random(b) < 0.1] = np.inf
    got = np.asarray(pareto_kernel.dominated_mask(lat, bram))
    want = ref.dominated_mask_ref(lat, bram)
    np.testing.assert_array_equal(got, want)


def test_inf_never_dominates():
    lat = np.full(128, np.inf, dtype=np.float32)
    lat[0] = 5.0
    bram = np.zeros(128, dtype=np.float32)
    got = np.asarray(pareto_kernel.dominated_mask(lat, bram))
    # The one feasible point is undominated; the +inf points are dominated
    # by the feasible one (same bram, smaller latency) -- which is fine,
    # the caller masks padding by index.
    assert got[0] == 0
    assert got[1:].all()


def test_exact_ties_are_undominated():
    # No strict inequality on either axis => duplicates never dominate
    # each other; the dominance condition requires at least one strict.
    lat = np.full(128, np.inf, dtype=np.float32)
    bram = np.zeros(128, dtype=np.float32)
    lat[:4] = 7.0
    bram[:4] = 3.0
    got = np.asarray(pareto_kernel.dominated_mask(lat, bram))
    assert got[:4].tolist() == [0, 0, 0, 0]
    np.testing.assert_array_equal(got, ref.dominated_mask_ref(lat, bram))


def test_one_axis_tie_with_strict_other_axis_dominates():
    # (10, 5) vs (10, 3): latency ties, BRAM is strictly better => the
    # bigger-BRAM row is dominated. Symmetric case on the latency axis.
    lat = np.full(128, np.inf, dtype=np.float32)
    bram = np.zeros(128, dtype=np.float32)
    lat[:4] = [10.0, 10.0, 8.0, 9.0]
    bram[:4] = [5.0, 3.0, 4.0, 4.0]
    got = np.asarray(pareto_kernel.dominated_mask(lat, bram))
    # Row 0 dominated by row 1 (lat tie, less BRAM); row 3 dominated by
    # row 2 (BRAM tie, lower latency); rows 1 and 2 are the front.
    assert got[:4].tolist() == [1, 0, 0, 1]
    np.testing.assert_array_equal(got, ref.dominated_mask_ref(lat, bram))


def test_inf_padding_parity_with_reference():
    # A realistic engine batch shape: a short valid prefix of evaluated
    # lanes (some deadlocked => +inf) followed by +inf padding rows up to
    # the export batch. Kernel and O(B^2) reference must agree on every
    # row, valid and padding alike.
    rng = np.random.default_rng(0xF1F0)
    b, valid = 256, 37
    lat = np.full(b, np.inf, dtype=np.float32)
    bram = np.zeros(b, dtype=np.float32)
    lat[:valid] = rng.integers(1, 50, size=valid).astype(np.float32)
    lat[:valid][rng.random(valid) < 0.2] = np.inf  # deadlocked lanes
    bram[:valid] = rng.integers(0, 20, size=valid).astype(np.float32)
    got = np.asarray(pareto_kernel.dominated_mask(lat, bram))
    np.testing.assert_array_equal(got, ref.dominated_mask_ref(lat, bram))
    # Zero-BRAM +inf padding rows tie exactly with each other (inf <= inf
    # holds, inf < inf does not; bram 0 == 0): undominated unless some
    # valid feasible row has bram == 0.
    if not np.any(np.isfinite(lat[:valid]) & (bram[:valid] == 0)):
        assert not got[valid:][bram[valid:] == 0].any()


def test_all_inf_batch_follows_ieee_bram_ordering():
    # Every row deadlocked: dominance degenerates to the BRAM ordering
    # (the IEEE corner the Rust runtime interpreter documents — a
    # deadlocked row IS dominated by another deadlocked row with strictly
    # smaller BRAM, since inf <= inf holds but inf < inf does not).
    lat = np.full(128, np.inf, dtype=np.float32)
    bram = np.arange(128, dtype=np.float32)
    got = np.asarray(pareto_kernel.dominated_mask(lat, bram))
    assert got[0] == 0, "smallest-BRAM deadlock row is undominated"
    assert got[1:].all(), "every larger-BRAM deadlock row is dominated"
    np.testing.assert_array_equal(got, ref.dominated_mask_ref(lat, bram))
    # With equal BRAM everywhere, nothing is strict: all undominated.
    flat = np.asarray(pareto_kernel.dominated_mask(lat, np.zeros(128, np.float32)))
    assert not flat.any()
