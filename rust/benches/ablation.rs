//! Ablation studies for the design choices DESIGN.md calls out:
//!
//! A. §III-C pruning: random sampling from the pruned candidate sets vs
//!    uniform sampling over the raw space `[2, uᵢ]` — frontier
//!    hypervolume at equal budget (the paper's claim that raw sampling
//!    "is often ineffective").
//! B. §III-D grouping: per-FIFO vs per-group sampling on a wide design.
//! C. Evaluator memoization: warm vs cold cache across optimizer runs.
//! D. BRAM model accuracy: Algorithm 1 vs the prior-work-style
//!    conservative estimate (ceil(w/18)·ceil(d/1024)) the paper says
//!    overestimates.
//!
//! Run: `cargo bench --bench ablation`

use fifoadvisor::bench_suite;
use fifoadvisor::bram;
use fifoadvisor::dse::{drive, Evaluator};
use fifoadvisor::opt::pareto::{hypervolume_2d, ObjPoint};
use fifoadvisor::opt::random::RandomSearch;
use fifoadvisor::opt::{self, Space};
use fifoadvisor::report::csv::Csv;
use fifoadvisor::trace::collect_trace;
use fifoadvisor::util::Rng;
use std::sync::Arc;

fn front_hv(ev: &Evaluator, ref_point: (u64, u32)) -> f64 {
    let pts: Vec<ObjPoint> = ev
        .history
        .iter()
        .enumerate()
        .filter_map(|(i, p)| {
            p.latency.map(|l| ObjPoint {
                latency: l,
                bram: p.bram,
                index: i,
            })
        })
        .collect();
    hypervolume_2d(&pts, ref_point)
}

fn main() {
    let budget: usize = std::env::var("FIFOADVISOR_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(500);
    let mut csv = Csv::new(&["ablation", "design", "variant", "value"]);

    println!("=== Ablation A: pruned vs raw-uniform sampling (budget {budget}) ===\n");
    for design in ["k15mmseq", "Autoencoder", "k2mm"] {
        let bd = bench_suite::build(design);
        let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&trace);
        let mut ev = Evaluator::parallel(trace.clone(), 8);
        let (maxp, _) = ev.eval_baselines();
        let refp = (maxp.latency.unwrap() * 3, maxp.bram + 1);

        ev.reset_run(true);
        drive(&mut RandomSearch::new(1, false), &mut ev, &space, budget);
        let hv_pruned = front_hv(&ev, refp);

        ev.reset_run(true);
        drive(&mut RandomSearch::new_uniform_raw(1), &mut ev, &space, budget);
        let hv_raw = front_hv(&ev, refp);

        println!(
            "  {design:<16} hypervolume pruned {:.3e} vs raw {:.3e}  ({:.2}x better)",
            hv_pruned,
            hv_raw,
            hv_pruned / hv_raw.max(1e-12)
        );
        csv.row(vec!["pruning".into(), design.into(), "pruned".into(), format!("{hv_pruned:.6e}")]);
        csv.row(vec!["pruning".into(), design.into(), "raw".into(), format!("{hv_raw:.6e}")]);
    }

    println!("\n=== Ablation B: grouped vs per-FIFO sampling ===\n");
    for design in ["FeedForward", "mvt"] {
        let bd = bench_suite::build(design);
        let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&trace);
        let mut ev = Evaluator::parallel(trace.clone(), 8);
        let (maxp, _) = ev.eval_baselines();
        let refp = (maxp.latency.unwrap() * 3, maxp.bram + 1);
        let mut hv = Vec::new();
        for grouped in [false, true] {
            ev.reset_run(true);
            drive(&mut RandomSearch::new(1, grouped), &mut ev, &space, budget);
            hv.push(front_hv(&ev, refp));
        }
        println!(
            "  {design:<16} hypervolume per-fifo {:.3e} vs grouped {:.3e}  ({:.2}x better)",
            hv[0],
            hv[1],
            hv[1] / hv[0].max(1e-12)
        );
        csv.row(vec!["grouping".into(), design.into(), "per_fifo".into(), format!("{:.6e}", hv[0])]);
        csv.row(vec!["grouping".into(), design.into(), "grouped".into(), format!("{:.6e}", hv[1])]);
    }

    println!("\n=== Ablation C: evaluator memoization (grouped_sa, warm vs cold) ===\n");
    {
        let bd = bench_suite::build("k15mmtree");
        let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&trace);
        let mut ev = Evaluator::parallel(trace.clone(), 8);
        // Cold.
        ev.reset_run(true);
        let t0 = std::time::Instant::now();
        drive(&mut *opt::by_name("grouped_sa", 1).unwrap(), &mut ev, &space, budget);
        let cold = t0.elapsed().as_secs_f64();
        let cold_sims = ev.n_sim;
        // Warm (same optimizer re-run with the cache kept).
        ev.reset_run(false);
        let before = ev.n_sim;
        let t0 = std::time::Instant::now();
        drive(&mut *opt::by_name("grouped_sa", 1).unwrap(), &mut ev, &space, budget);
        let warm = t0.elapsed().as_secs_f64();
        let warm_sims = ev.n_sim - before;
        println!(
            "  cold: {cold:.3}s / {cold_sims} sims   warm: {warm:.3}s / {warm_sims} sims  ({:.1}x faster)",
            cold / warm.max(1e-9)
        );
        csv.row(vec!["memo".into(), "k15mmtree".into(), "cold_secs".into(), format!("{cold:.4}")]);
        csv.row(vec!["memo".into(), "k15mmtree".into(), "warm_secs".into(), format!("{warm:.4}")]);
    }

    println!("\n=== Ablation D: Algorithm 1 vs conservative BRAM estimate ===\n");
    {
        let mut rng = Rng::new(7);
        let mut over = Vec::new();
        for _ in 0..10_000 {
            let d = rng.range_u32(3, 20_000);
            let w = rng.range_u32(1, 128);
            let ours = bram::bram_for_fifo(d, w);
            let naive = w.div_ceil(18) * d.div_ceil(1024);
            if ours > 0 {
                over.push(naive as f64 / ours as f64);
            }
        }
        let avg = over.iter().sum::<f64>() / over.len() as f64;
        let max = over.iter().cloned().fold(0.0, f64::max);
        println!(
            "  conservative estimate overcounts by {avg:.2}x on average (max {max:.1}x) over {} samples",
            over.len()
        );
        csv.row(vec!["bram_model".into(), "random".into(), "avg_overcount".into(), format!("{avg:.3}")]);
    }

    csv.write("results/ablation.csv").unwrap();
    println!("\nwrote results/ablation.csv");
}
