//! Fig. 3 reproduction: Pareto frontiers of all five optimizers on the
//! selected designs (k15mmtree, k15mmseq, Autoencoder), with the
//! Baseline-Max/Min anchors and the α=0.7 highlighted points.
//!
//! Run: `cargo bench --bench fig3`
//! Env: FIFOADVISOR_BUDGET (default 1000)

use fifoadvisor::bench_suite;
use fifoadvisor::dse::{drive, Evaluator};
use fifoadvisor::opt::objective::select_highlight;
use fifoadvisor::opt::{self, Space};
use fifoadvisor::report::ascii;
use fifoadvisor::report::csv::Csv;
use fifoadvisor::trace::collect_trace;
use std::sync::Arc;

const DESIGNS: [&str; 3] = ["k15mmtree", "k15mmseq", "Autoencoder"];
const OPTS: [(char, &str); 5] = [
    ('g', "greedy"),
    ('r', "random"),
    ('R', "grouped_random"),
    ('s', "sa"),
    ('S', "grouped_sa"),
];

fn main() {
    let budget: usize = std::env::var("FIFOADVISOR_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1000);
    let mut csv = Csv::new(&["design", "optimizer", "latency", "bram", "highlighted"]);

    for design in DESIGNS {
        let bd = bench_suite::build(design);
        let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&trace);
        let mut ev = Evaluator::parallel(trace.clone(), 8);
        let (base, minp) = ev.eval_baselines();
        let base_lat = base.latency.unwrap();

        println!("\n=== Fig 3: {design} (budget {budget}) ===");
        println!(
            "Baseline-Max ({} cyc, {} BRAM)   Baseline-Min: {}",
            base_lat,
            base.bram,
            match minp.latency {
                Some(l) => format!("({l} cyc, {} BRAM)", minp.bram),
                None => "DEADLOCK ✗".into(),
            }
        );

        let mut plot: Vec<(char, Vec<(f64, f64)>)> = Vec::new();
        for (label, name) in OPTS {
            ev.reset_run(true);
            drive(&mut *opt::by_name(name, 1).unwrap(), &mut ev, &space, budget);
            let front = ev.pareto();
            let pts: Vec<(u64, u32)> =
                front.iter().map(|p| (p.latency.unwrap(), p.bram)).collect();
            let star = select_highlight(&pts, 0.7, base_lat, base.bram);
            for (i, &(l, b)) in pts.iter().enumerate() {
                csv.row(vec![
                    design.to_string(),
                    name.to_string(),
                    l.to_string(),
                    b.to_string(),
                    (Some(i) == star).to_string(),
                ]);
            }
            let (sl, sb) = star.map(|i| pts[i]).unwrap_or((0, 0));
            println!(
                "  {name:<16} front {:>3} pts   ★ lat {:>8} ({:.4}×) bram {:>4}",
                pts.len(),
                sl,
                sl as f64 / base_lat as f64,
                sb
            );
            plot.push((label, pts.iter().map(|&(l, b)| (l as f64, b as f64)).collect()));
        }

        let base_pt = [(base_lat as f64, base.bram as f64)];
        let mut series: Vec<ascii::Series> = plot
            .iter()
            .map(|(label, pts)| ascii::Series {
                label: *label,
                points: pts,
            })
            .collect();
        series.push(ascii::Series {
            label: 'M',
            points: &base_pt,
        });
        println!(
            "{}",
            ascii::scatter(&series, 72, 18, "latency (cycles)", "FIFO BRAM")
        );
    }
    csv.write("results/fig3.csv").unwrap();
    println!("wrote results/fig3.csv");
}
