//! Fig. 4 reproduction: the α=0.7 highlighted Pareto point of every
//! optimizer on every Table II design, compared against (a) Baseline-Max
//! (latency ratio + BRAM reduction) and (b) Baseline-Min (latency ratio +
//! BRAM overhead, with ×→✓ deadlock rescues), plus the per-optimizer
//! aggregate statistics the paper quotes in §IV-B.
//!
//! Run: `cargo bench --bench fig4`
//! Env: FIFOADVISOR_BUDGET (default 1000)

use fifoadvisor::bench_suite::{self, TABLE2_DESIGNS};
use fifoadvisor::dse::{drive, Evaluator};
use fifoadvisor::opt::objective::select_highlight;
use fifoadvisor::opt::{self, Space};
use fifoadvisor::report::csv::Csv;
use fifoadvisor::trace::collect_trace;
use fifoadvisor::util::stats::{geomean, mean};
use std::sync::Arc;

const OPTS: [&str; 5] = ["greedy", "random", "grouped_random", "sa", "grouped_sa"];

fn main() {
    let budget: usize = std::env::var("FIFOADVISOR_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1000);
    println!("=== Fig 4: highlighted points vs baselines (budget {budget}) ===\n");

    let mut csv = Csv::new(&[
        "design",
        "optimizer",
        "star_latency",
        "star_bram",
        "max_latency",
        "max_bram",
        "min_latency",
        "min_bram",
        "min_deadlocked",
        "rescued",
    ]);
    // Per-optimizer aggregates (vs Max: lat ratios + bram reduction %;
    // vs Min: lat ratios + absolute bram overhead).
    let mut lat_ratio_max: Vec<Vec<f64>> = vec![Vec::new(); OPTS.len()];
    let mut bram_red_max: Vec<Vec<f64>> = vec![Vec::new(); OPTS.len()];
    let mut lat_ratio_min: Vec<Vec<f64>> = vec![Vec::new(); OPTS.len()];
    let mut bram_over_min: Vec<Vec<f64>> = vec![Vec::new(); OPTS.len()];
    let mut zero_bram_count = vec![0usize; OPTS.len()];
    let mut rescues = vec![0usize; OPTS.len()];
    let mut deadlocked_designs = 0usize;

    for design in TABLE2_DESIGNS {
        let bd = bench_suite::build(design);
        let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&trace);
        let mut ev = Evaluator::parallel(trace.clone(), 8);
        let (maxp, minp) = ev.eval_baselines();
        let (base_lat, base_bram) = (maxp.latency.unwrap(), maxp.bram);
        if !minp.is_feasible() {
            deadlocked_designs += 1;
        }

        print!("{design:<26}");
        for (k, name) in OPTS.iter().enumerate() {
            ev.reset_run(true);
            drive(&mut *opt::by_name(name, 1).unwrap(), &mut ev, &space, budget);
            let front = ev.pareto();
            let pts: Vec<(u64, u32)> =
                front.iter().map(|p| (p.latency.unwrap(), p.bram)).collect();
            let star = select_highlight(&pts, 0.7, base_lat, base_bram).unwrap();
            let (sl, sb) = pts[star];

            lat_ratio_max[k].push(sl as f64 / base_lat as f64);
            bram_red_max[k]
                .push((base_bram as f64 - sb as f64) / base_bram.max(1) as f64 * 100.0);
            if sb == 0 {
                zero_bram_count[k] += 1;
            }
            let rescued = !minp.is_feasible();
            if rescued {
                rescues[k] += 1;
                // un-deadlocking guaranteed: the front is feasible.
            } else {
                lat_ratio_min[k].push(sl as f64 / minp.latency.unwrap() as f64);
            }
            bram_over_min[k].push(sb as f64); // Baseline-Min bram is always 0
            print!(" | {:.3}x {:>4}B", sl as f64 / base_lat as f64, sb);
            csv.row(vec![
                design.to_string(),
                name.to_string(),
                sl.to_string(),
                sb.to_string(),
                base_lat.to_string(),
                base_bram.to_string(),
                minp.latency.map(|l| l.to_string()).unwrap_or_default(),
                minp.bram.to_string(),
                (!minp.is_feasible()).to_string(),
                rescued.to_string(),
            ]);
        }
        println!();
    }

    println!("\n--- Fig 4(a): vs Baseline-Max (paper values in parens) ---");
    println!(
        "{:<16} {:>16} {:>22} {:>14}",
        "optimizer", "lat geomean", "BRAM reduction avg", "zero-BRAM designs"
    );
    let paper_a = [
        ("greedy", "0.9995x / 85.6%"),
        ("random", "1.40x / 70.6%"),
        ("grouped_random", "1.0026x"),
        ("sa", "1.23x / 79.4%"),
        ("grouped_sa", "0.9994x"),
    ];
    for (k, name) in OPTS.iter().enumerate() {
        println!(
            "{:<16} {:>15.4}x {:>21.1}% {:>10}/21   (paper {})",
            name,
            geomean(&lat_ratio_max[k]).unwrap(),
            mean(&bram_red_max[k]).unwrap(),
            zero_bram_count[k],
            paper_a.iter().find(|p| p.0 == *name).unwrap().1
        );
    }

    println!("\n--- Fig 4(b): vs Baseline-Min ---");
    println!(
        "{:<16} {:>16} {:>18} {:>16}",
        "optimizer", "lat geomean", "BRAM overhead avg", "rescues (×→✓)"
    );
    for (k, name) in OPTS.iter().enumerate() {
        println!(
            "{:<16} {:>15.4}x {:>17.1}B {:>8}/{}",
            name,
            geomean(&lat_ratio_min[k]).unwrap_or(f64::NAN),
            mean(&bram_over_min[k]).unwrap(),
            rescues[k],
            deadlocked_designs
        );
    }
    println!("(paper 4(b): rnd 0.71x/131.0B, SA 0.63x/97.7B, greedy 0.53x/67.4B, grp.rnd 0.53x/13.9B, grp.SA 0.52x/3.0B)");
    csv.write("results/fig4.csv").unwrap();
    println!("\nwrote results/fig4.csv");
}
