//! Fig. 5 reproduction: iso-runtime convergence of the optimizers on
//! k15mmtree — best α-score (α=0.7, vs Baseline-Max) observed so far as
//! a function of wall-clock time, including optimizer logic overhead.
//!
//! Run: `cargo bench --bench fig5`
//! Env: FIFOADVISOR_BUDGET (default 1000)

use fifoadvisor::bench_suite;
use fifoadvisor::dse::{drive, Evaluator};
use fifoadvisor::opt::objective::alpha_score;
use fifoadvisor::opt::{self, Space};
use fifoadvisor::report::ascii;
use fifoadvisor::report::csv::Csv;
use fifoadvisor::trace::collect_trace;
use std::sync::Arc;

const OPTS: [(char, &str); 5] = [
    ('g', "greedy"),
    ('r', "random"),
    ('R', "grouped_random"),
    ('s', "sa"),
    ('S', "grouped_sa"),
];

fn main() {
    let budget: usize = std::env::var("FIFOADVISOR_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1000);
    let design = "k15mmtree";
    let bd = bench_suite::build(design);
    let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
    let space = Space::from_trace(&trace);
    let mut ev = Evaluator::parallel(trace.clone(), 8);
    let (base, _) = ev.eval_baselines();
    let (base_lat, base_bram) = (base.latency.unwrap(), base.bram);

    println!("=== Fig 5: convergence on {design} (budget {budget}) ===\n");
    let mut csv = Csv::new(&["optimizer", "t_secs", "best_score"]);
    let mut plot: Vec<(char, Vec<(f64, f64)>)> = Vec::new();
    for (label, name) in OPTS {
        ev.reset_run(true);
        drive(&mut *opt::by_name(name, 1).unwrap(), &mut ev, &space, budget);
        // Best-so-far α-score over the evaluation history.
        let mut best = f64::INFINITY;
        let mut curve: Vec<(f64, f64)> = Vec::new();
        for p in &ev.history {
            if let Some(l) = p.latency {
                let s = alpha_score(0.7, l, p.bram, base_lat, base_bram);
                if s < best {
                    best = s;
                    curve.push((p.t, s));
                    csv.row(vec![name.to_string(), format!("{:.6}", p.t), format!("{s:.6}")]);
                }
            }
        }
        let total_t = ev.history.last().map(|p| p.t).unwrap_or(0.0);
        curve.push((total_t, best));
        println!(
            "  {name:<16} final best score {best:.4} after {:.2}s ({} evals)",
            total_t,
            ev.n_evals()
        );
        plot.push((label, curve));
    }

    let series: Vec<ascii::Series> = plot
        .iter()
        .map(|(label, pts)| ascii::Series {
            label: *label,
            points: pts,
        })
        .collect();
    println!(
        "\n(g=greedy r=random R=grouped-random s=SA S=grouped-SA; lower is better)\n{}",
        ascii::convergence(&series, 72, 18)
    );
    csv.write("results/fig5.csv").unwrap();
    println!("wrote results/fig5.csv");
}
