//! Fig. 6 reproduction: the FlowGNN-PNA case study (§IV-D) — Pareto
//! frontiers of all optimizers with a 5000-sample budget against the
//! designer-sized Baseline-Max, on a design with data-dependent control
//! flow. All optimizer runs must finish in well under 10 s (the paper's
//! bound).
//!
//! Run: `cargo bench --bench fig6`

use fifoadvisor::bench_suite;
use fifoadvisor::dse::{drive, Evaluator};
use fifoadvisor::opt::objective::select_highlight;
use fifoadvisor::opt::{self, Space};
use fifoadvisor::report::ascii;
use fifoadvisor::report::csv::Csv;
use fifoadvisor::trace::collect_trace;
use std::sync::Arc;

const OPTS: [(char, &str); 5] = [
    ('g', "greedy"),
    ('r', "random"),
    ('R', "grouped_random"),
    ('s', "sa"),
    ('S', "grouped_sa"),
];

fn main() {
    let budget: usize = std::env::var("FIFOADVISOR_BUDGET")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(5000);
    let bd = bench_suite::build("flowgnn_pna");
    let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
    let space = Space::from_trace(&trace);
    let mut ev = Evaluator::parallel(trace.clone(), 8);
    let (designer, minp) = ev.eval_baselines();
    let (base_lat, base_bram) = (designer.latency.unwrap(), designer.bram);

    println!("=== Fig 6: FlowGNN-PNA case study (budget {budget}) ===");
    println!(
        "designer Baseline-Max: {} cycles / {} BRAM;  all-min: {}\n",
        base_lat,
        base_bram,
        if minp.is_feasible() { "feasible" } else { "DEADLOCK" }
    );

    let mut csv = Csv::new(&["optimizer", "latency", "bram", "highlighted", "runtime_secs"]);
    let mut plot: Vec<(char, Vec<(f64, f64)>)> = Vec::new();
    for (label, name) in OPTS {
        ev.reset_run(true);
        let t0 = std::time::Instant::now();
        drive(&mut *opt::by_name(name, 1).unwrap(), &mut ev, &space, budget);
        let dt = t0.elapsed().as_secs_f64();
        let front = ev.pareto();
        let pts: Vec<(u64, u32)> = front.iter().map(|p| (p.latency.unwrap(), p.bram)).collect();
        let star = select_highlight(&pts, 0.7, base_lat, base_bram);
        for (i, &(l, b)) in pts.iter().enumerate() {
            csv.row(vec![
                name.to_string(),
                l.to_string(),
                b.to_string(),
                (Some(i) == star).to_string(),
                format!("{dt:.3}"),
            ]);
        }
        let (sl, sb) = star.map(|i| pts[i]).unwrap_or((0, 0));
        println!(
            "  {name:<16} {:>4} front pts in {dt:>6.2}s   ★ lat {sl} ({:.4}×) bram {sb}",
            pts.len(),
            sl as f64 / base_lat as f64
        );
        assert!(dt < 10.0, "{name}: exceeded the paper's <10 s bound ({dt:.1}s)");
        plot.push((label, pts.iter().map(|&(l, b)| (l as f64, b as f64)).collect()));
    }

    let base_pt = [(base_lat as f64, base_bram as f64)];
    let mut series: Vec<ascii::Series> = plot
        .iter()
        .map(|(label, pts)| ascii::Series {
            label: *label,
            points: pts,
        })
        .collect();
    series.push(ascii::Series {
        label: 'M',
        points: &base_pt,
    });
    println!(
        "\n{}",
        ascii::scatter(&series, 72, 18, "latency (cycles)", "FIFO BRAM")
    );
    csv.write("results/fig6.csv").unwrap();
    println!("wrote results/fig6.csv");
}
