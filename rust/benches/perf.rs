//! §Perf micro-benchmarks: the numbers EXPERIMENTS.md §Perf tracks.
//!
//! 1. Incremental re-simulation latency per design (the paper's "<1 ms
//!    per FIFO size change" headline) + trace-op throughput.
//! 2. Fast vs golden simulator speed ratio.
//! 3. Leader/worker scaling (1→16 threads) on batch evaluation.
//! 4. BRAM analytics backend: native Rust vs the batched analytics
//!    module, per-batch latency and the batch-size crossover.
//! 5. Ask/tell engine throughput: sims/sec serial vs the persistent
//!    worker pool, with cache hit rate and worker utilization.
//! 6. Delta-incremental vs full re-simulation: per-design speedup on
//!    1-channel and 2-channel depth deltas, with a bit-identical check
//!    between both paths (a mismatch aborts the bench).
//! 7. Scenario-bank evaluation: workload eval throughput over a 4-graph
//!    FlowGNN-PNA workload and the per-scenario incremental hit rate on
//!    a DSE-shaped mutation walk (a walk with zero incremental replays
//!    aborts the bench).
//! 8. Simulation-free pruning: end-to-end `optimize` runs
//!    (greedy/SA/NSGA-II on the fig2 and FlowGNN workloads, serial and
//!    `--jobs 4`) with pruning on vs off — oracle/clamp hit rates, sims
//!    avoided, scenario-replay reduction, and wall clock. Hard asserts:
//!    bit-identical histories/fronts, a nonzero pruning hit fraction,
//!    never more sims, strictly fewer scenario replays. Wall clock is
//!    guarded with deliberate slack (2× + 0.25 s) so CI noise on tiny
//!    workloads cannot flake — the sim counts are the real guarantee.
//! 9. Graph-compiled backend (`--backend compiled`) vs fast: repeated-eval
//!    throughput over the fig2 and FlowGNN workloads on delta (mutation)
//!    and cold (re-randomized) walks, with a full-outcome identity assert
//!    on every step and a hard assert that compiled throughput is ≥ fast
//!    on at least one (workload, walk) cell.
//! 10. Lane-batched backend (`--backend batched`) vs compiled:
//!    configs-per-second at batch sizes K ∈ {1, 8, 64, 256} over the
//!    fig2 and FlowGNN workloads, one shared random config stream per
//!    workload. Hard asserts: primary-trace full-outcome identity
//!    (latency, deadlock verdict AND blocked set) of every lane against
//!    `CompiledSim`, bank-level latency identity on every step of every
//!    K cell, and batched throughput ≥ compiled on at least one K ≥ 8
//!    cell.
//! 11. Analytic depth bounds: (a) engine-toggle A/B on the shared
//!    bounded space — bit-identical histories/fronts with the bounds
//!    layer on vs off, never more sims; (b) full-pipeline A/B — the
//!    bounded space + engine bounds vs the pre-bounds pipeline
//!    (write-count space, engine layer off) on the fig2, k15mmtree, and
//!    FlowGNN suites, comparing total simulations and wall clock under
//!    the same proposal budget. Hard asserts: identical min-latency
//!    corner in both arms (the cap-soundness theorem end-to-end) and a
//!    strict simulation reduction on at least one of the k15mmtree /
//!    FlowGNN suites.
//! 12. Scenario-bank distillation: distilled vs full-bank optimization
//!    (SA and grouped SA on the fig2, mini_dnn, and FlowGNN workloads)
//!    under the same proposal budget, comparing inner-loop per-scenario
//!    simulations and wall clock. Hard asserts: bit-identical histories
//!    and fronts between the distilled fixpoint and the from-scratch
//!    full-bank run on every cell, and a strict inner-loop
//!    scenario-simulation reduction on the fig2 workload (where the
//!    n = 16 scenario dominates its siblings).
//!
//! Run: `cargo bench --bench perf`. Besides `results/perf.csv` it writes
//! machine-readable snapshots: `BENCH_2.json` (every §Perf 1–6 metric
//! row), `BENCH_3.json` (the §Perf 7 scenario-bank rows), `BENCH_4.json`
//! (the §Perf 8 pruning rows), `BENCH_5.json` (the §Perf 9 backend
//! comparison rows), `BENCH_6.json` (the §Perf 10 lane-batched rows),
//! `BENCH_8.json` (the §Perf 11 depth-bounds rows), and `BENCH_9.json`
//! (the §Perf 12 distillation rows).
//! Set `FIFOADVISOR_PERF_SMOKE=1` for a reduced-iteration run (the CI
//! regression smoke): same sections, same correctness assertions, far
//! fewer samples.

use fifoadvisor::bench_suite;
use fifoadvisor::dse::pool::parallel_latencies;
use fifoadvisor::dse::{BramBatch, EvalEngine, NativeBram};
use fifoadvisor::report::csv::Csv;
use fifoadvisor::runtime::{BatchAnalytics, XlaBram};
use fifoadvisor::sim::fast::FastSim;
use fifoadvisor::sim::golden::simulate_golden;
use fifoadvisor::sim::{BackendKind, ScenarioSim, SimOptions};
use fifoadvisor::trace::collect_trace;
use fifoadvisor::util::stats::{fmt_duration, Summary};
use fifoadvisor::util::{Json, Rng};
use std::sync::Arc;
use std::time::Instant;

fn time_n<F: FnMut()>(n: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..n {
        f();
    }
    t0.elapsed().as_secs_f64() / n as f64
}

fn main() {
    let smoke = std::env::var("FIFOADVISOR_PERF_SMOKE").is_ok();
    if smoke {
        println!("(FIFOADVISOR_PERF_SMOKE set: reduced-iteration run)\n");
    }
    let mut csv = Csv::new(&["metric", "design", "value", "unit"]);

    println!("=== §Perf 1: incremental re-simulation latency ===\n");
    println!(
        "{:<26} {:>10} {:>12} {:>12} {:>14}",
        "design", "trace ops", "median", "p95", "ops/sec"
    );
    let designs = [
        "bicg",
        "gemm",
        "k15mmtree",
        "Autoencoder",
        "FeedForward",
        "ResidualBlock",
    ];
    for name in designs {
        let bd = bench_suite::build(name);
        let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let mut sim = FastSim::new(trace.clone());
        let ub = trace.upper_bounds();
        let mut rng = Rng::new(1);
        // Random configs, pre-generated (measure sim only).
        let n_cfg = if smoke { 12 } else { 64 };
        let configs: Vec<Vec<u32>> = (0..n_cfg)
            .map(|_| ub.iter().map(|&u| rng.range_u32(2, u.max(2))).collect())
            .collect();
        sim.simulate(&configs[0]); // warm
        let mut times = Vec::new();
        for c in &configs {
            let t0 = Instant::now();
            let _ = sim.simulate(c);
            times.push(t0.elapsed().as_secs_f64());
        }
        let s = Summary::of(&times);
        println!(
            "{:<26} {:>10} {:>12} {:>12} {:>14.2e}",
            name,
            trace.total_ops(),
            fmt_duration(s.median),
            fmt_duration(s.p95),
            trace.total_ops() as f64 / s.median
        );
        csv.row(vec![
            "resim_median_secs".into(),
            name.into(),
            format!("{:.6e}", s.median),
            "s".into(),
        ]);
    }

    println!("\n=== §Perf 2: fast vs golden simulator ===\n");
    for name in ["gemm", "k15mmtree"] {
        let bd = bench_suite::build(name);
        let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let cfg = trace.baseline_max();
        let mut sim = FastSim::new(trace.clone());
        let t_fast = time_n(10, || {
            let _ = sim.simulate(&cfg);
        });
        let t_gold = time_n(3, || {
            let _ = simulate_golden(&trace, &cfg, SimOptions::default());
        });
        println!(
            "{name:<26} fast {} vs golden {}  ({:.0}x)",
            fmt_duration(t_fast),
            fmt_duration(t_gold),
            t_gold / t_fast
        );
        csv.row(vec![
            "fast_vs_golden_ratio".into(),
            name.into(),
            format!("{:.1}", t_gold / t_fast),
            "x".into(),
        ]);
    }

    println!("\n=== §Perf 3: leader/worker scaling (FeedForward, 128-config batch) ===\n");
    {
        let bd = bench_suite::build("FeedForward");
        let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let proto = FastSim::new(trace.clone());
        let ub = trace.upper_bounds();
        let mut rng = Rng::new(2);
        let configs: Vec<Box<[u32]>> = (0..128)
            .map(|_| {
                ub.iter()
                    .map(|&u| rng.range_u32(2, u.max(2)))
                    .collect::<Box<[u32]>>()
            })
            .collect();
        let t1 = time_n(3, || {
            let _ = parallel_latencies(&proto, &configs, 1);
        });
        for threads in [2usize, 4, 8, 16] {
            let t = time_n(3, || {
                let _ = parallel_latencies(&proto, &configs, threads);
            });
            println!(
                "  {threads:>2} threads: {} per batch  (speedup {:.2}x)",
                fmt_duration(t),
                t1 / t
            );
            csv.row(vec![
                format!("pool_speedup_{threads}"),
                "FeedForward".into(),
                format!("{:.3}", t1 / t),
                "x".into(),
            ]);
        }
    }

    println!("\n=== §Perf 4: BRAM analytics backend (256-config batch, 848 FIFOs) ===\n");
    {
        let f = 848usize;
        let mut rng = Rng::new(3);
        let widths: Vec<u32> = (0..f).map(|_| *rng.choose(&[8u32, 32, 64])).collect();
        let configs: Vec<Box<[u32]>> = (0..256)
            .map(|_| {
                (0..f)
                    .map(|_| rng.range_u32(2, 8192))
                    .collect::<Box<[u32]>>()
            })
            .collect();
        let mut native = NativeBram;
        let t_native = time_n(20, || {
            let _ = native.bram_totals(&configs, &widths);
        });
        println!(
            "  native Rust       : {} per 256-config batch",
            fmt_duration(t_native)
        );
        csv.row(vec![
            "bram_native_secs".into(),
            "848f".into(),
            format!("{t_native:.6e}"),
            "s".into(),
        ]);
        match BatchAnalytics::load_default() {
            Ok(a) => {
                let mut xla = XlaBram::new(a);
                let _ = xla.bram_totals(&configs[..1], &widths); // warm/compile
                let t_xla = time_n(10, || {
                    let _ = xla.bram_totals(&configs, &widths);
                });
                println!(
                    "  XLA/PJRT artifact : {} per 256-config batch ({} also computes β-grid scores + dominance mask)",
                    fmt_duration(t_xla),
                    if t_xla > t_native { "note: artifact" } else { "artifact" }
                );
                csv.row(vec![
                    "bram_xla_secs".into(),
                    "848f".into(),
                    format!("{t_xla:.6e}"),
                    "s".into(),
                ]);
            }
            Err(e) => println!("  analytics backend unavailable ({e})"),
        }
    }

    println!("\n=== §Perf 5: ask/tell engine throughput (FeedForward, 256-config batch) ===\n");
    {
        let bd = bench_suite::build("FeedForward");
        let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let ub = trace.upper_bounds();
        let mut rng = Rng::new(4);
        let configs: Vec<Box<[u32]>> = (0..256)
            .map(|_| {
                ub.iter()
                    .map(|&u| rng.range_u32((u / 2).max(2), u.max(2)))
                    .collect::<Box<[u32]>>()
            })
            .collect();
        let mut serial_rate = 0.0;
        for jobs in [1usize, 2, 4, 8] {
            let mut ev = EvalEngine::parallel(trace.clone(), jobs);
            ev.eval_batch(&configs); // warm (cold cache)
            ev.reset_run(true);
            ev.eval_batch(&configs);
            let rate = ev.sims_per_sec();
            if jobs == 1 {
                serial_rate = rate;
            }
            println!(
                "  {jobs:>2} jobs: {rate:>9.0} sims/s  (speedup {:.2}x, utilization {:.0}%)",
                rate / serial_rate.max(1e-9),
                ev.worker_utilization() * 100.0
            );
            csv.row(vec![
                format!("engine_sims_per_sec_{jobs}"),
                "FeedForward".into(),
                format!("{rate:.1}"),
                "sims/s".into(),
            ]);
        }
    }

    println!("\n=== §Perf 6: delta-incremental vs full re-simulation ===\n");
    println!(
        "{:<26} {:>10} {:>11} {:>11} {:>9} {:>11} {:>9}",
        "design", "trace ops", "full med", "Δ1ch med", "speedup", "Δ2ch med", "speedup"
    );
    for name in [
        "gemm",
        "k15mmtree",
        "Autoencoder",
        "FeedForward",
        "ResidualBlock",
    ] {
        let bd = bench_suite::build(name);
        let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let base = trace.baseline_max();
        let nch = base.len();
        // The redraw loop below needs at least one channel that can move.
        assert!(base.iter().any(|&d| d > 2), "{name}: degenerate bounds");
        let steps = if smoke { 16 } else { 96 };
        let mut speedups: Vec<f64> = Vec::new();
        let mut incr_meds: Vec<f64> = Vec::new();
        let mut full_meds: Vec<f64> = Vec::new();
        for (label, delta_channels) in [("1ch", 1usize), ("2ch", 2usize)] {
            // A DSE-shaped walk: each step mutates `delta_channels` FIFOs
            // of the previous configuration (±1 steps and collapses — the
            // SA/greedy move shapes), starting from Baseline-Max.
            let mut rng = Rng::new(6);
            let mut cur = base.clone();
            let mut walk: Vec<Vec<u32>> = Vec::with_capacity(steps);
            for _ in 0..steps {
                // Every step must actually change the configuration —
                // otherwise the warm run's identical-config short-circuit
                // (zero work) would flatter the measured delta cost.
                let prev_cfg = cur.clone();
                while cur == prev_cfg {
                    for _ in 0..delta_channels {
                        let i = rng.index(nch);
                        cur[i] = match rng.below(3) {
                            0 => base[i].max(3) - 1,
                            1 => 2,
                            _ => base[i],
                        };
                    }
                }
                walk.push(cur.clone());
            }
            // Cold reference: full replay every step.
            let mut cold = FastSim::new(trace.clone());
            cold.set_incremental(false);
            let mut t_full = Vec::with_capacity(steps);
            let mut full_lats = Vec::with_capacity(steps);
            for cfg in &walk {
                let t0 = Instant::now();
                full_lats.push(cold.simulate(cfg).latency());
                t_full.push(t0.elapsed().as_secs_f64());
            }
            // Warm run: delta replay against the retained schedule.
            let mut warm = FastSim::new(trace.clone());
            warm.simulate(&base);
            let mut t_incr = Vec::with_capacity(steps);
            let mut replayed = 0u64;
            let mut total = 0u64;
            for (cfg, full_lat) in walk.iter().zip(&full_lats) {
                let t0 = Instant::now();
                let lat = warm.simulate(cfg).latency();
                t_incr.push(t0.elapsed().as_secs_f64());
                // CI guard: a delta replay that diverges from the full
                // replay is a correctness bug, not a perf number.
                assert_eq!(
                    lat, *full_lat,
                    "incremental/full mismatch on {name} ({label}) cfg {cfg:?}"
                );
                replayed += warm.last_run().replayed_ops;
                total += warm.last_run().total_ops;
            }
            let sf = Summary::of(&t_full);
            let si = Summary::of(&t_incr);
            full_meds.push(sf.median);
            incr_meds.push(si.median);
            let speedup = sf.median / si.median.max(1e-12);
            speedups.push(speedup);
            csv.row(vec![
                format!("incr_resim_median_secs_{label}"),
                name.into(),
                format!("{:.6e}", si.median),
                "s".into(),
            ]);
            csv.row(vec![
                format!("incr_speedup_{label}"),
                name.into(),
                format!("{speedup:.2}"),
                "x".into(),
            ]);
            csv.row(vec![
                format!("incr_replay_fraction_{label}"),
                name.into(),
                format!("{:.4}", replayed as f64 / total.max(1) as f64),
                "".into(),
            ]);
        }
        csv.row(vec![
            "full_resim_median_secs".into(),
            name.into(),
            format!("{:.6e}", full_meds[0]),
            "s".into(),
        ]);
        println!(
            "{:<26} {:>10} {:>11} {:>11} {:>8.1}x {:>11} {:>8.1}x",
            name,
            trace.total_ops(),
            fmt_duration(full_meds[0]),
            fmt_duration(incr_meds[0]),
            speedups[0],
            fmt_duration(incr_meds[1]),
            speedups[1]
        );
    }

    println!("\n=== §Perf 7: scenario-bank evaluation (FlowGNN-PNA workload) ===\n");
    let mut scen_rows: Vec<Json> = Vec::new();
    {
        let w = bench_suite::build_workload("flowgnn_pna").unwrap();
        let k = w.num_scenarios();
        let label = format!("flowgnn_pna[{k}]");
        let base = w.baseline_max();
        let nch = base.len();
        let mut sim = ScenarioSim::new(&w);
        sim.simulate(&base); // warm every scenario's retained schedule

        // A DSE-shaped walk: each step mutates one FIFO of the previous
        // configuration (±1 steps and collapses).
        let steps = if smoke { 24 } else { 128 };
        let mut rng = Rng::new(9);
        let mut cur = base.clone();
        let mut times = Vec::with_capacity(steps);
        let mut incr_evals = 0u64;
        let mut per_scen_incr = vec![0u64; k];
        for _ in 0..steps {
            let prev = cur.clone();
            while cur == prev {
                let i = rng.index(nch);
                cur[i] = match rng.below(3) {
                    0 => base[i].max(3) - 1,
                    1 => 2,
                    _ => base[i],
                };
            }
            let t0 = Instant::now();
            let _ = sim.simulate(&cur);
            times.push(t0.elapsed().as_secs_f64());
            if sim.last_run().incremental {
                incr_evals += 1;
            }
            for (s, r) in per_scen_incr.iter_mut().zip(sim.scenario_runs()) {
                if r.incremental {
                    *s += 1;
                }
            }
        }
        // CI guard (workload acceptance): per-scenario delta replay must
        // engage on single-channel mutation walks.
        assert!(
            incr_evals > 0,
            "multi-scenario walk produced no incremental replays"
        );
        let s = Summary::of(&times);
        println!(
            "{label:<26} {} scenarios, {} total trace ops: median eval {} ({:.0} workload evals/s)",
            k,
            w.total_ops(),
            fmt_duration(s.median),
            1.0 / s.median.max(1e-12)
        );
        let mut push = |metric: String, design: String, value: f64, unit: &str| {
            csv.row(vec![
                metric.clone(),
                design.clone(),
                format!("{value:.6e}"),
                unit.into(),
            ]);
            scen_rows.push(Json::obj(vec![
                ("metric", Json::Str(metric)),
                ("design", Json::Str(design)),
                ("value", Json::Num(value)),
                ("unit", Json::Str(unit.into())),
            ]));
        };
        push(
            "scenario_eval_median_secs".into(),
            label.clone(),
            s.median,
            "s",
        );
        push(
            "scenario_evals_per_sec".into(),
            label.clone(),
            1.0 / s.median.max(1e-12),
            "evals/s",
        );
        push(
            "scenario_incr_rate".into(),
            label.clone(),
            incr_evals as f64 / steps as f64,
            "",
        );
        // Per-scenario columns: one incremental-hit-rate row per graph.
        for (name, hits) in w
            .scenarios()
            .iter()
            .map(|sc| sc.name.clone())
            .zip(&per_scen_incr)
        {
            let rate = *hits as f64 / steps as f64;
            println!("    {name:<20} incremental hit rate {:.0}%", rate * 100.0);
            push(
                "scenario_incr_hit_rate".into(),
                format!("{label}/{name}"),
                rate,
                "",
            );
        }
    }

    println!("\n=== §Perf 8: simulation-free pruning (oracle + clamp + early exit) ===\n");
    let mut prune_rows: Vec<Json> = Vec::new();
    {
        use fifoadvisor::dse::drive;
        use fifoadvisor::opt::{self, Space};

        type HistoryRecord = Vec<(Box<[u32]>, Option<u64>, u32)>;
        fn history_of(ev: &EvalEngine) -> HistoryRecord {
            ev.history
                .iter()
                .map(|p| (p.depths.clone(), p.latency, p.bram))
                .collect()
        }

        let budget = if smoke { 120 } else { 400 };
        let optimizers = ["greedy", "grouped_sa", "nsga2"];
        for wname in ["fig2", "flowgnn_pna"] {
            let w = Arc::new(bench_suite::build_workload(wname).unwrap());
            let k = w.num_scenarios();
            let space = Space::from_workload(&w);
            // The channel with the largest merged write count: collapsing
            // it to depth 2 is a guaranteed deadlock on these workloads
            // (it must buffer a burst its reader cannot drain yet).
            let caps: Vec<u64> = (0..w.num_fifos())
                .map(|ch| {
                    w.scenarios()
                        .iter()
                        .map(|s| s.trace.channels[ch].writes)
                        .max()
                        .unwrap()
                })
                .collect();
            let hot = caps
                .iter()
                .enumerate()
                .max_by_key(|&(i, &c)| (c, std::cmp::Reverse(i)))
                .unwrap()
                .0;
            for jobs in [1usize, 4] {
                let mut ev_p = EvalEngine::for_workload(w.clone(), jobs);
                let mut ev_u = EvalEngine::for_workload(w.clone(), jobs);
                ev_u.set_prune(false);
                let (mut secs_p, mut secs_u) = (0.0f64, 0.0f64);
                let (mut sims_p, mut sims_u) = (0u64, 0u64);
                let (mut scen_p, mut scen_u) = (0u64, 0u64);
                let (mut oracle_hits, mut clamp_hits, mut avoided) = (0u64, 0u64, 0u64);
                let mut proposals = 0u64;
                for oname in optimizers {
                    ev_p.reset_run(true);
                    ev_u.reset_run(true);
                    let t0 = Instant::now();
                    drive(&mut *opt::by_name(oname, 11).unwrap(), &mut ev_p, &space, budget);
                    let tp = t0.elapsed().as_secs_f64();
                    let t0 = Instant::now();
                    drive(&mut *opt::by_name(oname, 11).unwrap(), &mut ev_u, &space, budget);
                    let tu = t0.elapsed().as_secs_f64();
                    // CI guard: pruning must be invisible in the results —
                    // bit-identical histories and Pareto fronts.
                    assert_eq!(
                        history_of(&ev_p),
                        history_of(&ev_u),
                        "{wname}/{oname} jobs={jobs}: pruned history diverged"
                    );
                    let front = |ev: &EvalEngine| -> Vec<(Option<u64>, u32)> {
                        ev.pareto().iter().map(|p| (p.latency, p.bram)).collect()
                    };
                    assert_eq!(front(&ev_p), front(&ev_u), "{wname}/{oname}: front diverged");
                    let (sp, su) = (ev_p.stats(), ev_u.stats());
                    assert!(sp.sims <= su.sims, "{wname}/{oname}: pruning added sims");
                    secs_p += tp;
                    secs_u += tu;
                    sims_p += sp.sims;
                    sims_u += su.sims;
                    scen_p += sp.scenario_sims;
                    scen_u += su.scenario_sims;
                    oracle_hits += sp.oracle_hits;
                    clamp_hits += sp.clamp_hits;
                    avoided += sp.sims_avoided;
                    proposals += sp.proposals;
                    if jobs == 1 {
                        println!(
                            "  {wname:<14} {oname:<10} sims {:>5} → {:>5}  scen-sims {:>6} → {:>6}  \
                             orcl {:>4} clmp {:>4}  {} vs {}",
                            su.sims,
                            sp.sims,
                            su.scenario_sims,
                            sp.scenario_sims,
                            sp.oracle_hits,
                            sp.clamp_hits,
                            fmt_duration(tu),
                            fmt_duration(tp)
                        );
                    }
                }
                // Deterministic probe phase (cold caches, both arms): a
                // collapsed hot channel deadlocks; the all-2 probe is
                // component-wise below it, so the pruned arm must answer
                // it from the oracle while the unpruned arm re-simulates.
                ev_p.reset_run(true);
                ev_u.reset_run(true);
                let mut probe_a = space.bounds.clone();
                probe_a[hot] = 2;
                let probe_b = vec![2u32; w.num_fifos()];
                for probe in [&probe_a, &probe_b] {
                    let rp = ev_p.eval(probe);
                    let ru = ev_u.eval(probe);
                    assert_eq!(rp, ru, "{wname}: probe diverged");
                    assert_eq!(rp.0, None, "{wname}: probe {probe:?} should deadlock");
                }
                assert!(
                    ev_p.stats().oracle_hits >= 1,
                    "{wname} jobs={jobs}: dominated probe must be oracle-answered"
                );
                sims_p += ev_p.stats().sims;
                sims_u += ev_u.stats().sims;
                scen_p += ev_p.stats().scenario_sims;
                scen_u += ev_u.stats().scenario_sims;
                oracle_hits += ev_p.stats().oracle_hits;
                clamp_hits += ev_p.stats().clamp_hits;
                avoided += ev_p.stats().sims_avoided;
                proposals += ev_p.stats().proposals;

                // §Perf 8 acceptance: pruning answers a nonzero fraction
                // of proposals, strictly reduces per-scenario replays,
                // and is never (meaningfully) slower. The wall-clock
                // bound carries generous slack — the hard guarantees are
                // the sim counts and bit-identical results above.
                assert!(
                    oracle_hits + clamp_hits > 0,
                    "{wname} jobs={jobs}: pruning never engaged"
                );
                assert!(
                    scen_p < scen_u,
                    "{wname} jobs={jobs}: pruning must strictly reduce scenario replays \
                     ({scen_p} vs {scen_u})"
                );
                assert!(
                    secs_p <= secs_u * 2.0 + 0.25,
                    "{wname} jobs={jobs}: pruning slower than no-prune ({secs_p:.3}s vs {secs_u:.3}s)"
                );
                let label = format!("{wname}[{k}]x{jobs}");
                println!(
                    "  {label:<18} total: sims {sims_u} → {sims_p}, scenario replays {scen_u} → \
                     {scen_p}, {oracle_hits} oracle / {clamp_hits} clamp hits, {avoided} avoided, \
                     wall {} → {}",
                    fmt_duration(secs_u),
                    fmt_duration(secs_p)
                );
                let mut push = |metric: &str, value: f64, unit: &str| {
                    csv.row(vec![
                        metric.to_string(),
                        label.clone(),
                        format!("{value:.6e}"),
                        unit.into(),
                    ]);
                    prune_rows.push(Json::obj(vec![
                        ("metric", Json::Str(metric.into())),
                        ("design", Json::Str(label.clone())),
                        ("value", Json::Num(value)),
                        ("unit", Json::Str(unit.into())),
                    ]));
                };
                push("prune_proposals", proposals as f64, "");
                push("prune_oracle_hits", oracle_hits as f64, "");
                push("prune_clamp_hits", clamp_hits as f64, "");
                push("prune_sims_avoided", avoided as f64, "");
                push(
                    "prune_hit_fraction",
                    (oracle_hits + clamp_hits) as f64 / proposals.max(1) as f64,
                    "",
                );
                push("prune_sims", sims_p as f64, "");
                push("prune_sims_noprune", sims_u as f64, "");
                push("prune_scenario_sims", scen_p as f64, "");
                push("prune_scenario_sims_noprune", scen_u as f64, "");
                push("prune_optimize_secs", secs_p, "s");
                push("prune_optimize_secs_noprune", secs_u, "s");
                push(
                    "prune_speedup",
                    secs_u / secs_p.max(1e-12),
                    "x",
                );
            }
        }
    }

    println!("\n=== §Perf 9: graph-compiled vs fast backend (repeated evaluation) ===\n");
    let mut backend_rows: Vec<Json> = Vec::new();
    {
        /// Evaluate a pre-generated walk on one backend, returning the
        /// best-of-`reps` throughput (scheduler noise on a shared CI
        /// runner hits single timings hard; the max over independent
        /// repetitions is the standard de-flake) and every full outcome
        /// of the last repetition (for the identity assert — outcomes
        /// are deterministic, so any repetition would do).
        fn run_walk(
            w: &fifoadvisor::Workload,
            base: &[u32],
            walk: &[Vec<u32>],
            kind: BackendKind,
            delta: bool,
            reps: usize,
        ) -> (f64, Vec<fifoadvisor::SimOutcome>) {
            let mut best = 0.0f64;
            let mut outs = Vec::new();
            for _ in 0..reps {
                let mut bank = ScenarioSim::with_backend(w, SimOptions::default(), kind);
                if delta {
                    bank.simulate(base); // warm every scenario's retained schedule
                } else {
                    bank.set_incremental(false); // cold full pass every step
                }
                let mut o = Vec::with_capacity(walk.len());
                let t0 = Instant::now();
                for cfg in walk {
                    o.push(bank.simulate(cfg));
                }
                let dt = t0.elapsed().as_secs_f64();
                best = best.max(walk.len() as f64 / dt.max(1e-12));
                outs = o;
            }
            (best, outs)
        }

        let steps = if smoke { 48 } else { 256 };
        let (mut wins, mut cells) = (0usize, 0usize);
        for wname in ["fig2", "flowgnn_pna"] {
            let w = bench_suite::build_workload(wname).unwrap();
            let k = w.num_scenarios();
            let ub = w.upper_bounds();
            let base = w.baseline_max();
            let nch = base.len();
            for (mode, delta_walk) in [("delta", true), ("cold", false)] {
                // One shared walk per cell so both backends see byte-equal
                // inputs: DSE-shaped single-channel mutations for the
                // delta cells, fresh random configurations for the cold
                // cells.
                let mut rng = Rng::new(0xBEC5 ^ wname.len() as u64);
                let mut cur = base.clone();
                let mut walk: Vec<Vec<u32>> = Vec::with_capacity(steps);
                for _ in 0..steps {
                    if delta_walk {
                        let prev = cur.clone();
                        while cur == prev {
                            let i = rng.index(nch);
                            cur[i] = match rng.below(3) {
                                0 => base[i].max(3) - 1,
                                1 => 2,
                                _ => base[i],
                            };
                        }
                    } else {
                        cur = ub.iter().map(|&u| rng.range_u32(2, u.max(2))).collect();
                    }
                    walk.push(cur.clone());
                }
                let (fast_rate, fast_outs) =
                    run_walk(&w, &base, &walk, BackendKind::Fast, delta_walk, 3);
                let (comp_rate, comp_outs) =
                    run_walk(&w, &base, &walk, BackendKind::Compiled, delta_walk, 3);
                // CI guard: the backends must be bit-identical on every
                // step — latency, deadlock verdict, and blocked sets.
                for (i, (f, c)) in fast_outs.iter().zip(&comp_outs).enumerate() {
                    assert_eq!(
                        f, c,
                        "{wname}/{mode} step {i}: compiled != fast on cfg {:?}",
                        walk[i]
                    );
                }
                cells += 1;
                if comp_rate >= fast_rate {
                    wins += 1;
                }
                println!(
                    "  {wname:<14}[{k}] {mode:<5}: fast {fast_rate:>9.0} evals/s, \
                     compiled {comp_rate:>9.0} evals/s ({:.2}x)",
                    comp_rate / fast_rate.max(1e-12)
                );
                let label = format!("{wname}[{k}]/{mode}");
                let mut push = |metric: &str, value: f64, unit: &str| {
                    csv.row(vec![
                        metric.to_string(),
                        label.clone(),
                        format!("{value:.6e}"),
                        unit.into(),
                    ]);
                    backend_rows.push(Json::obj(vec![
                        ("metric", Json::Str(metric.into())),
                        ("design", Json::Str(label.clone())),
                        ("value", Json::Num(value)),
                        ("unit", Json::Str(unit.into())),
                    ]));
                };
                push("backend_eval_rate_fast", fast_rate, "evals/s");
                push("backend_eval_rate_compiled", comp_rate, "evals/s");
                push(
                    "backend_compiled_speedup",
                    comp_rate / fast_rate.max(1e-12),
                    "x",
                );
            }
        }
        // §Perf 9 acceptance: the graph-compiled backend matches or beats
        // the fast simulator somewhere. The identity asserts above are
        // the correctness guarantee; this throughput claim rides on
        // best-of-3 timings across 4 independent cells, so a single
        // noisy measurement cannot flip it.
        assert!(
            wins >= 1,
            "compiled backend won {wins}/{cells} throughput cells — expected ≥ 1"
        );
        println!("  compiled ≥ fast in {wins}/{cells} cells");
    }

    println!("\n=== §Perf 10: lane-batched vs compiled backend (batch evaluation) ===\n");
    let mut batched_rows: Vec<Json> = Vec::new();
    {
        use fifoadvisor::{BatchedSim, CompiledSim, SimOutcome};

        let total = if smoke { 64 } else { 512 };
        let reps = 3;
        let (mut wins, mut cells) = (0usize, 0usize);
        for wname in ["fig2", "flowgnn_pna"] {
            let w = bench_suite::build_workload(wname).unwrap();
            let nscen = w.num_scenarios();
            let ub = w.upper_bounds();
            // One shared random config stream per workload: every K cell
            // chunks the same `total` configurations, so rates are
            // comparable across batch sizes and against the per-config
            // compiled reference.
            let mut rng = Rng::new(0xBA7C ^ wname.len() as u64);
            let cfgs: Vec<Box<[u32]>> = (0..total)
                .map(|_| ub.iter().map(|&u| rng.range_u32(2, u.max(2))).collect())
                .collect();

            // Primary-trace conformance: every lane of a ragged batched
            // walk over the stream carries the exact full SimOutcome
            // (latency, deadlock verdict, blocked set) the compiled
            // backend computes for that configuration alone.
            {
                let t = Arc::clone(w.primary());
                let mut bat = BatchedSim::new(Arc::clone(&t));
                let mut comp = CompiledSim::new(t);
                comp.set_incremental(false);
                for chunk in cfgs.chunks(48) {
                    for ((out, _), cfg) in bat.eval_batch(chunk).iter().zip(chunk) {
                        assert_eq!(
                            *out,
                            comp.simulate(cfg),
                            "{wname}: batched lane != compiled on cfg {cfg:?}"
                        );
                    }
                }
            }

            // Compiled reference rate: per-config bank evaluation, cold
            // (the configs are re-randomized, matching §Perf 9's cold
            // cells and the always-cold batched walk).
            let mut comp_rate = 0.0f64;
            let mut lat_c: Vec<Option<u64>> = Vec::new();
            for _ in 0..reps {
                let mut bank =
                    ScenarioSim::with_backend(&w, SimOptions::default(), BackendKind::Compiled);
                bank.set_incremental(false);
                let mut l = Vec::with_capacity(total);
                let t0 = Instant::now();
                for cfg in &cfgs {
                    l.push(match bank.simulate(cfg) {
                        SimOutcome::Done { latency } => Some(latency),
                        SimOutcome::Deadlock { .. } => None,
                    });
                }
                let dt = t0.elapsed().as_secs_f64();
                comp_rate = comp_rate.max(total as f64 / dt.max(1e-12));
                lat_c = l;
            }

            let label_w = format!("{wname}[{nscen}]");
            {
                let mut push = |metric: &str, value: f64, unit: &str| {
                    csv.row(vec![
                        metric.to_string(),
                        label_w.clone(),
                        format!("{value:.6e}"),
                        unit.into(),
                    ]);
                    batched_rows.push(Json::obj(vec![
                        ("metric", Json::Str(metric.into())),
                        ("design", Json::Str(label_w.clone())),
                        ("value", Json::Num(value)),
                        ("unit", Json::Str(unit.into())),
                    ]));
                };
                push("batched_ref_rate_compiled", comp_rate, "cfgs/s");
            }

            for kk in [1usize, 8, 64, 256] {
                let mut bat_rate = 0.0f64;
                let mut lat_b: Vec<Option<u64>> = Vec::new();
                for _ in 0..reps {
                    let mut bank =
                        ScenarioSim::with_backend(&w, SimOptions::default(), BackendKind::Batched);
                    let mut l = Vec::with_capacity(total);
                    let t0 = Instant::now();
                    for chunk in cfgs.chunks(kk) {
                        for le in bank.eval_batch(chunk, true) {
                            l.push(le.latency);
                        }
                    }
                    let dt = t0.elapsed().as_secs_f64();
                    bat_rate = bat_rate.max(total as f64 / dt.max(1e-12));
                    lat_b = l;
                }
                // CI guard: bank-level latency identity on every step.
                for (i, (b, c)) in lat_b.iter().zip(&lat_c).enumerate() {
                    assert_eq!(
                        b, c,
                        "{wname}/K{kk} step {i}: batched != compiled on cfg {:?}",
                        cfgs[i]
                    );
                }
                if kk >= 8 {
                    cells += 1;
                    if bat_rate >= comp_rate {
                        wins += 1;
                    }
                }
                println!(
                    "  {wname:<14}[{nscen}] K={kk:<4}: batched {bat_rate:>9.0} cfgs/s, \
                     compiled {comp_rate:>9.0} cfgs/s ({:.2}x)",
                    bat_rate / comp_rate.max(1e-12)
                );
                let label = format!("{label_w}/K{kk}");
                let mut push = |metric: &str, value: f64, unit: &str| {
                    csv.row(vec![
                        metric.to_string(),
                        label.clone(),
                        format!("{value:.6e}"),
                        unit.into(),
                    ]);
                    batched_rows.push(Json::obj(vec![
                        ("metric", Json::Str(metric.into())),
                        ("design", Json::Str(label.clone())),
                        ("value", Json::Num(value)),
                        ("unit", Json::Str(unit.into())),
                    ]));
                };
                push("batched_eval_rate", bat_rate, "cfgs/s");
                push(
                    "batched_speedup_vs_compiled",
                    bat_rate / comp_rate.max(1e-12),
                    "x",
                );
            }
        }
        // §Perf 10 acceptance: lane batching matches or beats per-config
        // compiled evaluation at some K ≥ 8. The identity asserts above
        // are the correctness guarantee; the throughput claim rides on
        // best-of-3 timings across 6 independent K ≥ 8 cells.
        assert!(
            wins >= 1,
            "batched backend won {wins}/{cells} K ≥ 8 throughput cells — expected ≥ 1"
        );
        println!("  batched ≥ compiled in {wins}/{cells} K ≥ 8 cells");
    }

    println!("\n=== §Perf 11: analytic depth bounds (search-space collapse) ===\n");
    let mut bounds_rows: Vec<Json> = Vec::new();
    {
        use fifoadvisor::dse::drive;
        use fifoadvisor::opt::bounds::DepthBounds;
        use fifoadvisor::opt::{self, Space};
        use fifoadvisor::Workload;

        type HistoryRecord = Vec<(Box<[u32]>, Option<u64>, u32)>;
        fn history_of(ev: &EvalEngine) -> HistoryRecord {
            ev.history
                .iter()
                .map(|p| (p.depths.clone(), p.latency, p.bram))
                .collect()
        }
        fn front_of(ev: &EvalEngine) -> Vec<(Option<u64>, u32)> {
            ev.pareto().iter().map(|p| (p.latency, p.bram)).collect()
        }

        let budget = if smoke { 120 } else { 400 };
        let optimizers = ["greedy", "grouped_sa"];
        let suites: Vec<(&str, Arc<Workload>)> = vec![
            ("fig2", Arc::new(bench_suite::build_workload("fig2").unwrap())),
            ("k15mmtree", {
                let bd = bench_suite::build("k15mmtree");
                Arc::new(Workload::single(Arc::new(
                    collect_trace(&bd.design, &bd.args).unwrap(),
                )))
            }),
            (
                "flowgnn_pna",
                Arc::new(bench_suite::build_workload("flowgnn_pna").unwrap()),
            ),
        ];
        let mut reduced = 0usize;
        for (wname, w) in &suites {
            let db = DepthBounds::for_workload(w);
            let space_on = Space::from_workload(w);
            let space_off = Space::from_workload_unbounded(w);
            let cands =
                |s: &Space| -> f64 { s.per_fifo.iter().map(|c| c.len() as f64).product() };

            // (a) Engine toggle on the shared bounded space: the bounds
            // layer must be invisible in the results — bit-identical
            // histories and fronts, never more sims.
            let (mut t_sims_on, mut t_sims_off, mut floor_hits) = (0u64, 0u64, 0u64);
            for oname in optimizers {
                let mut ev_on = EvalEngine::for_workload(w.clone(), 1);
                let mut ev_off = EvalEngine::for_workload(w.clone(), 1);
                ev_off.set_bounds(false);
                ev_on.eval_baselines();
                ev_off.eval_baselines();
                drive(&mut *opt::by_name(oname, 13).unwrap(), &mut ev_on, &space_on, budget);
                drive(&mut *opt::by_name(oname, 13).unwrap(), &mut ev_off, &space_on, budget);
                assert_eq!(
                    history_of(&ev_on),
                    history_of(&ev_off),
                    "{wname}/{oname}: bounds toggle changed the history"
                );
                assert_eq!(
                    front_of(&ev_on),
                    front_of(&ev_off),
                    "{wname}/{oname}: bounds toggle changed the front"
                );
                assert!(
                    ev_on.stats().sims <= ev_off.stats().sims,
                    "{wname}/{oname}: bounds added sims"
                );
                t_sims_on += ev_on.stats().sims;
                t_sims_off += ev_off.stats().sims;
                floor_hits += ev_on.stats().bounds_floor_hits;
            }

            // (b) Full pipeline A/B under the same proposal budget: the
            // bounded space with the engine layer on vs the pre-bounds
            // pipeline (write-count candidate ranges, engine layer off).
            let (mut p_sims_on, mut p_sims_off) = (0u64, 0u64);
            let (mut p_secs_on, mut p_secs_off) = (0.0f64, 0.0f64);
            for oname in optimizers {
                let mut ev_on = EvalEngine::for_workload(w.clone(), 1);
                let t0 = Instant::now();
                ev_on.eval_baselines();
                drive(&mut *opt::by_name(oname, 13).unwrap(), &mut ev_on, &space_on, budget);
                p_secs_on += t0.elapsed().as_secs_f64();
                let mut ev_off = EvalEngine::for_workload(w.clone(), 1);
                ev_off.set_bounds(false);
                let t0 = Instant::now();
                ev_off.eval_baselines();
                drive(&mut *opt::by_name(oname, 13).unwrap(), &mut ev_off, &space_off, budget);
                p_secs_off += t0.elapsed().as_secs_f64();
                // Cap-soundness end-to-end: both arms carry their
                // Baseline-Max corner, and raising any depth above the
                // tightened cap cannot change the outcome — so the
                // minimal achievable latency must agree exactly.
                let min_lat = |f: &[(Option<u64>, u32)]| {
                    f.iter().filter_map(|&(l, _)| l).min().unwrap()
                };
                assert_eq!(
                    min_lat(&front_of(&ev_on)),
                    min_lat(&front_of(&ev_off)),
                    "{wname}/{oname}: bounded arm lost the min-latency corner"
                );
                p_sims_on += ev_on.stats().sims;
                p_sims_off += ev_off.stats().sims;
            }
            if *wname != "fig2" && p_sims_on < p_sims_off {
                reduced += 1;
            }
            println!(
                "  {wname:<14} {} floor(s) / {} tightened cap(s): space {:.3e} → {:.3e} configs, \
                 toggle sims {} → {} ({} floor hits), pipeline sims {} → {}, wall {} → {}",
                db.num_floored(),
                db.num_cap_tightenings(),
                cands(&space_off),
                cands(&space_on),
                t_sims_off,
                t_sims_on,
                floor_hits,
                p_sims_off,
                p_sims_on,
                fmt_duration(p_secs_off),
                fmt_duration(p_secs_on)
            );
            let mut push = |metric: &str, value: f64, unit: &str| {
                csv.row(vec![
                    metric.to_string(),
                    wname.to_string(),
                    format!("{value:.6e}"),
                    unit.into(),
                ]);
                bounds_rows.push(Json::obj(vec![
                    ("metric", Json::Str(metric.into())),
                    ("design", Json::Str(wname.to_string())),
                    ("value", Json::Num(value)),
                    ("unit", Json::Str(unit.into())),
                ]));
            };
            push("bounds_analytic_floors", db.num_floored() as f64, "");
            push("bounds_cap_tightenings", db.num_cap_tightenings() as f64, "");
            push("bounds_space_configs", cands(&space_on), "configs");
            push("bounds_space_configs_unbounded", cands(&space_off), "configs");
            push("bounds_toggle_sims", t_sims_on as f64, "");
            push("bounds_toggle_sims_off", t_sims_off as f64, "");
            push("bounds_floor_hits", floor_hits as f64, "");
            push("bounds_pipeline_sims", p_sims_on as f64, "");
            push("bounds_pipeline_sims_off", p_sims_off as f64, "");
            push(
                "bounds_pipeline_sims_saved",
                p_sims_off.saturating_sub(p_sims_on) as f64,
                "",
            );
            push("bounds_pipeline_secs", p_secs_on, "s");
            push("bounds_pipeline_secs_off", p_secs_off, "s");
        }
        // §Perf 11 acceptance: the bounds pass must strictly reduce
        // simulations-to-frontier on at least one non-toy suite. fig2 is
        // reported for reference but excluded from the gate.
        assert!(
            reduced >= 1,
            "bounds reduced pipeline sims on neither k15mmtree nor flowgnn_pna"
        );
    }

    println!("\n=== §Perf 12: scenario-bank distillation (distilled vs full bank) ===\n");
    let mut distill_rows: Vec<Json> = Vec::new();
    {
        use fifoadvisor::dse::advhunt::DistillConfig;
        use fifoadvisor::dse::{drive, optimize_distilled};
        use fifoadvisor::opt::{self, Space};

        type HistoryRecord = Vec<(Box<[u32]>, Option<u64>, u32)>;
        fn history_of(pts: &[fifoadvisor::dse::EvalPoint]) -> HistoryRecord {
            pts.iter()
                .map(|p| (p.depths.clone(), p.latency, p.bram))
                .collect()
        }

        let budget = if smoke { 120 } else { 400 };
        let optimizers = ["sa", "grouped_sa"];
        let mut fig2_reduced = false;
        for wname in ["fig2", "mini_dnn", "flowgnn_pna"] {
            let w = Arc::new(bench_suite::build_workload(wname).unwrap());
            let k = w.num_scenarios();
            let space = Space::from_workload(&w);
            let (mut inner, mut verify, mut full_scen) = (0u64, 0u64, 0u64);
            let (mut secs_d, mut secs_f) = (0.0f64, 0.0f64);
            let (mut kept_init, mut kept_fin, mut promoted, mut iterations) =
                (0usize, 0usize, 0usize, 0usize);
            for oname in optimizers {
                let cfg = DistillConfig {
                    optimizer: oname.to_string(),
                    seed: 17,
                    budget,
                    ..DistillConfig::default()
                };
                let t0 = Instant::now();
                let out = optimize_distilled(&w, &space, &cfg);
                secs_d += t0.elapsed().as_secs_f64();

                // Full-bank reference, same optimizer + seed.
                let mut full = EvalEngine::for_workload(w.clone(), 1);
                let t0 = Instant::now();
                full.eval_baselines();
                drive(&mut *opt::by_name(oname, 17).unwrap(), &mut full, &space, budget);
                secs_f += t0.elapsed().as_secs_f64();

                // CI guard: distillation must be invisible in the results.
                assert_eq!(
                    history_of(&out.history),
                    history_of(&full.history),
                    "{wname}/{oname}: distilled history diverged"
                );
                let ref_front: Vec<(Option<u64>, u32)> =
                    full.pareto().iter().map(|p| (p.latency, p.bram)).collect();
                let got_front: Vec<(Option<u64>, u32)> =
                    out.front.iter().map(|p| (p.latency, p.bram)).collect();
                assert_eq!(got_front, ref_front, "{wname}/{oname}: front diverged");
                // Scenarios the distilled bank keeps can only re-run what
                // the full bank runs: inner-loop work never grows.
                assert!(
                    out.inner_scenario_sims <= full.stats().scenario_sims,
                    "{wname}/{oname}: distilled inner loop ran MORE scenario sims"
                );
                inner += out.inner_scenario_sims;
                verify += out.verify_scenario_sims;
                full_scen += full.stats().scenario_sims;
                kept_init += out.kept_initial.len();
                kept_fin += out.kept_final.len();
                promoted += out.promotions.len();
                iterations += out.iterations;
            }
            if wname == "fig2" && inner < full_scen {
                fig2_reduced = true;
            }
            let label = format!("{wname}[{k}]");
            println!(
                "  {label:<18} kept {}/{} (+{} promoted, {} fixpoint iter): inner scen-sims \
                 {full_scen} → {inner} (+{verify} verify), wall {} → {}",
                kept_fin,
                k * optimizers.len(),
                promoted,
                iterations,
                fmt_duration(secs_f),
                fmt_duration(secs_d)
            );
            let mut push = |metric: &str, value: f64, unit: &str| {
                csv.row(vec![
                    metric.to_string(),
                    label.clone(),
                    format!("{value:.6e}"),
                    unit.into(),
                ]);
                distill_rows.push(Json::obj(vec![
                    ("metric", Json::Str(metric.into())),
                    ("design", Json::Str(label.clone())),
                    ("value", Json::Num(value)),
                    ("unit", Json::Str(unit.into())),
                ]));
            };
            push("distill_kept_initial", kept_init as f64, "");
            push("distill_kept_final", kept_fin as f64, "");
            push("distill_promotions", promoted as f64, "");
            push("distill_iterations", iterations as f64, "");
            push("distill_inner_scenario_sims", inner as f64, "");
            push("distill_verify_scenario_sims", verify as f64, "");
            push("distill_full_scenario_sims", full_scen as f64, "");
            push(
                "distill_scenario_sims_saved",
                full_scen.saturating_sub(inner) as f64,
                "",
            );
            push(
                "distill_inner_fraction",
                inner as f64 / full_scen.max(1) as f64,
                "",
            );
            push("distill_optimize_secs", secs_d, "s");
            push("distill_optimize_secs_full", secs_f, "s");
        }
        // §Perf 12 acceptance: on the fig2 workload the n = 16 scenario
        // dominates its siblings, so the distilled inner loop must run
        // strictly fewer per-scenario simulations than the full bank.
        // The bit-identity asserts above are the correctness guarantee.
        assert!(
            fig2_reduced,
            "distillation did not reduce fig2's inner-loop scenario sims"
        );
    }

    csv.write("results/perf.csv").unwrap();
    println!("\nwrote results/perf.csv");

    let snapshot9 = Json::obj(vec![
        ("bench", Json::Str("distill".into())),
        ("schema", Json::Str("metric-rows/v1".into())),
        ("smoke", Json::Bool(smoke)),
        ("rows", Json::Arr(distill_rows)),
    ]);
    fifoadvisor::report::write_file("BENCH_9.json", &snapshot9.to_string_pretty()).unwrap();
    println!("wrote BENCH_9.json");

    let snapshot8 = Json::obj(vec![
        ("bench", Json::Str("bounds".into())),
        ("schema", Json::Str("metric-rows/v1".into())),
        ("smoke", Json::Bool(smoke)),
        ("rows", Json::Arr(bounds_rows)),
    ]);
    fifoadvisor::report::write_file("BENCH_8.json", &snapshot8.to_string_pretty()).unwrap();
    println!("wrote BENCH_8.json");

    let snapshot6 = Json::obj(vec![
        ("bench", Json::Str("batched_backend".into())),
        ("schema", Json::Str("metric-rows/v1".into())),
        ("smoke", Json::Bool(smoke)),
        ("rows", Json::Arr(batched_rows)),
    ]);
    fifoadvisor::report::write_file("BENCH_6.json", &snapshot6.to_string_pretty()).unwrap();
    println!("wrote BENCH_6.json");

    let snapshot5 = Json::obj(vec![
        ("bench", Json::Str("backend_compare".into())),
        ("schema", Json::Str("metric-rows/v1".into())),
        ("smoke", Json::Bool(smoke)),
        ("rows", Json::Arr(backend_rows)),
    ]);
    fifoadvisor::report::write_file("BENCH_5.json", &snapshot5.to_string_pretty()).unwrap();
    println!("wrote BENCH_5.json");

    let snapshot4 = Json::obj(vec![
        ("bench", Json::Str("pruning".into())),
        ("schema", Json::Str("metric-rows/v1".into())),
        ("smoke", Json::Bool(smoke)),
        ("rows", Json::Arr(prune_rows)),
    ]);
    fifoadvisor::report::write_file("BENCH_4.json", &snapshot4.to_string_pretty()).unwrap();
    println!("wrote BENCH_4.json");

    let snapshot3 = Json::obj(vec![
        ("bench", Json::Str("scenario_bank".into())),
        ("schema", Json::Str("metric-rows/v1".into())),
        ("smoke", Json::Bool(smoke)),
        ("rows", Json::Arr(scen_rows)),
    ]);
    fifoadvisor::report::write_file("BENCH_3.json", &snapshot3.to_string_pretty()).unwrap();
    println!("wrote BENCH_3.json");

    // Machine-readable perf snapshot (the §Perf trajectory file). The
    // §Perf 7 scenario rows live in BENCH_3.json only, the §Perf 8
    // pruning rows in BENCH_4.json only, the §Perf 9 backend rows in
    // BENCH_5.json only, the §Perf 10 lane-batched rows in BENCH_6.json
    // only, the §Perf 11 depth-bounds rows in BENCH_8.json only, and the
    // §Perf 12 distillation rows in BENCH_9.json only, so BENCH_2.json
    // stays row-for-row comparable with pre-workload snapshots.
    let rows_json: Vec<Json> = csv
        .rows()
        .iter()
        .filter(|r| {
            !r[0].starts_with("scenario_")
                && !r[0].starts_with("prune_")
                && !r[0].starts_with("backend_")
                && !r[0].starts_with("batched_")
                && !r[0].starts_with("bounds_")
                && !r[0].starts_with("distill_")
        })
        .map(|r| {
            let value = match r[2].parse::<f64>() {
                Ok(v) => Json::Num(v),
                Err(_) => Json::Str(r[2].clone()),
            };
            Json::obj(vec![
                ("metric", Json::Str(r[0].clone())),
                ("design", Json::Str(r[1].clone())),
                ("value", value),
                ("unit", Json::Str(r[3].clone())),
            ])
        })
        .collect();
    let snapshot = Json::obj(vec![
        ("bench", Json::Str("perf".into())),
        ("schema", Json::Str("metric-rows/v1".into())),
        ("smoke", Json::Bool(smoke)),
        ("rows", Json::Arr(rows_json)),
    ]);
    fifoadvisor::report::write_file("BENCH_2.json", &snapshot.to_string_pretty()).unwrap();
    println!("wrote BENCH_2.json ({} metric rows)", csv.len());
}
