//! §Perf micro-benchmarks: the numbers EXPERIMENTS.md §Perf tracks.
//!
//! 1. Incremental re-simulation latency per design (the paper's "<1 ms
//!    per FIFO size change" headline) + trace-op throughput.
//! 2. Fast vs golden simulator speed ratio.
//! 3. Leader/worker scaling (1→16 threads) on batch evaluation.
//! 4. BRAM analytics backend: native Rust vs the batched analytics
//!    module, per-batch latency and the batch-size crossover.
//! 5. Ask/tell engine throughput: sims/sec serial vs the persistent
//!    worker pool, with cache hit rate and worker utilization.
//!
//! Run: `cargo bench --bench perf`

use fifoadvisor::bench_suite;
use fifoadvisor::dse::pool::parallel_latencies;
use fifoadvisor::dse::{BramBatch, EvalEngine, NativeBram};
use fifoadvisor::report::csv::Csv;
use fifoadvisor::runtime::{BatchAnalytics, XlaBram};
use fifoadvisor::sim::fast::FastSim;
use fifoadvisor::sim::golden::simulate_golden;
use fifoadvisor::sim::SimOptions;
use fifoadvisor::trace::collect_trace;
use fifoadvisor::util::stats::{fmt_duration, Summary};
use fifoadvisor::util::Rng;
use std::sync::Arc;
use std::time::Instant;

fn time_n<F: FnMut()>(n: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..n {
        f();
    }
    t0.elapsed().as_secs_f64() / n as f64
}

fn main() {
    let mut csv = Csv::new(&["metric", "design", "value", "unit"]);

    println!("=== §Perf 1: incremental re-simulation latency ===\n");
    println!(
        "{:<26} {:>10} {:>12} {:>12} {:>14}",
        "design", "trace ops", "median", "p95", "ops/sec"
    );
    let designs = [
        "bicg",
        "gemm",
        "k15mmtree",
        "Autoencoder",
        "FeedForward",
        "ResidualBlock",
    ];
    for name in designs {
        let bd = bench_suite::build(name);
        let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let mut sim = FastSim::new(trace.clone());
        let ub = trace.upper_bounds();
        let mut rng = Rng::new(1);
        // Random configs, pre-generated (measure sim only).
        let configs: Vec<Vec<u32>> = (0..64)
            .map(|_| ub.iter().map(|&u| rng.range_u32(2, u.max(2))).collect())
            .collect();
        sim.simulate(&configs[0]); // warm
        let mut times = Vec::new();
        for c in &configs {
            let t0 = Instant::now();
            let _ = sim.simulate(c);
            times.push(t0.elapsed().as_secs_f64());
        }
        let s = Summary::of(&times);
        println!(
            "{:<26} {:>10} {:>12} {:>12} {:>14.2e}",
            name,
            trace.total_ops(),
            fmt_duration(s.median),
            fmt_duration(s.p95),
            trace.total_ops() as f64 / s.median
        );
        csv.row(vec![
            "resim_median_secs".into(),
            name.into(),
            format!("{:.6e}", s.median),
            "s".into(),
        ]);
    }

    println!("\n=== §Perf 2: fast vs golden simulator ===\n");
    for name in ["gemm", "k15mmtree"] {
        let bd = bench_suite::build(name);
        let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let cfg = trace.baseline_max();
        let mut sim = FastSim::new(trace.clone());
        let t_fast = time_n(10, || {
            let _ = sim.simulate(&cfg);
        });
        let t_gold = time_n(3, || {
            let _ = simulate_golden(&trace, &cfg, SimOptions::default());
        });
        println!(
            "{name:<26} fast {} vs golden {}  ({:.0}x)",
            fmt_duration(t_fast),
            fmt_duration(t_gold),
            t_gold / t_fast
        );
        csv.row(vec![
            "fast_vs_golden_ratio".into(),
            name.into(),
            format!("{:.1}", t_gold / t_fast),
            "x".into(),
        ]);
    }

    println!("\n=== §Perf 3: leader/worker scaling (FeedForward, 128-config batch) ===\n");
    {
        let bd = bench_suite::build("FeedForward");
        let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let proto = FastSim::new(trace.clone());
        let ub = trace.upper_bounds();
        let mut rng = Rng::new(2);
        let configs: Vec<Box<[u32]>> = (0..128)
            .map(|_| {
                ub.iter()
                    .map(|&u| rng.range_u32(2, u.max(2)))
                    .collect::<Box<[u32]>>()
            })
            .collect();
        let t1 = time_n(3, || {
            let _ = parallel_latencies(&proto, &configs, 1);
        });
        for threads in [2usize, 4, 8, 16] {
            let t = time_n(3, || {
                let _ = parallel_latencies(&proto, &configs, threads);
            });
            println!(
                "  {threads:>2} threads: {} per batch  (speedup {:.2}x)",
                fmt_duration(t),
                t1 / t
            );
            csv.row(vec![
                format!("pool_speedup_{threads}"),
                "FeedForward".into(),
                format!("{:.3}", t1 / t),
                "x".into(),
            ]);
        }
    }

    println!("\n=== §Perf 4: BRAM analytics backend (256-config batch, 848 FIFOs) ===\n");
    {
        let f = 848usize;
        let mut rng = Rng::new(3);
        let widths: Vec<u32> = (0..f).map(|_| *rng.choose(&[8u32, 32, 64])).collect();
        let configs: Vec<Box<[u32]>> = (0..256)
            .map(|_| {
                (0..f)
                    .map(|_| rng.range_u32(2, 8192))
                    .collect::<Box<[u32]>>()
            })
            .collect();
        let mut native = NativeBram;
        let t_native = time_n(20, || {
            let _ = native.bram_totals(&configs, &widths);
        });
        println!(
            "  native Rust       : {} per 256-config batch",
            fmt_duration(t_native)
        );
        csv.row(vec![
            "bram_native_secs".into(),
            "848f".into(),
            format!("{t_native:.6e}"),
            "s".into(),
        ]);
        match BatchAnalytics::load_default() {
            Ok(a) => {
                let mut xla = XlaBram::new(a);
                let _ = xla.bram_totals(&configs[..1], &widths); // warm/compile
                let t_xla = time_n(10, || {
                    let _ = xla.bram_totals(&configs, &widths);
                });
                println!(
                    "  XLA/PJRT artifact : {} per 256-config batch ({} also computes β-grid scores + dominance mask)",
                    fmt_duration(t_xla),
                    if t_xla > t_native { "note: artifact" } else { "artifact" }
                );
                csv.row(vec![
                    "bram_xla_secs".into(),
                    "848f".into(),
                    format!("{t_xla:.6e}"),
                    "s".into(),
                ]);
            }
            Err(e) => println!("  analytics backend unavailable ({e})"),
        }
    }

    println!("\n=== §Perf 5: ask/tell engine throughput (FeedForward, 256-config batch) ===\n");
    {
        let bd = bench_suite::build("FeedForward");
        let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let ub = trace.upper_bounds();
        let mut rng = Rng::new(4);
        let configs: Vec<Box<[u32]>> = (0..256)
            .map(|_| {
                ub.iter()
                    .map(|&u| rng.range_u32((u / 2).max(2), u.max(2)))
                    .collect::<Box<[u32]>>()
            })
            .collect();
        let mut serial_rate = 0.0;
        for jobs in [1usize, 2, 4, 8] {
            let mut ev = EvalEngine::parallel(trace.clone(), jobs);
            ev.eval_batch(&configs); // warm (cold cache)
            ev.reset_run(true);
            ev.eval_batch(&configs);
            let rate = ev.sims_per_sec();
            if jobs == 1 {
                serial_rate = rate;
            }
            println!(
                "  {jobs:>2} jobs: {rate:>9.0} sims/s  (speedup {:.2}x, utilization {:.0}%)",
                rate / serial_rate.max(1e-9),
                ev.worker_utilization() * 100.0
            );
            csv.row(vec![
                format!("engine_sims_per_sec_{jobs}"),
                "FeedForward".into(),
                format!("{rate:.1}"),
                "sims/s".into(),
            ]);
        }
    }

    csv.write("results/perf.csv").unwrap();
    println!("\nwrote results/perf.csv");
}
