//! Table II reproduction: fast-simulator accuracy against the golden
//! reference on all 21 evaluation designs, at Baseline-Max depths (the
//! configuration the paper co-simulates).
//!
//! In the paper the reference is Vitis C/RTL co-simulation and
//! LightningSim is within one cycle on 20/21 designs; here the reference
//! is the independent cycle-stepped golden simulator and agreement is
//! exact by construction of shared semantics — divergence would flag an
//! implementation bug. Also reports both simulators' runtimes (the
//! Table II rationale: the trace-based simulator is the fast one).
//!
//! Run: `cargo bench --bench table2`

use fifoadvisor::bench_suite::{self, TABLE2_DESIGNS};
use fifoadvisor::report::csv::Csv;
use fifoadvisor::sim::fast::FastSim;
use fifoadvisor::sim::golden::simulate_golden;
use fifoadvisor::sim::SimOptions;
use fifoadvisor::trace::collect_trace;
use fifoadvisor::util::stats::fmt_duration;
use std::sync::Arc;
use std::time::Instant;

/// Paper Table II (design, FIFOs, co-sim cycles) for side-by-side print.
const PAPER: &[(&str, u32, u64)] = &[
    ("atax", 175, 2180),
    ("Autoencoder", 392, 39178),
    ("bicg", 25, 1112),
    ("DepthSepConvBlock", 84, 134541),
    ("FeedForward", 848, 65997),
    ("gemm", 88, 24051),
    ("k2mm", 64, 36352),
    ("k3mm", 95, 49092),
    ("k7mmseq_balanced", 112, 5684),
    ("k7mmseq_unbalanced", 108, 10036),
    ("k7mmtree_unbalanced", 128, 8750),
    ("mvt", 288, 667),
    ("ResidualBlock", 64, 2092531),
    ("k15mmseq_imbalanced", 59, 7802),
    ("k15mmseq", 188, 61052),
    ("k15mmseq_relu_imbalanced", 116, 8504),
    ("k15mmseq_relu", 232, 28838),
    ("k15mmtree_imbalanced", 163, 16237),
    ("k15mmtree", 192, 20326),
    ("k15mmtree_relu_imbalanced", 340, 16489),
    ("k15mmtree_relu", 320, 17277),
];

fn main() {
    println!("=== Table II: simulator cycle accuracy (Baseline-Max) ===\n");
    println!(
        "{:<26} {:>6} {:>6} | {:>10} {:>10} {:>5} | {:>10} {:>10} | {:>12}",
        "design", "FIFOs", "paper", "golden", "fast", "diff", "t_golden", "t_fast", "paper cycles"
    );
    let mut csv = Csv::new(&[
        "design",
        "fifos",
        "paper_fifos",
        "golden_cycles",
        "fast_cycles",
        "diff",
        "golden_secs",
        "fast_secs",
        "paper_cycles",
    ]);
    let mut all_match = true;
    for name in TABLE2_DESIGNS {
        let paper = PAPER.iter().find(|p| p.0 == name).unwrap();
        let bd = bench_suite::build(name);
        let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let cfg = trace.baseline_max();

        let mut fast = FastSim::new(trace.clone());
        fast.simulate(&cfg); // warm
        let t0 = Instant::now();
        let f = fast.simulate(&cfg).latency().unwrap();
        let t_fast = t0.elapsed().as_secs_f64();

        let t0 = Instant::now();
        let g = simulate_golden(&trace, &cfg, SimOptions::default())
            .latency()
            .unwrap();
        let t_golden = t0.elapsed().as_secs_f64();

        let diff = if f == g {
            "✓".to_string()
        } else {
            all_match = false;
            format!("{:+.2}%", (f as f64 - g as f64) / g as f64 * 100.0)
        };
        println!(
            "{:<26} {:>6} {:>6} | {:>10} {:>10} {:>5} | {:>10} {:>10} | {:>12}",
            name,
            trace.num_fifos(),
            paper.1,
            g,
            f,
            diff,
            fmt_duration(t_golden),
            fmt_duration(t_fast),
            paper.2
        );
        csv.row(vec![
            name.to_string(),
            trace.num_fifos().to_string(),
            paper.1.to_string(),
            g.to_string(),
            f.to_string(),
            diff.clone(),
            format!("{t_golden:.6}"),
            format!("{t_fast:.6}"),
            paper.2.to_string(),
        ]);
    }
    csv.write("results/table2.csv").unwrap();
    println!(
        "\n{} — wrote results/table2.csv",
        if all_match {
            "all designs: fast == golden exactly (paper: ≤1 cycle on 20/21)"
        } else {
            "MISMATCHES FOUND — simulator bug"
        }
    );
    assert!(all_match);
}
