//! Table III reproduction: FIFOAdvisor search runtime vs estimated
//! HLS/RTL co-simulation search runtime (1000 samples, co-sim with 32
//! perfectly-parallel workers), per design × optimizer, with the speedup
//! geomean per optimizer column.
//!
//! Run: `cargo bench --bench table3`
//! Env: FIFOADVISOR_BUDGET (default 1000), FIFOADVISOR_THREADS (8)

use fifoadvisor::bench_suite;
use fifoadvisor::dse::{drive, Evaluator};
use fifoadvisor::opt::{self, Space};
use fifoadvisor::report::csv::Csv;
use fifoadvisor::sim::cosim;
use fifoadvisor::trace::collect_trace;
use fifoadvisor::util::stats::{fmt_duration, geomean};
use std::sync::Arc;

const OPTS: [&str; 5] = ["greedy", "random", "grouped_random", "sa", "grouped_sa"];

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let budget = env_usize("FIFOADVISOR_BUDGET", 1000);
    let threads = env_usize("FIFOADVISOR_THREADS", 8);
    println!(
        "=== Table III: search runtime, budget {budget}, {threads} worker threads, co-sim PAR=32 ===\n"
    );
    println!(
        "{:<26} {:>12} | {:>10} {:>10} {:>10} {:>10} {:>10}",
        "design", "co-sim(est)", "greedy", "rnd", "grp.rnd", "SA", "grp.SA"
    );
    let mut csv = Csv::new(&[
        "design", "cosim_secs", "greedy_secs", "random_secs", "grouped_random_secs", "sa_secs",
        "grouped_sa_secs",
    ]);
    let mut speedups: Vec<Vec<f64>> = vec![Vec::new(); OPTS.len()];

    for name in bench_suite::all_names() {
        let bd = bench_suite::build(name);
        let trace = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&trace);
        let mut ev = Evaluator::parallel(trace.clone(), threads);

        // Co-sim estimate: best-case per-run time (Baseline-Max = fewest
        // cycles) × budget / 32 — the paper's conservative lower bound.
        let base_cycles = {
            ev.eval(&trace.baseline_max()).0.unwrap()
        };
        let cosim_secs = cosim::cosim_search_secs(base_cycles, trace.num_fifos(), budget as u64, 32);

        let mut row = vec![name.to_string(), format!("{cosim_secs:.1}")];
        let mut cells = Vec::new();
        for (k, opt_name) in OPTS.iter().enumerate() {
            ev.reset_run(true);
            let mut o = opt::by_name(opt_name, 1).unwrap();
            let t0 = std::time::Instant::now();
            drive(&mut *o, &mut ev, &space, budget);
            let dt = t0.elapsed().as_secs_f64().max(1e-6);
            speedups[k].push(cosim_secs / dt);
            row.push(format!("{dt:.3}"));
            cells.push(fmt_duration(dt));
        }
        println!(
            "{:<26} {:>12} | {:>10} {:>10} {:>10} {:>10} {:>10}",
            name,
            fmt_duration(cosim_secs),
            cells[0],
            cells[1],
            cells[2],
            cells[3],
            cells[4]
        );
        csv.row(row);
    }

    print!("\nspeedup geomean          {:>12} |", "");
    for s in &speedups {
        let g = geomean(s).unwrap();
        print!(" 10^{:.2}   ", g.log10());
    }
    println!("\n(paper: 10^6.53 10^6.88 10^6.91 10^6.20 10^6.19)");
    csv.write("results/table3.csv").unwrap();
    println!("wrote results/table3.csv");
}
