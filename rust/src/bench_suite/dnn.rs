//! Deep-learning block kernels from the Stream-HLS suite: FeedForward,
//! Autoencoder, ResidualBlock, DepthSepConvBlock, ResMLP — plus the
//! data-dependent [`mini_dnn`] special, whose deadlock thresholds depend
//! on its runtime tiling arguments (a second non-FlowGNN target for the
//! adversarial scenario hunter).

use super::stages::{self, F32, W8};
use super::BenchDesign;
use crate::ir::{DesignBuilder, Expr};

/// Transformer FFN block: `y = W2·gelu(W1·x + b1) + b2`, very wide PE
/// array. Paper: 848 FIFOs, 65997 cycles.
pub fn feedforward() -> BenchDesign {
    let p = 106;
    let mut b = DesignBuilder::new("FeedForward", 0);
    let ws = stages::port_sources(&mut b, "W", &[("w1", p, 128), ("w2", p, 128)], W8);
    let x = stages::source(&mut b, "x", p, 128, F32);
    let h = stages::matmul(&mut b, "h", &x, &ws[0], 8, 16, 0);
    let g = stages::map(&mut b, "gelu", &h, 2);
    let rep = stages::replay(&mut b, "h_rep", &g, 8); // 128 tokens
    let y = stages::matmul(&mut b, "y", &rep, &ws[1], 8, 16, 0);
    let out = stages::map(&mut b, "bias", &y, 1);
    stages::sink(&mut b, "store_y", &out, 0);
    BenchDesign::new(b.build())
}

/// 4-layer MLP autoencoder (encode ×2, decode ×2), ReLU between layers.
/// Paper: 392 FIFOs, 39178 cycles.
pub fn autoencoder() -> BenchDesign {
    let p = 24;
    let mut b = DesignBuilder::new("Autoencoder", 0);
    let ws = stages::port_sources(
        &mut b,
        "W",
        &[("w1", p, 512), ("w2", p, 256), ("w3", p, 256), ("w4", p, 512)],
        W8,
    );
    let x = stages::source(&mut b, "x", p, 512, F32);
    let mut cur = stages::matmul(&mut b, "l1", &x, &ws[0], 8, 64, 0);
    cur = stages::map(&mut b, "relu1", &cur, 1);
    for (i, out_tokens) in [(2usize, 32u64), (3, 32), (4, 64)] {
        let reduce = 8;
        let need = reduce * out_tokens;
        let factor = need / cur.tokens;
        assert_eq!(factor * cur.tokens, need);
        let rep = stages::replay(&mut b, &format!("rep{i}"), &cur, factor);
        cur = stages::matmul(&mut b, &format!("l{i}"), &rep, &ws[i - 1], reduce, out_tokens, 0);
        if i < 4 {
            cur = stages::map(&mut b, &format!("relu{i}"), &cur, 1);
        }
    }
    stages::sink(&mut b, "store", &cur, 0);
    BenchDesign::new(b.build())
}

/// Residual block: `y = x + conv2(relu(conv1(x)))`, long-running stages
/// (the paper's co-simulated count is ~2.1M cycles — by far the longest;
/// the per-output accumulation delays model the deep conv pipelines).
/// Paper: 64 FIFOs, 2092531 cycles.
pub fn residual_block() -> BenchDesign {
    let p = 6;
    let mut b = DesignBuilder::new("ResidualBlock", 0);
    let ws = stages::port_sources(&mut b, "W", &[("w1", p, 4096), ("w2", p, 4096)], W8);
    let x = stages::source(&mut b, "x", p, 512, F32);
    let (path, skip) = stages::tee(&mut b, "split", &x);
    let path_rep = stages::replay(&mut b, "x_rep", &path, 8); // 4096
    let c1 = stages::matmul(&mut b, "conv1", &path_rep, &ws[0], 8, 512, 1500);
    let r1 = stages::map(&mut b, "relu", &c1, 2);
    let r1_rep = stages::replay(&mut b, "h_rep", &r1, 8); // 4096
    let c2 = stages::matmul(&mut b, "conv2", &r1_rep, &ws[1], 8, 512, 1500);
    let y = stages::join_add(&mut b, "add", &c2, &skip, 1);
    stages::sink(&mut b, "store", &y, 0);
    BenchDesign::new(b.build())
}

/// Depthwise-separable conv block: depthwise conv (long elementwise
/// stage) then pointwise 1×1 conv (matmul) + batchnorm.
/// Paper: 84 FIFOs, 134541 cycles.
pub fn depth_sep_conv_block() -> BenchDesign {
    let p = 14;
    let mut b = DesignBuilder::new("DepthSepConvBlock", 0);
    let x = stages::source(&mut b, "x", p, 256, F32);
    let dw = stages::map(&mut b, "dwconv", &x, 500);
    let rep = stages::replay(&mut b, "dw_rep", &dw, 8); // 2048
    let w = stages::source(&mut b, "w", p, 2048, F32);
    let pw = stages::matmul(&mut b, "pwconv", &rep, &w, 8, 256, 0);
    let bn = stages::map(&mut b, "bn_relu", &pw, 2);
    stages::sink(&mut b, "store", &bn, 0);
    BenchDesign::new(b.build())
}

/// ResMLP: two MLP blocks with residual connections.
/// (Table III row; not in Table II.)
pub fn resmlp() -> BenchDesign {
    let p = 16;
    let mut b = DesignBuilder::new("ResMLP", 0);
    let ws = stages::port_sources(
        &mut b,
        "W",
        &[("b0_w1", p, 512), ("b0_w2", p, 512), ("b1_w1", p, 512), ("b1_w2", p, 512)],
        W8,
    );
    let x = stages::source(&mut b, "x", p, 64, F32);
    let mut cur = x;
    for blk in 0..2 {
        let (path, skip) = stages::tee(&mut b, &format!("b{blk}_split"), &cur);
        let rep1 = stages::replay(&mut b, &format!("b{blk}_rep1"), &path, 8); // 512
        let h = stages::matmul(&mut b, &format!("b{blk}_mm1"), &rep1, &ws[2 * blk], 8, cur.tokens, 0);
        let g = stages::map(&mut b, &format!("b{blk}_gelu"), &h, 2);
        let rep2 = stages::replay(&mut b, &format!("b{blk}_rep2"), &g, 8);
        let y = stages::matmul(&mut b, &format!("b{blk}_mm2"), &rep2, &ws[2 * blk + 1], 8, cur.tokens, 0);
        cur = stages::join_add(&mut b, &format!("b{blk}_add"), &y, &skip, 1);
    }
    stages::sink(&mut b, "store", &cur, 0);
    BenchDesign::new(b.build())
}

/// Data-dependent tiled mini-DNN with runtime arguments
/// `(blocks, m)`: a loader streams all `blocks·m` activations before any
/// weights (so the activation FIFO floors at `blocks·m − 1`, like fig2's
/// x channel), and the PE emits `m` partial results per block before the
/// block-ready token the store waits on (so the result FIFO floors at
/// `m`). Both thresholds move with the runtime tiling — a config sized
/// for one `(blocks, m)` split deadlocks under a sibling with a larger
/// `m`, even at identical total work.
pub fn mini_dnn(blocks: i64, m: i64) -> BenchDesign {
    let mut b = DesignBuilder::new("mini_dnn", 2);
    let a = b.channel("a", 32);
    let w = b.channel("w", 32);
    let z = b.channel("z", 32);
    let rdy = b.channel("rdy", 32);
    b.process("loader", |p| {
        p.for_expr(Expr::arg(0).mul(Expr::arg(1)), |p, _| p.write(a, Expr::c(1)));
        p.for_expr(Expr::arg(0).mul(Expr::arg(1)), |p, _| p.write(w, Expr::c(1)));
    });
    b.process("pe", |p| {
        p.for_expr(Expr::arg(0), |p, _| {
            p.for_expr(Expr::arg(1), |p, _| {
                let av = p.read(a);
                let wv = p.read(w);
                p.write(z, Expr::var(av).mul(Expr::var(wv)));
            });
            p.write(rdy, Expr::c(1));
        });
    });
    b.process("store", |p| {
        p.for_expr(Expr::arg(0), |p, _| {
            p.read(rdy);
            p.for_expr(Expr::arg(1), |p, _| {
                p.read(z);
            });
        });
    });
    BenchDesign::with_args(b.build(), vec![blocks, m])
}

/// [`mini_dnn`] under its default tiling (8 blocks × 16).
pub fn mini_dnn_default() -> BenchDesign {
    mini_dnn(8, 16)
}

/// Scenario argument sets for mini_dnn workload runs: three tilings of
/// the *same* total work (128 MACs) with different per-block depths, so
/// single-scenario-optimal result-FIFO depths deadlock on siblings.
pub fn mini_dnn_scenario_args() -> Vec<(String, Vec<i64>)> {
    [(8i64, 16i64), (16, 8), (4, 32)]
        .iter()
        .map(|&(blocks, m)| (format!("b{blocks}m{m}"), vec![blocks, m]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fast::FastSim;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    #[test]
    fn mini_dnn_thresholds_track_tiling() {
        for (blocks, m) in [(8i64, 16i64), (16, 8), (4, 32)] {
            let bd = mini_dnn(blocks, m);
            let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
            let total = (blocks * m) as u32;
            let mut s = FastSim::new(t.clone());
            // a floors at blocks·m − 1, z at m; rdy is free.
            let ok = s.simulate(&[total - 1, 2, m as u32, 2]);
            assert!(!ok.is_deadlock(), "({blocks},{m}): floors should be safe");
            let bad = s.simulate(&[total - 2, 2, m as u32, 2]);
            assert!(bad.is_deadlock(), "({blocks},{m}): a below floor");
            let bad = s.simulate(&[total - 1, 2, m as u32 - 1, 2]);
            assert!(bad.is_deadlock(), "({blocks},{m}): z below floor");
        }
    }

    #[test]
    fn mini_dnn_scenarios_share_total_work() {
        let totals: Vec<i64> = mini_dnn_scenario_args()
            .iter()
            .map(|(_, a)| a[0] * a[1])
            .collect();
        assert!(totals.iter().all(|&t| t == totals[0]));
    }

    #[test]
    fn residual_block_is_megacycle_scale() {
        let bd = residual_block();
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let mut s = FastSim::new(t.clone());
        let lat = s.simulate(&t.baseline_max()).latency().unwrap();
        assert!(
            (400_000..=6_000_000).contains(&lat),
            "ResidualBlock latency {lat} not ~2M-cycle scale"
        );
    }

    #[test]
    fn feedforward_is_widest() {
        assert_eq!(feedforward().design.num_fifos(), 8 * 106);
    }

    #[test]
    fn dnn_designs_have_stream_array_groups() {
        for bd in [feedforward(), autoencoder(), resmlp(), depth_sep_conv_block()] {
            let groups: Vec<_> = bd.design.groups();
            // every group is a full P-wide stream array
            assert!(
                groups.iter().all(|g| g.len() > 1),
                "{}: expected arrays",
                bd.design.name
            );
        }
    }
}
