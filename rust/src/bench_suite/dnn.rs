//! Deep-learning block kernels from the Stream-HLS suite: FeedForward,
//! Autoencoder, ResidualBlock, DepthSepConvBlock, ResMLP.

use super::stages::{self, F32, W8};
use super::BenchDesign;
use crate::ir::DesignBuilder;

/// Transformer FFN block: `y = W2·gelu(W1·x + b1) + b2`, very wide PE
/// array. Paper: 848 FIFOs, 65997 cycles.
pub fn feedforward() -> BenchDesign {
    let p = 106;
    let mut b = DesignBuilder::new("FeedForward", 0);
    let ws = stages::port_sources(&mut b, "W", &[("w1", p, 128), ("w2", p, 128)], W8);
    let x = stages::source(&mut b, "x", p, 128, F32);
    let h = stages::matmul(&mut b, "h", &x, &ws[0], 8, 16, 0);
    let g = stages::map(&mut b, "gelu", &h, 2);
    let rep = stages::replay(&mut b, "h_rep", &g, 8); // 128 tokens
    let y = stages::matmul(&mut b, "y", &rep, &ws[1], 8, 16, 0);
    let out = stages::map(&mut b, "bias", &y, 1);
    stages::sink(&mut b, "store_y", &out, 0);
    BenchDesign::new(b.build())
}

/// 4-layer MLP autoencoder (encode ×2, decode ×2), ReLU between layers.
/// Paper: 392 FIFOs, 39178 cycles.
pub fn autoencoder() -> BenchDesign {
    let p = 24;
    let mut b = DesignBuilder::new("Autoencoder", 0);
    let ws = stages::port_sources(
        &mut b,
        "W",
        &[("w1", p, 512), ("w2", p, 256), ("w3", p, 256), ("w4", p, 512)],
        W8,
    );
    let x = stages::source(&mut b, "x", p, 512, F32);
    let mut cur = stages::matmul(&mut b, "l1", &x, &ws[0], 8, 64, 0);
    cur = stages::map(&mut b, "relu1", &cur, 1);
    for (i, out_tokens) in [(2usize, 32u64), (3, 32), (4, 64)] {
        let reduce = 8;
        let need = reduce * out_tokens;
        let factor = need / cur.tokens;
        assert_eq!(factor * cur.tokens, need);
        let rep = stages::replay(&mut b, &format!("rep{i}"), &cur, factor);
        cur = stages::matmul(&mut b, &format!("l{i}"), &rep, &ws[i - 1], reduce, out_tokens, 0);
        if i < 4 {
            cur = stages::map(&mut b, &format!("relu{i}"), &cur, 1);
        }
    }
    stages::sink(&mut b, "store", &cur, 0);
    BenchDesign::new(b.build())
}

/// Residual block: `y = x + conv2(relu(conv1(x)))`, long-running stages
/// (the paper's co-simulated count is ~2.1M cycles — by far the longest;
/// the per-output accumulation delays model the deep conv pipelines).
/// Paper: 64 FIFOs, 2092531 cycles.
pub fn residual_block() -> BenchDesign {
    let p = 6;
    let mut b = DesignBuilder::new("ResidualBlock", 0);
    let ws = stages::port_sources(&mut b, "W", &[("w1", p, 4096), ("w2", p, 4096)], W8);
    let x = stages::source(&mut b, "x", p, 512, F32);
    let (path, skip) = stages::tee(&mut b, "split", &x);
    let path_rep = stages::replay(&mut b, "x_rep", &path, 8); // 4096
    let c1 = stages::matmul(&mut b, "conv1", &path_rep, &ws[0], 8, 512, 1500);
    let r1 = stages::map(&mut b, "relu", &c1, 2);
    let r1_rep = stages::replay(&mut b, "h_rep", &r1, 8); // 4096
    let c2 = stages::matmul(&mut b, "conv2", &r1_rep, &ws[1], 8, 512, 1500);
    let y = stages::join_add(&mut b, "add", &c2, &skip, 1);
    stages::sink(&mut b, "store", &y, 0);
    BenchDesign::new(b.build())
}

/// Depthwise-separable conv block: depthwise conv (long elementwise
/// stage) then pointwise 1×1 conv (matmul) + batchnorm.
/// Paper: 84 FIFOs, 134541 cycles.
pub fn depth_sep_conv_block() -> BenchDesign {
    let p = 14;
    let mut b = DesignBuilder::new("DepthSepConvBlock", 0);
    let x = stages::source(&mut b, "x", p, 256, F32);
    let dw = stages::map(&mut b, "dwconv", &x, 500);
    let rep = stages::replay(&mut b, "dw_rep", &dw, 8); // 2048
    let w = stages::source(&mut b, "w", p, 2048, F32);
    let pw = stages::matmul(&mut b, "pwconv", &rep, &w, 8, 256, 0);
    let bn = stages::map(&mut b, "bn_relu", &pw, 2);
    stages::sink(&mut b, "store", &bn, 0);
    BenchDesign::new(b.build())
}

/// ResMLP: two MLP blocks with residual connections.
/// (Table III row; not in Table II.)
pub fn resmlp() -> BenchDesign {
    let p = 16;
    let mut b = DesignBuilder::new("ResMLP", 0);
    let ws = stages::port_sources(
        &mut b,
        "W",
        &[("b0_w1", p, 512), ("b0_w2", p, 512), ("b1_w1", p, 512), ("b1_w2", p, 512)],
        W8,
    );
    let x = stages::source(&mut b, "x", p, 64, F32);
    let mut cur = x;
    for blk in 0..2 {
        let (path, skip) = stages::tee(&mut b, &format!("b{blk}_split"), &cur);
        let rep1 = stages::replay(&mut b, &format!("b{blk}_rep1"), &path, 8); // 512
        let h = stages::matmul(&mut b, &format!("b{blk}_mm1"), &rep1, &ws[2 * blk], 8, cur.tokens, 0);
        let g = stages::map(&mut b, &format!("b{blk}_gelu"), &h, 2);
        let rep2 = stages::replay(&mut b, &format!("b{blk}_rep2"), &g, 8);
        let y = stages::matmul(&mut b, &format!("b{blk}_mm2"), &rep2, &ws[2 * blk + 1], 8, cur.tokens, 0);
        cur = stages::join_add(&mut b, &format!("b{blk}_add"), &y, &skip, 1);
    }
    stages::sink(&mut b, "store", &cur, 0);
    BenchDesign::new(b.build())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fast::FastSim;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    #[test]
    fn residual_block_is_megacycle_scale() {
        let bd = residual_block();
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let mut s = FastSim::new(t.clone());
        let lat = s.simulate(&t.baseline_max()).latency().unwrap();
        assert!(
            (400_000..=6_000_000).contains(&lat),
            "ResidualBlock latency {lat} not ~2M-cycle scale"
        );
    }

    #[test]
    fn feedforward_is_widest() {
        assert_eq!(feedforward().design.num_fifos(), 8 * 106);
    }

    #[test]
    fn dnn_designs_have_stream_array_groups() {
        for bd in [feedforward(), autoencoder(), resmlp(), depth_sep_conv_block()] {
            let groups: Vec<_> = bd.design.groups();
            // every group is a full P-wide stream array
            assert!(
                groups.iter().all(|g| g.len() > 1),
                "{}: expected arrays",
                bd.design.name
            );
        }
    }
}
