//! The paper's Fig. 2 motivating example: a design whose FIFOs *cannot*
//! be sized optimally or deadlock-free without runtime analysis, because
//! the deadlock threshold depends on the runtime kernel argument `n`.
//!
//! ```c
//! void producer(stream &x, stream &y, int n) {
//!   for (int i = 0; i < n; i++) x.write(1);
//!   for (int i = 0; i < n; i++) y.write(1);
//! }
//! void consumer(int *out, stream &x, stream &y, int n) {
//!   int sum = 0;
//!   for (int i = 0; i < n; i++) sum += x.read() + y.read();
//!   *out = sum;
//! }
//! ```
//!
//! The consumer alternates x/y reads while the producer writes all of x
//! first, so x must buffer `n - 1` tokens: any `depth(x) < n - 1`
//! deadlocks, and `n` is only known at runtime.

use super::BenchDesign;
use crate::ir::{DesignBuilder, Expr};

/// Scenario argument sets for multi-trace (workload) runs: different
/// runtime `n`s give different x-channel deadlock thresholds
/// (`depth(x) ≥ n − 1`), so a config sized optimally for a small-`n`
/// scenario deadlocks under a larger-`n` sibling — the minimal example
/// of why robust sizing must quantify over inputs.
pub fn scenario_args(ns: &[i64]) -> Vec<(String, Vec<i64>)> {
    ns.iter().map(|&n| (format!("n{n}"), vec![n])).collect()
}

/// Build `mult_by_2` for runtime argument `n`.
pub fn mult_by_2(n: i64) -> BenchDesign {
    let mut b = DesignBuilder::new("fig2", 1);
    let x = b.channel("x", 32);
    let y = b.channel("y", 32);
    b.process("producer", |p| {
        p.for_expr(Expr::arg(0), |p, _| p.write(x, Expr::c(1)));
        p.for_expr(Expr::arg(0), |p, _| p.write(y, Expr::c(1)));
    });
    b.process("consumer", |p| {
        let sum = p.var();
        p.set(sum, Expr::c(0));
        p.for_expr(Expr::arg(0), |p, _| {
            let a = p.read(x);
            let c = p.read(y);
            p.set(sum, Expr::var(sum).add(Expr::var(a)).add(Expr::var(c)));
        });
    });
    BenchDesign::with_args(b.build(), vec![n])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fast::FastSim;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    #[test]
    fn deadlock_threshold_is_n_minus_one() {
        for n in [4i64, 16, 33] {
            let bd = mult_by_2(n);
            let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
            let mut s = FastSim::new(t.clone());
            let ok = s.simulate(&[(n - 1) as u32, 2]);
            assert!(!ok.is_deadlock(), "n={n}: depth n-1 should be safe");
            let bad = s.simulate(&[(n - 2) as u32, 2]);
            assert!(bad.is_deadlock(), "n={n}: depth n-2 should deadlock");
        }
    }
}
