//! FlowGNN-style GNN accelerator with data-dependent control flow — the
//! §IV-D case study (Principal Neighborhood Aggregation).
//!
//! Message-passing dataflow: a **scatter** unit streams one message per
//! edge into per-lane gather FIFOs, bucketed by destination node; because
//! the graph connectivity is a *runtime input*, the number of messages
//! each lane receives (and therefore every FIFO's deadlock threshold) is
//! unknowable statically. The scatter unit emits per-lane message counts
//! only *after* the edge scan (as in degree-table-driven GNN designs), so
//! a gather lane cannot drain its message FIFO until scatter has finished
//! — the message FIFOs must buffer a data-dependent burst, which is
//! exactly the situation the paper argues only simulation can size.
//!
//! The graph is generated in-VM from an LCG seeded by a kernel argument,
//! so different `args` give different traces (multi-stimulus
//! optimization exercises this).
//!
//! Pipeline: `scatter → gather[P] (PNA: mean/max/min/std) → update[P]
//! (weight matmul) → store`, with designer depth hints on every FIFO
//! (the case study's user-sized Baseline-Max, §IV-D).

use super::BenchDesign;
use crate::ir::{DesignBuilder, Expr};

/// Number of parallel gather/update lanes.
pub const LANES: usize = 8;

/// Graph seeds whose quadratic-hash routing produces *distinct* per-lane
/// burst (degree) distributions at 64 nodes / 512 edges — e.g. seed 7
/// loads lanes `[0,128,0,128,…]` while seed 8 loads `[128,256,0,0,…]`.
/// Sizing the msg FIFOs for one of these graphs deadlocks on a sibling
/// whose bursts land on different lanes; all stay within the designer's
/// 256-deep hints, so the merged Baseline-Max remains feasible.
pub const SCENARIO_SEEDS: [i64; 8] = [7, 8, 2, 6, 1234, 14, 20, 26];

/// Scenario argument sets for multi-trace (workload) runs: `k ≤ 8`
/// graphs with the seeds above (64 nodes, 512 edges each).
pub fn scenario_args(k: usize) -> Vec<(String, Vec<i64>)> {
    assert!(
        k <= SCENARIO_SEEDS.len(),
        "at most {} distinct graph scenarios",
        SCENARIO_SEEDS.len()
    );
    SCENARIO_SEEDS[..k]
        .iter()
        .map(|&s| (format!("graph_s{s}"), vec![64, 512, s]))
        .collect()
}

/// Build the PNA design for `num_nodes`, `num_edges`, and an LCG `seed`
/// (all runtime kernel arguments).
pub fn pna(num_nodes: i64, num_edges: i64, seed: i64) -> BenchDesign {
    let p = LANES;
    let mut b = DesignBuilder::new("flowgnn_pna", 3);
    let n_arg = || Expr::arg(0);
    let e_arg = || Expr::arg(1);

    // Designer-sized FIFOs (the case study's hand-tuned Baseline-Max).
    let msg = b.channel_array_with_depth("msg", p, 64, 256);
    let deg = b.channel_array_with_depth("deg", p, 16, 4);
    let agg = b.channel_array_with_depth("agg", p, 128, 16);
    let w = b.channel_array_with_depth("w", p, 32, 32);
    let out = b.channel_array_with_depth("out", p, 128, 8);

    // Scatter: stream one message per edge into msg[dst % P], THEN emit
    // the per-lane counts. dst(e) = LCG(seed, e) mod N.
    let msg_c = msg.clone();
    let deg_c = deg.clone();
    b.process("scatter", move |pb| {
        // Per-lane running counters.
        let counts: Vec<_> = (0..p).map(|_| pb.var()).collect();
        for &c in &counts {
            pb.set(c, Expr::c(0));
        }
        pb.for_expr(e_arg(), |pb, e| {
            // dst = (e² + seed·e + seed) mod N — a quadratic hash, NOT a
            // linear congruence: linear maps mod a power-of-two N give
            // every lane identical load, whereas real graphs have skewed
            // degree distributions. Quadratic residues concentrate
            // destinations unevenly, seed-dependently. Always >= 0 for
            // sane (positive) args.
            let dst = pb.var();
            pb.set(
                dst,
                Expr::var(e)
                    .mul(Expr::var(e))
                    .add(Expr::arg(2).mul(Expr::var(e)))
                    .add(Expr::arg(2))
                    .rem(n_arg())
                    .max(Expr::c(0)),
            );
            let lane = pb.var();
            pb.set(lane, Expr::var(dst).rem(Expr::c(p as i64)));
            // Route to the matching lane FIFO (P-way predicated dispatch,
            // as an unrolled comparison chain like HLS would synthesize).
            for (li, (&m, &cv)) in msg_c.iter().zip(&counts).enumerate() {
                pb.if_then(Expr::var(lane).eq(Expr::c(li as i64)), |pb| {
                    pb.write(m, Expr::var(dst));
                    pb.set(cv, Expr::var(cv).add(Expr::c(1)));
                });
            }
        });
        // Counts are only known after the full edge scan.
        for (li, &d) in deg_c.iter().enumerate() {
            pb.write(d, Expr::var(counts[li]));
        }
    });

    // Gather lanes: PNA aggregation over the lane's message burst, then
    // one aggregate token per (node, aggregator) pair for the lane's
    // node share.
    for lane in 0..p {
        let (m, d, a) = (msg[lane], deg[lane], agg[lane]);
        b.process(&format!("gather{lane}"), move |pb| {
            let n_msgs = pb.read(d);
            let acc = pb.var();
            pb.set(acc, Expr::c(0));
            pb.for_expr(Expr::var(n_msgs), |pb, _| {
                let v = pb.read(m);
                pb.delay(1); // running mean/max/min/std update
                pb.set(acc, Expr::var(acc).add(Expr::var(v)));
            });
            // Emit 4 PNA aggregates (mean, max, min, std) per node in the
            // lane's share of nodes.
            let share = pb.var();
            pb.set(share, n_arg().div(Expr::c(p as i64)));
            pb.for_expr(Expr::var(share), |pb, _| {
                pb.for_n(4, |pb, _| {
                    pb.delay(1);
                    pb.write(a, Expr::var(acc));
                });
            });
        });
    }

    // Per-lane weight loaders + update units (small matmul over the 4
    // aggregates), then store.
    for lane in 0..p {
        let wl = w[lane];
        b.process(&format!("load_w{lane}"), move |pb| {
            let share = pb.var();
            pb.set(share, n_arg().div(Expr::c(p as i64)));
            pb.for_expr(Expr::var(share), |pb, _| {
                pb.for_n(4, |pb, _| pb.write(wl, Expr::c(3)));
            });
        });
        let (a, wl, o) = (agg[lane], w[lane], out[lane]);
        b.process(&format!("update{lane}"), move |pb| {
            let share = pb.var();
            pb.set(share, n_arg().div(Expr::c(p as i64)));
            pb.for_expr(Expr::var(share), |pb, _| {
                let acc = pb.var();
                pb.set(acc, Expr::c(0));
                pb.for_n(4, |pb, _| {
                    let x = pb.read(a);
                    let ww = pb.read(wl);
                    pb.set(acc, Expr::var(acc).add(Expr::var(x).mul(Expr::var(ww))));
                });
                pb.delay(2);
                pb.write(o, Expr::var(acc));
            });
        });
    }
    let out_c = out.clone();
    b.process("store", move |pb| {
        let share = pb.var();
        pb.set(share, n_arg().div(Expr::c(p as i64)));
        pb.for_expr(Expr::var(share), |pb, _| {
            for &o in &out_c {
                let _ = pb.read(o);
            }
        });
    });

    BenchDesign::with_args(b.build(), vec![num_nodes, num_edges, seed])
}

/// The default case-study instance: 64 nodes, 512 edges.
pub fn pna_default() -> BenchDesign {
    pna(64, 512, 7)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fast::FastSim;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    #[test]
    fn trace_depends_on_graph() {
        let a = pna(64, 512, 7);
        let bb = pna(64, 512, 8);
        let ta = collect_trace(&a.design, &a.args).unwrap();
        let tb = collect_trace(&bb.design, &bb.args).unwrap();
        // Same totals (one message per edge)...
        let wa: u64 = ta.channels[..LANES].iter().map(|c| c.writes).sum();
        let wb: u64 = tb.channels[..LANES].iter().map(|c| c.writes).sum();
        assert_eq!(wa, 512);
        assert_eq!(wb, 512);
        // ...but different per-lane distribution (data-dependent routing).
        let da: Vec<u64> = ta.channels[..LANES].iter().map(|c| c.writes).collect();
        let db: Vec<u64> = tb.channels[..LANES].iter().map(|c| c.writes).collect();
        assert_ne!(da, db, "different seeds must route differently");
    }

    #[test]
    fn msg_fifos_must_buffer_data_dependent_burst() {
        let bd = pna_default();
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let mut s = FastSim::new(t.clone());
        // Designer sizes (hints) are safe.
        assert!(!s.simulate(&t.baseline_max()).is_deadlock());
        // All-minimum deadlocks: gather can't see deg until scatter ends,
        // so msg FIFOs must hold whole bursts.
        assert!(s.simulate(&t.baseline_min()).is_deadlock());
        // The exact threshold per lane is its burst size: sizing each msg
        // FIFO to its observed writes un-deadlocks even with deg/agg tiny.
        let mut depths = t.baseline_min();
        for lane in 0..LANES {
            depths[lane] = t.channels[lane].writes as u32;
        }
        assert!(!s.simulate(&depths).is_deadlock());
    }

    #[test]
    fn scenario_seeds_have_distinct_burst_distributions() {
        // The first four seeds must give pairwise-different per-lane
        // bursts (otherwise a workload over them proves nothing), and
        // every burst must fit the designer's 256-deep msg hint so the
        // merged Baseline-Max stays feasible.
        let dists: Vec<Vec<u64>> = scenario_args(4)
            .iter()
            .map(|(_, args)| {
                let bd = pna(args[0], args[1], args[2]);
                let t = collect_trace(&bd.design, &bd.args).unwrap();
                t.channels[..LANES].iter().map(|c| c.writes).collect()
            })
            .collect();
        for i in 0..dists.len() {
            for j in 0..i {
                assert_ne!(dists[i], dists[j], "seeds {i} and {j} route identically");
            }
            assert!(dists[i].iter().all(|&b| b <= 256), "{:?}", dists[i]);
        }
    }

    #[test]
    fn design_has_depth_hints_everywhere() {
        let bd = pna_default();
        assert!(bd.design.channels.iter().all(|c| c.depth_hint.is_some()));
        assert_eq!(bd.design.num_fifos(), 5 * LANES);
    }
}
