//! The kNmm kernel family from Stream-HLS: chains (`seq`) and balanced
//! binary trees (`tree`) of matrix multiplications, with optional ReLU
//! stages and balanced/unbalanced variants (unbalanced variants give
//! alternate stages mismatched compute rates, which is what makes their
//! FIFO sizing interesting).

use super::stages::{self, StageOut, F32, W8};
use super::BenchDesign;
use crate::ir::DesignBuilder;

/// Per-stage geometry: every matmul consumes `REDUCE` (left,right) pairs
/// per output and produces `OUT` tokens per PE channel; replay stages
/// re-expand an upstream output to `REDUCE * OUT` tokens.
const REDUCE: u64 = 8;
const OUT: u64 = 32;
const IN_TOKENS: u64 = REDUCE * OUT; // 256

/// A sequential chain of `n` matmuls:
/// `Y = (((A·W1)·W2)·W3)…` — ALL weight streams served sequentially by
/// one shared memory port ([`stages::port_sources`]): stage `i` consumes
/// its weights only once stage `i-1` produces, so small weight FIFOs
/// throttle the port and delay every later stage — the gradual
/// latency↔memory frontier of Fig. 3.
///
/// `unbalanced` gives odd stages a 3-cycle extra per-output delay
/// (mismatched PE rates → upstream FIFOs back up unevenly).
pub fn kmm_seq(name: &str, n: usize, p: usize, relu: bool, unbalanced: bool) -> BenchDesign {
    let mut b = DesignBuilder::new(name, 0);
    let w_names: Vec<String> = (0..n).map(|i| format!("w{i}")).collect();
    let specs: Vec<(&str, usize, u64)> = w_names
        .iter()
        .map(|nm| (nm.as_str(), p, IN_TOKENS))
        .collect();
    let ws = stages::port_sources(&mut b, "W", &specs, W8);
    let a = stages::source(&mut b, "a", p, IN_TOKENS, F32);
    let mut cur = stages::matmul(&mut b, "mm0", &a, &ws[0], REDUCE, OUT, 0);
    if relu {
        cur = stages::map(&mut b, "relu0", &cur, 1);
    }
    for i in 1..n {
        let delay = if unbalanced && i % 2 == 1 { 3 } else { 0 };
        let rep = stages::replay(&mut b, &format!("rep{i}"), &cur, REDUCE);
        cur = stages::matmul(&mut b, &format!("mm{i}"), &rep, &ws[i], REDUCE, OUT, delay);
        if relu {
            cur = stages::map(&mut b, &format!("relu{i}"), &cur, 1);
        }
    }
    stages::sink(&mut b, "y", &cur, 0);
    BenchDesign::new(b.build())
}

/// A balanced binary tree over `leaves` input matrices (`leaves - 1`
/// matmuls): leaf matmuls read two loaders directly; internal matmuls
/// read the replayed outputs of their children.
///
/// `unbalanced` slows the left child of every internal node by a 3-cycle
/// per-output delay, skewing the two operand arrival rates at each join.
pub fn kmm_tree(name: &str, leaves: usize, p: usize, relu: bool, unbalanced: bool) -> BenchDesign {
    assert!(leaves.is_power_of_two() && leaves >= 4);
    let mut b = DesignBuilder::new(name, 0);

    // Right-hand leaf operands (the "weight" side) share one memory port,
    // served leaf 0 → leaf N: later leaves start late unless earlier
    // right-operand FIFOs buffer the port's bursts.
    let r_names: Vec<String> = (0..leaves / 2).map(|i| format!("in{}", 2 * i + 1)).collect();
    let specs: Vec<(&str, usize, u64)> = r_names
        .iter()
        .map(|nm| (nm.as_str(), p, IN_TOKENS))
        .collect();
    let rights = stages::port_sources(&mut b, "R", &specs, W8);

    // Level 0: leaf matmuls over (dedicated left, ported right) pairs.
    let mut level: Vec<StageOut> = Vec::new();
    for i in 0..leaves / 2 {
        let l = stages::source(&mut b, &format!("in{}", 2 * i), p, IN_TOKENS, F32);
        let delay = if unbalanced && i % 2 == 0 { 3 } else { 0 };
        let mut m = stages::matmul(&mut b, &format!("leaf{i}"), &l, &rights[i], REDUCE, OUT, delay);
        if relu {
            m = stages::map(&mut b, &format!("lrelu{i}"), &m, 1);
        }
        level.push(m);
    }

    // Internal levels: join pairs until one stream remains.
    let mut lvl = 0;
    while level.len() > 1 {
        lvl += 1;
        let mut next = Vec::new();
        for i in 0..level.len() / 2 {
            let lrep = stages::replay(
                &mut b,
                &format!("l{lvl}_{i}_lrep"),
                &level[2 * i],
                REDUCE,
            );
            let rrep = stages::replay(
                &mut b,
                &format!("l{lvl}_{i}_rrep"),
                &level[2 * i + 1],
                REDUCE,
            );
            let delay = if unbalanced && i % 2 == 0 { 3 } else { 0 };
            let mut m = stages::matmul(
                &mut b,
                &format!("node{lvl}_{i}"),
                &lrep,
                &rrep,
                REDUCE,
                OUT,
                delay,
            );
            if relu {
                m = stages::map(&mut b, &format!("nrelu{lvl}_{i}"), &m, 1);
            }
            next.push(m);
        }
        level = next;
    }
    // The non-ReLU 16-leaf trees carry a quantization-calibration sidecar
    // on the root output: its full-block buffering requirement is what
    // makes their Baseline-Min deadlock (the paper's two ×→✓ designs,
    // k15mmtree among them) — and the rescue depth (32 × 32 bit = 1024
    // bits) is exactly the SRL limit, so un-deadlocking costs zero BRAM.
    let out = if leaves == 16 && !relu {
        stages::scale_sidecar(&mut b, "quant", &level[0])
    } else {
        level.pop().unwrap()
    };
    stages::sink(&mut b, "y", &out, 0);
    BenchDesign::new(b.build())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fast::FastSim;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    #[test]
    fn seq_chain_structure() {
        let bd = kmm_seq("k3_test", 3, 2, false, false);
        // a + w0 + mm0 + 2×(rep + w + mm) = 3 + 6 stages of 2 chans = 18
        assert_eq!(bd.design.num_fifos(), 9 * 2);
        let t = collect_trace(&bd.design, &bd.args).unwrap();
        for c in &t.channels {
            assert_eq!(c.writes, c.reads);
        }
    }

    #[test]
    fn tree_structure() {
        let bd = kmm_tree("k7_test", 8, 2, false, false);
        // 8 src + 7 mm + 2×3 replays (3 internal nodes) = 21 groups × P
        assert_eq!(bd.design.num_fifos(), 21 * 2);
    }

    #[test]
    fn relu_and_unbalanced_variants_simulate() {
        for (relu, unb) in [(false, true), (true, false), (true, true)] {
            let bd = kmm_seq("v", 5, 2, relu, unb);
            let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
            let mut s = FastSim::new(t.clone());
            assert!(!s.simulate(&t.baseline_max()).is_deadlock());
            let bd = kmm_tree("vt", 8, 2, relu, unb);
            let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
            let mut s = FastSim::new(t.clone());
            assert!(!s.simulate(&t.baseline_max()).is_deadlock());
        }
    }

    #[test]
    fn unbalanced_is_slower_at_min_depths() {
        // At Baseline-Min the mismatched rates show up as extra stalling.
        let bal = kmm_seq("b", 7, 2, false, false);
        let unb = kmm_seq("u", 7, 2, false, true);
        let tb = Arc::new(collect_trace(&bal.design, &bal.args).unwrap());
        let tu = Arc::new(collect_trace(&unb.design, &unb.args).unwrap());
        let lb = FastSim::new(tb.clone()).simulate(&tb.baseline_min()).latency();
        let lu = FastSim::new(tu.clone()).simulate(&tu.baseline_min()).latency();
        match (lb, lu) {
            (Some(lb), Some(lu)) => assert!(lu > lb, "unbalanced {lu} <= balanced {lb}"),
            _ => {} // a deadlock at min depths is also acceptable here
        }
    }
}
