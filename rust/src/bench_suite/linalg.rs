//! Polybench-style linear-algebra kernels from the Stream-HLS suite:
//! atax, bicg, gemm, gesummv, mvt, k2mm, k3mm.
//!
//! Parallelization factors (PE counts) are chosen so the FIFO counts
//! track the paper's Table II; token counts put cycle counts in the same
//! order of magnitude as the paper's co-simulated cycles. Matrix streams
//! are served by a *shared memory port* ([`stages::port_sources`]) — the
//! realistic single-HBM-port pattern that creates the latency↔memory
//! trade-off the paper explores (small FIFOs on an early stream delay
//! every later stream).

use super::stages::{self, StageOut, F32};
use super::BenchDesign;
use crate::ir::DesignBuilder;

/// One streaming matvec stage: PE array consuming a matrix stream and a
/// (replayed or loaded) vector stream.
fn matvec(
    b: &mut DesignBuilder,
    prefix: &str,
    mat: &StageOut,
    reduce: u64,
    out_tokens: u64,
    vec_in: Option<&StageOut>,
) -> StageOut {
    let p = mat.chans.len();
    let vec = match vec_in {
        Some(v) => {
            assert_eq!(v.tokens * (out_tokens * reduce / v.tokens), out_tokens * reduce);
            stages::replay(b, &format!("{prefix}_vrep"), v, out_tokens * reduce / v.tokens)
        }
        None => stages::source(b, &format!("{prefix}_vec"), p, reduce * out_tokens, F32),
    };
    stages::matmul(b, prefix, mat, &vec, reduce, out_tokens, 0)
}

/// atax: `y = Aᵀ(A·x)` — two chained matvec passes; both matrix streams
/// share the port. Paper: 175 FIFOs, 2180 cycles.
pub fn atax() -> BenchDesign {
    let p = 29;
    let mut b = DesignBuilder::new("atax", 0);
    let mats = stages::port_sources(&mut b, "A", &[("a1", p, 64), ("a2", p, 64)], F32);
    let t1 = matvec(&mut b, "ax", &mats[0], 8, 8, None);
    let t2 = matvec(&mut b, "aty", &mats[1], 8, 8, Some(&t1));
    stages::sink(&mut b, "y", &t2, 0);
    BenchDesign::new(b.build())
}

/// bicg: two *independent* matvec kernels sharing the matrix port.
/// Paper: 25 FIFOs, 1112 cycles.
pub fn bicg() -> BenchDesign {
    let p = 4;
    let mut b = DesignBuilder::new("bicg", 0);
    let mats = stages::port_sources(&mut b, "A", &[("aq", p, 256), ("as", p, 256)], F32);
    let q = matvec(&mut b, "q", &mats[0], 16, 16, None);
    let s = matvec(&mut b, "s", &mats[1], 16, 16, None);
    stages::sink(&mut b, "store_q", &q, 0);
    stages::sink(&mut b, "store_s", &s, 0);
    BenchDesign::new(b.build())
}

/// gemm: `C = A·B`, single stage with dedicated loaders (rate-matched
/// everywhere — its frontier collapses to the zero-BRAM corner, which is
/// exactly the Fig. 4 "↓" behaviour). Paper: 88 FIFOs, 24051 cycles.
pub fn gemm() -> BenchDesign {
    let p = 28;
    let mut b = DesignBuilder::new("gemm", 0);
    let a = stages::source(&mut b, "a", p, 960, F32);
    let w = stages::source(&mut b, "b", p, 960, F32);
    let c = stages::matmul(&mut b, "c", &a, &w, 8, 120, 0);
    stages::sink(&mut b, "c_out", &c, 0);
    BenchDesign::new(b.build())
}

/// gesummv: `y = α·A·x + β·B·x` — two matvecs (shared port) joined by an
/// add. (Table III row; not in Table II.)
pub fn gesummv() -> BenchDesign {
    let p = 4;
    let mut b = DesignBuilder::new("gesummv", 0);
    let mats = stages::port_sources(&mut b, "AB", &[("ma", p, 64), ("mb", p, 64)], F32);
    let ax = matvec(&mut b, "ax", &mats[0], 8, 8, None);
    let bx = matvec(&mut b, "bx", &mats[1], 8, 8, None);
    let y = stages::join_add(&mut b, "y", &ax, &bx, 1);
    stages::sink(&mut b, "store_y", &y, 0);
    BenchDesign::new(b.build())
}

/// mvt: `x1 += A·y1; x2 += Aᵀ·y2` — two matvecs, heavily parallelized,
/// matrix streams sharing the port. Paper: 288 FIFOs, 667 cycles.
pub fn mvt() -> BenchDesign {
    let p = 48;
    let mut b = DesignBuilder::new("mvt", 0);
    let mats = stages::port_sources(&mut b, "A", &[("m1", p, 14), ("m2", p, 14)], F32);
    let x1 = matvec(&mut b, "x1", &mats[0], 7, 2, None);
    let x2 = matvec(&mut b, "x2", &mats[1], 7, 2, None);
    stages::sink(&mut b, "store_x1", &x1, 0);
    stages::sink(&mut b, "store_x2", &x2, 0);
    BenchDesign::new(b.build())
}

/// k2mm: `D = (A·B)·C`; the two weight matrices share the port.
/// Paper: 64 FIFOs, 36352 cycles.
pub fn k2mm() -> BenchDesign {
    let p = 10;
    let mut b = DesignBuilder::new("k2mm", 0);
    let ws = stages::port_sources(&mut b, "W", &[("b", p, 1800), ("c", p, 600)], F32);
    let a = stages::source(&mut b, "a", p, 1800, F32);
    let tmp = stages::matmul(&mut b, "tmp", &a, &ws[0], 24, 75, 0);
    let rep = stages::replay(&mut b, "tmp_rep", &tmp, 8); // 600 tokens
    let d = stages::matmul(&mut b, "d", &rep, &ws[1], 24, 25, 0);
    stages::sink(&mut b, "d_out", &d, 0);
    BenchDesign::new(b.build())
}

/// k3mm: `G = (A·B)·(C·D)`; B and D share the port.
/// Paper: 95 FIFOs, 49092 cycles.
pub fn k3mm() -> BenchDesign {
    let p = 10;
    let mut b = DesignBuilder::new("k3mm", 0);
    let ws = stages::port_sources(&mut b, "W", &[("b", p, 1800), ("d", p, 1800)], F32);
    let a = stages::source(&mut b, "a", p, 1800, F32);
    let e = stages::matmul(&mut b, "e", &a, &ws[0], 24, 75, 0);
    let c = stages::source(&mut b, "c", p, 1800, F32);
    let f = stages::matmul(&mut b, "f", &c, &ws[1], 24, 75, 0);
    let e_rep = stages::replay(&mut b, "e_rep", &e, 8); // 600
    let f_rep = stages::replay(&mut b, "f_rep", &f, 8); // 600
    let g = stages::matmul(&mut b, "g", &e_rep, &f_rep, 24, 25, 0);
    stages::sink(&mut b, "g_out", &g, 0);
    BenchDesign::new(b.build())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fast::FastSim;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    #[test]
    fn cycle_counts_in_paper_ballpark() {
        // (design, paper cycles). Substitution keeps the order of
        // magnitude, not exact counts (DESIGN.md §2).
        let cases: &[(BenchDesign, u64)] = &[
            (atax(), 2180),
            (bicg(), 1112),
            (gemm(), 24051),
            (mvt(), 667),
            (k2mm(), 36352),
            (k3mm(), 49092),
        ];
        for (bd, paper) in cases {
            let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
            let mut s = FastSim::new(t.clone());
            let lat = s.simulate(&t.baseline_max()).latency().unwrap();
            let ratio = lat as f64 / *paper as f64;
            assert!(
                (0.2..=5.0).contains(&ratio),
                "{}: ours {lat} vs paper {paper} (ratio {ratio:.2})",
                bd.design.name
            );
        }
    }

    #[test]
    fn shared_port_creates_latency_tradeoff() {
        // Small FIFOs on the first-served stream must slow the design
        // (the port trickles, delaying the second stream) but NOT
        // deadlock it — the gradual frontier the paper explores.
        for bd in [atax(), bicg(), k2mm()] {
            let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
            let mut s = FastSim::new(t.clone());
            let lmax = s.simulate(&t.baseline_max()).latency().unwrap();
            let min = s.simulate(&t.baseline_min());
            let lmin = min
                .latency()
                .unwrap_or_else(|| panic!("{}: min deadlocked", bd.design.name));
            assert!(
                lmin as f64 > lmax as f64 * 1.15,
                "{}: no tradeoff (min {lmin} vs max {lmax})",
                bd.design.name
            );
        }
    }
}
