//! Generators for the evaluation designs.
//!
//! Stand-ins for the paper's 24 Stream-HLS benchmark kernels (Table II /
//! Table III), the Fig. 2 motivating example, and the FlowGNN-PNA case
//! study (§IV-D). Each generator reproduces the *structural* properties
//! the experiments exercise — dataflow topology, number of FIFOs, stream
//! arrays (groups), producer/consumer rate relationships, and (for
//! FlowGNN and Fig. 2) data-dependent control flow — with matrix sizes
//! chosen so FIFO counts track the paper's Table II and cycle counts land
//! in the same orders of magnitude. See DESIGN.md §2 for the
//! substitution rationale.

pub mod dnn;
pub mod fig2;
pub mod flowgnn;
pub mod kmm;
pub mod linalg;
pub mod stages;

use crate::ir::Design;

/// A named benchmark design plus the kernel arguments its trace is
/// collected under.
pub struct BenchDesign {
    pub design: Design,
    pub args: Vec<i64>,
}

impl BenchDesign {
    fn new(design: Design) -> BenchDesign {
        BenchDesign {
            design,
            args: vec![],
        }
    }

    fn with_args(design: Design, args: Vec<i64>) -> BenchDesign {
        BenchDesign { design, args }
    }
}

/// Names of the 21 Table II designs, in the paper's order.
pub const TABLE2_DESIGNS: [&str; 21] = [
    "atax",
    "Autoencoder",
    "bicg",
    "DepthSepConvBlock",
    "FeedForward",
    "gemm",
    "k2mm",
    "k3mm",
    "k7mmseq_balanced",
    "k7mmseq_unbalanced",
    "k7mmtree_unbalanced",
    "mvt",
    "ResidualBlock",
    "k15mmseq_imbalanced",
    "k15mmseq",
    "k15mmseq_relu_imbalanced",
    "k15mmseq_relu",
    "k15mmtree_imbalanced",
    "k15mmtree",
    "k15mmtree_relu_imbalanced",
    "k15mmtree_relu",
];

/// The additional designs appearing in Table III.
pub const EXTRA_DESIGNS: [&str; 3] = ["gesummv", "k7mmtree_balanced", "ResMLP"];

/// All Stream-HLS-style benchmark names (Table II ∪ Table III).
pub fn all_names() -> Vec<&'static str> {
    let mut v: Vec<&str> = TABLE2_DESIGNS.to_vec();
    v.extend(EXTRA_DESIGNS);
    v
}

/// Build a benchmark design by name. Panics on unknown names; see
/// [`try_build`].
pub fn build(name: &str) -> BenchDesign {
    try_build(name).unwrap_or_else(|| panic!("unknown design '{name}'"))
}

/// Default multi-trace scenario argument sets for the data-dependent
/// specials, whose traces are argument-specific (`None` for the static
/// Stream-HLS designs).
pub fn scenario_args(name: &str) -> Option<Vec<(String, Vec<i64>)>> {
    match name {
        "fig2" => Some(fig2::scenario_args(&[8, 16, 12])),
        "flowgnn_pna" => Some(flowgnn::scenario_args(4)),
        "mini_dnn" => Some(dnn::mini_dnn_scenario_args()),
        _ => None,
    }
}

/// The finite kernel-argument space of a data-dependent design — the
/// domain the adversarial scenario hunter
/// ([`dse::advhunt`](crate::dse::advhunt)) searches for deadlock
/// counterexamples. `None` for the static Stream-HLS designs (their
/// traces are argument-independent, so there is nothing to hunt).
pub fn arg_space(name: &str) -> Option<crate::opt::genome::ArgSpace> {
    use crate::opt::genome::{ArgDim, ArgSpace};
    Some(match name {
        "fig2" => ArgSpace::new(vec![ArgDim::new("n", (2..=32).collect())]),
        "flowgnn_pna" => ArgSpace::new(vec![
            ArgDim::new("nodes", vec![64]),
            ArgDim::new("edges", vec![512]),
            ArgDim::new("seed", flowgnn::SCENARIO_SEEDS.to_vec()),
        ]),
        "mini_dnn" => ArgSpace::new(vec![
            ArgDim::new("blocks", vec![2, 4, 8, 16, 32]),
            ArgDim::new("m", vec![2, 4, 8, 16, 32, 64]),
        ]),
        _ => return None,
    })
}

/// Build a design's default workload: the multi-scenario set from
/// [`scenario_args`] when one exists, otherwise a single scenario under
/// the design's default args.
pub fn build_workload(name: &str) -> Option<crate::trace::workload::Workload> {
    use crate::trace::workload::Workload;
    let bd = try_build(name)?;
    Some(match scenario_args(name) {
        Some(scen) => Workload::from_design(&bd.design, &scen)
            .expect("suite scenario set must build"),
        None => Workload::single(std::sync::Arc::new(
            crate::trace::collect_trace(&bd.design, &bd.args)
                .expect("suite design must trace"),
        )),
    })
}

/// Build a benchmark design by name, including the non-Stream-HLS
/// specials `fig2` and `flowgnn_pna`.
pub fn try_build(name: &str) -> Option<BenchDesign> {
    Some(match name {
        "atax" => linalg::atax(),
        "bicg" => linalg::bicg(),
        "gemm" => linalg::gemm(),
        "gesummv" => linalg::gesummv(),
        "mvt" => linalg::mvt(),
        "k2mm" => linalg::k2mm(),
        "k3mm" => linalg::k3mm(),
        "k7mmseq_balanced" => kmm::kmm_seq("k7mmseq_balanced", 7, 5, false, false),
        "k7mmseq_unbalanced" => kmm::kmm_seq("k7mmseq_unbalanced", 7, 5, false, true),
        "k7mmtree_balanced" => kmm::kmm_tree("k7mmtree_balanced", 8, 6, false, false),
        "k7mmtree_unbalanced" => kmm::kmm_tree("k7mmtree_unbalanced", 8, 6, false, true),
        "k15mmseq" => kmm::kmm_seq("k15mmseq", 15, 4, false, false),
        "k15mmseq_imbalanced" => kmm::kmm_seq("k15mmseq_imbalanced", 15, 1, false, true),
        "k15mmseq_relu" => kmm::kmm_seq("k15mmseq_relu", 15, 4, true, false),
        "k15mmseq_relu_imbalanced" => kmm::kmm_seq("k15mmseq_relu_imbalanced", 15, 2, true, true),
        "k15mmtree" => kmm::kmm_tree("k15mmtree", 16, 4, false, false),
        "k15mmtree_imbalanced" => kmm::kmm_tree("k15mmtree_imbalanced", 16, 3, false, true),
        "k15mmtree_relu" => kmm::kmm_tree("k15mmtree_relu", 16, 5, true, false),
        "k15mmtree_relu_imbalanced" => kmm::kmm_tree("k15mmtree_relu_imbalanced", 16, 5, true, true),
        "FeedForward" => dnn::feedforward(),
        "Autoencoder" => dnn::autoencoder(),
        "ResidualBlock" => dnn::residual_block(),
        "DepthSepConvBlock" => dnn::depth_sep_conv_block(),
        "ResMLP" => dnn::resmlp(),
        "fig2" => fig2::mult_by_2(16),
        "flowgnn_pna" => flowgnn::pna_default(),
        "mini_dnn" => dnn::mini_dnn_default(),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::collect_trace;

    #[test]
    fn all_designs_build_and_trace() {
        for name in all_names() {
            let bd = build(name);
            let t = collect_trace(&bd.design, &bd.args)
                .unwrap_or_else(|e| panic!("{name}: trace failed: {e}"));
            assert!(t.num_fifos() > 0, "{name}");
            assert!(t.total_ops() > 0, "{name}");
            // Every channel's traffic is balanced (all writes consumed).
            for c in &t.channels {
                assert_eq!(c.writes, c.reads, "{name}: channel {} unbalanced", c.name);
            }
        }
    }

    #[test]
    fn fifo_counts_track_table2() {
        // (name, paper FIFO count). Our generators must land within ±35%
        // (documented substitution tolerance in DESIGN.md).
        let expected: &[(&str, usize)] = &[
            ("atax", 175),
            ("Autoencoder", 392),
            ("bicg", 25),
            ("DepthSepConvBlock", 84),
            ("FeedForward", 848),
            ("gemm", 88),
            ("k2mm", 64),
            ("k3mm", 95),
            ("k7mmseq_balanced", 112),
            ("k7mmseq_unbalanced", 108),
            ("k7mmtree_unbalanced", 128),
            ("mvt", 288),
            ("ResidualBlock", 64),
            ("k15mmseq_imbalanced", 59),
            ("k15mmseq", 188),
            ("k15mmseq_relu_imbalanced", 116),
            ("k15mmseq_relu", 232),
            ("k15mmtree_imbalanced", 163),
            ("k15mmtree", 192),
            ("k15mmtree_relu_imbalanced", 340),
            ("k15mmtree_relu", 320),
        ];
        for &(name, paper) in expected {
            let ours = build(name).design.num_fifos();
            let lo = (paper as f64 * 0.65) as usize;
            let hi = (paper as f64 * 1.35) as usize;
            assert!(
                (lo..=hi).contains(&ours),
                "{name}: paper {paper} FIFOs, ours {ours} (outside ±35%)"
            );
        }
    }

    #[test]
    fn workload_builders_cover_specials_and_suite() {
        let w = build_workload("flowgnn_pna").unwrap();
        assert_eq!(w.num_scenarios(), 4);
        let w = build_workload("fig2").unwrap();
        assert_eq!(w.num_scenarios(), 3);
        let w = build_workload("mini_dnn").unwrap();
        assert_eq!(w.num_scenarios(), 3);
        let w = build_workload("bicg").unwrap();
        assert!(w.is_single());
        assert!(build_workload("nope").is_none());
    }

    #[test]
    fn arg_spaces_cover_scenario_args() {
        // Every design with an arg space traces under every point, and
        // its default scenario args are points of the space.
        for name in ["fig2", "flowgnn_pna", "mini_dnn"] {
            let a = arg_space(name).unwrap();
            let bd = build(name);
            assert_eq!(a.num_args(), bd.design.num_args);
            for (_, args) in scenario_args(name).unwrap() {
                assert!(
                    a.encode(&args).is_some(),
                    "{name}: scenario args {args:?} outside its arg space"
                );
            }
            // A corner of the space traces successfully.
            let corner = a.decode(&vec![u32::MAX; a.num_args()]);
            collect_trace(&bd.design, &corner)
                .unwrap_or_else(|e| panic!("{name}: corner {corner:?} failed: {e}"));
        }
        assert!(arg_space("gemm").is_none());
    }

    #[test]
    fn baseline_max_never_deadlocks() {
        use crate::sim::fast::FastSim;
        use std::sync::Arc;
        for name in all_names() {
            let bd = build(name);
            let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
            let mut sim = FastSim::new(t.clone());
            let out = sim.simulate(&t.baseline_max());
            assert!(!out.is_deadlock(), "{name} deadlocked at Baseline-Max");
        }
    }
}
