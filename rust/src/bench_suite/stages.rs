//! Streaming-stage library: composable building blocks that mirror how
//! Stream-HLS structures generated dataflow kernels — parallel PE arrays
//! connected by stream arrays (`hls::stream<float> pipe[P]`), weight/input
//! loader tasks, local-buffer replay tasks for data reuse, elementwise map
//! stages (ReLU/GELU/bias), and join/sink tasks.
//!
//! Every stage takes and returns a [`StageOut`]: `P` parallel channels
//! each carrying `tokens` values over the whole kernel execution. Stages
//! enforce token-count compatibility with assertions, so generator bugs
//! fail loudly at build time rather than producing silently-unbalanced
//! traffic.

use crate::ir::{ChannelId, DesignBuilder, Expr};

/// Output bundle of a stage: `chans[p]` carries `tokens` values.
#[derive(Debug, Clone)]
pub struct StageOut {
    pub chans: Vec<ChannelId>,
    pub tokens: u64,
}

/// Default stream element width (float32).
pub const F32: u32 = 32;

/// Quantized-weight stream width (int8): puts the full-overlap FIFO
/// depth at or below the 1024-bit SRL threshold, so right-sizing weight
/// FIFOs reaches zero BRAM without a latency penalty — the knee shape of
/// the paper's Fig. 3 frontiers.
pub const W8: u32 = 8;

/// A loader task (`load_A`): streams `tokens` values into each of `p`
/// channels, channel-major (one DRAM burst per destination channel, one
/// write per cycle). Channel-major order matches [`port_sources`] so
/// paired left/right operand bursts arrive PE-by-PE in the same order —
/// shallow FIFOs serialize the PEs (latency grows) but never deadlock.
pub fn source(b: &mut DesignBuilder, name: &str, p: usize, tokens: u64, width: u32) -> StageOut {
    let chans = b.channel_array(name, p, width);
    let chans_c = chans.clone();
    b.process(&format!("load_{name}"), move |pb| {
        for &c in &chans_c {
            pb.for_n(tokens, |pb, t| {
                pb.write(c, Expr::var(t));
            });
        }
    });
    StageOut { chans, tokens }
}

/// A parallel matmul / matvec PE array: PE `p` produces `out_tokens`
/// results; each result accumulates over `reduce` (left, right) pairs
/// read from the PE's left/right input channels, then spends
/// `extra_delay` cycles (activation, accumulation drain) before writing.
///
/// Token balance: `left.tokens == right.tokens == reduce * out_tokens`.
pub fn matmul(
    b: &mut DesignBuilder,
    name: &str,
    left: &StageOut,
    right: &StageOut,
    reduce: u64,
    out_tokens: u64,
    extra_delay: u32,
) -> StageOut {
    assert_eq!(left.chans.len(), right.chans.len(), "{name}: PE count mismatch");
    assert_eq!(
        left.tokens,
        reduce * out_tokens,
        "{name}: left tokens {} != reduce {} * out {}",
        left.tokens,
        reduce,
        out_tokens
    );
    assert_eq!(right.tokens, reduce * out_tokens, "{name}: right tokens");
    let p = left.chans.len();
    let out = b.channel_array(name, p, F32);
    for pe in 0..p {
        let (l, r, o) = (left.chans[pe], right.chans[pe], out[pe]);
        b.process(&format!("{name}_pe{pe}"), move |pb| {
            pb.for_n(out_tokens, |pb, _| {
                let acc = pb.var();
                pb.set(acc, Expr::c(0));
                pb.for_n(reduce, |pb, _| {
                    let a = pb.read(l);
                    let w = pb.read(r);
                    pb.set(acc, Expr::var(acc).add(Expr::var(a).mul(Expr::var(w))));
                });
                if extra_delay > 0 {
                    pb.delay(extra_delay);
                }
                pb.write(o, Expr::var(acc));
            });
        });
    }
    StageOut { chans: out, tokens: out_tokens }
}

/// Elementwise map stage (ReLU / GELU / bias-add): one PE per channel,
/// read → `delay` → write.
pub fn map(b: &mut DesignBuilder, name: &str, input: &StageOut, delay: u32) -> StageOut {
    let p = input.chans.len();
    let tokens = input.tokens;
    let out = b.channel_array(name, p, F32);
    for pe in 0..p {
        let (i, o) = (input.chans[pe], out[pe]);
        b.process(&format!("{name}_pe{pe}"), move |pb| {
            pb.for_n(tokens, |pb, _| {
                let v = pb.read(i);
                if delay > 0 {
                    pb.delay(delay);
                }
                // max(v, 0) — ReLU-shaped so values stay meaningful.
                pb.write(o, Expr::var(v).max(Expr::c(0)));
            });
        });
    }
    StageOut { chans: out, tokens }
}

/// Local-buffer replay stage (data reuse): each PE reads its whole input
/// stream into a local buffer, then streams it out `factor` times
/// (`tokens * factor` outputs). Models the BRAM-buffered reuse tasks
/// Stream-HLS inserts between matmul stages.
pub fn replay(b: &mut DesignBuilder, name: &str, input: &StageOut, factor: u64) -> StageOut {
    let p = input.chans.len();
    let tokens = input.tokens;
    let out = b.channel_array(name, p, F32);
    for pe in 0..p {
        let (i, o) = (input.chans[pe], out[pe]);
        b.process(&format!("{name}_pe{pe}"), move |pb| {
            // Fill local buffer (values are consumed; the VM does not
            // model the array contents, only the last value, which is
            // fine: downstream latency depends on timing, not values).
            let last = pb.var();
            pb.for_n(tokens, |pb, _| {
                pb.read_into(i, last);
            });
            pb.for_n(factor, |pb, _| {
                pb.for_n(tokens, |pb, _| {
                    pb.write(o, Expr::var(last));
                });
            });
        });
    }
    StageOut {
        chans: out,
        tokens: tokens * factor,
    }
}

/// A shared memory port (`load_all`): ONE process serving several stream
/// arrays *sequentially* — all tokens of stream 0, then stream 1, etc.
/// This is the realistic Stream-HLS/AXI pattern (one HBM port feeds every
/// weight stream) and the main source of the latency↔memory trade-off:
/// if an early stream's FIFOs are small, the port trickles at its
/// consumer's pace and every later stream (and its consumer stage) starts
/// late; sized to full depth, the port bursts and all stages overlap.
///
/// `specs` = (name, PE count, tokens per channel) per stream.
pub fn port_sources(
    b: &mut DesignBuilder,
    port_name: &str,
    specs: &[(&str, usize, u64)],
    width: u32,
) -> Vec<StageOut> {
    let outs: Vec<StageOut> = specs
        .iter()
        .map(|&(name, p, tokens)| StageOut {
            chans: b.channel_array(name, p, width),
            tokens,
        })
        .collect();
    let plan: Vec<(Vec<ChannelId>, u64)> = outs
        .iter()
        .map(|s| (s.chans.clone(), s.tokens))
        .collect();
    b.process(&format!("port_{port_name}"), move |pb| {
        for (chans, tokens) in &plan {
            // Channel-major bursts (a DRAM burst per destination stream):
            // each channel receives its whole allotment back-to-back at
            // one token/cycle — faster than any PE drains it, so shallow
            // FIFOs throttle the port and delay every later stream.
            for &c in chans {
                let tokens = *tokens;
                pb.for_n(tokens, |pb, t| {
                    pb.write(c, Expr::var(t));
                });
            }
        }
    });
    outs
}

/// Quantization-calibration sidecar: tee the input; a calibration task
/// consumes one whole copy to compute a scale factor it emits only at the
/// end; a requantize task must read the scale BEFORE processing the other
/// copy. The tee's data branch therefore has to buffer the entire block —
/// a *data-dependent-looking* full-buffer requirement whose deadlock
/// threshold equals the block size (`input.tokens`). With 32-bit data and
/// 32-token blocks the rescue depth is exactly the SRL limit, so the
/// un-deadlocked fix costs zero BRAM (the §IV-B "×→✓ at 0 BRAM" cases).
pub fn scale_sidecar(b: &mut DesignBuilder, name: &str, input: &StageOut) -> StageOut {
    let p = input.chans.len();
    let tokens = input.tokens;
    let (data, calib_in) = tee(b, &format!("{name}_tee"), input);
    let scale = b.channel_array(&format!("{name}_scale"), p, F32);
    let out = b.channel_array(name, p, F32);
    for pe in 0..p {
        let (ci, sc) = (calib_in.chans[pe], scale[pe]);
        b.process(&format!("{name}_calib{pe}"), move |pb| {
            let mx = pb.var();
            pb.set(mx, Expr::c(0));
            pb.for_n(tokens, |pb, _| {
                let v = pb.read(ci);
                pb.set(mx, Expr::var(mx).max(Expr::var(v)));
            });
            pb.write(sc, Expr::var(mx));
        });
        let (di, sc, o) = (data.chans[pe], scale[pe], out[pe]);
        b.process(&format!("{name}_requant{pe}"), move |pb| {
            let s = pb.read(sc);
            pb.for_n(tokens, |pb, _| {
                let v = pb.read(di);
                pb.delay(1);
                pb.write(o, Expr::var(v).min(Expr::var(s)));
            });
        });
    }
    StageOut { chans: out, tokens }
}

/// Elementwise binary join (residual add): reads one token from each
/// side, writes one.
pub fn join_add(
    b: &mut DesignBuilder,
    name: &str,
    a: &StageOut,
    c: &StageOut,
    delay: u32,
) -> StageOut {
    assert_eq!(a.chans.len(), c.chans.len(), "{name}: PE count mismatch");
    assert_eq!(a.tokens, c.tokens, "{name}: token mismatch");
    let p = a.chans.len();
    let tokens = a.tokens;
    let out = b.channel_array(name, p, F32);
    for pe in 0..p {
        let (x, y, o) = (a.chans[pe], c.chans[pe], out[pe]);
        b.process(&format!("{name}_pe{pe}"), move |pb| {
            pb.for_n(tokens, |pb, _| {
                let u = pb.read(x);
                let v = pb.read(y);
                if delay > 0 {
                    pb.delay(delay);
                }
                pb.write(o, Expr::var(u).add(Expr::var(v)));
            });
        });
    }
    StageOut { chans: out, tokens }
}

/// Sink task (`store_C`): drains all channels channel-major (one AXI
/// write burst per channel — matching the loaders' burst order so
/// shallow FIFOs serialize rather than deadlock), `delay` cycles/beat.
pub fn sink(b: &mut DesignBuilder, name: &str, input: &StageOut, delay: u32) {
    let chans = input.chans.clone();
    let tokens = input.tokens;
    b.process(&format!("store_{name}"), move |pb| {
        for &c in &chans {
            pb.for_n(tokens, |pb, _| {
                let _ = pb.read(c);
                if delay > 0 {
                    pb.delay(delay);
                }
            });
        }
    });
}

/// Split one stage into two identical consumers by inserting a `tee`
/// task per channel (needed because channels are single-consumer). Used
/// for residual/skip connections.
pub fn tee(b: &mut DesignBuilder, name: &str, input: &StageOut) -> (StageOut, StageOut) {
    let p = input.chans.len();
    let tokens = input.tokens;
    let out_a = b.channel_array(&format!("{name}_a"), p, F32);
    let out_b = b.channel_array(&format!("{name}_b"), p, F32);
    for pe in 0..p {
        let (i, a, c) = (input.chans[pe], out_a[pe], out_b[pe]);
        b.process(&format!("{name}_pe{pe}"), move |pb| {
            pb.for_n(tokens, |pb, _| {
                let v = pb.read(i);
                pb.write(a, Expr::var(v));
                pb.write(c, Expr::var(v));
            });
        });
    }
    (
        StageOut { chans: out_a, tokens },
        StageOut { chans: out_b, tokens },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fast::FastSim;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    #[test]
    fn source_matmul_sink_composes() {
        let mut b = DesignBuilder::new("t", 0);
        let a = source(&mut b, "a", 2, 12, F32);
        let w = source(&mut b, "w", 2, 12, F32);
        let c = matmul(&mut b, "c", &a, &w, 4, 3, 0);
        sink(&mut b, "out", &c, 0);
        let d = b.build();
        assert_eq!(d.num_fifos(), 6);
        let t = collect_trace(&d, &[]).unwrap();
        assert_eq!(t.channels[4].writes, 3); // c[0]
        let mut s = FastSim::new(Arc::new(t));
        assert!(!s.simulate(&[2; 6]).is_deadlock());
    }

    #[test]
    fn replay_multiplies_tokens() {
        let mut b = DesignBuilder::new("t", 0);
        let a = source(&mut b, "a", 1, 5, F32);
        let r = replay(&mut b, "r", &a, 3);
        assert_eq!(r.tokens, 15);
        sink(&mut b, "out", &r, 0);
        let d = b.build();
        let t = collect_trace(&d, &[]).unwrap();
        assert_eq!(t.channels[1].writes, 15);
        assert_eq!(t.channels[1].reads, 15);
    }

    #[test]
    fn tee_duplicates_and_join_rebalances() {
        let mut b = DesignBuilder::new("t", 0);
        let a = source(&mut b, "a", 2, 8, F32);
        let (t1, t2) = tee(&mut b, "tee", &a);
        let m = map(&mut b, "relu", &t1, 1);
        let j = join_add(&mut b, "add", &m, &t2, 0);
        sink(&mut b, "out", &j, 0);
        let d = b.build();
        let tr = collect_trace(&d, &[]).unwrap();
        let mut s = FastSim::new(Arc::new(tr.clone()));
        // Tight depths can deadlock a diamond; baseline-max can not.
        assert!(!s.simulate(&tr.baseline_max()).is_deadlock());
    }

    #[test]
    #[should_panic(expected = "left tokens")]
    fn token_mismatch_is_loud() {
        let mut b = DesignBuilder::new("t", 0);
        let a = source(&mut b, "a", 1, 10, F32);
        let w = source(&mut b, "w", 1, 12, F32);
        let _ = matmul(&mut b, "c", &a, &w, 4, 3, 0);
    }
}
