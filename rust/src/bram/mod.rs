//! FIFO memory-usage model — the paper's `f_bram` (§III-B) and the
//! design-space pruning it enables (§III-C).
//!
//! Implements Algorithm 1 exactly: a BRAM_18K primitive supports the
//! (depth × width) configurations 1K×18, 2K×9, 4K×4, 8K×2, 16K×1; FIFOs
//! with depth ≤ 2 or total size ≤ 1024 bits are implemented as shift
//! registers (SRL) and consume zero BRAMs. The model targets the
//! UltraScale+ BRAM18K primitive (Alveo U280 in the paper's evaluation);
//! [`UramModel`] extends the same ladder scheme to URAM288 primitives
//! (flagged as future work in §III-B, implemented here).

/// The BRAM_18K (depth, width) configuration ladder, widest first.
pub const BRAM18K_SHAPES: [(u32, u32); 5] = [
    (1024, 18),
    (2048, 9),
    (4096, 4),
    (8192, 2),
    (16384, 1),
];

/// Total bits at or below which Vitis implements the FIFO as a shift
/// register (zero BRAM).
pub const SRL_THRESHOLD_BITS: u64 = 1024;

/// BRAM_18K count for one FIFO of `depth` elements × `width_bits` bits
/// (paper Algorithm 1).
pub fn bram_for_fifo(depth: u32, width_bits: u32) -> u32 {
    if is_srl(depth, width_bits) {
        return 0;
    }
    let mut n = 0u32;
    let mut w = width_bits;
    for (di, wi) in BRAM18K_SHAPES {
        n += (w / wi) * depth.div_ceil(di);
        w %= wi;
        if w > 0 && depth <= di {
            n += 1;
            w = 0;
        }
    }
    n
}

/// Whether a FIFO of this shape is implemented as a shift register
/// (consumes zero BRAM, and — footnote 2 of the paper — has one cycle
/// less read latency than a BRAM-backed FIFO).
#[inline]
pub fn is_srl(depth: u32, width_bits: u32) -> bool {
    depth <= 2 || (depth as u64) * (width_bits as u64) <= SRL_THRESHOLD_BITS
}

/// Total BRAM count for a full FIFO configuration.
pub fn bram_total(depths: &[u32], widths: &[u32]) -> u32 {
    assert_eq!(depths.len(), widths.len());
    depths
        .iter()
        .zip(widths)
        .map(|(&d, &w)| bram_for_fifo(d, w))
        .sum()
}

/// §III-C pruning: the per-FIFO candidate depth set.
///
/// `f_bram` is a step function of depth, so only depths that *maximally
/// utilize* their allocated BRAMs need be explored: depth 2 (minimum), the
/// largest depth at each BRAM-count plateau, and the upper bound `u`.
/// E.g. for width 32 and u = 4096 this returns depths like
/// `[2, 32, 1024, 2048, 3072, 4096]` instead of 4095 points.
pub fn candidate_depths(width_bits: u32, u: u32) -> Vec<u32> {
    let u = u.max(2);
    let mut out = vec![2u32];
    if u == 2 {
        return out;
    }
    // Plateau boundaries: bram(d) < bram(d+1) means d is the last depth of
    // its plateau. Candidate boundary depths are (a) the SRL threshold and
    // (b) multiples of the ladder depths, so we test just those rather
    // than scanning every depth.
    let mut boundaries: Vec<u32> = Vec::new();
    let srl_max = (SRL_THRESHOLD_BITS / width_bits.max(1) as u64) as u32;
    if srl_max > 2 {
        boundaries.push(srl_max.min(u));
    }
    for (di, _) in BRAM18K_SHAPES {
        let mut d = di;
        while d < u {
            boundaries.push(d);
            d = d.saturating_add(di);
        }
    }
    boundaries.push(u);
    boundaries.sort_unstable();
    boundaries.dedup();
    for b in boundaries {
        if b <= 2 || b > u {
            continue;
        }
        // Keep b if it ends a BRAM plateau (cost strictly increases at
        // b+1) or it is the upper bound. Plateau ends can only fall on the
        // SRL threshold or multiples of ladder depths, all of which are in
        // `boundaries`, so nothing is missed (validated against the O(u)
        // scan in tests).
        if b == u || bram_for_fifo(b, width_bits) < bram_for_fifo(b + 1, width_bits) {
            out.push(b);
        }
    }
    out
}

/// Exhaustive (scan-based) candidate set, used to validate
/// [`candidate_depths`] in tests. O(u).
pub fn candidate_depths_scan(width_bits: u32, u: u32) -> Vec<u32> {
    let u = u.max(2);
    let mut out = vec![2u32];
    for d in 3..=u {
        if d == u || bram_for_fifo(d, width_bits) < bram_for_fifo(d + 1, width_bits) {
            out.push(d);
        }
    }
    out
}

/// Flip-flop / LUT cost model for FIFOs — the paper's §III-B "optimizing
/// both BRAM and FF usage is in the scope of future work", implemented
/// here as an auxiliary metric (reported, not yet a third objective).
///
/// SRL-mapped FIFOs burn shift-register LUTs (one SRL32 chain per bit
/// column per 32 depth) plus I/O registers; BRAM FIFOs only pay the I/O
/// registers and the occupancy counters.
pub fn ff_for_fifo(depth: u32, width_bits: u32) -> u32 {
    let counters = 2 * (32 - depth.max(2).leading_zeros()); // 2 × ⌈log2 d⌉
    if is_srl(depth, width_bits) {
        // SRL consumes LUTs, not FFs, for storage; FFs for I/O + count.
        2 * width_bits + counters
    } else {
        2 * width_bits + counters + 8 // BRAM output pipeline regs
    }
}

/// Shift-register LUT count for an SRL-mapped FIFO (0 for BRAM FIFOs).
pub fn srl_luts_for_fifo(depth: u32, width_bits: u32) -> u32 {
    if is_srl(depth, width_bits) {
        depth.div_ceil(32) * width_bits
    } else {
        0
    }
}

/// URAM288 model (8 bits × 4096 / 16 bits × 4096 / ... the URAM primitive
/// is fixed 72 bits × 4096 with no width ladder; Vitis packs FIFOs into
/// ⌈w/72⌉ × ⌈d/4096⌉ URAMs and never SRL-maps them).
pub struct UramModel;

impl UramModel {
    /// URAM288 count for one FIFO.
    pub fn uram_for_fifo(depth: u32, width_bits: u32) -> u32 {
        if depth <= 2 {
            return 0;
        }
        width_bits.div_ceil(72) * depth.div_ceil(4096)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn srl_fifos_cost_zero() {
        assert_eq!(bram_for_fifo(2, 512), 0);
        assert_eq!(bram_for_fifo(1, 32), 0);
        assert_eq!(bram_for_fifo(32, 32), 0); // 1024 bits == threshold
        assert_ne!(bram_for_fifo(33, 32), 0); // 1056 bits > threshold
    }

    #[test]
    fn algorithm1_worked_examples() {
        // 1024 × 32b: one 1K×18 column (32/18=1, rem 14) + the d≤1024
        // remainder rule fires on the first rung → 2 BRAMs.
        assert_eq!(bram_for_fifo(1024, 32), 2);
        // 1024 × 18b: exactly one 1K×18.
        assert_eq!(bram_for_fifo(1024, 18), 1);
        // 2048 × 18b: two 1K×18.
        assert_eq!(bram_for_fifo(2048, 18), 2);
        // 2048 × 9b: one 2K×9.
        assert_eq!(bram_for_fifo(2048, 9), 1);
        // 4096 × 14b: 14 = 9 + 4 + 1 → ceil(4096/2048)=2 (2K×9)
        //   + ceil(4096/4096)=1 (4K×4), then rem 1 with d ≤ 4096 → +1 = 4.
        assert_eq!(bram_for_fifo(4096, 14), 4);
        // 16384 × 1b: one 16K×1.
        assert_eq!(bram_for_fifo(16384, 1), 1);
        // 512 × 36b (large element, shallow): 36/18 = 2 → 2 BRAMs.
        assert_eq!(bram_for_fifo(512, 36), 2);
    }

    #[test]
    fn monotone_in_depth() {
        for w in [1u32, 8, 9, 16, 18, 32, 64, 128] {
            let mut prev = 0;
            for d in 2..5000 {
                let b = bram_for_fifo(d, w);
                assert!(b >= prev, "w={w} d={d}: {b} < {prev}");
                prev = b;
            }
        }
    }

    #[test]
    fn width_is_not_monotone_by_design() {
        // A genuine quirk of the BRAM18K ladder the model must reproduce:
        // a 9-bit FIFO packs into one 2K×9 column, while an 8-bit FIFO of
        // the same depth needs two 4K×4 columns — narrower can cost MORE.
        assert_eq!(bram_for_fifo(10000, 9), 5); // 1 × ceil(10000/2048)
        assert_eq!(bram_for_fifo(10000, 8), 6); // 2 × ceil(10000/4096)
        assert!(bram_for_fifo(10000, 8) > bram_for_fifo(10000, 9));
    }

    #[test]
    fn candidates_match_exhaustive_scan() {
        for w in [1u32, 4, 8, 9, 16, 18, 32, 37, 64, 128] {
            for u in [2u32, 3, 10, 31, 32, 33, 100, 1024, 1025, 5000, 16384] {
                let fast = candidate_depths(w, u);
                let slow = candidate_depths_scan(w, u);
                assert_eq!(fast, slow, "w={w} u={u}");
            }
        }
    }

    #[test]
    fn candidates_are_sorted_unique_and_bounded() {
        let c = candidate_depths(32, 4096);
        assert_eq!(c[0], 2);
        assert_eq!(*c.last().unwrap(), 4096);
        for pair in c.windows(2) {
            assert!(pair[0] < pair[1]);
        }
        // Pruning must be drastic vs the 4095-point raw space (§III-C).
        assert!(c.len() < 20, "pruned space too large: {}", c.len());
    }

    #[test]
    fn paper_example_2047_pruned() {
        // "decreasing a FIFO's depth from 2048 to 2047 will never change
        // the number of BRAMs ... we can skip testing depth 2047"
        let c = candidate_depths(18, 4096);
        assert!(c.contains(&2048));
        assert!(!c.contains(&2047));
        assert_eq!(bram_for_fifo(2047, 18), bram_for_fifo(2048, 18));
    }

    #[test]
    fn bram_total_sums() {
        assert_eq!(
            bram_total(&[1024, 2, 2048], &[32, 32, 18]),
            bram_for_fifo(1024, 32) + bram_for_fifo(2048, 18)
        );
    }

    #[test]
    fn ff_and_lut_models() {
        // SRL FIFO: storage in LUTs, not FFs.
        assert!(srl_luts_for_fifo(32, 32) > 0);
        assert_eq!(srl_luts_for_fifo(4096, 32), 0); // BRAM-mapped
        assert_eq!(srl_luts_for_fifo(32, 32), 32); // one SRL32 per bit
        assert_eq!(srl_luts_for_fifo(64, 8), 16); // two chains × 8 bits
        // FF cost grows with width and (log) depth, BRAM adds pipeline.
        assert!(ff_for_fifo(4096, 32) > ff_for_fifo(16, 32));
        assert!(ff_for_fifo(16, 64) > ff_for_fifo(16, 32));
    }

    #[test]
    fn uram_model_basics() {
        assert_eq!(UramModel::uram_for_fifo(2, 72), 0);
        assert_eq!(UramModel::uram_for_fifo(4096, 72), 1);
        assert_eq!(UramModel::uram_for_fifo(4097, 72), 2);
        assert_eq!(UramModel::uram_for_fifo(4096, 73), 2);
    }
}
