//! Flag parsing: `--key value` and bare `--flag` pairs. A `--key` may be
//! repeated; [`Args::get`] returns the last occurrence (override
//! semantics) while [`Args::get_all`]/[`Args::get_lists`] return every
//! occurrence in order (the CLI's multi-scenario `--args` path).

use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

#[derive(Debug, Default)]
pub struct Args {
    values: HashMap<String, Vec<String>>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow!("expected --flag, got '{a}'"))?;
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                out.values
                    .entry(key.to_string())
                    .or_default()
                    .push(argv[i + 1].clone());
                i += 2;
            } else {
                out.flags.push(key.to_string());
                i += 1;
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.values
            .get(key)
            .and_then(|v| v.last())
            .map(|s| s.as_str())
    }

    /// Every occurrence of `--key value`, in command-line order.
    pub fn get_all(&self, key: &str) -> &[String] {
        self.values.get(key).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key)
            .ok_or_else(|| anyhow!("missing required --{key}"))
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    /// A strictly positive number (budgets and timeouts — zero or
    /// negative values are config errors, not "disabled"); `None` when
    /// the flag is absent.
    pub fn get_positive_f64(&self, key: &str) -> Result<Option<f64>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => {
                let x: f64 = v
                    .parse()
                    .map_err(|_| anyhow!("--{key} expects a number, got '{v}'"))?;
                if !x.is_finite() || x <= 0.0 {
                    bail!("--{key} must be a positive number, got '{v}'");
                }
                Ok(Some(x))
            }
        }
    }

    /// Comma-separated integer list (last occurrence).
    pub fn get_list(&self, key: &str) -> Result<Option<Vec<i64>>> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => parse_int_list(key, v).map(Some),
        }
    }

    /// One parsed comma-separated integer list per `--key` occurrence
    /// (empty when the flag never appears).
    pub fn get_lists(&self, key: &str) -> Result<Vec<Vec<i64>>> {
        self.get_all(key)
            .iter()
            .map(|v| parse_int_list(key, v))
            .collect()
    }
}

fn parse_int_list(key: &str, v: &str) -> Result<Vec<i64>> {
    let mut out = Vec::new();
    for part in v.split(',') {
        let p = part.trim();
        if p.is_empty() {
            bail!("--{key}: empty element in list '{v}'");
        }
        out.push(
            p.parse()
                .map_err(|_| anyhow!("--{key}: bad integer '{p}'"))?,
        );
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(parts: &[&str]) -> Vec<String> {
        parts.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_values_and_flags() {
        let a = Args::parse(&sv(&["--design", "gemm", "--xla", "--budget", "500"])).unwrap();
        assert_eq!(a.get("design"), Some("gemm"));
        assert!(a.has_flag("xla"));
        assert_eq!(a.get_u64("budget", 1000).unwrap(), 500);
        assert_eq!(a.get_u64("seed", 1).unwrap(), 1);
    }

    #[test]
    fn rejects_positional() {
        assert!(Args::parse(&sv(&["gemm"])).is_err());
    }

    #[test]
    fn lists() {
        let a = Args::parse(&sv(&["--args", "64, 512,7"])).unwrap();
        assert_eq!(a.get_list("args").unwrap(), Some(vec![64, 512, 7]));
        assert_eq!(a.get_list("missing").unwrap(), None);
        let bad = Args::parse(&sv(&["--args", "1,,2"])).unwrap();
        assert!(bad.get_list("args").is_err());
    }

    #[test]
    fn repeated_flags_collect_in_order() {
        let a = Args::parse(&sv(&["--args", "1,2", "--seed", "5", "--args", "3,4"])).unwrap();
        // `get` keeps override semantics (last wins)…
        assert_eq!(a.get("args"), Some("3,4"));
        assert_eq!(a.get_list("args").unwrap(), Some(vec![3, 4]));
        // …while `get_all`/`get_lists` see every occurrence in order.
        assert_eq!(a.get_all("args"), &["1,2".to_string(), "3,4".to_string()]);
        assert_eq!(a.get_lists("args").unwrap(), vec![vec![1, 2], vec![3, 4]]);
        assert!(a.get_all("missing").is_empty());
        assert!(a.get_lists("missing").unwrap().is_empty());
        let bad = Args::parse(&sv(&["--args", "1", "--args", "x"])).unwrap();
        assert!(bad.get_lists("args").is_err());
    }

    #[test]
    fn positive_f64_validates() {
        let a = Args::parse(&sv(&["--timeout-secs", "2.5"])).unwrap();
        assert_eq!(a.get_positive_f64("timeout-secs").unwrap(), Some(2.5));
        assert_eq!(a.get_positive_f64("missing").unwrap(), None);
        let zero = Args::parse(&sv(&["--timeout-secs", "0"])).unwrap();
        assert!(zero.get_positive_f64("timeout-secs").is_err());
        let neg = Args::parse(&sv(&["--timeout-secs", "-3"])).unwrap();
        assert!(neg.get_positive_f64("timeout-secs").is_err());
        let junk = Args::parse(&sv(&["--timeout-secs", "soon"])).unwrap();
        assert!(junk.get_positive_f64("timeout-secs").is_err());
    }

    #[test]
    fn require_errors() {
        let a = Args::parse(&sv(&[])).unwrap();
        assert!(a.require("design").is_err());
    }
}
