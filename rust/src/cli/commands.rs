//! Subcommand implementations.

use super::Args;
use crate::bench_suite;
use crate::dse::{drive, CancelToken, EvalPoint, Evaluator};
use crate::opt::objective::select_highlight;
use crate::opt::{self, Space};
use crate::report::{self, ascii};
use crate::sim::BackendKind;
use crate::trace::workload::Workload;
use crate::util::stats::fmt_duration;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

/// Parse `--backend {fast,compiled,batched}` (defaults to the
/// event-driven fast simulator). Backend selection never changes
/// results — only the throughput profile.
fn parse_backend(args: &Args) -> Result<BackendKind> {
    match args.get("backend") {
        None => Ok(BackendKind::Fast),
        Some(s) => BackendKind::parse(s).map_err(|e| anyhow!("--backend: {e}")),
    }
}

fn load_workload(args: &Args) -> Result<(String, Arc<Workload>)> {
    // Four sources, in precedence order: a saved workload JSON, a cached
    // trace JSON, a FADL design file, or a built-in suite design. The
    // design paths accept a repeatable `--args A,B,..` — each occurrence
    // becomes one scenario of the workload.
    if let Some(path) = args.get("scenario-file") {
        let w = Workload::load(path)?;
        return Ok((w.design_name().to_string(), Arc::new(w)));
    }
    if let Some(path) = args.get("trace-file") {
        let t = crate::trace::serde::load(path)?;
        let name = t.design_name.clone();
        return Ok((name, Arc::new(Workload::single(Arc::new(t)))));
    }
    let (name, design, default_args) = if let Some(path) = args.get("design-file") {
        let design = crate::ir::fadl::parse_file(path)?;
        // FADL designs default to all-zero args of the right arity (a
        // zero-length vector would trip the arg-count check whenever
        // num_args > 0).
        let defaults = vec![0i64; design.num_args];
        if design.num_args > 0 && args.get_all("args").is_empty() {
            println!(
                "note: design '{}' takes {} runtime arg(s); tracing with all-zero defaults \
                 (pass --args A,B,.. to override)",
                design.name, design.num_args
            );
        }
        (design.name.clone(), design, defaults)
    } else {
        let name = args.require("design")?.to_string();
        let bd = bench_suite::try_build(&name)
            .ok_or_else(|| anyhow!("unknown design '{name}' (see `fifoadvisor list`)"))?;
        (name, bd.design, bd.args)
    };
    let arg_sets = args.get_lists("args")?;
    let sets: Vec<Vec<i64>> = if arg_sets.is_empty() {
        vec![default_args]
    } else {
        arg_sets
    };
    let w = Workload::from_design_args(&design, &sets)?;
    if let Some(out) = args.get("save-trace") {
        crate::trace::serde::save(w.primary(), out)?;
        println!("saved trace to {out}");
    }
    if let Some(out) = args.get("save-workload") {
        w.save(out)?;
        println!("saved {}-scenario workload to {out}", w.num_scenarios());
    }
    Ok((name, Arc::new(w)))
}

/// Run a sweep configuration file (designs × optimizers × seeds)
/// through the fault-tolerant orchestrator. `--resume`, `--shard i/n`,
/// and `--out-dir DIR` override the matching config keys, so one config
/// file serves every shard of a CI matrix and the final merge pass.
pub fn sweep(args: &Args) -> Result<()> {
    let path = args.require("config")?;
    let mut cfg = crate::dse::sweep::SweepConfig::from_file(path)?;
    if args.has_flag("resume") {
        cfg.resume = true;
    }
    if let Some(dir) = args.get("out-dir") {
        cfg.out_dir = Some(dir.to_string());
    }
    if let Some(s) = args.get("shard") {
        cfg.shard = Some(crate::dse::sweep::parse_shard(s)?);
    }
    println!(
        "sweep: {} designs × {} optimizers × {} seeds, budget {}{}{}",
        cfg.designs.len(),
        cfg.optimizers.len(),
        cfg.seeds.len(),
        cfg.budget,
        match cfg.shard {
            Some((i, n)) => format!(", shard {i}/{n}"),
            None => String::new(),
        },
        if cfg.resume { ", resuming" } else { "" }
    );
    let out = crate::dse::sweep::run_sweep_with(&cfg, &Default::default())?;
    print!("{}", crate::dse::sweep::rows_to_markdown(&out.rows));
    if out.resumed > 0 {
        println!("resumed {} done cell(s) from the manifest", out.resumed);
    }
    if out.truncated > 0 {
        println!(
            "{} cell(s) hit a per-cell budget and kept best-so-far fronts (✂)",
            out.truncated
        );
    }
    if let Some(dir) = &cfg.out_dir {
        if cfg.shard.is_none() {
            report::write_file(
                &format!("{dir}/summary.md"),
                &crate::dse::sweep::rows_to_markdown(&out.rows),
            )?;
        }
        println!("per-run JSON + manifest written to {dir}/");
    }
    if !out.failed.is_empty() {
        for f in &out.failed {
            println!(
                "FAILED {}/{}/s{} after {} attempt(s): {}",
                f.design, f.optimizer, f.seed, f.attempts, f.reason
            );
        }
        bail!(
            "sweep: {} cell(s) failed (recorded in the manifest; rerun with --resume to retry)",
            out.failed.len()
        );
    }
    Ok(())
}

pub fn list() -> Result<()> {
    println!("Stream-HLS suite:");
    for n in bench_suite::all_names() {
        let bd = bench_suite::build(n);
        println!(
            "  {n:<28} {:>5} FIFOs  {:>2} args",
            bd.design.num_fifos(),
            bd.design.num_args
        );
    }
    println!("specials (data-dependent control flow; traces are argument-specific):");
    for n in ["fig2", "flowgnn_pna"] {
        let bd = bench_suite::build(n);
        println!(
            "  {n:<28} {:>5} FIFOs  {:>2} args",
            bd.design.num_fifos(),
            bd.design.num_args
        );
    }
    Ok(())
}

pub fn info(args: &Args) -> Result<()> {
    let (name, w) = load_workload(args)?;
    let space = Space::from_workload(&w);
    println!("design       : {name}");
    println!("processes    : {}", w.primary().process_names.len());
    println!("FIFOs        : {}", w.num_fifos());
    println!("scenarios    : {}", w.num_scenarios());
    if w.num_scenarios() > 1 {
        for s in w.scenarios() {
            println!(
                "    {:<20} args {:?}  {:>8} ops  weight {}",
                s.name,
                s.trace.args,
                s.trace.total_ops(),
                s.weight
            );
        }
    }
    println!("groups       : {}", space.groups.len());
    println!("trace ops    : {}", w.total_ops());
    println!("pruned space : 10^{:.1} configurations", space.log10_size());
    print_depth_bounds(&w, &space);
    let mut ev = Evaluator::for_workload(w.clone(), 1);
    let (maxp, minp) = ev.eval_baselines();
    println!(
        "Baseline-Max : latency {} cycles, {} BRAM",
        maxp.latency.unwrap(),
        maxp.bram
    );
    match minp.latency {
        Some(l) => println!("Baseline-Min : latency {l} cycles, {} BRAM", minp.bram),
        None => println!("Baseline-Min : DEADLOCK"),
    }
    Ok(())
}

/// The per-channel `[lower, cap]` ranges the optimizers actually search,
/// with each bound's provenance. Small designs get the full table;
/// larger ones list only the channels where the analytic pass improved
/// on the trivial `[2, write-count]` range.
fn print_depth_bounds(w: &Workload, space: &Space) {
    use crate::opt::bounds::{BoundSource, DepthBounds};
    let b = DepthBounds::for_workload(w);
    let n = b.num_fifos();
    println!(
        "depth bounds : {} analytic floor(s), {} tightened cap(s)",
        b.num_floored(),
        b.num_cap_tightenings()
    );
    let src = |s: BoundSource| match s {
        BoundSource::Analytic => "analytic",
        BoundSource::WriteCount => "write-count",
    };
    let rows: Vec<usize> = if n <= 16 {
        (0..n).collect()
    } else {
        (0..n)
            .filter(|&ch| {
                b.floor_source(ch) == BoundSource::Analytic
                    || b.cap_source(ch) == BoundSource::Analytic
            })
            .collect()
    };
    if n > 16 && !rows.is_empty() {
        println!("    ({} of {n} channels have a non-trivial bound)", rows.len());
    }
    const MAX_ROWS: usize = 32;
    let names = &w.primary().channels;
    for &ch in rows.iter().take(MAX_ROWS) {
        println!(
            "    {:<24} [{:>5}, {:>6}]  floor: {}, cap: {}",
            names[ch].name,
            space.min_depth(ch).min(space.bounds[ch].max(2)),
            space.bounds[ch].max(2),
            src(b.floor_source(ch)),
            src(b.cap_source(ch)),
        );
    }
    if rows.len() > MAX_ROWS {
        println!("    ... {} more", rows.len() - MAX_ROWS);
    }
}

pub fn simulate(args: &Args) -> Result<()> {
    let (name, w) = load_workload(args)?;
    let depths: Vec<u32> = if let Some(d) = args.get_list("depths")? {
        if d.len() != w.num_fifos() {
            bail!(
                "--depths has {} entries, design '{name}' has {} FIFOs",
                d.len(),
                w.num_fifos()
            );
        }
        d.into_iter().map(|x| x.max(1) as u32).collect()
    } else {
        match args.get("baseline").unwrap_or("max") {
            "max" => w.baseline_max(),
            "min" => w.baseline_min(),
            other => bail!("--baseline must be max|min, got '{other}'"),
        }
    };
    let mut ev = Evaluator::for_workload_with_sim(w.clone(), 1, parse_backend(args)?);
    let t0 = std::time::Instant::now();
    let (lat, bram) = ev.eval(&depths);
    let dt = t0.elapsed().as_secs_f64();
    match lat {
        Some(l) => println!(
            "{name}: latency {l} cycles, {bram} BRAM  (simulated in {})",
            fmt_duration(dt)
        ),
        None => println!(
            "{name}: DEADLOCK  ({bram} BRAM)  (simulated in {})",
            fmt_duration(dt)
        ),
    }
    if w.num_scenarios() > 1 {
        for (sname, l) in ev.per_scenario_latencies(&depths) {
            match l {
                Some(l) => println!("    {sname:<20} {l} cycles"),
                None => println!("    {sname:<20} DEADLOCK"),
            }
        }
    }
    Ok(())
}

pub fn optimize(args: &Args) -> Result<()> {
    let (name, w) = load_workload(args)?;
    let opt_name = args.get("optimizer").unwrap_or("grouped_sa").to_string();
    let budget = args.get_u64("budget", 1000)? as usize;
    let seed = args.get_u64("seed", 1)?;
    // `--jobs` is the canonical worker-count flag; `--threads` stays as
    // a legacy alias.
    let jobs = match args.get("jobs") {
        Some(_) => args.get_u64("jobs", 4)?,
        None => args.get_u64("threads", 4)?,
    } as usize;
    let alpha = args.get_f64("alpha", 0.7)?;
    let backend = parse_backend(args)?;
    let timeout_secs = args.get_positive_f64("timeout-secs")?;

    let mut ev = if args.has_flag("xla") {
        let analytics = crate::runtime::BatchAnalytics::load_default()?;
        println!("batched analytics: platform {}", analytics.platform());
        Evaluator::for_workload_full(
            w.clone(),
            Box::new(crate::runtime::XlaBram::new(analytics)),
            jobs,
            backend,
        )
    } else {
        Evaluator::for_workload_with_sim(w.clone(), jobs, backend)
    };
    // A/B escape hatch: disable the simulation-free pruning layer
    // (dominance oracle, occupancy clamp, scenario early exit). Results
    // are identical either way; only the sims/sec differ.
    if args.has_flag("no-prune") {
        ev.set_prune(false);
    }
    // Same for the analytic depth-bounds layer (floor short-circuit,
    // oracle seeding, tightened clamp caps). The search space keeps its
    // analytic collapse either way — the flag only toggles the engine
    // side, so histories stay bit-identical for the A/B comparison.
    if args.has_flag("no-bounds") {
        ev.set_bounds(false);
    }
    let b = ev.depth_bounds();
    if b.num_floored() > 0 || b.num_cap_tightenings() > 0 {
        println!(
            "  bounds: {} analytic floor(s), {} tightened cap(s){}",
            b.num_floored(),
            b.num_cap_tightenings(),
            if ev.bounds() { "" } else { " (engine layer OFF)" }
        );
    }
    let space = Space::from_workload(&w);
    let (base, minp) = ev.eval_baselines();
    ev.reset_run(false);
    // Wall-clock budget: drive stops at the next ask/tell round once the
    // deadline passes, keeping the best-so-far front (flagged truncated).
    if let Some(t) = timeout_secs {
        let limit = std::time::Duration::from_secs_f64(t);
        ev.set_cancel_token(CancelToken::with_timeout(limit));
    }

    let mut optimizer = opt::by_name(&opt_name, seed)
        .ok_or_else(|| anyhow!("unknown optimizer '{opt_name}'"))?;
    let t0 = std::time::Instant::now();
    drive(&mut *optimizer, &mut ev, &space, budget);
    let dt = t0.elapsed().as_secs_f64();

    let front: Vec<EvalPoint> = ev.pareto().into_iter().cloned().collect();
    println!(
        "{name} × {opt_name}: {} evals ({} sims) in {} → {} Pareto points",
        ev.n_evals(),
        ev.n_sim,
        fmt_duration(dt),
        front.len()
    );
    println!("  engine: {}", report::engine_stats_line(&ev));
    if ev.truncated() {
        println!(
            "  NOTE: hit --timeout-secs {} — best-so-far front below; the run JSON is \
             flagged \"truncated\"",
            timeout_secs.unwrap_or(0.0)
        );
    }
    let base_lat = base.latency.unwrap();
    println!(
        "  Baseline-Max: {} cycles / {} BRAM   Baseline-Min: {}",
        base_lat,
        base.bram,
        match minp.latency {
            Some(l) => format!("{l} cycles / {} BRAM", minp.bram),
            None => "DEADLOCK".into(),
        }
    );
    for p in &front {
        println!(
            "    lat {:>10}  bram {:>5}  ({:.4}x, {:+.1}%)",
            p.latency.unwrap(),
            p.bram,
            p.latency.unwrap() as f64 / base_lat as f64,
            (p.bram as f64 - base.bram as f64) / base.bram.max(1) as f64 * 100.0
        );
    }
    let pts: Vec<(u64, u32)> = front.iter().map(|p| (p.latency.unwrap(), p.bram)).collect();
    if let Some(star) = select_highlight(&pts, alpha, base_lat, base.bram) {
        let s = &front[star];
        println!(
            "  ★ highlighted (α={alpha}): lat {} ({:.4}×), bram {} ({:.1}% of max)",
            s.latency.unwrap(),
            s.latency.unwrap() as f64 / base_lat as f64,
            s.bram,
            s.bram as f64 / base.bram.max(1) as f64 * 100.0
        );
    }

    // Per-scenario columns for workload runs: worst-case latency is the
    // objective above; this table shows where each frontier point's
    // latency actually lands per scenario. Each point is re-simulated
    // once; the same latencies feed the extra ASCII series below.
    let mut scenario_pts: Vec<Vec<(f64, f64)>> = Vec::new();
    if ev.num_scenarios() > 1 {
        scenario_pts = vec![Vec::new(); ev.num_scenarios()];
        let names = ev.scenario_names().to_vec();
        println!(
            "  per-scenario frontier latencies (objective = worst case):\n    {:>7}  {}",
            "bram",
            names
                .iter()
                .map(|n| format!("{n:>14}"))
                .collect::<Vec<_>>()
                .join(" ")
        );
        for p in &front {
            let lats = ev.per_scenario_latencies(&p.depths);
            for (i, (_, l)) in lats.iter().enumerate() {
                if let Some(l) = l {
                    scenario_pts[i].push((*l as f64, p.bram as f64));
                }
            }
            println!(
                "    {:>7}  {}",
                p.bram,
                lats.iter()
                    .map(|(_, l)| match l {
                        Some(v) => format!("{v:>14}"),
                        None => format!("{:>14}", "DEADLOCK"),
                    })
                    .collect::<Vec<_>>()
                    .join(" ")
            );
        }
    }

    // ASCII frontier plot — on workloads each scenario's per-point
    // latency becomes its own series ('0', '1', …) beside the worst-case
    // frontier ('o').
    let front_pts: Vec<(f64, f64)> = front
        .iter()
        .map(|p| (p.latency.unwrap() as f64, p.bram as f64))
        .collect();
    let base_pts = [(base_lat as f64, base.bram as f64)];
    let mut series = vec![
        ascii::Series {
            label: 'o',
            points: &front_pts,
        },
        ascii::Series {
            label: 'M',
            points: &base_pts,
        },
    ];
    for (i, pts) in scenario_pts.iter().enumerate() {
        series.push(ascii::Series {
            label: char::from_digit((i % 10) as u32, 10).unwrap(),
            points: pts,
        });
    }
    println!(
        "{}",
        ascii::scatter(&series, 64, 16, "latency (cycles)", "BRAM")
    );

    if let Some(out) = args.get("out") {
        let front_refs: Vec<&EvalPoint> = front.iter().collect();
        let j = report::run_to_json(
            &name,
            &opt_name,
            seed,
            budget,
            &ev.history,
            &front_refs,
            dt,
            Some(&ev),
        );
        report::write_file(out, &j.to_string_pretty())?;
        println!("  wrote {out}");
    }
    Ok(())
}

pub fn hunt(args: &Args) -> Result<()> {
    let (name, w) = load_workload(args)?;
    let space = Space::from_workload(&w);
    let mut ev = Evaluator::for_workload_with_sim(w.clone(), 1, parse_backend(args)?);
    if let Some(t) = args.get_positive_f64("timeout-secs")? {
        let limit = std::time::Duration::from_secs_f64(t);
        ev.set_cancel_token(CancelToken::with_timeout(limit));
    }
    let hunter = opt::vitis_hunter::VitisHunter::new();
    match hunter.hunt(&mut ev, &space, 1000) {
        Some(cfg) => {
            let (lat, bram) = ev.eval(&cfg);
            println!(
                "{name}: hunter found a feasible config after {} sims: latency {:?}, {} BRAM",
                ev.n_sim,
                lat.unwrap(),
                bram
            );
        }
        None if ev.truncated() => {
            println!("{name}: hunter hit --timeout-secs before finding a feasible config")
        }
        None => println!("{name}: hunter failed within budget"),
    }
    Ok(())
}
