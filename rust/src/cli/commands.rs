//! Subcommand implementations.

use super::Args;
use crate::bench_suite;
use crate::dse::{drive, Evaluator};
use crate::opt::objective::select_highlight;
use crate::opt::{self, Space};
use crate::report::{self, ascii};
use crate::trace::{collect_trace, Trace};
use crate::util::stats::fmt_duration;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

fn load_trace(args: &Args) -> Result<(String, Arc<Trace>)> {
    // Three sources, in precedence order: a cached trace JSON, a FADL
    // design file, or a built-in suite design.
    if let Some(path) = args.get("trace-file") {
        let t = crate::trace::serde::load(path)?;
        return Ok((t.design_name.clone(), Arc::new(t)));
    }
    let (name, design, default_args) = if let Some(path) = args.get("design-file") {
        let design = crate::ir::fadl::parse_file(path)?;
        (design.name.clone(), design, vec![0i64; 0])
    } else {
        let name = args.require("design")?.to_string();
        let bd = bench_suite::try_build(&name)
            .ok_or_else(|| anyhow!("unknown design '{name}' (see `fifoadvisor list`)"))?;
        (name, bd.design, bd.args)
    };
    let call_args = args.get_list("args")?.unwrap_or(default_args);
    let t = collect_trace(&design, &call_args)?;
    if let Some(out) = args.get("save-trace") {
        crate::trace::serde::save(&t, out)?;
        println!("saved trace to {out}");
    }
    Ok((name, Arc::new(t)))
}

/// Run a sweep configuration file (designs × optimizers × seeds).
pub fn sweep(args: &Args) -> Result<()> {
    let path = args.require("config")?;
    let cfg = crate::dse::sweep::SweepConfig::from_file(path)?;
    println!(
        "sweep: {} designs × {} optimizers × {} seeds, budget {}",
        cfg.designs.len(),
        cfg.optimizers.len(),
        cfg.seeds.len(),
        cfg.budget
    );
    let rows = crate::dse::sweep::run_sweep(&cfg)?;
    print!("{}", crate::dse::sweep::rows_to_markdown(&rows));
    if let Some(dir) = &cfg.out_dir {
        report::write_file(
            &format!("{dir}/summary.md"),
            &crate::dse::sweep::rows_to_markdown(&rows),
        )?;
        println!("per-run JSON + summary.md written to {dir}/");
    }
    Ok(())
}

pub fn list() -> Result<()> {
    println!("Stream-HLS suite:");
    for n in bench_suite::all_names() {
        let bd = bench_suite::build(n);
        println!("  {n:<28} {:>5} FIFOs", bd.design.num_fifos());
    }
    println!("specials:");
    for n in ["fig2", "flowgnn_pna"] {
        let bd = bench_suite::build(n);
        println!("  {n:<28} {:>5} FIFOs (data-dependent control flow)", bd.design.num_fifos());
    }
    Ok(())
}

pub fn info(args: &Args) -> Result<()> {
    let (name, t) = load_trace(args)?;
    let space = Space::from_trace(&t);
    println!("design       : {name}");
    println!("processes    : {}", t.process_names.len());
    println!("FIFOs        : {}", t.num_fifos());
    println!("groups       : {}", space.groups.len());
    println!("trace ops    : {}", t.total_ops());
    println!("pruned space : 10^{:.1} configurations", space.log10_size());
    let mut ev = Evaluator::new(t.clone());
    let (maxp, minp) = ev.eval_baselines();
    println!(
        "Baseline-Max : latency {} cycles, {} BRAM",
        maxp.latency.unwrap(),
        maxp.bram
    );
    match minp.latency {
        Some(l) => println!("Baseline-Min : latency {l} cycles, {} BRAM", minp.bram),
        None => println!("Baseline-Min : DEADLOCK"),
    }
    Ok(())
}

pub fn simulate(args: &Args) -> Result<()> {
    let (name, t) = load_trace(args)?;
    let depths: Vec<u32> = if let Some(d) = args.get_list("depths")? {
        if d.len() != t.num_fifos() {
            bail!(
                "--depths has {} entries, design '{name}' has {} FIFOs",
                d.len(),
                t.num_fifos()
            );
        }
        d.into_iter().map(|x| x.max(1) as u32).collect()
    } else {
        match args.get("baseline").unwrap_or("max") {
            "max" => t.baseline_max(),
            "min" => t.baseline_min(),
            other => bail!("--baseline must be max|min, got '{other}'"),
        }
    };
    let mut ev = Evaluator::new(t.clone());
    let t0 = std::time::Instant::now();
    let (lat, bram) = ev.eval(&depths);
    let dt = t0.elapsed().as_secs_f64();
    match lat {
        Some(l) => println!("{name}: latency {l} cycles, {bram} BRAM  (simulated in {})", fmt_duration(dt)),
        None => println!("{name}: DEADLOCK  ({bram} BRAM)  (simulated in {})", fmt_duration(dt)),
    }
    Ok(())
}

pub fn optimize(args: &Args) -> Result<()> {
    let (name, t) = load_trace(args)?;
    let opt_name = args.get("optimizer").unwrap_or("grouped_sa").to_string();
    let budget = args.get_u64("budget", 1000)? as usize;
    let seed = args.get_u64("seed", 1)?;
    // `--jobs` is the canonical worker-count flag; `--threads` stays as
    // a legacy alias.
    let jobs = match args.get("jobs") {
        Some(_) => args.get_u64("jobs", 4)?,
        None => args.get_u64("threads", 4)?,
    } as usize;
    let alpha = args.get_f64("alpha", 0.7)?;

    let mut ev = if args.has_flag("xla") {
        let analytics = crate::runtime::BatchAnalytics::load_default()?;
        println!("batched analytics: platform {}", analytics.platform());
        Evaluator::with_backend(t.clone(), Box::new(crate::runtime::XlaBram::new(analytics)), jobs)
    } else {
        Evaluator::parallel(t.clone(), jobs)
    };
    let space = Space::from_trace(&t);
    let (base, minp) = ev.eval_baselines();
    ev.reset_run(false);

    let mut optimizer = opt::by_name(&opt_name, seed)
        .ok_or_else(|| anyhow!("unknown optimizer '{opt_name}'"))?;
    let t0 = std::time::Instant::now();
    drive(&mut *optimizer, &mut ev, &space, budget);
    let dt = t0.elapsed().as_secs_f64();

    let front = ev.pareto();
    println!(
        "{name} × {opt_name}: {} evals ({} sims) in {} → {} Pareto points",
        ev.n_evals(),
        ev.n_sim,
        fmt_duration(dt),
        front.len()
    );
    println!("  engine: {}", report::engine_stats_line(&ev));
    let base_lat = base.latency.unwrap();
    println!(
        "  Baseline-Max: {} cycles / {} BRAM   Baseline-Min: {}",
        base_lat,
        base.bram,
        match minp.latency {
            Some(l) => format!("{l} cycles / {} BRAM", minp.bram),
            None => "DEADLOCK".into(),
        }
    );
    for p in &front {
        println!(
            "    lat {:>10}  bram {:>5}  ({:.4}x, {:+.1}%)",
            p.latency.unwrap(),
            p.bram,
            p.latency.unwrap() as f64 / base_lat as f64,
            (p.bram as f64 - base.bram as f64) / base.bram.max(1) as f64 * 100.0
        );
    }
    let pts: Vec<(u64, u32)> = front.iter().map(|p| (p.latency.unwrap(), p.bram)).collect();
    if let Some(star) = select_highlight(&pts, alpha, base_lat, base.bram) {
        let s = &front[star];
        println!(
            "  ★ highlighted (α={alpha}): lat {} ({:.4}×), bram {} ({:.1}% of max)",
            s.latency.unwrap(),
            s.latency.unwrap() as f64 / base_lat as f64,
            s.bram,
            s.bram as f64 / base.bram.max(1) as f64 * 100.0
        );
    }

    // ASCII frontier plot.
    let front_pts: Vec<(f64, f64)> = front
        .iter()
        .map(|p| (p.latency.unwrap() as f64, p.bram as f64))
        .collect();
    let base_pts = [(base_lat as f64, base.bram as f64)];
    println!(
        "{}",
        ascii::scatter(
            &[
                ascii::Series { label: 'o', points: &front_pts },
                ascii::Series { label: 'M', points: &base_pts },
            ],
            64,
            16,
            "latency (cycles)",
            "BRAM",
        )
    );

    if let Some(out) = args.get("out") {
        let j = report::run_to_json(
            &name,
            &opt_name,
            seed,
            budget,
            &ev.history,
            &front,
            dt,
            Some(&ev),
        );
        report::write_file(out, &j.to_string_pretty())?;
        println!("  wrote {out}");
    }
    Ok(())
}

pub fn hunt(args: &Args) -> Result<()> {
    let (name, t) = load_trace(args)?;
    let space = Space::from_trace(&t);
    let mut ev = Evaluator::new(t.clone());
    let hunter = opt::vitis_hunter::VitisHunter::new();
    match hunter.hunt(&mut ev, &space, 1000) {
        Some(cfg) => {
            let (lat, bram) = ev.eval(&cfg);
            println!(
                "{name}: hunter found a feasible config after {} sims: latency {:?}, {} BRAM",
                ev.n_sim,
                lat.unwrap(),
                bram
            );
        }
        None => println!("{name}: hunter failed within budget"),
    }
    Ok(())
}
