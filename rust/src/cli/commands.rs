//! Subcommand implementations.

use super::Args;
use crate::bench_suite;
use crate::dse::advhunt::{self, Certificate, DistillConfig, HuntConfig};
use crate::dse::{drive, CancelToken, EvalPoint, Evaluator};
use crate::opt::objective::select_highlight;
use crate::opt::{self, Space};
use crate::report::{self, ascii};
use crate::sim::BackendKind;
use crate::trace::workload::Workload;
use crate::util::stats::fmt_duration;
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

/// Parse `--backend {fast,compiled,batched}` (defaults to the
/// event-driven fast simulator). Backend selection never changes
/// results — only the throughput profile.
fn parse_backend(args: &Args) -> Result<BackendKind> {
    match args.get("backend") {
        None => Ok(BackendKind::Fast),
        Some(s) => BackendKind::parse(s).map_err(|e| anyhow!("--backend: {e}")),
    }
}

fn load_workload(args: &Args) -> Result<(String, Arc<Workload>)> {
    // Four sources, in precedence order: a saved workload JSON, a cached
    // trace JSON, a FADL design file, or a built-in suite design. The
    // design paths accept a repeatable `--args A,B,..` — each occurrence
    // becomes one scenario of the workload.
    if let Some(path) = args.get("scenario-file") {
        let w = Workload::load(path)?;
        return Ok((w.design_name().to_string(), Arc::new(w)));
    }
    if let Some(path) = args.get("trace-file") {
        let t = crate::trace::serde::load(path)?;
        let name = t.design_name.clone();
        return Ok((name, Arc::new(Workload::single(Arc::new(t)))));
    }
    let (name, design, default_args) = if let Some(path) = args.get("design-file") {
        let design = crate::ir::fadl::parse_file(path)?;
        // FADL designs default to all-zero args of the right arity (a
        // zero-length vector would trip the arg-count check whenever
        // num_args > 0).
        let defaults = vec![0i64; design.num_args];
        if design.num_args > 0 && args.get_all("args").is_empty() {
            println!(
                "note: design '{}' takes {} runtime arg(s); tracing with all-zero defaults \
                 (pass --args A,B,.. to override)",
                design.name, design.num_args
            );
        }
        (design.name.clone(), design, defaults)
    } else {
        let name = args.require("design")?.to_string();
        let bd = bench_suite::try_build(&name)
            .ok_or_else(|| anyhow!("unknown design '{name}' (see `fifoadvisor list`)"))?;
        (name, bd.design, bd.args)
    };
    let arg_sets = args.get_lists("args")?;
    let sets: Vec<Vec<i64>> = if arg_sets.is_empty() {
        vec![default_args]
    } else {
        arg_sets
    };
    let w = Workload::from_design_args(&design, &sets)?;
    // e.g. duplicate --args occurrences folded into one weighted scenario.
    for note in w.notes() {
        println!("note: {note}");
    }
    if let Some(out) = args.get("save-trace") {
        crate::trace::serde::save(w.primary(), out)?;
        println!("saved trace to {out}");
    }
    if let Some(out) = args.get("save-workload") {
        w.save(out)?;
        println!("saved {}-scenario workload to {out}", w.num_scenarios());
    }
    Ok((name, Arc::new(w)))
}

/// Open the cross-run snapshot store when `--cache-dir` is given (and
/// `--no-store` is not), returning it with this run's cache key. The
/// key covers the design, the full workload content, the simulation
/// backend and the pruning regime — see [`crate::store::Store::key`].
fn open_store(
    args: &Args,
    name: &str,
    w: &Workload,
    backend: BackendKind,
    prune: bool,
    bounds: bool,
) -> Result<Option<(crate::store::Store, String)>> {
    if args.has_flag("no-store") {
        return Ok(None);
    }
    let Some(dir) = args.get("cache-dir") else {
        return Ok(None);
    };
    let max_mb = args.get_u64("cache-max-mb", 512)?;
    let store = crate::store::Store::new(dir, max_mb);
    let key = crate::store::Store::key(name, w, backend.name(), prune, bounds);
    Ok(Some((store, key)))
}

/// Warm-start the engine from the store snapshot under this run's key.
/// A rejected or corrupt snapshot degrades to a cold start — warm runs
/// stay bit-identical to cold ones either way.
fn warm_start(store: &Option<(crate::store::Store, String)>, ev: &mut Evaluator) {
    let Some((st, key)) = store else { return };
    let Some(snap) = st.load(key) else { return };
    match snap.apply(ev) {
        Ok(n) => println!("  store: warm-started {n} memo entries (key {key})"),
        Err(e) => println!("  store: snapshot {key} rejected ({e}); cold start"),
    }
}

/// Persist the engine's memo/oracle back to the store after a run.
fn save_snapshot(store: &Option<(crate::store::Store, String)>, name: &str, ev: &Evaluator) {
    let Some((st, key)) = store else { return };
    let snap = crate::store::Snapshot::capture(name, ev);
    match st.save(key, &snap) {
        Ok(()) => println!(
            "  store: saved {} memo + {} oracle entries (key {key})",
            snap.memo.len(),
            snap.oracle.len()
        ),
        Err(e) => println!("  store: save failed: {e}"),
    }
}

/// Run a sweep configuration file (designs × optimizers × seeds)
/// through the fault-tolerant orchestrator. `--resume`, `--shard i/n`,
/// and `--out-dir DIR` override the matching config keys, so one config
/// file serves every shard of a CI matrix and the final merge pass.
pub fn sweep(args: &Args) -> Result<()> {
    let path = args.require("config")?;
    let mut cfg = crate::dse::sweep::SweepConfig::from_file(path)?;
    if args.has_flag("resume") {
        cfg.resume = true;
    }
    if let Some(dir) = args.get("out-dir") {
        cfg.out_dir = Some(dir.to_string());
    }
    if let Some(dir) = args.get("cache-dir") {
        cfg.cache_dir = Some(dir.to_string());
    }
    if let Some(s) = args.get("shard") {
        cfg.shard = Some(crate::dse::sweep::parse_shard(s)?);
    }
    println!(
        "sweep: {} designs × {} optimizers × {} seeds, budget {}{}{}",
        cfg.designs.len(),
        cfg.optimizers.len(),
        cfg.seeds.len(),
        cfg.budget,
        match cfg.shard {
            Some((i, n)) => format!(", shard {i}/{n}"),
            None => String::new(),
        },
        if cfg.resume { ", resuming" } else { "" }
    );
    let out = crate::dse::sweep::run_sweep_with(&cfg, &Default::default())?;
    print!("{}", crate::dse::sweep::rows_to_markdown(&out.rows));
    if out.resumed > 0 {
        println!("resumed {} done cell(s) from the manifest", out.resumed);
    }
    if out.truncated > 0 {
        println!(
            "{} cell(s) hit a per-cell budget and kept best-so-far fronts (✂)",
            out.truncated
        );
    }
    if let Some(dir) = &cfg.out_dir {
        if cfg.shard.is_none() {
            report::write_file(
                &format!("{dir}/summary.md"),
                &crate::dse::sweep::rows_to_markdown(&out.rows),
            )?;
        }
        println!("per-run JSON + manifest written to {dir}/");
    }
    if !out.failed.is_empty() {
        for f in &out.failed {
            println!(
                "FAILED {}/{}/s{} after {} attempt(s): {}",
                f.design, f.optimizer, f.seed, f.attempts, f.reason
            );
        }
        bail!(
            "sweep: {} cell(s) failed (recorded in the manifest; rerun with --resume to retry)",
            out.failed.len()
        );
    }
    Ok(())
}

pub fn list() -> Result<()> {
    println!("Stream-HLS suite:");
    for n in bench_suite::all_names() {
        let bd = bench_suite::build(n);
        println!(
            "  {n:<28} {:>5} FIFOs  {:>2} args",
            bd.design.num_fifos(),
            bd.design.num_args
        );
    }
    println!("specials (data-dependent control flow; traces are argument-specific):");
    for n in ["fig2", "flowgnn_pna", "mini_dnn"] {
        let bd = bench_suite::build(n);
        // [arg-space]: the design exposes a finite kernel-argument space,
        // so `certify` / `hunt-scenarios` can hunt it adversarially.
        println!(
            "  {n:<28} {:>5} FIFOs  {:>2} args{}",
            bd.design.num_fifos(),
            bd.design.num_args,
            if bench_suite::arg_space(n).is_some() {
                "  [arg-space]"
            } else {
                ""
            }
        );
    }
    Ok(())
}

pub fn info(args: &Args) -> Result<()> {
    let (name, w) = load_workload(args)?;
    let space = Space::from_workload(&w);
    println!("design       : {name}");
    println!("processes    : {}", w.primary().process_names.len());
    println!("FIFOs        : {}", w.num_fifos());
    println!("scenarios    : {}", w.num_scenarios());
    if w.num_scenarios() > 1 {
        print_scenario_table(&w);
    }
    println!("groups       : {}", space.groups.len());
    println!("trace ops    : {}", w.total_ops());
    println!("pruned space : 10^{:.1} configurations", space.log10_size());
    print_depth_bounds(&w, &space);
    let mut ev = Evaluator::for_workload(w.clone(), 1);
    let (maxp, minp) = ev.eval_baselines();
    println!(
        "Baseline-Max : latency {} cycles, {} BRAM",
        maxp.latency.unwrap(),
        maxp.bram
    );
    match minp.latency {
        Some(l) => println!("Baseline-Min : latency {l} cycles, {} BRAM", minp.bram),
        None => println!("Baseline-Min : DEADLOCK"),
    }
    Ok(())
}

/// Per-scenario pressure table: where each scenario's occupancy peaks
/// and deadlock floors land, and whether the scenario-bank distillation
/// would keep it or fold it into a dominating sibling. Explains the
/// `--distill` partition before an optimize run commits to it.
fn print_scenario_table(w: &Workload) {
    use crate::sim::scenario::{distill_partition, scenario_profiles};
    let profiles = scenario_profiles(w);
    let (kept, dominators) = distill_partition(&profiles);
    println!(
        "    {:<20} {:<16} {:>8} {:>9} {:>9} {:>10}  distill",
        "scenario", "args", "ops", "Σpeak", "Σfloor", "base lat"
    );
    for (i, (s, p)) in w.scenarios().iter().zip(&profiles).enumerate() {
        let verdict = if kept.contains(&i) {
            "keep".to_string()
        } else {
            let dom = dominators
                .iter()
                .find(|&&(d, _)| d == i)
                .map(|&(_, j)| profiles[j].name.clone())
                .unwrap_or_default();
            format!("drop (≼ {dom})")
        };
        println!(
            "    {:<20} {:<16} {:>8} {:>9} {:>9} {:>10}  {}",
            s.name,
            format!("{:?}", s.trace.args),
            s.trace.total_ops(),
            p.peak_occ.iter().map(|&o| o as u64).sum::<u64>(),
            p.floors.iter().map(|&f| f as u64).sum::<u64>(),
            p.base_latency,
            verdict
        );
    }
    println!(
        "    (Σpeak / Σfloor / blocked-set dominance decides drop; dropped \
         scenarios are re-verified against every frontier point)"
    );
}

/// The per-channel `[lower, cap]` ranges the optimizers actually search,
/// with each bound's provenance. Small designs get the full table;
/// larger ones list only the channels where the analytic pass improved
/// on the trivial `[2, write-count]` range.
fn print_depth_bounds(w: &Workload, space: &Space) {
    use crate::opt::bounds::{BoundSource, DepthBounds};
    let b = DepthBounds::for_workload(w);
    let n = b.num_fifos();
    println!(
        "depth bounds : {} analytic floor(s), {} tightened cap(s)",
        b.num_floored(),
        b.num_cap_tightenings()
    );
    let src = |s: BoundSource| match s {
        BoundSource::Analytic => "analytic",
        BoundSource::WriteCount => "write-count",
    };
    let rows: Vec<usize> = if n <= 16 {
        (0..n).collect()
    } else {
        (0..n)
            .filter(|&ch| {
                b.floor_source(ch) == BoundSource::Analytic
                    || b.cap_source(ch) == BoundSource::Analytic
            })
            .collect()
    };
    if n > 16 && !rows.is_empty() {
        println!("    ({} of {n} channels have a non-trivial bound)", rows.len());
    }
    const MAX_ROWS: usize = 32;
    let names = &w.primary().channels;
    for &ch in rows.iter().take(MAX_ROWS) {
        println!(
            "    {:<24} [{:>5}, {:>6}]  floor: {}, cap: {}",
            names[ch].name,
            space.min_depth(ch).min(space.bounds[ch].max(2)),
            space.bounds[ch].max(2),
            src(b.floor_source(ch)),
            src(b.cap_source(ch)),
        );
    }
    if rows.len() > MAX_ROWS {
        println!("    ... {} more", rows.len() - MAX_ROWS);
    }
}

pub fn simulate(args: &Args) -> Result<()> {
    let (name, w) = load_workload(args)?;
    let depths: Vec<u32> = if let Some(d) = args.get_list("depths")? {
        if d.len() != w.num_fifos() {
            bail!(
                "--depths has {} entries, design '{name}' has {} FIFOs",
                d.len(),
                w.num_fifos()
            );
        }
        d.into_iter().map(|x| x.max(1) as u32).collect()
    } else {
        match args.get("baseline").unwrap_or("max") {
            "max" => w.baseline_max(),
            "min" => w.baseline_min(),
            other => bail!("--baseline must be max|min, got '{other}'"),
        }
    };
    let backend = parse_backend(args)?;
    let mut ev = Evaluator::for_workload_with_sim(w.clone(), 1, backend);
    let store = open_store(args, &name, &w, backend, ev.prune(), ev.bounds())?;
    warm_start(&store, &mut ev);
    let t0 = std::time::Instant::now();
    let (lat, bram) = ev.eval(&depths);
    let dt = t0.elapsed().as_secs_f64();
    match lat {
        Some(l) => println!(
            "{name}: latency {l} cycles, {bram} BRAM  (simulated in {})",
            fmt_duration(dt)
        ),
        None => println!(
            "{name}: DEADLOCK  ({bram} BRAM)  (simulated in {})",
            fmt_duration(dt)
        ),
    }
    if w.num_scenarios() > 1 {
        for (sname, l) in ev.per_scenario_latencies(&depths) {
            match l {
                Some(l) => println!("    {sname:<20} {l} cycles"),
                None => println!("    {sname:<20} DEADLOCK"),
            }
        }
    }
    save_snapshot(&store, &name, &ev);
    Ok(())
}

pub fn optimize(args: &Args) -> Result<()> {
    let (name, w) = load_workload(args)?;
    let opt_name = args.get("optimizer").unwrap_or("grouped_sa").to_string();
    let budget = args.get_u64("budget", 1000)? as usize;
    let seed = args.get_u64("seed", 1)?;
    // `--jobs` is the canonical worker-count flag; `--threads` stays as
    // a legacy alias.
    let jobs = match args.get("jobs") {
        Some(_) => args.get_u64("jobs", 4)?,
        None => args.get_u64("threads", 4)?,
    } as usize;
    let alpha = args.get_f64("alpha", 0.7)?;
    let backend = parse_backend(args)?;
    let timeout_secs = args.get_positive_f64("timeout-secs")?;

    if args.has_flag("distill") {
        if args.has_flag("xla") {
            bail!("--distill uses the native BRAM backend (drop --xla)");
        }
        return optimize_distilled_cmd(args, &name, &w);
    }

    let mut ev = if args.has_flag("xla") {
        let analytics = crate::runtime::BatchAnalytics::load_default()?;
        println!("batched analytics: platform {}", analytics.platform());
        Evaluator::for_workload_full(
            w.clone(),
            Box::new(crate::runtime::XlaBram::new(analytics)),
            jobs,
            backend,
        )
    } else {
        Evaluator::for_workload_with_sim(w.clone(), jobs, backend)
    };
    // A/B escape hatch: disable the simulation-free pruning layer
    // (dominance oracle, occupancy clamp, scenario early exit). Results
    // are identical either way; only the sims/sec differ.
    if args.has_flag("no-prune") {
        ev.set_prune(false);
    }
    // Same for the analytic depth-bounds layer (floor short-circuit,
    // oracle seeding, tightened clamp caps). The search space keeps its
    // analytic collapse either way — the flag only toggles the engine
    // side, so histories stay bit-identical for the A/B comparison.
    if args.has_flag("no-bounds") {
        ev.set_bounds(false);
    }
    let b = ev.depth_bounds();
    if b.num_floored() > 0 || b.num_cap_tightenings() > 0 {
        println!(
            "  bounds: {} analytic floor(s), {} tightened cap(s){}",
            b.num_floored(),
            b.num_cap_tightenings(),
            if ev.bounds() { "" } else { " (engine layer OFF)" }
        );
    }
    let space = Space::from_workload(&w);
    // Warm-start from the cross-run store before the baselines, so a
    // replay run answers even those from the memo. XLA runs keep the
    // store off: snapshot validation recomputes BRAM with the native
    // backend, and mixing artifacts would defeat the exactness check.
    let store = if args.has_flag("xla") {
        None
    } else {
        open_store(args, &name, &w, backend, ev.prune(), ev.bounds())?
    };
    warm_start(&store, &mut ev);
    let (base, minp) = ev.eval_baselines();
    ev.reset_run(false);
    // Wall-clock budget: drive stops at the next ask/tell round once the
    // deadline passes, keeping the best-so-far front (flagged truncated).
    if let Some(t) = timeout_secs {
        let limit = std::time::Duration::from_secs_f64(t);
        ev.set_cancel_token(CancelToken::with_timeout(limit));
    }

    let mut optimizer = opt::by_name(&opt_name, seed)
        .ok_or_else(|| anyhow!("unknown optimizer '{opt_name}'"))?;
    let t0 = std::time::Instant::now();
    drive(&mut *optimizer, &mut ev, &space, budget);
    let dt = t0.elapsed().as_secs_f64();

    let front: Vec<EvalPoint> = ev.pareto().into_iter().cloned().collect();
    println!(
        "{name} × {opt_name}: {} evals ({} sims) in {} → {} Pareto points",
        ev.n_evals(),
        ev.n_sim,
        fmt_duration(dt),
        front.len()
    );
    println!("  engine: {}", report::engine_stats_line(&ev));
    if ev.truncated() {
        println!(
            "  NOTE: hit --timeout-secs {} — best-so-far front below; the run JSON is \
             flagged \"truncated\"",
            timeout_secs.unwrap_or(0.0)
        );
    }
    let base_lat = base.latency.unwrap();
    println!(
        "  Baseline-Max: {} cycles / {} BRAM   Baseline-Min: {}",
        base_lat,
        base.bram,
        match minp.latency {
            Some(l) => format!("{l} cycles / {} BRAM", minp.bram),
            None => "DEADLOCK".into(),
        }
    );
    for p in &front {
        println!(
            "    lat {:>10}  bram {:>5}  ({:.4}x, {:+.1}%)",
            p.latency.unwrap(),
            p.bram,
            p.latency.unwrap() as f64 / base_lat as f64,
            (p.bram as f64 - base.bram as f64) / base.bram.max(1) as f64 * 100.0
        );
    }
    let pts: Vec<(u64, u32)> = front.iter().map(|p| (p.latency.unwrap(), p.bram)).collect();
    let star = select_highlight(&pts, alpha, base_lat, base.bram);
    if let Some(star) = star {
        let s = &front[star];
        println!(
            "  ★ highlighted (α={alpha}): lat {} ({:.4}×), bram {} ({:.1}% of max)",
            s.latency.unwrap(),
            s.latency.unwrap() as f64 / base_lat as f64,
            s.bram,
            s.bram as f64 / base.bram.max(1) as f64 * 100.0
        );
    }

    // Per-scenario columns for workload runs: worst-case latency is the
    // objective above; this table shows where each frontier point's
    // latency actually lands per scenario. Each point is re-simulated
    // once; the same latencies feed the extra ASCII series below.
    let mut scenario_pts: Vec<Vec<(f64, f64)>> = Vec::new();
    if ev.num_scenarios() > 1 {
        scenario_pts = vec![Vec::new(); ev.num_scenarios()];
        let names = ev.scenario_names().to_vec();
        println!(
            "  per-scenario frontier latencies (objective = worst case):\n    {:>7}  {}",
            "bram",
            names
                .iter()
                .map(|n| format!("{n:>14}"))
                .collect::<Vec<_>>()
                .join(" ")
        );
        for p in &front {
            let lats = ev.per_scenario_latencies(&p.depths);
            for (i, (_, l)) in lats.iter().enumerate() {
                if let Some(l) = l {
                    scenario_pts[i].push((*l as f64, p.bram as f64));
                }
            }
            println!(
                "    {:>7}  {}",
                p.bram,
                lats.iter()
                    .map(|(_, l)| match l {
                        Some(v) => format!("{v:>14}"),
                        None => format!("{:>14}", "DEADLOCK"),
                    })
                    .collect::<Vec<_>>()
                    .join(" ")
            );
        }
    }

    // ASCII frontier plot — on workloads each scenario's per-point
    // latency becomes its own series ('0', '1', …) beside the worst-case
    // frontier ('o').
    let front_pts: Vec<(f64, f64)> = front
        .iter()
        .map(|p| (p.latency.unwrap() as f64, p.bram as f64))
        .collect();
    let base_pts = [(base_lat as f64, base.bram as f64)];
    let mut series = vec![
        ascii::Series {
            label: 'o',
            points: &front_pts,
        },
        ascii::Series {
            label: 'M',
            points: &base_pts,
        },
    ];
    for (i, pts) in scenario_pts.iter().enumerate() {
        series.push(ascii::Series {
            label: char::from_digit((i % 10) as u32, 10).unwrap(),
            points: pts,
        });
    }
    println!(
        "{}",
        ascii::scatter(&series, 64, 16, "latency (cycles)", "BRAM")
    );

    // --certify: adversarially hunt the design's kernel-argument space
    // for a scenario that deadlocks the config we are about to ship (the
    // ★ highlight, falling back to the first frontier point).
    let cert = if args.has_flag("certify") {
        let target = star.map(|i| &front[i]).or_else(|| front.first());
        certify_front_point(args, &name, target)?
    } else {
        None
    };

    if let Some(out) = args.get("out") {
        let front_refs: Vec<&EvalPoint> = front.iter().collect();
        let mut j = report::run_to_json(
            &name,
            &opt_name,
            seed,
            budget,
            &ev.history,
            &front_refs,
            dt,
            Some(&ev),
        );
        if let (Some(c), crate::util::json::Json::Obj(map)) = (&cert, &mut j) {
            map.insert("certificate".to_string(), c.to_json());
        }
        report::write_file(out, &j.to_string_pretty())?;
        println!("  wrote {out}");
    }
    save_snapshot(&store, &name, &ev);
    Ok(())
}

/// Shared `--certify` tail for optimize runs (plain and distilled).
fn certify_front_point(
    args: &Args,
    name: &str,
    target: Option<&EvalPoint>,
) -> Result<Option<Certificate>> {
    let Some(p) = target else {
        println!("  certify: no feasible frontier point to certify");
        return Ok(None);
    };
    // `--optimizer`/`--budget` belong to the DSE run here, so the hunt
    // reads `--hunt-optimizer`/`--certify-budget` instead.
    let cfg = hunt_config_from(args, "hunt-optimizer", "certify-budget")?;
    match advhunt::certify_design(name, &p.depths, &cfg) {
        Some(c) => {
            println!(
                "  certificate: {}  ({} scenario(s) tested, {} sims, {})",
                c.verdict(),
                c.scenarios_tested,
                c.sims,
                fmt_duration(c.elapsed_secs)
            );
            Ok(Some(c))
        }
        None => {
            println!(
                "  certify: design '{name}' exposes no kernel-argument space \
                 (static trace — nothing to hunt)"
            );
            Ok(None)
        }
    }
}

/// Build a [`HuntConfig`] from the shared hunt flags. The optimizer and
/// budget key names are passed in because `optimize --certify` reserves
/// `--optimizer`/`--budget` for the DSE run itself.
fn hunt_config_from(args: &Args, opt_key: &str, budget_key: &str) -> Result<HuntConfig> {
    let mut cfg = HuntConfig {
        optimizer: args.get(opt_key).unwrap_or("auto").to_string(),
        seed: args.get_u64("seed", 1)?,
        budget: args.get_u64(budget_key, 64)? as usize,
        jobs: args.get_u64("jobs", 1)? as usize,
        cancel: CancelToken::new(),
    };
    if !advhunt::HUNT_OPTIMIZERS.contains(&cfg.optimizer.as_str()) {
        bail!(
            "hunt optimizer '{}' not in {:?}",
            cfg.optimizer,
            advhunt::HUNT_OPTIMIZERS
        );
    }
    if let Some(t) = args.get_positive_f64("timeout-secs")? {
        cfg.cancel = CancelToken::with_timeout(std::time::Duration::from_secs_f64(t));
    }
    Ok(cfg)
}

pub fn hunt(args: &Args) -> Result<()> {
    let (name, w) = load_workload(args)?;
    let space = Space::from_workload(&w);
    let mut ev = Evaluator::for_workload_with_sim(w.clone(), 1, parse_backend(args)?);
    if let Some(t) = args.get_positive_f64("timeout-secs")? {
        let limit = std::time::Duration::from_secs_f64(t);
        ev.set_cancel_token(CancelToken::with_timeout(limit));
    }
    let hunter = opt::vitis_hunter::VitisHunter::new();
    match hunter.hunt(&mut ev, &space, 1000) {
        Some(cfg) => {
            let (lat, bram) = ev.eval(&cfg);
            println!(
                "{name}: hunter found a feasible config after {} sims: latency {:?}, {} BRAM",
                ev.n_sim,
                lat.unwrap(),
                bram
            );
        }
        None if ev.truncated() => {
            println!("{name}: hunter hit --timeout-secs before finding a feasible config")
        }
        None => println!("{name}: hunter failed within budget"),
    }
    Ok(())
}

/// `optimize --distill`: run the inner DSE loop on the dominance-
/// distilled scenario bank with the full-bank re-verify fixpoint.
/// History, front, and highlight are bit-identical to the plain path —
/// only the scenario-simulation count changes.
fn optimize_distilled_cmd(args: &Args, name: &str, w: &Arc<Workload>) -> Result<()> {
    let opt_name = args.get("optimizer").unwrap_or("grouped_sa").to_string();
    let budget = args.get_u64("budget", 1000)? as usize;
    let seed = args.get_u64("seed", 1)?;
    let jobs = match args.get("jobs") {
        Some(_) => args.get_u64("jobs", 4)?,
        None => args.get_u64("threads", 4)?,
    } as usize;
    let alpha = args.get_f64("alpha", 0.7)?;
    let mut cfg = DistillConfig {
        optimizer: opt_name.clone(),
        seed,
        budget,
        jobs,
        prune: !args.has_flag("no-prune"),
        bounds: !args.has_flag("no-bounds"),
        backend: parse_backend(args)?,
        cancel: CancelToken::new(),
    };
    if let Some(t) = args.get_positive_f64("timeout-secs")? {
        cfg.cancel = CancelToken::with_timeout(std::time::Duration::from_secs_f64(t));
    }
    let space = Space::from_workload(w);
    let t0 = std::time::Instant::now();
    let out = advhunt::optimize_distilled(w, &space, &cfg);
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{name} × {opt_name} (distilled): kept {}/{} scenario(s){}, {} fixpoint iteration(s)",
        out.kept_final.len(),
        w.num_scenarios(),
        if out.promotions.is_empty() {
            String::new()
        } else {
            format!(" after promoting {:?}", out.promotions)
        },
        out.iterations
    );
    println!(
        "  scenario sims: {} inner + {} verify, {} evals in {} → {} Pareto points",
        out.inner_scenario_sims,
        out.verify_scenario_sims,
        out.history.len(),
        fmt_duration(dt),
        out.front.len()
    );
    if out.truncated {
        println!(
            "  NOTE: hit --timeout-secs {} — best-so-far front; the full-bank fixpoint \
             is NOT verified",
            args.get_positive_f64("timeout-secs")?.unwrap_or(0.0)
        );
    }
    let base_lat = out.baseline_max.latency.unwrap();
    println!(
        "  Baseline-Max: {} cycles / {} BRAM   Baseline-Min: {}",
        base_lat,
        out.baseline_max.bram,
        match out.baseline_min.latency {
            Some(l) => format!("{l} cycles / {} BRAM", out.baseline_min.bram),
            None => "DEADLOCK".into(),
        }
    );
    for p in &out.front {
        println!(
            "    lat {:>10}  bram {:>5}  ({:.4}x)",
            p.latency.unwrap(),
            p.bram,
            p.latency.unwrap() as f64 / base_lat as f64
        );
    }
    let pts: Vec<(u64, u32)> = out
        .front
        .iter()
        .map(|p| (p.latency.unwrap(), p.bram))
        .collect();
    let star = select_highlight(&pts, alpha, base_lat, out.baseline_max.bram);
    if let Some(si) = star {
        let s = &out.front[si];
        println!(
            "  ★ highlighted (α={alpha}): lat {} ({:.4}×), bram {}",
            s.latency.unwrap(),
            s.latency.unwrap() as f64 / base_lat as f64,
            s.bram
        );
    }
    let cert = if args.has_flag("certify") {
        let target = star.map(|i| &out.front[i]).or_else(|| out.front.first());
        certify_front_point(args, name, target)?
    } else {
        None
    };
    if let Some(path) = args.get("out") {
        use crate::util::json::Json;
        let front_refs: Vec<&EvalPoint> = out.front.iter().collect();
        let mut j = report::run_to_json(
            name, &opt_name, seed, budget, &out.history, &front_refs, dt, None,
        );
        if let Json::Obj(map) = &mut j {
            map.insert(
                "distill".to_string(),
                Json::obj(vec![
                    (
                        "kept_initial",
                        Json::nums(&out.kept_initial.iter().map(|&i| i as f64).collect::<Vec<_>>()),
                    ),
                    (
                        "kept_final",
                        Json::nums(&out.kept_final.iter().map(|&i| i as f64).collect::<Vec<_>>()),
                    ),
                    (
                        "promotions",
                        Json::nums(&out.promotions.iter().map(|&i| i as f64).collect::<Vec<_>>()),
                    ),
                    ("iterations", Json::Num(out.iterations as f64)),
                    ("inner_scenario_sims", Json::Num(out.inner_scenario_sims as f64)),
                    ("verify_scenario_sims", Json::Num(out.verify_scenario_sims as f64)),
                    ("truncated", Json::Bool(out.truncated)),
                ]),
            );
            if let Some(c) = &cert {
                map.insert("certificate".to_string(), c.to_json());
            }
        }
        report::write_file(path, &j.to_string_pretty())?;
        println!("  wrote {path}");
    }
    Ok(())
}

/// `fifoadvisor certify`: robustness certificate for a concrete config —
/// hunt the design's kernel-argument space for a scenario that deadlocks
/// it, or report "no counterexample in N scenarios / T seconds".
pub fn certify(args: &Args) -> Result<()> {
    let name = args.require("design")?.to_string();
    let Some(space) = bench_suite::arg_space(&name) else {
        bail!(
            "design '{name}' exposes no kernel-argument space — nothing to hunt \
             (see the [arg-space] markers in `fifoadvisor list`)"
        );
    };
    let bd = bench_suite::try_build(&name)
        .ok_or_else(|| anyhow!("unknown design '{name}' (see `fifoadvisor list`)"))?;
    let w = bench_suite::build_workload(&name).expect("arg-space designs build workloads");
    let depths: Vec<u32> = match args.get_list("depths")? {
        Some(d) => {
            if d.len() != w.num_fifos() {
                bail!(
                    "--depths has {} entries, design '{name}' has {} FIFOs",
                    d.len(),
                    w.num_fifos()
                );
            }
            d.into_iter().map(|x| x.max(1) as u32).collect()
        }
        None => match args.get("baseline").unwrap_or("max") {
            "max" => w.baseline_max(),
            "min" => w.baseline_min(),
            other => bail!("--baseline must be max|min, got '{other}'"),
        },
    };
    let cfg = hunt_config_from(args, "optimizer", "budget")?;
    let cert = advhunt::certify(&bd.design, &name, &space, &depths, &cfg);
    println!("{name} @ {depths:?}");
    println!("  verdict : {}", cert.verdict());
    match &cert.counterexample {
        Some(ce) => println!(
            "  breaking args {:?} deadlock the config (blocked channels {:?}{})",
            ce.args,
            ce.blocked,
            if ce.analytic { ", proven analytically" } else { "" }
        ),
        None => println!(
            "  no counterexample in {} scenario(s) / {}{}",
            cert.scenarios_tested,
            fmt_duration(cert.elapsed_secs),
            if cert.is_exhaustive() {
                " — the entire argument space"
            } else {
                ""
            }
        ),
    }
    println!(
        "  {} sims over a {}-point space{}",
        cert.sims,
        match cert.space_points {
            Some(n) => n.to_string(),
            None => "?".into(),
        },
        if cert.truncated {
            " (truncated by budget/timeout)"
        } else {
            ""
        }
    );
    if let Some(out) = args.get("out") {
        report::write_file(out, &cert.to_json().to_string_pretty())?;
        println!("  wrote {out}");
    }
    Ok(())
}

/// `fifoadvisor hunt-scenarios`: adversarial scenario mining over a
/// design's kernel-argument space — break a given config (`--depths`) or
/// find the maximum-pressure scenario; then show the dominance partition
/// distillation would apply to the design's default scenario bank.
pub fn hunt_scenarios(args: &Args) -> Result<()> {
    let name = args.require("design")?.to_string();
    let Some(space) = bench_suite::arg_space(&name) else {
        bail!(
            "design '{name}' exposes no kernel-argument space — nothing to hunt \
             (see the [arg-space] markers in `fifoadvisor list`)"
        );
    };
    let bd = bench_suite::try_build(&name)
        .ok_or_else(|| anyhow!("unknown design '{name}' (see `fifoadvisor list`)"))?;
    let depths: Option<Vec<u32>> = args
        .get_list("depths")?
        .map(|d| d.into_iter().map(|x| x.max(1) as u32).collect());
    if let Some(d) = &depths {
        if d.len() != bd.design.num_fifos() {
            bail!(
                "--depths has {} entries, design '{name}' has {} FIFOs",
                d.len(),
                bd.design.num_fifos()
            );
        }
    }
    let cfg = hunt_config_from(args, "optimizer", "budget")?;
    let r = advhunt::hunt(&bd.design, &space, depths.as_deref(), &cfg);
    match (&depths, &r.counterexample) {
        (Some(_), Some(ce)) => println!(
            "{name}: BROKEN — args {:?} deadlock the config (blocked channels {:?}{})",
            ce.args,
            ce.blocked,
            if ce.analytic { ", proven analytically" } else { "" }
        ),
        (Some(_), None) => println!(
            "{name}: no breaking scenario among {} tested",
            r.scenarios_tested
        ),
        (None, _) => match &r.best {
            Some((a, p)) => println!(
                "{name}: max-pressure scenario args {a:?} (pressure {p}, {} tested)",
                r.scenarios_tested
            ),
            None => println!("{name}: no scenario evaluated"),
        },
    }
    println!(
        "  {} sims, {} analytic floor hit(s), {}{}",
        r.sims,
        r.floor_hits,
        fmt_duration(r.elapsed_secs),
        if r.truncated {
            " (truncated by budget/timeout)"
        } else {
            ""
        }
    );
    let w = bench_suite::build_workload(&name).expect("arg-space designs build workloads");
    if w.num_scenarios() > 1 {
        println!("default-bank distillation partition:");
        print_scenario_table(&w);
    }
    Ok(())
}

/// `fifoadvisor serve`: the persistent sizing service. Blocks until a
/// `shutdown` request arrives.
pub fn serve(args: &Args) -> Result<()> {
    let cfg = crate::serve::ServeConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:7733").to_string(),
        unix_socket: args.get("unix-socket").map(str::to_string),
        cache_dir: if args.has_flag("no-store") {
            None
        } else {
            args.get("cache-dir").map(str::to_string)
        },
        cache_max_mb: args.get_u64("cache-max-mb", 512)?,
        jobs: args.get_u64("jobs", 1)?.max(1) as usize,
    };
    crate::serve::run(cfg)?;
    Ok(())
}

/// `fifoadvisor request`: one-shot client for [`serve`] — send one JSON
/// request line, print the one-line response. Exits non-zero when the
/// server answers `"ok": false`, so shell scripts and CI can assert on
/// the exit code alone.
pub fn request(args: &Args) -> Result<()> {
    use crate::util::json::Json;
    use std::io::{BufRead, BufReader, Write};

    let addr = args.get("addr").unwrap_or("127.0.0.1:7733");
    let raw = args.require("json")?;
    // Validate locally first: a malformed request should fail here with
    // a parse error, not bounce off the server.
    let req = Json::parse(raw).map_err(|e| anyhow!("--json is not valid JSON: {e}"))?;
    let mut stream = std::net::TcpStream::connect(addr)
        .map_err(|e| anyhow!("cannot reach server at {addr}: {e}"))?;
    writeln!(stream, "{}", req.to_string_compact())?;
    let mut line = String::new();
    BufReader::new(stream.try_clone()?).read_line(&mut line)?;
    if line.is_empty() {
        bail!("server closed the connection without answering");
    }
    print!("{line}");
    let resp = Json::parse(&line).map_err(|e| anyhow!("unparseable response: {e}"))?;
    if resp.get("ok").and_then(Json::as_bool) != Some(true) {
        bail!(
            "request failed: {}",
            resp.get("error").and_then(Json::as_str).unwrap_or("unknown error")
        );
    }
    Ok(())
}
