//! Command-line front end (hand-rolled; the offline mirror has no clap).
//!
//! ```text
//! fifoadvisor list
//! fifoadvisor info     --design NAME [--args 64,512,7 [--args 64,512,8 ..]]
//! fifoadvisor simulate --design NAME [--baseline max|min | --depths 2,4,..]
//! fifoadvisor optimize --design NAME --optimizer grouped_sa [--budget 1000]
//!                      [--seed 1] [--jobs 4] [--xla] [--alpha 0.7]
//!                      [--out results/run.json] [--no-prune] [--no-bounds]
//!                      [--backend fast|compiled|batched] [--timeout-secs T]
//!                      [--cache-dir DIR] [--cache-max-mb 512] [--no-store]
//! fifoadvisor hunt     --design NAME [--timeout-secs T]
//! fifoadvisor certify  --design NAME --depths 2,4,.. [--budget 64]
//!                      [--optimizer auto] [--seed 1] [--jobs 4]
//!                      [--timeout-secs T] [--out cert.json]
//! fifoadvisor hunt-scenarios --design NAME [--depths 2,4,..]
//!                      [--budget 64] [--optimizer auto] [--seed 1]
//! fifoadvisor sweep    --config sweep.json [--resume] [--shard i/n]
//!                      [--out-dir DIR] [--cache-dir DIR]
//! fifoadvisor serve    [--addr 127.0.0.1:7733] [--unix-socket PATH]
//!                      [--cache-dir DIR] [--cache-max-mb 512] [--jobs N]
//! fifoadvisor request  --json '{"cmd":"ping"}' [--addr 127.0.0.1:7733]
//! ```
//!
//! Repeating `--args` builds a multi-scenario [`Workload`]
//! (scenario-robust sizing: worst-case latency, deadlock in any scenario
//! is infeasible); `--scenario-file W.json` loads a saved workload and
//! `--save-workload W.json` writes one.
//!
//! [`Workload`]: crate::trace::workload::Workload

pub mod args;
pub mod commands;

pub use args::Args;

use anyhow::{bail, Result};

/// Entry point used by `main.rs`.
pub fn run(argv: &[String]) -> Result<()> {
    if argv.is_empty() {
        print_usage();
        return Ok(());
    }
    let cmd = argv[0].as_str();
    let args = Args::parse(&argv[1..])?;
    match cmd {
        "list" => commands::list(),
        "info" => commands::info(&args),
        "simulate" => commands::simulate(&args),
        "optimize" => commands::optimize(&args),
        "hunt" => commands::hunt(&args),
        "certify" => commands::certify(&args),
        "hunt-scenarios" => commands::hunt_scenarios(&args),
        "sweep" => commands::sweep(&args),
        "serve" => commands::serve(&args),
        "request" => commands::request(&args),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `fifoadvisor help`)"),
    }
}

fn print_usage() {
    println!(
        "fifoadvisor — automated FIFO sizing DSE for HLS dataflow designs

USAGE:
  fifoadvisor list
  fifoadvisor info     --design NAME [--args A,B,C]
  fifoadvisor simulate --design NAME [--baseline max|min | --depths D1,D2,..]
  fifoadvisor optimize --design NAME --optimizer OPT [--budget N] [--seed S]
                       [--jobs N] [--xla] [--alpha 0.7] [--out FILE.json]
                       [--no-prune] [--no-bounds] [--distill]
                       [--certify] [--certify-budget N]
                       [--backend fast|compiled|batched]
                       [--cache-dir DIR] [--cache-max-mb 512] [--no-store]
                       (--jobs sizes the persistent worker pool; --threads
                        is accepted as a legacy alias. --no-prune disables
                        the simulation-free pruning layer — dominance
                        oracle, occupancy clamp, scenario early exit — for
                        A/B debugging; results are identical either way.
                        --no-bounds likewise disables the engine side of
                        the analytic depth-bounds pass — sub-floor
                        short-circuit, oracle seeding, tightened clamp
                        caps — again without changing any result.
                        --backend picks the simulation core: the
                        event-driven fast simulator (default), the
                        graph-compiled one, or the lane-batched SoA one
                        that answers a whole proposal batch in one graph
                        walk; outcomes are bit-identical, only throughput
                        differs. simulate/hunt accept --backend too.
                        --timeout-secs cuts the run off at the next
                        ask/tell round once the wall-clock budget passes;
                        the best-so-far front is reported and the run
                        JSON is flagged \"truncated\".
                        --distill runs the inner loop on the
                        dominance-distilled scenario bank with a
                        full-bank re-verify fixpoint — results stay
                        bit-identical, only scenario simulations drop.
                        --certify appends a robustness certificate for
                        the highlighted config: an adversarial hunt over
                        the design's kernel-argument space, budget
                        --certify-budget [64].
                        --cache-dir warm-starts the engine from the
                        cross-run snapshot store and saves an updated
                        snapshot after the run — a second identical
                        optimize replays with zero simulations, even
                        across processes; results are bit-identical to
                        a cold run. --cache-max-mb bounds the store
                        (LRU-evicted, 0 = unlimited); --no-store skips
                        the store even when --cache-dir is given.
                        simulate accepts the same three flags)
  fifoadvisor hunt     --design NAME [--timeout-secs T]
  fifoadvisor certify  --design NAME (--depths D1,D2,.. | --baseline max|min)
                       [--budget 64] [--optimizer auto] [--seed 1]
                       [--jobs N] [--timeout-secs T] [--out cert.json]
                       (hunts the design's kernel-argument space for a
                        scenario that deadlocks the given config; reports
                        either a concrete breaking arg vector or \"no
                        counterexample in N scenarios / T seconds\". The
                        auto optimizer enumerates the space exhaustively
                        when it fits the budget, making clean verdicts
                        exact. Only designs with a finite argument space
                        — see the [arg-space] markers in `list`)
  fifoadvisor hunt-scenarios --design NAME [--depths D1,D2,..]
                       [--budget 64] [--optimizer auto] [--seed 1]
                       [--jobs N] [--timeout-secs T]
                       (adversarial scenario mining: with --depths, hunt
                        for a breaking scenario; without, report the
                        maximum-pressure scenario of the argument space.
                        Also prints the dominance partition the
                        scenario-bank distillation would use)
  fifoadvisor sweep    --config sweep.json [--resume] [--shard i/n]
                       [--out-dir DIR] [--cache-dir DIR]
                       (the fault-tolerant grid orchestrator: every cell
                        is checkpointed into out_dir/manifest.json;
                        --resume skips done cells and retries failed
                        ones, --shard i/n runs a deterministic 1/n slice
                        of the grid for CI matrix jobs, --out-dir
                        overrides the config's out_dir, --cache-dir
                        additionally snapshots each cell's memo/oracle
                        into the cross-run store)
  fifoadvisor serve    [--addr 127.0.0.1:7733] [--unix-socket PATH]
                       [--cache-dir DIR] [--cache-max-mb 512] [--jobs N]
                       (the persistent sizing service: newline-delimited
                        JSON over TCP — one request object per line, one
                        response per line. Commands: ping, stats,
                        simulate, optimize, hunt, certify, shutdown.
                        Engines stay hot per (design, args, backend,
                        prune, bounds, jobs), so the second identical
                        optimize replays from the memo with zero
                        simulations; with --cache-dir the replay also
                        survives restarts. Per-request timeout_secs /
                        max_sims fields install a cancellation budget)
  fifoadvisor request  --json '{\"cmd\":\"ping\"}' [--addr 127.0.0.1:7733]
                       (one-shot client for serve: sends the JSON line,
                        prints the one-line response — enough for shell
                        scripts and the CI smoke job)

Any command accepting --design also accepts:
  --design-file F.fadl   a FADL text design (see rust/src/ir/fadl.rs)
  --trace-file T.json    a previously saved trace
  --save-trace T.json    cache the collected (primary) trace

Scenario-robust sizing: repeat --args once per scenario to optimize the
worst case over several runtime inputs (e.g. --args 64,512,7 --args
64,512,8 on flowgnn_pna). A config that deadlocks in ANY scenario is
infeasible.
  --scenario-file W.json load a saved multi-scenario workload
  --save-workload W.json save the workload built from --args

OPTIMIZERS: greedy random grouped_random sa grouped_sa nsga2 grouped_nsga2
            exhaustive vitis_hunter
DESIGNS:    `fifoadvisor list`"
    );
}
