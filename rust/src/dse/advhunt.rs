//! Adversarial scenario hunting, robustness certificates, and
//! scenario-bank distillation.
//!
//! The paper's central claim — runtime simulation is the only reliable
//! deadlock-safe analysis for data-dependent designs — cuts both ways: a
//! kernel-argument vector missing from the user's scenario bank can hide
//! a deadlock in a config reported "feasible". Millisecond incremental
//! re-evaluation makes an *outer* adversarial search over the argument
//! space affordable. This module provides the three pieces:
//!
//! 1. **[`hunt`]** — an adversarial outer loop over a design's finite
//!    kernel-argument space ([`ArgSpace`]), reusing the existing ask/tell
//!    optimizers with *args-as-genome* ([`crate::opt::genome`]): each
//!    proposal decodes to a concrete arg vector, its trace is collected,
//!    and the candidate scenario is scored by counterexample status
//!    (deadlock of the config under test — detected analytically via
//!    [`DepthBounds::below_floor`] when possible, by simulation
//!    otherwise) and then by peak-occupancy pressure. Without a config
//!    under test the hunt maximizes pressure outright (worst-case
//!    scenario mining).
//! 2. **[`certify`]** — a robustness certificate for an optimized
//!    config: either a concrete breaking arg vector, or "no
//!    counterexample in N scenarios / T seconds" (bounded-exhaustiveness
//!    certificates are exact: when the space fits the budget the `auto`
//!    optimizer enumerates it exhaustively).
//! 3. **[`optimize_distilled`]** — scenario-bank distillation: drop
//!    scenarios whose occupancy peaks, floors, and deadlock-relevant
//!    blocked sets are dominated by a sibling
//!    ([`distill_partition`]), run the inner DSE loop on the distilled
//!    bank, then re-verify every distilled-evaluated feasible front
//!    candidate against the full bank, promoting violators and
//!    re-entering the loop until fixpoint. At fixpoint the merged
//!    history is **bit-identical** to a from-scratch full-bank run
//!    (same optimizer, same seed): infeasible answers are sound for
//!    free (a deadlock on a kept scenario is a deadlock on the full
//!    bank; analytic floors and oracle seeds come from the *full*
//!    workload's [`DepthBounds`] via
//!    [`EvalEngine::set_depth_bounds`]), and the re-verify pass proves
//!    every feasible answer's worst-case latency is already attained on
//!    the kept scenarios.
//!
//! Hunts and distilled runs respect [`CancelToken`] budgets (wall-clock
//! deadline + simulation budget, checked per ask/tell round) and surface
//! a `truncated` flag, so the sweep orchestrator can checkpoint their
//! outcomes into its manifest like any other cell.

use std::collections::{BTreeSet, HashMap, HashSet};
use std::sync::Arc;
use std::time::Instant;

use super::cancel::CancelToken;
use super::engine::{EvalEngine, EvalResult};
use super::EvalPoint;
use crate::ir::Design;
use crate::opt::bounds::DepthBounds;
use crate::opt::genome::ArgSpace;
use crate::opt::pareto::{pareto_front, ObjPoint};
use crate::opt::{by_name, AskCtx, Optimizer, Space};
use crate::sim::fast::{FastSim, SimOutcome};
use crate::sim::scenario::{distill_partition, scenario_profiles, ScenarioSim};
use crate::sim::BackendKind;
use crate::trace::collect_trace;
use crate::trace::workload::Workload;
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// Adversarial hunting
// ---------------------------------------------------------------------------

/// Optimizer names the hunter accepts (`auto` picks exhaustive when the
/// space fits the budget, SA otherwise). The stats-driven depth
/// optimizers (greedy, vitis_hunter) are excluded: per-channel stall
/// statistics are meaningless over an argument genome.
pub const HUNT_OPTIMIZERS: [&str; 8] = [
    "auto",
    "exhaustive",
    "random",
    "grouped_random",
    "sa",
    "grouped_sa",
    "nsga2",
    "grouped_nsga2",
];

/// Pressure scores are told to the (minimizing) optimizers as
/// `BIAS − pressure`, so maximizing pressure is minimizing "latency".
const PRESSURE_BIAS: u64 = 1 << 40;

/// Hunt parameters.
#[derive(Debug, Clone)]
pub struct HuntConfig {
    /// One of [`HUNT_OPTIMIZERS`].
    pub optimizer: String,
    /// Optimizer seed (hunts are deterministic given the seed).
    pub seed: u64,
    /// Maximum argument-vector proposals.
    pub budget: usize,
    /// Worker threads for candidate trace collection + simulation.
    /// Results are bit-identical between serial and parallel runs.
    pub jobs: usize,
    /// Cooperative cancellation (deadline / simulation budget), checked
    /// per ask/tell round.
    pub cancel: CancelToken,
}

impl Default for HuntConfig {
    fn default() -> HuntConfig {
        HuntConfig {
            optimizer: "auto".to_string(),
            seed: 1,
            budget: 64,
            jobs: 1,
            cancel: CancelToken::new(),
        }
    }
}

/// A concrete breaking scenario found by the hunter.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CounterExample {
    /// The kernel-argument vector whose trace deadlocks the config.
    pub args: Vec<i64>,
    /// Channels involved in the deadlock (blocked-on channels, sorted;
    /// for analytic counterexamples, the channels below their floor).
    pub blocked: Vec<usize>,
    /// True when the deadlock was proven analytically (config below the
    /// candidate trace's depth floor) without a simulation.
    pub analytic: bool,
}

/// Outcome of one hunt.
#[derive(Debug, Clone)]
pub struct HuntReport {
    /// First breaking scenario found in proposal order, if any.
    pub counterexample: Option<CounterExample>,
    /// Distinct argument vectors evaluated.
    pub scenarios_tested: usize,
    /// Candidate-scenario simulations run.
    pub sims: u64,
    /// Counterexamples answered analytically (no simulation).
    pub floor_hits: u64,
    /// Highest-pressure non-breaking scenario seen `(args, pressure)`.
    pub best: Option<(Vec<i64>, u64)>,
    /// Wall-clock seconds.
    pub elapsed_secs: f64,
    /// True when the cancel token stopped the hunt early.
    pub truncated: bool,
}

/// One evaluated candidate scenario (memoized per distinct arg vector).
#[derive(Debug, Clone)]
struct CandEval {
    /// Blocked channels when the candidate breaks the config.
    counterexample: Option<Vec<usize>>,
    analytic: bool,
    /// Occupancy pressure (Σ peak occupancy + Σ analytic floors).
    pressure: u64,
    sims: u64,
}

/// Pick the hunt optimizer: `auto` resolves to exhaustive when the whole
/// space fits the budget (making clean certificates exact), SA
/// otherwise. Returns `None` for names outside [`HUNT_OPTIMIZERS`].
fn hunt_optimizer(cfg: &HuntConfig, space: &ArgSpace) -> Option<Box<dyn Optimizer>> {
    let name: &str = if cfg.optimizer == "auto" {
        match space.num_points() {
            Some(n) if n <= cfg.budget => "exhaustive",
            _ => "sa",
        }
    } else if HUNT_OPTIMIZERS.contains(&cfg.optimizer.as_str()) {
        &cfg.optimizer
    } else {
        return None;
    };
    by_name(name, cfg.seed)
}

/// Evaluate one candidate arg vector against the config under test (or,
/// with `depths == None`, probe its pressure at its own Baseline-Max).
fn eval_candidate(design: &Design, args: &[i64], depths: Option<&[u32]>) -> CandEval {
    let trace = collect_trace(design, args)
        .unwrap_or_else(|e| panic!("arg-space point {args:?} failed to trace: {e}"));
    let bounds = DepthBounds::for_trace(&trace);
    let floor_pressure: u64 = bounds.floors.iter().map(|&f| f as u64).sum();
    if let Some(d) = depths {
        if bounds.below_floor(d) {
            let blocked: Vec<usize> = bounds
                .floors
                .iter()
                .enumerate()
                .filter(|&(c, &f)| d[c] < f)
                .map(|(c, _)| c)
                .collect();
            return CandEval {
                counterexample: Some(blocked),
                analytic: true,
                pressure: u64::MAX,
                sims: 0,
            };
        }
    }
    let probe: Vec<u32> = match depths {
        Some(d) => d.to_vec(),
        None => trace.baseline_max(),
    };
    let mut sim = FastSim::new(Arc::new(trace));
    let (out, stats) = sim.simulate_with_stats(&probe);
    match out {
        SimOutcome::Deadlock { blocked } => {
            let mut chans: Vec<usize> = blocked.iter().map(|b| b.channel).collect();
            chans.sort_unstable();
            chans.dedup();
            CandEval {
                counterexample: Some(chans),
                analytic: false,
                pressure: u64::MAX,
                sims: 1,
            }
        }
        SimOutcome::Done { .. } => CandEval {
            counterexample: None,
            analytic: false,
            pressure: stats.max_occupancy.iter().map(|&o| o as u64).sum::<u64>()
                + floor_pressure,
            sims: 1,
        },
    }
}

/// Evaluate fresh candidates, fanning out over `jobs` threads in
/// deterministic order-preserving chunks (results are reassembled in
/// input order, so serial and parallel hunts are bit-identical).
fn eval_fresh(
    design: &Design,
    fresh: &[Vec<i64>],
    depths: Option<&[u32]>,
    jobs: usize,
) -> Vec<CandEval> {
    if jobs <= 1 || fresh.len() <= 1 {
        return fresh
            .iter()
            .map(|a| eval_candidate(design, a, depths))
            .collect();
    }
    let chunk = fresh.len().div_ceil(jobs);
    let mut out: Vec<Option<CandEval>> = vec![None; fresh.len()];
    std::thread::scope(|s| {
        for (slots, args) in out.chunks_mut(chunk).zip(fresh.chunks(chunk)) {
            s.spawn(move || {
                for (slot, a) in slots.iter_mut().zip(args) {
                    *slot = Some(eval_candidate(design, a, depths));
                }
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Hunt the design's argument space for a scenario that breaks `depths`
/// (deadlocks under the config), or — with `depths == None` — for the
/// maximum-pressure scenario. Stops at the first counterexample (in
/// proposal order — deterministic under a fixed seed and independent of
/// `jobs`), budget exhaustion, or cancellation.
pub fn hunt(
    design: &Design,
    space: &ArgSpace,
    depths: Option<&[u32]>,
    cfg: &HuntConfig,
) -> HuntReport {
    let start = Instant::now();
    let gspace = space.genome_space();
    let mut opt = hunt_optimizer(cfg, space).unwrap_or_else(|| {
        panic!(
            "unknown hunt optimizer '{}' (expected one of {:?})",
            cfg.optimizer, HUNT_OPTIMIZERS
        )
    });
    let batch_hint = (cfg.jobs.max(1) * 8).clamp(16, 128);
    let mut memo: HashMap<Vec<i64>, CandEval> = HashMap::new();
    let mut sims = 0u64;
    let mut floor_hits = 0u64;
    let mut best: Option<(Vec<i64>, u64)> = None;
    let mut counterexample = None;
    let mut truncated = false;
    let mut proposed = 0usize;
    'rounds: loop {
        if opt.done() {
            break;
        }
        if cfg.cancel.triggered(sims) {
            truncated = true;
            break;
        }
        let ctx = AskCtx {
            space: &gspace,
            budget_left: cfg.budget.saturating_sub(proposed),
            batch_hint,
        };
        let batch = opt.ask(&ctx);
        if batch.is_empty() {
            break;
        }
        proposed += batch.len();
        let decoded: Vec<Vec<i64>> = batch.iter().map(|p| space.decode(p)).collect();
        let mut fresh: Vec<Vec<i64>> = Vec::new();
        {
            let mut seen: HashSet<&[i64]> = HashSet::new();
            for a in &decoded {
                if !memo.contains_key(a) && seen.insert(a) {
                    fresh.push(a.clone());
                }
            }
        }
        let evals = eval_fresh(design, &fresh, depths, cfg.jobs);
        for (a, e) in fresh.into_iter().zip(evals) {
            sims += e.sims;
            if e.analytic {
                floor_hits += 1;
            }
            memo.insert(a, e);
        }
        let results: Vec<EvalResult> = decoded
            .iter()
            .zip(&batch)
            .map(|(a, p)| {
                let e = &memo[a];
                EvalResult {
                    depths: p.clone(),
                    latency: if e.counterexample.is_some() {
                        None
                    } else {
                        Some(PRESSURE_BIAS.saturating_sub(e.pressure))
                    },
                    bram: 0,
                    stats: None,
                    blocked: Vec::new(),
                }
            })
            .collect();
        opt.tell(&results);
        for a in &decoded {
            let e = &memo[a];
            if let Some(blocked) = &e.counterexample {
                counterexample = Some(CounterExample {
                    args: a.clone(),
                    blocked: blocked.clone(),
                    analytic: e.analytic,
                });
                break 'rounds;
            }
            let better = match &best {
                None => true,
                Some((_, bp)) => e.pressure > *bp,
            };
            if better {
                best = Some((a.clone(), e.pressure));
            }
        }
    }
    HuntReport {
        counterexample,
        scenarios_tested: memo.len(),
        sims,
        floor_hits,
        best,
        elapsed_secs: start.elapsed().as_secs_f64(),
        truncated,
    }
}

// ---------------------------------------------------------------------------
// Robustness certificates
// ---------------------------------------------------------------------------

/// A robustness certificate for one config over one design's argument
/// space: either a concrete breaking arg vector, or "no counterexample
/// in N scenarios / T seconds". When the hunt enumerated the whole
/// space without truncation, a clean certificate is *exact*.
#[derive(Debug, Clone)]
pub struct Certificate {
    /// Design name the certificate is about.
    pub design: String,
    /// The config under test.
    pub depths: Vec<u32>,
    /// The breaking scenario, if one was found.
    pub counterexample: Option<CounterExample>,
    /// Distinct scenarios tried.
    pub scenarios_tested: usize,
    /// Total points in the argument space (`None` on overflow).
    pub space_points: Option<usize>,
    /// Simulations spent.
    pub sims: u64,
    /// Hunt wall-clock seconds.
    pub elapsed_secs: f64,
    /// True when the hunt was cut off by its cancel token.
    pub truncated: bool,
}

impl Certificate {
    /// No counterexample found (within the tested budget).
    pub fn is_clean(&self) -> bool {
        self.counterexample.is_none()
    }

    /// The clean certificate covered the *entire* argument space — the
    /// config provably cannot deadlock on any in-space scenario.
    pub fn is_exhaustive(&self) -> bool {
        self.is_clean()
            && !self.truncated
            && self.space_points == Some(self.scenarios_tested)
    }

    /// Compact verdict for sweep columns / logs, e.g.
    /// `broken@[64, 512, 8]`, `clean-exhaustive(8)`, `clean(40)`,
    /// `clean?(12/s truncated)`.
    pub fn verdict(&self) -> String {
        match &self.counterexample {
            Some(ce) => format!("broken@{:?}", ce.args),
            None if self.is_exhaustive() => {
                format!("clean-exhaustive({})", self.scenarios_tested)
            }
            None if self.truncated => format!("clean?({} truncated)", self.scenarios_tested),
            None => format!("clean({})", self.scenarios_tested),
        }
    }

    /// JSON object for run records.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("design", Json::Str(self.design.clone())),
            ("depths", Json::nums(&self.depths.iter().map(|&d| d as f64).collect::<Vec<_>>())),
            ("verdict", Json::Str(self.verdict())),
            (
                "counterexample",
                match &self.counterexample {
                    Some(ce) => Json::obj(vec![
                        (
                            "args",
                            Json::Arr(ce.args.iter().map(|&a| Json::Num(a as f64)).collect()),
                        ),
                        (
                            "blocked",
                            Json::nums(
                                &ce.blocked.iter().map(|&c| c as f64).collect::<Vec<_>>(),
                            ),
                        ),
                        ("analytic", Json::Bool(ce.analytic)),
                    ]),
                    None => Json::Null,
                },
            ),
            ("scenarios_tested", Json::Num(self.scenarios_tested as f64)),
            (
                "space_points",
                match self.space_points {
                    Some(n) => Json::Num(n as f64),
                    None => Json::Null,
                },
            ),
            ("sims", Json::Num(self.sims as f64)),
            ("elapsed_secs", Json::Num(self.elapsed_secs)),
            ("exhaustive", Json::Bool(self.is_exhaustive())),
            ("truncated", Json::Bool(self.truncated)),
        ])
    }
}

/// Certify `depths` over the design's argument space (a break-mode
/// [`hunt`]).
pub fn certify(
    design: &Design,
    design_name: &str,
    space: &ArgSpace,
    depths: &[u32],
    cfg: &HuntConfig,
) -> Certificate {
    let report = hunt(design, space, Some(depths), cfg);
    Certificate {
        design: design_name.to_string(),
        depths: depths.to_vec(),
        counterexample: report.counterexample,
        scenarios_tested: report.scenarios_tested,
        space_points: space.num_points(),
        sims: report.sims,
        elapsed_secs: report.elapsed_secs,
        truncated: report.truncated,
    }
}

/// [`certify`] a bench-suite design by name; `None` when the design
/// exposes no argument space (static designs have nothing to hunt).
pub fn certify_design(name: &str, depths: &[u32], cfg: &HuntConfig) -> Option<Certificate> {
    let space = crate::bench_suite::arg_space(name)?;
    let bd = crate::bench_suite::try_build(name)?;
    Some(certify(&bd.design, name, &space, depths, cfg))
}

// ---------------------------------------------------------------------------
// Scenario-bank distillation
// ---------------------------------------------------------------------------

/// Inner-DSE parameters for a distilled run (mirrors a sweep cell's
/// knobs).
#[derive(Debug, Clone)]
pub struct DistillConfig {
    /// Inner optimizer name ([`by_name`]).
    pub optimizer: String,
    pub seed: u64,
    /// Proposal budget per fixpoint iteration (the reference full-bank
    /// run gets the same budget).
    pub budget: usize,
    pub jobs: usize,
    /// Engine pruning layer toggle (`--no-prune`).
    pub prune: bool,
    /// Engine analytic-bounds toggle (`--no-bounds`).
    pub bounds: bool,
    /// Simulation backend for both engines.
    pub backend: BackendKind,
    /// Cooperative cancellation across the whole fixpoint loop
    /// (sim budget counts distilled + full + verify simulations).
    pub cancel: CancelToken,
}

impl Default for DistillConfig {
    fn default() -> DistillConfig {
        DistillConfig {
            optimizer: "sa".to_string(),
            seed: 1,
            budget: 200,
            jobs: 1,
            prune: true,
            bounds: true,
            backend: BackendKind::Fast,
            cancel: CancelToken::new(),
        }
    }
}

/// Outcome of a distilled optimization run.
#[derive(Debug, Clone)]
pub struct DistillOutcome {
    /// Merged evaluation history of the final fixpoint iteration, in
    /// proposal order (baselines first) — bit-identical to a full-bank
    /// run's history.
    pub history: Vec<EvalPoint>,
    /// Pareto front over the feasible history.
    pub front: Vec<EvalPoint>,
    /// Baseline-Max / Baseline-Min points (full-bank exact).
    pub baseline_max: EvalPoint,
    /// See [`baseline_max`](Self::baseline_max).
    pub baseline_min: EvalPoint,
    /// Scenario indices kept by the initial dominance partition.
    pub kept_initial: Vec<usize>,
    /// Scenario indices in the final (fixpoint) distilled bank.
    pub kept_final: Vec<usize>,
    /// Scenarios promoted back by the re-verify pass, in promotion
    /// order.
    pub promotions: Vec<usize>,
    /// Fixpoint iterations run (1 = the initial partition verified
    /// clean).
    pub iterations: usize,
    /// Per-scenario simulator invocations spent inside the final
    /// iteration's inner DSE loop (the number distillation reduces).
    pub inner_scenario_sims: u64,
    /// Per-scenario simulator invocations spent re-verifying the front
    /// against dropped scenarios (all iterations).
    pub verify_scenario_sims: u64,
    /// True when the cancel token cut the run off (the fixpoint is then
    /// *not* guaranteed — the front is best-so-far, like a truncated
    /// sweep cell).
    pub truncated: bool,
}

impl DistillOutcome {
    /// Scenarios dropped from the final bank.
    pub fn dropped_final(&self, num_scenarios: usize) -> Vec<usize> {
        (0..num_scenarios)
            .filter(|i| !self.kept_final.contains(i))
            .collect()
    }
}

/// Run the inner DSE loop on the dominance-distilled scenario bank,
/// re-verifying against the full bank until fixpoint. See the module
/// docs for the bit-identity argument. The caller's `space` must be the
/// *full* workload's space ([`Space::from_workload`]).
pub fn optimize_distilled(
    workload: &Arc<Workload>,
    space: &Space,
    cfg: &DistillConfig,
) -> DistillOutcome {
    let n = workload.num_scenarios();
    let profiles = scenario_profiles(workload);
    let (mut kept, _dominators) = distill_partition(&profiles);
    let kept_initial = kept.clone();
    let full_bounds = DepthBounds::for_workload(workload);
    let mut promotions: Vec<usize> = Vec::new();
    let mut iterations = 0usize;
    let mut verify_scenario_sims = 0u64;

    // The full-bank engine: baselines, wants_stats batches, and (by
    // sharing its sim-count with the token check) the budget meter.
    let mut full = EvalEngine::for_workload_full(
        workload.clone(),
        Box::new(super::NativeBram),
        cfg.jobs,
        cfg.backend,
    );
    full.set_prune(cfg.prune);
    full.set_bounds(cfg.bounds);

    loop {
        iterations += 1;
        let dropped: Vec<usize> = (0..n).filter(|i| !kept.contains(i)).collect();
        full.reset_run(true);

        // The distilled engine: the kept scenarios only, but the FULL
        // workload's analytic bounds (floors/caps/oracle seeds), so its
        // pruning layers answer exactly like the full engine's.
        let sub = Arc::new(workload.subset(&kept));
        let mut dist = EvalEngine::for_workload_full(
            sub,
            Box::new(super::NativeBram),
            cfg.jobs,
            cfg.backend,
        );
        dist.set_prune(cfg.prune);
        dist.set_bounds(cfg.bounds);
        dist.set_depth_bounds(full_bounds.clone());

        let mut opt = by_name(&cfg.optimizer, cfg.seed)
            .unwrap_or_else(|| panic!("unknown optimizer '{}'", cfg.optimizer));

        // Baselines are evaluated on the full bank (their exact values
        // land in history and reports); mirror them into the distilled
        // oracle so both runs learn them at the same point.
        let (bmax, bmin) = full.eval_baselines();
        dist.note_external(&bmax.depths, bmax.latency);
        dist.note_external(&bmin.depths, bmin.latency);
        let mut history: Vec<EvalPoint> = vec![bmax.clone(), bmin.clone()];
        // History indices answered by the distilled engine (the only
        // ones whose feasible latencies need full-bank re-verification).
        let mut dist_points: Vec<usize> = Vec::new();
        let mut truncated = false;

        // The drive loop, split across the two engines: latency-only
        // batches run on the distilled bank, stats batches on the full
        // bank (max-merged stats must cover every scenario), mirrored
        // into the distilled oracle.
        loop {
            if opt.done() {
                break;
            }
            let spent = full.n_sim + dist.n_sim + verify_scenario_sims;
            if cfg.cancel.triggered(spent) {
                truncated = true;
                break;
            }
            let proposed = history.len() - 2;
            let ctx = AskCtx {
                space,
                budget_left: cfg.budget.saturating_sub(proposed),
                batch_hint: dist.batch_hint(),
            };
            let batch = opt.ask(&ctx);
            if batch.is_empty() {
                break;
            }
            let hints = opt.hints();
            let results = if opt.wants_stats() {
                let r = full.eval_results_hinted(&batch, &hints, true);
                for res in &r {
                    dist.note_external(&res.depths, res.latency);
                }
                r
            } else {
                let r = dist.eval_results_hinted(&batch, &hints, false);
                for k in 0..r.len() {
                    dist_points.push(history.len() + k);
                }
                r
            };
            for res in &results {
                history.push(EvalPoint {
                    depths: res.depths.clone(),
                    latency: res.latency,
                    bram: res.bram,
                    t: full.elapsed(),
                });
            }
            opt.tell(&results);
        }

        // Re-verify: every feasible distilled answer must already attain
        // its worst case on the kept scenarios — any dropped scenario
        // that deadlocks or exceeds the reported latency is promoted.
        let mut violators: BTreeSet<usize> = BTreeSet::new();
        if !dropped.is_empty() && !truncated {
            let dropped_w = workload.subset(&dropped);
            let mut vsim = ScenarioSim::new(&dropped_w);
            let mut vmemo: HashMap<Box<[u32]>, Vec<Option<u64>>> = HashMap::new();
            for &hi in &dist_points {
                let p = &history[hi];
                let Some(lat) = p.latency else { continue };
                if !vmemo.contains_key(&p.depths) {
                    vsim.simulate(&p.depths);
                    verify_scenario_sims += vsim.last_scenarios_run() as u64;
                    vmemo.insert(p.depths.clone(), vsim.scenario_latencies().to_vec());
                }
                for (j, dl) in vmemo[&p.depths].iter().enumerate() {
                    match dl {
                        None => {
                            violators.insert(dropped[j]);
                        }
                        Some(l) if *l > lat => {
                            violators.insert(dropped[j]);
                        }
                        _ => {}
                    }
                }
            }
        }

        if violators.is_empty() || truncated {
            let pts: Vec<ObjPoint> = history
                .iter()
                .enumerate()
                .filter_map(|(i, p)| {
                    p.latency.map(|l| ObjPoint {
                        latency: l,
                        bram: p.bram,
                        index: i,
                    })
                })
                .collect();
            let front: Vec<EvalPoint> = pareto_front(&pts)
                .into_iter()
                .map(|p| history[p.index].clone())
                .collect();
            let inner_scenario_sims =
                full.stats().scenario_sims + dist.stats().scenario_sims;
            return DistillOutcome {
                history,
                front,
                baseline_max: bmax,
                baseline_min: bmin,
                kept_initial,
                kept_final: kept,
                promotions,
                iterations,
                inner_scenario_sims,
                verify_scenario_sims,
                truncated,
            };
        }
        promotions.extend(violators.iter().copied());
        kept.extend(violators);
        kept.sort_unstable();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;

    #[test]
    fn fig2_certify_finds_subfloor_counterexample() {
        // Depth 10 on x survives n ≤ 11 but deadlocks for n ≥ 12 — the
        // hunter must find some breaking n in the 2..=32 space.
        let cert = certify_design("fig2", &[10, 2], &HuntConfig::default()).unwrap();
        let ce = cert.counterexample.expect("must break");
        assert!(ce.args[0] >= 12, "breaking n {} too small", ce.args[0]);
        assert!(ce.blocked.contains(&0));
        assert!(!cert.is_clean());
        assert!(cert.verdict().starts_with("broken@"));
    }

    #[test]
    fn fig2_certifies_clean_at_space_maximum() {
        // Depth 31 ≥ n − 1 for every n ≤ 32: exhaustively clean.
        let cert = certify_design("fig2", &[31, 2], &HuntConfig::default()).unwrap();
        assert!(cert.is_clean());
        assert!(cert.is_exhaustive(), "31-point space fits the 64 budget");
        assert_eq!(cert.scenarios_tested, 31);
        assert!(cert.verdict().starts_with("clean-exhaustive"));
        // Static designs expose no space.
        assert!(certify_design("gemm", &[2, 2], &HuntConfig::default()).is_none());
    }

    #[test]
    fn hunts_are_deterministic_and_job_independent() {
        let bd = bench_suite::build("mini_dnn");
        let space = bench_suite::arg_space("mini_dnn").unwrap();
        // auto → exhaustive (30-point space ≤ 64 budget), so the
        // counterexample is guaranteed regardless of seed.
        let cfg = HuntConfig {
            optimizer: "auto".to_string(),
            budget: 64,
            seed: 9,
            ..HuntConfig::default()
        };
        // z sized for m = 16 breaks under m = 32 or 64.
        let depths = [4096, 4096, 16, 2];
        let a = hunt(&bd.design, &space, Some(&depths), &cfg);
        let b = hunt(&bd.design, &space, Some(&depths), &cfg);
        let par = hunt(
            &bd.design,
            &space,
            Some(&depths),
            &HuntConfig { jobs: 4, ..cfg.clone() },
        );
        let ce = a.counterexample.clone().expect("m=32/64 tilings break z=16");
        assert!(ce.args[1] > 16);
        assert_eq!(a.counterexample, b.counterexample);
        assert_eq!(a.scenarios_tested, b.scenarios_tested);
        assert_eq!(a.counterexample, par.counterexample);
        assert_eq!(a.scenarios_tested, par.scenarios_tested);
    }

    #[test]
    fn pressure_hunt_reports_max_pressure_scenario() {
        let bd = bench_suite::build("fig2");
        let space = bench_suite::arg_space("fig2").unwrap();
        let r = hunt(&bd.design, &space, None, &HuntConfig::default());
        assert!(r.counterexample.is_none(), "pressure mode never breaks");
        let (args, _) = r.best.expect("must report a best scenario");
        // Pressure grows with n: the exhaustive auto hunt finds n = 32.
        assert_eq!(args, vec![32]);
        assert_eq!(r.scenarios_tested, 31);
    }

    #[test]
    fn cancel_token_truncates_hunts() {
        let bd = bench_suite::build("fig2");
        let space = bench_suite::arg_space("fig2").unwrap();
        let cfg = HuntConfig {
            cancel: CancelToken::with_limits(None, Some(0)),
            optimizer: "random".to_string(),
            budget: 1000,
            ..HuntConfig::default()
        };
        let r = hunt(&bd.design, &space, Some(&[31, 2]), &cfg);
        assert!(r.truncated);
        assert!(r.counterexample.is_none());
    }

    #[test]
    fn distilled_run_matches_full_bank_on_fig2() {
        let w = Arc::new(bench_suite::build_workload("fig2").unwrap());
        let space = Space::from_workload(&w);
        let cfg = DistillConfig {
            optimizer: "sa".to_string(),
            seed: 3,
            budget: 80,
            ..DistillConfig::default()
        };
        let out = optimize_distilled(&w, &space, &cfg);
        assert!(!out.truncated);
        assert!(out.kept_final.len() < w.num_scenarios() || !out.promotions.is_empty());

        // Reference: a plain full-bank run, same optimizer + seed.
        let mut full = EvalEngine::for_workload(w.clone(), 1);
        full.eval_baselines();
        let mut opt = by_name("sa", 3).unwrap();
        super::super::drive(&mut *opt, &mut full, &space, 80);
        let ref_hist: Vec<(Box<[u32]>, Option<u64>, u32)> = full
            .history
            .iter()
            .map(|p| (p.depths.clone(), p.latency, p.bram))
            .collect();
        let got_hist: Vec<(Box<[u32]>, Option<u64>, u32)> = out
            .history
            .iter()
            .map(|p| (p.depths.clone(), p.latency, p.bram))
            .collect();
        assert_eq!(got_hist, ref_hist, "distilled history must be bit-identical");
        let ref_front: Vec<(Box<[u32]>, Option<u64>, u32)> = full
            .pareto()
            .into_iter()
            .map(|p| (p.depths.clone(), p.latency, p.bram))
            .collect();
        let got_front: Vec<(Box<[u32]>, Option<u64>, u32)> = out
            .front
            .iter()
            .map(|p| (p.depths.clone(), p.latency, p.bram))
            .collect();
        assert_eq!(got_front, ref_front);
    }
}
