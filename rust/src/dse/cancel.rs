//! Cooperative cancellation for DSE runs.
//!
//! A [`CancelToken`] bundles the three ways a run can be cut short —
//! an explicit [`cancel`](CancelToken::cancel) call, a wall-clock
//! deadline, and a simulation-count budget — behind one cheap
//! [`triggered`](CancelToken::triggered) check. [`drive`](crate::dse::drive)
//! consults the engine's token once per ask/tell round, and the engine
//! additionally polls the explicit-cancel/deadline legs *inside* a
//! round: per queued job on the worker pool, per scenario boundary
//! under the lane-batched backend, and per configuration on the serial
//! path — so one large batch can no longer overrun a deadline by its
//! full length. Cancellation stays cooperative and result-safe: an
//! aborted batch is rolled back wholesale, the run stops at the last
//! *completed* round with its history and Pareto front intact (the
//! engine flags the run
//! [`truncated`](crate::dse::EvalEngine::truncated)), and a cancelled
//! run's history is a prefix of the uncancelled one's.
//!
//! Tokens are `Clone` + `Send` + `Sync` and share state through an
//! `Arc`, so an orchestrator can hold one handle to cancel a cell while
//! the cell's engine polls another.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

struct Inner {
    cancelled: AtomicBool,
    /// Absolute wall-clock cutoff (set at construction; the clock starts
    /// when the token is created, not when the run starts).
    deadline: Option<Instant>,
    /// Maximum simulator invocations before the run is cut off.
    sim_budget: Option<u64>,
}

/// Shared cancellation handle. The default token never triggers.
#[derive(Clone)]
pub struct CancelToken {
    inner: Arc<Inner>,
}

impl Default for CancelToken {
    fn default() -> Self {
        Self::new()
    }
}

impl CancelToken {
    /// A token that only triggers on an explicit [`cancel`](Self::cancel).
    pub fn new() -> CancelToken {
        Self::with_limits(None, None)
    }

    /// A token that triggers once `timeout` has elapsed from now.
    pub fn with_timeout(timeout: Duration) -> CancelToken {
        Self::with_limits(Some(timeout), None)
    }

    /// A token with any combination of wall-clock and simulation-count
    /// budgets (`None` = unlimited).
    pub fn with_limits(timeout: Option<Duration>, sim_budget: Option<u64>) -> CancelToken {
        CancelToken {
            inner: Arc::new(Inner {
                cancelled: AtomicBool::new(false),
                deadline: timeout.map(|t| Instant::now() + t),
                sim_budget,
            }),
        }
    }

    /// Request cancellation; every clone of this token observes it.
    pub fn cancel(&self) {
        self.inner.cancelled.store(true, Ordering::SeqCst);
    }

    /// True once [`cancel`](Self::cancel) has been called.
    pub fn cancelled(&self) -> bool {
        self.inner.cancelled.load(Ordering::SeqCst)
    }

    /// True once the wall-clock deadline (if any) has passed.
    pub fn deadline_exceeded(&self) -> bool {
        self.inner.deadline.is_some_and(|d| Instant::now() >= d)
    }

    /// The simulation budget this token enforces, if any.
    pub fn sim_budget(&self) -> Option<u64> {
        self.inner.sim_budget
    }

    /// Should a run that has performed `sims` simulations stop now?
    /// Checked at round boundaries, so a run may overshoot the budget by
    /// at most one batch.
    pub fn triggered(&self, sims: u64) -> bool {
        self.cancelled()
            || self.deadline_exceeded()
            || self.inner.sim_budget.is_some_and(|b| sims >= b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_token_never_triggers() {
        let t = CancelToken::new();
        assert!(!t.triggered(0));
        assert!(!t.triggered(u64::MAX));
        assert!(!t.deadline_exceeded());
        assert_eq!(t.sim_budget(), None);
    }

    #[test]
    fn explicit_cancel_is_shared_across_clones() {
        let t = CancelToken::new();
        let clone = t.clone();
        assert!(!clone.triggered(0));
        t.cancel();
        assert!(clone.cancelled());
        assert!(clone.triggered(0));
    }

    #[test]
    fn sim_budget_triggers_at_threshold() {
        let t = CancelToken::with_limits(None, Some(10));
        assert!(!t.triggered(9));
        assert!(t.triggered(10));
        assert!(t.triggered(11));
    }

    #[test]
    fn deadline_triggers_after_elapse() {
        let t = CancelToken::with_timeout(Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(5));
        assert!(t.deadline_exceeded());
        assert!(t.triggered(0));
        // A generous deadline has not passed yet.
        let slow = CancelToken::with_timeout(Duration::from_secs(3600));
        assert!(!slow.triggered(0));
    }
}
