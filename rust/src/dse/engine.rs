//! The ask/tell evaluation engine.
//!
//! [`EvalEngine`] owns everything the optimizer loop needs to evaluate
//! FIFO configurations at full hardware speed:
//!
//! - a **persistent worker pool** ([`WorkerPool`]): `jobs` threads are
//!   spawned once at engine construction, each holding its own cloned
//!   [`ScenarioSim`] bank over the shared workload traces (running
//!   whichever [`BackendKind`] the engine was built with — the
//!   event-driven `FastSim` by default, the graph-compiled `CompiledSim`
//!   under `--backend compiled`; the memo/oracle/clamp layers above the
//!   bank are backend-agnostic), and are fed
//!   work over per-worker queues — no per-batch thread spawning on the
//!   hot path. Dispatch is
//!   **sticky and locality-aware**: every proposal is routed to the
//!   worker whose retained simulation schedule is Hamming-closest to the
//!   proposal's locality hint (its parent configuration, reported by the
//!   optimizer through [`Optimizer::hints`]), under a per-worker cap
//!   that keeps batches balanced — so small mutations land on a worker
//!   that can re-simulate them as a cheap delta instead of a full
//!   replay;
//! - a **sharded memo cache** ([`ShardedCache`]): N shards keyed by the
//!   configuration hash, so concurrent lookups from worker threads don't
//!   serialize on a single lock;
//! - **in-batch deduplication** and one batched [`BramBatch`] backend
//!   call per batch (the XLA-artifact-shaped hot path);
//! - centralized **budget/history accounting**: [`drive`] runs any
//!   [`Optimizer`] by alternating `ask` → evaluate → `tell` until the
//!   optimizer finishes or the proposal budget is exhausted.
//!
//! Results are deterministic: the history is assembled in proposal order
//! regardless of worker scheduling, so a serial run and a `--jobs N` run
//! produce identical latencies, BRAM totals and Pareto fronts.
//!
//! The engine evaluates a [`Workload`] — one or many traces of the same
//! design under different kernel arguments. The memo cache key is still
//! the depth vector (one workload per engine), latency is the
//! scenario-aggregated objective (worst-case by default), and deadlock in
//! any scenario is infeasible. Single-scenario workloads take the exact
//! single-trace fast path, so `EvalEngine::new(trace)` behaves exactly
//! as before the workload refactor.
//!
//! # Simulation-free pruning
//!
//! Every latency-only proposal is threaded through the
//! [`crate::opt::dominance`] layer before any simulator runs:
//!
//! - the monotone [`FeasibilityOracle`] answers proposals component-wise
//!   ≤ a known deadlock as `Deadlock` instantly (and learns from every
//!   engine result);
//! - the occupancy-clamp [`Canonicalizer`] collapses depths above each
//!   channel's write-count cap onto one canonical memo point per
//!   SRL↔BRAM read-latency class, so the whole region above the cap
//!   shares a single cache entry (latency is memoized by canonical key;
//!   BRAM cost is always computed from the *actual* depths);
//! - multi-scenario deadlocks early-exit through
//!   [`ScenarioSim::eval_latency`], probing the historically
//!   deadlock-prone scenario first.
//!
//! Pruning is sound (see the module docs of [`crate::opt::dominance`]):
//! pruned and unpruned runs produce bit-identical histories and Pareto
//! fronts — only [`EngineStats::sims`] differs. `--no-prune` /
//! [`EvalEngine::set_prune`] switch the whole layer off for A/B runs;
//! the stats-evaluation path (greedy ranking, targeted hunter) always
//! simulates, since it exists to collect per-channel statistics and
//! deadlock block info.
//!
//! # Analytic depth bounds
//!
//! On top of the learned pruning layer, the engine runs the
//! [`crate::opt::bounds`] pass once at construction: per-channel
//! deadlock floors and tightened clamp caps mined from the compiled
//! event graph. With bounds on (the default) the engine answers any
//! proposal below a floor as `Deadlock` with **zero** simulation (before
//! the oracle is even consulted), seeds the oracle's infeasible
//! antichain with the one-below-floor frontier, and canonicalizes with
//! the tightened caps instead of the raw write counts. Like pruning,
//! the bounds layer never changes results — `--no-bounds` /
//! [`EvalEngine::set_bounds`] switch it off for A/B runs.

use super::{BramBatch, EvalPoint, NativeBram};
use crate::bram;
use crate::dse::cancel::CancelToken;
use crate::opt::bounds::DepthBounds;
use crate::opt::dominance::{Canonicalizer, FeasibilityOracle};
use crate::opt::pareto::{pareto_front, ObjPoint};
use crate::opt::{AskCtx, Optimizer, Space};
use crate::sim::fast::{BlockInfo, ChannelStats, RunInfo, SimOutcome};
use crate::sim::scenario::ScenarioSim;
use crate::sim::{BackendKind, SimOptions};
use crate::trace::workload::Workload;
use crate::trace::Trace;
use std::collections::{HashMap, HashSet};
use std::hash::{Hash, Hasher};
use std::sync::mpsc;
use std::sync::{Arc, RwLock};
use std::thread;
use std::time::Instant;

// ---------------------------------------------------------------------------
// Sharded memo cache
// ---------------------------------------------------------------------------

/// One memoized evaluation: `(latency, bram)` — `None` latency means
/// deadlock. Public so the persistent store ([`crate::store`]) can dump
/// and re-import memo shards verbatim.
pub type CacheValue = (Option<u64>, u32);

/// A concurrent memo cache for evaluated configurations, split into
/// power-of-two shards selected by the configuration hash. Readers on
/// different shards never contend; readers on the same shard share an
/// `RwLock` read guard.
pub struct ShardedCache {
    shards: Box<[RwLock<HashMap<Box<[u32]>, CacheValue>>]>,
    mask: usize,
}

impl ShardedCache {
    /// Create a cache with at least `shards` shards (rounded up to a
    /// power of two).
    pub fn new(shards: usize) -> ShardedCache {
        let n = shards.max(1).next_power_of_two();
        let shards: Vec<RwLock<HashMap<Box<[u32]>, CacheValue>>> =
            (0..n).map(|_| RwLock::new(HashMap::new())).collect();
        ShardedCache {
            shards: shards.into_boxed_slice(),
            mask: n - 1,
        }
    }

    fn shard_of(&self, cfg: &[u32]) -> usize {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        cfg.hash(&mut h);
        (h.finish() as usize) & self.mask
    }

    /// Look up a configuration (lock-sharded read).
    pub fn get(&self, cfg: &[u32]) -> Option<CacheValue> {
        self.shards[self.shard_of(cfg)]
            .read()
            .expect("cache shard poisoned")
            .get(cfg)
            .copied()
    }

    /// Insert (or overwrite) a configuration's evaluation.
    pub fn insert(&self, cfg: Box<[u32]>, value: CacheValue) {
        self.shards[self.shard_of(&cfg)]
            .write()
            .expect("cache shard poisoned")
            .insert(cfg, value);
    }

    /// Total entries across all shards.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().expect("cache shard poisoned").len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every entry.
    pub fn clear(&self) {
        for s in self.shards.iter() {
            s.write().expect("cache shard poisoned").clear();
        }
    }

    /// Number of shards (always a power of two).
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Every entry across all shards, sorted by key — the persistent
    /// store's export path. Sorting makes snapshots byte-deterministic
    /// regardless of shard layout and insertion order.
    pub fn dump(&self) -> Vec<(Box<[u32]>, CacheValue)> {
        let mut out: Vec<(Box<[u32]>, CacheValue)> = Vec::with_capacity(self.len());
        for s in self.shards.iter() {
            let g = s.read().expect("cache shard poisoned");
            out.extend(g.iter().map(|(k, v)| (k.clone(), *v)));
        }
        out.sort();
        out
    }
}

// ---------------------------------------------------------------------------
// Persistent worker pool
// ---------------------------------------------------------------------------

struct Job {
    idx: usize,
    cfg: Box<[u32]>,
    /// Latency-only early exit: stop at the first deadlocked scenario.
    early: bool,
    /// Cooperative cancellation: the worker checks the token *before*
    /// it starts simulating — a triggered token turns the job into an
    /// immediate `aborted` reply instead of a simulation, so a large
    /// batch drains its queues in microseconds once a deadline passes.
    cancel: Option<CancelToken>,
}

struct JobDone {
    idx: usize,
    latency: Option<u64>,
    simulated: bool,
    aborted: bool,
    nanos: u64,
    run: RunInfo,
    gap: Option<u64>,
    scen_runs: u32,
}

/// Result of one pool job, in submission order.
#[derive(Debug, Clone, Copy, Default)]
pub struct JobOutcome {
    /// Simulated latency (`None` = deadlock).
    pub latency: Option<u64>,
    /// False when the shared memo cache already held the result.
    pub simulated: bool,
    /// True when the job's cancellation token had triggered before the
    /// worker started it — the job was skipped, `latency` is
    /// meaningless, and the caller must discard the whole batch.
    pub aborted: bool,
    /// Wall time this job occupied its worker.
    pub nanos: u64,
    /// Simulator telemetry for this job (zeroed for cache hits).
    pub run: RunInfo,
    /// Worst − best per-scenario latency (the robustness gap; `None`
    /// for cache hits, deadlocks, and single-scenario workloads report 0).
    pub gap: Option<u64>,
    /// Scenario members actually simulated (may be < the workload's
    /// scenario count when the early-exit path stopped at a deadlock;
    /// 0 for cache hits).
    pub scen_runs: u32,
}

/// Number of differing positions between two configurations; mismatched
/// lengths count as maximally distant.
fn hamming(a: &[u32], b: &[u32]) -> u64 {
    if a.len() != b.len() {
        return u64::MAX - 1;
    }
    a.iter().zip(b).filter(|(x, y)| x != y).count() as u64
}

/// A pool of simulation workers that outlives any single batch. Each
/// worker owns a cloned [`ScenarioSim`] bank (the traces themselves are
/// shared through `Arc`s, so a clone duplicates only per-scenario
/// scratch) and, optionally, a handle to the engine's [`ShardedCache`]
/// which it consults before simulating — so configurations evaluated
/// concurrently by another client of the same cache are not re-simulated.
///
/// Every worker has its own queue, and the dispatcher tracks the last
/// configuration sent to each worker — the schedule its `FastSim` will
/// have retained once the queue drains. [`run_with_hints`](Self::run_with_hints)
/// routes each job to the worker whose tracked schedule is
/// Hamming-closest to the job's locality hint, capped at ⌈batch/jobs⌉
/// jobs per worker so locality never starves parallelism. Results are
/// reassembled in submission order, and the simulator itself guarantees
/// delta replays are bit-identical to cold ones, so dispatch choices can
/// never change results — only how much work each one costs.
pub struct WorkerPool {
    jobs: usize,
    task_tx: Vec<mpsc::Sender<Job>>,
    result_rx: mpsc::Receiver<JobDone>,
    handles: Vec<thread::JoinHandle<()>>,
    /// Last configuration dispatched to each worker.
    last_cfg: Vec<Option<Box<[u32]>>>,
    /// Per-batch assignment-count scratch.
    assigned: Vec<usize>,
}

impl WorkerPool {
    /// Spawn `jobs` workers, each with its own clone of `proto`.
    pub fn new(proto: &ScenarioSim, jobs: usize, cache: Option<Arc<ShardedCache>>) -> WorkerPool {
        let jobs = jobs.max(1);
        let (result_tx, result_rx) = mpsc::channel::<JobDone>();
        let mut handles = Vec::with_capacity(jobs);
        let mut task_tx = Vec::with_capacity(jobs);
        for _ in 0..jobs {
            let (tx, rx) = mpsc::channel::<Job>();
            let mut sim = proto.clone();
            let res = result_tx.clone();
            let cache = cache.clone();
            handles.push(thread::spawn(move || {
                while let Ok(job) = rx.recv() {
                    let t0 = Instant::now();
                    // Per-job cancellation check: once the batch's token
                    // triggers (explicit cancel or wall-clock deadline),
                    // every job still queued is answered `aborted`
                    // without touching the simulator. The sim-count leg
                    // of the budget stays with the engine, which owns
                    // the counters.
                    if job
                        .cancel
                        .as_ref()
                        .is_some_and(|c| c.cancelled() || c.deadline_exceeded())
                    {
                        if res
                            .send(JobDone {
                                idx: job.idx,
                                latency: None,
                                simulated: false,
                                aborted: true,
                                nanos: 0,
                                run: RunInfo::default(),
                                gap: None,
                                scen_runs: 0,
                            })
                            .is_err()
                        {
                            break;
                        }
                        continue;
                    }
                    let (latency, simulated, run, gap, scen_runs) =
                        match cache.as_ref().and_then(|c| c.get(&job.cfg)) {
                            Some((lat, _)) => (lat, false, RunInfo::default(), None, 0),
                            None => {
                                let lat = sim.eval_latency(&job.cfg, job.early);
                                (
                                    lat,
                                    true,
                                    sim.last_run(),
                                    sim.last_gap(),
                                    sim.last_scenarios_run(),
                                )
                            }
                        };
                    let nanos = t0.elapsed().as_nanos() as u64;
                    if res
                        .send(JobDone {
                            idx: job.idx,
                            latency,
                            simulated,
                            aborted: false,
                            nanos,
                            run,
                            gap,
                            scen_runs,
                        })
                        .is_err()
                    {
                        break;
                    }
                }
            }));
            task_tx.push(tx);
        }
        WorkerPool {
            jobs,
            task_tx,
            result_rx,
            handles,
            last_cfg: vec![None; jobs],
            assigned: vec![0; jobs],
        }
    }

    /// Number of worker threads.
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Evaluate every configuration, returning outcomes in input order.
    /// The calling thread blocks until the whole batch is done.
    pub fn run(&mut self, configs: &[Box<[u32]>]) -> Vec<JobOutcome> {
        self.run_batch(configs, None, false)
    }

    /// [`run`](Self::run) with per-job locality hints: `hints[k]`, when
    /// present, is the configuration job `k` was derived from; the job is
    /// dispatched to the worker whose retained schedule is closest to it
    /// (falling back to the configuration itself as its own hint).
    pub fn run_with_hints(
        &mut self,
        configs: &[Box<[u32]>],
        hints: Option<&[Option<Box<[u32]>>]>,
    ) -> Vec<JobOutcome> {
        self.run_batch(configs, hints, false)
    }

    /// [`run_with_hints`](Self::run_with_hints) with the latency-only
    /// early-exit flag: with `early_exit` set, multi-scenario workers
    /// stop at the first deadlocked scenario
    /// ([`ScenarioSim::eval_latency`]). Verdicts and latencies are
    /// identical either way — only the per-scenario replay count
    /// changes.
    pub fn run_batch(
        &mut self,
        configs: &[Box<[u32]>],
        hints: Option<&[Option<Box<[u32]>>]>,
        early_exit: bool,
    ) -> Vec<JobOutcome> {
        self.run_batch_cancellable(configs, hints, early_exit, None)
    }

    /// [`run_batch`](Self::run_batch) with a cancellation token each
    /// worker checks before starting a job: once the token's explicit
    /// cancel or wall-clock deadline triggers, the rest of the batch
    /// comes back with [`JobOutcome::aborted`] set instead of being
    /// simulated. A batch whose token never triggers is dispatched and
    /// evaluated exactly like an uncancellable one.
    pub fn run_batch_cancellable(
        &mut self,
        configs: &[Box<[u32]>],
        hints: Option<&[Option<Box<[u32]>>]>,
        early_exit: bool,
        cancel: Option<&CancelToken>,
    ) -> Vec<JobOutcome> {
        let n = configs.len();
        if n == 0 {
            return Vec::new();
        }
        // Sticky, balanced dispatch (deterministic: ties break to the
        // lowest worker index; cold workers are chosen last).
        let cap = n.div_ceil(self.jobs);
        for a in &mut self.assigned {
            *a = 0;
        }
        for (idx, cfg) in configs.iter().enumerate() {
            let target: &[u32] = hints
                .and_then(|h| h.get(idx))
                .and_then(|h| h.as_deref())
                .unwrap_or(cfg.as_ref());
            let mut best = usize::MAX;
            let mut best_d = u64::MAX;
            for w in 0..self.jobs {
                if self.assigned[w] >= cap {
                    continue;
                }
                let d = match &self.last_cfg[w] {
                    Some(prev) => hamming(prev, target),
                    None => u64::MAX - 1,
                };
                if best == usize::MAX || d < best_d {
                    best = w;
                    best_d = d;
                }
            }
            debug_assert!(best != usize::MAX, "cap must leave a worker available");
            self.assigned[best] += 1;
            // Dispatch-time tracking is an approximation on two counts:
            // a worker that answers a job from the shared cache keeps its
            // older retained schedule, and the count cap balances job
            // counts, not job costs. Both only affect how much a delta
            // saves, never what it computes; the common engine path
            // pre-filters cache hits, so the tracking is exact there.
            self.last_cfg[best] = Some(cfg.clone());
            self.task_tx[best]
                .send(Job {
                    idx,
                    cfg: cfg.clone(),
                    early: early_exit,
                    cancel: cancel.cloned(),
                })
                .expect("worker pool channel closed");
        }
        let mut out = vec![JobOutcome::default(); n];
        for _ in 0..n {
            let done = self
                .result_rx
                .recv()
                .expect("a simulation worker died (panic in FastSim?)");
            out[done.idx] = JobOutcome {
                latency: done.latency,
                simulated: done.simulated,
                aborted: done.aborted,
                nanos: done.nanos,
                run: done.run,
                gap: done.gap,
                scen_runs: done.scen_runs,
            };
        }
        out
    }

    /// Latency-only convenience used by the [`super::pool`] shim.
    pub fn run_latencies(&mut self, configs: &[Box<[u32]>]) -> Vec<Option<u64>> {
        self.run(configs).into_iter().map(|o| o.latency).collect()
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // Closing the task channels wakes every worker out of `recv`.
        self.task_tx.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Engine statistics
// ---------------------------------------------------------------------------

/// Counters the report layer exposes (cache hit rate, sims/sec, worker
/// utilization). Reset by [`EvalEngine::reset_run`].
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    /// Configurations proposed (history entries; cache hits included).
    pub proposals: u64,
    /// Proposals served from the memo cache (in-batch duplicates count).
    pub cache_hits: u64,
    /// Simulator invocations this run (unlike [`EvalEngine::n_sim`],
    /// reset by every [`EvalEngine::reset_run`] — so rate/utilization
    /// figures stay consistent across warm-cache resets).
    pub sims: u64,
    /// Batches evaluated through the engine.
    pub batches: u64,
    /// Total wall time jobs occupied simulation workers (or the inline
    /// serial path).
    pub busy_nanos: u64,
    /// Simulations served by delta-incremental replay (subset of
    /// [`sims`](Self::sims)).
    pub incr_sims: u64,
    /// Total dirty channels across incremental simulations.
    pub dirty_channels: u64,
    /// Trace ops actually re-propagated across all simulations.
    pub replayed_ops: u64,
    /// Trace ops the same simulations would have propagated as full
    /// replays (sims × trace ops).
    pub replayable_ops: u64,
    /// Per-scenario simulator invocations actually run. Without pruning
    /// every workload simulation runs every scenario
    /// (`sims × num_scenarios`); the pruned early-exit path may stop at
    /// the first deadlocked scenario and run fewer.
    pub scenario_sims: u64,
    /// Sum of the robustness gap (worst − best per-scenario latency)
    /// over feasible simulations.
    pub robust_gap_sum: u64,
    /// Feasible simulations contributing to
    /// [`robust_gap_sum`](Self::robust_gap_sum).
    pub robust_points: u64,
    /// Proposals answered `Deadlock` by the dominance oracle — no memo
    /// entry existed and no simulation ran.
    pub oracle_hits: u64,
    /// Proposals whose depth vector was occupancy-clamped onto a
    /// canonical memo point (evaluated at the canonical key; BRAM still
    /// from the actual depths).
    pub clamp_hits: u64,
    /// Simulations avoided outright: oracle answers plus clamped
    /// proposals served from an existing canonical evaluation instead of
    /// a fresh simulation of their own.
    pub sims_avoided: u64,
    /// Proposals answered `Deadlock` by the analytic depth-floor
    /// short-circuit — a subset of [`oracle_hits`](Self::oracle_hits)
    /// (counted into both so the accounting invariant is unchanged).
    pub bounds_floor_hits: u64,
    /// Channels whose clamp cap the analytic bounds pass tightened below
    /// the PR 4 write count (static per workload; 0 with bounds off).
    pub cap_tightenings: u64,
    /// Lane-batched SoA graph walks executed (one per scenario member
    /// with live lanes, per miss batch) — nonzero only under the
    /// batched backend.
    pub batch_walks: u64,
    /// Depth-vector lanes packed into those walks.
    pub lanes_packed: u64,
    /// Lane capacity of those walks (walks × batch width) — the
    /// occupancy denominator.
    pub lane_slots: u64,
}

impl EngineStats {
    /// Fraction of proposals answered from the memo cache.
    pub fn hit_rate(&self) -> f64 {
        if self.proposals == 0 {
            0.0
        } else {
            self.cache_hits as f64 / self.proposals as f64
        }
    }

    /// Fraction of simulations served as delta replays.
    pub fn incremental_rate(&self) -> f64 {
        if self.sims == 0 {
            0.0
        } else {
            self.incr_sims as f64 / self.sims as f64
        }
    }

    /// Mean dirty channels per incremental simulation.
    pub fn dirty_per_incremental(&self) -> f64 {
        if self.incr_sims == 0 {
            0.0
        } else {
            self.dirty_channels as f64 / self.incr_sims as f64
        }
    }

    /// Fraction of trace ops actually re-propagated (1.0 = every
    /// simulation was a full replay).
    pub fn replay_fraction(&self) -> f64 {
        if self.replayable_ops == 0 {
            1.0
        } else {
            self.replayed_ops as f64 / self.replayable_ops as f64
        }
    }

    /// Mean robustness gap (worst − best per-scenario latency) over
    /// feasible simulations. Always 0 for single-scenario workloads.
    pub fn mean_robustness_gap(&self) -> f64 {
        if self.robust_points == 0 {
            0.0
        } else {
            self.robust_gap_sum as f64 / self.robust_points as f64
        }
    }

    /// Fraction of proposals answered by the dominance oracle.
    pub fn oracle_rate(&self) -> f64 {
        if self.proposals == 0 {
            0.0
        } else {
            self.oracle_hits as f64 / self.proposals as f64
        }
    }

    /// Fraction of proposals evaluated at a clamp-canonicalized point.
    pub fn clamp_rate(&self) -> f64 {
        if self.proposals == 0 {
            0.0
        } else {
            self.clamp_hits as f64 / self.proposals as f64
        }
    }

    /// Mean depth-vector lanes answered per lane-batched graph walk
    /// (0 when the batched backend never ran).
    pub fn lanes_per_walk(&self) -> f64 {
        if self.batch_walks == 0 {
            0.0
        } else {
            self.lanes_packed as f64 / self.batch_walks as f64
        }
    }

    /// Fraction of lane capacity actually occupied across all batched
    /// walks (< 1.0 when scenario early exit dropped deadlocked lanes).
    pub fn batch_occupancy(&self) -> f64 {
        if self.lane_slots == 0 {
            0.0
        } else {
            self.lanes_packed as f64 / self.lane_slots as f64
        }
    }

    /// Graph traversals saved by lane packing vs evaluating each lane
    /// with its own walk.
    pub fn walks_saved(&self) -> u64 {
        self.lanes_packed.saturating_sub(self.batch_walks)
    }

    /// Fold one simulator run's telemetry into the counters.
    /// `scenarios_run` is the number of scenario members the call
    /// actually simulated.
    fn note_run(&mut self, run: &RunInfo, scenarios_run: u32, gap: Option<u64>) {
        if run.incremental {
            self.incr_sims += 1;
            self.dirty_channels += run.dirty_channels as u64;
        }
        self.replayed_ops += run.replayed_ops;
        self.replayable_ops += run.total_ops;
        self.scenario_sims += scenarios_run as u64;
        if let Some(g) = gap {
            self.robust_gap_sum += g;
            self.robust_points += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Evaluation results handed to optimizers
// ---------------------------------------------------------------------------

/// One evaluated proposal, as delivered to [`Optimizer::tell`].
#[derive(Debug, Clone)]
pub struct EvalResult {
    pub depths: Box<[u32]>,
    /// `None` means the configuration deadlocks.
    pub latency: Option<u64>,
    pub bram: u32,
    /// Per-channel occupancy/stall statistics — present only when the
    /// optimizer requested a stats evaluation
    /// ([`Optimizer::wants_stats`]).
    pub stats: Option<ChannelStats>,
    /// Processes stuck at deadlock — populated only on stats
    /// evaluations of deadlocking configurations.
    pub blocked: Vec<BlockInfo>,
}

impl EvalResult {
    pub fn is_feasible(&self) -> bool {
        self.latency.is_some()
    }
}

// ---------------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------------

/// One exported memo-cache entry — `(depths, latency, bram)` with `None`
/// latency meaning deadlock. The persistent store's unit of exchange.
pub type MemoEntry = (Vec<u32>, Option<u64>, u32);

/// One exported dominance-oracle outcome — `(depths, latency)`.
pub type OracleEntry = (Vec<u32>, Option<u64>);

/// The black-box evaluator `x → (f_lat(x), f_bram(x))` (paper §III) with
/// the persistent worker pool and sharded memo cache. Construct once per
/// (design, workload); drive optimizers through [`drive`] or call the
/// evaluation methods directly. Single-trace constructors wrap the trace
/// in a [`Workload::single`].
pub struct EvalEngine {
    sim: ScenarioSim,
    workload: Arc<Workload>,
    pub widths: Vec<u32>,
    cache: Arc<ShardedCache>,
    pool: Option<WorkerPool>,
    backend: Box<dyn BramBatch>,
    /// Every proposal in order (cache hits included — the optimizer
    /// budget counts proposals, as in the paper's fixed 1000 samples).
    pub history: Vec<EvalPoint>,
    /// Number of actual simulator invocations (cache misses).
    pub n_sim: u64,
    jobs: usize,
    stats: EngineStats,
    start: Instant,
    /// Master switch for the simulation-free pruning layer (oracle,
    /// clamp canonicalization, scenario early exit). On by default;
    /// `--no-prune` / sweep `"prune": false` turn it off for A/B runs.
    prune: bool,
    /// Master switch for the analytic depth-bounds layer (floor
    /// short-circuit, oracle seeding, tightened clamp caps). On by
    /// default; `--no-bounds` / sweep `"bounds": false` turn it off for
    /// A/B runs. Independent of [`prune`](Self::prune).
    bounds: bool,
    /// The once-per-workload analytic bounds ([`DepthBounds`]).
    depth_bounds: DepthBounds,
    /// Which simulation backend the bank (and every pool worker's clone
    /// of it) runs — the CLI's `--backend {fast,compiled,batched}`.
    sim_backend: BackendKind,
    canon: Canonicalizer,
    oracle: FeasibilityOracle,
    /// Per-scenario latencies memoized by canonical key — the
    /// [`Self::per_scenario_latencies`] diagnostic path, so repeated
    /// frontier-table rendering does not pay full scenario replays.
    scenario_memo: HashMap<Box<[u32]>, Box<[Option<u64>]>>,
    /// Cooperative cancellation handle: [`drive`] checks it once per
    /// ask/tell round against this run's sim count (wall-clock deadline,
    /// sim budget, or an orchestrator's explicit cancel). The default
    /// token never triggers.
    cancel: CancelToken,
    /// Set by [`drive`] when the last run stopped early because the
    /// token triggered — history/front are best-so-far, not
    /// budget-complete. Cleared by [`Self::reset_run`].
    truncated: bool,
}

impl EvalEngine {
    /// Engine with the native BRAM backend and serial simulation.
    pub fn new(trace: Arc<Trace>) -> EvalEngine {
        Self::with_backend(trace, Box::new(NativeBram), 1)
    }

    /// Engine with `jobs` persistent simulation workers.
    pub fn parallel(trace: Arc<Trace>, jobs: usize) -> EvalEngine {
        Self::with_backend(trace, Box::new(NativeBram), jobs)
    }

    /// Full control: custom BRAM backend (e.g. the analytics artifact) +
    /// parallelism.
    pub fn with_backend(trace: Arc<Trace>, backend: Box<dyn BramBatch>, jobs: usize) -> EvalEngine {
        Self::for_workload_with_backend(Arc::new(Workload::single(trace)), backend, jobs)
    }

    /// Engine over a multi-trace [`Workload`] with the native BRAM
    /// backend and `jobs` workers.
    pub fn for_workload(workload: Arc<Workload>, jobs: usize) -> EvalEngine {
        Self::for_workload_with_backend(workload, Box::new(NativeBram), jobs)
    }

    /// Workload engine with a custom BRAM backend.
    pub fn for_workload_with_backend(
        workload: Arc<Workload>,
        backend: Box<dyn BramBatch>,
        jobs: usize,
    ) -> EvalEngine {
        Self::for_workload_full(workload, backend, jobs, BackendKind::Fast)
    }

    /// Workload engine with the native BRAM backend and an explicit
    /// simulation backend (`--backend {fast,compiled,batched}`).
    pub fn for_workload_with_sim(
        workload: Arc<Workload>,
        jobs: usize,
        sim_backend: BackendKind,
    ) -> EvalEngine {
        Self::for_workload_full(workload, Box::new(NativeBram), jobs, sim_backend)
    }

    /// Full control: workload, BRAM backend, worker count, and the
    /// simulation backend every worker's [`ScenarioSim`] bank runs. The
    /// memo/oracle/clamp layers are backend-agnostic, so everything above
    /// the bank behaves identically whichever backend is selected.
    pub fn for_workload_full(
        workload: Arc<Workload>,
        backend: Box<dyn BramBatch>,
        jobs: usize,
        sim_backend: BackendKind,
    ) -> EvalEngine {
        let sim = ScenarioSim::with_backend(&workload, SimOptions::default(), sim_backend);
        Self::for_workload_with_bank(workload, backend, jobs, sim, sim_backend)
    }

    /// Engine over a pre-built scenario bank — the sweep orchestrator's
    /// cross-cell reuse path: cells sharing a design clone one prototype
    /// bank, so compiled/batched event-graph tables stay `Arc`-shared
    /// across cells instead of being recompiled per cell. `sim` must
    /// have been built from `workload` with backend `sim_backend`; a
    /// pristine clone is indistinguishable from a fresh bank, so results
    /// are identical either way.
    pub fn for_workload_with_bank(
        workload: Arc<Workload>,
        backend: Box<dyn BramBatch>,
        jobs: usize,
        sim: ScenarioSim,
        sim_backend: BackendKind,
    ) -> EvalEngine {
        let widths: Vec<u32> = workload
            .primary()
            .channels
            .iter()
            .map(|c| c.width_bits)
            .collect();
        let jobs = jobs.max(1);
        let cache = Arc::new(ShardedCache::new((jobs * 4).clamp(4, 64)));
        // Under the lane-batched backend the whole miss batch rides one
        // SoA walk per scenario — lane packing replaces sticky worker
        // dispatch, so no pool is spun up and serial vs `--jobs N`
        // identity is trivial (same code path).
        let pool = if jobs > 1 && sim_backend != BackendKind::Batched {
            Some(WorkerPool::new(&sim, jobs, Some(Arc::clone(&cache))))
        } else {
            None
        };
        // The analytic bounds pass (once per workload): tightened clamp
        // caps feed the canonicalizer, the deadlock floors seed the
        // oracle and back the sub-floor short-circuit.
        let depth_bounds = DepthBounds::for_workload(&workload);
        let canon = Canonicalizer::new(depth_bounds.caps.clone(), &widths);
        let oracle = FeasibilityOracle::for_workload(&workload);
        let mut engine = EvalEngine {
            sim,
            workload,
            widths,
            cache,
            pool,
            backend,
            history: Vec::new(),
            n_sim: 0,
            jobs,
            stats: EngineStats::default(),
            start: Instant::now(),
            prune: true,
            bounds: true,
            depth_bounds,
            sim_backend,
            canon,
            oracle,
            scenario_memo: HashMap::new(),
            cancel: CancelToken::new(),
            truncated: false,
        };
        engine.stats.cap_tightenings = engine.depth_bounds.num_cap_tightenings() as u64;
        engine.seed_oracle_from_bounds();
        engine
    }

    /// Seed the oracle's infeasible antichain with the one-below-floor
    /// frontier: for every channel with a non-trivial analytic floor,
    /// the configuration at `floor − 1` with every sibling fully relaxed
    /// is a proven deadlock (the floor holds for *any* sibling depths),
    /// so everything below the floor is dominated. No-op with bounds
    /// off.
    fn seed_oracle_from_bounds(&mut self) {
        if !self.bounds {
            return;
        }
        let wcaps: Vec<u32> = self
            .depth_bounds
            .write_caps()
            .iter()
            .map(|&w| w.max(2))
            .collect();
        for (ch, &f) in self.depth_bounds.floors.iter().enumerate() {
            if f > 2 {
                let mut v = wcaps.clone();
                v[ch] = f - 1;
                self.oracle.note(&v, None);
            }
        }
    }

    /// The simulation backend the engine's bank (and workers) run.
    pub fn sim_backend(&self) -> BackendKind {
        self.sim_backend
    }

    /// The workload being optimized.
    pub fn workload(&self) -> &Arc<Workload> {
        &self.workload
    }

    /// The primary (first-scenario) trace.
    pub fn trace(&self) -> &Arc<Trace> {
        self.workload.primary()
    }

    /// Scenarios per simulation (1 = single-trace engine).
    pub fn num_scenarios(&self) -> usize {
        self.sim.num_scenarios()
    }

    /// Scenario names, in workload order.
    pub fn scenario_names(&self) -> &[String] {
        self.sim.names()
    }

    /// Per-scenario latencies of one configuration — a diagnostic that
    /// is *not* recorded in history or stats (use it for per-scenario
    /// report columns after a run). Results are memoized by
    /// clamp-canonical key, so repeated frontier-table rendering does
    /// not pay full scenario replays; the underlying run uses the full
    /// [`ScenarioSim::simulate`] path (every scenario, no early exit).
    pub fn per_scenario_latencies(&mut self, depths: &[u32]) -> Vec<(String, Option<u64>)> {
        let key: Box<[u32]> = match self.prune.then(|| self.canon.canonical(depths)).flatten() {
            Some(c) => c,
            None => depths.into(),
        };
        if !self.scenario_memo.contains_key(&key) {
            let _ = self.sim.simulate(&key);
            self.scenario_memo
                .insert(key.clone(), self.sim.scenario_latencies().into());
        }
        let lats = &self.scenario_memo[&key];
        self.sim
            .names()
            .iter()
            .cloned()
            .zip(lats.iter().copied())
            .collect()
    }

    /// Enable/disable the simulation-free pruning layer (on by default).
    /// Pruning never changes results — histories and fronts are
    /// bit-identical either way — only how many simulations they cost.
    pub fn set_prune(&mut self, on: bool) {
        self.prune = on;
    }

    /// Whether the pruning layer is active.
    pub fn prune(&self) -> bool {
        self.prune
    }

    /// Enable/disable the analytic depth-bounds layer (on by default).
    /// Like pruning, bounds never change results — only how many
    /// simulations they cost. Disabling rebuilds the canonicalizer on
    /// the raw write-count caps and forgets the oracle's floor seeds
    /// (along with anything else it learned); re-enabling restores the
    /// tightened caps and re-seeds.
    pub fn set_bounds(&mut self, on: bool) {
        if on == self.bounds {
            return;
        }
        self.bounds = on;
        let caps = if on {
            self.depth_bounds.caps.clone()
        } else {
            self.depth_bounds.write_caps().to_vec()
        };
        self.canon = Canonicalizer::new(caps, &self.widths);
        self.oracle.clear();
        self.stats.cap_tightenings = if on {
            self.depth_bounds.num_cap_tightenings() as u64
        } else {
            0
        };
        self.seed_oracle_from_bounds();
    }

    /// Whether the analytic depth-bounds layer is active.
    pub fn bounds(&self) -> bool {
        self.bounds
    }

    /// Replace the engine's analytic depth bounds wholesale — used by the
    /// distillation loop ([`super::advhunt`]) so an engine evaluating a
    /// *subset* of the workload's scenarios still clamps, floors and
    /// oracle-seeds exactly like the full-bank engine (a prerequisite for
    /// bit-identical distilled vs full histories). Rebuilds the
    /// canonicalizer on the new caps and re-derives the oracle's floor
    /// seeds from scratch.
    pub fn set_depth_bounds(&mut self, bounds: DepthBounds) {
        self.depth_bounds = bounds;
        let caps = if self.bounds {
            self.depth_bounds.caps.clone()
        } else {
            self.depth_bounds.write_caps().to_vec()
        };
        self.canon = Canonicalizer::new(caps, &self.widths);
        self.oracle.clear();
        self.scenario_memo.clear();
        self.stats.cap_tightenings = if self.bounds {
            self.depth_bounds.num_cap_tightenings() as u64
        } else {
            0
        };
        self.seed_oracle_from_bounds();
    }

    /// Feed the pruning oracle an outcome evaluated *elsewhere* (e.g. by
    /// the full-bank stats engine while this engine runs the distilled
    /// bank). Keeps the two engines' oracle knowledge in lockstep so
    /// subsequent answers cannot diverge. No-op with pruning off; the
    /// outcome is not recorded in history or stats.
    pub fn note_external(&mut self, depths: &[u32], latency: Option<u64>) {
        if self.prune {
            self.oracle.note(depths, latency);
        }
    }

    /// The analytic per-channel depth bounds of this workload
    /// (computed once at construction; valid whether or not the layer
    /// is [active](Self::bounds)).
    pub fn depth_bounds(&self) -> &DepthBounds {
        &self.depth_bounds
    }

    /// The dominance oracle's current knowledge (diagnostics/tests).
    pub fn oracle(&self) -> &FeasibilityOracle {
        &self.oracle
    }

    /// The occupancy-clamp canonicalizer in use (diagnostics/tests).
    pub fn canonicalizer(&self) -> &Canonicalizer {
        &self.canon
    }

    /// Name of the BRAM backend in use.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Worker count (1 = serial inline evaluation).
    pub fn jobs(&self) -> usize {
        self.jobs
    }

    /// Engine counters for the report layer.
    pub fn stats(&self) -> &EngineStats {
        self.stats_ref()
    }

    fn stats_ref(&self) -> &EngineStats {
        &self.stats
    }

    /// True simulator invocations per wall-clock second since the run
    /// started — memo, oracle, and clamp answers are **not** counted
    /// (they cost no simulation); see
    /// [`proposals_per_sec`](Self::proposals_per_sec) for the answer
    /// rate the optimizer observes.
    pub fn sims_per_sec(&self) -> f64 {
        self.stats.sims as f64 / self.elapsed().max(1e-9)
    }

    /// Proposals answered per wall-clock second (simulated, memoized,
    /// oracle- and clamp-served alike).
    pub fn proposals_per_sec(&self) -> f64 {
        self.stats.proposals as f64 / self.elapsed().max(1e-9)
    }

    /// Fraction of total worker capacity spent simulating.
    pub fn worker_utilization(&self) -> f64 {
        let busy = self.stats.busy_nanos as f64 / 1e9;
        (busy / (self.elapsed().max(1e-9) * self.jobs as f64)).min(1.0)
    }

    /// Entries currently memoized.
    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    /// Shard count of the memo cache.
    pub fn cache_shards(&self) -> usize {
        self.cache.num_shards()
    }

    /// Preferred proposal batch size for `ask` (enough to keep every
    /// worker busy several times over without starving `tell` feedback).
    pub fn batch_hint(&self) -> usize {
        if self.jobs <= 1 {
            64
        } else {
            (self.jobs * 32).clamp(64, 512)
        }
    }

    /// Reset history and the start-of-run clock (keep the memo cache and
    /// the oracle's learned dominance knowledge — incremental reuse
    /// across optimizers is part of the design; pass `clear_cache` to
    /// measure cold-start behaviour, which also forgets the oracle and
    /// the per-scenario memo).
    pub fn reset_run(&mut self, clear_cache: bool) {
        self.history.clear();
        self.stats = EngineStats::default();
        if self.bounds {
            self.stats.cap_tightenings = self.depth_bounds.num_cap_tightenings() as u64;
        }
        self.truncated = false;
        if clear_cache {
            self.cache.clear();
            self.oracle.clear();
            self.scenario_memo.clear();
            self.n_sim = 0;
            self.seed_oracle_from_bounds();
        }
        self.start = Instant::now();
    }

    /// Install a cancellation token; [`drive`] checks it per ask/tell
    /// round. [`Self::reset_run`] keeps the token (budgets usually span
    /// one cell's whole run), so install a fresh one per run.
    pub fn set_cancel_token(&mut self, token: CancelToken) {
        self.cancel = token;
    }

    /// The active cancellation token (clone it to cancel from another
    /// thread).
    pub fn cancel_token(&self) -> &CancelToken {
        &self.cancel
    }

    /// Has the token triggered given this run's simulation count?
    pub fn cancel_triggered(&self) -> bool {
        self.cancel.triggered(self.stats.sims)
    }

    /// True when the last [`drive`] run stopped early on the
    /// cancellation token — the history/front is best-so-far rather than
    /// budget-complete (surfaced as `"truncated"` in run JSON).
    pub fn truncated(&self) -> bool {
        self.truncated
    }

    /// Seconds since engine creation / last [`Self::reset_run`].
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Number of proposals so far (the budget meter).
    pub fn n_evals(&self) -> usize {
        self.history.len()
    }

    /// Simulate one canonical configuration inline, updating the
    /// counters and learning the result. Returns its latency.
    fn simulate_miss(&mut self, cfg: &[u32]) -> Option<u64> {
        let early = self.prune && self.sim.num_scenarios() > 1;
        let t0 = Instant::now();
        let lat = self.sim.eval_latency(cfg, early);
        self.stats.busy_nanos += t0.elapsed().as_nanos() as u64;
        let run = self.sim.last_run();
        let gap = self.sim.last_gap();
        let scen = self.sim.last_scenarios_run();
        self.stats.note_run(&run, scen, gap);
        self.n_sim += 1;
        self.stats.sims += 1;
        if self.prune {
            self.oracle.note(cfg, lat);
        }
        lat
    }

    /// Evaluate one configuration (memoized), recording it in history.
    pub fn eval(&mut self, depths: &[u32]) -> (Option<u64>, u32) {
        let key: Box<[u32]> = depths.into();
        let (lat, br) = match self.cache.get(depths) {
            Some(v) => {
                self.stats.cache_hits += 1;
                v
            }
            None => {
                if self.bounds && self.depth_bounds.below_floor(depths) {
                    // Below an analytic deadlock floor: provably
                    // infeasible whatever the sibling depths, no
                    // simulation (and no oracle query needed).
                    self.stats.oracle_hits += 1;
                    self.stats.bounds_floor_hits += 1;
                    self.stats.sims_avoided += 1;
                    let br = bram::bram_total(depths, &self.widths);
                    self.cache.insert(key.clone(), (None, br));
                    (None, br)
                } else if self.prune && self.oracle.is_dominated_infeasible(depths) {
                    // Dominated by a known deadlock: no simulation.
                    self.stats.oracle_hits += 1;
                    self.stats.sims_avoided += 1;
                    let br = bram::bram_total(depths, &self.widths);
                    self.cache.insert(key.clone(), (None, br));
                    (None, br)
                } else if let Some(canon) =
                    self.prune.then(|| self.canon.canonical(depths)).flatten()
                {
                    // Occupancy-clamped: evaluate at the canonical point,
                    // BRAM from the actual depths.
                    self.stats.clamp_hits += 1;
                    let lat = match self.cache.get(&canon) {
                        Some((lat, _)) => {
                            self.stats.cache_hits += 1;
                            self.stats.sims_avoided += 1;
                            lat
                        }
                        None => {
                            let lat = self.simulate_miss(&canon);
                            let cbr = bram::bram_total(&canon, &self.widths);
                            self.cache.insert(canon, (lat, cbr));
                            lat
                        }
                    };
                    let br = bram::bram_total(depths, &self.widths);
                    self.cache.insert(key.clone(), (lat, br));
                    (lat, br)
                } else {
                    let lat = self.simulate_miss(depths);
                    let br = bram::bram_total(depths, &self.widths);
                    self.cache.insert(key.clone(), (lat, br));
                    (lat, br)
                }
            }
        };
        self.stats.proposals += 1;
        self.history.push(EvalPoint {
            depths: key,
            latency: lat,
            bram: br,
            t: self.elapsed(),
        });
        (lat, br)
    }

    /// Evaluate a batch through the full pipeline: in-batch dedup, memo
    /// lookup, parallel simulation of the misses on the worker pool, and
    /// one batched backend call for the BRAM totals.
    pub fn eval_batch(&mut self, configs: &[Box<[u32]>]) -> Vec<(Option<u64>, u32)> {
        self.eval_results(configs, false)
            .into_iter()
            .map(|r| (r.latency, r.bram))
            .collect()
    }

    /// The ask/tell evaluation path. With `want_stats` the batch is
    /// evaluated serially with per-channel statistics and deadlock block
    /// info (the greedy ranking / targeted hunter path); otherwise the
    /// batched pool path is used.
    pub fn eval_results(&mut self, configs: &[Box<[u32]>], want_stats: bool) -> Vec<EvalResult> {
        self.eval_results_hinted(configs, &[], want_stats)
    }

    /// [`eval_results`](Self::eval_results) with per-proposal locality
    /// hints (parent configurations from [`Optimizer::hints`]). Hints are
    /// advisory: they steer the worker pool's sticky dispatch and never
    /// affect results. Pass `&[]` for no hints.
    pub fn eval_results_hinted(
        &mut self,
        configs: &[Box<[u32]>],
        hints: &[Option<Box<[u32]>>],
        want_stats: bool,
    ) -> Vec<EvalResult> {
        if want_stats {
            // The stats path simulates every proposal by design, one at
            // a time — so the cancellation check runs per proposal.
            // Completed evaluations stay in history (best-so-far
            // semantics); a short return tells [`drive`] to stop.
            let mut out = Vec::with_capacity(configs.len());
            for c in configs.iter() {
                if self.cancel.triggered(self.stats.sims) {
                    self.truncated = true;
                    break;
                }
                out.push(self.eval_one_with_stats(c));
            }
            return out;
        }
        // Snapshot for mid-batch aborts: an aborted batch contributes
        // nothing (no history entries, no memo/oracle learning), so its
        // partial telemetry is rolled back wholesale — stats stay
        // consistent with history, and a non-cancelled run is untouched
        // (`EngineStats` is `Copy`; the snapshot costs a memcpy).
        let stats_snapshot = self.stats;
        let n_sim_snapshot = self.n_sim;
        let mut aborted = false;
        self.stats.batches += 1;

        // How a proposal that missed the raw memo lookup gets its cache
        // entry filled after the batch resolves.
        enum Fill {
            /// Copy the latency of this canonical configuration.
            Canon(Box<[u32]>),
            /// Dominated by a known deadlock: latency is `None`.
            OracleDeadlock,
        }

        // Phase 1 — classify every proposal: raw memo hit, in-batch
        // duplicate, oracle answer, clamp merge onto a canonical point,
        // or a genuine miss scheduled for simulation (deduplicated by
        // canonical key). Learning happens after the batch, so the
        // classification is independent of this batch's own results and
        // identical between serial and `--jobs N` runs.
        let mut misses: Vec<Box<[u32]>> = Vec::new();
        let mut miss_hints: Vec<Option<Box<[u32]>>> = Vec::new();
        let mut extras: Vec<(Box<[u32]>, Fill)> = Vec::new();
        {
            let mut seen_raw: HashSet<&[u32]> = HashSet::new();
            let mut scheduled: HashSet<Box<[u32]>> = HashSet::new();
            for (i, c) in configs.iter().enumerate() {
                if self.cache.get(c).is_some() || !seen_raw.insert(c.as_ref()) {
                    self.stats.cache_hits += 1;
                    continue;
                }
                if self.bounds && self.depth_bounds.below_floor(c) {
                    // Below an analytic deadlock floor: certain
                    // infeasibility, same fill path as an oracle answer.
                    self.stats.oracle_hits += 1;
                    self.stats.bounds_floor_hits += 1;
                    self.stats.sims_avoided += 1;
                    extras.push((c.clone(), Fill::OracleDeadlock));
                    continue;
                }
                if self.prune && self.oracle.is_dominated_infeasible(c) {
                    self.stats.oracle_hits += 1;
                    self.stats.sims_avoided += 1;
                    extras.push((c.clone(), Fill::OracleDeadlock));
                    continue;
                }
                match self.prune.then(|| self.canon.canonical(c)).flatten() {
                    Some(canon) => {
                        self.stats.clamp_hits += 1;
                        let known = self.cache.get(&canon).is_some()
                            || scheduled.contains(canon.as_ref());
                        if known {
                            // The canonical point is (or will be) known:
                            // this proposal needs no simulation of its own.
                            self.stats.cache_hits += 1;
                            self.stats.sims_avoided += 1;
                        } else {
                            scheduled.insert(canon.clone());
                            misses.push(canon.clone());
                            miss_hints.push(hints.get(i).cloned().flatten());
                        }
                        extras.push((c.clone(), Fill::Canon(canon)));
                    }
                    None => {
                        if scheduled.contains(c.as_ref()) {
                            // Raw config equal to another proposal's
                            // canonical point, already scheduled.
                            self.stats.cache_hits += 1;
                        } else {
                            scheduled.insert(c.clone());
                            misses.push(c.clone());
                            miss_hints.push(hints.get(i).cloned().flatten());
                        }
                    }
                }
            }
        }

        // Phase 2 — simulate the canonical misses. Under the batched
        // backend the whole miss batch is packed into SoA lanes and
        // answered by one graph walk per scenario member; otherwise the
        // misses fan out to the worker pool (or run inline when serial).
        let early = self.prune && self.sim.num_scenarios() > 1;
        let lats: Vec<Option<u64>> = if misses.is_empty() {
            Vec::new()
        } else if self.sim_backend == BackendKind::Batched {
            // Lane-batched path: the abort closure is polled at every
            // scenario boundary inside the fused walk, so one huge batch
            // can no longer overrun a wall-clock deadline by its full
            // length. (The sim-count budget leg stays at batch
            // granularity here — lanes resolve together.)
            let cancel = self.cancel.clone();
            let t0 = Instant::now();
            let lanes = self
                .sim
                .eval_batch_cancellable(&misses, early, &move || {
                    cancel.cancelled() || cancel.deadline_exceeded()
                });
            self.stats.busy_nanos += t0.elapsed().as_nanos() as u64;
            match lanes {
                None => {
                    aborted = true;
                    Vec::new()
                }
                Some(lanes) => {
                    for le in &lanes {
                        self.stats.note_run(&le.run, le.scen_runs, le.gap);
                    }
                    let tel = self.sim.last_batch_telemetry();
                    self.stats.batch_walks += tel.walks;
                    self.stats.lanes_packed += tel.lanes_packed;
                    self.stats.lane_slots += tel.lane_slots;
                    self.n_sim += misses.len() as u64;
                    self.stats.sims += misses.len() as u64;
                    lanes.into_iter().map(|le| le.latency).collect()
                }
            }
        } else {
            match &mut self.pool {
                Some(pool) if misses.len() > 1 => {
                    let outcomes = pool.run_batch_cancellable(
                        &misses,
                        Some(&miss_hints[..]),
                        early,
                        Some(&self.cancel),
                    );
                    if outcomes.iter().any(|o| o.aborted) {
                        aborted = true;
                        Vec::new()
                    } else {
                        for o in &outcomes {
                            if o.simulated {
                                self.n_sim += 1;
                                self.stats.sims += 1;
                                self.stats.note_run(&o.run, o.scen_runs, o.gap);
                                // Audit: only time spent simulating counts as
                                // busy — a worker answering from the shared
                                // cache did no simulation work.
                                self.stats.busy_nanos += o.nanos;
                            }
                        }
                        outcomes.into_iter().map(|o| o.latency).collect()
                    }
                }
                _ => {
                    let t0 = Instant::now();
                    let mut lats: Vec<Option<u64>> = Vec::with_capacity(misses.len());
                    for c in misses.iter() {
                        // Serial inline path: full per-config check —
                        // including the sim budget, since the counter is
                        // exact between configs here.
                        if self.cancel.triggered(self.stats.sims + lats.len() as u64) {
                            aborted = true;
                            break;
                        }
                        lats.push(self.sim.eval_latency(c, early));
                        let run = self.sim.last_run();
                        let gap = self.sim.last_gap();
                        let scen = self.sim.last_scenarios_run();
                        self.stats.note_run(&run, scen, gap);
                    }
                    self.n_sim += lats.len() as u64;
                    self.stats.sims += lats.len() as u64;
                    self.stats.busy_nanos += t0.elapsed().as_nanos() as u64;
                    lats
                }
            }
        };
        if aborted {
            // Roll back to the pre-batch counters and hand [`drive`] an
            // empty batch: the run ends at the last *completed* round,
            // so a cancelled run's history is a prefix-identical
            // truncation of the uncancelled one.
            self.stats = stats_snapshot;
            self.n_sim = n_sim_snapshot;
            self.truncated = true;
            return Vec::new();
        }

        // Phase 3 — learn every simulated result (in deterministic miss
        // order), then one batched backend call for every configuration
        // that needs a fresh BRAM total: the canonical misses plus the
        // raw keys served through the oracle or a canonical point (their
        // BRAM comes from the *actual* depths, never the clamped ones).
        if self.prune {
            for (c, lat) in misses.iter().zip(&lats) {
                self.oracle.note(c, *lat);
            }
        }
        if !misses.is_empty() || !extras.is_empty() {
            let n_miss = misses.len();
            let mut bram_in: Vec<Box<[u32]>> = Vec::with_capacity(n_miss + extras.len());
            bram_in.extend(misses.iter().cloned());
            bram_in.extend(extras.iter().map(|(raw, _)| raw.clone()));
            let brams = self.backend.bram_totals(&bram_in, &self.widths);
            let (miss_brams, extra_brams) = brams.split_at(n_miss);
            for ((c, lat), &br) in misses.into_iter().zip(lats).zip(miss_brams) {
                self.cache.insert(c, (lat, br));
            }
            for ((raw, fill), &br) in extras.into_iter().zip(extra_brams) {
                let lat = match fill {
                    Fill::OracleDeadlock => None,
                    Fill::Canon(canon) => {
                        self.cache
                            .get(&canon)
                            .expect("canonical point must be cached")
                            .0
                    }
                };
                self.cache.insert(raw, (lat, br));
            }
        }

        let t = self.elapsed();
        self.stats.proposals += configs.len() as u64;
        configs
            .iter()
            .map(|c| {
                let (lat, br) = self.cache.get(c).expect("batch member must be cached");
                self.history.push(EvalPoint {
                    depths: c.clone(),
                    latency: lat,
                    bram: br,
                    t,
                });
                EvalResult {
                    depths: c.clone(),
                    latency: lat,
                    bram: br,
                    stats: None,
                    blocked: Vec::new(),
                }
            })
            .collect()
    }

    fn eval_one_with_stats(&mut self, depths: &[u32]) -> EvalResult {
        // Stats evaluations always simulate — their purpose is the
        // per-channel statistics and deadlock block info, which the
        // pruning layer cannot synthesize. The result still feeds the
        // oracle.
        let t0 = Instant::now();
        let (out, stats) = self.sim.simulate_with_stats(depths);
        self.stats.busy_nanos += t0.elapsed().as_nanos() as u64;
        let run = self.sim.last_run();
        let scen = self.sim.last_scenarios_run();
        let gap = self.sim.last_gap();
        self.stats.note_run(&run, scen, gap);
        self.n_sim += 1;
        self.stats.sims += 1;
        let lat = out.latency();
        if self.prune {
            self.oracle.note(depths, lat);
        }
        let br = bram::bram_total(depths, &self.widths);
        let key: Box<[u32]> = depths.into();
        self.cache.insert(key.clone(), (lat, br));
        self.stats.proposals += 1;
        self.history.push(EvalPoint {
            depths: key.clone(),
            latency: lat,
            bram: br,
            t: self.elapsed(),
        });
        let blocked = match out {
            SimOutcome::Deadlock { blocked } => blocked,
            SimOutcome::Done { .. } => Vec::new(),
        };
        EvalResult {
            depths: key,
            latency: lat,
            bram: br,
            stats: Some(stats),
            blocked,
        }
    }

    /// Evaluate with per-channel occupancy/stall statistics (kept for
    /// diagnostics and back-compat; the ask/tell path uses
    /// [`Optimizer::wants_stats`] instead).
    pub fn eval_with_stats(&mut self, depths: &[u32]) -> (SimOutcome, ChannelStats) {
        let (out, stats) = self.sim.simulate_with_stats(depths);
        let run = self.sim.last_run();
        let scen = self.sim.last_scenarios_run();
        let gap = self.sim.last_gap();
        self.stats.note_run(&run, scen, gap);
        self.n_sim += 1;
        self.stats.sims += 1;
        if self.prune {
            self.oracle.note(depths, out.latency());
        }
        let br = bram::bram_total(depths, &self.widths);
        self.stats.proposals += 1;
        self.history.push(EvalPoint {
            depths: depths.into(),
            latency: out.latency(),
            bram: br,
            t: self.elapsed(),
        });
        (out, stats)
    }

    /// Pareto front over the feasible evaluation history.
    pub fn pareto(&self) -> Vec<&EvalPoint> {
        let pts: Vec<ObjPoint> = self
            .history
            .iter()
            .enumerate()
            .filter_map(|(i, p)| {
                p.latency.map(|l| ObjPoint {
                    latency: l,
                    bram: p.bram,
                    index: i,
                })
            })
            .collect();
        pareto_front(&pts)
            .into_iter()
            .map(|p| &self.history[p.index])
            .collect()
    }

    /// The memo cache's contents, sorted by depth vector — the
    /// persistent store's export path. Each entry is
    /// `(depths, latency, bram)` with `None` latency meaning deadlock.
    pub fn memo_entries(&self) -> Vec<MemoEntry> {
        self.cache
            .dump()
            .into_iter()
            .map(|(k, (lat, br))| (k.to_vec(), lat, br))
            .collect()
    }

    /// Warm-start the memo cache from persisted entries (the store's
    /// import path). Entries are inserted verbatim; soundness rests on
    /// the store's keying — a snapshot is only offered to an engine
    /// whose workload traces, backend and bound regime hash identically
    /// to the one that produced it, and under that key every entry is
    /// exactly what a fresh simulation would return, so warm and cold
    /// runs are bit-identical in history and front (only the sim count
    /// differs). Returns the number of entries imported.
    pub fn import_memo(&mut self, entries: &[MemoEntry]) -> usize {
        for (depths, lat, bram) in entries {
            self.cache.insert(depths.as_slice().into(), (*lat, *bram));
        }
        entries.len()
    }

    /// Warm-start the dominance oracle by replaying persisted outcomes
    /// through [`FeasibilityOracle::note`] — the antichains rebuild
    /// themselves under their usual bounds. No-op with pruning off
    /// (the oracle would never be consulted). Returns the number of
    /// outcomes replayed.
    pub fn import_oracle(&mut self, entries: &[OracleEntry]) -> usize {
        if !self.prune {
            return 0;
        }
        for (depths, lat) in entries {
            self.oracle.note(depths, *lat);
        }
        entries.len()
    }

    /// Convenience: evaluate both paper baselines, returning
    /// (Baseline-Max, Baseline-Min) points. For multi-scenario workloads
    /// Baseline-Max uses the merged (max-over-scenarios) upper bounds.
    pub fn eval_baselines(&mut self) -> (EvalPoint, EvalPoint) {
        let w = self.workload.clone();
        self.eval(&w.baseline_max());
        let max = self.history.last().unwrap().clone();
        self.eval(&w.baseline_min());
        let min = self.history.last().unwrap().clone();
        (max, min)
    }
}

// ---------------------------------------------------------------------------
// The central optimizer loop
// ---------------------------------------------------------------------------

/// Run `opt` against `engine` until it signals completion, returns an
/// empty batch, or the proposal budget is exhausted (budget discipline is
/// cooperative: the remaining budget is passed to every `ask`, and an
/// optimizer that proposes past it — e.g. greedy's final keep-evaluation
/// — may overrun by a batch). Returns the number of proposals made.
pub fn drive(
    opt: &mut dyn Optimizer,
    engine: &mut EvalEngine,
    space: &Space,
    budget: usize,
) -> usize {
    let start_evals = engine.n_evals();
    loop {
        if opt.done() {
            break;
        }
        // Cooperative cancellation: stop at the round boundary with the
        // best-so-far history/front intact. Checked here (not mid-batch)
        // so serial/parallel bit-identity of completed rounds holds.
        if engine.cancel_triggered() {
            engine.truncated = true;
            break;
        }
        let proposed = engine.n_evals() - start_evals;
        let ctx = AskCtx {
            space,
            budget_left: budget.saturating_sub(proposed),
            batch_hint: engine.batch_hint(),
        };
        let batch = opt.ask(&ctx);
        if batch.is_empty() {
            break;
        }
        let hints = opt.hints();
        let results = engine.eval_results_hinted(&batch, &hints, opt.wants_stats());
        if results.len() != batch.len() {
            // The engine aborted mid-batch on its cancellation token
            // (and already rolled the partial batch back / flagged the
            // run truncated): stop without telling the optimizer a
            // short batch it never asked for.
            engine.truncated = true;
            break;
        }
        opt.tell(&results);
    }
    engine.n_evals() - start_evals
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::sim::fast::FastSim;
    use crate::trace::collect_trace;

    fn trace_of(name: &str) -> Arc<Trace> {
        let bd = bench_suite::build(name);
        Arc::new(collect_trace(&bd.design, &bd.args).unwrap())
    }

    #[test]
    fn sharded_cache_roundtrip_and_clear() {
        let c = ShardedCache::new(5); // rounds up to 8
        assert_eq!(c.num_shards(), 8);
        for i in 0..100u32 {
            c.insert(vec![i, i + 1].into(), (Some(i as u64), i));
        }
        assert_eq!(c.len(), 100);
        assert_eq!(c.get(&[7, 8]), Some((Some(7), 7)));
        assert_eq!(c.get(&[7, 9]), None);
        c.clear();
        assert!(c.is_empty());
    }

    #[test]
    fn pool_preserves_order_and_reports_cache_hits() {
        let t = trace_of("gesummv");
        let sim = ScenarioSim::single(t.clone());
        let cache = Arc::new(ShardedCache::new(8));
        let mut pool = WorkerPool::new(&sim, 4, Some(Arc::clone(&cache)));
        let ub = t.upper_bounds();
        let mut rng = crate::util::Rng::new(5);
        let configs: Vec<Box<[u32]>> = (0..30)
            .map(|_| {
                ub.iter()
                    .map(|&u| rng.range_u32(2, u.max(2)))
                    .collect::<Box<[u32]>>()
            })
            .collect();
        let first = pool.run(&configs);
        assert!(first.iter().all(|o| o.simulated));
        // Serial reference.
        let mut serial = FastSim::new(t.clone());
        for (c, o) in configs.iter().zip(&first) {
            assert_eq!(serial.simulate(c).latency(), o.latency);
        }
        // Populate the cache; the second run must hit it.
        for (c, o) in configs.iter().zip(&first) {
            cache.insert(c.clone(), (o.latency, 0));
        }
        let second = pool.run(&configs);
        assert!(second.iter().all(|o| !o.simulated));
        for (a, b) in first.iter().zip(&second) {
            assert_eq!(a.latency, b.latency);
        }
    }

    #[test]
    fn engine_batch_dedups_and_counts() {
        let t = trace_of("bicg");
        let mut ev = EvalEngine::parallel(t.clone(), 2);
        let cfg: Box<[u32]> = t.baseline_max().into();
        let batch = vec![cfg.clone(), cfg.clone(), cfg.clone()];
        let out = ev.eval_batch(&batch);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], out[1]);
        assert_eq!(ev.n_sim, 1, "in-batch duplicates must be deduped");
        assert_eq!(ev.n_evals(), 3, "history counts proposals");
        assert_eq!(ev.stats().cache_hits, 2);
        // Second batch: pure cache.
        ev.eval_batch(&batch);
        assert_eq!(ev.n_sim, 1);
        assert!(ev.stats().hit_rate() > 0.5);
    }

    #[test]
    fn drive_runs_an_optimizer_within_budget() {
        let t = trace_of("bicg");
        let space = Space::from_trace(&t);
        let mut ev = EvalEngine::new(t);
        let mut o = crate::opt::random::RandomSearch::new(3, false);
        let n = drive(&mut o, &mut ev, &space, 100);
        assert_eq!(n, 100);
        assert_eq!(ev.n_evals(), 100);
    }

    #[test]
    fn hinted_dispatch_preserves_order_and_results() {
        let t = trace_of("gesummv");
        let sim = ScenarioSim::single(t.clone());
        let mut pool = WorkerPool::new(&sim, 3, None);
        let ub = t.upper_bounds();
        // A mutation chain: each config differs from a shared base in one
        // position — the locality hint is the base.
        let base: Box<[u32]> = ub.iter().map(|&u| u.max(2)).collect();
        let mut configs: Vec<Box<[u32]>> = Vec::new();
        let mut hints: Vec<Option<Box<[u32]>>> = Vec::new();
        for i in 0..20 {
            let mut c = base.to_vec();
            let ch = i % c.len();
            c[ch] = 2 + (i as u32 % c[ch].max(3));
            configs.push(c.into());
            hints.push(if i % 4 == 0 { None } else { Some(base.clone()) });
        }
        let hinted = pool.run_with_hints(&configs, Some(&hints[..]));
        let mut serial = FastSim::new(t.clone());
        for (c, o) in configs.iter().zip(&hinted) {
            assert_eq!(serial.simulate(c).latency(), o.latency, "cfg {c:?}");
        }
        // Cap keeps the batch balanced even with identical hints.
        let max_assigned = *pool.assigned.iter().max().unwrap();
        assert!(max_assigned <= 20usize.div_ceil(3));
    }

    /// `k` independent producer→consumer pipes: a single-channel depth
    /// delta can only dirty one pipe, so the dirty frontier stays tiny.
    fn parallel_pipes_trace(k: usize, n: u64) -> Arc<Trace> {
        use crate::ir::{DesignBuilder, Expr};
        let mut b = DesignBuilder::new("pipes", 0);
        let chans: Vec<usize> = (0..k).map(|i| b.channel(&format!("c{i}"), 32)).collect();
        for (i, &c) in chans.iter().enumerate() {
            b.process(&format!("w{i}"), move |p| {
                p.for_n(n, |p, _| p.write(c, Expr::c(0)))
            });
            b.process(&format!("r{i}"), move |p| {
                p.for_n(n, |p, _| {
                    let _ = p.read(c);
                })
            });
        }
        Arc::new(collect_trace(&b.build(), &[]).unwrap())
    }

    #[test]
    fn engine_counts_incremental_sims_on_mutation_chains() {
        // Serial engine: consecutive ±1 single-channel mutations must be
        // served as delta replays, and the counters must see them.
        let t = parallel_pipes_trace(8, 32);
        let mut ev = EvalEngine::new(t.clone());
        let base = t.baseline_max();
        ev.eval(&base);
        for ch in 0..base.len() {
            let mut c = base.clone();
            c[ch] -= 1;
            ev.eval(&c);
        }
        let s = ev.stats();
        assert_eq!(s.sims, 1 + base.len() as u64);
        assert!(
            s.incr_sims >= base.len() as u64,
            "±1 mutations should all be delta replays: {s:?}"
        );
        assert!(s.replayed_ops < s.replayable_ops, "deltas must save work");
        assert!(s.incremental_rate() > 0.0 && s.incremental_rate() <= 1.0);
        assert!(s.replay_fraction() < 1.0);
        assert!(s.dirty_per_incremental() >= 1.0);
    }

    fn fig2_workload(ns: &[i64]) -> Arc<Workload> {
        let bd = bench_suite::build("fig2");
        let named: Vec<(String, Vec<i64>)> =
            ns.iter().map(|&n| (format!("n{n}"), vec![n])).collect();
        Arc::new(Workload::from_design(&bd.design, &named).unwrap())
    }

    #[test]
    fn workload_engine_aggregates_worst_case_and_counts_scenarios() {
        let w = fig2_workload(&[8, 16]);
        let mut ev = EvalEngine::for_workload(w.clone(), 1);
        // Bounds off so the sub-floor probe below really simulates.
        ev.set_bounds(false);
        let cfg = w.baseline_max();
        let (lat, _) = ev.eval(&cfg);
        let per: Vec<Option<u64>> = w
            .scenarios()
            .iter()
            .map(|s| FastSim::new(s.trace.clone()).simulate(&cfg).latency())
            .collect();
        assert_eq!(lat, per.iter().flatten().max().copied());
        // A config feasible only on the small-n scenario is infeasible.
        let (lat, _) = ev.eval(&[7, 2]);
        assert_eq!(lat, None);
        let s = ev.stats();
        assert_eq!(s.sims, 2);
        assert_eq!(s.scenario_sims, 4, "each sim runs every scenario");
        assert_eq!(s.robust_points, 1, "only the feasible eval has a gap");
        assert!(s.mean_robustness_gap() > 0.0, "n=8 vs n=16 latencies differ");
        // Per-scenario diagnostics agree with independent simulation.
        let diag = ev.per_scenario_latencies(&cfg);
        assert_eq!(diag.len(), 2);
        for ((_, l), p) in diag.iter().zip(&per) {
            assert_eq!(l, p);
        }
    }

    #[test]
    fn workload_engine_serial_vs_parallel_identical() {
        let w = fig2_workload(&[8, 16, 12]);
        let space = Space::from_workload(&w);
        let histories: Vec<Vec<(Box<[u32]>, Option<u64>, u32)>> = [1usize, 4]
            .iter()
            .map(|&jobs| {
                let mut ev = EvalEngine::for_workload(w.clone(), jobs);
                let mut o = crate::opt::random::RandomSearch::new(13, false);
                drive(&mut o, &mut ev, &space, 96);
                ev.history
                    .iter()
                    .map(|p| (p.depths.clone(), p.latency, p.bram))
                    .collect()
            })
            .collect();
        assert_eq!(histories[0], histories[1]);
    }

    #[test]
    fn oracle_answers_dominated_deadlocks_without_simulating() {
        let t = trace_of("fig2"); // n = 16: x < 15 deadlocks
        let mut ev = EvalEngine::new(t.clone());
        // Bounds off: this test exercises the *learned* oracle, and the
        // analytic floor would otherwise answer everything below x = 15.
        ev.set_bounds(false);
        let (lat, _) = ev.eval(&[2, 16]);
        assert_eq!(lat, None);
        assert_eq!(ev.n_sim, 1);
        // [2, 2] ≤ [2, 16]: answered by the oracle, no simulation.
        let (lat, br) = ev.eval(&[2, 2]);
        assert_eq!(lat, None);
        assert_eq!(br, 0);
        assert_eq!(ev.n_sim, 1, "dominated deadlock must not simulate");
        let s = ev.stats();
        assert_eq!(s.oracle_hits, 1);
        assert_eq!(s.sims_avoided, 1);
        assert_eq!(s.oracle_rate(), 0.5);
        // The answer is memoized like any other: a repeat is a cache hit.
        ev.eval(&[2, 2]);
        assert_eq!(ev.stats().oracle_hits, 1);
        assert_eq!(ev.stats().cache_hits, 1);
        // History records the oracle answer exactly like a simulation.
        assert_eq!(ev.history[1].latency, None);
        // Identical to an unpruned engine.
        let mut cold = EvalEngine::new(t);
        cold.set_prune(false);
        cold.set_bounds(false);
        assert_eq!(cold.eval(&[2, 2]).0, None);
        assert_eq!(cold.stats().oracle_hits, 0);
        assert_eq!(cold.n_sim, 1);
    }

    /// Producer→consumer pipe with a designer depth hint far above the
    /// observed write count — the clamp region `(writes, hint]`.
    fn hinted_pipe_trace(n: u64, hint: u32) -> Arc<Trace> {
        use crate::ir::{DesignBuilder, Expr};
        let mut b = DesignBuilder::new("hinted", 0);
        let c = b.channel_with_depth("c", 32, hint);
        b.process("p", move |p| {
            p.for_n(n, |p, _| p.write(c, Expr::c(0)));
        });
        b.process("q", move |p| {
            p.for_n(n, |p, _| {
                let _ = p.read(c);
            })
        });
        Arc::new(crate::trace::collect_trace(&b.build(), &[]).unwrap())
    }

    #[test]
    fn clamp_collapses_the_region_above_the_write_count() {
        let t = hinted_pipe_trace(8, 64); // cap = 8, bound = 64
        let mut ev = EvalEngine::new(t.clone());
        let (lat16, _) = ev.eval(&[16]); // canonicalizes to [8]
        assert_eq!(ev.n_sim, 1);
        let (lat32, _) = ev.eval(&[32]); // same canonical point: no sim
        assert_eq!(ev.n_sim, 1, "clamp-equivalent configs share one sim");
        let (lat8, _) = ev.eval(&[8]); // the canonical point itself
        assert_eq!(ev.n_sim, 1);
        assert_eq!(lat16, lat32);
        assert_eq!(lat16, lat8);
        // Ground truth: identical to a cold simulation of the raw config.
        let want = FastSim::new(t.clone()).simulate(&[32]).latency();
        assert_eq!(lat32, want);
        let s = ev.stats();
        assert_eq!(s.clamp_hits, 2);
        assert_eq!(s.sims_avoided, 1);
        // Depth 64 × 32 bits crosses the SRL threshold: its canonical
        // point is the shallowest BRAM-class depth (33), a *different*
        // memo point — and one cycle slower (footnote 2).
        let (lat64, _) = ev.eval(&[64]);
        assert_eq!(ev.n_sim, 2);
        assert_eq!(lat64, lat16.map(|l| l + 1));
        assert_eq!(lat64, FastSim::new(t.clone()).simulate(&[64]).latency());
        // The batch path merges clamp-equivalent proposals too.
        let mut ev2 = EvalEngine::parallel(t, 2);
        let batch: Vec<Box<[u32]>> = vec![[16u32].into(), [32].into(), [24].into(), [8].into()];
        let out = ev2.eval_batch(&batch);
        assert!(out.iter().all(|&(l, _)| l == lat16));
        assert_eq!(ev2.n_sim, 1, "whole SRL-class clamp region is one canonical sim");
        assert_eq!(ev2.stats().clamp_hits, 3);
    }

    #[test]
    fn early_exit_and_oracle_compose_on_workloads() {
        let w = fig2_workload(&[8, 16]);
        let mut ev = EvalEngine::for_workload(w.clone(), 1);
        // Bounds off: every probe here sits below the analytic x floor,
        // and the point is to watch the oracle/early-exit machinery.
        ev.set_bounds(false);
        // Feasible on n=8, deadlocks on n=16: probed in index order the
        // first time, so both scenarios run.
        let (lat, _) = ev.eval(&[7, 2]);
        assert_eq!(lat, None);
        assert_eq!(ev.stats().scenario_sims, 2);
        // Dominated by the learned deadlock: no simulation at all.
        let (lat, _) = ev.eval(&[6, 2]);
        assert_eq!(lat, None);
        assert_eq!(ev.stats().oracle_hits, 1);
        assert_eq!(ev.stats().scenario_sims, 2);
        // Not dominated ([7,3] has y deeper): simulated, but the
        // deadlock-prone scenario is now probed first — one replay only.
        let (lat, _) = ev.eval(&[7, 3]);
        assert_eq!(lat, None);
        assert_eq!(ev.stats().scenario_sims, 3, "early exit after 1 probe");
        // An unpruned engine reaches the same verdicts with full replays.
        let mut off = EvalEngine::for_workload(w, 1);
        off.set_prune(false);
        off.set_bounds(false);
        for cfg in [[7u32, 2], [6, 2], [7, 3]] {
            assert_eq!(off.eval(&cfg).0, None, "{cfg:?}");
        }
        assert_eq!(off.stats().scenario_sims, 6, "no early exit when off");
        assert_eq!(off.stats().oracle_hits, 0);
    }

    #[test]
    fn accounting_invariant_holds_with_pruning() {
        // Every proposal is exactly one of: memo hit, oracle answer, or
        // simulation.
        let w = fig2_workload(&[8, 16, 12]);
        let space = Space::from_workload(&w);
        let mut ev = EvalEngine::for_workload(w, 1);
        let mut o = crate::opt::random::RandomSearch::new(7, false);
        drive(&mut o, &mut ev, &space, 150);
        let s = ev.stats();
        assert_eq!(s.cache_hits + s.oracle_hits + s.sims, s.proposals);
        assert!(ev.proposals_per_sec() > 0.0);
    }

    #[test]
    fn serial_and_parallel_drives_are_identical() {
        let t = trace_of("gesummv");
        let space = Space::from_trace(&t);
        let runs: Vec<Vec<(Box<[u32]>, Option<u64>, u32)>> = [1usize, 4]
            .iter()
            .map(|&jobs| {
                let mut ev = EvalEngine::parallel(t.clone(), jobs);
                let mut o = crate::opt::random::RandomSearch::new(11, false);
                drive(&mut o, &mut ev, &space, 128);
                ev.history
                    .iter()
                    .map(|p| (p.depths.clone(), p.latency, p.bram))
                    .collect()
            })
            .collect();
        assert_eq!(runs[0], runs[1]);
    }

    #[test]
    fn compiled_backend_engine_is_identical_to_fast() {
        // Backend selection must be invisible above the bank: identical
        // histories (latency and BRAM per proposal) for the same
        // optimizer/seed, serial and parallel, single-trace and workload.
        let w = fig2_workload(&[8, 16, 12]);
        let space = Space::from_workload(&w);
        for jobs in [1usize, 4] {
            let histories: Vec<Vec<(Box<[u32]>, Option<u64>, u32)>> =
                [BackendKind::Fast, BackendKind::Compiled, BackendKind::Batched]
                    .iter()
                    .map(|&kind| {
                        let mut ev = EvalEngine::for_workload_with_sim(w.clone(), jobs, kind);
                        assert_eq!(ev.sim_backend(), kind);
                        let mut o = crate::opt::random::RandomSearch::new(13, false);
                        drive(&mut o, &mut ev, &space, 96);
                        if kind == BackendKind::Batched {
                            let s = ev.stats();
                            assert!(s.batch_walks > 0, "batched engine must lane-batch");
                            assert!(s.lanes_packed >= s.batch_walks);
                            assert!(s.lanes_per_walk() >= 1.0);
                            assert!(s.batch_occupancy() > 0.0 && s.batch_occupancy() <= 1.0);
                            assert_eq!(
                                s.cache_hits + s.oracle_hits + s.sims,
                                s.proposals,
                                "accounting invariant under the batched backend"
                            );
                        } else {
                            assert_eq!(ev.stats().batch_walks, 0);
                        }
                        ev.history
                            .iter()
                            .map(|p| (p.depths.clone(), p.latency, p.bram))
                            .collect()
                    })
                    .collect();
            assert_eq!(
                histories[0], histories[1],
                "jobs={jobs}: compiled backend diverged from fast"
            );
            assert_eq!(
                histories[0], histories[2],
                "jobs={jobs}: batched backend diverged from fast"
            );
        }
    }

    /// The batched backend never spins up a worker pool — lane packing
    /// replaces sticky dispatch — so `--jobs N` is the serial code path
    /// and walk telemetry is identical whatever the job count.
    #[test]
    fn batched_engine_lane_telemetry_is_jobs_invariant() {
        let w = fig2_workload(&[8, 16]);
        let space = Space::from_workload(&w);
        let stats: Vec<EngineStats> = [1usize, 4]
            .iter()
            .map(|&jobs| {
                let mut ev =
                    EvalEngine::for_workload_with_sim(w.clone(), jobs, BackendKind::Batched);
                let mut o = crate::opt::random::RandomSearch::new(23, false);
                drive(&mut o, &mut ev, &space, 80);
                *ev.stats()
            })
            .collect();
        for s in &stats {
            assert!(s.batch_walks > 0);
            assert_eq!(s.walks_saved(), s.lanes_packed - s.batch_walks);
        }
        assert_eq!(stats[0].batch_walks, stats[1].batch_walks);
        assert_eq!(stats[0].lanes_packed, stats[1].lanes_packed);
        assert_eq!(stats[0].lane_slots, stats[1].lane_slots);
        assert_eq!(stats[0].sims, stats[1].sims);
        assert_eq!(stats[0].scenario_sims, stats[1].scenario_sims);
        // The bounds counters are deterministic too.
        assert_eq!(stats[0].bounds_floor_hits, stats[1].bounds_floor_hits);
        assert_eq!(stats[0].cap_tightenings, stats[1].cap_tightenings);
    }

    /// A sim-budget token makes `drive` stop at a round boundary with
    /// best-so-far history and the engine flagged truncated; the
    /// completed rounds match an uncancelled run's prefix.
    #[test]
    fn drive_truncates_on_cancel_token() {
        let t = trace_of("bicg");
        let space = Space::from_trace(&t);

        let mut full = EvalEngine::new(t.clone());
        let mut o = crate::opt::random::RandomSearch::new(7, false);
        drive(&mut o, &mut full, &space, 200);
        assert!(!full.truncated(), "no token: never truncated");

        let mut cut = EvalEngine::new(t.clone());
        cut.set_cancel_token(CancelToken::with_limits(None, Some(1)));
        let mut o = crate::opt::random::RandomSearch::new(7, false);
        let n = drive(&mut o, &mut cut, &space, 200);
        assert!(cut.truncated(), "budget hit must flag truncation");
        assert!(n < full.n_evals(), "truncated run stops early");
        assert!(n > 0, "the first round completes before the check");
        for (a, b) in cut.history.iter().zip(&full.history) {
            assert_eq!(a.depths, b.depths);
            assert_eq!(a.latency, b.latency);
        }
        // reset_run clears the flag; an explicit cancel() pre-trigger
        // stops the next drive before any proposals.
        cut.reset_run(false);
        assert!(!cut.truncated());
        cut.cancel_token().cancel();
        let mut o = crate::opt::random::RandomSearch::new(7, false);
        assert_eq!(drive(&mut o, &mut cut, &space, 200), 0);
        assert!(cut.truncated());
    }

    #[test]
    fn bounds_floor_short_circuit_answers_without_simulating() {
        let t = trace_of("fig2"); // n = 16: x floors at 15
        let mut ev = EvalEngine::new(t.clone());
        assert!(ev.bounds(), "bounds layer is on by default");
        assert_eq!(ev.depth_bounds().floors, vec![15, 1]);
        let (lat, br) = ev.eval(&[2, 16]);
        assert_eq!(lat, None);
        assert_eq!(br, bram::bram_total(&[2, 16], &ev.widths));
        assert_eq!(ev.n_sim, 0, "sub-floor proposals never simulate");
        let s = ev.stats();
        assert_eq!(s.bounds_floor_hits, 1);
        assert_eq!(s.oracle_hits, 1, "floor hits count as oracle answers");
        assert_eq!(s.sims_avoided, 1);
        assert_eq!(s.cache_hits + s.oracle_hits + s.sims, s.proposals);
        // The answer is memoized: a repeat is a plain cache hit.
        ev.eval(&[2, 16]);
        assert_eq!(ev.stats().bounds_floor_hits, 1);
        assert_eq!(ev.stats().cache_hits, 1);
        // The batch path takes the same short-circuit; at the floor
        // itself the design runs.
        let out = ev.eval_batch(&[[14u32, 2].into(), [15, 2].into()]);
        assert_eq!(out[0].0, None);
        assert!(out[1].0.is_some(), "at the floor the design runs");
        assert_eq!(ev.stats().bounds_floor_hits, 2);
        assert_eq!(ev.n_sim, 1);
        // Bit-identical verdict from an engine with bounds disabled —
        // it just pays a simulation for it.
        let mut off = EvalEngine::new(t);
        off.set_bounds(false);
        assert!(!off.bounds());
        assert_eq!(off.eval(&[2, 16]).0, None);
        assert_eq!(off.stats().bounds_floor_hits, 0);
        assert_eq!(off.stats().cap_tightenings, 0);
        assert_eq!(off.n_sim, 1);
    }

    #[test]
    fn engine_seeds_oracle_from_analytic_floors() {
        let t = trace_of("fig2");
        let mut ev = EvalEngine::new(t);
        // The one-below-floor frontier is pre-learned: [14, 16] (x one
        // below its floor, y fully relaxed) dominates every sub-floor x.
        assert_eq!(ev.oracle().num_infeasible(), 1);
        // reset_run with a cache clear forgets and re-seeds.
        ev.reset_run(true);
        assert_eq!(ev.oracle().num_infeasible(), 1);
        // Disabling bounds forgets the seeds (and restores the
        // write-count clamp caps); re-enabling restores both.
        ev.set_bounds(false);
        assert_eq!(ev.oracle().num_infeasible(), 0);
        ev.reset_run(true);
        assert_eq!(ev.oracle().num_infeasible(), 0, "no seeds while off");
        ev.set_bounds(true);
        assert_eq!(ev.oracle().num_infeasible(), 1);
    }

    #[test]
    fn bounds_toggle_never_changes_results() {
        // Histories and fronts are bit-identical with the bounds layer
        // on or off — only the simulation counts differ (the baselines
        // include the sub-floor Baseline-Min, so the on-arm strictly
        // saves at least one simulation).
        let w = fig2_workload(&[8, 16]);
        let space = Space::from_workload(&w);
        let mut histories: Vec<Vec<(Box<[u32]>, Option<u64>, u32)>> = Vec::new();
        let mut sims = Vec::new();
        for &on in &[true, false] {
            let mut ev = EvalEngine::for_workload(w.clone(), 1);
            ev.set_bounds(on);
            ev.eval_baselines();
            let mut o = crate::opt::random::RandomSearch::new(5, false);
            drive(&mut o, &mut ev, &space, 100);
            sims.push(ev.stats().sims);
            histories.push(
                ev.history
                    .iter()
                    .map(|p| (p.depths.clone(), p.latency, p.bram))
                    .collect(),
            );
        }
        assert_eq!(histories[0], histories[1]);
        assert!(
            sims[0] < sims[1],
            "bounds must save simulations: {} vs {}",
            sims[0],
            sims[1]
        );
    }

    /// Regression: cancellation used to be checked only between
    /// ask/tell rounds, so one large batch could overrun a wall-clock
    /// deadline by its full length. Calling the eval path directly
    /// (drive's round-boundary check never runs) with an
    /// already-expired deadline must now abort *inside* the batch on
    /// both the serial and the pool path: empty results, truncated
    /// flag, counters rolled back.
    #[test]
    fn expired_deadline_aborts_one_large_batch_mid_round() {
        let t = trace_of("gesummv");
        let ub = t.upper_bounds();
        let mut rng = crate::util::Rng::new(3);
        let batch: Vec<Box<[u32]>> = (0..64)
            .map(|_| {
                ub.iter()
                    .map(|&u| rng.range_u32(2, u.max(2)))
                    .collect::<Box<[u32]>>()
            })
            .collect();
        for jobs in [1usize, 4] {
            let mut ev = EvalEngine::parallel(t.clone(), jobs);
            ev.set_cancel_token(CancelToken::with_timeout(std::time::Duration::ZERO));
            let out = ev.eval_results(&batch, false);
            assert!(out.is_empty(), "jobs={jobs}: aborted batch has no results");
            assert!(ev.truncated(), "jobs={jobs}: abort must flag truncation");
            assert_eq!(ev.n_sim, 0, "jobs={jobs}: counters must roll back");
            assert_eq!(ev.stats().sims, 0, "jobs={jobs}");
            assert_eq!(ev.stats().batches, 0, "jobs={jobs}");
            assert!(ev.history.is_empty(), "jobs={jobs}: no partial history");
        }
    }

    /// The same regression under `--backend batched`, the worst case
    /// pre-fix: the whole miss batch rode one fused call. The abort
    /// closure is polled at scenario boundaries inside the walk.
    #[test]
    fn expired_deadline_aborts_the_batched_backend_mid_walk() {
        let w = fig2_workload(&[8, 16]);
        let mut ev = EvalEngine::for_workload_with_sim(w.clone(), 1, BackendKind::Batched);
        ev.set_cancel_token(CancelToken::with_timeout(std::time::Duration::ZERO));
        let batch: Vec<Box<[u32]>> = (2u32..34).map(|x| vec![15 + (x % 2), x].into()).collect();
        let out = ev.eval_results(&batch, false);
        assert!(out.is_empty());
        assert!(ev.truncated());
        assert_eq!(ev.n_sim, 0);
        assert_eq!(ev.stats().sims, 0);
        assert!(ev.history.is_empty());
    }

    /// A token that never fires must leave the run bit-identical to an
    /// untokened one — the cancellable paths add checks, never
    /// different work.
    #[test]
    fn generous_token_runs_are_bit_identical_to_untokened() {
        let t = trace_of("bicg");
        let space = Space::from_trace(&t);
        let histories: Vec<Vec<(Box<[u32]>, Option<u64>, u32)>> = [false, true]
            .iter()
            .map(|&tok| {
                let mut ev = EvalEngine::parallel(t.clone(), 2);
                if tok {
                    ev.set_cancel_token(CancelToken::with_timeout(
                        std::time::Duration::from_secs(3600),
                    ));
                }
                let mut o = crate::opt::random::RandomSearch::new(17, false);
                drive(&mut o, &mut ev, &space, 100);
                assert!(!ev.truncated());
                ev.history
                    .iter()
                    .map(|p| (p.depths.clone(), p.latency, p.bram))
                    .collect()
            })
            .collect();
        assert_eq!(histories[0], histories[1]);
    }

    /// The store's replay guarantee, at the engine level: exporting the
    /// memo + oracle after a run and importing them into a fresh engine
    /// makes the identical run a pure cache replay — zero simulations,
    /// bit-identical history.
    #[test]
    fn memo_and_oracle_export_import_replays_with_zero_sims() {
        let w = fig2_workload(&[8, 16]);
        let space = Space::from_workload(&w);
        let mut a = EvalEngine::for_workload(w.clone(), 1);
        a.eval_baselines();
        let mut o = crate::opt::random::RandomSearch::new(9, false);
        drive(&mut o, &mut a, &space, 120);
        let memo = a.memo_entries();
        let oracle = a.oracle().entries();
        assert!(!memo.is_empty());
        assert!(a.stats().sims > 0, "the cold run must simulate");

        let mut b = EvalEngine::for_workload(w, 1);
        assert_eq!(b.import_memo(&memo), memo.len());
        b.import_oracle(&oracle);
        b.eval_baselines();
        let mut o = crate::opt::random::RandomSearch::new(9, false);
        drive(&mut o, &mut b, &space, 120);
        assert_eq!(b.stats().sims, 0, "warm replay must not simulate");
        assert_eq!(b.n_sim, 0);
        let ha: Vec<_> = a
            .history
            .iter()
            .map(|p| (p.depths.clone(), p.latency, p.bram))
            .collect();
        let hb: Vec<_> = b
            .history
            .iter()
            .map(|p| (p.depths.clone(), p.latency, p.bram))
            .collect();
        assert_eq!(ha, hb, "warm history must match cold bit-for-bit");
    }
}
