//! The DSE engine: the black-box evaluator `x → (f_lat(x), f_bram(x))`
//! (paper §III), with memoization, wall-clock-stamped evaluation history
//! (for the Fig. 5 convergence study), a leader/worker parallel batch
//! path, and an optional AOT-compiled XLA backend for the batched
//! BRAM/objective analytics (see [`crate::runtime`]).

pub mod pool;
pub mod sweep;

use crate::bram;
use crate::opt::pareto::{pareto_front, ObjPoint};
use crate::sim::fast::{FastSim, SimOutcome};
use crate::trace::Trace;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// One evaluated FIFO configuration.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub depths: Box<[u32]>,
    /// `None` means the configuration deadlocks.
    pub latency: Option<u64>,
    pub bram: u32,
    /// Seconds since the evaluator was created when this evaluation
    /// completed (includes optimizer logic time, as in Fig. 5).
    pub t: f64,
}

impl EvalPoint {
    pub fn is_feasible(&self) -> bool {
        self.latency.is_some()
    }
}

/// Pluggable backend for batched BRAM totals — implemented natively
/// (Algorithm 1 in Rust) and by the PJRT-executed JAX/Pallas artifact
/// ([`crate::runtime::BatchAnalytics`]). Not `Send`: the PJRT client is
/// thread-pinned; only the [`FastSim`] clones cross worker threads.
pub trait BramBatch {
    /// Total BRAM count for each configuration in the batch.
    fn bram_totals(&mut self, configs: &[Box<[u32]>], widths: &[u32]) -> Vec<u32>;
    /// Human-readable backend name (for logs/reports).
    fn name(&self) -> &'static str;
}

/// The native Algorithm-1 backend.
pub struct NativeBram;

impl BramBatch for NativeBram {
    fn bram_totals(&mut self, configs: &[Box<[u32]>], widths: &[u32]) -> Vec<u32> {
        configs
            .iter()
            .map(|c| bram::bram_total(c, widths))
            .collect()
    }
    fn name(&self) -> &'static str {
        "native"
    }
}

/// The black-box evaluator. Construct once per (design, trace); share
/// among optimizers sequentially.
pub struct Evaluator {
    sim: FastSim,
    pub widths: Vec<u32>,
    cache: HashMap<Box<[u32]>, (Option<u64>, u32)>,
    /// Every proposal in order (cache hits included — the optimizer
    /// budget counts proposals, as in the paper's fixed 1000 samples).
    pub history: Vec<EvalPoint>,
    /// Number of actual simulator invocations (cache misses).
    pub n_sim: u64,
    /// Worker threads for batch evaluation (1 = serial).
    pub threads: usize,
    backend: Box<dyn BramBatch>,
    start: Instant,
}

impl Evaluator {
    /// Evaluator with the native BRAM backend and serial simulation.
    pub fn new(trace: Arc<Trace>) -> Evaluator {
        Self::with_backend(trace, Box::new(NativeBram), 1)
    }

    /// Evaluator with `threads` parallel simulation workers.
    pub fn parallel(trace: Arc<Trace>, threads: usize) -> Evaluator {
        Self::with_backend(trace, Box::new(NativeBram), threads)
    }

    /// Full control: custom BRAM backend (e.g. the XLA artifact) +
    /// parallelism.
    pub fn with_backend(
        trace: Arc<Trace>,
        backend: Box<dyn BramBatch>,
        threads: usize,
    ) -> Evaluator {
        let widths: Vec<u32> = trace.channels.iter().map(|c| c.width_bits).collect();
        Evaluator {
            sim: FastSim::new(trace),
            widths,
            cache: HashMap::new(),
            history: Vec::new(),
            n_sim: 0,
            threads: threads.max(1),
            backend,
            start: Instant::now(),
        }
    }

    /// The trace being optimized.
    pub fn trace(&self) -> &Arc<Trace> {
        self.sim.trace()
    }

    /// Name of the BRAM backend in use.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Reset history and the start-of-run clock (keep the memo cache —
    /// incremental reuse across optimizers is part of the design; pass
    /// `clear_cache` to measure cold-start behaviour).
    pub fn reset_run(&mut self, clear_cache: bool) {
        self.history.clear();
        if clear_cache {
            self.cache.clear();
            self.n_sim = 0;
        }
        self.start = Instant::now();
    }

    /// Seconds since evaluator creation / last [`Self::reset_run`].
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Number of proposals so far (the budget meter).
    pub fn n_evals(&self) -> usize {
        self.history.len()
    }

    /// Evaluate one configuration (memoized), recording it in history.
    pub fn eval(&mut self, depths: &[u32]) -> (Option<u64>, u32) {
        let key: Box<[u32]> = depths.into();
        let (lat, br) = match self.cache.get(&key) {
            Some(&v) => v,
            None => {
                let lat = self.sim.simulate(depths).latency();
                let br = bram::bram_total(depths, &self.widths);
                self.n_sim += 1;
                self.cache.insert(key.clone(), (lat, br));
                (lat, br)
            }
        };
        self.history.push(EvalPoint {
            depths: key,
            latency: lat,
            bram: br,
            t: self.elapsed(),
        });
        (lat, br)
    }

    /// Evaluate a batch: uncached configs are simulated in parallel
    /// across [`threads`](Self::threads) workers and the BRAM totals are
    /// computed by the configured backend in one call (the XLA hot path).
    pub fn eval_batch(&mut self, configs: &[Box<[u32]>]) -> Vec<(Option<u64>, u32)> {
        // Identify cache misses (deduplicated within the batch).
        let mut misses: Vec<Box<[u32]>> = Vec::new();
        let mut seen: HashMap<&[u32], ()> = HashMap::new();
        for c in configs {
            if !self.cache.contains_key(c.as_ref()) && !seen.contains_key(c.as_ref()) {
                seen.insert(c, ());
                misses.push(c.clone());
            }
        }
        if !misses.is_empty() {
            let lats = pool::parallel_latencies(&self.sim, &misses, self.threads);
            let brams = self.backend.bram_totals(&misses, &self.widths);
            self.n_sim += misses.len() as u64;
            for ((c, lat), br) in misses.into_iter().zip(lats).zip(brams) {
                self.cache.insert(c, (lat, br));
            }
        }
        let t = self.elapsed();
        configs
            .iter()
            .map(|c| {
                let &(lat, br) = self.cache.get(c.as_ref()).unwrap();
                self.history.push(EvalPoint {
                    depths: c.clone(),
                    latency: lat,
                    bram: br,
                    t,
                });
                (lat, br)
            })
            .collect()
    }

    /// Evaluate with per-channel occupancy/stall statistics (used by the
    /// greedy optimizer's ranking pass).
    pub fn eval_with_stats(
        &mut self,
        depths: &[u32],
    ) -> (SimOutcome, crate::sim::fast::ChannelStats) {
        self.n_sim += 1;
        let (out, stats) = self.sim.simulate_with_stats(depths);
        let br = bram::bram_total(depths, &self.widths);
        self.history.push(EvalPoint {
            depths: depths.into(),
            latency: out.latency(),
            bram: br,
            t: self.elapsed(),
        });
        (out, stats)
    }

    /// Pareto front over the feasible evaluation history.
    pub fn pareto(&self) -> Vec<&EvalPoint> {
        let pts: Vec<ObjPoint> = self
            .history
            .iter()
            .enumerate()
            .filter_map(|(i, p)| {
                p.latency.map(|l| ObjPoint {
                    latency: l,
                    bram: p.bram,
                    index: i,
                })
            })
            .collect();
        pareto_front(&pts)
            .into_iter()
            .map(|p| &self.history[p.index])
            .collect()
    }

    /// Convenience: evaluate both paper baselines, returning
    /// (Baseline-Max, Baseline-Min) points.
    pub fn eval_baselines(&mut self) -> (EvalPoint, EvalPoint) {
        let t = self.trace().clone();
        self.eval(&t.baseline_max());
        let max = self.history.last().unwrap().clone();
        self.eval(&t.baseline_min());
        let min = self.history.last().unwrap().clone();
        (max, min)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::trace::collect_trace;

    fn evaluator(name: &str) -> Evaluator {
        let bd = bench_suite::build(name);
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        Evaluator::new(t)
    }

    #[test]
    fn eval_is_memoized_but_history_counts_proposals() {
        let mut ev = evaluator("bicg");
        let cfg = ev.trace().baseline_max();
        let a = ev.eval(&cfg);
        let b = ev.eval(&cfg);
        assert_eq!(a, b);
        assert_eq!(ev.n_evals(), 2);
        assert_eq!(ev.n_sim, 1);
    }

    #[test]
    fn batch_matches_serial() {
        let mut ev = evaluator("gesummv");
        let t = ev.trace().clone();
        let configs: Vec<Box<[u32]>> = vec![
            t.baseline_max().into(),
            t.baseline_min().into(),
            t.baseline_max().iter().map(|&d| (d / 2).max(2)).collect(),
        ];
        let batch = ev.eval_batch(&configs);
        let mut ev2 = evaluator("gesummv");
        let serial: Vec<_> = configs.iter().map(|c| ev2.eval(c)).collect();
        assert_eq!(batch, serial);
    }

    #[test]
    fn parallel_batch_matches_serial_batch() {
        let bd = bench_suite::build("gesummv");
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let mut ev1 = Evaluator::new(t.clone());
        let mut ev4 = Evaluator::parallel(t.clone(), 4);
        let mut rng = crate::util::Rng::new(3);
        let ub = t.upper_bounds();
        let configs: Vec<Box<[u32]>> = (0..40)
            .map(|_| {
                ub.iter()
                    .map(|&u| rng.range_u32(2, u.max(2)))
                    .collect::<Box<[u32]>>()
            })
            .collect();
        assert_eq!(ev1.eval_batch(&configs), ev4.eval_batch(&configs));
    }

    #[test]
    fn pareto_over_history() {
        let mut ev = evaluator("bicg");
        let (maxp, minp) = ev.eval_baselines();
        assert!(maxp.is_feasible());
        let front = ev.pareto();
        assert!(!front.is_empty());
        // Baseline-Min (depth 2 everywhere) has zero BRAM; if feasible it
        // must put a zero-BRAM point on the front.
        if minp.is_feasible() {
            assert!(front.iter().any(|p| p.bram == 0));
        }
    }

    #[test]
    fn reset_run_keeps_or_clears_cache() {
        let mut ev = evaluator("bicg");
        let cfg = ev.trace().baseline_max();
        ev.eval(&cfg);
        ev.reset_run(false);
        assert_eq!(ev.n_evals(), 0);
        ev.eval(&cfg);
        assert_eq!(ev.n_sim, 1, "cache kept");
        ev.reset_run(true);
        ev.eval(&cfg);
        assert_eq!(ev.n_sim, 1, "cache cleared, resimulated");
    }
}
