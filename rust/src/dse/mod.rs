//! The DSE engine layer: the black-box evaluator `x → (f_lat(x),
//! f_bram(x))` (paper §III) behind a batch-first **ask/tell** interface.
//!
//! - [`engine`] — the [`EvalEngine`]: persistent worker pool, sharded
//!   memo cache, in-batch dedup, batched BRAM backend calls, engine
//!   statistics, and the central [`drive`] loop that runs any
//!   [`Optimizer`](crate::opt::Optimizer). Engines evaluate a
//!   [`Workload`](crate::trace::workload::Workload) — one or many traces
//!   of the design under different kernel arguments — with worst-case
//!   aggregation and deadlock-in-any-scenario infeasibility
//!   (single-trace constructors wrap a single-scenario workload).
//! - [`pool`] — a thin latency-only shim over the engine's worker pool
//!   (kept for benches and direct simulator fan-out).
//! - [`cancel`] — cooperative cancellation: [`CancelToken`] bundles
//!   explicit cancel / wall-clock deadline / simulation budget behind
//!   one check that [`drive`] consults per ask/tell round.
//! - [`sweep`] — the fault-tolerant experiment-grid orchestrator:
//!   checkpointed cells, a resumable manifest, deterministic sharding,
//!   per-cell budgets, and panic isolation.
//! - [`advhunt`] — the adversarial outer loop: scenario hunting over a
//!   design's kernel-argument space (args-as-genome over the existing
//!   ask/tell optimizers), robustness certificates for optimized
//!   configs, and scenario-bank distillation with a full-bank re-verify
//!   fixpoint whose results are bit-identical to full-bank optimization.
//!
//! [`Evaluator`] is an alias of [`EvalEngine`] kept for the pervasive
//! call sites that predate the ask/tell refactor.

pub mod advhunt;
pub mod cancel;
pub mod engine;
pub mod pool;
pub mod sweep;

pub use advhunt::{certify_design, hunt, optimize_distilled, Certificate, HuntConfig, HuntReport};
pub use cancel::CancelToken;
pub use engine::{
    drive, EngineStats, EvalEngine, EvalResult, MemoEntry, OracleEntry, ShardedCache, WorkerPool,
};

/// Back-compat name for the evaluation engine.
pub type Evaluator = EvalEngine;

use crate::bram;

/// One evaluated FIFO configuration.
#[derive(Debug, Clone)]
pub struct EvalPoint {
    pub depths: Box<[u32]>,
    /// `None` means the configuration deadlocks.
    pub latency: Option<u64>,
    pub bram: u32,
    /// Seconds since the engine was created when this evaluation
    /// completed (includes optimizer logic time, as in Fig. 5).
    pub t: f64,
}

impl EvalPoint {
    pub fn is_feasible(&self) -> bool {
        self.latency.is_some()
    }
}

/// Pluggable backend for batched BRAM totals — implemented natively
/// (Algorithm 1 in Rust) and by the batched analytics module
/// ([`crate::runtime::BatchAnalytics`]). Not `Send`: analytics clients
/// may be thread-pinned; only the [`crate::sim::fast::FastSim`] clones
/// cross worker threads.
pub trait BramBatch {
    /// Total BRAM count for each configuration in the batch.
    fn bram_totals(&mut self, configs: &[Box<[u32]>], widths: &[u32]) -> Vec<u32>;
    /// Human-readable backend name (for logs/reports).
    fn name(&self) -> &'static str;
}

/// The native Algorithm-1 backend.
pub struct NativeBram;

impl BramBatch for NativeBram {
    fn bram_totals(&mut self, configs: &[Box<[u32]>], widths: &[u32]) -> Vec<u32> {
        configs.iter().map(|c| bram::bram_total(c, widths)).collect()
    }
    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    fn evaluator(name: &str) -> Evaluator {
        let bd = bench_suite::build(name);
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        Evaluator::new(t)
    }

    #[test]
    fn eval_is_memoized_but_history_counts_proposals() {
        let mut ev = evaluator("bicg");
        let cfg = ev.trace().baseline_max();
        let a = ev.eval(&cfg);
        let b = ev.eval(&cfg);
        assert_eq!(a, b);
        assert_eq!(ev.n_evals(), 2);
        assert_eq!(ev.n_sim, 1);
    }

    #[test]
    fn batch_matches_serial() {
        let mut ev = evaluator("gesummv");
        let t = ev.trace().clone();
        let configs: Vec<Box<[u32]>> = vec![
            t.baseline_max().into(),
            t.baseline_min().into(),
            t.baseline_max().iter().map(|&d| (d / 2).max(2)).collect(),
        ];
        let batch = ev.eval_batch(&configs);
        let mut ev2 = evaluator("gesummv");
        let serial: Vec<_> = configs.iter().map(|c| ev2.eval(c)).collect();
        assert_eq!(batch, serial);
    }

    #[test]
    fn parallel_batch_matches_serial_batch() {
        let bd = bench_suite::build("gesummv");
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let mut ev1 = Evaluator::new(t.clone());
        let mut ev4 = Evaluator::parallel(t.clone(), 4);
        let mut rng = crate::util::Rng::new(3);
        let ub = t.upper_bounds();
        let configs: Vec<Box<[u32]>> = (0..40)
            .map(|_| {
                ub.iter()
                    .map(|&u| rng.range_u32(2, u.max(2)))
                    .collect::<Box<[u32]>>()
            })
            .collect();
        assert_eq!(ev1.eval_batch(&configs), ev4.eval_batch(&configs));
    }

    #[test]
    fn pareto_over_history() {
        let mut ev = evaluator("bicg");
        let (maxp, minp) = ev.eval_baselines();
        assert!(maxp.is_feasible());
        let front = ev.pareto();
        assert!(!front.is_empty());
        // Baseline-Min (depth 2 everywhere) has zero BRAM; if feasible it
        // must put a zero-BRAM point on the front.
        if minp.is_feasible() {
            assert!(front.iter().any(|p| p.bram == 0));
        }
    }

    #[test]
    fn reset_run_keeps_or_clears_cache() {
        let mut ev = evaluator("bicg");
        let cfg = ev.trace().baseline_max();
        ev.eval(&cfg);
        ev.reset_run(false);
        assert_eq!(ev.n_evals(), 0);
        ev.eval(&cfg);
        assert_eq!(ev.n_sim, 1, "cache kept");
        ev.reset_run(true);
        ev.eval(&cfg);
        assert_eq!(ev.n_sim, 1, "cache cleared, resimulated");
    }
}
