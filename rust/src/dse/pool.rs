//! Leader/worker parallel evaluation: the leader (the optimizer loop)
//! proposes a batch of configurations; workers — each holding its own
//! cloned [`FastSim`] engine over the shared trace — evaluate disjoint
//! chunks. `std::thread::scope` keeps lifetimes simple and the pool
//! allocation-light (the offline crate mirror has no rayon/tokio).

use crate::sim::fast::FastSim;

/// Simulate every configuration, returning latencies (`None` =
/// deadlock), preserving order. `threads == 1` runs inline on the given
/// engine clone-free.
pub fn parallel_latencies(
    proto: &FastSim,
    configs: &[Box<[u32]>],
    threads: usize,
) -> Vec<Option<u64>> {
    if threads <= 1 || configs.len() < 2 {
        let mut sim = proto.clone();
        return configs.iter().map(|c| sim.simulate(c).latency()).collect();
    }
    let threads = threads.min(configs.len());
    let chunk = configs.len().div_ceil(threads);
    let mut out: Vec<Option<u64>> = vec![None; configs.len()];
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (i, chunk_cfgs) in configs.chunks(chunk).enumerate() {
            let mut sim = proto.clone();
            handles.push((
                i,
                s.spawn(move || {
                    chunk_cfgs
                        .iter()
                        .map(|c| sim.simulate(c).latency())
                        .collect::<Vec<_>>()
                }),
            ));
        }
        for (i, h) in handles {
            let res = h.join().expect("worker panicked");
            out[i * chunk..i * chunk + res.len()].copy_from_slice(&res);
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::trace::collect_trace;
    use crate::util::Rng;
    use std::sync::Arc;

    #[test]
    fn pool_preserves_order_and_results() {
        let bd = bench_suite::build("fig2");
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let proto = FastSim::new(t.clone());
        let mut rng = Rng::new(11);
        let ub = t.upper_bounds();
        let configs: Vec<Box<[u32]>> = (0..33)
            .map(|_| {
                ub.iter()
                    .map(|&u| rng.range_u32(2, u.max(2)))
                    .collect::<Box<[u32]>>()
            })
            .collect();
        let serial = parallel_latencies(&proto, &configs, 1);
        for threads in [2, 3, 8, 64] {
            assert_eq!(parallel_latencies(&proto, &configs, threads), serial);
        }
    }

    #[test]
    fn empty_and_single_config() {
        let bd = bench_suite::build("fig2");
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let proto = FastSim::new(t.clone());
        assert!(parallel_latencies(&proto, &[], 4).is_empty());
        let one: Vec<Box<[u32]>> = vec![t.baseline_max().into()];
        assert_eq!(parallel_latencies(&proto, &one, 4).len(), 1);
    }
}
