//! Latency-only batch evaluation — a thin shim over the engine's
//! [`WorkerPool`](super::engine::WorkerPool). Kept because the perf
//! benches and a few tools want raw simulator fan-out without the memo
//! cache, history, or BRAM accounting of the full
//! [`EvalEngine`](super::EvalEngine). Each call builds a transient pool
//! (this standalone entry point has no engine to borrow one from) —
//! long-lived callers that batch repeatedly should hold an `EvalEngine`
//! or a `WorkerPool` instead and amortize the spawn cost.
//!
//! Unlike the old per-batch `std::thread::scope` implementation, the pool
//! here handles every edge case uniformly: an empty slice returns
//! immediately, a single configuration runs inline, and `threads`
//! larger than the batch simply leaves the surplus workers idle.
//!
//! Under the lane-batched backend ([`BatchedSim`](crate::sim::BatchedSim))
//! thread fan-out is the wrong tool: one SoA graph walk already answers
//! the whole batch, so [`lane_latencies`] packs the configurations into
//! lanes of a single bank instead of dispatching jobs — the same
//! replacement [`EvalEngine`](super::EvalEngine) makes when
//! `--backend batched` is selected.
//!
//! The pool deliberately has **no** panic isolation: a worker panic
//! propagates and fails the run. Containing faults is the sweep
//! orchestrator's job alone — [`dse::sweep`](super::sweep) catches at
//! the cell boundary, records the cell as failed in its manifest, and
//! keeps sibling cells running (CI audits that the unwind catch
//! appears nowhere else).

use super::engine::WorkerPool;
use crate::sim::fast::FastSim;
use crate::sim::scenario::ScenarioSim;

/// Simulate every configuration, returning latencies (`None` =
/// deadlock), preserving order. `threads == 1` runs inline on a local
/// engine clone.
pub fn parallel_latencies(
    proto: &FastSim,
    configs: &[Box<[u32]>],
    threads: usize,
) -> Vec<Option<u64>> {
    if configs.is_empty() {
        return Vec::new();
    }
    if threads <= 1 || configs.len() < 2 {
        let mut sim = proto.clone();
        return configs.iter().map(|c| sim.simulate(c).latency()).collect();
    }
    // The pool's workers hold scenario banks; wrapping the prototype as
    // a single-scenario bank preserves its options and retained schedule.
    let bank = ScenarioSim::from_fastsim(proto.clone());
    let mut pool = WorkerPool::new(&bank, threads.min(configs.len()), None);
    pool.run_latencies(configs)
}

/// Lane-batched counterpart of [`parallel_latencies`]: evaluate every
/// configuration through one [`ScenarioSim::eval_batch`] call on a clone
/// of `bank` (no threads, no pool) — with a lane-batched backend the
/// whole batch is one SoA walk per scenario member. Order-preserving;
/// `None` = deadlock in some scenario.
pub fn lane_latencies(bank: &ScenarioSim, configs: &[Box<[u32]>]) -> Vec<Option<u64>> {
    if configs.is_empty() {
        return Vec::new();
    }
    let mut bank = bank.clone();
    bank.eval_batch(configs, false)
        .into_iter()
        .map(|le| le.latency)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::trace::collect_trace;
    use crate::util::Rng;
    use std::sync::Arc;

    #[test]
    fn pool_preserves_order_and_results() {
        let bd = bench_suite::build("fig2");
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let proto = FastSim::new(t.clone());
        let mut rng = Rng::new(11);
        let ub = t.upper_bounds();
        let configs: Vec<Box<[u32]>> = (0..33)
            .map(|_| {
                ub.iter()
                    .map(|&u| rng.range_u32(2, u.max(2)))
                    .collect::<Box<[u32]>>()
            })
            .collect();
        let serial = parallel_latencies(&proto, &configs, 1);
        for threads in [2, 3, 8, 64] {
            assert_eq!(parallel_latencies(&proto, &configs, threads), serial);
        }
    }

    #[test]
    fn empty_and_single_config() {
        let bd = bench_suite::build("fig2");
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let proto = FastSim::new(t.clone());
        assert!(parallel_latencies(&proto, &[], 4).is_empty());
        let one: Vec<Box<[u32]>> = vec![t.baseline_max().into()];
        assert_eq!(parallel_latencies(&proto, &one, 4).len(), 1);
    }

    #[test]
    fn more_threads_than_configs() {
        // Regression: the old chunked implementation computed chunk
        // indices from a thread count that could exceed the batch.
        let bd = bench_suite::build("bicg");
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let proto = FastSim::new(t.clone());
        let configs: Vec<Box<[u32]>> = vec![
            t.baseline_max().into(),
            t.baseline_min().into(),
            t.baseline_max().iter().map(|&d| (d / 2).max(2)).collect(),
        ];
        let serial = parallel_latencies(&proto, &configs, 1);
        for threads in [3, 4, 7, 128] {
            assert_eq!(parallel_latencies(&proto, &configs, threads), serial);
        }
    }

    #[test]
    fn lane_latencies_match_thread_fanout() {
        use crate::sim::{BackendKind, SimOptions};
        use crate::trace::workload::Workload;
        let bd = bench_suite::build("fig2");
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let proto = FastSim::new(t.clone());
        let mut rng = Rng::new(17);
        let ub = t.upper_bounds();
        let configs: Vec<Box<[u32]>> = (0..25)
            .map(|_| {
                ub.iter()
                    .map(|&u| rng.range_u32(2, u.max(2)))
                    .collect::<Box<[u32]>>()
            })
            .collect();
        let want = parallel_latencies(&proto, &configs, 4);
        let w = Workload::single(Arc::clone(&t));
        for kind in [BackendKind::Fast, BackendKind::Compiled, BackendKind::Batched] {
            let bank = ScenarioSim::with_backend(&w, SimOptions::default(), kind);
            assert_eq!(lane_latencies(&bank, &configs), want, "{kind:?}");
        }
        let bank = ScenarioSim::from_fastsim(proto);
        assert!(lane_latencies(&bank, &[]).is_empty());
    }

    #[test]
    fn two_configs_two_threads() {
        let bd = bench_suite::build("gesummv");
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let proto = FastSim::new(t.clone());
        let configs: Vec<Box<[u32]>> =
            vec![t.baseline_max().into(), t.baseline_min().into()];
        let serial = parallel_latencies(&proto, &configs, 1);
        assert_eq!(parallel_latencies(&proto, &configs, 2), serial);
    }
}
