//! The sweep launcher: a JSON run-configuration describing a whole
//! experiment grid (designs × optimizers × seeds), executed in one
//! command — the front end the benches and CI use.
//!
//! ```json
//! {
//!   "designs": ["gemm", "k15mmseq",
//!               {"design": "flowgnn_pna",
//!                "scenarios": [[64, 512, 7], [64, 512, 8]]}],
//!   "optimizers": ["greedy", "grouped_sa"],
//!   "budget": 1000,
//!   "seeds": [1, 2],
//!   "jobs": 4,
//!   "alpha": 0.7,
//!   "out_dir": "results/sweep"
//! }
//! ```
//!
//! A design entry is either a bare name (single scenario under the
//! suite's default args) or an object with a `"scenarios"` list of
//! kernel-argument arrays — each array becomes one scenario of a
//! [`Workload`] and the run sizes for the worst case over all of them.
//! (`"threads"` is accepted as a legacy alias of `"jobs"`; `"prune":
//! false` disables the simulation-free pruning layer for A/B runs, like
//! the CLI's `--no-prune`; `"backend": "fast" | "compiled" | "batched"`
//! selects the simulation backend, like the CLI's `--backend` — results
//! are bit-identical either way, only the throughput profile differs.)

use crate::bench_suite;
use crate::dse::{drive, Evaluator};
use crate::opt::objective::select_highlight;
use crate::opt::{self, Space};
use crate::report;
use crate::trace::collect_trace;
use crate::trace::workload::Workload;
use crate::util::Json;
use anyhow::{anyhow, Context, Result};
use std::sync::Arc;

/// One design entry of a sweep: a suite design plus the scenario
/// argument sets to size for (empty = the suite's default args).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DesignSpec {
    pub name: String,
    pub arg_sets: Vec<Vec<i64>>,
}

/// Parsed sweep configuration.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub designs: Vec<DesignSpec>,
    pub optimizers: Vec<String>,
    pub budget: usize,
    pub seeds: Vec<u64>,
    /// Persistent simulation workers per engine (1 = serial).
    pub jobs: usize,
    pub alpha: f64,
    /// Simulation-free pruning (oracle + clamp + early exit). On by
    /// default; `"prune": false` is the sweep-config escape hatch
    /// mirroring the CLI's `--no-prune`.
    pub prune: bool,
    /// Simulation backend (`"backend"` key; mirrors the CLI's
    /// `--backend {fast,compiled,batched}`).
    pub backend: crate::sim::BackendKind,
    pub out_dir: Option<String>,
}

impl SweepConfig {
    pub fn from_json(j: &Json) -> Result<SweepConfig> {
        let strs = |key: &str| -> Result<Vec<String>> {
            j.get(key)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("sweep config: '{key}' must be an array"))?
                .iter()
                .map(|s| {
                    s.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| anyhow!("'{key}' entries must be strings"))
                })
                .collect()
        };
        let designs_json = j
            .get("designs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("sweep config: 'designs' must be an array"))?;
        let mut designs = Vec::with_capacity(designs_json.len());
        for d in designs_json {
            if let Some(name) = d.as_str() {
                designs.push(DesignSpec {
                    name: name.to_string(),
                    arg_sets: Vec::new(),
                });
                continue;
            }
            let name = d
                .get("design")
                .and_then(|v| v.as_str())
                .ok_or_else(|| {
                    anyhow!(
                        "sweep config: design entries must be a name or \
                         {{\"design\", \"scenarios\"}}"
                    )
                })?
                .to_string();
            let sets = d.get("scenarios").and_then(|v| v.as_arr()).ok_or_else(|| {
                anyhow!("design '{name}': 'scenarios' must be an array of arg arrays")
            })?;
            let mut arg_sets = Vec::with_capacity(sets.len());
            for s in sets {
                let arr = s.as_arr().ok_or_else(|| {
                    anyhow!("design '{name}': each scenario must be an arg array")
                })?;
                arg_sets.push(
                    arr.iter()
                        .map(|v| {
                            v.as_f64().map(|x| x as i64).ok_or_else(|| {
                                anyhow!("design '{name}': scenario args must be numbers")
                            })
                        })
                        .collect::<Result<Vec<i64>>>()?,
                );
            }
            if arg_sets.is_empty() {
                return Err(anyhow!("design '{name}': empty scenario list"));
            }
            designs.push(DesignSpec { name, arg_sets });
        }
        let optimizers = strs("optimizers")?;
        for o in &optimizers {
            if opt::by_name(o, 0).is_none() {
                return Err(anyhow!("unknown optimizer '{o}'"));
            }
        }
        for d in &designs {
            if bench_suite::try_build(&d.name).is_none() {
                return Err(anyhow!("unknown design '{}'", d.name));
            }
        }
        let jobs = j
            .get("jobs")
            .or_else(|| j.get("threads"))
            .and_then(|v| v.as_u64())
            .unwrap_or(1) as usize;
        let backend = match j.get("backend").and_then(|v| v.as_str()) {
            None => crate::sim::BackendKind::Fast,
            Some(s) => crate::sim::BackendKind::parse(s)
                .map_err(|e| anyhow!("sweep config: {e}"))?,
        };
        Ok(SweepConfig {
            designs,
            optimizers,
            budget: j.get("budget").and_then(|v| v.as_u64()).unwrap_or(1000) as usize,
            seeds: j
                .get("seeds")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|s| s.as_u64()).collect())
                .unwrap_or_else(|| vec![1]),
            jobs,
            alpha: j.get("alpha").and_then(|v| v.as_f64()).unwrap_or(0.7),
            prune: j.get("prune").and_then(|v| v.as_bool()).unwrap_or(true),
            backend,
            out_dir: j
                .get("out_dir")
                .and_then(|v| v.as_str())
                .map(str::to_string),
        })
    }

    pub fn from_file(path: &str) -> Result<SweepConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::from_json(&Json::parse(&text).context("parsing sweep config")?)
    }
}

/// One (design, optimizer, seed) result row.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub design: String,
    pub optimizer: String,
    pub seed: u64,
    /// Scenarios in the run's workload (1 = plain single-trace run).
    pub scenarios: usize,
    pub evals: usize,
    /// Actual simulator invocations (evals minus memo hits).
    pub sims: u64,
    /// Fraction of simulations served as delta-incremental replays.
    pub incr_rate: f64,
    /// Fraction of trace ops actually re-propagated (1.0 = all full
    /// replays).
    pub replay_frac: f64,
    /// Fraction of proposals answered by the dominance oracle.
    pub oracle_rate: f64,
    /// Fraction of proposals evaluated at a clamp-canonical point.
    pub clamp_rate: f64,
    /// Simulations avoided outright by the pruning layer.
    pub sims_avoided: u64,
    /// Mean depth-vector lanes per lane-batched graph walk (0 unless
    /// the batched backend ran).
    pub lanes_per_walk: f64,
    /// Fraction of lane capacity occupied across batched walks.
    pub batch_occupancy: f64,
    /// Graph traversals saved by lane packing vs one walk per lane.
    pub walks_saved: u64,
    pub elapsed_secs: f64,
    pub front_size: usize,
    pub star_latency: u64,
    pub star_bram: u32,
    pub base_latency: u64,
    pub base_bram: u32,
    pub min_deadlocked: bool,
}

/// Execute the sweep; returns all rows (and writes per-run JSON when
/// `out_dir` is set).
pub fn run_sweep(cfg: &SweepConfig) -> Result<Vec<SweepRow>> {
    let mut rows = Vec::new();
    for spec in &cfg.designs {
        let design = &spec.name;
        let bd = bench_suite::build(design);
        let workload = if spec.arg_sets.is_empty() {
            Workload::single(Arc::new(collect_trace(&bd.design, &bd.args)?))
        } else {
            Workload::from_design_args(&bd.design, &spec.arg_sets)?
        };
        let workload = Arc::new(workload);
        let space = Space::from_workload(&workload);
        let mut ev = Evaluator::for_workload_with_sim(workload.clone(), cfg.jobs, cfg.backend);
        ev.set_prune(cfg.prune);
        let (maxp, minp) = ev.eval_baselines();
        let (base_lat, base_bram) = (
            maxp.latency
                .ok_or_else(|| anyhow!("{design}: Baseline-Max deadlocks"))?,
            maxp.bram,
        );
        for optimizer in &cfg.optimizers {
            for &seed in &cfg.seeds {
                ev.reset_run(true);
                let mut o = opt::by_name(optimizer, seed).unwrap();
                let t0 = std::time::Instant::now();
                drive(&mut *o, &mut ev, &space, cfg.budget);
                let dt = t0.elapsed().as_secs_f64();
                let front = ev.pareto();
                let pts: Vec<(u64, u32)> =
                    front.iter().map(|p| (p.latency.unwrap(), p.bram)).collect();
                let star = select_highlight(&pts, cfg.alpha, base_lat, base_bram)
                    .map(|i| pts[i])
                    .unwrap_or((base_lat, base_bram));
                rows.push(SweepRow {
                    design: design.clone(),
                    optimizer: optimizer.clone(),
                    seed,
                    scenarios: workload.num_scenarios(),
                    evals: ev.n_evals(),
                    sims: ev.n_sim,
                    incr_rate: ev.stats().incremental_rate(),
                    replay_frac: ev.stats().replay_fraction(),
                    oracle_rate: ev.stats().oracle_rate(),
                    clamp_rate: ev.stats().clamp_rate(),
                    sims_avoided: ev.stats().sims_avoided,
                    lanes_per_walk: ev.stats().lanes_per_walk(),
                    batch_occupancy: ev.stats().batch_occupancy(),
                    walks_saved: ev.stats().walks_saved(),
                    elapsed_secs: dt,
                    front_size: front.len(),
                    star_latency: star.0,
                    star_bram: star.1,
                    base_latency: base_lat,
                    base_bram,
                    min_deadlocked: !minp.is_feasible(),
                });
                if let Some(dir) = &cfg.out_dir {
                    let j = report::run_to_json(
                        design,
                        optimizer,
                        seed,
                        cfg.budget,
                        &ev.history,
                        &front,
                        dt,
                        Some(&ev),
                    );
                    report::write_file(
                        &format!("{dir}/{design}_{optimizer}_s{seed}.json"),
                        &j.to_string_pretty(),
                    )?;
                }
            }
        }
    }
    Ok(rows)
}

/// Render sweep rows as a markdown summary table.
pub fn rows_to_markdown(rows: &[SweepRow]) -> String {
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.design.clone(),
                r.optimizer.clone(),
                r.seed.to_string(),
                r.scenarios.to_string(),
                format!("{:.3}", r.elapsed_secs),
                r.sims.to_string(),
                format!("{:.0}%", r.incr_rate * 100.0),
                format!("{:.0}%", r.replay_frac * 100.0),
                format!("{:.0}%", r.oracle_rate * 100.0),
                format!("{:.0}%", r.clamp_rate * 100.0),
                r.sims_avoided.to_string(),
                format!("{:.1}", r.lanes_per_walk),
                format!("{:.0}%", r.batch_occupancy * 100.0),
                r.front_size.to_string(),
                format!("{:.4}", r.star_latency as f64 / r.base_latency as f64),
                format!(
                    "{:.1}%",
                    (r.base_bram as f64 - r.star_bram as f64) / r.base_bram.max(1) as f64 * 100.0
                ),
                if r.min_deadlocked { "×→✓" } else { "" }.to_string(),
            ]
        })
        .collect();
    report::markdown_table(
        &[
            "design", "optimizer", "seed", "scen", "secs", "sims", "incr%", "replay%", "orcl%",
            "clmp%", "avoid", "ln/wk", "occ%", "front", "lat×", "BRAM↓", "rescue",
        ],
        &table_rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_parsing_and_validation() {
        let j = Json::parse(
            r#"{"designs": ["fig2"], "optimizers": ["greedy", "random"],
                "budget": 50, "seeds": [1, 2], "threads": 1}"#,
        )
        .unwrap();
        let cfg = SweepConfig::from_json(&j).unwrap();
        assert_eq!(
            cfg.designs,
            vec![DesignSpec {
                name: "fig2".into(),
                arg_sets: Vec::new()
            }]
        );
        assert_eq!(cfg.seeds, vec![1, 2]);
        assert_eq!(cfg.budget, 50);
        assert_eq!(cfg.alpha, 0.7);
        assert_eq!(cfg.jobs, 1, "threads accepted as legacy alias");
        assert!(cfg.prune, "pruning defaults on");

        let j = Json::parse(r#"{"designs": ["fig2"], "optimizers": ["greedy"], "jobs": 4}"#)
            .unwrap();
        assert_eq!(SweepConfig::from_json(&j).unwrap().jobs, 4);

        let j = Json::parse(
            r#"{"designs": ["fig2"], "optimizers": ["greedy"], "prune": false}"#,
        )
        .unwrap();
        assert!(!SweepConfig::from_json(&j).unwrap().prune);

        let bad = Json::parse(r#"{"designs": ["nope"], "optimizers": ["greedy"]}"#).unwrap();
        assert!(SweepConfig::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"designs": ["fig2"], "optimizers": ["nope"]}"#).unwrap();
        assert!(SweepConfig::from_json(&bad).is_err());
    }

    #[test]
    fn sweep_executes_grid() {
        let j = Json::parse(
            r#"{"designs": ["fig2", "gesummv"], "optimizers": ["greedy", "grouped_sa"],
                "budget": 60, "seeds": [1], "jobs": 1}"#,
        )
        .unwrap();
        let cfg = SweepConfig::from_json(&j).unwrap();
        let rows = run_sweep(&cfg).unwrap();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.front_size >= 1, "{}/{}", r.design, r.optimizer);
            assert!(r.star_latency > 0);
            assert!(r.sims as usize <= r.evals + 2);
        }
        assert!(rows.iter().any(|r| r.design == "fig2" && r.min_deadlocked));
        assert!(rows.iter().all(|r| r.scenarios == 1));
        let md = rows_to_markdown(&rows);
        assert!(md.contains("fig2"));
        assert!(md.contains("×→✓"));
    }

    #[test]
    fn prune_toggle_changes_cost_never_results() {
        let grid = |prune: bool| {
            let j = Json::parse(&format!(
                r#"{{"designs": [{{"design": "fig2", "scenarios": [[8], [16]]}}],
                    "optimizers": ["grouped_sa"], "budget": 80, "seeds": [1],
                    "jobs": 1, "prune": {prune}}}"#
            ))
            .unwrap();
            run_sweep(&SweepConfig::from_json(&j).unwrap()).unwrap()
        };
        let on = grid(true);
        let off = grid(false);
        assert_eq!(on[0].star_latency, off[0].star_latency);
        assert_eq!(on[0].star_bram, off[0].star_bram);
        assert_eq!(on[0].front_size, off[0].front_size);
        assert_eq!(on[0].evals, off[0].evals);
        assert!(on[0].sims <= off[0].sims, "pruning must never add sims");
        assert_eq!(off[0].oracle_rate, 0.0);
        assert_eq!(off[0].sims_avoided, 0);
    }

    #[test]
    fn backend_key_selects_simulator_and_never_changes_results() {
        let grid = |backend: &str| {
            let j = Json::parse(&format!(
                r#"{{"designs": [{{"design": "fig2", "scenarios": [[8], [16]]}}],
                    "optimizers": ["grouped_sa"], "budget": 60, "seeds": [1],
                    "jobs": 1, "backend": "{backend}"}}"#
            ))
            .unwrap();
            run_sweep(&SweepConfig::from_json(&j).unwrap()).unwrap()
        };
        let fast = grid("fast");
        for backend in ["compiled", "batched"] {
            let other = grid(backend);
            assert_eq!(fast[0].star_latency, other[0].star_latency, "{backend}");
            assert_eq!(fast[0].star_bram, other[0].star_bram, "{backend}");
            assert_eq!(fast[0].front_size, other[0].front_size, "{backend}");
            assert_eq!(fast[0].evals, other[0].evals, "{backend}");
            assert_eq!(fast[0].sims, other[0].sims, "{backend}");
            if backend == "batched" {
                assert!(other[0].lanes_per_walk >= 1.0, "lane telemetry missing");
                assert!(other[0].batch_occupancy > 0.0);
            } else {
                assert_eq!(other[0].lanes_per_walk, 0.0);
            }
        }
        assert_eq!(fast[0].lanes_per_walk, 0.0);
        assert_eq!(fast[0].walks_saved, 0);

        let defaulted = Json::parse(
            r#"{"designs": ["fig2"], "optimizers": ["greedy"]}"#,
        )
        .unwrap();
        assert_eq!(
            SweepConfig::from_json(&defaulted).unwrap().backend,
            crate::sim::BackendKind::Fast
        );
        let bad = Json::parse(
            r#"{"designs": ["fig2"], "optimizers": ["greedy"], "backend": "gpu"}"#,
        )
        .unwrap();
        assert!(SweepConfig::from_json(&bad).is_err());
    }

    #[test]
    fn scenario_lists_build_workload_runs() {
        let j = Json::parse(
            r#"{"designs": [{"design": "fig2", "scenarios": [[8], [16]]}],
                "optimizers": ["greedy"], "budget": 60, "seeds": [1], "jobs": 1}"#,
        )
        .unwrap();
        let cfg = SweepConfig::from_json(&j).unwrap();
        assert_eq!(cfg.designs[0].arg_sets, vec![vec![8], vec![16]]);
        let rows = run_sweep(&cfg).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].scenarios, 2);
        // Worst-case baseline latency comes from the n=16 scenario, so it
        // matches a plain single-scenario n=16 run's baseline.
        let j16 = Json::parse(
            r#"{"designs": [{"design": "fig2", "scenarios": [[16]]}],
                "optimizers": ["greedy"], "budget": 60, "seeds": [1], "jobs": 1}"#,
        )
        .unwrap();
        let rows16 = run_sweep(&SweepConfig::from_json(&j16).unwrap()).unwrap();
        assert_eq!(rows[0].base_latency, rows16[0].base_latency);
        let md = rows_to_markdown(&rows);
        assert!(md.contains("| 2 |"), "scenario count column missing: {md}");

        // Malformed scenario entries are rejected.
        let bad = Json::parse(
            r#"{"designs": [{"design": "fig2", "scenarios": []}], "optimizers": ["greedy"]}"#,
        )
        .unwrap();
        assert!(SweepConfig::from_json(&bad).is_err());
        let bad = Json::parse(
            r#"{"designs": [{"design": "fig2", "scenarios": [["x"]]}], "optimizers": ["greedy"]}"#,
        )
        .unwrap();
        assert!(SweepConfig::from_json(&bad).is_err());
    }
}
