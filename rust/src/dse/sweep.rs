//! The fault-tolerant sweep orchestrator: a JSON run-configuration
//! describing a whole experiment grid (designs × optimizers × seeds),
//! executed as independently checkpointed *cells* by a work-stealing
//! runner that survives crashes, panics, and budget blowouts — the
//! front end the benches, CI matrix jobs, and the fleet-scale service
//! path use.
//!
//! ```json
//! {
//!   "designs": ["gemm", "k15mmseq",
//!               {"design": "flowgnn_pna",
//!                "scenarios": [[64, 512, 7], [64, 512, 8]]}],
//!   "optimizers": ["greedy", "grouped_sa"],
//!   "budget": 1000,
//!   "seeds": [1, 2],
//!   "jobs": 4,
//!   "alpha": 0.7,
//!   "out_dir": "results/sweep",
//!   "resume": false,
//!   "shard": "0/2",
//!   "max_retries": 1,
//!   "cell_timeout_secs": 120.0,
//!   "cell_sim_budget": 100000,
//!   "cell_workers": 1
//! }
//! ```
//!
//! A design entry is either a bare name (single scenario under the
//! suite's default args) or an object with a `"scenarios"` list of
//! kernel-argument arrays — each array becomes one scenario of a
//! [`Workload`] and the run sizes for the worst case over all of them.
//! (`"threads"` is accepted as a legacy alias of `"jobs"`; `"prune":
//! false` disables the simulation-free pruning layer for A/B runs, like
//! the CLI's `--no-prune`; `"bounds": false` likewise disables the
//! engine side of the analytic depth-bounds pass, like the CLI's
//! `--no-bounds`; `"backend": "fast" | "compiled" | "batched"`
//! selects the simulation backend, like the CLI's `--backend` — results
//! are bit-identical either way, only the throughput profile differs.)
//! Unknown top-level keys are rejected with the accepted key set, so a
//! typo never falls through to a silent default.
//!
//! # Orchestration model
//!
//! The grid is flattened into cells — one [`CellKey`] per
//! (design, optimizer, seed) — each identified by a **stable 64-bit id**
//! (FNV-1a over the design name, its scenario arg-sets, the optimizer,
//! the seed, and every result-affecting config field: backend, budget,
//! alpha, prune, bounds, sim budget). Because cell results are
//! deterministic (serial/parallel, pruned/unpruned, bounded/unbounded,
//! and all backends are bit-identical
//! by pinned invariant), a cell id names its result, which is what makes
//! the following safe:
//!
//! - **Checkpointing** — every artifact (per-cell run record, the
//!   `manifest.json` status map, aggregates) is written atomically via
//!   [`crate::util::atomic_write`]; a crash leaves whole old files or
//!   whole new files, never prefixes. The manifest flips a cell
//!   `pending` → `done`/`failed{reason}` only *after* its record file
//!   landed.
//! - **Resume** (`"resume": true`) — prior `manifest*.json` files in
//!   `out_dir` are merged (config-hash-checked so incompatible sweeps
//!   can't mix); `done` cells are replayed from their embedded result
//!   rows without touching their record files (byte-for-byte skip), and
//!   `failed` cells are retried up to `"max_retries"` more times with
//!   exponential backoff (`"retry_backoff_ms"` doubling per attempt).
//! - **Sharding** (`"shard": "i/n"`) — a cell belongs to shard
//!   `id % n == i`, a deterministic partition, so CI matrix jobs split
//!   one sweep across machines; their out-dirs merge cleanly and a final
//!   unsharded `--resume` pass over the merged directory re-runs nothing
//!   and emits the aggregate CSV/JSON.
//! - **Graceful degradation** — each cell's engine carries a
//!   [`CancelToken`] with the config's wall-clock / simulation budgets
//!   ([`drive`](crate::dse::drive) checks it per ask/tell round;
//!   best-so-far front survives, flagged `truncated`), and the whole
//!   cell body runs under `catch_unwind` so a poisoned design records a
//!   `failed` entry with the panic message while sibling cells continue.
//!   (Worker-pool threads own cloned sims, so unwinding a cell cannot
//!   corrupt another cell's state; `catch_unwind` is confined to this
//!   module, audited in CI.)
//!
//! Cells sharing a design clone one prototype [`ScenarioSim`] bank, so
//! compiled/batched event-graph tables are built once per design and
//! `Arc`-shared across cells instead of recompiled per cell.

use crate::bench_suite;
use crate::dse::cancel::CancelToken;
use crate::dse::{drive, Evaluator, NativeBram};
use crate::opt::objective::select_highlight;
use crate::opt::{self, Space};
use crate::report::{self, csv::Csv};
use crate::sim::scenario::ScenarioSim;
use crate::sim::{BackendKind, SimOptions};
use crate::trace::collect_trace;
use crate::trace::workload::Workload;
use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One design entry of a sweep: a suite design plus the scenario
/// argument sets to size for (empty = the suite's default args).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct DesignSpec {
    pub name: String,
    pub arg_sets: Vec<Vec<i64>>,
}

/// The accepted top-level sweep-config keys. Parsing rejects anything
/// else by name, so a typo (`"budgett"`) fails loudly instead of
/// falling through to a silent default.
pub const ACCEPTED_KEYS: &[&str] = &[
    "alpha",
    "backend",
    "bounds",
    "budget",
    "cache_dir",
    "cell_sim_budget",
    "cell_timeout_secs",
    "cell_workers",
    "certify",
    "certify_budget",
    "designs",
    "distill",
    "jobs",
    "max_retries",
    "optimizers",
    "out_dir",
    "prune",
    "resume",
    "retry_backoff_ms",
    "seeds",
    "shard",
    "threads",
];

/// Parsed sweep configuration.
#[derive(Debug, Clone)]
pub struct SweepConfig {
    pub designs: Vec<DesignSpec>,
    pub optimizers: Vec<String>,
    pub budget: usize,
    pub seeds: Vec<u64>,
    /// Persistent simulation workers per engine (1 = serial).
    pub jobs: usize,
    pub alpha: f64,
    /// Simulation-free pruning (oracle + clamp + early exit). On by
    /// default; `"prune": false` is the sweep-config escape hatch
    /// mirroring the CLI's `--no-prune`.
    pub prune: bool,
    /// Engine-side analytic depth bounds (sub-floor short-circuit,
    /// oracle seeding, tightened clamp caps). On by default; `"bounds":
    /// false` mirrors the CLI's `--no-bounds`.
    pub bounds: bool,
    /// Simulation backend (`"backend"` key; mirrors the CLI's
    /// `--backend {fast,compiled,batched}`).
    pub backend: BackendKind,
    /// Run multi-scenario cells on the dominance-distilled scenario bank
    /// with the full-bank re-verify fixpoint (`"distill": true`; mirrors
    /// the CLI's `--distill`). Fronts and stars stay bit-identical —
    /// only the scenario-simulation count drops. Single-scenario cells
    /// are unaffected.
    pub distill: bool,
    /// Emit a robustness certificate for each cell's ★ config by
    /// adversarially hunting the design's kernel-argument space
    /// (`"certify": true`; designs without an argument space record
    /// `no-arg-space`).
    pub certify: bool,
    /// Hunt budget per certificate (`"certify_budget"`, default 64).
    pub certify_budget: usize,
    pub out_dir: Option<String>,
    /// Cross-run snapshot store directory (`"cache_dir"`; mirrors the
    /// CLI's `--cache-dir`). When set, each completed cell saves its
    /// engine's memo/oracle snapshot so later one-shot or `serve` runs
    /// over the same (design, workload, backend, regime) warm-start
    /// from it. Sweeps are a store *producer*: cells themselves always
    /// run cold, keeping rows bit-reproducible regardless of what is
    /// already cached. Orchestration-only, like `resume`/`out_dir` —
    /// not part of the config fingerprint.
    pub cache_dir: Option<String>,
    /// Merge prior `manifest*.json` files in `out_dir` and skip `done`
    /// cells byte-for-byte (`--resume`).
    pub resume: bool,
    /// Extra attempts for a failed cell beyond the first (so a cell runs
    /// at most `1 + max_retries` times per invocation).
    pub max_retries: u64,
    /// Base backoff between retry attempts; doubles per attempt.
    pub retry_backoff_ms: u64,
    /// Per-cell wall-clock budget; on expiry the cell keeps its
    /// best-so-far front, flagged truncated.
    pub cell_timeout_secs: Option<f64>,
    /// Per-cell simulation-count budget (checked per ask/tell round).
    pub cell_sim_budget: Option<u64>,
    /// Deterministic cell partition `(i, n)`: this invocation runs only
    /// cells with `id % n == i` (`--shard i/n`).
    pub shard: Option<(usize, usize)>,
    /// Concurrent cell workers (each cell still gets `jobs` simulation
    /// workers; 1 = cells run one at a time).
    pub cell_workers: usize,
}

/// Parse a `"i/n"` shard designator, validating `n >= 1` and `i < n`.
pub fn parse_shard(s: &str) -> Result<(usize, usize)> {
    let (a, b) = s
        .split_once('/')
        .ok_or_else(|| anyhow!("shard must be 'i/n' (e.g. '0/4'), got '{s}'"))?;
    let idx: usize = a
        .trim()
        .parse()
        .map_err(|_| anyhow!("shard index must be an integer, got '{a}'"))?;
    let total: usize = b
        .trim()
        .parse()
        .map_err(|_| anyhow!("shard count must be an integer, got '{b}'"))?;
    if total == 0 {
        bail!("shard count must be >= 1, got '{s}'");
    }
    if idx >= total {
        bail!("shard index {idx} out of range for {total} shard(s)");
    }
    Ok((idx, total))
}

impl SweepConfig {
    pub fn from_json(j: &Json) -> Result<SweepConfig> {
        let Json::Obj(map) = j else {
            bail!("sweep config must be a JSON object");
        };
        for k in map.keys() {
            if !ACCEPTED_KEYS.contains(&k.as_str()) {
                bail!(
                    "sweep config: unknown key '{k}' (accepted keys: {})",
                    ACCEPTED_KEYS.join(", ")
                );
            }
        }
        let strs = |key: &str| -> Result<Vec<String>> {
            j.get(key)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("sweep config: '{key}' must be an array"))?
                .iter()
                .map(|s| {
                    s.as_str()
                        .map(str::to_string)
                        .ok_or_else(|| anyhow!("'{key}' entries must be strings"))
                })
                .collect()
        };
        let designs_json = j
            .get("designs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("sweep config: 'designs' must be an array"))?;
        let mut designs = Vec::with_capacity(designs_json.len());
        for d in designs_json {
            if let Some(name) = d.as_str() {
                designs.push(DesignSpec {
                    name: name.to_string(),
                    arg_sets: Vec::new(),
                });
                continue;
            }
            let name = d
                .get("design")
                .and_then(|v| v.as_str())
                .ok_or_else(|| {
                    anyhow!(
                        "sweep config: design entries must be a name or \
                         {{\"design\", \"scenarios\"}}"
                    )
                })?
                .to_string();
            let sets = d.get("scenarios").and_then(|v| v.as_arr()).ok_or_else(|| {
                anyhow!("design '{name}': 'scenarios' must be an array of arg arrays")
            })?;
            let mut arg_sets = Vec::with_capacity(sets.len());
            for s in sets {
                let arr = s.as_arr().ok_or_else(|| {
                    anyhow!("design '{name}': each scenario must be an arg array")
                })?;
                arg_sets.push(
                    arr.iter()
                        .map(|v| {
                            v.as_f64().map(|x| x as i64).ok_or_else(|| {
                                anyhow!("design '{name}': scenario args must be numbers")
                            })
                        })
                        .collect::<Result<Vec<i64>>>()?,
                );
            }
            if arg_sets.is_empty() {
                return Err(anyhow!("design '{name}': empty scenario list"));
            }
            designs.push(DesignSpec { name, arg_sets });
        }
        let optimizers = strs("optimizers")?;
        for o in &optimizers {
            if opt::by_name(o, 0).is_none() {
                return Err(anyhow!("unknown optimizer '{o}'"));
            }
        }
        for d in &designs {
            if bench_suite::try_build(&d.name).is_none() {
                return Err(anyhow!("unknown design '{}'", d.name));
            }
        }
        let jobs = j
            .get("jobs")
            .or_else(|| j.get("threads"))
            .and_then(|v| v.as_u64())
            .unwrap_or(1) as usize;
        let backend = match j.get("backend").and_then(|v| v.as_str()) {
            None => BackendKind::Fast,
            Some(s) => BackendKind::parse(s).map_err(|e| anyhow!("sweep config: {e}"))?,
        };
        let shard = match j.get("shard") {
            None => None,
            Some(v) => {
                let s = v.as_str().ok_or_else(|| {
                    anyhow!("sweep config: 'shard' must be a string like \"0/2\"")
                })?;
                Some(parse_shard(s)?)
            }
        };
        let cell_timeout_secs = match j.get("cell_timeout_secs") {
            None => None,
            Some(v) => {
                let t = v.as_f64().ok_or_else(|| {
                    anyhow!("sweep config: 'cell_timeout_secs' must be a number")
                })?;
                if t <= 0.0 {
                    bail!("sweep config: 'cell_timeout_secs' must be positive");
                }
                Some(t)
            }
        };
        Ok(SweepConfig {
            designs,
            optimizers,
            budget: j.get("budget").and_then(|v| v.as_u64()).unwrap_or(1000) as usize,
            seeds: j
                .get("seeds")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|s| s.as_u64()).collect())
                .unwrap_or_else(|| vec![1]),
            jobs,
            alpha: j.get("alpha").and_then(|v| v.as_f64()).unwrap_or(0.7),
            prune: j.get("prune").and_then(|v| v.as_bool()).unwrap_or(true),
            bounds: j.get("bounds").and_then(|v| v.as_bool()).unwrap_or(true),
            backend,
            distill: j.get("distill").and_then(|v| v.as_bool()).unwrap_or(false),
            certify: j.get("certify").and_then(|v| v.as_bool()).unwrap_or(false),
            certify_budget: j
                .get("certify_budget")
                .and_then(|v| v.as_u64())
                .unwrap_or(64) as usize,
            out_dir: j
                .get("out_dir")
                .and_then(|v| v.as_str())
                .map(str::to_string),
            cache_dir: j
                .get("cache_dir")
                .and_then(|v| v.as_str())
                .map(str::to_string),
            resume: j.get("resume").and_then(|v| v.as_bool()).unwrap_or(false),
            max_retries: j.get("max_retries").and_then(|v| v.as_u64()).unwrap_or(1),
            retry_backoff_ms: j
                .get("retry_backoff_ms")
                .and_then(|v| v.as_u64())
                .unwrap_or(250),
            cell_timeout_secs,
            cell_sim_budget: j.get("cell_sim_budget").and_then(|v| v.as_u64()),
            shard,
            cell_workers: j
                .get("cell_workers")
                .and_then(|v| v.as_u64())
                .unwrap_or(1)
                .max(1) as usize,
        })
    }

    pub fn from_file(path: &str) -> Result<SweepConfig> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::from_json(&Json::parse(&text).context("parsing sweep config")?)
    }

    /// Canonical encoding of every config field that can change a cell's
    /// *results*. `jobs` is excluded (serial/parallel bit-identity is a
    /// pinned invariant), grid membership is excluded (shards and
    /// extended grids stay resume-compatible), and the wall-clock budget
    /// is excluded (nondeterministic by nature — a timeout-truncated
    /// cell is flagged in its row instead).
    fn fingerprint(&self) -> String {
        // distill never changes fronts/stars, but it does change a row's
        // simulation telemetry and `distilled` column; certify adds the
        // `certified` column. Both are row content, so both fingerprint.
        format!(
            "v2|budget={}|alpha={}|prune={}|backend={}|sim_budget={:?}|bounds={}\
             |distill={}|certify={}|certify_budget={}",
            self.budget,
            self.alpha,
            self.prune,
            self.backend.name(),
            self.cell_sim_budget,
            self.bounds,
            self.distill,
            self.certify,
            self.certify_budget
        )
    }

    /// Stable hash of the result-affecting config fields; manifests from
    /// a different hash refuse to merge on resume.
    pub fn config_hash(&self) -> u64 {
        fnv1a(self.fingerprint().as_bytes())
    }
}

/// FNV-1a 64-bit ([`crate::util::fnv1a`]) — stable across Rust versions
/// and machines (unlike `DefaultHasher`), which is what lets cell ids
/// name results in manifests shared between CI shards (and lets the
/// store's cache keys name engine state across processes).
use crate::util::fnv1a;

/// One (design × optimizer × seed) cell of the sweep grid.
#[derive(Debug, Clone)]
pub struct CellKey {
    pub design: DesignSpec,
    pub optimizer: String,
    pub seed: u64,
}

impl CellKey {
    /// Stable 64-bit cell id: FNV-1a over the cell coordinates and the
    /// config fingerprint. Deterministic results mean this id names the
    /// cell's *result*, so manifests keyed by it can be merged across
    /// shards and resumed across processes.
    pub fn id(&self, cfg: &SweepConfig) -> u64 {
        let mut s = format!("design={}", self.design.name);
        for set in &self.design.arg_sets {
            s.push(';');
            for a in set {
                s.push_str(&a.to_string());
                s.push(',');
            }
        }
        s.push_str(&format!(
            "|opt={}|seed={}|{}",
            self.optimizer,
            self.seed,
            cfg.fingerprint()
        ));
        fnv1a(s.as_bytes())
    }

    /// The manifest key: the cell id as 16 hex digits.
    pub fn id_hex(&self, cfg: &SweepConfig) -> String {
        format!("{:016x}", self.id(cfg))
    }

    /// File stem of the per-cell run record. Bare designs keep the
    /// historical `{design}_{optimizer}_s{seed}` name; multi-scenario
    /// entries insert a hash of their arg-sets so two workloads of the
    /// same design never collide on one file.
    pub fn file_stem(&self) -> String {
        if self.design.arg_sets.is_empty() {
            format!("{}_{}_s{}", self.design.name, self.optimizer, self.seed)
        } else {
            let mut enc = String::new();
            for set in &self.design.arg_sets {
                enc.push(';');
                for a in set {
                    enc.push_str(&a.to_string());
                    enc.push(',');
                }
            }
            format!(
                "{}_w{:08x}_{}_s{}",
                self.design.name,
                (fnv1a(enc.as_bytes()) & 0xffff_ffff) as u32,
                self.optimizer,
                self.seed
            )
        }
    }
}

/// One (design, optimizer, seed) result row.
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub design: String,
    pub optimizer: String,
    pub seed: u64,
    /// Scenarios in the run's workload (1 = plain single-trace run).
    pub scenarios: usize,
    pub evals: usize,
    /// Actual simulator invocations (evals minus memo hits).
    pub sims: u64,
    /// Fraction of simulations served as delta-incremental replays.
    pub incr_rate: f64,
    /// Fraction of trace ops actually re-propagated (1.0 = all full
    /// replays).
    pub replay_frac: f64,
    /// Fraction of proposals answered by the dominance oracle.
    pub oracle_rate: f64,
    /// Fraction of proposals evaluated at a clamp-canonical point.
    pub clamp_rate: f64,
    /// Simulations avoided outright by the pruning layer.
    pub sims_avoided: u64,
    /// Proposals answered by the analytic sub-floor short-circuit.
    pub bounds_floor_hits: u64,
    /// Channels whose clamp cap the analytic bound tightened below the
    /// write count (static per workload).
    pub cap_tightenings: u64,
    /// Mean depth-vector lanes per lane-batched graph walk (0 unless
    /// the batched backend ran).
    pub lanes_per_walk: f64,
    /// Fraction of lane capacity occupied across batched walks.
    pub batch_occupancy: f64,
    /// Graph traversals saved by lane packing vs one walk per lane.
    pub walks_saved: u64,
    pub elapsed_secs: f64,
    pub front_size: usize,
    pub star_latency: u64,
    pub star_bram: u32,
    pub base_latency: u64,
    pub base_bram: u32,
    pub min_deadlocked: bool,
    /// The cell hit its wall-clock or simulation budget and kept its
    /// best-so-far front instead of completing the proposal budget.
    pub truncated: bool,
    /// Distillation summary for distilled multi-scenario cells:
    /// `kept/total` plus `+n` promoted back by the re-verify fixpoint
    /// (e.g. `"2/3+1"`). Empty for plain cells.
    pub distilled: String,
    /// Robustness-certificate verdict for the ★ config
    /// ([`Certificate::verdict`](crate::dse::advhunt::Certificate)),
    /// or `no-arg-space` for static designs. Empty unless `"certify"`.
    pub certified: String,
}

/// Serialize a result row. `include_elapsed` is true for manifest
/// embedding (full fidelity) and false for the aggregate JSON, which
/// carries only deterministic fields so interrupted-then-resumed and
/// uninterrupted runs emit identical bytes.
fn row_to_json(r: &SweepRow, include_elapsed: bool) -> Json {
    let mut f = vec![
        ("design", Json::Str(r.design.clone())),
        ("optimizer", Json::Str(r.optimizer.clone())),
        ("seed", Json::Num(r.seed as f64)),
        ("scenarios", Json::Num(r.scenarios as f64)),
        ("evals", Json::Num(r.evals as f64)),
        ("sims", Json::Num(r.sims as f64)),
        // Rates clamp to finite on emission (a non-finite Json::Num
        // would serialize as null, and row_from_json round-trips these
        // through manifests on resume).
        ("incr_rate", Json::Num(report::finite_or_zero(r.incr_rate))),
        (
            "replay_frac",
            Json::Num(report::finite_or_zero(r.replay_frac)),
        ),
        (
            "oracle_rate",
            Json::Num(report::finite_or_zero(r.oracle_rate)),
        ),
        ("clamp_rate", Json::Num(report::finite_or_zero(r.clamp_rate))),
        ("sims_avoided", Json::Num(r.sims_avoided as f64)),
        ("bounds_floor_hits", Json::Num(r.bounds_floor_hits as f64)),
        ("cap_tightenings", Json::Num(r.cap_tightenings as f64)),
        (
            "lanes_per_walk",
            Json::Num(report::finite_or_zero(r.lanes_per_walk)),
        ),
        (
            "batch_occupancy",
            Json::Num(report::finite_or_zero(r.batch_occupancy)),
        ),
        ("walks_saved", Json::Num(r.walks_saved as f64)),
        ("front_size", Json::Num(r.front_size as f64)),
        ("star_latency", Json::Num(r.star_latency as f64)),
        ("star_bram", Json::Num(r.star_bram as f64)),
        ("base_latency", Json::Num(r.base_latency as f64)),
        ("base_bram", Json::Num(r.base_bram as f64)),
        ("min_deadlocked", Json::Bool(r.min_deadlocked)),
        ("truncated", Json::Bool(r.truncated)),
        ("distilled", Json::Str(r.distilled.clone())),
        ("certified", Json::Str(r.certified.clone())),
    ];
    if include_elapsed {
        f.push(("elapsed_secs", Json::Num(r.elapsed_secs)));
    }
    Json::obj(f)
}

fn row_from_json(j: &Json) -> Result<SweepRow> {
    let num = |k: &str| -> Result<f64> {
        j.get(k)
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("manifest row: missing numeric '{k}'"))
    };
    let text = |k: &str| -> Result<String> {
        j.get(k)
            .and_then(|v| v.as_str())
            .map(str::to_string)
            .ok_or_else(|| anyhow!("manifest row: missing string '{k}'"))
    };
    let flag = |k: &str| -> Result<bool> {
        j.get(k)
            .and_then(|v| v.as_bool())
            .ok_or_else(|| anyhow!("manifest row: missing bool '{k}'"))
    };
    Ok(SweepRow {
        design: text("design")?,
        optimizer: text("optimizer")?,
        seed: num("seed")? as u64,
        scenarios: num("scenarios")? as usize,
        evals: num("evals")? as usize,
        sims: num("sims")? as u64,
        incr_rate: num("incr_rate")?,
        replay_frac: num("replay_frac")?,
        oracle_rate: num("oracle_rate")?,
        clamp_rate: num("clamp_rate")?,
        sims_avoided: num("sims_avoided")? as u64,
        bounds_floor_hits: num("bounds_floor_hits")? as u64,
        cap_tightenings: num("cap_tightenings")? as u64,
        lanes_per_walk: num("lanes_per_walk")?,
        batch_occupancy: num("batch_occupancy")?,
        walks_saved: num("walks_saved")? as u64,
        elapsed_secs: num("elapsed_secs")?,
        front_size: num("front_size")? as usize,
        star_latency: num("star_latency")? as u64,
        star_bram: num("star_bram")? as u32,
        base_latency: num("base_latency")? as u64,
        base_bram: num("base_bram")? as u32,
        min_deadlocked: flag("min_deadlocked")?,
        truncated: flag("truncated")?,
        distilled: text("distilled")?,
        certified: text("certified")?,
    })
}

// ---------------------------------------------------------------------------
// The manifest
// ---------------------------------------------------------------------------

/// Lifecycle state of one cell in the manifest.
#[derive(Debug, Clone, PartialEq)]
pub enum CellStatus {
    Pending,
    Done { truncated: bool },
    Failed { reason: String },
}

/// One manifest entry, keyed by the cell's 16-hex id.
#[derive(Debug, Clone)]
pub struct CellEntry {
    pub design: String,
    pub optimizer: String,
    pub seed: u64,
    pub status: CellStatus,
    /// Cumulative run attempts across invocations.
    pub attempts: u64,
    /// The full result row (present iff the cell is done).
    pub row: Option<SweepRow>,
}

/// The checkpoint file tracking cell status for resume/shard merging.
/// Written atomically after every cell completion; keyed by stable cell
/// ids so shard manifests from different machines merge by union.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// [`SweepConfig::config_hash`] of the writing config — resume
    /// refuses to merge manifests from an incompatible config.
    pub config_hash: u64,
    /// This writer's shard, for provenance (unsharded writers store
    /// `None`).
    pub shard: Option<(usize, usize)>,
    pub cells: BTreeMap<String, CellEntry>,
}

impl Manifest {
    pub fn to_json(&self) -> Json {
        let mut cells = BTreeMap::new();
        for (k, e) in &self.cells {
            let mut f = vec![
                ("design", Json::Str(e.design.clone())),
                ("optimizer", Json::Str(e.optimizer.clone())),
                ("seed", Json::Num(e.seed as f64)),
                ("attempts", Json::Num(e.attempts as f64)),
            ];
            match &e.status {
                CellStatus::Pending => f.push(("status", Json::Str("pending".into()))),
                CellStatus::Done { truncated } => {
                    f.push(("status", Json::Str("done".into())));
                    f.push(("truncated", Json::Bool(*truncated)));
                }
                CellStatus::Failed { reason } => {
                    f.push(("status", Json::Str("failed".into())));
                    f.push(("reason", Json::Str(reason.clone())));
                }
            }
            if let Some(r) = &e.row {
                f.push(("row", row_to_json(r, true)));
            }
            cells.insert(k.clone(), Json::obj(f));
        }
        Json::obj(vec![
            ("version", Json::Num(1.0)),
            ("config_hash", Json::Str(format!("{:016x}", self.config_hash))),
            (
                "shard",
                match self.shard {
                    Some((i, n)) => Json::Str(format!("{i}/{n}")),
                    None => Json::Null,
                },
            ),
            ("cells", Json::Obj(cells)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let version = j.get("version").and_then(|v| v.as_u64()).unwrap_or(0);
        if version != 1 {
            bail!("manifest: unsupported version {version} (expected 1)");
        }
        let config_hash = j
            .get("config_hash")
            .and_then(|v| v.as_str())
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .ok_or_else(|| anyhow!("manifest: missing or malformed 'config_hash'"))?;
        let shard = match j.get("shard") {
            None | Some(Json::Null) => None,
            Some(v) => {
                let s = v
                    .as_str()
                    .ok_or_else(|| anyhow!("manifest: 'shard' must be a string or null"))?;
                Some(parse_shard(s)?)
            }
        };
        let Some(Json::Obj(cells_json)) = j.get("cells") else {
            bail!("manifest: 'cells' must be an object");
        };
        let mut cells = BTreeMap::new();
        for (k, c) in cells_json {
            let text = |key: &str| -> Result<String> {
                c.get(key)
                    .and_then(|v| v.as_str())
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("manifest cell {k}: missing string '{key}'"))
            };
            let status = match text("status")?.as_str() {
                "pending" => CellStatus::Pending,
                "done" => CellStatus::Done {
                    truncated: c
                        .get("truncated")
                        .and_then(|v| v.as_bool())
                        .unwrap_or(false),
                },
                "failed" => CellStatus::Failed {
                    reason: text("reason").unwrap_or_else(|_| "unknown".into()),
                },
                other => bail!("manifest cell {k}: unknown status '{other}'"),
            };
            let row = match c.get("row") {
                Some(r) => Some(row_from_json(r).with_context(|| format!("manifest cell {k}"))?),
                None => None,
            };
            if matches!(status, CellStatus::Done { .. }) && row.is_none() {
                bail!("manifest cell {k}: done without an embedded row");
            }
            cells.insert(
                k.clone(),
                CellEntry {
                    design: text("design")?,
                    optimizer: text("optimizer")?,
                    seed: c
                        .get("seed")
                        .and_then(|v| v.as_u64())
                        .ok_or_else(|| anyhow!("manifest cell {k}: missing 'seed'"))?,
                    status,
                    attempts: c.get("attempts").and_then(|v| v.as_u64()).unwrap_or(0),
                    row,
                },
            );
        }
        Ok(Manifest {
            config_hash,
            shard,
            cells,
        })
    }

    pub fn save(&self, path: &str) -> std::io::Result<()> {
        report::write_file(path, &self.to_json().to_string_pretty())
    }

    pub fn load(path: &str) -> Result<Manifest> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Self::from_json(&Json::parse(&text).with_context(|| format!("parsing {path}"))?)
    }
}

/// Path of the manifest this invocation writes.
fn manifest_file(dir: &str, shard: Option<(usize, usize)>) -> String {
    match shard {
        Some((i, n)) => format!("{dir}/manifest.shard-{i}-of-{n}.json"),
        None => format!("{dir}/manifest.json"),
    }
}

/// All manifests in `dir` (the unsharded one plus any shard manifests),
/// in sorted filename order for a deterministic merge. A missing or
/// empty directory is a fresh start, not an error.
fn load_prior_manifests(dir: &str) -> Result<Vec<(String, Manifest)>> {
    let Ok(rd) = std::fs::read_dir(dir) else {
        return Ok(Vec::new());
    };
    let mut names: Vec<String> = rd
        .filter_map(|e| e.ok())
        .filter_map(|e| e.file_name().into_string().ok())
        .filter(|n| {
            n == "manifest.json" || (n.starts_with("manifest.shard-") && n.ends_with(".json"))
        })
        .collect();
    names.sort();
    let mut out = Vec::new();
    for n in names {
        let path = format!("{dir}/{n}");
        out.push((path.clone(), Manifest::load(&path)?));
    }
    Ok(out)
}

/// Union-merge a prior manifest entry: done beats failed beats pending;
/// ties keep the existing entry but carry the larger attempt count.
fn merge_entry(cells: &mut BTreeMap<String, CellEntry>, key: String, e: CellEntry) {
    use std::collections::btree_map::Entry;
    let rank = |s: &CellStatus| match s {
        CellStatus::Done { .. } => 2,
        CellStatus::Failed { .. } => 1,
        CellStatus::Pending => 0,
    };
    match cells.entry(key) {
        Entry::Vacant(v) => {
            v.insert(e);
        }
        Entry::Occupied(mut o) => {
            let cur = o.get_mut();
            if rank(&e.status) > rank(&cur.status) {
                *cur = e;
            } else {
                cur.attempts = cur.attempts.max(e.attempts);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Orchestration
// ---------------------------------------------------------------------------

/// Orchestration callbacks for tests and embedders.
#[derive(Default)]
pub struct SweepHooks {
    /// Called at the start of every cell *attempt* (not for cells
    /// skipped via resume), with the attempt number (1-based, this
    /// invocation). Runs inside the cell's panic isolation, so a
    /// panicking hook records that cell as failed — the fault-injection
    /// point the panic-isolation tests use.
    #[allow(clippy::type_complexity)]
    pub on_cell_start: Option<Box<dyn Fn(&CellKey, u64) + Send + Sync>>,
    /// Stop claiming new cells once this many have completed (resumed
    /// skips count) — the crash-injection knob for resume tests.
    pub stop_after_cells: Option<usize>,
}

/// A failed cell as reported in [`SweepOutcome`] and the aggregates.
#[derive(Debug, Clone)]
pub struct FailedCell {
    pub design: String,
    pub optimizer: String,
    pub seed: u64,
    pub reason: String,
    pub attempts: u64,
}

/// Everything a finished (or early-stopped) sweep invocation produced.
pub struct SweepOutcome {
    /// Result rows in grid order (failed cells are absent).
    pub rows: Vec<SweepRow>,
    /// Cells that exhausted their attempts, in grid order.
    pub failed: Vec<FailedCell>,
    /// Cells served from the resume manifest without re-running.
    pub resumed: usize,
    /// Cells that hit a wall-clock/simulation budget (their rows are
    /// flagged `truncated`).
    pub truncated: usize,
    /// True when [`SweepHooks::stop_after_cells`] halted the run before
    /// every cell completed (aggregates are withheld).
    pub stopped_early: bool,
    /// The manifest this invocation wrote, when `out_dir` is set.
    pub manifest_path: Option<String>,
}

/// Per-design shared state: the workload plus one prototype scenario
/// bank every cell of the design clones (compiled/batched event-graph
/// tables stay `Arc`-shared across cells). The bank sits behind a mutex
/// only because `ScenarioSim` is `Send` but not `Sync`; workers lock it
/// just long enough to clone.
enum Proto {
    Ready {
        workload: Arc<Workload>,
        bank: Mutex<ScenarioSim>,
    },
    /// Trace collection or workload validation failed (deterministic —
    /// retrying is pointless), or panicked.
    Broken(String),
}

impl Proto {
    fn build(spec: &DesignSpec, backend: BackendKind) -> Proto {
        let build = || -> Result<(Arc<Workload>, ScenarioSim)> {
            let bd = bench_suite::build(&spec.name);
            let workload = if spec.arg_sets.is_empty() {
                Workload::single(Arc::new(collect_trace(&bd.design, &bd.args)?))
            } else {
                Workload::from_design_args(&bd.design, &spec.arg_sets)?
            };
            let workload = Arc::new(workload);
            let bank = ScenarioSim::with_backend(&workload, SimOptions::default(), backend);
            Ok((workload, bank))
        };
        match catch_unwind(AssertUnwindSafe(build)) {
            Ok(Ok((workload, bank))) => Proto::Ready {
                workload,
                bank: Mutex::new(bank),
            },
            Ok(Err(e)) => Proto::Broken(format!("error: {e:#}")),
            Err(payload) => Proto::Broken(format!("panicked: {}", panic_message(&payload))),
        }
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".into()
    }
}

/// Mutable state the cell workers share under one lock.
struct SharedState {
    manifest: Manifest,
    /// Result slot per cell index — grid order regardless of which
    /// worker finishes when.
    rows: Vec<Option<SweepRow>>,
    /// `(cell index, failure)` so failures can be reported in grid
    /// order.
    failed: Vec<(usize, FailedCell)>,
    /// First checkpoint-write error, surfaced after the run.
    save_error: Option<String>,
}

/// Borrowed context handed to every cell worker.
struct RunCtx<'a> {
    cfg: &'a SweepConfig,
    hooks: &'a SweepHooks,
    cells: &'a [CellKey],
    protos: &'a HashMap<DesignSpec, Proto>,
    next: &'a AtomicUsize,
    completed: &'a AtomicUsize,
    resumed: &'a AtomicUsize,
    shared: &'a Mutex<SharedState>,
    manifest_path: Option<&'a str>,
}

/// Execute the sweep; returns all rows in grid order (and writes
/// per-run JSON, the manifest, and aggregates when `out_dir` is set).
/// Any failed cell turns into an error *after* the whole grid has been
/// given its chance — use [`run_sweep_with`] to inspect partial
/// outcomes instead.
pub fn run_sweep(cfg: &SweepConfig) -> Result<Vec<SweepRow>> {
    let out = run_sweep_with(cfg, &SweepHooks::default())?;
    if !out.failed.is_empty() {
        let list: Vec<String> = out
            .failed
            .iter()
            .map(|f| format!("{}/{}/s{}: {}", f.design, f.optimizer, f.seed, f.reason))
            .collect();
        bail!(
            "sweep: {} cell(s) failed:\n  {}",
            out.failed.len(),
            list.join("\n  ")
        );
    }
    Ok(out.rows)
}

/// The fault-tolerant orchestrator (see the module docs for the model).
/// Work-stealing over the (possibly sharded, possibly resumed) cell
/// list with `cell_workers` threads; each cell is retried, budgeted,
/// panic-isolated, and checkpointed independently.
pub fn run_sweep_with(cfg: &SweepConfig, hooks: &SweepHooks) -> Result<SweepOutcome> {
    if cfg.resume && cfg.out_dir.is_none() {
        bail!("sweep config: \"resume\": true requires \"out_dir\"");
    }
    // The full grid, design-major — cell index is grid (row) order.
    let mut all: Vec<CellKey> = Vec::new();
    for d in &cfg.designs {
        for o in &cfg.optimizers {
            for &seed in &cfg.seeds {
                all.push(CellKey {
                    design: d.clone(),
                    optimizer: o.clone(),
                    seed,
                });
            }
        }
    }
    let cells: Vec<CellKey> = match cfg.shard {
        None => all,
        Some((i, n)) => all
            .into_iter()
            .filter(|c| c.id(cfg) % n as u64 == i as u64)
            .collect(),
    };

    let mut manifest = Manifest {
        config_hash: cfg.config_hash(),
        shard: cfg.shard,
        cells: BTreeMap::new(),
    };
    if cfg.resume {
        let dir = cfg.out_dir.as_deref().unwrap();
        for (path, prior) in load_prior_manifests(dir)? {
            if prior.config_hash != manifest.config_hash {
                bail!(
                    "resume: {path} was written by an incompatible sweep config \
                     (its hash {:016x}, this config {:016x}) — refusing to mix results",
                    prior.config_hash,
                    manifest.config_hash
                );
            }
            for (k, e) in prior.cells {
                merge_entry(&mut manifest.cells, k, e);
            }
        }
    }
    for c in &cells {
        manifest
            .cells
            .entry(c.id_hex(cfg))
            .or_insert_with(|| CellEntry {
                design: c.design.name.clone(),
                optimizer: c.optimizer.clone(),
                seed: c.seed,
                status: CellStatus::Pending,
                attempts: 0,
                row: None,
            });
    }
    let manifest_path = cfg.out_dir.as_ref().map(|d| manifest_file(d, cfg.shard));
    if let Some(p) = &manifest_path {
        manifest.save(p).with_context(|| format!("writing {p}"))?;
    }

    // One workload + prototype bank per distinct design that still has
    // cells to run (built up front, panic-isolated per design).
    let mut protos: HashMap<DesignSpec, Proto> = HashMap::new();
    for c in &cells {
        if matches!(
            manifest.cells[&c.id_hex(cfg)].status,
            CellStatus::Done { .. }
        ) {
            continue;
        }
        protos
            .entry(c.design.clone())
            .or_insert_with(|| Proto::build(&c.design, cfg.backend));
    }

    let next = AtomicUsize::new(0);
    let completed = AtomicUsize::new(0);
    let resumed = AtomicUsize::new(0);
    let shared = Mutex::new(SharedState {
        manifest,
        rows: vec![None; cells.len()],
        failed: Vec::new(),
        save_error: None,
    });
    let ctx = RunCtx {
        cfg,
        hooks,
        cells: &cells,
        protos: &protos,
        next: &next,
        completed: &completed,
        resumed: &resumed,
        shared: &shared,
        manifest_path: manifest_path.as_deref(),
    };
    let workers = cfg.cell_workers.clamp(1, cells.len().max(1));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| cell_worker(&ctx));
        }
    });

    let state = shared.into_inner().unwrap();
    if let Some(e) = state.save_error {
        bail!("sweep: checkpoint write failed: {e}");
    }
    let stopped_early = completed.load(Ordering::SeqCst) < cells.len();
    let rows: Vec<SweepRow> = state.rows.into_iter().flatten().collect();
    let mut failed_indexed = state.failed;
    failed_indexed.sort_by_key(|(i, _)| *i);
    let failed: Vec<FailedCell> = failed_indexed.into_iter().map(|(_, f)| f).collect();
    let truncated = rows.iter().filter(|r| r.truncated).count();

    // Aggregates only from a complete, unsharded view of the grid —
    // shard invocations leave them to the final merged resume pass.
    if let (Some(dir), None, false) = (&cfg.out_dir, cfg.shard, stopped_early) {
        write_aggregates(dir, &rows, &failed, cfg)?;
    }

    Ok(SweepOutcome {
        rows,
        failed,
        resumed: resumed.load(Ordering::SeqCst),
        truncated,
        stopped_early,
        manifest_path,
    })
}

/// One work-stealing worker: claim the next cell index, skip it if the
/// (possibly resumed) manifest already has it done, otherwise run it
/// with retries and checkpoint the result.
fn cell_worker(ctx: &RunCtx) {
    loop {
        if ctx
            .hooks
            .stop_after_cells
            .is_some_and(|n| ctx.completed.load(Ordering::SeqCst) >= n)
        {
            return;
        }
        let i = ctx.next.fetch_add(1, Ordering::SeqCst);
        if i >= ctx.cells.len() {
            return;
        }
        let cell = &ctx.cells[i];
        let key = cell.id_hex(ctx.cfg);
        // Resume skip: replay the embedded row; the cell's record file
        // on disk stays byte-for-byte untouched.
        {
            let mut st = ctx.shared.lock().unwrap();
            let done_row = match st.manifest.cells.get(&key) {
                Some(e) if matches!(e.status, CellStatus::Done { .. }) => e.row.clone(),
                _ => None,
            };
            if let Some(row) = done_row {
                st.rows[i] = Some(row);
                drop(st);
                ctx.resumed.fetch_add(1, Ordering::SeqCst);
                ctx.completed.fetch_add(1, Ordering::SeqCst);
                continue;
            }
        }
        let outcome = run_cell_with_retries(ctx, cell);
        let mut st = ctx.shared.lock().unwrap();
        let entry = st
            .manifest
            .cells
            .get_mut(&key)
            .expect("every claimed cell was seeded into the manifest");
        entry.attempts += outcome.attempts;
        let attempts_total = entry.attempts;
        match outcome.result {
            Ok(row) => {
                entry.status = CellStatus::Done {
                    truncated: row.truncated,
                };
                entry.row = Some(row.clone());
                st.rows[i] = Some(row);
            }
            Err(reason) => {
                entry.status = CellStatus::Failed {
                    reason: reason.clone(),
                };
                entry.row = None;
                st.failed.push((
                    i,
                    FailedCell {
                        design: cell.design.name.clone(),
                        optimizer: cell.optimizer.clone(),
                        seed: cell.seed,
                        reason,
                        attempts: attempts_total,
                    },
                ));
            }
        }
        // Checkpoint under the lock so manifest writes serialize; the
        // write itself is atomic (temp + rename).
        if let Some(p) = ctx.manifest_path {
            if let Err(e) = st.manifest.save(p) {
                if st.save_error.is_none() {
                    st.save_error = Some(e.to_string());
                }
            }
        }
        drop(st);
        ctx.completed.fetch_add(1, Ordering::SeqCst);
    }
}

struct CellOutcome {
    result: std::result::Result<SweepRow, String>,
    /// Attempts consumed this invocation.
    attempts: u64,
}

/// Run one cell under panic isolation, retrying up to
/// `1 + max_retries` attempts with exponential backoff. The prototype
/// bank is cloned *outside* the unwind boundary so a panicking cell can
/// never poison the design's shared bank.
fn run_cell_with_retries(ctx: &RunCtx, cell: &CellKey) -> CellOutcome {
    let (workload, bank_slot) = match ctx.protos.get(&cell.design) {
        Some(Proto::Ready { workload, bank }) => (workload, bank),
        Some(Proto::Broken(msg)) => {
            return CellOutcome {
                result: Err(msg.clone()),
                attempts: 1,
            }
        }
        None => {
            return CellOutcome {
                result: Err("internal: no prototype bank for design".into()),
                attempts: 1,
            }
        }
    };
    let mut attempt = 0u64;
    loop {
        attempt += 1;
        let bank = bank_slot.lock().unwrap().clone();
        let run = catch_unwind(AssertUnwindSafe(|| {
            if let Some(h) = &ctx.hooks.on_cell_start {
                h(cell, attempt);
            }
            run_cell(ctx.cfg, cell, workload, bank)
        }));
        let reason = match run {
            Ok(Ok(row)) => {
                return CellOutcome {
                    result: Ok(row),
                    attempts: attempt,
                }
            }
            Ok(Err(e)) => format!("error: {e:#}"),
            Err(payload) => format!("panicked: {}", panic_message(&payload)),
        };
        if attempt > ctx.cfg.max_retries {
            return CellOutcome {
                result: Err(reason),
                attempts: attempt,
            };
        }
        let backoff = ctx
            .cfg
            .retry_backoff_ms
            .saturating_mul(1 << (attempt - 1).min(10))
            .min(60_000);
        std::thread::sleep(Duration::from_millis(backoff));
    }
}

/// One cell: fresh engine over the design's shared workload (cloning
/// the prototype bank), baselines, budgeted drive, result row, and the
/// atomic per-cell record write. Fresh per-cell engines are what make
/// resumed and uninterrupted sweeps bit-identical — no state leaks
/// between cells.
fn run_cell(
    cfg: &SweepConfig,
    cell: &CellKey,
    workload: &Arc<Workload>,
    bank: ScenarioSim,
) -> Result<SweepRow> {
    if cfg.distill && workload.num_scenarios() > 1 {
        return run_cell_distilled(cfg, cell, workload);
    }
    let design = &cell.design.name;
    let space = Space::from_workload(workload);
    let mut ev = Evaluator::for_workload_with_bank(
        workload.clone(),
        Box::new(NativeBram),
        cfg.jobs,
        bank,
        cfg.backend,
    );
    ev.set_prune(cfg.prune);
    ev.set_bounds(cfg.bounds);
    let (maxp, minp) = ev.eval_baselines();
    let (base_lat, base_bram) = (
        maxp.latency
            .ok_or_else(|| anyhow!("{design}: Baseline-Max deadlocks"))?,
        maxp.bram,
    );
    ev.reset_run(true);
    ev.set_cancel_token(CancelToken::with_limits(
        cfg.cell_timeout_secs.map(Duration::from_secs_f64),
        cfg.cell_sim_budget,
    ));
    let mut o = opt::by_name(&cell.optimizer, cell.seed)
        .ok_or_else(|| anyhow!("unknown optimizer '{}'", cell.optimizer))?;
    let t0 = Instant::now();
    drive(&mut *o, &mut ev, &space, cfg.budget);
    let dt = t0.elapsed().as_secs_f64();
    let front = ev.pareto();
    let pts: Vec<(u64, u32)> = front.iter().map(|p| (p.latency.unwrap(), p.bram)).collect();
    let star_idx = select_highlight(&pts, cfg.alpha, base_lat, base_bram);
    let star = star_idx.map(|i| pts[i]).unwrap_or((base_lat, base_bram));
    let star_depths: Box<[u32]> = star_idx
        .map(|i| front[i].depths.clone())
        .unwrap_or_else(|| workload.baseline_max().into());
    let row = SweepRow {
        design: design.clone(),
        optimizer: cell.optimizer.clone(),
        seed: cell.seed,
        scenarios: workload.num_scenarios(),
        evals: ev.n_evals(),
        sims: ev.n_sim,
        incr_rate: ev.stats().incremental_rate(),
        replay_frac: ev.stats().replay_fraction(),
        oracle_rate: ev.stats().oracle_rate(),
        clamp_rate: ev.stats().clamp_rate(),
        sims_avoided: ev.stats().sims_avoided,
        bounds_floor_hits: ev.stats().bounds_floor_hits,
        cap_tightenings: ev.stats().cap_tightenings,
        lanes_per_walk: ev.stats().lanes_per_walk(),
        batch_occupancy: ev.stats().batch_occupancy(),
        walks_saved: ev.stats().walks_saved(),
        elapsed_secs: dt,
        front_size: front.len(),
        star_latency: star.0,
        star_bram: star.1,
        base_latency: base_lat,
        base_bram,
        min_deadlocked: !minp.is_feasible(),
        truncated: ev.truncated(),
        distilled: String::new(),
        certified: certify_verdict(cfg, design, cell.seed, &star_depths),
    };
    // The record file lands (atomically) before the manifest flips this
    // cell to done — a crash between the two just re-runs the cell,
    // which rewrites the same deterministic content.
    if let Some(dir) = &cfg.out_dir {
        let j = report::run_to_json(
            design,
            &cell.optimizer,
            cell.seed,
            cfg.budget,
            &ev.history,
            &front,
            dt,
            Some(&ev),
        );
        report::write_file(
            &format!("{dir}/{}.json", cell.file_stem()),
            &j.to_string_pretty(),
        )?;
    }
    // Feed the cross-run store. Best-effort: a full disk or unwritable
    // cache dir must not fail the cell — the row above is the product,
    // the snapshot is an accelerant for later runs.
    if let Some(dir) = &cfg.cache_dir {
        let store = crate::store::Store::new(dir, 0);
        let key = crate::store::Store::key(
            design,
            workload,
            cfg.backend.name(),
            cfg.prune,
            cfg.bounds,
        );
        let snap = crate::store::Snapshot::capture(design, &ev);
        if let Err(e) = store.save(&key, &snap) {
            eprintln!("sweep: {design}/s{}: store save failed: {e}", cell.seed);
        }
    }
    Ok(row)
}

/// Distilled variant of [`run_cell`]: the inner loop runs on the
/// dominance-distilled scenario bank with the full-bank re-verify
/// fixpoint ([`crate::dse::advhunt::optimize_distilled`]). Fronts and
/// stars are bit-identical to the plain cell (pinned by test); a
/// distilled row's `sims` counts *per-scenario* simulator invocations
/// (inner + verify) — the quantity distillation reduces — and its
/// engine-telemetry rates are zeroed (two engines share the work).
fn run_cell_distilled(
    cfg: &SweepConfig,
    cell: &CellKey,
    workload: &Arc<Workload>,
) -> Result<SweepRow> {
    use crate::dse::advhunt::{optimize_distilled, DistillConfig};
    let design = &cell.design.name;
    let space = Space::from_workload(workload);
    let dcfg = DistillConfig {
        optimizer: cell.optimizer.clone(),
        seed: cell.seed,
        budget: cfg.budget,
        jobs: cfg.jobs,
        prune: cfg.prune,
        bounds: cfg.bounds,
        backend: cfg.backend,
        cancel: CancelToken::with_limits(
            cfg.cell_timeout_secs.map(Duration::from_secs_f64),
            cfg.cell_sim_budget,
        ),
    };
    let t0 = Instant::now();
    let out = optimize_distilled(workload, &space, &dcfg);
    let dt = t0.elapsed().as_secs_f64();
    let base_lat = out
        .baseline_max
        .latency
        .ok_or_else(|| anyhow!("{design}: Baseline-Max deadlocks"))?;
    let base_bram = out.baseline_max.bram;
    // `out.history` seeds the two paper baselines before the proposals;
    // the plain cell resets after its baselines, so recompute the front
    // over the proposal slice to keep the two rows bit-comparable.
    let proposals = &out.history[2.min(out.history.len())..];
    let obj: Vec<crate::opt::pareto::ObjPoint> = proposals
        .iter()
        .enumerate()
        .filter_map(|(i, p)| {
            p.latency.map(|l| crate::opt::pareto::ObjPoint {
                latency: l,
                bram: p.bram,
                index: i,
            })
        })
        .collect();
    let front: Vec<&crate::dse::EvalPoint> = crate::opt::pareto::pareto_front(&obj)
        .into_iter()
        .map(|p| &proposals[p.index])
        .collect();
    let pts: Vec<(u64, u32)> = front.iter().map(|p| (p.latency.unwrap(), p.bram)).collect();
    let star_idx = select_highlight(&pts, cfg.alpha, base_lat, base_bram);
    let star = star_idx.map(|i| pts[i]).unwrap_or((base_lat, base_bram));
    let star_depths: Box<[u32]> = star_idx
        .map(|i| front[i].depths.clone())
        .unwrap_or_else(|| workload.baseline_max().into());
    let distilled = format!(
        "{}/{}{}",
        out.kept_final.len(),
        workload.num_scenarios(),
        if out.promotions.is_empty() {
            String::new()
        } else {
            format!("+{}", out.promotions.len())
        }
    );
    let row = SweepRow {
        design: design.clone(),
        optimizer: cell.optimizer.clone(),
        seed: cell.seed,
        scenarios: workload.num_scenarios(),
        evals: proposals.len(),
        sims: out.inner_scenario_sims + out.verify_scenario_sims,
        incr_rate: 0.0,
        replay_frac: 0.0,
        oracle_rate: 0.0,
        clamp_rate: 0.0,
        sims_avoided: 0,
        bounds_floor_hits: 0,
        cap_tightenings: 0,
        lanes_per_walk: 0.0,
        batch_occupancy: 0.0,
        walks_saved: 0,
        elapsed_secs: dt,
        front_size: front.len(),
        star_latency: star.0,
        star_bram: star.1,
        base_latency: base_lat,
        base_bram,
        min_deadlocked: !out.baseline_min.is_feasible(),
        truncated: out.truncated,
        distilled,
        certified: certify_verdict(cfg, design, cell.seed, &star_depths),
    };
    if let Some(dir) = &cfg.out_dir {
        let j = report::run_to_json(
            design,
            &cell.optimizer,
            cell.seed,
            cfg.budget,
            proposals,
            &front,
            dt,
            None,
        );
        report::write_file(
            &format!("{dir}/{}.json", cell.file_stem()),
            &j.to_string_pretty(),
        )?;
    }
    Ok(row)
}

/// The `certified` column for a cell: adversarially hunt the design's
/// kernel-argument space against the ★ config. Empty unless the config
/// sets `"certify"`; `no-arg-space` for static designs.
fn certify_verdict(cfg: &SweepConfig, design: &str, seed: u64, depths: &[u32]) -> String {
    if !cfg.certify {
        return String::new();
    }
    let hunt = crate::dse::advhunt::HuntConfig {
        optimizer: "auto".into(),
        seed,
        budget: cfg.certify_budget,
        jobs: cfg.jobs,
        cancel: CancelToken::with_limits(
            cfg.cell_timeout_secs.map(Duration::from_secs_f64),
            None,
        ),
    };
    match crate::dse::advhunt::certify_design(design, depths, &hunt) {
        Some(c) => c.verdict(),
        None => "no-arg-space".into(),
    }
}

/// Aggregate CSV + JSON over the completed grid. Only deterministic
/// fields are emitted (no wall-clock), so an interrupted-then-resumed
/// sweep and an uninterrupted one produce identical bytes — the
/// regression the orchestration tests pin.
fn write_aggregates(
    dir: &str,
    rows: &[SweepRow],
    failed: &[FailedCell],
    cfg: &SweepConfig,
) -> Result<()> {
    let mut csv = Csv::new(&[
        "design",
        "optimizer",
        "seed",
        "scenarios",
        "evals",
        "sims",
        "incr_rate",
        "replay_frac",
        "oracle_rate",
        "clamp_rate",
        "sims_avoided",
        "bounds_floor_hits",
        "cap_tightenings",
        "lanes_per_walk",
        "batch_occupancy",
        "walks_saved",
        "front_size",
        "star_latency",
        "star_bram",
        "base_latency",
        "base_bram",
        "min_deadlocked",
        "truncated",
        "distilled",
        "certified",
    ]);
    // Rate columns route through the shared emission clamp: a memo-only
    // cell can produce NaN/inf rates, and `f64::to_string` would write
    // them verbatim ("NaN"), breaking numeric CSV consumers.
    let rate = |x: f64| report::finite_or_zero(x).to_string();
    for r in rows {
        csv.row(vec![
            r.design.clone(),
            r.optimizer.clone(),
            r.seed.to_string(),
            r.scenarios.to_string(),
            r.evals.to_string(),
            r.sims.to_string(),
            rate(r.incr_rate),
            rate(r.replay_frac),
            rate(r.oracle_rate),
            rate(r.clamp_rate),
            r.sims_avoided.to_string(),
            r.bounds_floor_hits.to_string(),
            r.cap_tightenings.to_string(),
            rate(r.lanes_per_walk),
            rate(r.batch_occupancy),
            r.walks_saved.to_string(),
            r.front_size.to_string(),
            r.star_latency.to_string(),
            r.star_bram.to_string(),
            r.base_latency.to_string(),
            r.base_bram.to_string(),
            r.min_deadlocked.to_string(),
            r.truncated.to_string(),
            r.distilled.clone(),
            r.certified.clone(),
        ]);
    }
    csv.write(&format!("{dir}/aggregate.csv"))?;
    let j = Json::obj(vec![
        (
            "config_hash",
            Json::Str(format!("{:016x}", cfg.config_hash())),
        ),
        (
            "rows",
            Json::Arr(rows.iter().map(|r| row_to_json(r, false)).collect()),
        ),
        (
            "failed",
            Json::Arr(
                failed
                    .iter()
                    .map(|f| {
                        Json::obj(vec![
                            ("design", Json::Str(f.design.clone())),
                            ("optimizer", Json::Str(f.optimizer.clone())),
                            ("seed", Json::Num(f.seed as f64)),
                            ("reason", Json::Str(f.reason.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    report::write_file(&format!("{dir}/aggregate.json"), &j.to_string_pretty())?;
    Ok(())
}

/// Render sweep rows as a markdown summary table.
pub fn rows_to_markdown(rows: &[SweepRow]) -> String {
    let table_rows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.design.clone(),
                r.optimizer.clone(),
                r.seed.to_string(),
                r.scenarios.to_string(),
                format!("{:.3}", r.elapsed_secs),
                r.sims.to_string(),
                format!("{:.0}%", r.incr_rate * 100.0),
                format!("{:.0}%", r.replay_frac * 100.0),
                format!("{:.0}%", r.oracle_rate * 100.0),
                format!("{:.0}%", r.clamp_rate * 100.0),
                r.sims_avoided.to_string(),
                r.bounds_floor_hits.to_string(),
                format!("{:.1}", r.lanes_per_walk),
                format!("{:.0}%", r.batch_occupancy * 100.0),
                r.front_size.to_string(),
                format!("{:.4}", r.star_latency as f64 / r.base_latency as f64),
                format!(
                    "{:.1}%",
                    (r.base_bram as f64 - r.star_bram as f64) / r.base_bram.max(1) as f64 * 100.0
                ),
                if r.min_deadlocked { "×→✓" } else { "" }.to_string(),
                if r.truncated { "✂" } else { "" }.to_string(),
                r.distilled.clone(),
                r.certified.clone(),
            ]
        })
        .collect();
    report::markdown_table(
        &[
            "design", "optimizer", "seed", "scen", "secs", "sims", "incr%", "replay%", "orcl%",
            "clmp%", "avoid", "flr", "ln/wk", "occ%", "front", "lat×", "BRAM↓", "rescue", "cut",
            "dstl", "cert",
        ],
        &table_rows,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_parsing_and_validation() {
        let j = Json::parse(
            r#"{"designs": ["fig2"], "optimizers": ["greedy", "random"],
                "budget": 50, "seeds": [1, 2], "threads": 1}"#,
        )
        .unwrap();
        let cfg = SweepConfig::from_json(&j).unwrap();
        assert_eq!(
            cfg.designs,
            vec![DesignSpec {
                name: "fig2".into(),
                arg_sets: Vec::new()
            }]
        );
        assert_eq!(cfg.seeds, vec![1, 2]);
        assert_eq!(cfg.budget, 50);
        assert_eq!(cfg.alpha, 0.7);
        assert_eq!(cfg.jobs, 1, "threads accepted as legacy alias");
        assert!(cfg.prune, "pruning defaults on");
        assert!(cfg.bounds, "bounds default on");
        assert!(!cfg.resume);
        assert_eq!(cfg.max_retries, 1);
        assert_eq!(cfg.retry_backoff_ms, 250);
        assert_eq!(cfg.shard, None);
        assert_eq!(cfg.cell_workers, 1);

        let j = Json::parse(r#"{"designs": ["fig2"], "optimizers": ["greedy"], "jobs": 4}"#)
            .unwrap();
        assert_eq!(SweepConfig::from_json(&j).unwrap().jobs, 4);

        let j = Json::parse(
            r#"{"designs": ["fig2"], "optimizers": ["greedy"], "prune": false}"#,
        )
        .unwrap();
        assert!(!SweepConfig::from_json(&j).unwrap().prune);

        let j = Json::parse(
            r#"{"designs": ["fig2"], "optimizers": ["greedy"], "bounds": false}"#,
        )
        .unwrap();
        assert!(!SweepConfig::from_json(&j).unwrap().bounds);

        let bad = Json::parse(r#"{"designs": ["nope"], "optimizers": ["greedy"]}"#).unwrap();
        assert!(SweepConfig::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"designs": ["fig2"], "optimizers": ["nope"]}"#).unwrap();
        assert!(SweepConfig::from_json(&bad).is_err());
    }

    #[test]
    fn unknown_keys_are_rejected_by_name() {
        let bad = Json::parse(
            r#"{"designs": ["fig2"], "optimizers": ["greedy"], "budgett": 50}"#,
        )
        .unwrap();
        let err = SweepConfig::from_json(&bad).unwrap_err().to_string();
        assert!(err.contains("budgett"), "must name the offending key: {err}");
        assert!(
            err.contains("accepted keys") && err.contains("budget"),
            "must list the accepted key set: {err}"
        );
        let not_obj = Json::parse(r#"[1, 2]"#).unwrap();
        assert!(SweepConfig::from_json(&not_obj).is_err());
    }

    #[test]
    fn shard_parsing_and_validation() {
        assert_eq!(parse_shard("0/2").unwrap(), (0, 2));
        assert_eq!(parse_shard("3/4").unwrap(), (3, 4));
        assert!(parse_shard("2/2").is_err(), "index must be < count");
        assert!(parse_shard("0/0").is_err(), "count must be >= 1");
        assert!(parse_shard("x/2").is_err());
        assert!(parse_shard("02").is_err(), "missing slash");

        let j = Json::parse(
            r#"{"designs": ["fig2"], "optimizers": ["greedy"], "shard": "1/3"}"#,
        )
        .unwrap();
        assert_eq!(SweepConfig::from_json(&j).unwrap().shard, Some((1, 3)));
        let bad = Json::parse(
            r#"{"designs": ["fig2"], "optimizers": ["greedy"], "shard": "3/3"}"#,
        )
        .unwrap();
        assert!(SweepConfig::from_json(&bad).is_err());
    }

    #[test]
    fn cell_ids_are_stable_and_config_sensitive() {
        let cfg = |budget: usize| {
            let j = Json::parse(&format!(
                r#"{{"designs": ["fig2", "gesummv"], "optimizers": ["greedy"],
                    "budget": {budget}, "seeds": [1, 2]}}"#
            ))
            .unwrap();
            SweepConfig::from_json(&j).unwrap()
        };
        let a = cfg(60);
        let cell = CellKey {
            design: a.designs[0].clone(),
            optimizer: "greedy".into(),
            seed: 1,
        };
        assert_eq!(cell.id(&a), cell.id(&a), "id is a pure function");
        assert_eq!(cell.id_hex(&a).len(), 16);
        // Different seed, design, or budget → different id.
        let other_seed = CellKey {
            seed: 2,
            ..cell.clone()
        };
        assert_ne!(cell.id(&a), other_seed.id(&a));
        let other_design = CellKey {
            design: a.designs[1].clone(),
            ..cell.clone()
        };
        assert_ne!(cell.id(&a), other_design.id(&a));
        assert_ne!(cell.id(&a), cell.id(&cfg(61)));
        assert_ne!(a.config_hash(), cfg(61).config_hash());
        assert_eq!(a.config_hash(), cfg(60).config_hash());
        // Bare-design record files keep the historical name; workload
        // entries get a disambiguating hash.
        assert_eq!(cell.file_stem(), "fig2_greedy_s1");
        let wl = CellKey {
            design: DesignSpec {
                name: "fig2".into(),
                arg_sets: vec![vec![8], vec![16]],
            },
            optimizer: "greedy".into(),
            seed: 1,
        };
        assert!(wl.file_stem().starts_with("fig2_w"));
        assert!(wl.file_stem().ends_with("_greedy_s1"));
    }

    #[test]
    fn manifest_roundtrips_through_json() {
        let row = SweepRow {
            design: "fig2".into(),
            optimizer: "greedy".into(),
            seed: 1,
            scenarios: 2,
            evals: 60,
            sims: 41,
            incr_rate: 0.512345678901,
            replay_frac: 0.25,
            oracle_rate: 0.1,
            clamp_rate: 0.0,
            sims_avoided: 7,
            bounds_floor_hits: 3,
            cap_tightenings: 1,
            lanes_per_walk: 3.5,
            batch_occupancy: 0.875,
            walks_saved: 11,
            elapsed_secs: 0.123456,
            front_size: 4,
            star_latency: 1234,
            star_bram: 5,
            base_latency: 2000,
            base_bram: 9,
            min_deadlocked: true,
            truncated: false,
            distilled: "2/3+1".into(),
            certified: "clean-exhaustive(8)".into(),
        };
        let mut cells = BTreeMap::new();
        cells.insert(
            "00000000deadbeef".to_string(),
            CellEntry {
                design: "fig2".into(),
                optimizer: "greedy".into(),
                seed: 1,
                status: CellStatus::Done { truncated: false },
                attempts: 1,
                row: Some(row.clone()),
            },
        );
        cells.insert(
            "00000000cafebabe".to_string(),
            CellEntry {
                design: "gesummv".into(),
                optimizer: "random".into(),
                seed: 2,
                status: CellStatus::Failed {
                    reason: "panicked: boom".into(),
                },
                attempts: 2,
                row: None,
            },
        );
        let m = Manifest {
            config_hash: 0xdead_beef_cafe_0123,
            shard: Some((1, 2)),
            cells,
        };
        let text = m.to_json().to_string_pretty();
        let back = Manifest::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.config_hash, m.config_hash);
        assert_eq!(back.shard, Some((1, 2)));
        assert_eq!(back.cells.len(), 2);
        let done = &back.cells["00000000deadbeef"];
        assert_eq!(done.status, CellStatus::Done { truncated: false });
        let r = done.row.as_ref().unwrap();
        assert_eq!(r.sims, row.sims);
        assert_eq!(r.bounds_floor_hits, 3);
        assert_eq!(r.cap_tightenings, 1);
        assert_eq!(r.incr_rate, row.incr_rate, "floats roundtrip exactly");
        assert_eq!(r.elapsed_secs, row.elapsed_secs);
        assert!(r.min_deadlocked);
        assert_eq!(r.distilled, "2/3+1");
        assert_eq!(r.certified, "clean-exhaustive(8)");
        let failed = &back.cells["00000000cafebabe"];
        assert_eq!(
            failed.status,
            CellStatus::Failed {
                reason: "panicked: boom".into()
            }
        );
        assert_eq!(failed.attempts, 2);
        // A done cell without a row is corrupt.
        let corrupt = text.replace("\"row\"", "\"not_row\"");
        assert!(Manifest::from_json(&Json::parse(&corrupt).unwrap()).is_err());
    }

    #[test]
    fn sweep_executes_grid() {
        let j = Json::parse(
            r#"{"designs": ["fig2", "gesummv"], "optimizers": ["greedy", "grouped_sa"],
                "budget": 60, "seeds": [1], "jobs": 1}"#,
        )
        .unwrap();
        let cfg = SweepConfig::from_json(&j).unwrap();
        let rows = run_sweep(&cfg).unwrap();
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.front_size >= 1, "{}/{}", r.design, r.optimizer);
            assert!(r.star_latency > 0);
            assert!(r.sims as usize <= r.evals + 2);
            assert!(!r.truncated, "no budgets configured");
        }
        assert!(rows.iter().any(|r| r.design == "fig2" && r.min_deadlocked));
        assert!(rows.iter().all(|r| r.scenarios == 1));
        let md = rows_to_markdown(&rows);
        assert!(md.contains("fig2"));
        assert!(md.contains("×→✓"));
    }

    #[test]
    fn prune_toggle_changes_cost_never_results() {
        let grid = |prune: bool| {
            let j = Json::parse(&format!(
                r#"{{"designs": [{{"design": "fig2", "scenarios": [[8], [16]]}}],
                    "optimizers": ["grouped_sa"], "budget": 80, "seeds": [1],
                    "jobs": 1, "prune": {prune}}}"#
            ))
            .unwrap();
            run_sweep(&SweepConfig::from_json(&j).unwrap()).unwrap()
        };
        let on = grid(true);
        let off = grid(false);
        assert_eq!(on[0].star_latency, off[0].star_latency);
        assert_eq!(on[0].star_bram, off[0].star_bram);
        assert_eq!(on[0].front_size, off[0].front_size);
        assert_eq!(on[0].evals, off[0].evals);
        assert!(on[0].sims <= off[0].sims, "pruning must never add sims");
        assert_eq!(off[0].oracle_rate, 0.0);
        assert_eq!(off[0].sims_avoided, 0);
    }

    #[test]
    fn bounds_toggle_changes_cost_never_results() {
        let grid = |bounds: bool| {
            let j = Json::parse(&format!(
                r#"{{"designs": [{{"design": "fig2", "scenarios": [[8], [16]]}}],
                    "optimizers": ["grouped_sa"], "budget": 80, "seeds": [1],
                    "jobs": 1, "bounds": {bounds}}}"#
            ))
            .unwrap();
            run_sweep(&SweepConfig::from_json(&j).unwrap()).unwrap()
        };
        let on = grid(true);
        let off = grid(false);
        assert_eq!(on[0].star_latency, off[0].star_latency);
        assert_eq!(on[0].star_bram, off[0].star_bram);
        assert_eq!(on[0].front_size, off[0].front_size);
        assert_eq!(on[0].evals, off[0].evals);
        assert!(on[0].sims <= off[0].sims, "bounds must never add sims");
        // The Baseline-Min probe sits below fig2's analytic floor, so the
        // bounded run answers at least that one without simulating.
        assert!(on[0].bounds_floor_hits >= 1);
        assert_eq!(off[0].bounds_floor_hits, 0);
        assert_eq!(off[0].cap_tightenings, 0);
    }

    #[test]
    fn backend_key_selects_simulator_and_never_changes_results() {
        let grid = |backend: &str| {
            let j = Json::parse(&format!(
                r#"{{"designs": [{{"design": "fig2", "scenarios": [[8], [16]]}}],
                    "optimizers": ["grouped_sa"], "budget": 60, "seeds": [1],
                    "jobs": 1, "backend": "{backend}"}}"#
            ))
            .unwrap();
            run_sweep(&SweepConfig::from_json(&j).unwrap()).unwrap()
        };
        let fast = grid("fast");
        for backend in ["compiled", "batched"] {
            let other = grid(backend);
            assert_eq!(fast[0].star_latency, other[0].star_latency, "{backend}");
            assert_eq!(fast[0].star_bram, other[0].star_bram, "{backend}");
            assert_eq!(fast[0].front_size, other[0].front_size, "{backend}");
            assert_eq!(fast[0].evals, other[0].evals, "{backend}");
            assert_eq!(fast[0].sims, other[0].sims, "{backend}");
            if backend == "batched" {
                assert!(other[0].lanes_per_walk >= 1.0, "lane telemetry missing");
                assert!(other[0].batch_occupancy > 0.0);
            } else {
                assert_eq!(other[0].lanes_per_walk, 0.0);
            }
        }
        assert_eq!(fast[0].lanes_per_walk, 0.0);
        assert_eq!(fast[0].walks_saved, 0);

        let defaulted = Json::parse(
            r#"{"designs": ["fig2"], "optimizers": ["greedy"]}"#,
        )
        .unwrap();
        assert_eq!(
            SweepConfig::from_json(&defaulted).unwrap().backend,
            BackendKind::Fast
        );
        let bad = Json::parse(
            r#"{"designs": ["fig2"], "optimizers": ["greedy"], "backend": "gpu"}"#,
        )
        .unwrap();
        assert!(SweepConfig::from_json(&bad).is_err());
    }

    #[test]
    fn scenario_lists_build_workload_runs() {
        let j = Json::parse(
            r#"{"designs": [{"design": "fig2", "scenarios": [[8], [16]]}],
                "optimizers": ["greedy"], "budget": 60, "seeds": [1], "jobs": 1}"#,
        )
        .unwrap();
        let cfg = SweepConfig::from_json(&j).unwrap();
        assert_eq!(cfg.designs[0].arg_sets, vec![vec![8], vec![16]]);
        let rows = run_sweep(&cfg).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].scenarios, 2);
        // Worst-case baseline latency comes from the n=16 scenario, so it
        // matches a plain single-scenario n=16 run's baseline.
        let j16 = Json::parse(
            r#"{"designs": [{"design": "fig2", "scenarios": [[16]]}],
                "optimizers": ["greedy"], "budget": 60, "seeds": [1], "jobs": 1}"#,
        )
        .unwrap();
        let rows16 = run_sweep(&SweepConfig::from_json(&j16).unwrap()).unwrap();
        assert_eq!(rows[0].base_latency, rows16[0].base_latency);
        let md = rows_to_markdown(&rows);
        assert!(md.contains("| 2 |"), "scenario count column missing: {md}");

        // Malformed scenario entries are rejected.
        let bad = Json::parse(
            r#"{"designs": [{"design": "fig2", "scenarios": []}], "optimizers": ["greedy"]}"#,
        )
        .unwrap();
        assert!(SweepConfig::from_json(&bad).is_err());
        let bad = Json::parse(
            r#"{"designs": [{"design": "fig2", "scenarios": [["x"]]}], "optimizers": ["greedy"]}"#,
        )
        .unwrap();
        assert!(SweepConfig::from_json(&bad).is_err());
    }

    #[test]
    fn cell_sim_budget_truncates_without_failing() {
        let j = Json::parse(
            r#"{"designs": ["fig2"], "optimizers": ["grouped_sa"], "budget": 200,
                "seeds": [1], "jobs": 1, "cell_sim_budget": 1}"#,
        )
        .unwrap();
        let cfg = SweepConfig::from_json(&j).unwrap();
        let out = run_sweep_with(&cfg, &SweepHooks::default()).unwrap();
        assert!(out.failed.is_empty(), "budget exhaustion is not failure");
        assert_eq!(out.rows.len(), 1);
        assert!(out.rows[0].truncated, "sim budget must flag truncation");
        assert_eq!(out.truncated, 1);
        assert!(
            out.rows[0].evals < 200,
            "truncated run must stop well short of the proposal budget"
        );
        let md = rows_to_markdown(&out.rows);
        assert!(md.contains("✂"), "markdown must mark truncated rows");
    }

    #[test]
    fn distill_key_matches_plain_cells_bit_for_bit() {
        let base = r#"{"designs": [{"design": "fig2", "scenarios": [[8], [16], [12]]}],
            "optimizers": ["sa"], "budget": 80, "seeds": [1], "jobs": 1"#;
        let plain_cfg =
            SweepConfig::from_json(&Json::parse(&format!("{base}}}")).unwrap()).unwrap();
        let dist_cfg =
            SweepConfig::from_json(&Json::parse(&format!("{base}, \"distill\": true}}")).unwrap())
                .unwrap();
        assert_ne!(
            plain_cfg.config_hash(),
            dist_cfg.config_hash(),
            "distill is a row-content key and must fingerprint"
        );
        let plain = run_sweep(&plain_cfg).unwrap();
        let dist = run_sweep(&dist_cfg).unwrap();
        assert_eq!(plain.len(), 1);
        assert_eq!(dist.len(), 1);
        let (p, d) = (&plain[0], &dist[0]);
        // Distillation changes cost, never results.
        assert_eq!(d.evals, p.evals);
        assert_eq!(d.front_size, p.front_size);
        assert_eq!(d.star_latency, p.star_latency);
        assert_eq!(d.star_bram, p.star_bram);
        assert_eq!(d.base_latency, p.base_latency);
        assert_eq!(d.base_bram, p.base_bram);
        assert_eq!(d.min_deadlocked, p.min_deadlocked);
        assert!(p.distilled.is_empty(), "plain cells leave the column empty");
        assert!(
            d.distilled.contains("/3"),
            "distilled column must show kept/total: {:?}",
            d.distilled
        );
        let kept: usize = d.distilled.split('/').next().unwrap().parse().unwrap();
        assert!(
            kept < 3,
            "fig2 n=16 dominates the smaller scenarios, so some must drop"
        );
        let md = rows_to_markdown(&dist);
        assert!(md.contains(&d.distilled), "dstl column missing: {md}");
    }

    #[test]
    fn certify_key_emits_verdicts_per_design() {
        let j = Json::parse(
            r#"{"designs": ["fig2", "gesummv"], "optimizers": ["greedy"], "budget": 40,
                "seeds": [1], "jobs": 1, "certify": true, "certify_budget": 40}"#,
        )
        .unwrap();
        let cfg = SweepConfig::from_json(&j).unwrap();
        let rows = run_sweep(&cfg).unwrap();
        let fig2 = rows.iter().find(|r| r.design == "fig2").unwrap();
        // Budget 40 covers fig2's 31-point arg space, so auto enumerates
        // it exhaustively: the verdict is exact either way.
        assert!(
            fig2.certified.starts_with("broken@") || fig2.certified == "clean-exhaustive(31)",
            "unexpected verdict {:?}",
            fig2.certified
        );
        let ges = rows.iter().find(|r| r.design == "gesummv").unwrap();
        assert_eq!(ges.certified, "no-arg-space");
        let md = rows_to_markdown(&rows);
        assert!(md.contains("no-arg-space"), "cert column missing: {md}");
    }
}
