//! Fluent builders for constructing [`Design`]s in Rust, used by the
//! benchmark-suite generators and by tests. The builder mirrors how an HLS
//! designer structures a dataflow region: declare streams (scalars or
//! arrays), then define each task function.

use super::{Channel, ChannelId, Design, Expr, Instr, Process, VarId};

/// Builds a [`Design`].
pub struct DesignBuilder {
    name: String,
    num_args: usize,
    channels: Vec<Channel>,
    processes: Vec<Process>,
}

impl DesignBuilder {
    /// Start a design taking `num_args` runtime kernel arguments.
    pub fn new(name: &str, num_args: usize) -> Self {
        DesignBuilder {
            name: name.to_string(),
            num_args,
            channels: Vec::new(),
            processes: Vec::new(),
        }
    }

    /// Declare a scalar stream: `hls::stream<intW> name`.
    pub fn channel(&mut self, name: &str, width_bits: u32) -> ChannelId {
        self.channel_full(name, width_bits, None, None)
    }

    /// Declare a scalar stream with a designer-specified depth
    /// (`#pragma HLS stream variable=name depth=d`).
    pub fn channel_with_depth(&mut self, name: &str, width_bits: u32, depth: u32) -> ChannelId {
        self.channel_full(name, width_bits, None, Some(depth))
    }

    /// Declare a stream array: `hls::stream<intW> name[n]`. All elements
    /// share the group `name` (grouped optimizers size them together).
    pub fn channel_array(&mut self, name: &str, n: usize, width_bits: u32) -> Vec<ChannelId> {
        (0..n)
            .map(|i| {
                self.channel_full(
                    &format!("{name}[{i}]"),
                    width_bits,
                    Some(name.to_string()),
                    None,
                )
            })
            .collect()
    }

    /// Stream array with a designer-specified depth.
    pub fn channel_array_with_depth(
        &mut self,
        name: &str,
        n: usize,
        width_bits: u32,
        depth: u32,
    ) -> Vec<ChannelId> {
        (0..n)
            .map(|i| {
                self.channel_full(
                    &format!("{name}[{i}]"),
                    width_bits,
                    Some(name.to_string()),
                    Some(depth),
                )
            })
            .collect()
    }

    fn channel_full(
        &mut self,
        name: &str,
        width_bits: u32,
        group: Option<String>,
        depth_hint: Option<u32>,
    ) -> ChannelId {
        assert!(width_bits > 0, "channel width must be positive");
        let id = self.channels.len();
        self.channels.push(Channel {
            name: name.to_string(),
            width_bits,
            group,
            depth_hint,
        });
        id
    }

    /// Define a process; the closure receives a [`ProcBuilder`].
    pub fn process<F: FnOnce(&mut ProcBuilder)>(&mut self, name: &str, f: F) {
        let mut pb = ProcBuilder {
            num_vars: 0,
            stack: vec![Vec::new()],
        };
        f(&mut pb);
        assert_eq!(pb.stack.len(), 1, "unbalanced builder scopes");
        self.processes.push(Process {
            name: name.to_string(),
            body: pb.stack.pop().unwrap(),
            num_vars: pb.num_vars,
        });
    }

    /// Finish the design.
    pub fn build(self) -> Design {
        assert!(!self.processes.is_empty(), "design has no processes");
        Design {
            name: self.name,
            channels: self.channels,
            processes: self.processes,
            num_args: self.num_args,
        }
    }
}

/// Builds one process body. Control-flow methods (`for_n`, `for_expr`,
/// `if_`) take closures that emit the nested body.
pub struct ProcBuilder {
    num_vars: usize,
    /// Stack of instruction lists; index 0 is the top-level body, deeper
    /// entries are open loop/branch bodies.
    stack: Vec<Vec<Instr>>,
}

impl ProcBuilder {
    fn emit(&mut self, i: Instr) {
        self.stack.last_mut().unwrap().push(i);
    }

    /// Allocate a fresh variable slot.
    pub fn var(&mut self) -> VarId {
        let v = self.num_vars;
        self.num_vars += 1;
        v
    }

    /// `var = expr`
    pub fn set(&mut self, var: VarId, e: Expr) {
        self.emit(Instr::Set(var, e));
    }

    /// Spend `cycles` compute cycles.
    pub fn delay(&mut self, cycles: u32) {
        if cycles > 0 {
            self.emit(Instr::Delay(Expr::c(cycles as i64)));
        }
    }

    /// Spend a data-dependent number of compute cycles.
    pub fn delay_expr(&mut self, e: Expr) {
        self.emit(Instr::Delay(e));
    }

    /// Blocking write.
    pub fn write(&mut self, ch: ChannelId, e: Expr) {
        self.emit(Instr::Write(ch, e));
    }

    /// Blocking read into a fresh variable; returns the variable.
    pub fn read(&mut self, ch: ChannelId) -> VarId {
        let v = self.var();
        self.emit(Instr::Read(ch, v));
        v
    }

    /// Blocking read into an existing variable.
    pub fn read_into(&mut self, ch: ChannelId, var: VarId) {
        self.emit(Instr::Read(ch, var));
    }

    /// `for i in 0..n { body }` with a constant trip count.
    pub fn for_n<F: FnOnce(&mut ProcBuilder, VarId)>(&mut self, n: u64, f: F) {
        self.for_expr(Expr::c(n as i64), f);
    }

    /// `for i in 0..count { body }` with a (possibly data-dependent) trip
    /// count expression, evaluated at loop entry.
    pub fn for_expr<F: FnOnce(&mut ProcBuilder, VarId)>(&mut self, count: Expr, f: F) {
        let var = self.var();
        self.stack.push(Vec::new());
        f(self, var);
        let body = self.stack.pop().unwrap();
        self.emit(Instr::For {
            var,
            start: Expr::c(0),
            count,
            body,
        });
    }

    /// `if cond != 0 { then } else { else }`.
    pub fn if_<T: FnOnce(&mut ProcBuilder), E: FnOnce(&mut ProcBuilder)>(
        &mut self,
        cond: Expr,
        then_f: T,
        else_f: E,
    ) {
        self.stack.push(Vec::new());
        then_f(self);
        let then_body = self.stack.pop().unwrap();
        self.stack.push(Vec::new());
        else_f(self);
        let else_body = self.stack.pop().unwrap();
        self.emit(Instr::If {
            cond,
            then_body,
            else_body,
        });
    }

    /// `if cond != 0 { then }` with no else branch.
    pub fn if_then<T: FnOnce(&mut ProcBuilder)>(&mut self, cond: Expr, then_f: T) {
        self.if_(cond, then_f, |_| {});
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_produces_expected_shapes() {
        let mut b = DesignBuilder::new("t", 2);
        let x = b.channel("x", 32);
        let deep = b.channel_with_depth("deep", 64, 512);
        b.process("prod", |p| {
            p.for_expr(Expr::arg(0), |p, _i| {
                p.delay(3);
                p.write(x, Expr::c(1));
            });
            p.write(deep, Expr::c(9));
        });
        b.process("cons", |p| {
            p.for_expr(Expr::arg(0), |p, _| {
                let _ = p.read(x);
            });
            let _ = p.read(deep);
        });
        let d = b.build();
        assert_eq!(d.num_args, 2);
        assert_eq!(d.channels[1].depth_hint, Some(512));
        assert_eq!(d.processes.len(), 2);
        // prod body: For + Write
        assert_eq!(d.processes[0].body.len(), 2);
        match &d.processes[0].body[0] {
            Instr::For { body, .. } => assert_eq!(body.len(), 2),
            other => panic!("expected For, got {other:?}"),
        }
    }

    #[test]
    fn if_builder_nests() {
        let mut b = DesignBuilder::new("t", 1);
        let x = b.channel("x", 8);
        b.process("p", |p| {
            p.if_(
                Expr::arg(0).lt(Expr::c(5)),
                |p| p.write(x, Expr::c(1)),
                |p| {
                    p.write(x, Expr::c(2));
                    p.write(x, Expr::c(3));
                },
            );
        });
        let d = b.build();
        match &d.processes[0].body[0] {
            Instr::If {
                then_body,
                else_body,
                ..
            } => {
                assert_eq!(then_body.len(), 1);
                assert_eq!(else_body.len(), 2);
            }
            other => panic!("expected If, got {other:?}"),
        }
    }

    #[test]
    #[should_panic(expected = "design has no processes")]
    fn empty_design_rejected() {
        DesignBuilder::new("empty", 0).build();
    }
}
