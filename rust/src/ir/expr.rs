//! VM expressions: integer arithmetic over constants, runtime kernel
//! arguments, and process-local variables. Comparisons yield 0/1 so they
//! can be used as `If` conditions or arithmetic operands.

use super::VarId;

/// An integer expression evaluated by the VM.
#[derive(Debug, Clone)]
pub enum Expr {
    /// Integer literal.
    Const(i64),
    /// Runtime kernel argument (the source of data-dependent control flow).
    Arg(usize),
    /// Process-local variable.
    Var(VarId),
    Add(Box<Expr>, Box<Expr>),
    Sub(Box<Expr>, Box<Expr>),
    Mul(Box<Expr>, Box<Expr>),
    /// Truncating division; division by zero evaluates to 0 (HLS designs
    /// guard their divides; the VM must still be total).
    Div(Box<Expr>, Box<Expr>),
    /// Remainder; by zero evaluates to 0.
    Mod(Box<Expr>, Box<Expr>),
    Min(Box<Expr>, Box<Expr>),
    Max(Box<Expr>, Box<Expr>),
    /// 1 if `lhs < rhs` else 0.
    Lt(Box<Expr>, Box<Expr>),
    /// 1 if `lhs <= rhs` else 0.
    Le(Box<Expr>, Box<Expr>),
    /// 1 if equal else 0.
    Eq(Box<Expr>, Box<Expr>),
}

impl Expr {
    /// Shorthand constant constructor.
    pub fn c(v: i64) -> Expr {
        Expr::Const(v)
    }

    /// Shorthand argument reference.
    pub fn arg(i: usize) -> Expr {
        Expr::Arg(i)
    }

    /// Shorthand variable reference.
    pub fn var(v: VarId) -> Expr {
        Expr::Var(v)
    }

    pub fn add(self, rhs: Expr) -> Expr {
        Expr::Add(Box::new(self), Box::new(rhs))
    }
    pub fn sub(self, rhs: Expr) -> Expr {
        Expr::Sub(Box::new(self), Box::new(rhs))
    }
    pub fn mul(self, rhs: Expr) -> Expr {
        Expr::Mul(Box::new(self), Box::new(rhs))
    }
    pub fn div(self, rhs: Expr) -> Expr {
        Expr::Div(Box::new(self), Box::new(rhs))
    }
    pub fn rem(self, rhs: Expr) -> Expr {
        Expr::Mod(Box::new(self), Box::new(rhs))
    }
    pub fn min(self, rhs: Expr) -> Expr {
        Expr::Min(Box::new(self), Box::new(rhs))
    }
    pub fn max(self, rhs: Expr) -> Expr {
        Expr::Max(Box::new(self), Box::new(rhs))
    }
    pub fn lt(self, rhs: Expr) -> Expr {
        Expr::Lt(Box::new(self), Box::new(rhs))
    }
    pub fn le(self, rhs: Expr) -> Expr {
        Expr::Le(Box::new(self), Box::new(rhs))
    }
    pub fn eq(self, rhs: Expr) -> Expr {
        Expr::Eq(Box::new(self), Box::new(rhs))
    }

    /// Evaluate against argument and variable stores. Wrapping arithmetic:
    /// HLS integer semantics, and the VM must never panic on user designs.
    pub fn eval(&self, args: &[i64], vars: &[i64]) -> i64 {
        match self {
            Expr::Const(v) => *v,
            Expr::Arg(i) => args[*i],
            Expr::Var(v) => vars[*v],
            Expr::Add(a, b) => a.eval(args, vars).wrapping_add(b.eval(args, vars)),
            Expr::Sub(a, b) => a.eval(args, vars).wrapping_sub(b.eval(args, vars)),
            Expr::Mul(a, b) => a.eval(args, vars).wrapping_mul(b.eval(args, vars)),
            Expr::Div(a, b) => {
                let d = b.eval(args, vars);
                if d == 0 {
                    0
                } else {
                    a.eval(args, vars).wrapping_div(d)
                }
            }
            Expr::Mod(a, b) => {
                let d = b.eval(args, vars);
                if d == 0 {
                    0
                } else {
                    a.eval(args, vars).wrapping_rem(d)
                }
            }
            Expr::Min(a, b) => a.eval(args, vars).min(b.eval(args, vars)),
            Expr::Max(a, b) => a.eval(args, vars).max(b.eval(args, vars)),
            Expr::Lt(a, b) => (a.eval(args, vars) < b.eval(args, vars)) as i64,
            Expr::Le(a, b) => (a.eval(args, vars) <= b.eval(args, vars)) as i64,
            Expr::Eq(a, b) => (a.eval(args, vars) == b.eval(args, vars)) as i64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let args = [10i64];
        let vars = [3i64, -2];
        let e = Expr::arg(0).add(Expr::var(0)).mul(Expr::c(2)); // (10+3)*2
        assert_eq!(e.eval(&args, &vars), 26);
        assert_eq!(Expr::c(7).div(Expr::c(2)).eval(&[], &[]), 3);
        assert_eq!(Expr::c(7).rem(Expr::c(4)).eval(&[], &[]), 3);
        assert_eq!(Expr::c(7).div(Expr::c(0)).eval(&[], &[]), 0);
        assert_eq!(Expr::c(7).rem(Expr::c(0)).eval(&[], &[]), 0);
    }

    #[test]
    fn comparisons_and_minmax() {
        assert_eq!(Expr::c(1).lt(Expr::c(2)).eval(&[], &[]), 1);
        assert_eq!(Expr::c(2).lt(Expr::c(2)).eval(&[], &[]), 0);
        assert_eq!(Expr::c(2).le(Expr::c(2)).eval(&[], &[]), 1);
        assert_eq!(Expr::c(2).eq(Expr::c(2)).eval(&[], &[]), 1);
        assert_eq!(Expr::c(5).min(Expr::c(3)).eval(&[], &[]), 3);
        assert_eq!(Expr::c(5).max(Expr::c(3)).eval(&[], &[]), 5);
    }

    #[test]
    fn wrapping_does_not_panic() {
        let e = Expr::c(i64::MAX).add(Expr::c(1));
        assert_eq!(e.eval(&[], &[]), i64::MIN);
        let m = Expr::c(i64::MIN).div(Expr::c(-1));
        // wrapping_div(MIN, -1) == MIN
        assert_eq!(m.eval(&[], &[]), i64::MIN);
    }
}
