//! FADL — the FIFOAdvisor design language: a small text format so the
//! tool can size FIFOs of user designs *standalone*, without writing Rust
//! (the paper open-sources FIFOAdvisor "as a standalone tool for HLS
//! designers"). A FADL file describes the dataflow region the way a
//! designer thinks about it: streams, stream arrays, and per-task
//! programs over them.
//!
//! ```text
//! design mult_by_2 args 1
//!
//! stream x width 32
//! stream y width 32
//! stream d[4] width 8 depth 64        # array of 4, designer depth hint
//!
//! process producer {
//!   for i in 0..arg0 { write x 1 }
//!   for i in 0..arg0 { write y 1 }
//! }
//! process consumer {
//!   let sum = 0
//!   for i in 0..arg0 {
//!     read x -> a
//!     read y -> b
//!     let sum = sum + a + b
//!   }
//! }
//! ```
//!
//! Statements: `let NAME = EXPR`, `delay EXPR`, `write STREAM EXPR`,
//! `read STREAM -> NAME`, `for NAME in EXPR..EXPR { ... }`,
//! `if EXPR { ... } [else { ... }]`. Expressions: integer literals,
//! `argN`, variables, `+ - * / % min max < <= ==` with parentheses
//! (no precedence — fully parenthesize mixed operators). Stream element
//! references: `s` (scalar) or `s[INDEX]` (constant index into an array).
//! `#` starts a comment.

use super::{ChannelId, Design, DesignBuilder, Expr, VarId};
use std::collections::HashMap;
use thiserror::Error;

#[derive(Debug, Error)]
#[error("fadl parse error at line {line}: {msg}")]
pub struct FadlError {
    pub line: usize,
    pub msg: String,
}

/// Parse FADL source text into a [`Design`].
pub fn parse(src: &str) -> Result<Design, FadlError> {
    Parser::new(src).parse()
}

/// Parse a FADL file.
pub fn parse_file(path: &str) -> anyhow::Result<Design> {
    let text = std::fs::read_to_string(path)?;
    Ok(parse(&text)?)
}

struct Tok {
    line: usize,
    text: String,
}

struct Parser {
    toks: Vec<Tok>,
    pos: usize,
}

impl Parser {
    fn new(src: &str) -> Parser {
        let mut toks = Vec::new();
        for (ln, raw) in src.lines().enumerate() {
            let line = ln + 1;
            let code = raw.split('#').next().unwrap_or("");
            let spaced = code
                .replace('{', " { ")
                .replace('}', " } ")
                .replace('(', " ( ")
                .replace(')', " ) ")
                .replace("->", " -> ")
                .replace("..", " .. ");
            for t in spaced.split_whitespace() {
                toks.push(Tok {
                    line,
                    text: t.to_string(),
                });
            }
        }
        Parser { toks, pos: 0 }
    }

    fn err(&self, msg: impl Into<String>) -> FadlError {
        FadlError {
            line: self.toks.get(self.pos.min(self.toks.len().saturating_sub(1)))
                .map(|t| t.line)
                .unwrap_or(0),
            msg: msg.into(),
        }
    }

    fn peek(&self) -> Option<&str> {
        self.toks.get(self.pos).map(|t| t.text.as_str())
    }

    fn next(&mut self) -> Result<&str, FadlError> {
        if self.pos >= self.toks.len() {
            return Err(FadlError {
                line: self.toks.last().map(|t| t.line).unwrap_or(0),
                msg: "unexpected end of file".into(),
            });
        }
        self.pos += 1;
        Ok(self.toks[self.pos - 1].text.as_str())
    }

    fn expect(&mut self, what: &str) -> Result<(), FadlError> {
        let line = self.toks.get(self.pos).map(|t| t.line).unwrap_or(0);
        let t = self.next()?;
        if t == what {
            Ok(())
        } else {
            let msg = format!("expected '{what}', got '{t}'");
            Err(FadlError { line, msg })
        }
    }

    fn parse(mut self) -> Result<Design, FadlError> {
        self.expect("design")?;
        let name = self.next()?.to_string();
        let mut num_args = 0usize;
        if self.peek() == Some("args") {
            self.next()?;
            num_args = self
                .next()?
                .parse()
                .map_err(|_| self.err("bad args count"))?;
        }
        let mut b = DesignBuilder::new(&name, num_args);
        // stream name → (first channel id, array length or 0 for scalar)
        let mut streams: HashMap<String, (ChannelId, usize)> = HashMap::new();

        while let Some(tok) = self.peek() {
            match tok {
                "stream" => {
                    self.next()?;
                    let decl = self.next()?.to_string();
                    let (sname, arity) = match decl.find('[') {
                        Some(i) => {
                            let n: usize = decl[i + 1..decl.len() - 1]
                                .parse()
                                .map_err(|_| self.err("bad array length"))?;
                            (decl[..i].to_string(), n)
                        }
                        None => (decl.clone(), 0),
                    };
                    let mut width = 32u32;
                    let mut depth: Option<u32> = None;
                    while matches!(self.peek(), Some("width") | Some("depth")) {
                        match self.next()? {
                            "width" => {
                                width = self
                                    .next()?
                                    .parse()
                                    .map_err(|_| self.err("bad width"))?
                            }
                            _ => {
                                depth = Some(
                                    self.next()?
                                        .parse()
                                        .map_err(|_| self.err("bad depth"))?,
                                )
                            }
                        }
                    }
                    let first = if arity == 0 {
                        match depth {
                            Some(d) => b.channel_with_depth(&sname, width, d),
                            None => b.channel(&sname, width),
                        }
                    } else {
                        let ids = match depth {
                            Some(d) => b.channel_array_with_depth(&sname, arity, width, d),
                            None => b.channel_array(&sname, arity, width),
                        };
                        ids[0]
                    };
                    if streams.insert(sname.clone(), (first, arity)).is_some() {
                        return Err(self.err(format!("duplicate stream '{sname}'")));
                    }
                }
                "process" => {
                    self.next()?;
                    let pname = self.next()?.to_string();
                    self.expect("{")?;
                    let body = self.block(&streams, num_args)?;
                    // Install via builder internals: reconstruct with a
                    // closure that replays parsed body.
                    b.process(&pname, |pb| body.install(pb));
                }
                other => return Err(self.err(format!("expected 'stream' or 'process', got '{other}'"))),
            }
        }
        Ok(b.build())
    }

    /// Parse statements until the closing `}` (consumed).
    fn block(
        &mut self,
        streams: &HashMap<String, (ChannelId, usize)>,
        num_args: usize,
    ) -> Result<Block, FadlError> {
        let mut stmts = Vec::new();
        loop {
            match self.peek() {
                Some("}") => {
                    self.next()?;
                    return Ok(Block { stmts });
                }
                None => return Err(self.err("unterminated block")),
                Some("let") => {
                    self.next()?;
                    let name = self.next()?.to_string();
                    self.expect("=")?;
                    let e = self.expr(num_args)?;
                    stmts.push(Stmt::Let(name, e));
                }
                Some("delay") => {
                    self.next()?;
                    let e = self.expr(num_args)?;
                    stmts.push(Stmt::Delay(e));
                }
                Some("write") => {
                    self.next()?;
                    let ch = self.stream_ref(streams)?;
                    let e = self.expr(num_args)?;
                    stmts.push(Stmt::Write(ch, e));
                }
                Some("read") => {
                    self.next()?;
                    let ch = self.stream_ref(streams)?;
                    self.expect("->")?;
                    let name = self.next()?.to_string();
                    stmts.push(Stmt::Read(ch, name));
                }
                Some("for") => {
                    self.next()?;
                    let var = self.next()?.to_string();
                    self.expect("in")?;
                    let start = self.expr(num_args)?;
                    self.expect("..")?;
                    let end = self.expr(num_args)?;
                    self.expect("{")?;
                    let body = self.block(streams, num_args)?;
                    stmts.push(Stmt::For(var, start, end, body));
                }
                Some("if") => {
                    self.next()?;
                    let cond = self.expr(num_args)?;
                    self.expect("{")?;
                    let then_b = self.block(streams, num_args)?;
                    let else_b = if self.peek() == Some("else") {
                        self.next()?;
                        self.expect("{")?;
                        self.block(streams, num_args)?
                    } else {
                        Block { stmts: Vec::new() }
                    };
                    stmts.push(Stmt::If(cond, then_b, else_b));
                }
                Some(other) => {
                    let msg = format!("unknown statement '{other}'");
                    return Err(self.err(msg));
                }
            }
        }
    }

    fn stream_ref(
        &mut self,
        streams: &HashMap<String, (ChannelId, usize)>,
    ) -> Result<ChannelId, FadlError> {
        let t = self.next()?.to_string();
        let (name, idx) = match t.find('[') {
            Some(i) => {
                let idx: usize = t[i + 1..t.len() - 1]
                    .parse()
                    .map_err(|_| self.err("bad stream index"))?;
                (t[..i].to_string(), idx)
            }
            None => (t, 0),
        };
        let &(first, arity) = streams
            .get(&name)
            .ok_or_else(|| self.err(format!("unknown stream '{name}'")))?;
        if arity == 0 && idx != 0 {
            return Err(self.err(format!("'{name}' is not an array")));
        }
        if arity > 0 && idx >= arity {
            return Err(self.err(format!("index {idx} out of range for '{name}[{arity}]'")));
        }
        Ok(first + idx)
    }

    /// Expressions: atom (op atom)* — same-operator chains only (no
    /// precedence; parenthesize mixed operators).
    fn expr(&mut self, num_args: usize) -> Result<PExpr, FadlError> {
        let mut lhs = self.atom(num_args)?;
        let mut seen_op: Option<String> = None;
        while let Some(op) = self.peek() {
            if !matches!(op, "+" | "-" | "*" | "/" | "%" | "min" | "max" | "<" | "<=" | "==") {
                break;
            }
            let op = op.to_string();
            if let Some(prev) = &seen_op {
                if *prev != op {
                    return Err(self.err(format!(
                        "mixing '{prev}' and '{op}' without parentheses"
                    )));
                }
            }
            seen_op = Some(op.clone());
            self.next()?;
            let rhs = self.atom(num_args)?;
            lhs = PExpr::Bin(op, Box::new(lhs), Box::new(rhs));
        }
        Ok(lhs)
    }

    fn atom(&mut self, num_args: usize) -> Result<PExpr, FadlError> {
        let line_guard = self.err("expected expression");
        let t = self.next()?.to_string();
        if t == "(" {
            let e = self.expr(num_args)?;
            self.expect(")")?;
            return Ok(e);
        }
        if let Ok(v) = t.parse::<i64>() {
            return Ok(PExpr::Const(v));
        }
        if let Some(rest) = t.strip_prefix("arg") {
            if let Ok(i) = rest.parse::<usize>() {
                if i >= num_args {
                    return Err(self.err(format!("arg{i} out of range (design has {num_args})")));
                }
                return Ok(PExpr::Arg(i));
            }
        }
        if t.chars().all(|c| c.is_alphanumeric() || c == '_') && !t.is_empty() {
            return Ok(PExpr::Var(t));
        }
        let _ = line_guard;
        Err(self.err(format!("bad expression token '{t}'")))
    }
}

/// Parsed (name-based) expression, resolved to VM [`Expr`] at install.
#[derive(Debug, Clone)]
enum PExpr {
    Const(i64),
    Arg(usize),
    Var(String),
    Bin(String, Box<PExpr>, Box<PExpr>),
}

#[derive(Debug, Clone)]
enum Stmt {
    Let(String, PExpr),
    Delay(PExpr),
    Write(ChannelId, PExpr),
    Read(ChannelId, String),
    For(String, PExpr, PExpr, Block),
    If(PExpr, Block, Block),
}

#[derive(Debug, Clone)]
struct Block {
    stmts: Vec<Stmt>,
}

impl PExpr {
    fn resolve(&self, vars: &HashMap<String, VarId>) -> Expr {
        match self {
            PExpr::Const(v) => Expr::Const(*v),
            PExpr::Arg(i) => Expr::Arg(*i),
            PExpr::Var(name) => match vars.get(name) {
                Some(&v) => Expr::Var(v),
                // Unknown variables read as 0 (like uninitialized C ints
                // would be UB; we pick a total semantics).
                None => Expr::Const(0),
            },
            PExpr::Bin(op, a, b) => {
                let (a, b) = (a.resolve(vars), b.resolve(vars));
                match op.as_str() {
                    "+" => a.add(b),
                    "-" => a.sub(b),
                    "*" => a.mul(b),
                    "/" => a.div(b),
                    "%" => a.rem(b),
                    "min" => a.min(b),
                    "max" => a.max(b),
                    "<" => a.lt(b),
                    "<=" => a.le(b),
                    _ => a.eq(b),
                }
            }
        }
    }
}

impl Block {
    fn install(&self, pb: &mut super::ProcBuilder) {
        let mut vars = HashMap::new();
        self.install_scoped(pb, &mut vars);
    }

    fn install_scoped(&self, pb: &mut super::ProcBuilder, vars: &mut HashMap<String, VarId>) {
        for stmt in &self.stmts {
            match stmt {
                Stmt::Let(name, e) => {
                    let expr = e.resolve(vars);
                    let v = *vars.entry(name.clone()).or_insert_with(|| pb.var());
                    pb.set(v, expr);
                }
                Stmt::Delay(e) => pb.delay_expr(e.resolve(vars)),
                Stmt::Write(ch, e) => pb.write(*ch, e.resolve(vars)),
                Stmt::Read(ch, name) => {
                    let v = *vars.entry(name.clone()).or_insert_with(|| pb.var());
                    pb.read_into(*ch, v);
                }
                Stmt::For(var, start, end, body) => {
                    let s = start.resolve(vars);
                    let e = end.resolve(vars);
                    let count = e.sub(s.clone());
                    let loop_var = pb.var();
                    vars.insert(var.clone(), loop_var);
                    // for_expr allocates its own var; we emit manually to
                    // bind the named variable: use ProcBuilder::for_expr
                    // with Set to alias.
                    let body_c = body.clone();
                    let mut vars_c = vars.clone();
                    pb.for_expr(count, |pb, i| {
                        pb.set(loop_var, Expr::Var(i).add(s));
                        body_c.install_scoped(pb, &mut vars_c);
                    });
                }
                Stmt::If(cond, then_b, else_b) => {
                    let c = cond.resolve(vars);
                    let (tb, eb) = (then_b.clone(), else_b.clone());
                    let mut tv = vars.clone();
                    let mut evs = vars.clone();
                    pb.if_(
                        c,
                        |pb| tb.install_scoped(pb, &mut tv),
                        |pb| eb.install_scoped(pb, &mut evs),
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::fast::FastSim;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    const FIG2: &str = r#"
design mult_by_2 args 1

stream x width 32
stream y width 32

process producer {
  for i in 0..arg0 { write x 1 }
  for i in 0..arg0 { write y 1 }
}
process consumer {
  let sum = 0
  for i in 0..arg0 {
    read x -> a
    read y -> b
    let sum = sum + a + b
  }
}
"#;

    #[test]
    fn fadl_fig2_matches_builder_fig2() {
        let parsed = parse(FIG2).unwrap();
        let built = crate::bench_suite::fig2::mult_by_2(16).design;
        let tp = Arc::new(collect_trace(&parsed, &[16]).unwrap());
        let tb = Arc::new(collect_trace(&built, &[16]).unwrap());
        assert_eq!(tp.total_ops(), tb.total_ops());
        // Same latency at the same depths.
        for depths in [[16u32, 2], [15, 2], [2, 2]] {
            let lp = FastSim::new(tp.clone()).simulate(&depths).latency();
            let lb = FastSim::new(tb.clone()).simulate(&depths).latency();
            assert_eq!(lp, lb, "depths {depths:?}");
        }
    }

    #[test]
    fn arrays_hints_and_indexing() {
        let src = r#"
design arr args 0
stream d[3] width 8 depth 64
process p {
  for i in 0..10 {
    write d[0] i
    write d[1] i
    write d[2] i
  }
}
process q {
  for i in 0..10 {
    read d[0] -> a
    read d[1] -> b
    read d[2] -> c
  }
}
"#;
        let design = parse(src).unwrap();
        assert_eq!(design.num_fifos(), 3);
        assert_eq!(design.channels[1].depth_hint, Some(64));
        assert_eq!(design.channels[2].group.as_deref(), Some("d"));
        let t = collect_trace(&design, &[]).unwrap();
        assert_eq!(t.channels[0].writes, 10);
    }

    #[test]
    fn if_else_and_delay() {
        let src = r#"
design br args 1
stream c width 32
process p {
  if arg0 < 5 {
    write c 1
  } else {
    delay 10
    write c 2
    write c 3
  }
}
process q {
  if arg0 < 5 {
    read c -> v
  } else {
    read c -> v
    read c -> v
  }
}
"#;
        let d = parse(src).unwrap();
        assert_eq!(collect_trace(&d, &[1]).unwrap().channels[0].writes, 1);
        assert_eq!(collect_trace(&d, &[9]).unwrap().channels[0].writes, 2);
    }

    #[test]
    fn parse_errors_have_lines() {
        let bad = "design x args 0\nstream s width 32\nprocess p {\n  frobnicate\n}\n";
        let err = parse(bad).unwrap_err();
        assert_eq!(err.line, 4);
        assert!(err.msg.contains("frobnicate"));

        assert!(parse("design x\nstream s\nprocess p { write t 1 }").is_err());
        assert!(parse("design x\nprocess p { if 1 { }").is_err()); // unterminated
    }

    #[test]
    fn mixed_operators_require_parens() {
        let src = "design x args 0\nstream s width 32\nprocess p { write s 1 + 2 * 3 }\nprocess q { read s -> v }";
        assert!(parse(src).is_err());
        let ok = "design x args 0\nstream s width 32\nprocess p { write s 1 + ( 2 * 3 ) }\nprocess q { read s -> v }";
        let d = parse(ok).unwrap();
        let t = collect_trace(&d, &[]).unwrap();
        assert_eq!(t.channels[0].writes, 1);
    }

    #[test]
    fn loop_bounds_with_start() {
        let src = r#"
design rng args 0
stream s width 32
process p {
  for i in 3..7 { write s i }
}
process q {
  for i in 0..4 { read s -> v }
}
"#;
        let d = parse(src).unwrap();
        let t = collect_trace(&d, &[]).unwrap();
        assert_eq!(t.channels[0].writes, 4);
    }
}
