//! Dataflow design intermediate representation.
//!
//! A [`Design`] stands in for a Vitis-HLS dataflow region (`#pragma HLS
//! dataflow`): a set of concurrently-started [`Process`]es (HLS functions)
//! communicating through FIFO [`Channel`]s (`hls::stream`). Each process
//! body is a program in a small imperative VM language ([`Instr`] /
//! [`Expr`]) supporting loops, conditionals, arithmetic, *data-dependent
//! control flow* (loop bounds and branches computed from runtime kernel
//! arguments or values read from streams), compute delays, and blocking
//! stream reads/writes.
//!
//! "Software execution" of this VM (see [`crate::trace`]) plays the role
//! LightningSim's trace collection plays for real HLS C++: it records the
//! exact sequence of FIFO operations and inter-operation delays, which —
//! by Kahn-process-network determinism — is independent of FIFO depths.

pub mod builder;
pub mod expr;
pub mod fadl;

pub use builder::{DesignBuilder, ProcBuilder};
pub use expr::Expr;

/// Index of a channel within its design.
pub type ChannelId = usize;
/// Index of a VM variable within its process.
pub type VarId = usize;

/// A FIFO channel (`hls::stream<T> name` or one element of a stream array).
#[derive(Debug, Clone)]
pub struct Channel {
    /// Human-readable name, e.g. `"x"` or `"data[3]"`.
    pub name: String,
    /// Element width in bits (e.g. 32 for `hls::stream<float>`).
    pub width_bits: u32,
    /// Stream-array group name, if this channel was declared as part of an
    /// array (e.g. `hls::stream<float> data[16]` → group `"data"`).
    /// Grouped optimizers assign one depth per group.
    pub group: Option<String>,
    /// Designer-declared depth, if any (used as the Baseline-Max depth and
    /// as the default upper bound; when absent the upper bound defaults to
    /// the observed write count, per §III of the paper).
    pub depth_hint: Option<u32>,
}

/// A dataflow process (an HLS function inside the dataflow region).
#[derive(Debug, Clone)]
pub struct Process {
    pub name: String,
    /// VM program body, executed once from the top when the kernel starts.
    pub body: Vec<Instr>,
    /// Number of VM variable slots the body uses.
    pub num_vars: usize,
}

/// A complete dataflow design.
#[derive(Debug, Clone)]
pub struct Design {
    pub name: String,
    pub channels: Vec<Channel>,
    pub processes: Vec<Process>,
    /// Number of runtime kernel arguments ([`Expr::Arg`] slots) the design
    /// expects — the source of data-dependent control flow.
    pub num_args: usize,
}

impl Design {
    /// Channel ids belonging to each group, in first-appearance order.
    /// Ungrouped channels each form their own singleton group.
    pub fn groups(&self) -> Vec<Vec<ChannelId>> {
        let mut order: Vec<String> = Vec::new();
        let mut map: std::collections::HashMap<String, Vec<ChannelId>> =
            std::collections::HashMap::new();
        let mut out = Vec::new();
        for (id, ch) in self.channels.iter().enumerate() {
            match &ch.group {
                Some(g) => {
                    if !map.contains_key(g) {
                        order.push(g.clone());
                    }
                    map.entry(g.clone()).or_default().push(id);
                }
                None => out.push((id, vec![id])),
            }
        }
        let mut grouped: Vec<(ChannelId, Vec<ChannelId>)> = order
            .into_iter()
            .map(|g| {
                let ids = map.remove(&g).unwrap();
                (ids[0], ids)
            })
            .collect();
        grouped.extend(out);
        grouped.sort_by_key(|(first, _)| *first);
        grouped.into_iter().map(|(_, ids)| ids).collect()
    }

    /// Total number of FIFO channels (the paper's per-design "FIFOs" count).
    pub fn num_fifos(&self) -> usize {
        self.channels.len()
    }
}

/// A VM instruction.
///
/// Delays model the compute cycles an HLS schedule inserts between FIFO
/// operations; consecutive FIFO operations are additionally spaced at
/// II = 1 by the simulator.
#[derive(Debug, Clone)]
pub enum Instr {
    /// `var = expr`
    Set(VarId, Expr),
    /// Advance local time by `expr` cycles (clamped at 0).
    Delay(Expr),
    /// Blocking write of `expr` to a channel.
    Write(ChannelId, Expr),
    /// Blocking read from a channel into `var`.
    Read(ChannelId, VarId),
    /// `for var in start .. start+count { body }` — `count` may be
    /// data-dependent (evaluated when the loop is entered).
    For {
        var: VarId,
        start: Expr,
        count: Expr,
        body: Vec<Instr>,
    },
    /// `if cond != 0 { then_body } else { else_body }`
    If {
        cond: Expr,
        then_body: Vec<Instr>,
        else_body: Vec<Instr>,
    },
}

impl Instr {
    /// Count FIFO operations statically reachable (for sizing estimates in
    /// diagnostics; loops count their body once).
    pub fn static_fifo_ops(instrs: &[Instr]) -> usize {
        instrs
            .iter()
            .map(|i| match i {
                Instr::Write(..) | Instr::Read(..) => 1,
                Instr::For { body, .. } => Self::static_fifo_ops(body),
                Instr::If {
                    then_body,
                    else_body,
                    ..
                } => Self::static_fifo_ops(then_body) + Self::static_fifo_ops(else_body),
                _ => 0,
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_design() -> Design {
        let mut b = DesignBuilder::new("mini", 1);
        let x = b.channel("x", 32);
        let arr = b.channel_array("d", 3, 16);
        b.process("p", |p| {
            p.write(x, Expr::c(1));
            for &c in &arr {
                p.write(c, Expr::c(2));
            }
        });
        b.process("q", |p| {
            let v = p.read(x);
            let _ = v;
            for &c in &arr {
                let w = p.read(c);
                let _ = w;
            }
        });
        b.build()
    }

    #[test]
    fn groups_cluster_arrays() {
        let d = mini_design();
        assert_eq!(d.num_fifos(), 4);
        let groups = d.groups();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0], vec![0]); // x alone
        assert_eq!(groups[1], vec![1, 2, 3]); // d[0..3]
        assert_eq!(d.channels[1].group.as_deref(), Some("d"));
        assert_eq!(d.channels[1].name, "d[0]");
    }

    #[test]
    fn static_fifo_op_count() {
        let d = mini_design();
        assert_eq!(Instr::static_fifo_ops(&d.processes[0].body), 4);
        assert_eq!(Instr::static_fifo_ops(&d.processes[1].body), 4);
    }
}
