//! # FIFOAdvisor
//!
//! A design-space-exploration (DSE) framework for automated FIFO sizing of
//! high-level-synthesis (HLS) dataflow designs — a full reproduction of
//! *FIFOAdvisor: A DSE Framework for Automated FIFO Sizing of High-Level
//! Synthesis Designs* (CS.AR 2025).
//!
//! The library is organized bottom-up:
//!
//! - [`ir`] — the dataflow design intermediate representation: processes
//!   (tasks) written in a small imperative VM language, connected by FIFO
//!   channels. This stands in for Vitis-HLS C++ designs.
//! - [`trace`] — "software execution" of a design: runs the VM once
//!   (Kahn-process-network semantics, so results are independent of FIFO
//!   sizes) and records the *execution trace* — the per-process sequence of
//!   FIFO operations with inter-operation delays. This is the LightningSim
//!   phase-1 analog. [`trace::workload`] groups traces of the same design
//!   under different kernel arguments into a validated, weighted
//!   [`Workload`](trace::workload::Workload) — the unit of scenario-robust
//!   sizing (with JSON serde for scenario sets).
//! - [`sim`] — latency evaluation of a trace under any FIFO depth
//!   assignment, behind the [`SimBackend`](sim::SimBackend) trait: the
//!   event-driven fast simulator ([`sim::fast`], the LightningSim
//!   phase-2 analog, µs–ms per configuration, with delta-incremental
//!   replay of the retained schedule), the graph-compiled simulator
//!   ([`sim::compiled`], the LightningSimV2 analog: the trace is lowered
//!   once into a static event graph — program-order, read-after-write
//!   and depth-parameterized full-FIFO edges — and each configuration is
//!   a longest-path propagation with depth-edge-only invalidation), and
//!   the lane-batched simulator ([`sim::batched`]: the same event graph
//!   in SoA layout — K depth vectors evaluated as K contiguous lanes
//!   per node in a single Kahn walk, with per-lane deadlock detection;
//!   select per run with `--backend {fast,compiled,batched}`), the
//!   multi-trace scenario bank ([`sim::scenario`]: one retained-schedule
//!   backend per workload scenario, worst-case/weighted aggregation,
//!   max-merged channel stats, lane-batched
//!   [`eval_batch`](sim::ScenarioSim::eval_batch)), the golden
//!   cycle-stepped reference ([`sim::golden`],
//!   the C/RTL co-simulation analog, now exercised on every shipped
//!   design family), and the co-simulation runtime cost model
//!   ([`sim::cosim`]). The unified conformance harness
//!   (`tests/backend_conformance.rs`) pins every backend bit-identical
//!   to the others (per lane, for the batched core) and latency-exact
//!   against golden.
//! - [`bram`] — the BRAM18K allocation model (paper Algorithm 1), the
//!   shift-register threshold, and the depth-breakpoint pruning of §III-C.
//! - [`opt`] — the optimizers of §III-D (random, grouped random, simulated
//!   annealing, grouped SA, greedy) plus baselines, Pareto extraction and
//!   the α/β scoring. All optimizers speak the batch-first **ask/tell**
//!   protocol ([`opt::Optimizer`]): `ask` proposes a batch, the engine
//!   evaluates it, `tell` hands the outcomes back. [`opt::dominance`]
//!   hosts the simulation-free pruning layer: the monotone
//!   [`FeasibilityOracle`](opt::dominance::FeasibilityOracle) (bounded
//!   dominance antichains over known deadlocks / known-feasible configs)
//!   and the occupancy-clamp
//!   [`Canonicalizer`](opt::dominance::Canonicalizer). [`opt::bounds`]
//!   is the analytic search-space collapse pass: per-channel deadlock
//!   floors and tightened clamp caps proved once per workload from the
//!   compiled event graph ([`DepthBounds`](opt::bounds::DepthBounds)),
//!   shrinking [`opt::Space`], pre-seeding the oracle and
//!   the clamp, short-circuiting sub-floor proposals in the engine
//!   (`--no-bounds` toggles the engine side for A/B runs), and giving
//!   greedy/the hunter their analytic starting points. [`opt::genome`]
//!   maps a design's finite kernel-argument space
//!   ([`ArgSpace`](opt::genome::ArgSpace)) onto the same genome the
//!   depth optimizers search, so the adversarial hunts of
//!   [`dse::advhunt`] reuse them unchanged.
//! - [`dse`] — the DSE engine layer: [`dse::EvalEngine`] owns the
//!   black-box evaluation `x → (f_lat, f_bram)` over a workload — a
//!   persistent worker pool (threads spawned once, each with a cloned
//!   per-scenario [`ScenarioSim`](sim::ScenarioSim) bank), a sharded memo
//!   cache keyed by *clamp-canonical* depth vector, the dominance-oracle
//!   pre-filter (proposals dominated by a known deadlock are answered
//!   without simulating; `--no-prune` disables), scenario early exit on
//!   the latency-only path, in-batch dedup, batched BRAM backend
//!   calls, lane-packed whole-batch dispatch under `--backend batched`
//!   (one `eval_batch` graph walk per scenario replaces the worker
//!   pool), and engine statistics (including per-scenario sim counts,
//!   oracle/clamp hit rates, lane-batching telemetry, and the
//!   robustness gap) — while
//!   [`dse::drive`] is the single loop that runs any optimizer against
//!   it with centralized budget/history accounting (`--jobs N` on the
//!   CLI sizes the pool). [`dse::cancel`] adds cooperative cancellation
//!   ([`CancelToken`](dse::CancelToken): explicit cancel, wall-clock
//!   deadline, simulation budget — checked by `drive` per ask/tell
//!   round, keeping the best-so-far front flagged truncated), and
//!   [`dse::sweep`] is the fault-tolerant experiment-grid orchestrator:
//!   work-stealing cell runner with atomic checkpointing into a
//!   resumable `manifest.json`, deterministic `--shard i/n`
//!   partitioning, per-cell retry with backoff, and per-cell panic
//!   isolation. [`dse::advhunt`] inverts the machinery into an
//!   adversarial outer loop: scenario [`hunt`](dse::hunt)s over a
//!   design's finite kernel-argument space reuse the ask/tell
//!   optimizers with *args-as-genome* ([`opt::genome`]), robustness
//!   [`Certificate`](dse::Certificate)s report a concrete breaking arg
//!   vector or a bounded-exhaustiveness clean verdict for an optimized
//!   config, and scenario-bank distillation
//!   ([`optimize_distilled`](dse::optimize_distilled)) runs the inner
//!   DSE on the dominance-distilled bank with a full-bank re-verify
//!   fixpoint — bit-identical results, strictly fewer scenario
//!   simulations.
//! - [`store`] — the cross-run snapshot store: versioned, checksummed
//!   on-disk snapshots of an engine's memo shards, feasibility-oracle
//!   antichains and analytic-bounds fingerprint, keyed by (design,
//!   workload hash, backend, pruning regime) and written through
//!   [`util::atomic_write`] with size-bounded LRU eviction. A
//!   warm-started run is bit-identical to a cold one; the second
//!   identical optimize replays with zero simulations (`--cache-dir` on
//!   the CLI, shared with [`serve`]).
//! - [`serve`] — the persistent sizing service (`fifoadvisor serve`):
//!   a std-only newline-delimited-JSON server (TCP, plus a unix socket
//!   on unix) keeping hot [`EvalEngine`](dse::EvalEngine)s resident on
//!   per-key actor threads, with per-request
//!   [`CancelToken`](dse::CancelToken) budgets and [`store`]-backed
//!   warm starts that survive restarts.
//! - [`runtime`] — the batched-analytics runtime: a native interpreter
//!   of the AOT-exported JAX/Pallas analytics computation (BRAM totals,
//!   β-grid objectives, dominance mask), shape-bucketed like the
//!   `artifacts/` convention (Python is never on the request path).
//! - [`bench_suite`] — generators for the paper's 24 evaluation designs
//!   (Stream-HLS-like kernels, the Fig. 2 example, FlowGNN-PNA).
//! - [`report`] — CSV/JSON emitters and ASCII plots for benches.
//! - [`cli`] — the command-line front end.
//! - [`util`] — PRNG, statistics, JSON, crash-safe atomic file writes
//!   ([`util::atomic_write`]: temp + fsync + rename, the primitive every
//!   artifact writer routes through), and a mini property-test driver
//!   plus the shared fuzz-generator set ([`util::prop`]) every
//!   randomized suite draws from (the offline crate mirror lacks
//!   rand/serde/proptest).

pub mod bench_suite;
pub mod bram;
pub mod cli;
pub mod dse;
pub mod ir;
pub mod opt;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod store;
pub mod trace;
pub mod util;


pub use ir::{Design, DesignBuilder};
pub use sim::batched::BatchedSim;
pub use sim::compiled::CompiledSim;
pub use sim::fast::{FastSim, SimOutcome};
pub use sim::scenario::ScenarioSim;
pub use sim::{BackendKind, SimBackend};
pub use trace::workload::Workload;
pub use trace::Trace;
