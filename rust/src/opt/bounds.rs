//! Analytic per-channel depth bounds — the simulation-free search-space
//! collapse pass.
//!
//! The DSE loop treats FIFO sizing as black-box optimization because
//! simulation is the only *complete* analysis for data-dependent designs
//! — but the compiled event graph ([`sim::compiled`]) makes two partial
//! analyses cheap and exact on the recorded trace:
//!
//! 1. **Deadlock floors.** A full-FIFO back-edge (write `w` waits on
//!    read `w − d`) closes a cycle whenever some write `w ≥ j + d` is
//!    already an *ancestor* of read `j` in the unconstrained DAG — the
//!    write-lead over read commits along program order. The largest such
//!    lead, `max_j (W_anc(j) − j)`, is a per-channel depth floor: every
//!    configuration below it deadlocks **regardless of every other
//!    channel's depth**, so the engine can answer it without simulating
//!    and the optimizers never need to sample there.
//! 2. **Tightened caps.** Above the PR 4 write-count cap the channel's
//!    constraint set is *empty*; the analytic cap shows where it becomes
//!    *implied* instead: once every potentially-binding full-FIFO edge is
//!    subsumed by a ≥ 2-edge DAG path (each edge costs ≥ 1 cycle, which
//!    covers the BRAM-class weight-2 read edge), the fixpoint cannot
//!    move, for any sibling depths and either read-latency class. The
//!    final cap is `min(write_cap, max(analytic_cap, 2))` — never wider
//!    than PR 4's, so the SRL/BRAM-class clamp soundness argument carries
//!    over unchanged.
//!
//! Both bounds are computed once per trace by
//! [`EventGraph::analytic_depth_bounds`] and max-merged over a workload's
//! scenarios (a deadlock in *any* scenario makes the workload
//! infeasible; the cap must pin the schedule in *every* scenario — the
//! same merge rule as the write-count caps and
//! [`Workload::upper_bounds`]). They feed [`opt::Space`](super::Space)
//! (shrunk per-dimension candidate ranges), the
//! [`EvalEngine`](crate::dse::EvalEngine) (floor short-circuit, oracle
//! seeding, tightened clamp caps) and the `greedy`/`vitis_hunter`
//! starting points.
//!
//! [`sim::compiled`]: crate::sim::compiled
//! [`EventGraph::analytic_depth_bounds`]: crate::sim::compiled::EventGraph
//! [`Workload::upper_bounds`]: crate::trace::workload::Workload::upper_bounds

use super::dominance;
use crate::sim::compiled::EventGraph;
use crate::trace::workload::Workload;
use crate::trace::{ChanOpIndex, Trace};

/// Where a reported bound comes from (for `fifoadvisor info`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BoundSource {
    /// Derived from the event graph (floor > 1, or cap < write count).
    Analytic,
    /// The trivial bound: floor 1 / the PR 4 write-count cap.
    WriteCount,
}

/// Per-channel analytic depth bounds for one trace or a whole workload.
#[derive(Debug, Clone)]
pub struct DepthBounds {
    /// Deadlock floors: `depth[c] < floors[c]` ⇒ deadlock, for any other
    /// depths (0 on never-written channels — nothing to prove there).
    pub floors: Vec<u32>,
    /// Clamp caps: `min(write_cap, max(analytic_cap, 2))`, schedule-
    /// invariant above within a read-latency class. Always ≥ `floors`.
    pub caps: Vec<u32>,
    /// The PR 4 write-count caps the analytic caps tightened from.
    write_caps: Vec<u32>,
}

impl DepthBounds {
    fn combine(analytic: (Vec<u32>, Vec<u32>), write_caps: Vec<u32>) -> DepthBounds {
        let (floors, acaps) = analytic;
        let caps: Vec<u32> = acaps
            .iter()
            .zip(&write_caps)
            .map(|(&a, &w)| w.min(a.max(2)))
            .collect();
        for (ch, (&f, &c)) in floors.iter().zip(&caps).enumerate() {
            debug_assert!(f <= c, "channel {ch}: floor {f} above cap {c}");
        }
        DepthBounds {
            floors,
            caps,
            write_caps,
        }
    }

    /// Bounds for a single trace.
    pub fn for_trace(trace: &Trace) -> DepthBounds {
        let index = ChanOpIndex::build(trace);
        let g = EventGraph::compile(trace, &index);
        Self::combine(g.analytic_depth_bounds(), dominance::trace_caps(trace))
    }

    /// Max-merged bounds over every scenario of a workload.
    pub fn for_workload(workload: &Workload) -> DepthBounds {
        let mut floors = vec![0u32; workload.num_fifos()];
        let mut caps = vec![0u32; workload.num_fifos()];
        for s in workload.scenarios() {
            let b = Self::for_trace(&s.trace);
            for ch in 0..floors.len() {
                floors[ch] = floors[ch].max(b.floors[ch]);
                caps[ch] = caps[ch].max(b.caps[ch]);
            }
        }
        DepthBounds {
            floors,
            caps,
            write_caps: dominance::write_caps(workload),
        }
    }

    /// Number of channels.
    pub fn num_fifos(&self) -> usize {
        self.floors.len()
    }

    /// The untightened PR 4 write-count caps.
    pub fn write_caps(&self) -> &[u32] {
        &self.write_caps
    }

    /// Source of a channel's lower bound.
    pub fn floor_source(&self, ch: usize) -> BoundSource {
        if self.floors[ch] > 1 {
            BoundSource::Analytic
        } else {
            BoundSource::WriteCount
        }
    }

    /// Source of a channel's upper cap.
    pub fn cap_source(&self, ch: usize) -> BoundSource {
        if self.caps[ch] < self.write_caps[ch] {
            BoundSource::Analytic
        } else {
            BoundSource::WriteCount
        }
    }

    /// Channels whose cap the analysis tightened below the write count.
    pub fn num_cap_tightenings(&self) -> usize {
        (0..self.num_fifos())
            .filter(|&ch| self.cap_source(ch) == BoundSource::Analytic)
            .count()
    }

    /// Channels with a non-trivial deadlock floor (> the search minimum
    /// of 2 — the ones the engine's short-circuit and the oracle seeds
    /// can actually exploit).
    pub fn num_floored(&self) -> usize {
        self.floors.iter().filter(|&&f| f > 2).count()
    }

    /// Does this configuration sit below some channel's deadlock floor
    /// (⇒ certainly infeasible, no simulation needed)?
    pub fn below_floor(&self, depths: &[u32]) -> bool {
        debug_assert_eq!(depths.len(), self.floors.len());
        depths.iter().zip(&self.floors).any(|(&d, &f)| d < f)
    }

    /// Machine-stable hash over floors, caps and write caps. The store
    /// embeds it in every snapshot: a persisted memo/oracle is reused
    /// only when the *freshly recomputed* bounds of the same workload
    /// agree, so a snapshot from a stale analysis (or a garbled one that
    /// still parsed) falls back to a cold start instead of mixing bound
    /// regimes.
    pub fn fingerprint(&self) -> u64 {
        let mut s = String::new();
        for (&f, (&c, &w)) in self
            .floors
            .iter()
            .zip(self.caps.iter().zip(&self.write_caps))
        {
            s.push_str(&format!("{f},{c},{w};"));
        }
        crate::util::fnv1a(s.as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::sim::fast::FastSim;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    fn fig2_trace(n: i64) -> Trace {
        let bd = bench_suite::build("fig2");
        collect_trace(&bd.design, &[n]).unwrap()
    }

    #[test]
    fn fig2_floor_matches_paper_threshold() {
        let b = DepthBounds::for_trace(&fig2_trace(16));
        assert_eq!(b.floors, vec![15, 1]);
        assert_eq!(b.caps, vec![16, 16]);
        assert_eq!(b.floor_source(0), BoundSource::Analytic);
        assert_eq!(b.floor_source(1), BoundSource::WriteCount);
        // Feed-forward producer: no cap tightens below the write count.
        assert_eq!(b.cap_source(0), BoundSource::WriteCount);
        assert_eq!(b.num_cap_tightenings(), 0);
        assert_eq!(b.num_floored(), 1);
        assert!(b.below_floor(&[14, 16]));
        assert!(!b.below_floor(&[15, 2]));
    }

    #[test]
    fn workload_merge_takes_worst_scenario() {
        let bd = bench_suite::build("fig2");
        let w = Workload::from_design_args(&bd.design, &[vec![8], vec![16]]).unwrap();
        let b = DepthBounds::for_workload(&w);
        // n16 dominates the x floor; caps merge to the larger write count.
        assert_eq!(b.floors, vec![15, 1]);
        assert_eq!(b.caps, vec![16, 16]);
    }

    #[test]
    fn flowgnn_msg_floors_equal_burst_sizes() {
        // The gather lanes read `deg` before draining `msg`, and `deg` is
        // written only after the full edge scan — so each msg FIFO's
        // analytic floor is exactly its data-dependent burst size
        // (the threshold flowgnn's own tests establish by simulation).
        let bd = bench_suite::build("flowgnn_pna");
        let t = collect_trace(&bd.design, &bd.args).unwrap();
        let b = DepthBounds::for_trace(&t);
        for lane in 0..crate::bench_suite::flowgnn::LANES {
            assert_eq!(
                b.floors[lane] as u64, t.channels[lane].writes,
                "lane {lane} floor must equal its burst"
            );
        }
        assert!(b.num_floored() > 0);
    }

    #[test]
    fn floors_are_sound_across_the_suite() {
        // For every shipped design: one-below-floor with every other
        // channel fully relaxed must deadlock (the floor's defining
        // property), checked against the event-driven simulator.
        for name in bench_suite::all_names() {
            let bd = bench_suite::build(name);
            let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
            let b = DepthBounds::for_trace(&t);
            let relaxed: Vec<u32> = t
                .channels
                .iter()
                .map(|c| (c.writes.max(2).min(u32::MAX as u64)) as u32)
                .collect();
            let mut s = FastSim::new(t.clone());
            for (ch, &f) in b.floors.iter().enumerate() {
                assert!(f <= b.caps[ch], "{name} ch {ch}: floor above cap");
                if f > 2 {
                    let mut cfg = relaxed.clone();
                    cfg[ch] = f - 1;
                    assert!(
                        s.simulate(&cfg).is_deadlock(),
                        "{name} ch {ch}: below floor {f} must deadlock"
                    );
                }
            }
        }
    }
}
