//! Simulation-free proposal pruning: the monotone **feasibility oracle**
//! and the **occupancy-clamp canonicalizer**.
//!
//! Both exploit structural facts about the commit-time constraint system
//! (see `sim`'s module docs) that let the DSE engine answer many
//! optimizer proposals without running a simulation at all:
//!
//! 1. **Deadlock is monotone in FIFO depths.** Whether a process ever
//!    blocks is decided purely by *op counts*: a write as ordinal `j` on
//!    a channel of depth `d` needs read `j − d` committed, a read needs
//!    its write committed — commit *times* (and hence the SRL/BRAM read
//!    latency) never gate progress. Shrinking any depth only raises the
//!    read ordinal a write waits on, so the committed-prefix fixpoint
//!    shrinks monotonically: if `y` deadlocks, every `x ≤ y`
//!    (component-wise) deadlocks too, and if `y` is feasible, every
//!    `x ≥ y` is feasible. The [`FeasibilityOracle`] maintains two
//!    bounded Pareto antichains — maximal known-deadlock configurations
//!    and minimal known-feasible ones — and answers dominance queries in
//!    O(entries × channels).
//!
//! 2. **The schedule is invariant above the write count.** The full-FIFO
//!    constraint on write ordinal `j` exists only when `j ≥ depth`, so
//!    any depth at or above the channel's total write count makes the
//!    channel's constraint set *empty* — the least-fixpoint schedule
//!    (latency, per-scenario latencies, blocked sets, statistics) is
//!    identical for every such depth, channel by channel and regardless
//!    of the other channels' depths, **provided the SRL↔BRAM read-latency
//!    class does not change**. The [`Canonicalizer`] clamps each depth
//!    above its per-channel write-count cap down to the smallest
//!    class-preserving representative, collapsing the entire region above
//!    the cap onto one memo entry per read-latency class. (BRAM cost is
//!    *not* invariant — the engine always computes it from the actual
//!    depths.)
//!
//! For multi-scenario workloads the cap is the max write count over
//! scenarios, so the clamped depth stays constraint-free in *every*
//! scenario. The oracle works in "deadlock space" — depths clamped to the
//! caps with no class caveat, since deadlock ignores read latency — which
//! makes each learned deadlock dominate the whole region above the caps.
//!
//! The latency recorded on feasible entries is an upper bound for
//! dominating configurations **only under uniform read latency**
//! ([`crate::sim::SimOptions::uniform_read_latency`]); with the SRL/BRAM
//! distinction enabled a deeper FIFO can be one cycle slower (paper
//! footnote 2), so the engine treats it as advisory metadata.

use crate::bram::SRL_THRESHOLD_BITS;
use crate::trace::workload::Workload;
use crate::trace::Trace;

/// Entries kept per antichain before the eviction policy engages.
pub const DEFAULT_ORACLE_CAPACITY: usize = 256;

/// `a ≤ b` component-wise.
#[inline]
fn dominated_by(a: &[u32], b: &[u32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x <= y)
}

// ---------------------------------------------------------------------------
// Occupancy-clamp canonicalization
// ---------------------------------------------------------------------------

/// Clamps depths above the per-channel write-count cap onto a canonical
/// class-preserving representative (fact 2 above). Construct once per
/// trace/workload; `canonical` is allocation-free when nothing clamps.
#[derive(Debug, Clone)]
pub struct Canonicalizer {
    /// Per-channel occupancy cap: the max write count over scenarios
    /// (floored at 2). Depths ≥ the cap are schedule-equivalent within a
    /// read-latency class.
    caps: Box<[u32]>,
    /// Per-channel largest SRL-mapped depth (`max(2, ⌊1024 / width⌋)`);
    /// `srl_max + 1` is the smallest BRAM-class depth.
    srl_max: Box<[u32]>,
}

/// One channel's clamp cap from its observed write count (floored at 2,
/// saturated at `u32::MAX`). The **single** definition both the
/// canonicalizer and the oracle use — they must agree byte-for-byte, or
/// a raw proposal and its canonical point could classify differently.
#[inline]
pub(crate) fn write_cap(writes: u64) -> u32 {
    (writes.min(u32::MAX as u64) as u32).max(2)
}

/// Per-channel clamp caps from one trace's write counts.
pub(crate) fn trace_caps(trace: &Trace) -> Vec<u32> {
    trace.channels.iter().map(|c| write_cap(c.writes)).collect()
}

/// Merged (max-over-scenarios) per-channel clamp caps for a workload.
pub(crate) fn write_caps(workload: &Workload) -> Vec<u32> {
    let mut caps = vec![2u32; workload.num_fifos()];
    for s in workload.scenarios() {
        for (cap, ch) in caps.iter_mut().zip(&s.trace.channels) {
            *cap = (*cap).max(write_cap(ch.writes));
        }
    }
    caps
}

impl Canonicalizer {
    /// Build from explicit caps and channel widths.
    pub fn new(caps: Vec<u32>, widths: &[u32]) -> Canonicalizer {
        assert_eq!(caps.len(), widths.len());
        let srl_max = widths
            .iter()
            .map(|&w| ((SRL_THRESHOLD_BITS / w.max(1) as u64).min(u32::MAX as u64) as u32).max(2))
            .collect();
        Canonicalizer {
            caps: caps.into(),
            srl_max,
        }
    }

    /// Caps from one trace's observed write counts.
    pub fn for_trace(trace: &Trace) -> Canonicalizer {
        let widths: Vec<u32> = trace.channels.iter().map(|c| c.width_bits).collect();
        Canonicalizer::new(trace_caps(trace), &widths)
    }

    /// Caps from a workload's merged (max-over-scenarios) write counts.
    pub fn for_workload(workload: &Workload) -> Canonicalizer {
        let widths: Vec<u32> = workload
            .primary()
            .channels
            .iter()
            .map(|c| c.width_bits)
            .collect();
        Canonicalizer::new(write_caps(workload), &widths)
    }

    /// The per-channel clamp caps.
    pub fn caps(&self) -> &[u32] {
        &self.caps
    }

    /// Canonical representative of one channel's depth: depths at or
    /// below the cap are their own representative; above it, the SRL
    /// class collapses to the cap and the BRAM class to
    /// `max(cap, srl_max + 1)` (the shallowest depth of the same class
    /// that is still ≥ the cap).
    #[inline]
    pub fn canonical_depth(&self, ch: usize, d: u32) -> u32 {
        let cap = self.caps[ch];
        if d <= cap {
            return d;
        }
        let srl_max = self.srl_max[ch];
        if d <= srl_max {
            cap
        } else {
            cap.max(srl_max + 1)
        }
    }

    /// Canonicalize a full configuration. Returns `None` when the
    /// configuration is already canonical (the common case — no
    /// allocation).
    pub fn canonical(&self, depths: &[u32]) -> Option<Box<[u32]>> {
        debug_assert_eq!(depths.len(), self.caps.len());
        let changed = depths
            .iter()
            .enumerate()
            .any(|(ch, &d)| self.canonical_depth(ch, d) != d);
        if !changed {
            return None;
        }
        Some(
            depths
                .iter()
                .enumerate()
                .map(|(ch, &d)| self.canonical_depth(ch, d))
                .collect(),
        )
    }
}

// ---------------------------------------------------------------------------
// Monotone feasibility oracle
// ---------------------------------------------------------------------------

/// Answer of a dominance query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OracleVerdict {
    /// Component-wise ≤ a known deadlock: certainly deadlocks.
    Infeasible,
    /// Component-wise ≥ a known-feasible configuration: certainly
    /// feasible. `latency_bound` is the dominated entry's latency — an
    /// upper bound only under uniform read latency (see module docs).
    Feasible { latency_bound: Option<u64> },
}

#[derive(Debug, Clone)]
struct Entry {
    cfg: Box<[u32]>,
    /// Aggregate latency of the learned run (`None` on the infeasible
    /// antichain).
    latency: Option<u64>,
    hits: u64,
    stamp: u64,
}

/// Two bounded Pareto antichains over "deadlock space" (depths clamped to
/// the write-count caps): maximal known-infeasible configurations and
/// minimal known-feasible ones. Learns from every engine result; answers
/// dominance queries in O(entries × channels). Eviction removes the entry
/// with the fewest hits (oldest stamp on ties), so the antichains stay
/// bounded and deterministic.
#[derive(Debug, Clone)]
pub struct FeasibilityOracle {
    caps: Box<[u32]>,
    capacity: usize,
    infeasible: Vec<Entry>,
    feasible: Vec<Entry>,
    clock: u64,
    queries: u64,
    infeasible_hits: u64,
    feasible_hits: u64,
    scratch: Vec<u32>,
}

impl FeasibilityOracle {
    /// Oracle over the given per-channel caps with the default antichain
    /// capacity.
    pub fn new(caps: Vec<u32>) -> FeasibilityOracle {
        Self::with_capacity(caps, DEFAULT_ORACLE_CAPACITY)
    }

    /// Oracle with an explicit per-antichain entry cap.
    pub fn with_capacity(caps: Vec<u32>, capacity: usize) -> FeasibilityOracle {
        let n = caps.len();
        FeasibilityOracle {
            caps: caps.into(),
            capacity: capacity.max(1),
            infeasible: Vec::new(),
            feasible: Vec::new(),
            clock: 0,
            queries: 0,
            infeasible_hits: 0,
            feasible_hits: 0,
            scratch: vec![0; n],
        }
    }

    /// Caps from a workload's merged write counts.
    pub fn for_workload(workload: &Workload) -> FeasibilityOracle {
        Self::new(write_caps(workload))
    }

    /// Caps from one trace's write counts.
    pub fn for_trace(trace: &Trace) -> FeasibilityOracle {
        Self::new(trace_caps(trace))
    }

    fn clamp_into_scratch(&mut self, depths: &[u32]) {
        debug_assert_eq!(depths.len(), self.caps.len());
        self.scratch.clear();
        self.scratch
            .extend(depths.iter().zip(self.caps.iter()).map(|(&d, &c)| d.min(c)));
    }

    /// Hot-path query: is this configuration component-wise ≤ a known
    /// deadlock? Scans only the infeasible antichain — the engine
    /// consumes only `Infeasible` verdicts, so it skips the
    /// feasible-side scan entirely.
    pub fn is_dominated_infeasible(&mut self, depths: &[u32]) -> bool {
        self.clamp_into_scratch(depths);
        self.queries += 1;
        self.clock += 1;
        self.scan_infeasible()
    }

    /// Dominance query: `Some(verdict)` when the configuration's
    /// feasibility is already decided by a learned entry, `None` when a
    /// simulation is needed.
    pub fn classify(&mut self, depths: &[u32]) -> Option<OracleVerdict> {
        self.clamp_into_scratch(depths);
        self.queries += 1;
        self.clock += 1;
        if self.scan_infeasible() {
            return Some(OracleVerdict::Infeasible);
        }
        let clock = self.clock;
        let mut bound: Option<Option<u64>> = None;
        for e in self.feasible.iter_mut() {
            if dominated_by(&e.cfg, &self.scratch) {
                e.hits += 1;
                e.stamp = clock;
                let b = bound.get_or_insert(e.latency);
                *b = match (*b, e.latency) {
                    (Some(a), Some(c)) => Some(a.min(c)),
                    (a, c) => a.or(c),
                };
            }
        }
        if let Some(latency_bound) = bound {
            self.feasible_hits += 1;
            return Some(OracleVerdict::Feasible { latency_bound });
        }
        None
    }

    /// Scan the infeasible antichain against the clamped scratch config,
    /// bumping hit bookkeeping on a match.
    fn scan_infeasible(&mut self) -> bool {
        let clock = self.clock;
        for e in self.infeasible.iter_mut() {
            if dominated_by(&self.scratch, &e.cfg) {
                e.hits += 1;
                e.stamp = clock;
                self.infeasible_hits += 1;
                return true;
            }
        }
        false
    }

    /// Learn one engine result (`latency == None` means deadlock). The
    /// configuration is clamped to deadlock space before insertion, so a
    /// single learned deadlock covers the whole region above the caps.
    pub fn note(&mut self, depths: &[u32], latency: Option<u64>) {
        self.clamp_into_scratch(depths);
        self.clock += 1;
        let stamp = self.clock;
        if latency.is_none() {
            // Maximal antichain of known deadlocks.
            if self
                .infeasible
                .iter()
                .any(|e| dominated_by(&self.scratch, &e.cfg))
            {
                return; // already covered
            }
            let s = &self.scratch;
            self.infeasible.retain(|e| !dominated_by(&e.cfg, s));
            if self.infeasible.len() >= self.capacity {
                evict(&mut self.infeasible);
            }
            self.infeasible.push(Entry {
                cfg: self.scratch.as_slice().into(),
                latency: None,
                hits: 0,
                stamp,
            });
        } else {
            // Minimal antichain of known-feasible configurations.
            if self
                .feasible
                .iter()
                .any(|e| dominated_by(&e.cfg, &self.scratch))
            {
                return; // already covered
            }
            let s = &self.scratch;
            self.feasible.retain(|e| !dominated_by(s, &e.cfg));
            if self.feasible.len() >= self.capacity {
                evict(&mut self.feasible);
            }
            self.feasible.push(Entry {
                cfg: self.scratch.as_slice().into(),
                latency,
                hits: 0,
                stamp,
            });
        }
    }

    /// Drop all learned entries (cold-start measurement).
    pub fn clear(&mut self) {
        self.infeasible.clear();
        self.feasible.clear();
        self.queries = 0;
        self.infeasible_hits = 0;
        self.feasible_hits = 0;
    }

    /// Entries on the known-deadlock antichain.
    pub fn num_infeasible(&self) -> usize {
        self.infeasible.len()
    }

    /// Entries on the known-feasible antichain.
    pub fn num_feasible(&self) -> usize {
        self.feasible.len()
    }

    /// Queries answered `Infeasible` since construction/`clear`.
    pub fn infeasible_hits(&self) -> u64 {
        self.infeasible_hits
    }

    /// Queries answered `Feasible` since construction/`clear`.
    pub fn feasible_hits(&self) -> u64 {
        self.feasible_hits
    }

    /// Total dominance queries since construction/`clear`.
    pub fn queries(&self) -> u64 {
        self.queries
    }

    /// The per-channel deadlock-space caps this oracle clamps against.
    pub fn caps(&self) -> &[u32] {
        &self.caps
    }

    /// Export both antichains for persistence: `(config, latency)` pairs
    /// — the known-deadlock side first (`latency == None`), then the
    /// known-feasible side — each side sorted by config so snapshots are
    /// deterministic. Hit/stamp bookkeeping is deliberately dropped: it
    /// orders *eviction*, never verdicts, and replaying the entries
    /// through [`note`](Self::note) rebuilds valid antichains. Reusing a
    /// learned antichain across runs is sound for the same reason the
    /// oracle is sound within a run: deadlock is monotone in depths and
    /// depends only on the trace's op counts, which the store's
    /// trace-hash keying pins.
    pub fn entries(&self) -> Vec<(Vec<u32>, Option<u64>)> {
        fn side(entries: &[Entry]) -> Vec<(Vec<u32>, Option<u64>)> {
            let mut out: Vec<(Vec<u32>, Option<u64>)> = entries
                .iter()
                .map(|e| (e.cfg.to_vec(), e.latency))
                .collect();
            out.sort();
            out
        }
        let mut all = side(&self.infeasible);
        all.extend(side(&self.feasible));
        all
    }
}

/// Remove the least useful entry: fewest hits, oldest stamp on ties.
fn evict(entries: &mut Vec<Entry>) {
    if let Some(i) = (0..entries.len()).min_by_key(|&i| (entries[i].hits, entries[i].stamp)) {
        entries.remove(i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bram::is_srl;

    #[test]
    fn canonicalizer_is_class_preserving_and_idempotent() {
        // caps [4, 8], widths [32, 600]: srl_max = [32, 2].
        let c = Canonicalizer::new(vec![4, 8], &[32, 600]);
        // Below/at cap: unchanged.
        assert_eq!(c.canonical(&[3, 8]), None);
        assert_eq!(c.canonical(&[4, 2]), None);
        // Above cap, SRL class (d ≤ 32 on ch 0): collapse to the cap.
        assert_eq!(c.canonical(&[17, 8]).unwrap().as_ref(), &[4, 8]);
        // Above cap, BRAM class: collapse to max(cap, srl_max + 1).
        assert_eq!(c.canonical(&[100, 8]).unwrap().as_ref(), &[33, 8]);
        // Wide channel: cap 8 already BRAM-class, so BRAM depths land on
        // the cap itself.
        assert_eq!(c.canonical(&[4, 20]).unwrap().as_ref(), &[4, 8]);
        for (raw, ch) in [(17u32, 0usize), (100, 0), (33, 0), (20, 1), (9, 1)] {
            let canon = c.canonical_depth(ch, raw);
            let w = [32u32, 600][ch];
            assert_eq!(is_srl(raw, w), is_srl(canon, w), "class flip at {raw}x{w}");
            assert!(canon <= raw);
            assert!(canon >= c.caps()[ch] || canon == raw);
            // Idempotent.
            assert_eq!(c.canonical_depth(ch, canon), canon);
        }
    }

    #[test]
    fn oracle_dominance_both_directions() {
        let mut o = FeasibilityOracle::new(vec![100, 100, 100]);
        assert_eq!(o.classify(&[2, 2, 2]), None);
        o.note(&[8, 4, 16], None); // deadlock
        o.note(&[32, 32, 32], Some(500)); // feasible
        // Dominated by the deadlock.
        assert_eq!(o.classify(&[8, 4, 16]), Some(OracleVerdict::Infeasible));
        assert_eq!(o.classify(&[2, 4, 3]), Some(OracleVerdict::Infeasible));
        // Dominates the feasible entry.
        assert_eq!(
            o.classify(&[32, 40, 32]),
            Some(OracleVerdict::Feasible {
                latency_bound: Some(500)
            })
        );
        // Neither: unknown.
        assert_eq!(o.classify(&[2, 100, 2]), None);
        assert_eq!(o.infeasible_hits(), 2);
        assert_eq!(o.feasible_hits(), 1);
        assert_eq!(o.queries(), 5);
        // The engine's infeasible-only fast query agrees with classify.
        for cfg in [[8u32, 4, 16], [2, 4, 3], [32, 40, 32], [2, 100, 2]] {
            let full = o.classify(&cfg) == Some(OracleVerdict::Infeasible);
            assert_eq!(o.is_dominated_infeasible(&cfg), full, "{cfg:?}");
        }
    }

    #[test]
    fn oracle_clamps_to_deadlock_space() {
        // Caps [4, 4]: everything above 4 is equivalent to 4.
        let mut o = FeasibilityOracle::new(vec![4, 4]);
        o.note(&[1000, 2], None);
        // A huge depth on channel 0 is still dominated after clamping.
        assert_eq!(o.classify(&[7, 2]), Some(OracleVerdict::Infeasible));
        assert_eq!(o.classify(&[4, 2]), Some(OracleVerdict::Infeasible));
        assert_eq!(o.classify(&[4, 3]), None);
        // Feasible side clamps too.
        o.note(&[4, 3], Some(9));
        assert_eq!(
            o.classify(&[900, 3]),
            Some(OracleVerdict::Feasible {
                latency_bound: Some(9)
            })
        );
    }

    #[test]
    fn antichains_stay_maximal_minimal_and_bounded() {
        let mut o = FeasibilityOracle::with_capacity(vec![100; 2], 4);
        // Dominated deadlocks collapse into the maximal entry.
        o.note(&[2, 2], None);
        o.note(&[8, 8], None); // swallows [2,2]
        assert_eq!(o.num_infeasible(), 1);
        o.note(&[3, 3], None); // covered, no-op
        assert_eq!(o.num_infeasible(), 1);
        // Feasible side keeps minimal elements.
        o.note(&[50, 50], Some(10));
        o.note(&[20, 20], Some(20)); // swallows [50,50]
        assert_eq!(o.num_feasible(), 1);
        o.note(&[60, 60], Some(8)); // covered, no-op
        assert_eq!(o.num_feasible(), 1);
        // Capacity: incomparable entries evict deterministically.
        for i in 0..10u32 {
            o.note(&[10 + i, 30 - i], None);
        }
        assert!(o.num_infeasible() <= 4);
        // Everything kept still answers correctly.
        assert_eq!(o.classify(&[2, 2]), Some(OracleVerdict::Infeasible));
    }

    #[test]
    fn entries_export_replays_into_an_equivalent_oracle() {
        let mut o = FeasibilityOracle::new(vec![100, 100]);
        o.note(&[8, 4], None);
        o.note(&[3, 9], None);
        o.note(&[40, 40], Some(77));
        let dump = o.entries();
        assert_eq!(dump.len(), 3);
        // Infeasible side first, each side sorted by config.
        assert_eq!(dump[0], (vec![3, 9], None));
        assert_eq!(dump[1], (vec![8, 4], None));
        assert_eq!(dump[2], (vec![40, 40], Some(77)));
        let mut back = FeasibilityOracle::new(o.caps().to_vec());
        for (cfg, lat) in &dump {
            back.note(cfg, *lat);
        }
        assert_eq!(back.entries(), dump, "replay rebuilds the antichains");
        assert_eq!(back.classify(&[2, 4]), Some(OracleVerdict::Infeasible));
    }

    #[test]
    fn clear_resets_everything() {
        let mut o = FeasibilityOracle::new(vec![10, 10]);
        o.note(&[5, 5], None);
        assert_eq!(o.classify(&[2, 2]), Some(OracleVerdict::Infeasible));
        o.clear();
        assert_eq!(o.num_infeasible(), 0);
        assert_eq!(o.classify(&[2, 2]), None);
        assert_eq!(o.infeasible_hits(), 0);
    }
}
