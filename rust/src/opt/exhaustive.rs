//! Exhaustive enumeration of the pruned space — only tractable for tiny
//! designs; used as the ground-truth front in optimizer-quality tests
//! and the pruning ablation. Under ask/tell the odometer state lives in
//! the optimizer and each `ask` emits the next batch of configurations.

use super::{AskCtx, Optimizer, Space};
use crate::dse::EvalResult;

pub struct Exhaustive {
    /// Safety cap on enumerated configurations.
    pub cap: usize,
    /// Odometer over `space.per_fifo` candidate indices (None = not
    /// started yet).
    idx: Option<Vec<usize>>,
    emitted: usize,
    finished: bool,
}

impl Exhaustive {
    pub fn new() -> Exhaustive {
        Exhaustive {
            cap: 200_000,
            idx: None,
            emitted: 0,
            finished: false,
        }
    }

    /// Exact size of the pruned cartesian space (None on overflow).
    pub fn space_size(space: &Space) -> Option<usize> {
        space
            .per_fifo
            .iter()
            .try_fold(1usize, |acc, c| acc.checked_mul(c.len()))
    }
}

impl Default for Exhaustive {
    fn default() -> Self {
        Self::new()
    }
}

impl Optimizer for Exhaustive {
    fn name(&self) -> &'static str {
        "exhaustive"
    }

    fn ask(&mut self, ctx: &AskCtx) -> Vec<Box<[u32]>> {
        if self.finished {
            return Vec::new();
        }
        let space = ctx.space;
        let n = space.num_fifos();
        let want = ctx
            .budget_left
            .min(self.cap - self.emitted)
            .min(ctx.batch_hint);
        if want == 0 {
            self.finished = true;
            return Vec::new();
        }
        let mut idx = self.idx.take().unwrap_or_else(|| vec![0usize; n]);
        let mut batch: Vec<Box<[u32]>> = Vec::with_capacity(want);
        loop {
            let cfg: Box<[u32]> = idx
                .iter()
                .zip(&space.per_fifo)
                .map(|(&i, c)| c[i])
                .collect();
            batch.push(cfg);
            self.emitted += 1;
            // Odometer increment.
            let mut pos = 0;
            loop {
                if pos == n {
                    self.finished = true;
                    break;
                }
                idx[pos] += 1;
                if idx[pos] < space.per_fifo[pos].len() {
                    break;
                }
                idx[pos] = 0;
                pos += 1;
            }
            if self.finished || batch.len() == want {
                break;
            }
        }
        self.idx = Some(idx);
        batch
    }

    fn tell(&mut self, _results: &[EvalResult]) {}

    fn done(&self) -> bool {
        self.finished
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::dse::{drive, Evaluator};
    use crate::trace::collect_trace;
    use std::sync::Arc;

    #[test]
    fn enumerates_full_space_of_fig2() {
        let bd = bench_suite::build("fig2");
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&t);
        let size = Exhaustive::space_size(&space).unwrap();
        let mut ev = Evaluator::new(t);
        drive(&mut Exhaustive::new(), &mut ev, &space, usize::MAX);
        assert_eq!(ev.n_evals(), size);
        // Every enumerated config is distinct.
        let distinct: std::collections::HashSet<_> =
            ev.history.iter().map(|p| p.depths.clone()).collect();
        assert_eq!(distinct.len(), size);
    }

    #[test]
    fn budget_caps_enumeration() {
        let bd = bench_suite::build("gesummv");
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&t);
        let mut ev = Evaluator::new(t);
        drive(&mut Exhaustive::new(), &mut ev, &space, 50);
        assert_eq!(ev.n_evals(), 50);
    }
}
