//! Exhaustive enumeration of the pruned space — only tractable for tiny
//! designs; used as the ground-truth front in optimizer-quality tests
//! and the pruning ablation.

use super::{Optimizer, Space};
use crate::dse::Evaluator;

pub struct Exhaustive {
    /// Safety cap on enumerated configurations.
    pub cap: usize,
}

impl Exhaustive {
    pub fn new() -> Exhaustive {
        Exhaustive { cap: 200_000 }
    }

    /// Exact size of the pruned cartesian space (None on overflow).
    pub fn space_size(space: &Space) -> Option<usize> {
        space
            .per_fifo
            .iter()
            .try_fold(1usize, |acc, c| acc.checked_mul(c.len()))
    }
}

impl Default for Exhaustive {
    fn default() -> Self {
        Self::new()
    }
}

impl Optimizer for Exhaustive {
    fn name(&self) -> &'static str {
        "exhaustive"
    }

    fn run(&mut self, ev: &mut Evaluator, space: &Space, budget: usize) {
        let limit = budget.min(self.cap);
        let n = space.num_fifos();
        let mut idx = vec![0usize; n];
        let mut batch: Vec<Box<[u32]>> = Vec::with_capacity(64);
        let mut count = 0usize;
        'outer: loop {
            let cfg: Box<[u32]> = idx
                .iter()
                .zip(&space.per_fifo)
                .map(|(&i, c)| c[i])
                .collect();
            batch.push(cfg);
            count += 1;
            if batch.len() == 64 {
                ev.eval_batch(&batch);
                batch.clear();
            }
            if count >= limit {
                break;
            }
            // Odometer increment.
            let mut pos = 0;
            loop {
                if pos == n {
                    break 'outer;
                }
                idx[pos] += 1;
                if idx[pos] < space.per_fifo[pos].len() {
                    break;
                }
                idx[pos] = 0;
                pos += 1;
            }
        }
        if !batch.is_empty() {
            ev.eval_batch(&batch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    #[test]
    fn enumerates_full_space_of_fig2() {
        let bd = bench_suite::build("fig2");
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&t);
        let size = Exhaustive::space_size(&space).unwrap();
        let mut ev = Evaluator::new(t);
        Exhaustive::new().run(&mut ev, &space, usize::MAX);
        assert_eq!(ev.n_evals(), size);
        // Every enumerated config is distinct.
        let distinct: std::collections::HashSet<_> =
            ev.history.iter().map(|p| p.depths.clone()).collect();
        assert_eq!(distinct.len(), size);
    }

    #[test]
    fn budget_caps_enumeration() {
        let bd = bench_suite::build("gesummv");
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&t);
        let mut ev = Evaluator::new(t);
        Exhaustive::new().run(&mut ev, &space, 50);
        assert_eq!(ev.n_evals(), 50);
    }
}
