//! Args-as-genome adapter: reuse the FIFO-depth ask/tell optimizers to
//! search a design's *kernel-argument* space (the adversarial outer loop
//! of [`dse::advhunt`](crate::dse::advhunt)).
//!
//! Every optimizer in this crate proposes depth vectors drawn from a
//! [`Space`]'s per-dimension candidate lists. An [`ArgSpace`] builds a
//! synthetic `Space` whose dimension `i` enumerates the *indices* of the
//! `i`-th argument's allowed values, so any existing optimizer (SA,
//! random, greedy, exhaustive, NSGA-II) can propose argument vectors
//! without knowing it: the hunter decodes each proposal back into
//! concrete `i64` kernel arguments via [`ArgSpace::decode`].
//!
//! Encoding detail: `Space::min_depth` clamps every dimension to
//! `max(2, floor)`, so raw indices 0 and 1 would be unreachable. The
//! genome therefore stores index `k` as candidate value `k + 2`
//! (dimension `i` has candidates `2..len_i + 2`), and `decode`
//! subtracts the offset. All dimensions are singleton "groups" of a
//! nominal 32-bit width — group structure and BRAM cost are meaningless
//! for argument vectors, and the hunter scores candidates itself.

use super::Space;

/// One searchable kernel argument: a name (for reports) and the finite
/// list of values the hunter may try.
#[derive(Debug, Clone)]
pub struct ArgDim {
    /// Human-readable argument name (e.g. `"nodes"`, `"seed"`).
    pub name: String,
    /// Allowed values, in the order they map onto genome indices. Must be
    /// non-empty.
    pub values: Vec<i64>,
}

impl ArgDim {
    /// Convenience constructor.
    pub fn new(name: &str, values: Vec<i64>) -> ArgDim {
        assert!(!values.is_empty(), "argument '{name}' has no values");
        ArgDim {
            name: name.to_string(),
            values,
        }
    }
}

/// The finite kernel-argument space of one design: the cartesian product
/// of its [`ArgDim`]s, in the design's positional argument order.
#[derive(Debug, Clone)]
pub struct ArgSpace {
    /// One dimension per design argument, positionally.
    pub dims: Vec<ArgDim>,
}

/// Offset between a genome candidate value and the argument-value index
/// it encodes (indices 0/1 are unreachable under `Space::min_depth`).
const GENOME_OFFSET: u32 = 2;

impl ArgSpace {
    /// Build from positional dimensions.
    pub fn new(dims: Vec<ArgDim>) -> ArgSpace {
        assert!(!dims.is_empty(), "argument space has no dimensions");
        ArgSpace { dims }
    }

    /// Number of design arguments.
    pub fn num_args(&self) -> usize {
        self.dims.len()
    }

    /// Total number of argument vectors in the space, or `None` on
    /// overflow (used to pick exhaustive search for tiny spaces).
    pub fn num_points(&self) -> Option<usize> {
        self.dims
            .iter()
            .try_fold(1usize, |acc, d| acc.checked_mul(d.values.len()))
    }

    /// The synthetic genome [`Space`] the depth optimizers search.
    /// Dimension `i`'s candidates are `GENOME_OFFSET..len_i +
    /// GENOME_OFFSET` (one per allowed value), singleton groups, nominal
    /// 32-bit widths.
    pub fn genome_space(&self) -> Space {
        let n = self.dims.len();
        let per_fifo: Vec<Vec<u32>> = self
            .dims
            .iter()
            .map(|d| (0..d.values.len() as u32).map(|k| k + GENOME_OFFSET).collect())
            .collect();
        let bounds: Vec<u32> = per_fifo.iter().map(|c| *c.last().unwrap()).collect();
        Space {
            per_fifo: per_fifo.clone(),
            bounds,
            floors: vec![GENOME_OFFSET; n],
            widths: vec![32; n],
            groups: (0..n).map(|i| vec![i]).collect(),
            per_group: per_fifo,
        }
    }

    /// Decode a genome proposal back into a concrete argument vector.
    /// Out-of-range codes clamp to the nearest valid index, so arbitrary
    /// (clamped) optimizer proposals always decode to a real point.
    pub fn decode(&self, proposal: &[u32]) -> Vec<i64> {
        assert_eq!(proposal.len(), self.dims.len());
        self.dims
            .iter()
            .zip(proposal)
            .map(|(d, &code)| {
                let idx = (code.saturating_sub(GENOME_OFFSET) as usize).min(d.values.len() - 1);
                d.values[idx]
            })
            .collect()
    }

    /// Encode a concrete argument vector (each value must appear in its
    /// dimension's list) — used to seed hunts from known scenarios.
    pub fn encode(&self, args: &[i64]) -> Option<Box<[u32]>> {
        assert_eq!(args.len(), self.dims.len());
        self.dims
            .iter()
            .zip(args)
            .map(|(d, a)| {
                d.values
                    .iter()
                    .position(|v| v == a)
                    .map(|k| k as u32 + GENOME_OFFSET)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::exhaustive::Exhaustive;

    fn space2() -> ArgSpace {
        ArgSpace::new(vec![
            ArgDim::new("n", vec![4, 8, 16]),
            ArgDim::new("seed", vec![7]),
        ])
    }

    #[test]
    fn genome_space_round_trips() {
        let a = space2();
        assert_eq!(a.num_points(), Some(3));
        let s = a.genome_space();
        assert_eq!(s.num_fifos(), 2);
        assert_eq!(s.per_fifo[0], vec![2, 3, 4]);
        assert_eq!(s.per_fifo[1], vec![2]);
        assert_eq!(s.min_depth(0), 2);
        // Every candidate decodes to the matching value and re-encodes.
        for (k, &v) in a.dims[0].values.iter().enumerate() {
            let code = k as u32 + 2;
            assert_eq!(a.decode(&[code, 2]), vec![v, 7]);
            assert_eq!(a.encode(&[v, 7]).unwrap().as_ref(), &[code, 2]);
        }
        assert_eq!(a.encode(&[5, 7]), None);
        // Out-of-range codes clamp instead of panicking.
        assert_eq!(a.decode(&[0, 99]), vec![4, 7]);
        let mut wild = vec![99u32, 0];
        s.clamp(&mut wild);
        assert_eq!(a.decode(&wild), vec![16, 7]);
    }

    #[test]
    fn exhaustive_enumerates_every_arg_vector() {
        let a = ArgSpace::new(vec![
            ArgDim::new("x", vec![1, 2]),
            ArgDim::new("y", vec![10, 20, 30]),
        ]);
        let s = a.genome_space();
        assert_eq!(Exhaustive::space_size(&s), Some(6));
        let mut opt = Exhaustive::new();
        let ctx = crate::opt::AskCtx {
            space: &s,
            budget_left: 100,
            batch_hint: 100,
        };
        let batch = crate::opt::Optimizer::ask(&mut opt, &ctx);
        let mut seen: Vec<Vec<i64>> = batch.iter().map(|p| a.decode(p)).collect();
        seen.sort();
        seen.dedup();
        assert_eq!(seen.len(), 6, "every (x, y) combination proposed once");
    }
}
