//! The greedy heuristic (§III-D), adopted from INR-Arch: rank FIFOs by
//! their observed occupancy under the baseline configuration, then — from
//! largest to smallest — try collapsing each FIFO to depth 2, keeping the
//! reduction unless it deadlocks or inflates latency beyond a fixed
//! percentage of the baseline. Deterministic; chooses its own stopping
//! point (between `num_fifos` and ~2·`num_fifos` + 1 evaluations).

use super::{Optimizer, Space};
use crate::dse::Evaluator;

pub struct Greedy {
    /// Maximum tolerated latency inflation over the baseline (the paper's
    /// "fixed percentage over baseline"; 1% by default).
    pub latency_tolerance: f64,
}

impl Greedy {
    pub fn new() -> Greedy {
        Greedy {
            latency_tolerance: 0.01,
        }
    }

    pub fn with_tolerance(latency_tolerance: f64) -> Greedy {
        Greedy { latency_tolerance }
    }
}

impl Default for Greedy {
    fn default() -> Self {
        Self::new()
    }
}

impl Optimizer for Greedy {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn run(&mut self, ev: &mut Evaluator, _space: &Space, budget: usize) {
        let trace = ev.trace().clone();
        let baseline = trace.baseline_max();

        // Baseline pass with occupancy statistics for the ranking.
        let (out, stats) = ev.eval_with_stats(&baseline);
        let base_lat = match out.latency() {
            Some(l) => l,
            None => return, // Baseline-Max deadlocking means a broken design.
        };
        let max_lat = base_lat + (base_lat as f64 * self.latency_tolerance).ceil() as u64;

        // Rank: largest observed depth first.
        let mut order: Vec<usize> = (0..trace.channels.len()).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(stats.max_occupancy[i]));

        let mut cur = baseline;
        for &i in &order {
            if ev.n_evals() >= budget.max(1) {
                break;
            }
            if cur[i] <= 2 {
                continue;
            }
            let saved = cur[i];
            cur[i] = 2;
            let (lat, _bram) = ev.eval(&cur);
            let ok = matches!(lat, Some(l) if l <= max_lat);
            if !ok {
                cur[i] = saved;
            }
        }
        // Final state evaluation so the kept configuration is in history.
        ev.eval(&cur);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::opt::Space;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    fn setup(name: &str) -> (Evaluator, Space) {
        let bd = bench_suite::build(name);
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&t);
        (Evaluator::new(t), space)
    }

    #[test]
    fn greedy_preserves_latency_and_cuts_bram() {
        let (mut ev, space) = setup("gemm");
        let t = ev.trace().clone();
        let mut base_ev = Evaluator::new(t.clone());
        let (basep, _) = base_ev.eval_baselines();
        let base_lat = basep.latency.unwrap();

        Greedy::new().run(&mut ev, &space, 10_000);
        let best = ev
            .history
            .iter()
            .filter(|p| p.is_feasible())
            .min_by_key(|p| (p.bram, p.latency.unwrap()))
            .unwrap();
        assert!(
            best.latency.unwrap() as f64 <= base_lat as f64 * 1.02,
            "latency blown: {} vs {}",
            best.latency.unwrap(),
            base_lat
        );
        assert!(
            best.bram < basep.bram,
            "no BRAM saved: {} vs {}",
            best.bram,
            basep.bram
        );
    }

    #[test]
    fn greedy_never_keeps_deadlock() {
        let (mut ev, space) = setup("fig2");
        Greedy::new().run(&mut ev, &space, 10_000);
        // The last history entry is the kept configuration.
        let kept = ev.history.last().unwrap();
        assert!(kept.is_feasible(), "greedy kept a deadlocked config");
    }

    #[test]
    fn greedy_on_flowgnn_respects_data_dependent_thresholds() {
        let (mut ev, space) = setup("flowgnn_pna");
        Greedy::new().run(&mut ev, &space, 10_000);
        let kept = ev.history.last().unwrap();
        assert!(kept.is_feasible());
        // The msg FIFOs (lanes) cannot all be 2 — bursts must fit.
        let any_big = kept.depths[..crate::bench_suite::flowgnn::LANES]
            .iter()
            .any(|&d| d > 2);
        assert!(any_big, "msg FIFOs all collapsed yet no deadlock?");
    }

    #[test]
    fn greedy_is_deterministic() {
        let (mut e1, space) = setup("bicg");
        Greedy::new().run(&mut e1, &space, 10_000);
        let (mut e2, _) = setup("bicg");
        Greedy::new().run(&mut e2, &space, 10_000);
        let d1: Vec<_> = e1.history.iter().map(|p| p.depths.clone()).collect();
        let d2: Vec<_> = e2.history.iter().map(|p| p.depths.clone()).collect();
        assert_eq!(d1, d2);
    }
}
