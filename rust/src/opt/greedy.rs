//! The greedy heuristic (§III-D), adopted from INR-Arch: rank FIFOs by
//! their observed occupancy under the baseline configuration, then — from
//! largest to smallest — try collapsing each FIFO to its search minimum
//! (`max(2, analytic floor)` — collapsing below the floor is a proven
//! deadlock, so the trial would be wasted), keeping the reduction unless
//! it deadlocks or inflates latency beyond a fixed percentage of the
//! baseline. Deterministic; chooses its own stopping point (between
//! `num_fifos` and ~2·`num_fifos` + 1 evaluations).
//!
//! Ask/tell phases: one stats evaluation of the baseline (the occupancy
//! ranking — requested through [`Optimizer::wants_stats`]), then a
//! sequence of single-configuration trial collapses (each trial depends
//! on the previous accept/reject, so the batch size is inherently 1),
//! then one final evaluation of the kept configuration.

use super::{AskCtx, Optimizer};
use crate::dse::EvalResult;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Evaluate Baseline-Max with stats for the occupancy ranking.
    Baseline,
    /// Try collapsing FIFOs one at a time, in ranking order.
    Trials,
    /// Evaluate the kept configuration one last time.
    Final,
    Done,
}

pub struct Greedy {
    /// Maximum tolerated latency inflation over the baseline (the paper's
    /// "fixed percentage over baseline"; 1% by default).
    pub latency_tolerance: f64,
    phase: Phase,
    /// FIFO indices, largest observed occupancy first.
    order: Vec<usize>,
    pos: usize,
    cur: Vec<u32>,
    /// Per-channel collapse targets (`space.min_depth`), captured at
    /// baseline time.
    floors: Vec<u32>,
    saved: u32,
    trying: Option<usize>,
    max_lat: u64,
    /// Locality hints (the base configuration each trial mutates) for
    /// the last asked batch.
    hint_buf: Vec<Option<Box<[u32]>>>,
}

impl Greedy {
    pub fn new() -> Greedy {
        Self::with_tolerance(0.01)
    }

    pub fn with_tolerance(latency_tolerance: f64) -> Greedy {
        Greedy {
            latency_tolerance,
            phase: Phase::Baseline,
            order: Vec::new(),
            pos: 0,
            cur: Vec::new(),
            floors: Vec::new(),
            saved: 0,
            trying: None,
            max_lat: 0,
            hint_buf: Vec::new(),
        }
    }
}

impl Default for Greedy {
    fn default() -> Self {
        Self::new()
    }
}

impl Optimizer for Greedy {
    fn name(&self) -> &'static str {
        "greedy"
    }

    fn ask(&mut self, ctx: &AskCtx) -> Vec<Box<[u32]>> {
        self.hint_buf.clear();
        match self.phase {
            Phase::Baseline => {
                // Baseline-Max: every FIFO at its upper bound (the space
                // carries the trace's `u_i`, already floored at 2).
                self.cur = ctx.space.bounds.iter().map(|&u| u.max(2)).collect();
                self.floors = (0..ctx.space.num_fifos())
                    .map(|i| ctx.space.min_depth(i).min(ctx.space.bounds[i].max(2)))
                    .collect();
                self.hint_buf.push(None);
                vec![self.cur.clone().into()]
            }
            Phase::Trials => {
                loop {
                    if ctx.budget_left == 0 || self.pos >= self.order.len() {
                        break;
                    }
                    let i = self.order[self.pos];
                    if self.cur[i] <= self.floors[i] {
                        self.pos += 1;
                        continue;
                    }
                    // Each trial is a single-FIFO collapse of the kept
                    // base — report that base as the locality hint.
                    self.hint_buf.push(Some(self.cur.clone().into()));
                    self.saved = self.cur[i];
                    self.cur[i] = self.floors[i];
                    self.trying = Some(i);
                    return vec![self.cur.clone().into()];
                }
                // No trials left: evaluate the kept configuration so it
                // is in history (may overrun a tight budget by one, as
                // the imperative implementation did).
                self.phase = Phase::Final;
                self.hint_buf.push(Some(self.cur.clone().into()));
                vec![self.cur.clone().into()]
            }
            Phase::Final | Phase::Done => Vec::new(),
        }
    }

    fn hints(&self) -> Vec<Option<Box<[u32]>>> {
        self.hint_buf.clone()
    }

    fn tell(&mut self, results: &[EvalResult]) {
        let r = match results.first() {
            Some(r) => r,
            None => return,
        };
        match self.phase {
            Phase::Baseline => {
                let base_lat = match r.latency {
                    Some(l) => l,
                    None => {
                        // Baseline-Max deadlocking means a broken design.
                        self.phase = Phase::Done;
                        return;
                    }
                };
                self.max_lat =
                    base_lat + (base_lat as f64 * self.latency_tolerance).ceil() as u64;
                let stats = r.stats.as_ref().expect("greedy baseline needs stats");
                self.order = (0..self.cur.len()).collect();
                self.order
                    .sort_by_key(|&i| std::cmp::Reverse(stats.max_occupancy[i]));
                self.pos = 0;
                self.phase = Phase::Trials;
            }
            Phase::Trials => {
                let i = self.trying.take().expect("trial result without a trial");
                let ok = matches!(r.latency, Some(l) if l <= self.max_lat);
                if !ok {
                    self.cur[i] = self.saved;
                }
                self.pos += 1;
            }
            Phase::Final => {
                self.phase = Phase::Done;
            }
            Phase::Done => {}
        }
    }

    fn done(&self) -> bool {
        self.phase == Phase::Done
    }

    fn wants_stats(&self) -> bool {
        self.phase == Phase::Baseline
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::dse::{drive, Evaluator};
    use crate::opt::Space;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    fn setup(name: &str) -> (Evaluator, Space) {
        let bd = bench_suite::build(name);
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&t);
        (Evaluator::new(t), space)
    }

    #[test]
    fn greedy_preserves_latency_and_cuts_bram() {
        let (mut ev, space) = setup("gemm");
        let t = ev.trace().clone();
        let mut base_ev = Evaluator::new(t.clone());
        let (basep, _) = base_ev.eval_baselines();
        let base_lat = basep.latency.unwrap();

        drive(&mut Greedy::new(), &mut ev, &space, 10_000);
        let best = ev
            .history
            .iter()
            .filter(|p| p.is_feasible())
            .min_by_key(|p| (p.bram, p.latency.unwrap()))
            .unwrap();
        assert!(
            best.latency.unwrap() as f64 <= base_lat as f64 * 1.02,
            "latency blown: {} vs {}",
            best.latency.unwrap(),
            base_lat
        );
        assert!(
            best.bram < basep.bram,
            "no BRAM saved: {} vs {}",
            best.bram,
            basep.bram
        );
    }

    #[test]
    fn greedy_never_keeps_deadlock() {
        let (mut ev, space) = setup("fig2");
        drive(&mut Greedy::new(), &mut ev, &space, 10_000);
        // The last history entry is the kept configuration.
        let kept = ev.history.last().unwrap();
        assert!(kept.is_feasible(), "greedy kept a deadlocked config");
    }

    #[test]
    fn greedy_on_flowgnn_respects_data_dependent_thresholds() {
        let (mut ev, space) = setup("flowgnn_pna");
        drive(&mut Greedy::new(), &mut ev, &space, 10_000);
        let kept = ev.history.last().unwrap();
        assert!(kept.is_feasible());
        // The msg FIFOs (lanes) cannot all be 2 — bursts must fit.
        let any_big = kept.depths[..crate::bench_suite::flowgnn::LANES]
            .iter()
            .any(|&d| d > 2);
        assert!(any_big, "msg FIFOs all collapsed yet no deadlock?");
    }

    #[test]
    fn greedy_is_deterministic() {
        let (mut e1, space) = setup("bicg");
        drive(&mut Greedy::new(), &mut e1, &space, 10_000);
        let (mut e2, _) = setup("bicg");
        drive(&mut Greedy::new(), &mut e2, &space, 10_000);
        let d1: Vec<_> = e1.history.iter().map(|p| p.depths.clone()).collect();
        let d2: Vec<_> = e2.history.iter().map(|p| p.depths.clone()).collect();
        assert_eq!(d1, d2);
    }
}
