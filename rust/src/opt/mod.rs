//! The optimizers of §III-D plus comparison baselines.
//!
//! | name             | paper §III-D           | module             |
//! |------------------|------------------------|--------------------|
//! | `random`         | Random Sampling        | [`random`]         |
//! | `grouped_random` | Grouped Random         | [`random`]         |
//! | `sa`             | Simulated Annealing    | [`sa`]             |
//! | `grouped_sa`     | Grouped SA             | [`sa`]             |
//! | `greedy`         | Greedy (INR-Arch)      | [`greedy`]         |
//! | `exhaustive`     | (testing aid)          | [`exhaustive`]     |
//! | `vitis_hunter`   | Vitis deadlock hunter  | [`vitis_hunter`]   |
//!
//! All optimizers record their proposals through the shared
//! [`Evaluator`](crate::dse::Evaluator); the Pareto front is extracted
//! from its history afterwards, exactly as in the paper's flow.

pub mod exhaustive;
pub mod greedy;
pub mod nsga2;
pub mod objective;
pub mod pareto;
pub mod random;
pub mod sa;
pub mod space;
pub mod vitis_hunter;

pub use space::Space;

use crate::dse::Evaluator;

/// A black-box FIFO-sizing optimizer.
pub trait Optimizer {
    /// Short name used in reports (matches the table above).
    fn name(&self) -> &'static str;
    /// Propose and evaluate up to `budget` configurations through `ev`
    /// (heuristics may stop early — the paper's greedy "deterministically
    /// chooses its own stopping point").
    fn run(&mut self, ev: &mut Evaluator, space: &Space, budget: usize);
}

/// The paper's five evaluated optimizers, with per-optimizer seeds.
pub fn paper_optimizers(seed: u64) -> Vec<Box<dyn Optimizer>> {
    vec![
        Box::new(greedy::Greedy::new()),
        Box::new(random::RandomSearch::new(seed, false)),
        Box::new(random::RandomSearch::new(seed ^ 1, true)),
        Box::new(sa::SimAnneal::new(seed ^ 2, false)),
        Box::new(sa::SimAnneal::new(seed ^ 3, true)),
    ]
}

/// Look up one optimizer by report name.
pub fn by_name(name: &str, seed: u64) -> Option<Box<dyn Optimizer>> {
    Some(match name {
        "random" => Box::new(random::RandomSearch::new(seed, false)),
        "grouped_random" => Box::new(random::RandomSearch::new(seed, true)),
        "sa" => Box::new(sa::SimAnneal::new(seed, false)),
        "grouped_sa" => Box::new(sa::SimAnneal::new(seed, true)),
        "greedy" => Box::new(greedy::Greedy::new()),
        "exhaustive" => Box::new(exhaustive::Exhaustive::new()),
        "vitis_hunter" => Box::new(vitis_hunter::VitisHunter::new()),
        "nsga2" => Box::new(nsga2::Nsga2::new(seed, false)),
        "grouped_nsga2" => Box::new(nsga2::Nsga2::new(seed, true)),
        _ => return None,
    })
}

/// All report names accepted by [`by_name`].
pub const OPTIMIZER_NAMES: [&str; 9] = [
    "greedy",
    "random",
    "grouped_random",
    "sa",
    "grouped_sa",
    "exhaustive",
    "vitis_hunter",
    "nsga2",
    "grouped_nsga2",
];
