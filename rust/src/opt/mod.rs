//! The optimizers of §III-D plus comparison baselines.
//!
//! | name             | paper §III-D           | module             |
//! |------------------|------------------------|--------------------|
//! | `random`         | Random Sampling        | [`random`]         |
//! | `grouped_random` | Grouped Random         | [`random`]         |
//! | `sa`             | Simulated Annealing    | [`sa`]             |
//! | `grouped_sa`     | Grouped SA             | [`sa`]             |
//! | `greedy`         | Greedy (INR-Arch)      | [`greedy`]         |
//! | `exhaustive`     | (testing aid)          | [`exhaustive`]     |
//! | `vitis_hunter`   | Vitis deadlock hunter  | [`vitis_hunter`]   |
//! | `nsga2`          | NSGA-II (extension)    | [`nsga2`]          |
//!
//! Every optimizer speaks the batch-first **ask/tell** protocol: the
//! engine's [`drive`](crate::dse::drive) loop repeatedly calls
//! [`Optimizer::ask`] for a batch of proposals, evaluates them (in
//! parallel, memoized, deduplicated), and hands the outcomes back through
//! [`Optimizer::tell`]. Optimizers never touch the evaluator directly —
//! population methods get their natural batch parallelism for free, and
//! the engine centralizes history, budget, and cache accounting. The
//! Pareto front is extracted from the engine history afterwards, exactly
//! as in the paper's flow.
//!
//! # Authoring an optimizer
//!
//! Implement [`Optimizer`] and register the name in [`by_name`]:
//!
//! - `ask` proposes a batch (at most `ctx.budget_left`; empty ends the
//!   run), `tell` receives one [`EvalResult`] per proposal in order.
//! - Override `wants_stats` to get per-channel occupancy/stall stats and
//!   deadlock block info on each result (evaluated serially — use it for
//!   ranking phases, not for bulk search).
//! - Override [`hints`](Optimizer::hints) whenever proposals are *small
//!   mutations of a known configuration* — return that parent per
//!   proposal. The simulator retains its last committed schedule and
//!   re-simulates a 1–2-channel delta at a fraction of a full replay, and
//!   the engine's worker pool routes each proposal to the worker whose
//!   retained schedule is Hamming-closest to the hint. Hints are purely
//!   advisory: results are bit-identical with or without them (and between
//!   serial and `--jobs N` runs); they only decide how much work each
//!   evaluation costs. SA reports its chain incumbents, greedy and the
//!   Vitis hunter their current base configuration.
//!
//! [`dominance`] hosts the simulation-free pruning layer the engine
//! threads every latency-only proposal through: the monotone
//! [`FeasibilityOracle`](dominance::FeasibilityOracle) (dominance
//! antichains over known deadlocks / known-feasible configs) and the
//! occupancy-clamp [`Canonicalizer`](dominance::Canonicalizer). Like
//! hints, pruning never changes results — only how many simulations they
//! cost. [`bounds`] adds the analytic depth-bounds pass on top: per-
//! channel deadlock floors and tightened clamp caps mined from the
//! compiled event graph, which shrink every [`Space`] dimension, seed
//! the oracle, and let the engine answer sub-floor proposals with zero
//! simulation (`--no-bounds` disables the engine side, mirroring
//! `--no-prune`).
//!
//! [`genome`] turns the same protocol outward: an
//! [`ArgSpace`](genome::ArgSpace) wraps a design's kernel-argument space
//! in a synthetic [`Space`] so any optimizer above can drive the
//! adversarial scenario hunter ([`dse::advhunt`](crate::dse::advhunt))
//! without modification — proposals are argument-value indices, decoded
//! back into concrete arg vectors per candidate.

pub mod bounds;
pub mod dominance;
pub mod exhaustive;
pub mod genome;
pub mod greedy;
pub mod nsga2;
pub mod objective;
pub mod pareto;
pub mod random;
pub mod sa;
pub mod space;
pub mod vitis_hunter;

pub use space::Space;

use crate::dse::EvalResult;

/// Context handed to every [`Optimizer::ask`] call.
pub struct AskCtx<'a> {
    /// The pruned search space (§III-C).
    pub space: &'a Space,
    /// Proposals remaining in the run's budget. The first `ask` of a run
    /// sees the full budget.
    pub budget_left: usize,
    /// The engine's preferred batch size (large enough to keep every
    /// worker busy). Purely advisory.
    pub batch_hint: usize,
}

/// A black-box FIFO-sizing optimizer (batch-first ask/tell protocol).
///
/// Contract: after a non-empty `ask`, the driver evaluates the batch and
/// calls `tell` exactly once with one [`EvalResult`] per proposal, in
/// proposal order, before the next `ask`. An empty `ask` (or `done()`
/// returning true) ends the run. Optimizers are single-run objects —
/// construct a fresh one per run.
pub trait Optimizer {
    /// Short name used in reports (matches the table above).
    fn name(&self) -> &'static str;

    /// Propose the next batch of configurations. Return at most
    /// `ctx.budget_left` proposals (heuristics may stop early — the
    /// paper's greedy "deterministically chooses its own stopping
    /// point"); an empty batch ends the run.
    fn ask(&mut self, ctx: &AskCtx) -> Vec<Box<[u32]>>;

    /// Receive the evaluated outcomes of the batch just asked.
    fn tell(&mut self, results: &[EvalResult]);

    /// True once the optimizer has nothing more to propose.
    fn done(&self) -> bool {
        false
    }

    /// When true, the batch just asked is evaluated serially with
    /// per-channel statistics and deadlock block info attached to each
    /// [`EvalResult`] (queried by the driver after each `ask`).
    fn wants_stats(&self) -> bool {
        false
    }

    /// Locality hints for the batch most recently returned by `ask`:
    /// element `k` is the configuration proposal `k` was *derived from*
    /// (the SA chain's incumbent, greedy's base configuration, …), or
    /// `None`. The engine uses them for sticky worker dispatch so small
    /// mutations become delta re-simulations; they never affect results.
    /// An empty vector (the default) means "no hints".
    fn hints(&self) -> Vec<Option<Box<[u32]>>> {
        Vec::new()
    }
}

/// The paper's five evaluated optimizers, with per-optimizer seeds.
pub fn paper_optimizers(seed: u64) -> Vec<Box<dyn Optimizer>> {
    vec![
        Box::new(greedy::Greedy::new()),
        Box::new(random::RandomSearch::new(seed, false)),
        Box::new(random::RandomSearch::new(seed ^ 1, true)),
        Box::new(sa::SimAnneal::new(seed ^ 2, false)),
        Box::new(sa::SimAnneal::new(seed ^ 3, true)),
    ]
}

/// Look up one optimizer by report name.
pub fn by_name(name: &str, seed: u64) -> Option<Box<dyn Optimizer>> {
    Some(match name {
        "random" => Box::new(random::RandomSearch::new(seed, false)),
        "grouped_random" => Box::new(random::RandomSearch::new(seed, true)),
        "sa" => Box::new(sa::SimAnneal::new(seed, false)),
        "grouped_sa" => Box::new(sa::SimAnneal::new(seed, true)),
        "greedy" => Box::new(greedy::Greedy::new()),
        "exhaustive" => Box::new(exhaustive::Exhaustive::new()),
        "vitis_hunter" => Box::new(vitis_hunter::VitisHunter::new()),
        "nsga2" => Box::new(nsga2::Nsga2::new(seed, false)),
        "grouped_nsga2" => Box::new(nsga2::Nsga2::new(seed, true)),
        _ => return None,
    })
}

/// All report names accepted by [`by_name`].
pub const OPTIMIZER_NAMES: [&str; 9] = [
    "greedy",
    "random",
    "grouped_random",
    "sa",
    "grouped_sa",
    "exhaustive",
    "vitis_hunter",
    "nsga2",
    "grouped_nsga2",
];
