//! NSGA-II: a true multi-objective evolutionary optimizer — an extension
//! beyond the paper's five optimizers (its §III formulation explicitly
//! allows "any optimizer"; weighted-sum SA cannot reach non-convex
//! frontier regions, which NSGA-II's dominance-based selection can).
//!
//! Standard machinery, specialized to the pruned FIFO space: individuals
//! are index vectors into per-FIFO (or per-group) candidate sets;
//! crossover is uniform; mutation re-draws or steps candidate indices;
//! selection is non-dominated sorting + crowding distance; deadlocked
//! individuals rank behind every feasible one.
//!
//! Population methods are the natural fit for ask/tell: every `ask`
//! emits one whole generation (the initial population or an offspring
//! cohort) that the engine evaluates across all workers in one batch —
//! parallelism the imperative point-by-point loop left on the floor.

use super::{AskCtx, Optimizer, Space};
use crate::dse::EvalResult;
use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Init,
    Evolve,
    Done,
}

pub struct Nsga2 {
    rng: Rng,
    grouped: bool,
    /// Population size (per generation).
    pub pop: usize,
    /// Per-gene mutation probability.
    pub mutation_rate: f64,
    phase: Phase,
    /// Effective population size (capped by the run budget).
    pop_eff: usize,
    genomes: Vec<Vec<usize>>,
    fits: Vec<Fit>,
    /// Genomes of the batch awaiting evaluation.
    pending: Vec<Vec<usize>>,
}

impl Nsga2 {
    pub fn new(seed: u64, grouped: bool) -> Nsga2 {
        Nsga2 {
            rng: Rng::new(seed),
            grouped,
            pop: 48,
            mutation_rate: 0.08,
            phase: Phase::Init,
            pop_eff: 0,
            genomes: Vec::new(),
            fits: Vec::new(),
            pending: Vec::new(),
        }
    }

    fn candidates<'a>(&self, space: &'a Space) -> &'a [Vec<u32>] {
        if self.grouped {
            &space.per_group
        } else {
            &space.per_fifo
        }
    }

    fn expand(&self, space: &Space, genes: &[usize]) -> Box<[u32]> {
        let cands = self.candidates(space);
        let depths: Vec<u32> = genes.iter().zip(cands).map(|(&i, c)| c[i]).collect();
        if self.grouped {
            space.expand_group_depths(&depths).into()
        } else {
            depths.into()
        }
    }

    /// Per-individual crowding distance of the current population.
    fn population_crowding(&self, rank: &[usize]) -> Vec<f64> {
        let mut crowd = vec![0.0f64; self.genomes.len()];
        let max_rank = rank.iter().copied().max().unwrap_or(0);
        for level in 0..=max_rank {
            let front: Vec<usize> = (0..self.genomes.len())
                .filter(|&i| rank[i] == level)
                .collect();
            let d = crowding(&front, &self.fits);
            for (slot, &i) in front.iter().enumerate() {
                crowd[i] = d[slot];
            }
        }
        crowd
    }
}

/// Objectives of one individual: feasible → (latency, bram); infeasible
/// ranks behind everything.
#[derive(Clone, Copy, Debug)]
struct Fit {
    latency: Option<u64>,
    bram: u32,
}

impl Fit {
    fn dominates(&self, other: &Fit) -> bool {
        match (self.latency, other.latency) {
            (Some(a), Some(b)) => {
                (a <= b && self.bram <= other.bram) && (a < b || self.bram < other.bram)
            }
            (Some(_), None) => true, // feasible dominates deadlocked
            _ => false,
        }
    }
}

/// Fast non-dominated sort: returns front index per individual.
fn nondominated_rank(fits: &[Fit]) -> Vec<usize> {
    let n = fits.len();
    let mut dominated_by = vec![0usize; n];
    let mut dominates_list: Vec<Vec<usize>> = vec![Vec::new(); n];
    for i in 0..n {
        for j in 0..n {
            if i != j && fits[i].dominates(&fits[j]) {
                dominates_list[i].push(j);
                dominated_by[j] += 1;
            }
        }
    }
    let mut rank = vec![usize::MAX; n];
    let mut current: Vec<usize> = (0..n).filter(|&i| dominated_by[i] == 0).collect();
    let mut level = 0;
    while !current.is_empty() {
        let mut next = Vec::new();
        for &i in &current {
            rank[i] = level;
            for &j in &dominates_list[i] {
                dominated_by[j] -= 1;
                if dominated_by[j] == 0 {
                    next.push(j);
                }
            }
        }
        current = next;
        level += 1;
    }
    rank
}

/// Crowding distance within one front (bigger = more isolated = better).
fn crowding(front: &[usize], fits: &[Fit]) -> Vec<f64> {
    let m = front.len();
    let mut dist = vec![0.0f64; m];
    if m <= 2 {
        return vec![f64::INFINITY; m];
    }
    // Two objectives: latency (feasible only; deadlocked fronts get 0)
    // and bram.
    for obj in 0..2 {
        let key = |i: usize| -> f64 {
            let f = &fits[front[i]];
            match obj {
                0 => f.latency.map(|l| l as f64).unwrap_or(f64::INFINITY),
                _ => f.bram as f64,
            }
        };
        let mut order: Vec<usize> = (0..m).collect();
        order.sort_by(|&a, &b| key(a).partial_cmp(&key(b)).unwrap());
        dist[order[0]] = f64::INFINITY;
        dist[order[m - 1]] = f64::INFINITY;
        let span = (key(order[m - 1]) - key(order[0])).max(1e-12);
        if !span.is_finite() {
            continue;
        }
        for w in 1..m - 1 {
            dist[order[w]] += (key(order[w + 1]) - key(order[w - 1])) / span;
        }
    }
    dist
}

impl Optimizer for Nsga2 {
    fn name(&self) -> &'static str {
        if self.grouped {
            "grouped_nsga2"
        } else {
            "nsga2"
        }
    }

    fn ask(&mut self, ctx: &AskCtx) -> Vec<Box<[u32]>> {
        let space = ctx.space;
        match self.phase {
            Phase::Init => {
                let cands = self.candidates(space);
                let genes_len = cands.len();
                let pop = self.pop.min(ctx.budget_left.max(2));
                self.pop_eff = pop;
                // Initial population: corners + random.
                let mut genomes: Vec<Vec<usize>> = Vec::with_capacity(pop);
                genomes.push(cands.iter().map(|c| c.len() - 1).collect()); // Baseline-Max-ish
                genomes.push(vec![0; genes_len]); // Baseline-Min-ish
                while genomes.len() < pop {
                    genomes
                        .push((0..genes_len).map(|g| self.rng.index(cands[g].len())).collect());
                }
                genomes.truncate(pop);
                let batch = genomes.iter().map(|g| self.expand(space, g)).collect();
                self.pending = genomes;
                batch
            }
            Phase::Evolve => {
                let pop = self.pop_eff;
                if ctx.budget_left < pop {
                    self.phase = Phase::Done;
                    return Vec::new();
                }
                let cands = self.candidates(space);
                let genes_len = cands.len();
                // Offspring via binary tournament on (rank, crowding).
                let rank = nondominated_rank(&self.fits);
                let crowd = self.population_crowding(&rank);
                let n = self.genomes.len();
                let mut offspring: Vec<Vec<usize>> = Vec::with_capacity(pop);
                while offspring.len() < pop {
                    let tournament = |rng: &mut Rng| -> usize {
                        let a = rng.index(n);
                        let b = rng.index(n);
                        let a_better =
                            rank[a] < rank[b] || (rank[a] == rank[b] && crowd[a] >= crowd[b]);
                        if a_better {
                            a
                        } else {
                            b
                        }
                    };
                    let pa = tournament(&mut self.rng);
                    let pb = tournament(&mut self.rng);
                    // Uniform crossover.
                    let mut child: Vec<usize> = (0..genes_len)
                        .map(|g| {
                            if self.rng.chance(0.5) {
                                self.genomes[pa][g]
                            } else {
                                self.genomes[pb][g]
                            }
                        })
                        .collect();
                    // Mutation: step or re-draw.
                    for (g, gene) in child.iter_mut().enumerate() {
                        if self.rng.chance(self.mutation_rate) {
                            let len = cands[g].len();
                            *gene = if self.rng.chance(0.5) {
                                self.rng.index(len)
                            } else if self.rng.chance(0.5) {
                                (*gene + 1).min(len - 1)
                            } else {
                                gene.saturating_sub(1)
                            };
                        }
                    }
                    offspring.push(child);
                }
                let batch = offspring.iter().map(|g| self.expand(space, g)).collect();
                self.pending = offspring;
                batch
            }
            Phase::Done => Vec::new(),
        }
    }

    fn tell(&mut self, results: &[EvalResult]) {
        let new_fits: Vec<Fit> = results
            .iter()
            .map(|r| Fit {
                latency: r.latency,
                bram: r.bram,
            })
            .collect();
        match self.phase {
            Phase::Init => {
                self.genomes = std::mem::take(&mut self.pending);
                self.fits = new_fits;
                self.phase = Phase::Evolve;
            }
            Phase::Evolve => {
                // Environmental selection over parents ∪ offspring.
                self.genomes.extend(std::mem::take(&mut self.pending));
                self.fits.extend(new_fits);
                let rank = nondominated_rank(&self.fits);
                let crowd = self.population_crowding(&rank);
                let mut idx: Vec<usize> = (0..self.genomes.len()).collect();
                idx.sort_by(|&a, &b| {
                    rank[a].cmp(&rank[b]).then(
                        crowd[b]
                            .partial_cmp(&crowd[a])
                            .unwrap_or(std::cmp::Ordering::Equal),
                    )
                });
                idx.truncate(self.pop_eff);
                self.genomes = idx.iter().map(|&i| self.genomes[i].clone()).collect();
                self.fits = idx.iter().map(|&i| self.fits[i]).collect();
            }
            Phase::Done => {}
        }
    }

    fn done(&self) -> bool {
        self.phase == Phase::Done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::dse::{drive, Evaluator};
    use crate::trace::collect_trace;
    use std::sync::Arc;

    fn setup(name: &str) -> (Evaluator, Space) {
        let bd = bench_suite::build(name);
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&t);
        (Evaluator::new(t), space)
    }

    #[test]
    fn rank_and_crowding_basics() {
        let fits = [
            Fit { latency: Some(10), bram: 5 },
            Fit { latency: Some(5), bram: 10 },
            Fit { latency: Some(12), bram: 12 }, // dominated
            Fit { latency: None, bram: 0 },      // deadlocked
        ];
        let r = nondominated_rank(&fits);
        assert_eq!(r[0], 0);
        assert_eq!(r[1], 0);
        assert!(r[2] > 0);
        assert!(r[3] > r[2] || r[3] > 0);
        let front = vec![0, 1];
        let d = crowding(&front, &fits);
        assert!(d.iter().all(|&x| x == f64::INFINITY));
    }

    #[test]
    fn nsga2_respects_budget_and_finds_frontier() {
        let (mut ev, space) = setup("gesummv");
        drive(&mut Nsga2::new(5, false), &mut ev, &space, 300);
        assert!(ev.n_evals() <= 300);
        let front = ev.pareto();
        assert!(front.len() >= 2, "NSGA-II should spread the front");
    }

    #[test]
    fn grouped_nsga2_uniform_groups() {
        let (mut ev, space) = setup("gesummv");
        drive(&mut Nsga2::new(7, true), &mut ev, &space, 200);
        for p in &ev.history {
            for ids in &space.groups {
                let mx = ids.iter().map(|&i| p.depths[i]).max().unwrap();
                for &i in ids {
                    let hi = space.bounds[i].max(2);
                    let d = p.depths[i];
                    assert!(d == mx || d == hi || d == space.min_depth(i).min(hi));
                }
            }
        }
    }

    #[test]
    fn nsga2_rescues_deadlocked_min() {
        let (mut ev, space) = setup("fig2");
        drive(&mut Nsga2::new(3, false), &mut ev, &space, 150);
        assert!(ev.history.iter().any(|p| p.is_feasible()));
    }

    #[test]
    fn nsga2_generations_are_whole_batches() {
        let (mut ev, space) = setup("gesummv");
        let mut o = Nsga2::new(1, false);
        o.pop = 10;
        drive(&mut o, &mut ev, &space, 45);
        // init 10 + 3 generations of 10 = 40 ≤ 45 < 50.
        assert_eq!(ev.n_evals(), 40);
    }
}
