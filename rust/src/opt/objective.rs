//! Scalarizations of the dual objective:
//!
//! - the **β weighted sum** `f(x) = (1-β)·f_lat + β·f_bram` used by the
//!   simulated-annealing optimizer's chain grid (§III-D) — note the paper
//!   applies it to the *raw* objective values;
//! - the **α evaluation score**
//!   `α·(lat/base_lat) + (1-α)·(bram/base_bram)` used to pick the
//!   "highlighted" Pareto point compared against the baselines (§IV-B,
//!   α = 0.7 vs Baseline-Max).

/// How a multi-scenario workload's per-scenario latencies collapse into
/// the single scalar objective the optimizers see
/// ([`crate::sim::scenario::ScenarioSim`]). Deadlock in *any* scenario is
/// always infeasible regardless of mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Aggregation {
    /// Worst-case (max) latency over scenarios — the robust default, and
    /// exact (no float math) so single-scenario workloads are
    /// bit-identical to single-trace evaluation.
    #[default]
    WorstCase,
    /// Weight-averaged latency, rounded to the nearest cycle.
    Weighted,
}

/// Collapse per-scenario latencies into the workload objective. `None`
/// anywhere (a deadlock in some scenario) — or an empty slice — yields
/// `None`.
pub fn aggregate_latency(
    lats: &[Option<u64>],
    weights: &[f64],
    agg: Aggregation,
) -> Option<u64> {
    debug_assert_eq!(lats.len(), weights.len());
    if lats.is_empty() || lats.iter().any(|l| l.is_none()) {
        return None;
    }
    match agg {
        Aggregation::WorstCase => lats.iter().map(|l| l.unwrap()).max(),
        Aggregation::Weighted => {
            let wsum: f64 = weights.iter().sum();
            let acc: f64 = lats
                .iter()
                .zip(weights)
                .map(|(l, w)| l.unwrap() as f64 * w)
                .sum();
            Some((acc / wsum.max(f64::MIN_POSITIVE)).round() as u64)
        }
    }
}

/// Weighted-sum objective for one SA chain. Deadlocks are handled by the
/// caller (infinite objective).
#[inline]
pub fn weighted(beta: f64, latency: u64, bram: u32) -> f64 {
    (1.0 - beta) * latency as f64 + beta * bram as f64
}

/// The β grid `{0, 1/N, …, 1}` for `n + 1` chains.
pub fn beta_grid(n: usize) -> Vec<f64> {
    assert!(n >= 1);
    (0..=n).map(|i| i as f64 / n as f64).collect()
}

/// §IV-B evaluation score of a point against a baseline. Lower is
/// better. A zero-BRAM baseline is handled with a +1 Laplace shift so the
/// ratio stays finite and ordering is preserved.
pub fn alpha_score(
    alpha: f64,
    latency: u64,
    bram: u32,
    base_latency: u64,
    base_bram: u32,
) -> f64 {
    let lat_ratio = latency as f64 / base_latency.max(1) as f64;
    let bram_ratio = (bram as f64 + 1.0) / (base_bram as f64 + 1.0);
    alpha * lat_ratio + (1.0 - alpha) * bram_ratio
}

/// Pick the index of the α-score-minimizing feasible point (the paper's
/// ★ "highlighted Pareto point"). Returns `None` if `points` is empty.
pub fn select_highlight(
    points: &[(u64, u32)],
    alpha: f64,
    base_latency: u64,
    base_bram: u32,
) -> Option<usize> {
    points
        .iter()
        .enumerate()
        .map(|(i, &(l, b))| (i, alpha_score(alpha, l, b, base_latency, base_bram)))
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregation_worst_case_and_weighted() {
        let lats = [Some(100u64), Some(300), Some(200)];
        let w = [1.0, 1.0, 2.0];
        assert_eq!(
            aggregate_latency(&lats, &w, Aggregation::WorstCase),
            Some(300)
        );
        // (100 + 300 + 2·200) / 4 = 200
        assert_eq!(
            aggregate_latency(&lats, &w, Aggregation::Weighted),
            Some(200)
        );
        // Deadlock anywhere is infeasible in both modes.
        let dead = [Some(100u64), None];
        for agg in [Aggregation::WorstCase, Aggregation::Weighted] {
            assert_eq!(aggregate_latency(&dead, &[1.0, 1.0], agg), None);
        }
        assert_eq!(aggregate_latency(&[], &[], Aggregation::WorstCase), None);
        // Single scenario: both modes return the latency unchanged.
        for agg in [Aggregation::WorstCase, Aggregation::Weighted] {
            assert_eq!(aggregate_latency(&[Some(7)], &[3.5], agg), Some(7));
        }
    }

    #[test]
    fn weighted_endpoints() {
        assert_eq!(weighted(0.0, 100, 50), 100.0);
        assert_eq!(weighted(1.0, 100, 50), 50.0);
        assert_eq!(weighted(0.5, 100, 50), 75.0);
    }

    #[test]
    fn beta_grid_shape() {
        let g = beta_grid(4);
        assert_eq!(g, vec![0.0, 0.25, 0.5, 0.75, 1.0]);
    }

    #[test]
    fn alpha_score_prefers_latency_preservation_at_07() {
        // Point A: same latency, half the BRAM. Point B: 1.5x latency,
        // zero BRAM. α = 0.7 must prefer A (the paper's rationale).
        let (bl, bb) = (1000u64, 100u32);
        let a = alpha_score(0.7, 1000, 50, bl, bb);
        let b = alpha_score(0.7, 1500, 0, bl, bb);
        assert!(a < b, "a={a} b={b}");
    }

    #[test]
    fn zero_bram_baseline_is_finite() {
        let s = alpha_score(0.7, 100, 3, 100, 0);
        assert!(s.is_finite());
        // Zero-BRAM point against zero-BRAM baseline scores 1.0 exactly
        // when latency matches.
        assert!((alpha_score(0.7, 100, 0, 100, 0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn highlight_selection() {
        let pts = [(1000u64, 100u32), (1005, 0), (700, 400)];
        let i = select_highlight(&pts, 0.7, 1000, 100).unwrap();
        assert_eq!(i, 1, "near-baseline latency with zero BRAM should win");
        assert_eq!(select_highlight(&[], 0.7, 1000, 100), None);
    }
}
