//! Pareto-front utilities for the dual objective
//! `minimize (f_lat, f_bram)` (paper §III).
//!
//! Deadlocked configurations (latency `None`) are infeasible and never
//! enter the front.

/// A single evaluated objective pair (feasible points only).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ObjPoint {
    pub latency: u64,
    pub bram: u32,
    /// Index into the originating evaluation history.
    pub index: usize,
}

/// `a` dominates `b` iff `a` is no worse in both objectives and strictly
/// better in at least one.
#[inline]
pub fn dominates(a: (u64, u32), b: (u64, u32)) -> bool {
    (a.0 <= b.0 && a.1 <= b.1) && (a.0 < b.0 || a.1 < b.1)
}

/// Extract the Pareto-optimal subset (non-dominated points) from
/// `(latency, bram, index)` triples. O(n log n): sort by latency then
/// sweep bram. Duplicate objective pairs keep the first occurrence.
pub fn pareto_front(points: &[ObjPoint]) -> Vec<ObjPoint> {
    let mut sorted: Vec<ObjPoint> = points.to_vec();
    // Sort by latency asc, then bram asc, then index for determinism.
    sorted.sort_by(|a, b| {
        (a.latency, a.bram, a.index).cmp(&(b.latency, b.bram, b.index))
    });
    let mut front: Vec<ObjPoint> = Vec::new();
    let mut best_bram = u32::MAX;
    let mut last: Option<(u64, u32)> = None;
    for p in sorted {
        if p.bram < best_bram {
            if last != Some((p.latency, p.bram)) {
                front.push(p);
                last = Some((p.latency, p.bram));
            }
            best_bram = p.bram;
        }
    }
    front
}

/// 2-D hypervolume (area dominated by the front, up to `ref_point`) —
/// the frontier-quality metric used by the ablation bench. Points beyond
/// the reference are clipped; returns 0 for an empty front.
pub fn hypervolume_2d(points: &[ObjPoint], ref_point: (u64, u32)) -> f64 {
    let front = pareto_front(points);
    let mut hv = 0.0;
    let mut prev_lat = ref_point.0 as f64;
    // Front is sorted by latency asc / bram desc; integrate right-to-left.
    for p in front.iter().rev() {
        let lat = (p.latency as f64).min(ref_point.0 as f64);
        let bram = (p.bram as f64).min(ref_point.1 as f64);
        if lat < prev_lat {
            hv += (prev_lat - lat) * (ref_point.1 as f64 - bram);
            prev_lat = lat;
        }
    }
    hv
}

/// O(n²) reference implementation for testing the sweep.
pub fn pareto_front_naive(points: &[ObjPoint]) -> Vec<ObjPoint> {
    let mut out: Vec<ObjPoint> = Vec::new();
    for (i, p) in points.iter().enumerate() {
        let dominated = points.iter().enumerate().any(|(j, q)| {
            dominates((q.latency, q.bram), (p.latency, p.bram))
                || (j < i && q.latency == p.latency && q.bram == p.bram)
        });
        if !dominated {
            out.push(*p);
        }
    }
    out.sort_by(|a, b| (a.latency, a.bram, a.index).cmp(&(b.latency, b.bram, b.index)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn pt(latency: u64, bram: u32, index: usize) -> ObjPoint {
        ObjPoint {
            latency,
            bram,
            index,
        }
    }

    #[test]
    fn simple_front() {
        let pts = [pt(10, 5, 0), pt(8, 7, 1), pt(12, 3, 2), pt(10, 7, 3), pt(8, 7, 4)];
        let f = pareto_front(&pts);
        let objs: Vec<(u64, u32)> = f.iter().map(|p| (p.latency, p.bram)).collect();
        assert_eq!(objs, vec![(8, 7), (10, 5), (12, 3)]);
        // duplicate (8,7) keeps the first index
        assert_eq!(f[0].index, 1);
    }

    #[test]
    fn dominance_rules() {
        assert!(dominates((1, 1), (2, 2)));
        assert!(dominates((1, 2), (2, 2)));
        assert!(!dominates((2, 2), (2, 2)));
        assert!(!dominates((1, 3), (2, 2)));
    }

    #[test]
    fn front_matches_naive_on_random_inputs() {
        let mut rng = Rng::new(99);
        for _ in 0..50 {
            let n = 1 + rng.index(60);
            let pts: Vec<ObjPoint> = (0..n)
                .map(|i| pt(rng.below(40), rng.below(12) as u32, i))
                .collect();
            let fast = pareto_front(&pts);
            let slow = pareto_front_naive(&pts);
            assert_eq!(fast, slow);
        }
    }

    #[test]
    fn hypervolume_basics() {
        // Single point at (5, 2) with ref (10, 10): area (10-5)*(10-2)=40.
        let hv = hypervolume_2d(&[pt(5, 2, 0)], (10, 10));
        assert!((hv - 40.0).abs() < 1e-9);
        // Adding a dominated point changes nothing.
        let hv2 = hypervolume_2d(&[pt(5, 2, 0), pt(6, 3, 1)], (10, 10));
        assert!((hv2 - 40.0).abs() < 1e-9);
        // Adding a complementary point grows the volume.
        let hv3 = hypervolume_2d(&[pt(5, 2, 0), pt(2, 8, 1)], (10, 10));
        assert!(hv3 > hv2);
        assert_eq!(hypervolume_2d(&[], (10, 10)), 0.0);
        // Points beyond the reference contribute nothing.
        let hv4 = hypervolume_2d(&[pt(20, 20, 0)], (10, 10));
        assert_eq!(hv4, 0.0);
    }

    #[test]
    fn front_members_are_mutually_nondominated() {
        let mut rng = Rng::new(5);
        let pts: Vec<ObjPoint> = (0..200)
            .map(|i| pt(rng.below(1000), rng.below(64) as u32, i))
            .collect();
        let f = pareto_front(&pts);
        for a in &f {
            for b in &f {
                assert!(!dominates((a.latency, a.bram), (b.latency, b.bram)) || a == b);
            }
        }
        // And every input point is dominated by (or equal to) some member.
        for p in &pts {
            assert!(f.iter().any(|m| (m.latency, m.bram) == (p.latency, p.bram)
                || dominates((m.latency, m.bram), (p.latency, p.bram))));
        }
    }
}
