//! Random sampling and grouped random sampling (§III-D).
//!
//! Samples are drawn from the *pruned* candidate sets (§III-C) — the
//! paper notes uniform sampling over `[2, uᵢ]` is ineffective because
//! only the BRAM-plateau boundary depths matter. The grouped variant
//! draws one candidate per stream-array group and applies it to every
//! member, exploiting the similar access patterns of `hls::stream<T>
//! name[N]` arrays.
//!
//! Under ask/tell the sampler is stateless between batches: each `ask`
//! draws `min(budget_left, batch_hint)` fresh samples, which the engine
//! evaluates across its whole worker pool at once.

use super::{AskCtx, Optimizer, Space};
use crate::dse::EvalResult;
use crate::util::Rng;

pub struct RandomSearch {
    rng: Rng,
    grouped: bool,
    /// Ablation switch: sample uniformly from the RAW space `[2, uᵢ]`
    /// instead of the pruned candidate sets — the strategy §III-D calls
    /// "often ineffective". Exercised by `benches/ablation.rs`.
    pub uniform_raw: bool,
}

impl RandomSearch {
    pub fn new(seed: u64, grouped: bool) -> RandomSearch {
        RandomSearch {
            rng: Rng::new(seed),
            grouped,
            uniform_raw: false,
        }
    }

    /// Raw-space sampler (pruning disabled) for the ablation study.
    pub fn new_uniform_raw(seed: u64) -> RandomSearch {
        RandomSearch {
            rng: Rng::new(seed),
            grouped: false,
            uniform_raw: true,
        }
    }

    fn sample(&mut self, space: &Space) -> Box<[u32]> {
        if self.uniform_raw {
            return space
                .bounds
                .iter()
                .map(|&u| self.rng.range_u32(2, u.max(2)))
                .collect();
        }
        if self.grouped {
            let picks: Vec<u32> = space
                .per_group
                .iter()
                .map(|c| *self.rng.choose(c))
                .collect();
            space.expand_group_depths(&picks).into()
        } else {
            space
                .per_fifo
                .iter()
                .map(|c| *self.rng.choose(c))
                .collect()
        }
    }
}

impl Optimizer for RandomSearch {
    fn name(&self) -> &'static str {
        if self.grouped {
            "grouped_random"
        } else {
            "random"
        }
    }

    fn ask(&mut self, ctx: &AskCtx) -> Vec<Box<[u32]>> {
        let n = ctx.budget_left.min(ctx.batch_hint);
        (0..n).map(|_| self.sample(ctx.space)).collect()
    }

    fn tell(&mut self, _results: &[EvalResult]) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::dse::{drive, Evaluator};
    use crate::trace::collect_trace;
    use std::sync::Arc;

    fn setup(name: &str) -> (Evaluator, Space) {
        let bd = bench_suite::build(name);
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&t);
        (Evaluator::new(t), space)
    }

    #[test]
    fn respects_budget_and_candidates() {
        let (mut ev, space) = setup("bicg");
        let mut opt = RandomSearch::new(7, false);
        drive(&mut opt, &mut ev, &space, 100);
        assert_eq!(ev.n_evals(), 100);
        for p in &ev.history {
            for (i, &d) in p.depths.iter().enumerate() {
                assert!(
                    space.per_fifo[i].contains(&d),
                    "depth {d} not a pruned candidate of fifo {i}"
                );
            }
        }
    }

    #[test]
    fn grouped_assigns_uniform_depths_within_groups() {
        let (mut ev, space) = setup("gesummv");
        let mut opt = RandomSearch::new(7, true);
        drive(&mut opt, &mut ev, &space, 20);
        for p in &ev.history {
            for ids in &space.groups {
                // All members share the group draw, modulo per-member
                // bound/floor clamping.
                let draws: Vec<u32> = ids.iter().map(|&i| p.depths[i]).collect();
                let max = *draws.iter().max().unwrap();
                for (&i, &d) in ids.iter().zip(&draws) {
                    let hi = space.bounds[i].max(2);
                    assert!(d == max || d == hi || d == space.min_depth(i).min(hi));
                }
            }
        }
    }

    #[test]
    fn finds_feasible_points_on_fig2() {
        let (mut ev, space) = setup("fig2");
        let mut opt = RandomSearch::new(42, false);
        drive(&mut opt, &mut ev, &space, 200);
        let front = ev.pareto();
        assert!(!front.is_empty(), "random must find feasible fig2 configs");
    }

    #[test]
    fn deterministic_given_seed() {
        let (mut ev1, space) = setup("bicg");
        drive(&mut RandomSearch::new(5, false), &mut ev1, &space, 30);
        let (mut ev2, _) = setup("bicg");
        drive(&mut RandomSearch::new(5, false), &mut ev2, &space, 30);
        let d1: Vec<_> = ev1.history.iter().map(|p| p.depths.clone()).collect();
        let d2: Vec<_> = ev2.history.iter().map(|p| p.depths.clone()).collect();
        assert_eq!(d1, d2);
    }
}
