//! Simulated annealing and grouped simulated annealing (§III-D).
//!
//! The multi-objective search is scalarized into `N + 1` weighted-sum
//! chains, `f(x) = (1-β)·f_lat + β·f_bram` for β ∈ {0, 1/N, …, 1}; each
//! chain anneals independently and all evaluated points are aggregated
//! before Pareto extraction (the aggregation happens naturally through
//! the shared [`Evaluator`] history). As in the paper, the weighted sum
//! is applied to the *raw* objective values — one reason plain SA
//! underperforms the grouped/greedy methods in Fig. 4, which this
//! reproduction preserves.
//!
//! State is an index vector into the pruned candidate sets (per FIFO, or
//! per stream-array group in the grouped variant); neighbors perturb one
//! to three positions by ±1 steps or random jumps.

use super::objective::{beta_grid, weighted};
use super::{Optimizer, Space};
use crate::dse::Evaluator;
use crate::util::Rng;

/// Default number of β chains (`N + 1` with N = 7).
pub const DEFAULT_CHAINS: usize = 8;

pub struct SimAnneal {
    rng: Rng,
    grouped: bool,
    /// Number of β values (chains).
    pub chains: usize,
    /// Final temperature as a fraction of the initial.
    pub t_final_frac: f64,
}

impl SimAnneal {
    pub fn new(seed: u64, grouped: bool) -> SimAnneal {
        SimAnneal {
            rng: Rng::new(seed),
            grouped,
            chains: DEFAULT_CHAINS,
            t_final_frac: 1e-4,
        }
    }

    /// Candidate sets the chain state indexes into.
    fn candidates<'a>(&self, space: &'a Space) -> &'a [Vec<u32>] {
        if self.grouped {
            &space.per_group
        } else {
            &space.per_fifo
        }
    }

    fn expand(&self, space: &Space, state: &[usize]) -> Box<[u32]> {
        let cands = self.candidates(space);
        let depths: Vec<u32> = state.iter().zip(cands).map(|(&i, c)| c[i]).collect();
        if self.grouped {
            space.expand_group_depths(&depths).into()
        } else {
            depths.into()
        }
    }

    fn anneal_chain(
        &mut self,
        ev: &mut Evaluator,
        space: &Space,
        beta: f64,
        steps: usize,
    ) {
        if steps == 0 {
            return;
        }
        let cands = self.candidates(space);
        let n = cands.len();

        // Start from the full-depth corner: always feasible (Baseline-Max
        // expanded through the pruned space), so every chain has a valid
        // incumbent even on deadlock-heavy designs.
        let mut state: Vec<usize> = cands.iter().map(|c| c.len() - 1).collect();
        let cfg = self.expand(space, &state);
        let (lat, bram) = ev.eval(&cfg);
        let mut cur = match lat {
            Some(l) => weighted(beta, l, bram),
            None => f64::INFINITY,
        };

        // Initial temperature from the incumbent's scale; geometric decay.
        let t0 = (cur.abs().max(1.0)) * 0.1;
        let t_end = t0 * self.t_final_frac;
        let decay = (t_end / t0).powf(1.0 / steps.max(1) as f64);
        let mut temp = t0;

        for _ in 0..steps.saturating_sub(1) {
            // Perturb 1–3 positions.
            let mut next = state.clone();
            let moves = 1 + self.rng.index(3);
            for _ in 0..moves {
                let pos = self.rng.index(n);
                let len = cands[pos].len();
                if len == 1 {
                    continue;
                }
                next[pos] = if self.rng.chance(0.5) {
                    // ±1 step.
                    if self.rng.chance(0.5) {
                        (next[pos] + 1).min(len - 1)
                    } else {
                        next[pos].saturating_sub(1)
                    }
                } else {
                    self.rng.index(len)
                };
            }
            let cfg = self.expand(space, &next);
            let (lat, bram) = ev.eval(&cfg);
            let cand = match lat {
                Some(l) => weighted(beta, l, bram),
                None => f64::INFINITY,
            };
            let accept = cand <= cur
                || (cand.is_finite()
                    && self.rng.f64() < (-(cand - cur) / temp.max(1e-12)).exp());
            if accept {
                state = next;
                cur = cand;
            }
            temp *= decay;
        }
    }
}

impl Optimizer for SimAnneal {
    fn name(&self) -> &'static str {
        if self.grouped {
            "grouped_sa"
        } else {
            "sa"
        }
    }

    fn run(&mut self, ev: &mut Evaluator, space: &Space, budget: usize) {
        let betas = beta_grid(self.chains.max(2) - 1);
        let per_chain = budget / betas.len();
        for &beta in &betas {
            self.anneal_chain(ev, space, beta, per_chain);
        }
        // Spend any rounding remainder on the latency-focused chain.
        let rem = budget - per_chain * betas.len();
        if rem > 0 {
            self.anneal_chain(ev, space, 0.0, rem);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    fn setup(name: &str) -> (Evaluator, Space) {
        let bd = bench_suite::build(name);
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&t);
        (Evaluator::new(t), space)
    }

    #[test]
    fn budget_respected_exactly() {
        let (mut ev, space) = setup("bicg");
        SimAnneal::new(1, false).run(&mut ev, &space, 200);
        assert_eq!(ev.n_evals(), 200);
    }

    #[test]
    fn chains_start_feasible_and_explore() {
        let (mut ev, space) = setup("fig2");
        SimAnneal::new(2, false).run(&mut ev, &space, 160);
        let feasible = ev.history.iter().filter(|p| p.is_feasible()).count();
        assert!(feasible >= DEFAULT_CHAINS, "at least the chain starts");
        // Exploration: fig2's pruned space has exactly 4 configurations
        // ({2,16} × {2,16}); SA should visit all of them.
        let distinct: std::collections::HashSet<_> =
            ev.history.iter().map(|p| p.depths.clone()).collect();
        assert_eq!(distinct.len(), 4);
    }

    #[test]
    fn grouped_sa_moves_whole_groups() {
        let (mut ev, space) = setup("gesummv");
        SimAnneal::new(3, true).run(&mut ev, &space, 80);
        for p in &ev.history {
            for ids in &space.groups {
                let max = ids.iter().map(|&i| p.depths[i]).max().unwrap();
                for &i in ids {
                    let d = p.depths[i];
                    assert!(d == max || d == space.bounds[i].max(2));
                }
            }
        }
    }

    #[test]
    fn beta_one_chain_reaches_low_bram() {
        // With β = 1 the objective is pure BRAM; SA should discover (or
        // at least approach) a zero-BRAM config on a tiny design.
        let (mut ev, space) = setup("bicg");
        SimAnneal::new(4, false).run(&mut ev, &space, 400);
        let min_bram = ev
            .history
            .iter()
            .filter(|p| p.is_feasible())
            .map(|p| p.bram)
            .min()
            .unwrap();
        let (max_bl, _) = {
            let t = ev.trace().clone();
            let mut e2 = Evaluator::new(t.clone());
            let (m, _) = e2.eval_baselines();
            (m, ())
        };
        assert!(
            min_bram < max_bl.bram,
            "SA never improved on Baseline-Max BRAM ({min_bram} vs {})",
            max_bl.bram
        );
    }
}
