//! Simulated annealing and grouped simulated annealing (§III-D).
//!
//! The multi-objective search is scalarized into `N + 1` weighted-sum
//! chains, `f(x) = (1-β)·f_lat + β·f_bram` for β ∈ {0, 1/N, …, 1}; each
//! chain anneals independently and all evaluated points are aggregated
//! before Pareto extraction (the aggregation happens naturally through
//! the shared engine history). As in the paper, the weighted sum is
//! applied to the *raw* objective values — one reason plain SA
//! underperforms the grouped/greedy methods in Fig. 4, which this
//! reproduction preserves.
//!
//! State is an index vector into the pruned candidate sets (per FIFO, or
//! per stream-array group in the grouped variant); neighbors perturb one
//! to three positions by ±1 steps or random jumps.
//!
//! Under ask/tell the chains run **in lockstep**: every `ask` collects
//! one proposal from each chain that still has budget (so a whole
//! generation of chain moves is simulated as one parallel batch), and
//! `tell` applies each chain's accept/reject decision. The chains were
//! strictly sequential before this refactor, leaving the worker pool
//! idle.

use super::objective::{beta_grid, weighted};
use super::{AskCtx, Optimizer, Space};
use crate::dse::EvalResult;
use crate::util::Rng;

/// Default number of β chains (`N + 1` with N = 7).
pub const DEFAULT_CHAINS: usize = 8;

struct Chain {
    beta: f64,
    /// Current (accepted) state: candidate indices.
    state: Vec<usize>,
    /// Proposal awaiting its evaluation result.
    next: Option<Vec<usize>>,
    /// Current objective value (∞ until the start state is evaluated).
    cur: f64,
    temp: f64,
    decay: f64,
    /// Proposals this chain may still make.
    left: usize,
    started: bool,
}

pub struct SimAnneal {
    rng: Rng,
    grouped: bool,
    /// Number of β values (chains).
    pub chains: usize,
    /// Final temperature as a fraction of the initial.
    pub t_final_frac: f64,
    runs: Option<Vec<Chain>>,
    /// Chain index of each proposal in the last asked batch.
    asked: Vec<usize>,
    /// Locality hints (chain incumbents) for the last asked batch.
    hint_buf: Vec<Option<Box<[u32]>>>,
}

impl SimAnneal {
    pub fn new(seed: u64, grouped: bool) -> SimAnneal {
        SimAnneal {
            rng: Rng::new(seed),
            grouped,
            chains: DEFAULT_CHAINS,
            t_final_frac: 1e-4,
            runs: None,
            asked: Vec::new(),
            hint_buf: Vec::new(),
        }
    }

    /// Candidate sets the chain state indexes into.
    fn candidates<'a>(&self, space: &'a Space) -> &'a [Vec<u32>] {
        if self.grouped {
            &space.per_group
        } else {
            &space.per_fifo
        }
    }

    fn expand(&self, space: &Space, state: &[usize]) -> Box<[u32]> {
        let cands = self.candidates(space);
        let depths: Vec<u32> = state.iter().zip(cands).map(|(&i, c)| c[i]).collect();
        if self.grouped {
            space.expand_group_depths(&depths).into()
        } else {
            depths.into()
        }
    }

    /// Perturb 1–3 positions of a chain state.
    fn perturb(&mut self, cands: &[Vec<u32>], mut next: Vec<usize>) -> Vec<usize> {
        let n = cands.len();
        let moves = 1 + self.rng.index(3);
        for _ in 0..moves {
            let pos = self.rng.index(n);
            let len = cands[pos].len();
            if len == 1 {
                continue;
            }
            next[pos] = if self.rng.chance(0.5) {
                // ±1 step.
                if self.rng.chance(0.5) {
                    (next[pos] + 1).min(len - 1)
                } else {
                    next[pos].saturating_sub(1)
                }
            } else {
                self.rng.index(len)
            };
        }
        next
    }

    /// Build the chain set from the run budget (first `ask`).
    fn init_runs(&mut self, space: &Space, budget: usize) {
        let cands = self.candidates(space);
        // Start every chain from the full-depth corner: always feasible
        // (Baseline-Max expanded through the pruned space), so each chain
        // has a valid incumbent even on deadlock-heavy designs.
        let corner: Vec<usize> = cands.iter().map(|c| c.len() - 1).collect();
        let new_chain = |beta: f64, steps: usize| Chain {
            beta,
            state: corner.clone(),
            next: None,
            cur: f64::INFINITY,
            temp: 1.0,
            decay: self.t_final_frac.powf(1.0 / steps.max(1) as f64),
            left: steps,
            started: false,
        };
        let betas = beta_grid(self.chains.max(2) - 1);
        let per_chain = budget / betas.len();
        let mut runs: Vec<Chain> = Vec::new();
        if per_chain > 0 {
            for &beta in &betas {
                runs.push(new_chain(beta, per_chain));
            }
        }
        // Spend any rounding remainder on a latency-focused chain.
        let rem = budget - per_chain * betas.len();
        if rem > 0 {
            runs.push(new_chain(0.0, rem));
        }
        self.runs = Some(runs);
    }
}

impl Optimizer for SimAnneal {
    fn name(&self) -> &'static str {
        if self.grouped {
            "grouped_sa"
        } else {
            "sa"
        }
    }

    fn ask(&mut self, ctx: &AskCtx) -> Vec<Box<[u32]>> {
        if self.runs.is_none() {
            self.init_runs(ctx.space, ctx.budget_left);
        }
        self.asked.clear();
        self.hint_buf.clear();
        let mut batch: Vec<Box<[u32]>> = Vec::new();
        let n_runs = self.runs.as_ref().unwrap().len();
        for ci in 0..n_runs {
            let (started, left, state) = {
                let ch = &self.runs.as_ref().unwrap()[ci];
                (ch.started, ch.left, ch.state.clone())
            };
            if left == 0 {
                continue;
            }
            // The chain's incumbent is the proposal's parent: the engine
            // routes the move to the worker already holding its schedule.
            let parent = if started {
                Some(self.expand(ctx.space, &state))
            } else {
                None
            };
            let proposal = if started {
                let cands = self.candidates(ctx.space);
                self.perturb(cands, state)
            } else {
                state
            };
            batch.push(self.expand(ctx.space, &proposal));
            self.hint_buf.push(parent);
            let ch = &mut self.runs.as_mut().unwrap()[ci];
            ch.next = Some(proposal);
            ch.left -= 1;
            self.asked.push(ci);
        }
        batch
    }

    fn hints(&self) -> Vec<Option<Box<[u32]>>> {
        self.hint_buf.clone()
    }

    fn tell(&mut self, results: &[EvalResult]) {
        debug_assert_eq!(results.len(), self.asked.len());
        for (k, r) in results.iter().enumerate() {
            let ci = self.asked[k];
            let (beta, started, cur, temp) = {
                let ch = &self.runs.as_ref().unwrap()[ci];
                (ch.beta, ch.started, ch.cur, ch.temp)
            };
            let cand = match r.latency {
                Some(l) => weighted(beta, l, r.bram),
                None => f64::INFINITY,
            };
            if !started {
                // Start-state evaluation: fix the incumbent and set the
                // initial temperature from its scale.
                let scale = if cand.is_finite() {
                    cand.abs().max(1.0)
                } else {
                    1.0
                };
                let ch = &mut self.runs.as_mut().unwrap()[ci];
                ch.started = true;
                if let Some(next) = ch.next.take() {
                    ch.state = next;
                }
                ch.cur = cand;
                ch.temp = scale * 0.1;
            } else {
                let accept = cand <= cur
                    || (cand.is_finite()
                        && self.rng.f64() < (-(cand - cur) / temp.max(1e-12)).exp());
                let ch = &mut self.runs.as_mut().unwrap()[ci];
                let next = ch.next.take();
                if accept {
                    if let Some(next) = next {
                        ch.state = next;
                    }
                    ch.cur = cand;
                }
                ch.temp *= ch.decay;
            }
        }
        self.asked.clear();
    }

    fn done(&self) -> bool {
        match &self.runs {
            None => false,
            Some(runs) => runs.iter().all(|c| c.left == 0 && c.next.is_none()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::dse::{drive, Evaluator};
    use crate::trace::collect_trace;
    use std::sync::Arc;

    fn setup(name: &str) -> (Evaluator, Space) {
        let bd = bench_suite::build(name);
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&t);
        (Evaluator::new(t), space)
    }

    #[test]
    fn budget_respected_exactly() {
        let (mut ev, space) = setup("bicg");
        drive(&mut SimAnneal::new(1, false), &mut ev, &space, 200);
        assert_eq!(ev.n_evals(), 200);
    }

    #[test]
    fn chains_start_feasible_and_explore() {
        let (mut ev, space) = setup("fig2");
        drive(&mut SimAnneal::new(2, false), &mut ev, &space, 160);
        let feasible = ev.history.iter().filter(|p| p.is_feasible()).count();
        assert!(feasible >= DEFAULT_CHAINS, "at least the chain starts");
        // Exploration: fig2's pruned space has exactly 4 configurations
        // ({15,16} × {2,16} after the analytic floor collapse); SA
        // should visit all of them.
        let distinct: std::collections::HashSet<_> =
            ev.history.iter().map(|p| p.depths.clone()).collect();
        assert_eq!(distinct.len(), 4);
    }

    #[test]
    fn grouped_sa_moves_whole_groups() {
        let (mut ev, space) = setup("gesummv");
        drive(&mut SimAnneal::new(3, true), &mut ev, &space, 80);
        for p in &ev.history {
            for ids in &space.groups {
                let max = ids.iter().map(|&i| p.depths[i]).max().unwrap();
                for &i in ids {
                    let d = p.depths[i];
                    let hi = space.bounds[i].max(2);
                    assert!(d == max || d == hi || d == space.min_depth(i).min(hi));
                }
            }
        }
    }

    #[test]
    fn beta_one_chain_reaches_low_bram() {
        // With β = 1 the objective is pure BRAM; SA should discover (or
        // at least approach) a zero-BRAM config on a tiny design.
        let (mut ev, space) = setup("bicg");
        drive(&mut SimAnneal::new(4, false), &mut ev, &space, 400);
        let min_bram = ev
            .history
            .iter()
            .filter(|p| p.is_feasible())
            .map(|p| p.bram)
            .min()
            .unwrap();
        let (max_bl, _) = {
            let t = ev.trace().clone();
            let mut e2 = Evaluator::new(t.clone());
            let (m, _) = e2.eval_baselines();
            (m, ())
        };
        assert!(
            min_bram < max_bl.bram,
            "SA never improved on Baseline-Max BRAM ({min_bram} vs {})",
            max_bl.bram
        );
    }

    #[test]
    fn sa_is_deterministic_given_seed() {
        let (mut e1, space) = setup("gesummv");
        drive(&mut SimAnneal::new(9, false), &mut e1, &space, 120);
        let (mut e2, _) = setup("gesummv");
        drive(&mut SimAnneal::new(9, false), &mut e2, &space, 120);
        let d1: Vec<_> = e1.history.iter().map(|p| p.depths.clone()).collect();
        let d2: Vec<_> = e2.history.iter().map(|p| p.depths.clone()).collect();
        assert_eq!(d1, d2);
    }
}
