//! The pruned search space (§III-C): per-FIFO candidate depth lists from
//! the BRAM model's plateau breakpoints, plus the stream-array group
//! structure the grouped optimizers exploit (§III-D).

use crate::bram::candidate_depths;
use crate::trace::Trace;

/// Pruned design space for one design.
#[derive(Debug, Clone)]
pub struct Space {
    /// Per-channel sorted candidate depths (each maximally utilizes its
    /// BRAM allocation; always contains 2 and the upper bound).
    pub per_fifo: Vec<Vec<u32>>,
    /// Per-channel upper bounds `u_i`.
    pub bounds: Vec<u32>,
    /// Per-channel element widths (bits).
    pub widths: Vec<u32>,
    /// Stream-array groups: channel indices per group (singletons for
    /// ungrouped channels).
    pub groups: Vec<Vec<usize>>,
    /// Per-group candidate depths (breakpoints of the group's widest
    /// member at the group's largest bound).
    pub per_group: Vec<Vec<u32>>,
}

impl Space {
    /// Build the pruned space for a trace.
    pub fn from_trace(trace: &Trace) -> Space {
        let widths: Vec<u32> = trace.channels.iter().map(|c| c.width_bits).collect();
        Self::build(trace.upper_bounds(), widths, trace.groups())
    }

    /// Build the pruned space for a multi-trace
    /// [`Workload`](crate::trace::workload::Workload): bounds are the
    /// merged (max-over-scenarios) upper bounds, topology from the
    /// primary scenario. For single-scenario workloads this equals
    /// [`from_trace`](Self::from_trace) on the trace.
    pub fn from_workload(workload: &crate::trace::workload::Workload) -> Space {
        let primary = workload.primary();
        let widths: Vec<u32> = primary.channels.iter().map(|c| c.width_bits).collect();
        Self::build(workload.upper_bounds(), widths, primary.groups())
    }

    fn build(bounds: Vec<u32>, widths: Vec<u32>, groups: Vec<Vec<usize>>) -> Space {
        let per_fifo: Vec<Vec<u32>> = bounds
            .iter()
            .zip(&widths)
            .map(|(&u, &w)| candidate_depths(w, u))
            .collect();
        let per_group = groups
            .iter()
            .map(|ids| {
                let u = ids.iter().map(|&i| bounds[i]).max().unwrap();
                let w = ids.iter().map(|&i| widths[i]).max().unwrap();
                candidate_depths(w, u)
            })
            .collect();
        Space {
            per_fifo,
            bounds,
            widths,
            groups,
            per_group,
        }
    }

    /// Number of channels.
    pub fn num_fifos(&self) -> usize {
        self.per_fifo.len()
    }

    /// log10 of the pruned per-FIFO space size (design-space cardinality
    /// diagnostic; the raw space is Π(uᵢ - 1)).
    pub fn log10_size(&self) -> f64 {
        self.per_fifo.iter().map(|c| (c.len() as f64).log10()).sum()
    }

    /// Clamp an arbitrary depth vector into bounds (≥2, ≤uᵢ).
    pub fn clamp(&self, depths: &mut [u32]) {
        for (d, &u) in depths.iter_mut().zip(&self.bounds) {
            *d = (*d).clamp(2, u.max(2));
        }
    }

    /// Expand per-group depths into a full per-channel configuration
    /// (each member clamped to its own bound).
    pub fn expand_group_depths(&self, group_depths: &[u32]) -> Vec<u32> {
        assert_eq!(group_depths.len(), self.groups.len());
        let mut out = vec![2u32; self.num_fifos()];
        for (g, ids) in self.groups.iter().enumerate() {
            for &i in ids {
                out[i] = group_depths[g].clamp(2, self.bounds[i].max(2));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::trace::collect_trace;

    fn space_for(name: &str) -> Space {
        let bd = bench_suite::build(name);
        let t = collect_trace(&bd.design, &bd.args).unwrap();
        Space::from_trace(&t)
    }

    #[test]
    fn candidates_bounded_and_sorted() {
        let s = space_for("gemm");
        assert_eq!(s.num_fifos(), 84);
        for (c, &u) in s.per_fifo.iter().zip(&s.bounds) {
            assert_eq!(c[0], 2);
            assert_eq!(*c.last().unwrap(), u.max(2));
            assert!(c.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn pruning_shrinks_space_dramatically() {
        let s = space_for("k2mm");
        let raw: f64 = s
            .bounds
            .iter()
            .map(|&u| ((u.max(3) - 1) as f64).log10())
            .sum();
        assert!(
            s.log10_size() < raw / 2.0,
            "pruned 10^{:.1} vs raw 10^{:.1}",
            s.log10_size(),
            raw
        );
    }

    #[test]
    fn groups_share_candidates() {
        let s = space_for("FeedForward");
        assert!(s.groups.len() < s.num_fifos());
        assert_eq!(s.groups.len(), s.per_group.len());
        let cfg = s.expand_group_depths(&vec![2; s.groups.len()]);
        assert!(cfg.iter().all(|&d| d == 2));
        let maxes: Vec<u32> = s
            .groups
            .iter()
            .enumerate()
            .map(|(g, _)| *s.per_group[g].last().unwrap())
            .collect();
        let cfg = s.expand_group_depths(&maxes);
        for (i, &d) in cfg.iter().enumerate() {
            assert!(d >= 2 && d <= s.bounds[i].max(2));
        }
    }

    #[test]
    fn workload_space_merges_bounds() {
        use crate::trace::workload::Workload;
        let bd = bench_suite::build("fig2");
        let scen: Vec<(String, Vec<i64>)> = [8i64, 16]
            .iter()
            .map(|&n| (format!("n{n}"), vec![n]))
            .collect();
        let w = Workload::from_design(&bd.design, &scen).unwrap();
        let s = Space::from_workload(&w);
        // Bounds come from the larger scenario (n = 16 writes per chan).
        assert_eq!(s.bounds, vec![16, 16]);
        // A single-scenario workload space equals the trace space.
        let w1 = Workload::from_design(&bd.design, &scen[..1]).unwrap();
        let t = w1.primary().clone();
        let sw = Space::from_workload(&w1);
        let st = Space::from_trace(&t);
        assert_eq!(sw.bounds, st.bounds);
        assert_eq!(sw.per_fifo, st.per_fifo);
        assert_eq!(sw.groups, st.groups);
    }

    #[test]
    fn clamp_respects_bounds() {
        let s = space_for("bicg");
        let mut cfg = vec![0u32; s.num_fifos()];
        s.clamp(&mut cfg);
        assert!(cfg.iter().all(|&d| d >= 2));
        let mut cfg = vec![u32::MAX; s.num_fifos()];
        s.clamp(&mut cfg);
        for (i, &d) in cfg.iter().enumerate() {
            assert_eq!(d, s.bounds[i].max(2));
        }
    }
}
