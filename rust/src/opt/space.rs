//! The pruned search space (§III-C): per-FIFO candidate depth lists from
//! the BRAM model's plateau breakpoints, plus the stream-array group
//! structure the grouped optimizers exploit (§III-D).
//!
//! Since PR 8 the space is additionally collapsed by the analytic depth
//! bounds ([`super::bounds`]): each dimension's range is
//! `[max(2, floor), min(upper, cap)]` — candidates below a channel's
//! deadlock floor are provably infeasible and candidates above its
//! tightened cap are schedule-equivalent to the cap, so no optimizer
//! needs to sample either region. The floor itself is always a candidate
//! (it need not be a BRAM breakpoint — fig2's x channel floors at 15).
//! Use the `*_unbounded` constructors to reconstruct the PR 7 space for
//! A/B measurement.

use super::bounds::DepthBounds;
use crate::bram::candidate_depths;
use crate::trace::Trace;

/// Pruned design space for one design.
#[derive(Debug, Clone)]
pub struct Space {
    /// Per-channel sorted candidate depths (each maximally utilizes its
    /// BRAM allocation; always contains `max(2, floor)` and the upper
    /// bound).
    pub per_fifo: Vec<Vec<u32>>,
    /// Per-channel upper bounds `u_i` (tightened by the analytic caps).
    pub bounds: Vec<u32>,
    /// Per-channel analytic deadlock floors (0/1 where trivial; the
    /// effective search minimum is `max(2, floors[i])`).
    pub floors: Vec<u32>,
    /// Per-channel element widths (bits).
    pub widths: Vec<u32>,
    /// Stream-array groups: channel indices per group (singletons for
    /// ungrouped channels).
    pub groups: Vec<Vec<usize>>,
    /// Per-group candidate depths (breakpoints of the group's widest
    /// member at the group's largest bound, floored at the group's
    /// largest member floor).
    pub per_group: Vec<Vec<u32>>,
}

impl Space {
    /// Build the pruned space for a trace (bounds collapsed by the
    /// analytic depth-bounds pass).
    pub fn from_trace(trace: &Trace) -> Space {
        let widths: Vec<u32> = trace.channels.iter().map(|c| c.width_bits).collect();
        let b = DepthBounds::for_trace(trace);
        Self::build(trace.upper_bounds(), Some(&b), widths, trace.groups())
    }

    /// [`from_trace`](Self::from_trace) without the analytic collapse —
    /// the PR 7 space, kept for A/B measurement (§Perf 11).
    pub fn from_trace_unbounded(trace: &Trace) -> Space {
        let widths: Vec<u32> = trace.channels.iter().map(|c| c.width_bits).collect();
        Self::build(trace.upper_bounds(), None, widths, trace.groups())
    }

    /// Build the pruned space for a multi-trace
    /// [`Workload`](crate::trace::workload::Workload): bounds are the
    /// merged (max-over-scenarios) upper bounds and analytic bounds,
    /// topology from the primary scenario. For single-scenario workloads
    /// this equals [`from_trace`](Self::from_trace) on the trace.
    pub fn from_workload(workload: &crate::trace::workload::Workload) -> Space {
        let primary = workload.primary();
        let widths: Vec<u32> = primary.channels.iter().map(|c| c.width_bits).collect();
        let b = DepthBounds::for_workload(workload);
        Self::build(workload.upper_bounds(), Some(&b), widths, primary.groups())
    }

    /// [`from_workload`](Self::from_workload) without the analytic
    /// collapse (the PR 7 space).
    pub fn from_workload_unbounded(workload: &crate::trace::workload::Workload) -> Space {
        let primary = workload.primary();
        let widths: Vec<u32> = primary.channels.iter().map(|c| c.width_bits).collect();
        Self::build(workload.upper_bounds(), None, widths, primary.groups())
    }

    fn build(
        uppers: Vec<u32>,
        depth_bounds: Option<&DepthBounds>,
        widths: Vec<u32>,
        groups: Vec<Vec<usize>>,
    ) -> Space {
        let n = uppers.len();
        let bounds: Vec<u32> = match depth_bounds {
            Some(b) => uppers
                .iter()
                .zip(&b.caps)
                .map(|(&u, &c)| u.min(c).max(2))
                .collect(),
            None => uppers,
        };
        let floors: Vec<u32> = match depth_bounds {
            Some(b) => b
                .floors
                .iter()
                .zip(&bounds)
                .map(|(&f, &u)| f.min(u.max(2)))
                .collect(),
            None => vec![0; n],
        };
        let per_fifo: Vec<Vec<u32>> = bounds
            .iter()
            .zip(&widths)
            .zip(&floors)
            .map(|((&u, &w), &f)| floored_candidates(w, u, f))
            .collect();
        let per_group = groups
            .iter()
            .map(|ids| {
                let u = ids.iter().map(|&i| bounds[i]).max().unwrap();
                let w = ids.iter().map(|&i| widths[i]).max().unwrap();
                let f = ids.iter().map(|&i| floors[i]).max().unwrap();
                floored_candidates(w, u, f.min(u.max(2)))
            })
            .collect();
        Space {
            per_fifo,
            bounds,
            floors,
            widths,
            groups,
            per_group,
        }
    }

    /// Number of channels.
    pub fn num_fifos(&self) -> usize {
        self.per_fifo.len()
    }

    /// Effective per-channel search minimum: `max(2, floors[i])`.
    #[inline]
    pub fn min_depth(&self, i: usize) -> u32 {
        self.floors[i].max(2)
    }

    /// log10 of the pruned per-FIFO space size (design-space cardinality
    /// diagnostic; the raw space is Π(uᵢ - 1)).
    pub fn log10_size(&self) -> f64 {
        self.per_fifo.iter().map(|c| (c.len() as f64).log10()).sum()
    }

    /// Clamp an arbitrary depth vector into bounds (≥ max(2, floor),
    /// ≤ uᵢ).
    pub fn clamp(&self, depths: &mut [u32]) {
        for (i, d) in depths.iter_mut().enumerate() {
            let hi = self.bounds[i].max(2);
            *d = (*d).clamp(self.min_depth(i).min(hi), hi);
        }
    }

    /// Expand per-group depths into a full per-channel configuration
    /// (each member clamped to its own floor/bound).
    pub fn expand_group_depths(&self, group_depths: &[u32]) -> Vec<u32> {
        assert_eq!(group_depths.len(), self.groups.len());
        let mut out = vec![2u32; self.num_fifos()];
        for (g, ids) in self.groups.iter().enumerate() {
            for &i in ids {
                let hi = self.bounds[i].max(2);
                out[i] = group_depths[g].clamp(self.min_depth(i).min(hi), hi);
            }
        }
        out
    }
}

/// The candidate list for one dimension: the BRAM plateau breakpoints in
/// `[lo, u]` with the floor itself prepended when it is not a breakpoint
/// (`lo = max(2, floor)`).
fn floored_candidates(width: u32, upper: u32, floor: u32) -> Vec<u32> {
    let lo = floor.max(2).min(upper.max(2));
    let mut c: Vec<u32> = candidate_depths(width, upper)
        .into_iter()
        .filter(|&d| d >= lo)
        .collect();
    if c.first() != Some(&lo) {
        c.insert(0, lo);
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::trace::collect_trace;

    fn space_for(name: &str) -> Space {
        let bd = bench_suite::build(name);
        let t = collect_trace(&bd.design, &bd.args).unwrap();
        Space::from_trace(&t)
    }

    #[test]
    fn candidates_bounded_and_sorted() {
        let s = space_for("gemm");
        assert_eq!(s.num_fifos(), 84);
        for (i, (c, &u)) in s.per_fifo.iter().zip(&s.bounds).enumerate() {
            assert_eq!(c[0], s.min_depth(i).min(u.max(2)));
            assert_eq!(*c.last().unwrap(), u.max(2));
            assert!(c.windows(2).all(|w| w[0] < w[1]));
        }
    }

    #[test]
    fn pruning_shrinks_space_dramatically() {
        let s = space_for("k2mm");
        let raw: f64 = s
            .bounds
            .iter()
            .map(|&u| ((u.max(3) - 1) as f64).log10())
            .sum();
        assert!(
            s.log10_size() < raw / 2.0,
            "pruned 10^{:.1} vs raw 10^{:.1}",
            s.log10_size(),
            raw
        );
    }

    #[test]
    fn groups_share_candidates() {
        let s = space_for("FeedForward");
        assert!(s.groups.len() < s.num_fifos());
        assert_eq!(s.groups.len(), s.per_group.len());
        let cfg = s.expand_group_depths(&vec![2; s.groups.len()]);
        for (i, &d) in cfg.iter().enumerate() {
            assert_eq!(d, s.min_depth(i).min(s.bounds[i].max(2)));
        }
        let maxes: Vec<u32> = s
            .groups
            .iter()
            .enumerate()
            .map(|(g, _)| *s.per_group[g].last().unwrap())
            .collect();
        let cfg = s.expand_group_depths(&maxes);
        for (i, &d) in cfg.iter().enumerate() {
            assert!(d >= 2 && d <= s.bounds[i].max(2));
        }
    }

    #[test]
    fn workload_space_merges_bounds() {
        use crate::trace::workload::Workload;
        let bd = bench_suite::build("fig2");
        let scen: Vec<(String, Vec<i64>)> = [8i64, 16]
            .iter()
            .map(|&n| (format!("n{n}"), vec![n]))
            .collect();
        let w = Workload::from_design(&bd.design, &scen).unwrap();
        let s = Space::from_workload(&w);
        // Bounds come from the larger scenario (n = 16 writes per chan).
        assert_eq!(s.bounds, vec![16, 16]);
        // ...and so do the floors (x deadlocks below 15 at n = 16).
        assert_eq!(s.floors, vec![15, 1]);
        // A single-scenario workload space equals the trace space.
        let w1 = Workload::from_design(&bd.design, &scen[..1]).unwrap();
        let t = w1.primary().clone();
        let sw = Space::from_workload(&w1);
        let st = Space::from_trace(&t);
        assert_eq!(sw.bounds, st.bounds);
        assert_eq!(sw.floors, st.floors);
        assert_eq!(sw.per_fifo, st.per_fifo);
        assert_eq!(sw.groups, st.groups);
    }

    #[test]
    fn floors_collapse_fig2_candidates() {
        let s = space_for("fig2");
        // x floors at 15 (not a BRAM breakpoint — prepended), y is free.
        assert_eq!(s.per_fifo[0], vec![15, 16]);
        assert_eq!(s.per_fifo[0][0], s.min_depth(0));
        assert!(s.per_fifo[1].contains(&2));
        // The unbounded space still starts every dimension at 2.
        let bd = bench_suite::build("fig2");
        let t = collect_trace(&bd.design, &bd.args).unwrap();
        let u = Space::from_trace_unbounded(&t);
        assert_eq!(u.floors, vec![0, 0]);
        assert_eq!(u.per_fifo[0][0], 2);
        // Clamping pulls sub-floor depths up to the floor.
        let mut cfg = vec![2u32, 2];
        s.clamp(&mut cfg);
        assert_eq!(cfg, vec![15, 2]);
    }

    #[test]
    fn clamp_respects_bounds() {
        let s = space_for("bicg");
        let mut cfg = vec![0u32; s.num_fifos()];
        s.clamp(&mut cfg);
        for (i, &d) in cfg.iter().enumerate() {
            assert_eq!(d, s.min_depth(i).min(s.bounds[i].max(2)));
        }
        let mut cfg = vec![u32::MAX; s.num_fifos()];
        s.clamp(&mut cfg);
        for (i, &d) in cfg.iter().enumerate() {
            assert_eq!(d, s.bounds[i].max(2));
        }
    }
}
