//! The Vitis-style deadlock hunter (Fig. 1 left of the paper): start from
//! minimal FIFOs and repeatedly re-simulate with doubled sizes until the
//! design stops deadlocking. It finds *one feasible* configuration, not a
//! frontier — included as the comparison baseline and for the
//! deadlock-rescue example.

use super::{Optimizer, Space};
use crate::dse::Evaluator;

pub struct VitisHunter {
    /// Double only FIFOs implicated in the deadlock (true, smarter than
    /// stock Vitis) or all FIFOs (false, the stock behaviour).
    pub targeted: bool,
}

impl VitisHunter {
    pub fn new() -> VitisHunter {
        VitisHunter { targeted: false }
    }

    pub fn targeted() -> VitisHunter {
        VitisHunter { targeted: true }
    }

    /// Run the hunt; returns the first feasible configuration found.
    pub fn hunt(&self, ev: &mut Evaluator, space: &Space, budget: usize) -> Option<Box<[u32]>> {
        let trace = ev.trace().clone();
        let mut cur: Vec<u32> = trace.baseline_min();
        for _ in 0..budget.max(1) {
            // Identify the deadlock (needs block info → direct sim).
            let (lat, _) = ev.eval(&cur);
            if lat.is_some() {
                return Some(cur.into());
            }
            // Double and clamp.
            if self.targeted {
                // Re-simulate once more via stats to find write-blocked
                // channels (the evaluator's cached latency has no block
                // info; this is the baseline tool, efficiency secondary).
                let (out, _) = ev.eval_with_stats(&cur);
                if let crate::sim::fast::SimOutcome::Deadlock { blocked } = out {
                    for b in &blocked {
                        if b.on_write {
                            cur[b.channel] =
                                (cur[b.channel] * 2).min(space.bounds[b.channel].max(2));
                        }
                    }
                } else {
                    return Some(cur.into());
                }
            } else {
                for (d, &u) in cur.iter_mut().zip(&space.bounds) {
                    *d = (*d * 2).min(u.max(2));
                }
            }
            // Bail out if saturated (cannot grow further).
            if cur
                .iter()
                .zip(&space.bounds)
                .all(|(&d, &u)| d >= u.max(2))
            {
                let (lat, _) = ev.eval(&cur);
                return lat.map(|_| cur.into());
            }
        }
        None
    }
}

impl Default for VitisHunter {
    fn default() -> Self {
        Self::new()
    }
}

impl Optimizer for VitisHunter {
    fn name(&self) -> &'static str {
        "vitis_hunter"
    }

    fn run(&mut self, ev: &mut Evaluator, space: &Space, budget: usize) {
        let _ = self.hunt(ev, space, budget);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    fn setup(name: &str) -> (Evaluator, Space) {
        let bd = bench_suite::build(name);
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&t);
        (Evaluator::new(t), space)
    }

    #[test]
    fn hunts_fig2_to_feasibility() {
        let (mut ev, space) = setup("fig2");
        let cfg = VitisHunter::new().hunt(&mut ev, &space, 100).unwrap();
        let (lat, _) = ev.eval(&cfg);
        assert!(lat.is_some());
        // Stock doubling overshoots: x ends ≥ the n-1 threshold.
        assert!(cfg[0] >= 15);
    }

    #[test]
    fn targeted_hunts_flowgnn() {
        let (mut ev, space) = setup("flowgnn_pna");
        let cfg = VitisHunter::targeted().hunt(&mut ev, &space, 200).unwrap();
        let (lat, _) = ev.eval(&cfg);
        assert!(lat.is_some());
        // Only the burst-buffering msg FIFOs needed to grow.
        let lanes = crate::bench_suite::flowgnn::LANES;
        assert!(cfg[..lanes].iter().any(|&d| d > 2));
    }

    #[test]
    fn already_feasible_design_returns_immediately() {
        let (mut ev, space) = setup("bicg");
        let cfg = VitisHunter::new().hunt(&mut ev, &space, 100);
        if let Some(c) = cfg {
            // bicg at depth 2 everywhere is feasible → unchanged.
            if ev.history[0].is_feasible() {
                assert!(c.iter().all(|&d| d == 2));
            }
        }
    }
}
