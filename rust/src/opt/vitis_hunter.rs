//! The Vitis-style deadlock hunter (Fig. 1 left of the paper): start from
//! minimal FIFOs (the space's per-channel search minimum — the analytic
//! deadlock floor where one exists, so no round is wasted on proven
//! deadlocks) and repeatedly re-simulate with doubled sizes until the
//! design stops deadlocking. It finds *one feasible* configuration, not a
//! frontier — included as the comparison baseline and for the
//! deadlock-rescue example.
//!
//! Ask/tell: one configuration per round (the hunt is inherently
//! sequential). The targeted variant requests stats evaluations so each
//! round's deadlock block info arrives with the result — the old
//! imperative version needed a second simulation per round for that.

use super::{AskCtx, Optimizer, Space};
use crate::dse::{drive, EvalEngine, EvalResult};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Fresh,
    Running,
    /// All FIFOs saturated at their bounds: one last evaluation decides.
    LastChance,
    Done,
}

pub struct VitisHunter {
    /// Double only FIFOs implicated in the deadlock (true, smarter than
    /// stock Vitis) or all FIFOs (false, the stock behaviour).
    pub targeted: bool,
    phase: Phase,
    cur: Vec<u32>,
    bounds: Vec<u32>,
    iters_left: usize,
    found: Option<Box<[u32]>>,
    /// The previous round's proposal — the locality hint for the next
    /// one (each round is a doubling of the last).
    last_proposed: Option<Box<[u32]>>,
    hint_buf: Vec<Option<Box<[u32]>>>,
}

impl VitisHunter {
    pub fn new() -> VitisHunter {
        Self::with_targeting(false)
    }

    pub fn targeted() -> VitisHunter {
        Self::with_targeting(true)
    }

    fn with_targeting(targeted: bool) -> VitisHunter {
        VitisHunter {
            targeted,
            phase: Phase::Fresh,
            cur: Vec::new(),
            bounds: Vec::new(),
            iters_left: 0,
            found: None,
            last_proposed: None,
            hint_buf: Vec::new(),
        }
    }

    /// The feasible configuration the hunt ended on, if any.
    pub fn found(&self) -> Option<&[u32]> {
        self.found.as_deref()
    }

    /// Run the hunt against an engine; returns the first feasible
    /// configuration found.
    pub fn hunt(
        &self,
        engine: &mut EvalEngine,
        space: &Space,
        budget: usize,
    ) -> Option<Box<[u32]>> {
        let mut fresh = Self::with_targeting(self.targeted);
        drive(&mut fresh, engine, space, budget);
        fresh.found
    }

    fn saturated(&self) -> bool {
        self.cur
            .iter()
            .zip(&self.bounds)
            .all(|(&d, &u)| d >= u.max(2))
    }
}

impl Default for VitisHunter {
    fn default() -> Self {
        Self::new()
    }
}

impl Optimizer for VitisHunter {
    fn name(&self) -> &'static str {
        "vitis_hunter"
    }

    fn ask(&mut self, ctx: &AskCtx) -> Vec<Box<[u32]>> {
        self.hint_buf.clear();
        match self.phase {
            Phase::Fresh => {
                self.bounds = ctx.space.bounds.clone();
                // Baseline-Min, floored at the analytic bounds.
                self.cur = (0..ctx.space.num_fifos())
                    .map(|i| ctx.space.min_depth(i).min(ctx.space.bounds[i].max(2)))
                    .collect();
                self.iters_left = ctx.budget_left.max(1);
                self.phase = Phase::Running;
                let prop: Box<[u32]> = self.cur.clone().into();
                self.hint_buf.push(None);
                self.last_proposed = Some(prop.clone());
                vec![prop]
            }
            Phase::Running | Phase::LastChance => {
                let prop: Box<[u32]> = self.cur.clone().into();
                self.hint_buf.push(self.last_proposed.clone());
                self.last_proposed = Some(prop.clone());
                vec![prop]
            }
            Phase::Done => Vec::new(),
        }
    }

    fn hints(&self) -> Vec<Option<Box<[u32]>>> {
        self.hint_buf.clone()
    }

    fn tell(&mut self, results: &[EvalResult]) {
        let r = match results.first() {
            Some(r) => r,
            None => return,
        };
        if r.latency.is_some() {
            self.found = Some(self.cur.clone().into());
            self.phase = Phase::Done;
            return;
        }
        if self.phase == Phase::LastChance {
            self.phase = Phase::Done;
            return;
        }
        self.iters_left = self.iters_left.saturating_sub(1);
        if self.iters_left == 0 {
            self.phase = Phase::Done;
            return;
        }
        // Double and clamp.
        if self.targeted {
            for b in &r.blocked {
                if b.on_write {
                    self.cur[b.channel] =
                        (self.cur[b.channel] * 2).min(self.bounds[b.channel].max(2));
                }
            }
        } else {
            for (d, &u) in self.cur.iter_mut().zip(&self.bounds) {
                *d = (*d * 2).min(u.max(2));
            }
        }
        if self.saturated() {
            // Cannot grow further: one final evaluation decides.
            self.phase = Phase::LastChance;
        }
    }

    fn done(&self) -> bool {
        self.phase == Phase::Done
    }

    fn wants_stats(&self) -> bool {
        // Targeted doubling needs the per-round deadlock block info.
        self.targeted && self.phase != Phase::Done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_suite;
    use crate::dse::Evaluator;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    fn setup(name: &str) -> (Evaluator, Space) {
        let bd = bench_suite::build(name);
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let space = Space::from_trace(&t);
        (Evaluator::new(t), space)
    }

    #[test]
    fn hunts_fig2_to_feasibility() {
        let (mut ev, space) = setup("fig2");
        let cfg = VitisHunter::new().hunt(&mut ev, &space, 100).unwrap();
        let (lat, _) = ev.eval(&cfg);
        assert!(lat.is_some());
        // Stock doubling overshoots: x ends ≥ the n-1 threshold.
        assert!(cfg[0] >= 15);
    }

    #[test]
    fn targeted_hunts_flowgnn() {
        let (mut ev, space) = setup("flowgnn_pna");
        let cfg = VitisHunter::targeted().hunt(&mut ev, &space, 200).unwrap();
        let (lat, _) = ev.eval(&cfg);
        assert!(lat.is_some());
        // Only the burst-buffering msg FIFOs needed to grow.
        let lanes = crate::bench_suite::flowgnn::LANES;
        assert!(cfg[..lanes].iter().any(|&d| d > 2));
    }

    #[test]
    fn already_feasible_design_returns_immediately() {
        let (mut ev, space) = setup("bicg");
        let cfg = VitisHunter::new().hunt(&mut ev, &space, 100);
        if let Some(c) = cfg {
            // bicg at depth 2 everywhere is feasible → unchanged.
            if ev.history[0].is_feasible() {
                assert!(c.iter().all(|&d| d == 2));
            }
        }
    }
}
