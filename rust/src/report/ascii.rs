//! Terminal plots: scatter (Pareto frontiers, Fig. 3/6-style) and step
//! lines (convergence, Fig. 5-style). Pure text, fixed-size canvas.

/// A labelled point series.
pub struct Series<'a> {
    pub label: char,
    pub points: &'a [(f64, f64)],
}

/// Render a scatter plot of several series onto a `width`×`height` char
/// canvas with simple linear axes. Returns the multi-line string.
pub fn scatter(series: &[Series], width: usize, height: usize, x_label: &str, y_label: &str) -> String {
    let all: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.points.iter().copied())
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    if all.is_empty() {
        return String::from("(no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &all {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if x1 <= x0 {
        x1 = x0 + 1.0;
    }
    if y1 <= y0 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for s in series {
        for &(x, y) in s.points {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let cx = (((x - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let cy = (((y - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            let col = cx.min(width - 1);
            grid[row][col] = s.label;
        }
    }
    let mut out = String::new();
    out.push_str(&format!("  {y_label}  [{y0:.0} .. {y1:.0}]\n"));
    for row in grid {
        out.push_str("  |");
        out.extend(row);
        out.push('\n');
    }
    out.push_str("  +");
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("   {x_label}  [{x0:.0} .. {x1:.0}]\n"));
    out
}

/// Render best-so-far step curves (x = time, y = score) for Fig. 5-style
/// convergence comparisons. Input series need not be sorted.
pub fn convergence(series: &[Series], width: usize, height: usize) -> String {
    // Convert each series to a running-minimum staircase sampled on the
    // common time grid, then scatter it.
    let t_max = series
        .iter()
        .flat_map(|s| s.points.iter().map(|p| p.0))
        .fold(0.0_f64, f64::max)
        .max(1e-9);
    let mut stair_storage: Vec<Vec<(f64, f64)>> = Vec::new();
    for s in series {
        let mut pts: Vec<(f64, f64)> = s.points.iter().copied().filter(|p| p.1.is_finite()).collect();
        pts.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut best = f64::INFINITY;
        let mut stair = Vec::new();
        for (t, v) in pts {
            best = best.min(v);
            stair.push((t, best));
        }
        if let Some(&(_, last)) = stair.last() {
            stair.push((t_max, last));
        }
        stair_storage.push(stair);
    }
    let stair_series: Vec<Series> = series
        .iter()
        .zip(&stair_storage)
        .map(|(s, pts)| Series {
            label: s.label,
            points: pts,
        })
        .collect();
    scatter(&stair_series, width, height, "time (s)", "best score")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scatter_renders_points() {
        let pts = [(0.0, 0.0), (10.0, 5.0), (5.0, 2.5)];
        let s = scatter(
            &[Series {
                label: 'o',
                points: &pts,
            }],
            40,
            10,
            "lat",
            "bram",
        );
        assert_eq!(s.matches('o').count(), 3);
        assert!(s.contains("lat"));
        assert!(s.contains("bram"));
    }

    #[test]
    fn empty_series_is_safe() {
        assert_eq!(scatter(&[], 10, 5, "x", "y"), "(no data)\n");
        let s: [Series; 1] = [Series {
            label: 'x',
            points: &[],
        }];
        assert_eq!(scatter(&s, 10, 5, "x", "y"), "(no data)\n");
    }

    #[test]
    fn convergence_is_monotone_staircase() {
        let pts = [(0.1, 10.0), (0.2, 12.0), (0.3, 7.0), (0.5, 9.0)];
        let out = convergence(
            &[Series {
                label: '*',
                points: &pts,
            }],
            30,
            8,
        );
        assert!(out.contains('*'));
    }

    #[test]
    fn nonfinite_points_skipped() {
        let pts = [(0.0, f64::INFINITY), (1.0, 1.0)];
        let s = scatter(
            &[Series {
                label: 'o',
                points: &pts,
            }],
            20,
            5,
            "x",
            "y",
        );
        assert_eq!(s.matches('o').count(), 1);
    }
}
