//! Minimal CSV writer (RFC-4180 quoting) for experiment outputs.

/// Accumulates rows and renders/writes CSV text.
pub struct Csv {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(headers: &[&str]) -> Csv {
        Csv {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "csv row arity mismatch");
        self.rows.push(cells);
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let write_row = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if c.contains(',') || c.contains('"') || c.contains('\n') {
                    out.push('"');
                    out.push_str(&c.replace('"', "\"\""));
                    out.push('"');
                } else {
                    out.push_str(c);
                }
            }
            out.push('\n');
        };
        write_row(&mut out, &self.headers);
        for r in &self.rows {
            write_row(&mut out, r);
        }
        out
    }

    pub fn write(&self, path: &str) -> std::io::Result<()> {
        super::write_file(path, &self.to_string())
    }

    /// Accumulated rows (used by the JSON perf-snapshot emitters).
    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }

    /// Header names.
    pub fn headers(&self) -> &[String] {
        &self.headers
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_with_quoting() {
        let mut c = Csv::new(&["name", "value"]);
        c.row(vec!["plain".into(), "1".into()]);
        c.row(vec!["has,comma".into(), "has\"quote".into()]);
        let s = c.to_string();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "name,value");
        assert_eq!(lines[1], "plain,1");
        assert_eq!(lines[2], "\"has,comma\",\"has\"\"quote\"");
        assert_eq!(c.len(), 2);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        let mut c = Csv::new(&["a", "b"]);
        c.row(vec!["only-one".into()]);
    }
}
