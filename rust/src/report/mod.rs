//! Result emission: CSV files, JSON run records, markdown tables, and
//! terminal ASCII plots (scatter for Pareto frontiers, step lines for
//! convergence curves) — everything the table/figure benches print.

pub mod ascii;
pub mod csv;

use crate::dse::{EvalEngine, EvalPoint};
use crate::util::Json;

/// Serialize an evaluation point.
pub fn point_to_json(p: &EvalPoint) -> Json {
    Json::obj(vec![
        (
            "depths",
            Json::Arr(p.depths.iter().map(|&d| Json::Num(d as f64)).collect()),
        ),
        (
            "latency",
            match p.latency {
                Some(l) => Json::Num(l as f64),
                None => Json::Null,
            },
        ),
        ("bram", Json::Num(p.bram as f64)),
        ("t", Json::Num(p.t)),
    ])
}

/// Clamp a derived rate to a finite value for emission. An instant
/// memo-only run (every proposal a cache hit, elapsed ≈ 0) can push a
/// rate to NaN or ±inf; those serialize as `null` in JSON and as
/// `"NaN"`/`"inf"` in CSV, breaking downstream numeric parsers. Raw
/// counters are never clamped — only derived rates route through here.
pub fn finite_or_zero(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

/// Serialize the evaluation-engine counters (cache hit rate, sims/sec,
/// worker utilization, incremental-replay telemetry) for run records and
/// diagnostics. Every derived rate passes through [`finite_or_zero`].
pub fn engine_stats_to_json(engine: &EvalEngine) -> Json {
    let s = engine.stats();
    Json::obj(vec![
        ("jobs", Json::Num(engine.jobs() as f64)),
        ("sim_backend", Json::Str(engine.sim_backend().name().into())),
        ("cache_shards", Json::Num(engine.cache_shards() as f64)),
        ("proposals", Json::Num(s.proposals as f64)),
        ("cache_hits", Json::Num(s.cache_hits as f64)),
        ("cache_hit_rate", Json::Num(finite_or_zero(s.hit_rate()))),
        ("batches", Json::Num(s.batches as f64)),
        ("sims", Json::Num(s.sims as f64)),
        ("sims_per_sec", Json::Num(finite_or_zero(engine.sims_per_sec()))),
        (
            "proposals_per_sec",
            Json::Num(finite_or_zero(engine.proposals_per_sec())),
        ),
        (
            "worker_utilization",
            Json::Num(finite_or_zero(engine.worker_utilization())),
        ),
        ("prune", Json::Bool(engine.prune())),
        ("oracle_hits", Json::Num(s.oracle_hits as f64)),
        ("oracle_rate", Json::Num(finite_or_zero(s.oracle_rate()))),
        ("clamp_hits", Json::Num(s.clamp_hits as f64)),
        ("clamp_rate", Json::Num(finite_or_zero(s.clamp_rate()))),
        ("sims_avoided", Json::Num(s.sims_avoided as f64)),
        ("bounds", Json::Bool(engine.bounds())),
        ("bounds_floor_hits", Json::Num(s.bounds_floor_hits as f64)),
        ("cap_tightenings", Json::Num(s.cap_tightenings as f64)),
        ("incremental_sims", Json::Num(s.incr_sims as f64)),
        (
            "incremental_rate",
            Json::Num(finite_or_zero(s.incremental_rate())),
        ),
        (
            "dirty_channels_per_incremental_sim",
            Json::Num(finite_or_zero(s.dirty_per_incremental())),
        ),
        ("replayed_ops", Json::Num(s.replayed_ops as f64)),
        ("replayable_ops", Json::Num(s.replayable_ops as f64)),
        (
            "replay_fraction",
            Json::Num(finite_or_zero(s.replay_fraction())),
        ),
        ("scenarios", Json::Num(engine.num_scenarios() as f64)),
        (
            "scenario_names",
            Json::Arr(
                engine
                    .scenario_names()
                    .iter()
                    .map(|n| Json::Str(n.clone()))
                    .collect(),
            ),
        ),
        ("scenario_sims", Json::Num(s.scenario_sims as f64)),
        (
            "robustness_gap_mean",
            Json::Num(finite_or_zero(s.mean_robustness_gap())),
        ),
        ("batch_walks", Json::Num(s.batch_walks as f64)),
        ("lanes_packed", Json::Num(s.lanes_packed as f64)),
        ("lanes_per_walk", Json::Num(finite_or_zero(s.lanes_per_walk()))),
        (
            "batch_occupancy",
            Json::Num(finite_or_zero(s.batch_occupancy())),
        ),
        ("walks_saved", Json::Num(s.walks_saved() as f64)),
    ])
}

/// One-line human-readable engine summary for CLI output.
pub fn engine_stats_line(engine: &EvalEngine) -> String {
    let s = engine.stats();
    let scenarios = if engine.num_scenarios() > 1 {
        format!(
            ", {} scenarios ({} scenario-sims, mean robustness gap {:.0} cycles)",
            engine.num_scenarios(),
            s.scenario_sims,
            s.mean_robustness_gap()
        )
    } else {
        String::new()
    };
    let pruning = if engine.prune() {
        format!(
            ", pruning: {:.0}% oracle / {:.0}% clamp, {} sims avoided",
            finite_or_zero(s.oracle_rate()) * 100.0,
            finite_or_zero(s.clamp_rate()) * 100.0,
            s.sims_avoided
        )
    } else {
        ", pruning off".into()
    };
    let bounds = if engine.bounds() {
        format!(
            ", bounds: {} floor hits, {} caps tightened",
            s.bounds_floor_hits, s.cap_tightenings
        )
    } else {
        ", bounds off".into()
    };
    let backend = match engine.sim_backend() {
        crate::sim::BackendKind::Fast => String::new(),
        other => format!(", {} backend", other.name()),
    };
    let lanes = if s.batch_walks > 0 {
        format!(
            ", lane batching: {:.1} lanes/walk at {:.0}% occupancy, {} walks saved",
            finite_or_zero(s.lanes_per_walk()),
            finite_or_zero(s.batch_occupancy()) * 100.0,
            s.walks_saved()
        )
    } else {
        String::new()
    };
    format!(
        "{} jobs / {} cache shards: {:.1}% cache hits, {:.0} sims/s ({:.0} proposals/s), \
         {:.0}% worker utilization, \
         {:.0}% incremental ({:.1} dirty ch/sim, {:.1}% ops replayed)\
         {backend}{lanes}{pruning}{bounds}{scenarios}",
        engine.jobs(),
        engine.cache_shards(),
        finite_or_zero(s.hit_rate()) * 100.0,
        finite_or_zero(engine.sims_per_sec()),
        finite_or_zero(engine.proposals_per_sec()),
        finite_or_zero(engine.worker_utilization()) * 100.0,
        finite_or_zero(s.incremental_rate()) * 100.0,
        finite_or_zero(s.dirty_per_incremental()),
        finite_or_zero(s.replay_fraction()) * 100.0
    )
}

/// Serialize a full run (design, optimizer, history, front) for the
/// results directory. Pass the engine to embed its counters.
#[allow(clippy::too_many_arguments)]
pub fn run_to_json(
    design: &str,
    optimizer: &str,
    seed: u64,
    budget: usize,
    history: &[EvalPoint],
    front: &[&EvalPoint],
    elapsed_secs: f64,
    engine: Option<&EvalEngine>,
) -> Json {
    let mut fields = vec![
        ("design", Json::Str(design.into())),
        ("optimizer", Json::Str(optimizer.into())),
        ("seed", Json::Num(seed as f64)),
        ("budget", Json::Num(budget as f64)),
        ("elapsed_secs", Json::Num(elapsed_secs)),
        ("evals", Json::Num(history.len() as f64)),
        (
            "front",
            Json::Arr(front.iter().map(|p| point_to_json(p)).collect()),
        ),
    ];
    if let Some(e) = engine {
        fields.push(("truncated", Json::Bool(e.truncated())));
        fields.push(("engine", engine_stats_to_json(e)));
    }
    Json::obj(fields)
}

/// Render a markdown table.
pub fn markdown_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str("| ");
    out.push_str(&headers.join(" | "));
    out.push_str(" |\n|");
    for _ in headers {
        out.push_str("---|");
    }
    out.push('\n');
    for row in rows {
        out.push_str("| ");
        out.push_str(&row.join(" | "));
        out.push_str(" |\n");
    }
    out
}

/// Write a string to a file, creating parent directories. Delegates to
/// [`crate::util::atomic_write`], so every artifact routed through here
/// (run records, workload JSON, CSV tables, sweep manifests) is
/// crash-safe: readers see the old file or the new file, never a
/// truncated one.
pub fn write_file(path: &str, contents: &str) -> std::io::Result<()> {
    crate::util::atomic_write(path, contents)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_shape() {
        let t = markdown_table(
            &["a", "b"],
            &[vec!["1".into(), "2".into()], vec!["3".into(), "4".into()]],
        );
        assert_eq!(t.lines().count(), 4);
        assert!(t.contains("| 1 | 2 |"));
    }

    #[test]
    fn instant_memo_only_run_serializes_finite_rates() {
        assert_eq!(finite_or_zero(f64::NAN), 0.0);
        assert_eq!(finite_or_zero(f64::INFINITY), 0.0);
        assert_eq!(finite_or_zero(f64::NEG_INFINITY), 0.0);
        assert_eq!(finite_or_zero(1.5), 1.5);

        // A run answered entirely from the memo cache finishes with zero
        // sims in (close to) zero elapsed time — the degenerate inputs
        // behind NaN/inf rates. Every derived rate must still land in
        // the JSON as a plain finite number, never null.
        let bd = crate::bench_suite::build("fig2");
        let w =
            crate::trace::workload::Workload::from_design_args(&bd.design, &[vec![16]]).unwrap();
        let mut warm = EvalEngine::for_workload(std::sync::Arc::new(w), 1);
        let depths = warm.workload().baseline_max();
        warm.eval(&depths);
        let memo = warm.memo_entries();
        let mut ev = EvalEngine::for_workload(warm.workload().clone(), 1);
        assert!(ev.import_memo(&memo) > 0);
        ev.reset_run(false);
        ev.eval(&depths); // pure memo hit: zero sims this run
        assert_eq!(ev.stats().sims, 0);
        let j = engine_stats_to_json(&ev);
        let text = j.to_string_compact();
        assert!(
            !text.contains("null"),
            "a rate serialized as null (non-finite leaked through): {text}"
        );
        for key in [
            "cache_hit_rate",
            "sims_per_sec",
            "proposals_per_sec",
            "worker_utilization",
            "lanes_per_walk",
            "batch_occupancy",
            "robustness_gap_mean",
        ] {
            let v = j.get(key).and_then(Json::as_f64).unwrap();
            assert!(v.is_finite(), "{key} must be finite, got {v}");
        }
    }

    #[test]
    fn run_json_roundtrips() {
        let p = EvalPoint {
            depths: vec![2, 16].into(),
            latency: Some(100),
            bram: 3,
            t: 0.5,
        };
        let dead = EvalPoint {
            depths: vec![2, 2].into(),
            latency: None,
            bram: 0,
            t: 0.6,
        };
        let hist = vec![p.clone(), dead];
        let front = vec![&hist[0]];
        let j = run_to_json("fig2", "greedy", 1, 100, &hist, &front, 1.25, None);
        let text = j.to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed.get("design").unwrap().as_str(), Some("fig2"));
        assert_eq!(
            parsed.get("front").unwrap().as_arr().unwrap()[0]
                .get("latency")
                .unwrap()
                .as_u64(),
            Some(100)
        );
    }
}
