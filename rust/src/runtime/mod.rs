//! The PJRT runtime: loads the AOT-compiled JAX/Pallas analytics
//! artifacts (`artifacts/analytics_f*.hlo.txt`, produced once by
//! `python/compile/aot.py`) and executes them from the Rust DSE hot path.
//! Python is never invoked at runtime — the HLO text is parsed, compiled
//! and run entirely through the `xla` crate's PJRT CPU client.
//!
//! The exported module computes, for a fixed-shape batch
//! `(depths[B,F], widths[F], latencies[B], betas[K])`:
//! per-config BRAM totals, the β-grid weighted objectives, and the Pareto
//! dominance mask (see `python/compile/model.py`). Designs are padded to
//! the next FIFO-count bucket; batches are padded/chunked to `B`.

use crate::dse::BramBatch;
use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// Padding conventions shared with `python/compile/model.py`.
const PAD_DEPTH: i32 = 2;
const PAD_WIDTH: i32 = 1;

/// One compiled shape bucket.
struct Bucket {
    fifos: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// Result of one batched analytics execution.
#[derive(Debug, Clone)]
pub struct AnalyticsOut {
    /// Per-configuration total BRAM (valid prefix only).
    pub bram_totals: Vec<u32>,
    /// Row-major (K, valid) weighted objectives.
    pub scores: Vec<Vec<f64>>,
    /// Dominance mask over the batch (valid prefix only; padding masked).
    pub dominated: Vec<bool>,
}

/// The loaded artifact set.
pub struct BatchAnalytics {
    client: xla::PjRtClient,
    buckets: Vec<Bucket>,
    /// Fixed batch rows per execution (export-time constant).
    pub batch: usize,
    /// Fixed β-grid length (export-time constant).
    pub betas: usize,
    /// Calls executed (for perf reporting).
    pub calls: u64,
}

impl BatchAnalytics {
    /// Load every bucket listed in `<dir>/manifest.json` and compile them
    /// on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<BatchAnalytics> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} (run `make artifacts`)"))?;
        let manifest = Json::parse(&text).context("parsing manifest.json")?;
        let buckets_json = manifest
            .get("buckets")
            .and_then(|b| b.as_arr())
            .ok_or_else(|| anyhow!("manifest.json: missing buckets"))?;

        let client = xla::PjRtClient::cpu()?;
        let mut buckets = Vec::new();
        let mut batch = 0usize;
        let mut betas = 0usize;
        for b in buckets_json {
            let fifos = b
                .get("fifos")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| anyhow!("bucket missing fifos"))? as usize;
            batch = b
                .get("batch")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| anyhow!("bucket missing batch"))? as usize;
            betas = b
                .get("betas")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| anyhow!("bucket missing betas"))? as usize;
            let file = b
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("bucket missing file"))?;
            let path: PathBuf = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            buckets.push(Bucket { fifos, exe });
        }
        if buckets.is_empty() {
            bail!("manifest.json lists no buckets");
        }
        buckets.sort_by_key(|b| b.fifos);
        Ok(BatchAnalytics {
            client,
            buckets,
            batch,
            betas,
            calls: 0,
        })
    }

    /// Load from the conventional `artifacts/` directory next to the
    /// current working directory (or `$FIFOADVISOR_ARTIFACTS`).
    pub fn load_default() -> Result<BatchAnalytics> {
        let dir = std::env::var("FIFOADVISOR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(Path::new(&dir))
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Smallest bucket with capacity for `fifos`, if any.
    fn bucket_for(&self, fifos: usize) -> Option<&Bucket> {
        self.buckets.iter().find(|b| b.fifos >= fifos)
    }

    /// Largest supported FIFO count.
    pub fn max_fifos(&self) -> usize {
        self.buckets.last().map(|b| b.fifos).unwrap_or(0)
    }

    /// Run the analytics module over up to [`Self::batch`] configurations
    /// (callers chunk larger sets). `latencies[i] = None` marks a
    /// deadlocked config (encoded +inf).
    pub fn evaluate(
        &mut self,
        configs: &[Box<[u32]>],
        widths: &[u32],
        latencies: &[Option<u64>],
        betas: &[f64],
    ) -> Result<AnalyticsOut> {
        let valid = configs.len();
        if valid == 0 {
            bail!("empty batch");
        }
        if valid > self.batch {
            bail!("batch {} exceeds export size {}", valid, self.batch);
        }
        if betas.len() != self.betas {
            bail!("betas {} != export size {}", betas.len(), self.betas);
        }
        let f_real = widths.len();
        let bucket = self
            .bucket_for(f_real)
            .ok_or_else(|| anyhow!("{f_real} FIFOs exceeds largest bucket {}", self.max_fifos()))?;
        let f = bucket.fifos;
        let b = self.batch;

        // Pack + pad the inputs.
        let mut depths = vec![PAD_DEPTH; b * f];
        for (i, cfg) in configs.iter().enumerate() {
            assert_eq!(cfg.len(), f_real, "config width mismatch");
            for (j, &d) in cfg.iter().enumerate() {
                depths[i * f + j] = d as i32;
            }
        }
        let mut w = vec![PAD_WIDTH; f];
        for (j, &x) in widths.iter().enumerate() {
            w[j] = x as i32;
        }
        let mut lat = vec![f32::INFINITY; b];
        for (i, l) in latencies.iter().enumerate() {
            lat[i] = l.map(|v| v as f32).unwrap_or(f32::INFINITY);
        }
        let betas_f: Vec<f32> = betas.iter().map(|&x| x as f32).collect();

        let depths_lit = xla::Literal::vec1(&depths).reshape(&[b as i64, f as i64])?;
        let widths_lit = xla::Literal::vec1(&w);
        let lat_lit = xla::Literal::vec1(&lat);
        let betas_lit = xla::Literal::vec1(&betas_f);

        let result = bucket
            .exe
            .execute::<xla::Literal>(&[depths_lit, widths_lit, lat_lit, betas_lit])?[0][0]
            .to_literal_sync()?;
        self.calls += 1;
        let (totals_l, scores_l, dom_l) = result.to_tuple3()?;

        let totals_all = totals_l.to_vec::<i32>()?;
        let scores_all = scores_l.to_vec::<f32>()?;
        let dom_all = dom_l.to_vec::<i32>()?;

        let bram_totals: Vec<u32> = totals_all[..valid].iter().map(|&x| x as u32).collect();
        let scores: Vec<Vec<f64>> = (0..self.betas)
            .map(|k| {
                scores_all[k * b..k * b + valid]
                    .iter()
                    .map(|&x| x as f64)
                    .collect()
            })
            .collect();
        let dominated: Vec<bool> = dom_all[..valid].iter().map(|&x| x != 0).collect();
        Ok(AnalyticsOut {
            bram_totals,
            scores,
            dominated,
        })
    }
}

/// [`BramBatch`] backend over the XLA artifact: lets the DSE evaluator
/// compute BRAM totals through the AOT-compiled module. Falls back to
/// chunking for batches larger than the export size.
pub struct XlaBram {
    analytics: BatchAnalytics,
    betas: Vec<f64>,
}

impl XlaBram {
    pub fn new(analytics: BatchAnalytics) -> XlaBram {
        let k = analytics.betas;
        let betas = (0..k).map(|i| i as f64 / (k - 1) as f64).collect();
        XlaBram { analytics, betas }
    }

    pub fn calls(&self) -> u64 {
        self.analytics.calls
    }
}

impl BramBatch for XlaBram {
    fn bram_totals(&mut self, configs: &[Box<[u32]>], widths: &[u32]) -> Vec<u32> {
        let mut out = Vec::with_capacity(configs.len());
        let lat_dummy: Vec<Option<u64>> = vec![Some(1); self.analytics.batch];
        for chunk in configs.chunks(self.analytics.batch) {
            let res = self
                .analytics
                .evaluate(chunk, widths, &lat_dummy[..chunk.len()], &self.betas)
                .expect("XLA analytics execution failed");
            out.extend(res.bram_totals);
        }
        out
    }
    fn name(&self) -> &'static str {
        "xla-pjrt"
    }
}
