//! The batched-analytics runtime.
//!
//! The original reproduction executed an AOT-compiled JAX/Pallas
//! analytics module (`artifacts/*.hlo.txt`, produced by
//! `python/compile/aot.py`) through an XLA/PJRT CPU client. The PJRT
//! client crate is not available in the offline build environment, so
//! this module now ships a **native interpreter** of the same exported
//! computation: for a fixed-shape batch `(depths[B,F], widths[F],
//! latencies[B], betas[K])` it computes per-config BRAM totals (paper
//! Algorithm 1), the β-grid weighted objectives, and the Pareto
//! dominance mask — bit-for-bit the semantics `python/compile/model.py`
//! exports, which is exactly what `tests/runtime_xla.rs` cross-checks.
//!
//! Shape buckets mirror the artifact convention: designs are padded to
//! the next FIFO-count bucket and batches are chunked to `B` rows. When
//! an `artifacts/manifest.json` is present its bucket shapes are used;
//! otherwise built-in defaults apply, so the backend works out of the
//! box. Python stays off the request path either way.

use crate::bram;
use crate::dse::BramBatch;
use crate::opt::objective::weighted;
use crate::util::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// Default shape buckets used when no artifact manifest is present
/// (largest bucket covers every suite design; FeedForward has 848
/// FIFOs).
const DEFAULT_BUCKETS: [usize; 4] = [16, 64, 256, 1024];
/// Default rows per batched execution.
const DEFAULT_BATCH: usize = 256;
/// Default β-grid length.
const DEFAULT_BETAS: usize = 8;

/// Result of one batched analytics execution.
#[derive(Debug, Clone)]
pub struct AnalyticsOut {
    /// Per-configuration total BRAM (valid prefix only).
    pub bram_totals: Vec<u32>,
    /// Row-major (K, valid) weighted objectives.
    pub scores: Vec<Vec<f64>>,
    /// Dominance mask over the batch (valid prefix only).
    pub dominated: Vec<bool>,
}

/// The analytics module: shape buckets + the batched evaluator.
pub struct BatchAnalytics {
    /// Supported FIFO-count capacities, ascending.
    buckets: Vec<usize>,
    /// Fixed batch rows per execution (export-time constant).
    pub batch: usize,
    /// Fixed β-grid length (export-time constant).
    pub betas: usize,
    /// Calls executed (for perf reporting).
    pub calls: u64,
}

impl BatchAnalytics {
    /// Load bucket shapes from `<dir>/manifest.json` when present (the
    /// artifact convention shared with `python/compile/aot.py`),
    /// falling back to the built-in defaults otherwise.
    pub fn load(dir: &Path) -> Result<BatchAnalytics> {
        let manifest_path = dir.join("manifest.json");
        if !manifest_path.exists() {
            return Ok(Self::with_defaults());
        }
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?}"))?;
        let manifest = Json::parse(&text).context("parsing manifest.json")?;
        let buckets_json = manifest
            .get("buckets")
            .and_then(|b| b.as_arr())
            .ok_or_else(|| anyhow!("manifest.json: missing buckets"))?;
        let mut buckets = Vec::new();
        let mut batch = DEFAULT_BATCH;
        let mut betas = DEFAULT_BETAS;
        for b in buckets_json {
            let fifos = b
                .get("fifos")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| anyhow!("bucket missing fifos"))? as usize;
            batch = b
                .get("batch")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| anyhow!("bucket missing batch"))? as usize;
            betas = b
                .get("betas")
                .and_then(|v| v.as_u64())
                .ok_or_else(|| anyhow!("bucket missing betas"))? as usize;
            buckets.push(fifos);
        }
        if buckets.is_empty() {
            bail!("manifest.json lists no buckets");
        }
        buckets.sort_unstable();
        Ok(BatchAnalytics {
            buckets,
            batch,
            betas,
            calls: 0,
        })
    }

    fn with_defaults() -> BatchAnalytics {
        BatchAnalytics {
            buckets: DEFAULT_BUCKETS.to_vec(),
            batch: DEFAULT_BATCH,
            betas: DEFAULT_BETAS,
            calls: 0,
        }
    }

    /// Load from the conventional `artifacts/` directory (or
    /// `$FIFOADVISOR_ARTIFACTS`); built-in default shapes when absent.
    pub fn load_default() -> Result<BatchAnalytics> {
        let dir = std::env::var("FIFOADVISOR_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::load(Path::new(&dir))
    }

    /// Execution platform name (diagnostics).
    pub fn platform(&self) -> String {
        "native-interp".to_string()
    }

    /// Smallest bucket with capacity for `fifos`, if any.
    fn bucket_for(&self, fifos: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| b >= fifos)
    }

    /// Largest supported FIFO count.
    pub fn max_fifos(&self) -> usize {
        self.buckets.last().copied().unwrap_or(0)
    }

    /// Run the analytics module over up to [`Self::batch`] configurations
    /// (callers chunk larger sets). `latencies[i] = None` marks a
    /// deadlocked config (scored +inf, dominated by any feasible config
    /// with no more BRAM).
    pub fn evaluate(
        &mut self,
        configs: &[Box<[u32]>],
        widths: &[u32],
        latencies: &[Option<u64>],
        betas: &[f64],
    ) -> Result<AnalyticsOut> {
        let valid = configs.len();
        if valid == 0 {
            bail!("empty batch");
        }
        if valid > self.batch {
            bail!("batch {} exceeds export size {}", valid, self.batch);
        }
        if betas.len() != self.betas {
            bail!("betas {} != export size {}", betas.len(), self.betas);
        }
        if latencies.len() < valid {
            bail!("latencies {} shorter than batch {}", latencies.len(), valid);
        }
        let f_real = widths.len();
        if self.bucket_for(f_real).is_none() {
            bail!("{f_real} FIFOs exceeds largest bucket {}", self.max_fifos());
        }

        // BRAM totals (Algorithm 1, batched).
        let bram_totals: Vec<u32> = configs
            .iter()
            .map(|cfg| {
                assert_eq!(cfg.len(), f_real, "config width mismatch");
                bram::bram_total(cfg, widths)
            })
            .collect();

        // β-grid weighted objectives; deadlocks score +inf.
        let scores: Vec<Vec<f64>> = betas
            .iter()
            .map(|&beta| {
                latencies
                    .iter()
                    .take(valid)
                    .zip(&bram_totals)
                    .map(|(l, &b)| match l {
                        Some(l) => weighted(beta, *l, b),
                        None => f64::INFINITY,
                    })
                    .collect()
            })
            .collect();

        // Dominance mask — exactly the exported kernel's formula
        // (python/compile/kernels/pareto.py):
        //   dominated[i] = any j: lat_j <= lat_i && bram_j <= bram_i
        //                         && (lat_j < lat_i || bram_j < bram_i)
        // with deadlocks encoded as lat = +inf. Note the IEEE corner the
        // kernel inherits: a deadlocked row IS dominated by another
        // deadlocked row with strictly smaller BRAM (inf <= inf holds,
        // inf < inf does not).
        let enc: Vec<(f64, u32)> = latencies
            .iter()
            .take(valid)
            .zip(&bram_totals)
            .map(|(l, &b)| (l.map(|l| l as f64).unwrap_or(f64::INFINITY), b))
            .collect();
        let dominated: Vec<bool> = enc
            .iter()
            .map(|&(li, bi)| {
                enc.iter()
                    .any(|&(lj, bj)| lj <= li && bj <= bi && (lj < li || bj < bi))
            })
            .collect();

        self.calls += 1;
        Ok(AnalyticsOut {
            bram_totals,
            scores,
            dominated,
        })
    }

    /// The fused batched pipeline: evaluate a whole proposal batch
    /// through an [`EvalEngine`](crate::dse::EvalEngine) (one lane-packed
    /// SoA walk per scenario when the engine runs `--backend batched`,
    /// memo/oracle/clamp layers intact) and feed the resulting per-batch
    /// outcome arrays straight into one analytics execution — BRAM
    /// totals, β-grid objectives and the dominance mask in a single
    /// batched call, mirroring the exported Pallas pipeline
    /// (`python/compile/kernels/{bram,pareto}.py`). This interpreter is
    /// the conformance reference those kernels are tested against.
    ///
    /// The batch must fit one export batch (`configs.len() <=`
    /// [`Self::batch`]) because the dominance mask is a per-batch
    /// construct; chunk larger sets at the call site.
    pub fn evaluate_engine_batch(
        &mut self,
        engine: &mut crate::dse::EvalEngine,
        configs: &[Box<[u32]>],
        betas: &[f64],
    ) -> Result<AnalyticsOut> {
        if configs.len() > self.batch {
            bail!("batch {} exceeds export size {}", configs.len(), self.batch);
        }
        let widths = engine.widths.clone();
        let latencies: Vec<Option<u64>> = engine
            .eval_results(configs, false)
            .into_iter()
            .map(|r| r.latency)
            .collect();
        self.evaluate(configs, &widths, &latencies, betas)
    }
}

/// [`BramBatch`] backend over the analytics module: lets the DSE engine
/// compute batched BRAM totals through the exported computation. Chunks
/// batches larger than the export size. The type name is kept from the
/// PJRT-backed original so call sites and configs stay stable.
pub struct XlaBram {
    analytics: BatchAnalytics,
    betas: Vec<f64>,
}

impl XlaBram {
    pub fn new(analytics: BatchAnalytics) -> XlaBram {
        let k = analytics.betas;
        let betas = (0..k).map(|i| i as f64 / (k - 1) as f64).collect();
        XlaBram { analytics, betas }
    }

    pub fn calls(&self) -> u64 {
        self.analytics.calls
    }
}

impl BramBatch for XlaBram {
    fn bram_totals(&mut self, configs: &[Box<[u32]>], widths: &[u32]) -> Vec<u32> {
        let mut out = Vec::with_capacity(configs.len());
        let lat_dummy: Vec<Option<u64>> = vec![Some(1); self.analytics.batch];
        for chunk in configs.chunks(self.analytics.batch) {
            let res = self
                .analytics
                .evaluate(chunk, widths, &lat_dummy[..chunk.len()], &self.betas)
                .expect("analytics execution failed");
            out.extend(res.bram_totals);
        }
        out
    }
    fn name(&self) -> &'static str {
        "analytics"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_shapes_cover_the_suite() {
        let a = BatchAnalytics::load_default().unwrap();
        assert!(a.max_fifos() >= 848, "FeedForward must fit a bucket");
        assert!(a.batch >= 64);
        assert!(a.betas >= 2);
    }

    #[test]
    fn fused_engine_batch_matches_native_references() {
        use crate::dse::EvalEngine;
        use crate::sim::BackendKind;
        use crate::trace::workload::Workload;
        use std::sync::Arc;

        let bd = crate::bench_suite::build("fig2");
        let t = Arc::new(crate::trace::collect_trace(&bd.design, &bd.args).unwrap());
        let w = Arc::new(Workload::single(t.clone()));
        let mut a = BatchAnalytics::with_defaults();
        let betas: Vec<f64> = (0..a.betas).map(|i| i as f64 / 10.0).collect();
        // Mixed batch: feasible, deadlocked, duplicate and clamp-region
        // lanes.
        let configs: Vec<Box<[u32]>> = [
            [16u32, 2],
            [2, 2],
            [15, 2],
            [16, 2],
            [7, 3],
            [16, 16],
        ]
        .iter()
        .map(|c| c.to_vec().into_boxed_slice())
        .collect();
        let mut ev = EvalEngine::for_workload_with_sim(w.clone(), 1, BackendKind::Batched);
        let out = a.evaluate_engine_batch(&mut ev, &configs, &betas).unwrap();
        // Engine results are identical to a fast-backend engine.
        let mut fast = EvalEngine::for_workload_with_sim(w, 1, BackendKind::Fast);
        let want: Vec<(Option<u64>, u32)> = fast.eval_batch(&configs);
        // BRAM totals match Algorithm 1 per config.
        for (i, (cfg, &b)) in configs.iter().zip(&out.bram_totals).enumerate() {
            assert_eq!(b, crate::bram::bram_total(cfg, &ev.widths), "row {i}");
            assert_eq!(b, want[i].1, "row {i}: engine BRAM diverged");
        }
        // Dominance mask matches an O(B²) reference over the fused
        // latency/BRAM arrays.
        let enc: Vec<(f64, u32)> = want
            .iter()
            .map(|&(l, b)| (l.map(|l| l as f64).unwrap_or(f64::INFINITY), b))
            .collect();
        for (i, &(li, bi)) in enc.iter().enumerate() {
            let dom = enc
                .iter()
                .any(|&(lj, bj)| lj <= li && bj <= bi && (lj < li || bj < bi));
            assert_eq!(out.dominated[i], dom, "row {i}: dominance diverged");
        }
        // β-grid scores: +inf exactly on the deadlocked rows.
        for row in &out.scores {
            assert_eq!(row.len(), configs.len());
            for (s, &(l, _)) in row.iter().zip(&want) {
                assert_eq!(s.is_infinite(), l.is_none());
            }
        }
        assert!(ev.stats().batch_walks > 0, "fused path must lane-batch");
    }

    #[test]
    fn evaluate_rejects_bad_shapes() {
        let mut a = BatchAnalytics::with_defaults();
        let widths = vec![32u32; 4];
        let cfg: Vec<Box<[u32]>> = vec![vec![2u32; 4].into()];
        let betas: Vec<f64> = (0..a.betas).map(|i| i as f64).collect();
        assert!(a.evaluate(&[], &widths, &[], &betas).is_err());
        assert!(a
            .evaluate(&cfg, &widths, &[Some(1)], &betas[..1])
            .is_err());
        let too_many = vec![cfg[0].clone(); a.batch + 1];
        let lats = vec![Some(1u64); a.batch + 1];
        assert!(a.evaluate(&too_many, &widths, &lats, &betas).is_err());
    }
}
