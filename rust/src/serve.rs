//! The persistent sizing service: `fifoadvisor serve`.
//!
//! A long-running, std-only server speaking newline-delimited JSON over
//! TCP (and, on unix, an optional unix-domain socket). One request per
//! line, one response per line:
//!
//! ```text
//! → {"id":1,"cmd":"optimize","design":"fig2","optimizer":"grouped_sa","seed":1,"budget":200}
//! ← {"id":1,"ok":true,"result":{...deterministic...},"stats":{...timing/sims...}}
//! ```
//!
//! Commands: `ping`, `stats`, `simulate`, `optimize`, `hunt`,
//! `certify`, `shutdown`. Engine-backed commands name a built-in suite
//! design plus optional scenario `args`; the server keeps one hot
//! [`EvalEngine`] resident per (design, args, backend, prune, bounds,
//! jobs) so repeated requests hit a warm memo cache — the second
//! identical optimize is a pure replay with **zero** simulations.
//!
//! # Engine actors
//!
//! `EvalEngine` is deliberately not `Send` (its BRAM backend may be
//! thread-pinned), so each engine lives on a dedicated *actor thread*
//! that builds it locally and serves requests from an mpsc queue;
//! connection handlers only ship JSON jobs and wait for the reply.
//! Concurrent requests for the same engine serialize in queue order —
//! everything the engine layer guarantees (determinism, serial ==
//! `--jobs N`) carries over verbatim. Each request installs a fresh
//! [`CancelToken`] from its `timeout_secs` / `max_sims` fields, so one
//! slow request cannot wedge its actor forever.
//!
//! # Result/stats split
//!
//! Responses separate the deterministic payload (`result`: fronts,
//! verdicts, a history hash) from run-dependent telemetry (`stats`:
//! sims, elapsed). A warm-started answer is byte-identical to a cold
//! one in `result`; only `stats` may differ — which is exactly what
//! the CI smoke job asserts.
//!
//! # Cross-run cache
//!
//! With a `cache_dir`, each actor warm-starts its engine from the
//! [`crate::store`] snapshot under its key at creation and persists an
//! updated snapshot after every request that simulated something — so
//! the replay guarantee survives server restarts.

use crate::bench_suite;
use crate::dse::cancel::CancelToken;
use crate::dse::{advhunt, drive, EvalEngine};
use crate::opt::{self, Space};
use crate::sim::BackendKind;
use crate::store::{Snapshot, Store};
use crate::trace::workload::Workload;
use crate::util::fnv1a;
use crate::util::json::Json;
use std::collections::HashMap;
use std::io::{self, BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread;

/// Server configuration (the `fifoadvisor serve` flags).
pub struct ServeConfig {
    /// TCP bind address, e.g. `127.0.0.1:7733`.
    pub addr: String,
    /// Optional unix-domain socket path (unix only; ignored elsewhere).
    pub unix_socket: Option<String>,
    /// Cross-run snapshot directory (`None` = in-memory only).
    pub cache_dir: Option<String>,
    /// Store size budget in MiB (0 = unlimited).
    pub cache_max_mb: u64,
    /// Default worker count for engines (requests may override).
    pub jobs: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7733".to_string(),
            unix_socket: None,
            cache_dir: None,
            cache_max_mb: 512,
            jobs: 1,
        }
    }
}

/// One queued request for an engine actor.
struct EngineJob {
    req: Json,
    resp: mpsc::Sender<Json>,
}

struct ServerState {
    cfg: ServeConfig,
    /// Engine-actor queues by engine key. A dead actor (panicked) is
    /// detected on send failure and respawned lazily.
    engines: Mutex<HashMap<String, mpsc::Sender<EngineJob>>>,
    stop: AtomicBool,
    requests: AtomicU64,
}

// ---------------------------------------------------------------------------
// Request plumbing
// ---------------------------------------------------------------------------

fn err_response(id: Option<&Json>, msg: &str) -> Json {
    let mut pairs = vec![
        ("ok", Json::Bool(false)),
        ("error", Json::Str(msg.to_string())),
    ];
    if let Some(id) = id {
        pairs.push(("id", id.clone()));
    }
    Json::obj(pairs)
}

fn ok_response(id: Option<&Json>, result: Json, stats: Json) -> Json {
    let mut pairs = vec![
        ("ok", Json::Bool(true)),
        ("result", result),
        ("stats", stats),
    ];
    if let Some(id) = id {
        pairs.push(("id", id.clone()));
    }
    Json::obj(pairs)
}

fn get_u64_field(req: &Json, key: &str, default: u64) -> Result<u64, String> {
    match req.get(key) {
        None => Ok(default),
        Some(v) => v.as_u64().ok_or_else(|| format!("'{key}' must be a non-negative integer")),
    }
}

fn get_bool_field(req: &Json, key: &str, default: bool) -> Result<bool, String> {
    match req.get(key) {
        None => Ok(default),
        Some(v) => v.as_bool().ok_or_else(|| format!("'{key}' must be a boolean")),
    }
}

fn get_depths(req: &Json, w: &Workload) -> Result<Vec<u32>, String> {
    match req.get("depths") {
        Some(v) => {
            let arr = v.as_arr().ok_or("'depths' must be an array")?;
            if arr.len() != w.num_fifos() {
                return Err(format!(
                    "'depths' has {} entries, design has {} FIFOs",
                    arr.len(),
                    w.num_fifos()
                ));
            }
            arr.iter()
                .map(|d| {
                    d.as_u64()
                        .and_then(|u| u32::try_from(u).ok())
                        .map(|u| u.max(1))
                        .ok_or_else(|| "bad depth".to_string())
                })
                .collect()
        }
        None => match req.get("baseline").and_then(Json::as_str).unwrap_or("max") {
            "max" => Ok(w.baseline_max()),
            "min" => Ok(w.baseline_min()),
            other => Err(format!("'baseline' must be max|min, got '{other}'")),
        },
    }
}

/// Scenario argument sets, one inner vector per workload scenario.
type ArgSets = Vec<Vec<i64>>;

/// Resolve the request's design + scenario args into a workload.
fn build_workload(req: &Json) -> Result<(String, Arc<Workload>, ArgSets), String> {
    let name = req
        .get("design")
        .and_then(Json::as_str)
        .ok_or("missing 'design'")?
        .to_string();
    let bd = bench_suite::try_build(&name).ok_or_else(|| format!("unknown design '{name}'"))?;
    let sets: ArgSets = match req.get("args") {
        None => vec![bd.args.clone()],
        Some(v) => {
            let outer = v.as_arr().ok_or("'args' must be an array of arrays")?;
            let mut sets = Vec::with_capacity(outer.len());
            for s in outer {
                let inner = s.as_arr().ok_or("'args' must be an array of arrays")?;
                let mut one = Vec::with_capacity(inner.len());
                for a in inner {
                    let f = a.as_f64().ok_or("scenario args must be numbers")?;
                    one.push(f as i64);
                }
                sets.push(one);
            }
            if sets.is_empty() {
                vec![bd.args.clone()]
            } else {
                sets
            }
        }
    };
    let w = Workload::from_design_args(&bd.design, &sets).map_err(|e| e.to_string())?;
    Ok((name, Arc::new(w), sets))
}

/// Deterministic fingerprint of a run's history — depths, latency and
/// BRAM only (never wall-clock fields), so warm and cold runs hash
/// identically.
fn history_hash(engine: &EvalEngine) -> String {
    let mut s = String::new();
    for p in &engine.history {
        s.push_str(&format!("{:?}:{:?}:{};", p.depths, p.latency, p.bram));
    }
    format!("{:016x}", fnv1a(s.as_bytes()))
}

fn front_json(engine: &EvalEngine) -> Json {
    Json::Arr(
        engine
            .pareto()
            .into_iter()
            .map(|p| {
                Json::obj(vec![
                    ("depths", Json::nums(&p.depths.iter().map(|&d| d as f64).collect::<Vec<_>>())),
                    (
                        "latency",
                        match p.latency {
                            Some(l) => Json::Num(l as f64),
                            None => Json::Null,
                        },
                    ),
                    ("bram", Json::Num(p.bram as f64)),
                ])
            })
            .collect(),
    )
}

fn engine_stats_json(engine: &EvalEngine, elapsed: f64) -> Json {
    let s = engine.stats();
    Json::obj(vec![
        ("sims", Json::Num(s.sims as f64)),
        ("proposals", Json::Num(s.proposals as f64)),
        ("cache_hits", Json::Num(s.cache_hits as f64)),
        ("oracle_hits", Json::Num(s.oracle_hits as f64)),
        ("elapsed_secs", Json::Num(elapsed)),
    ])
}

// ---------------------------------------------------------------------------
// Engine actors
// ---------------------------------------------------------------------------

/// Everything an actor needs to build its engine locally (the engine
/// itself is not `Send`, so it must be born on the actor thread).
struct EngineSpec {
    design: String,
    workload: Arc<Workload>,
    backend: BackendKind,
    prune: bool,
    bounds: bool,
    jobs: usize,
    store: Option<(String, u64)>, // (dir, max_mb)
}

fn engine_key(spec: &EngineSpec, args: &[Vec<i64>]) -> String {
    format!(
        "{}|{:?}|{}|prune={}|bounds={}|jobs={}",
        spec.design,
        args,
        spec.backend.name(),
        spec.prune,
        spec.bounds,
        spec.jobs
    )
}

/// The actor loop: build the engine (warm-starting from the store when
/// available), then serve queued jobs until every sender is dropped.
fn engine_actor(spec: EngineSpec, rx: mpsc::Receiver<EngineJob>) {
    let mut engine =
        EvalEngine::for_workload_with_sim(spec.workload.clone(), spec.jobs, spec.backend);
    engine.set_prune(spec.prune);
    engine.set_bounds(spec.bounds);
    let store = spec
        .store
        .as_ref()
        .map(|(dir, mb)| (Store::new(dir, *mb), store_key(&spec)));
    if let Some((st, key)) = &store {
        if let Some(snap) = st.load(key) {
            match snap.apply(&mut engine) {
                Ok(n) => eprintln!("serve: engine {key}: warm-started {n} memo entries"),
                Err(e) => eprintln!("serve: engine {key}: snapshot rejected ({e}); cold start"),
            }
        }
    }
    let space = Space::from_workload(&spec.workload);
    while let Ok(job) = rx.recv() {
        let before = engine.n_sim;
        let resp = handle_engine_request(&job.req, &mut engine, &space);
        if engine.n_sim > before {
            if let Some((st, key)) = &store {
                let snap = Snapshot::capture(&spec.design, &engine);
                if let Err(e) = st.save(key, &snap) {
                    eprintln!("serve: engine {key}: snapshot save failed: {e}");
                }
            }
        }
        if job.resp.send(resp).is_err() {
            // Handler hung up (client gone); keep serving others.
            continue;
        }
    }
}

fn store_key(spec: &EngineSpec) -> String {
    Store::key(
        &spec.design,
        &spec.workload,
        spec.backend.name(),
        spec.prune,
        spec.bounds,
    )
}

/// Per-request cancellation token from `timeout_secs` / `max_sims`.
fn request_token(req: &Json) -> Result<CancelToken, String> {
    let timeout = match req.get("timeout_secs") {
        None => None,
        Some(v) => {
            let f = v.as_f64().filter(|f| *f > 0.0).ok_or("'timeout_secs' must be > 0")?;
            Some(std::time::Duration::from_secs_f64(f))
        }
    };
    let max_sims = match req.get("max_sims") {
        None => None,
        Some(v) => Some(v.as_u64().ok_or("'max_sims' must be a non-negative integer")?),
    };
    Ok(CancelToken::with_limits(timeout, max_sims))
}

fn handle_engine_request(req: &Json, engine: &mut EvalEngine, space: &Space) -> Json {
    let id = req.get("id");
    let cmd = req.get("cmd").and_then(Json::as_str).unwrap_or("");
    let t0 = std::time::Instant::now();
    let out: Result<Json, String> = (|| {
        engine.reset_run(false);
        engine.set_cancel_token(request_token(req)?);
        match cmd {
            "simulate" => {
                let depths = get_depths(req, engine.workload())?;
                let (lat, bram) = engine.eval(&depths);
                Ok(Json::obj(vec![
                    ("depths", Json::nums(&depths.iter().map(|&d| d as f64).collect::<Vec<_>>())),
                    (
                        "latency",
                        match lat {
                            Some(l) => Json::Num(l as f64),
                            None => Json::Null,
                        },
                    ),
                    ("bram", Json::Num(bram as f64)),
                    ("deadlock", Json::Bool(lat.is_none())),
                ]))
            }
            "optimize" => {
                let opt_name = req
                    .get("optimizer")
                    .and_then(Json::as_str)
                    .unwrap_or("grouped_sa")
                    .to_string();
                let seed = get_u64_field(req, "seed", 1)?;
                let budget = get_u64_field(req, "budget", 1000)? as usize;
                let mut optimizer = opt::by_name(&opt_name, seed)
                    .ok_or_else(|| format!("unknown optimizer '{opt_name}'"))?;
                engine.eval_baselines();
                engine.reset_run(false);
                drive(&mut *optimizer, engine, space, budget);
                Ok(Json::obj(vec![
                    ("optimizer", Json::Str(opt_name)),
                    ("seed", Json::Num(seed as f64)),
                    ("budget", Json::Num(budget as f64)),
                    ("front", front_json(engine)),
                    ("history_len", Json::Num(engine.history.len() as f64)),
                    ("history_hash", Json::Str(history_hash(engine))),
                    ("truncated", Json::Bool(engine.truncated())),
                ]))
            }
            "hunt" => {
                let budget = get_u64_field(req, "budget", 1000)? as usize;
                let hunter = opt::vitis_hunter::VitisHunter::new();
                match hunter.hunt(engine, space, budget) {
                    Some(cfg) => {
                        let (lat, bram) = engine.eval(&cfg);
                        Ok(Json::obj(vec![
                            ("found", Json::Bool(true)),
                            (
                                "depths",
                                Json::nums(&cfg.iter().map(|&d| d as f64).collect::<Vec<_>>()),
                            ),
                            (
                                "latency",
                                match lat {
                                    Some(l) => Json::Num(l as f64),
                                    None => Json::Null,
                                },
                            ),
                            ("bram", Json::Num(bram as f64)),
                        ]))
                    }
                    None => Ok(Json::obj(vec![
                        ("found", Json::Bool(false)),
                        ("truncated", Json::Bool(engine.truncated())),
                    ])),
                }
            }
            other => Err(format!("engine actor cannot serve '{other}'")),
        }
    })();
    let elapsed = t0.elapsed().as_secs_f64();
    match out {
        Ok(result) => ok_response(id, result, engine_stats_json(engine, elapsed)),
        Err(e) => err_response(id, &e),
    }
}

// ---------------------------------------------------------------------------
// Connection handling
// ---------------------------------------------------------------------------

fn handle_request(server: &Arc<ServerState>, line: &str) -> Json {
    let req = match Json::parse(line) {
        Ok(v) => v,
        Err(e) => return err_response(None, &format!("bad request json: {e:?}")),
    };
    let id = req.get("id").cloned();
    let id = id.as_ref();
    server.requests.fetch_add(1, Ordering::SeqCst);
    let cmd = match req.get("cmd").and_then(Json::as_str) {
        Some(c) => c.to_string(),
        None => return err_response(id, "missing 'cmd'"),
    };
    match cmd.as_str() {
        "ping" => ok_response(id, Json::Str("pong".to_string()), Json::obj(vec![])),
        "stats" => {
            let engines = server.engines.lock().expect("engines lock poisoned").len();
            ok_response(
                id,
                Json::obj(vec![
                    ("requests", Json::Num(server.requests.load(Ordering::SeqCst) as f64)),
                    ("engines", Json::Num(engines as f64)),
                ]),
                Json::obj(vec![]),
            )
        }
        "shutdown" => {
            server.stop.store(true, Ordering::SeqCst);
            // Self-connect to wake the blocking accept loop.
            let _ = TcpStream::connect(&server.cfg.addr);
            ok_response(id, Json::Str("stopping".to_string()), Json::obj(vec![]))
        }
        "certify" => handle_certify(&req, id),
        "simulate" | "optimize" | "hunt" => dispatch_to_engine(server, &req, id),
        other => err_response(id, &format!("unknown cmd '{other}'")),
    }
}

/// `certify` is stateless (it builds its own per-scenario machinery),
/// so it runs on the connection handler thread, no actor involved.
fn handle_certify(req: &Json, id: Option<&Json>) -> Json {
    let out: Result<Json, String> = (|| {
        let (name, w, _) = build_workload(req)?;
        let depths = get_depths(req, &w)?;
        let cfg = advhunt::HuntConfig {
            optimizer: req
                .get("hunt_optimizer")
                .and_then(Json::as_str)
                .unwrap_or("auto")
                .to_string(),
            seed: get_u64_field(req, "seed", 1)?,
            budget: get_u64_field(req, "budget", 64)? as usize,
            jobs: 1,
            cancel: request_token(req)?,
        };
        if !advhunt::HUNT_OPTIMIZERS.contains(&cfg.optimizer.as_str()) {
            return Err(format!(
                "hunt optimizer '{}' not in {:?}",
                cfg.optimizer,
                advhunt::HUNT_OPTIMIZERS
            ));
        }
        match advhunt::certify_design(&name, &depths, &cfg) {
            Some(c) => Ok(c.to_json()),
            None => Err(format!(
                "design '{name}' exposes no kernel-argument space — nothing to certify against"
            )),
        }
    })();
    match out {
        Ok(result) => ok_response(id, result, Json::obj(vec![])),
        Err(e) => err_response(id, &e),
    }
}

/// Route an engine-backed request to its actor, creating the actor on
/// first use. The workload is built (and validated) here on the handler
/// thread; the non-`Send` engine is built inside the actor.
fn dispatch_to_engine(server: &Arc<ServerState>, req: &Json, id: Option<&Json>) -> Json {
    let spec = (|| -> Result<(EngineSpec, ArgSets), String> {
        let (design, workload, args) = build_workload(req)?;
        let backend = match req.get("backend").and_then(Json::as_str) {
            None => BackendKind::Fast,
            Some(s) => BackendKind::parse(s)?,
        };
        let jobs = get_u64_field(req, "jobs", server.cfg.jobs as u64)?.max(1) as usize;
        Ok((
            EngineSpec {
                design,
                workload,
                backend,
                prune: get_bool_field(req, "prune", true)?,
                bounds: get_bool_field(req, "bounds", true)?,
                jobs,
                store: server
                    .cfg
                    .cache_dir
                    .as_ref()
                    .map(|d| (d.clone(), server.cfg.cache_max_mb)),
            },
            args,
        ))
    })();
    let (spec, args) = match spec {
        Ok(s) => s,
        Err(e) => return err_response(id, &e),
    };
    let key = engine_key(&spec, &args);
    let (rtx, rrx) = mpsc::channel();
    let job = EngineJob {
        req: req.clone(),
        resp: rtx,
    };
    // Send under the lock so a respawn after an actor death is racefree.
    {
        let mut engines = server.engines.lock().expect("engines lock poisoned");
        let tx = engines.entry(key.clone()).or_insert_with(|| {
            let (tx, rx) = mpsc::channel();
            thread::spawn(move || engine_actor(spec, rx));
            tx
        });
        if tx.send(job).is_err() {
            engines.remove(&key);
            return err_response(id, "engine actor died; retry the request");
        }
    }
    match rrx.recv() {
        Ok(resp) => resp,
        Err(_) => err_response(id, "engine actor dropped the request (panic?)"),
    }
}

fn handle_conn(server: Arc<ServerState>, reader: impl BufRead, mut writer: impl Write) {
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.trim().is_empty() {
            continue;
        }
        let resp = handle_request(&server, &line);
        if writeln!(writer, "{}", resp.to_string_compact()).is_err() {
            break;
        }
        let _ = writer.flush();
        if server.stop.load(Ordering::SeqCst) {
            break;
        }
    }
}

// ---------------------------------------------------------------------------
// Listeners
// ---------------------------------------------------------------------------

/// Run the server until a `shutdown` request arrives. Binds the TCP
/// address (and the unix socket, when configured on unix) and serves
/// each connection on its own thread.
pub fn run(cfg: ServeConfig) -> io::Result<()> {
    let listener = TcpListener::bind(&cfg.addr)?;
    // Rebind to whatever the OS resolved (port 0 → a concrete port), so
    // the shutdown self-connect and the startup banner agree with it.
    let addr = listener.local_addr()?;
    let mut cfg = cfg;
    cfg.addr = addr.to_string();
    println!("fifoadvisor serve: listening on {addr}");
    if let Some(dir) = &cfg.cache_dir {
        println!("fifoadvisor serve: cross-run cache at {dir}");
    }
    let server = Arc::new(ServerState {
        cfg,
        engines: Mutex::new(HashMap::new()),
        stop: AtomicBool::new(false),
        requests: AtomicU64::new(0),
    });

    #[cfg(unix)]
    if let Some(path) = server.cfg.unix_socket.clone() {
        let _ = std::fs::remove_file(&path);
        let ul = std::os::unix::net::UnixListener::bind(&path)?;
        println!("fifoadvisor serve: listening on unix:{path}");
        let srv = Arc::clone(&server);
        thread::spawn(move || {
            for stream in ul.incoming() {
                let Ok(stream) = stream else { break };
                if srv.stop.load(Ordering::SeqCst) {
                    break;
                }
                let srv = Arc::clone(&srv);
                thread::spawn(move || {
                    let Ok(r) = stream.try_clone() else { return };
                    handle_conn(srv, BufReader::new(r), stream);
                });
            }
        });
    }

    for stream in listener.incoming() {
        let stream = stream?;
        if server.stop.load(Ordering::SeqCst) {
            break;
        }
        let srv = Arc::clone(&server);
        thread::spawn(move || {
            let Ok(r) = stream.try_clone() else { return };
            handle_conn(srv, BufReader::new(r), stream);
        });
    }
    println!("fifoadvisor serve: shutdown");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    fn start_test_server(cache_dir: Option<String>) -> (String, thread::JoinHandle<()>) {
        // Port 0: the OS picks a free port; we learn it via a handshake
        // channel once the listener is bound.
        let (tx, rx) = mpsc::channel();
        let handle = thread::spawn(move || {
            let listener = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap().to_string();
            tx.send(addr.clone()).unwrap();
            let server = Arc::new(ServerState {
                cfg: ServeConfig {
                    addr,
                    unix_socket: None,
                    cache_dir,
                    cache_max_mb: 64,
                    jobs: 1,
                },
                engines: Mutex::new(HashMap::new()),
                stop: AtomicBool::new(false),
                requests: AtomicU64::new(0),
            });
            for stream in listener.incoming() {
                let stream = stream.unwrap();
                if server.stop.load(Ordering::SeqCst) {
                    break;
                }
                let srv = Arc::clone(&server);
                thread::spawn(move || {
                    let r = stream.try_clone().unwrap();
                    handle_conn(srv, BufReader::new(r), stream);
                });
            }
        });
        (rx.recv().unwrap(), handle)
    }

    fn roundtrip(addr: &str, req: &str) -> Json {
        let mut stream = TcpStream::connect(addr).unwrap();
        writeln!(stream, "{req}").unwrap();
        let mut line = String::new();
        BufReader::new(stream.try_clone().unwrap())
            .read_line(&mut line)
            .unwrap();
        Json::parse(&line).unwrap()
    }

    fn shutdown(addr: &str, handle: thread::JoinHandle<()>) {
        let _ = roundtrip(addr, "{\"cmd\":\"shutdown\"}");
        let _ = handle.join();
    }

    #[test]
    fn ping_and_errors_roundtrip() {
        let (addr, handle) = start_test_server(None);
        let r = roundtrip(&addr, "{\"cmd\":\"ping\",\"id\":7}");
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(r.get("id").and_then(Json::as_u64), Some(7));
        let r = roundtrip(&addr, "{\"cmd\":\"simulate\",\"design\":\"no_such\"}");
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false));
        let r = roundtrip(&addr, "not json at all");
        assert_eq!(r.get("ok").and_then(Json::as_bool), Some(false));
        shutdown(&addr, handle);
    }

    #[test]
    fn second_identical_optimize_is_a_zero_sim_replay() {
        let (addr, handle) = start_test_server(None);
        let req = "{\"cmd\":\"optimize\",\"design\":\"fig2\",\"optimizer\":\"grouped_sa\",\
                   \"seed\":3,\"budget\":120}";
        let a = roundtrip(&addr, req);
        assert_eq!(a.get("ok").and_then(Json::as_bool), Some(true), "{a:?}");
        let cold_sims = a.get("stats").unwrap().get("sims").unwrap().as_u64().unwrap();
        assert!(cold_sims > 0);
        let b = roundtrip(&addr, req);
        let warm_sims = b.get("stats").unwrap().get("sims").unwrap().as_u64().unwrap();
        assert_eq!(warm_sims, 0, "second identical optimize must replay");
        // The deterministic result payload is byte-identical.
        assert_eq!(
            a.get("result").unwrap().to_string_compact(),
            b.get("result").unwrap().to_string_compact()
        );
        shutdown(&addr, handle);
    }

    #[test]
    fn cache_dir_survives_a_server_restart_bit_identically() {
        let dir = std::env::temp_dir().join(format!(
            "fifoadvisor_serve_restart_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let cache = dir.to_str().unwrap().to_string();
        let req = "{\"cmd\":\"optimize\",\"design\":\"fig2\",\"optimizer\":\"grouped_sa\",\
                   \"seed\":5,\"budget\":100}";

        let (addr, handle) = start_test_server(Some(cache.clone()));
        let cold = roundtrip(&addr, req);
        assert_eq!(cold.get("ok").and_then(Json::as_bool), Some(true), "{cold:?}");
        shutdown(&addr, handle);

        // "Restart": a brand-new server over the same cache dir.
        let (addr, handle) = start_test_server(Some(cache));
        let warm = roundtrip(&addr, req);
        assert_eq!(
            warm.get("stats").unwrap().get("sims").unwrap().as_u64(),
            Some(0),
            "restarted server must replay from the store"
        );
        assert_eq!(
            cold.get("result").unwrap().to_string_compact(),
            warm.get("result").unwrap().to_string_compact(),
            "warm answer must be bit-identical to cold"
        );
        shutdown(&addr, handle);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn simulate_requests_share_the_resident_engine_memo() {
        let (addr, handle) = start_test_server(None);
        let req = "{\"cmd\":\"simulate\",\"design\":\"fig2\",\"depths\":[16,16]}";
        let a = roundtrip(&addr, req);
        assert_eq!(a.get("ok").and_then(Json::as_bool), Some(true), "{a:?}");
        assert_eq!(
            a.get("stats").unwrap().get("sims").unwrap().as_u64(),
            Some(1)
        );
        let b = roundtrip(&addr, req);
        assert_eq!(
            b.get("stats").unwrap().get("sims").unwrap().as_u64(),
            Some(0),
            "repeat simulate is a memo hit"
        );
        assert_eq!(
            a.get("result").unwrap().to_string_compact(),
            b.get("result").unwrap().to_string_compact()
        );
        shutdown(&addr, handle);
    }
}
