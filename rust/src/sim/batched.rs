//! The lane-batched SoA graph simulator — K depth vectors per Kahn walk.
//!
//! [`CompiledSim`](super::compiled::CompiledSim) lowers the trace into a
//! static event graph once, but still evaluates **one** depth vector per
//! longest-path traversal. Every optimizer above it asks in batches
//! (NSGA-II generations, SA lockstep chains, exhaustive blocks), so the
//! remaining factor-of-K on the hot path is the per-configuration walk
//! itself. `BatchedSim` removes it by lowering the *evaluation state*
//! into structure-of-arrays form over the same compiled
//! [`EventGraph`](super::compiled::EventGraph):
//!
//! - **Node times are stored lane-major**: node `n`'s K commit times are
//!   the contiguous block `time[n*K .. (n+1)*K]` — one `[u64; K]` lane
//!   row per node, so the K lanes of every node (and of its program-order
//!   predecessor) share cache lines during propagation.
//! - **In-degrees, committed counters, depths and read latencies** get
//!   the same lane-major treatment (`indeg[n*K + l]`, `done[p*K + l]`,
//!   `depth[ch*K + l]`, `rd_lat[ch*K + l]`).
//! - **Static in-degrees are broadcast** to all K lanes with one fill per
//!   node row; the depth-parameterized full-FIFO edges are then resolved
//!   *per lane* from the compiled ordinal→node tables — lane `l`'s write
//!   ordinal `j` waits on read `j − depth[l]`, so both the edge weight
//!   and the edge **endpoint** differ between lanes of the same node.
//! - One Kahn pass then drains a shared worklist of (node, lane) readiness
//!   events: each lane's commits form exactly the per-lane least fixpoint
//!   an independent [`CompiledSim`] cold walk would compute, while the
//!   graph tables stay hot in cache across all K lanes. Program-order
//!   chain-following keeps long compute runs off the worklist, per lane.
//! - **Per-lane deadlock detection and blocked-set recovery**: lanes
//!   whose in-degrees never drain leave their per-lane committed counters
//!   short, and each such lane recovers its own blocked set with the
//!   identical formula (and process order) as the scalar backends.
//!
//! The result is **bit-identical per lane** to [`FastSim`] and
//! [`CompiledSim`] — latency, deadlock verdict *and* blocked sets — which
//! `tests/backend_conformance.rs` pins across the lane grid (K ∈ {1, 3,
//! 8, 64}, ragged final batches, duplicate lanes, per-lane deadlock
//! boundaries).
//!
//! Batched evaluation is cold per batch: lane packing *replaces* the
//! retained-schedule delta replay of the warm backends (a batch of K
//! unrelated proposals has no single predecessor schedule to diff
//! against), so [`set_incremental`](BatchedSim::set_incremental) is a
//! no-op and [`RunInfo`] reports every lane as a full replay. The
//! single-configuration [`simulate`](BatchedSim::simulate) path is just a
//! K = 1 batch.
//!
//! [`FastSim`]: super::fast::FastSim

use super::compiled::{EventGraph, NONE, NO_TIME, WRITE_FLAG};
use super::fast::{BlockInfo, ChannelStats, RunInfo, SimOutcome};
use super::{SimBackend, SimOptions};
use crate::trace::{ChanOpIndex, Trace};
use std::sync::Arc;

/// The lane-batched simulator. Construction compiles the trace (shared
/// [`EventGraph`] lowering with [`CompiledSim`](super::CompiledSim));
/// [`eval_batch`](BatchedSim::eval_batch) evaluates K depth vectors in
/// one SoA Kahn walk. `Clone` duplicates the per-eval lane scratch; the
/// trace, the op-index maps and the compiled graph tables are shared.
#[derive(Clone)]
pub struct BatchedSim {
    trace: Arc<Trace>,
    opts: SimOptions,
    index: Arc<ChanOpIndex>,
    widths: Vec<u32>,
    graph: EventGraph,
    // --- per-eval lane-major scratch (resized to the batch width K) ---
    /// Lane count of the most recent batch.
    lanes: usize,
    /// Node commit times, lane-major: node `n`, lane `l` at `n*K + l`.
    time: Vec<u64>,
    /// Remaining in-degrees, lane-major.
    indeg: Vec<u8>,
    /// Per process per lane: ops committed.
    done: Vec<u32>,
    /// Per channel per lane: lane-resolved depth.
    depth: Vec<u32>,
    /// Per channel per lane: lane-resolved read latency.
    rd_lat: Vec<u64>,
    /// Worklist of (node, lane) readiness events: `node << 32 | lane`.
    queue: Vec<u64>,
    info: RunInfo,
}

impl BatchedSim {
    /// Compile a trace into the shared static event graph.
    pub fn new(trace: Arc<Trace>) -> BatchedSim {
        Self::with_options(trace, SimOptions::default())
    }

    /// [`new`](Self::new) with explicit [`SimOptions`].
    pub fn with_options(trace: Arc<Trace>, opts: SimOptions) -> BatchedSim {
        let widths: Vec<u32> = trace.channels.iter().map(|c| c.width_bits).collect();
        let index = Arc::new(ChanOpIndex::build(&trace));
        let graph = EventGraph::compile(&trace, &index);
        BatchedSim {
            trace,
            opts,
            index,
            widths,
            graph,
            lanes: 0,
            time: Vec::new(),
            indeg: Vec::new(),
            done: Vec::new(),
            depth: Vec::new(),
            rd_lat: Vec::new(),
            queue: Vec::new(),
            info: RunInfo::default(),
        }
    }

    /// The trace this simulator evaluates.
    pub fn trace(&self) -> &Arc<Trace> {
        &self.trace
    }

    /// Telemetry of the most recent call. After a batch this is the
    /// lane-summed view (every lane a full replay); per-lane telemetry
    /// comes back from [`eval_batch`](Self::eval_batch) directly.
    pub fn last_run(&self) -> RunInfo {
        self.info
    }

    /// Evaluate one FIFO depth configuration (a K = 1 batch).
    pub fn simulate(&mut self, depths: &[u32]) -> SimOutcome {
        let cfg: [Box<[u32]>; 1] = [depths.into()];
        let (out, run) = self
            .eval_batch(&cfg)
            .pop()
            .expect("K = 1 batch yields one lane");
        self.info = run;
        out
    }

    /// Evaluate K depth vectors in one lane-batched Kahn walk, returning
    /// each lane's full outcome (latency or per-lane blocked set) and
    /// telemetry, in input order. Batches may be ragged: successive calls
    /// with different K simply resize the lane scratch.
    pub fn eval_batch(&mut self, configs: &[Box<[u32]>]) -> Vec<(SimOutcome, RunInfo)> {
        let k = configs.len();
        if k == 0 {
            return Vec::new();
        }
        let trace = self.trace.clone();
        let nch = trace.channels.len();
        let nproc = trace.ops.len();
        let n_nodes = self.graph.n_nodes();
        for c in configs {
            assert_eq!(
                c.len(),
                nch,
                "configuration has {} depths, design has {} FIFOs",
                c.len(),
                nch
            );
        }
        self.lanes = k;
        // Lane-resolved depths and read latencies (the per-lane SRL↔BRAM
        // class sets each read edge's weight).
        self.depth.clear();
        self.depth.resize(nch * k, 0);
        self.rd_lat.clear();
        self.rd_lat.resize(nch * k, 0);
        for ch in 0..nch {
            let row = ch * k;
            for (l, c) in configs.iter().enumerate() {
                self.depth[row + l] = c[ch];
                self.rd_lat[row + l] =
                    super::read_latency(c[ch], self.widths[ch], self.opts.uniform_read_latency);
            }
        }
        // Broadcast the static in-degrees across all lanes, then add the
        // lane-parameterized depth edges: lane `l`'s write ordinal j ≥ d_l
        // waits on read j − d_l; ordinals past the read count wait on a
        // read that never happens, so their contribution is simply never
        // decremented (exactly the scalar backends' rule, per lane).
        self.indeg.clear();
        self.indeg.resize(n_nodes * k, 0);
        for (lane_row, &d0) in self.indeg.chunks_exact_mut(k).zip(self.graph.indeg0.iter()) {
            lane_row.fill(d0);
        }
        for ch in 0..nch {
            let wr = &self.graph.wr_node[ch];
            for (l, c) in configs.iter().enumerate() {
                let d = c[ch] as usize;
                if d < wr.len() {
                    for &n in &wr[d..] {
                        self.indeg[n as usize * k + l] += 1;
                    }
                }
            }
        }
        self.time.clear();
        self.time.resize(n_nodes * k, 0);
        self.done.clear();
        self.done.resize(nproc * k, 0);
        self.queue.clear();
        let roots = self.graph.roots.clone();
        for &r in roots.iter() {
            let row = r as usize * k;
            for l in 0..k {
                // `indeg == 0` guards the degenerate depth-0 case, where
                // even ordinal-0 writes carry a (cyclic) depth edge.
                if self.indeg[row + l] == 0 {
                    self.queue.push((r as u64) << 32 | l as u64);
                }
            }
        }
        self.propagate_lanes();
        // Per-lane outcome extraction + telemetry.
        let total_ops = trace.total_ops() as u64;
        self.info = RunInfo::default();
        let mut out = Vec::with_capacity(k);
        for l in 0..k {
            let committed: u64 = (0..nproc).map(|p| self.done[p * k + l] as u64).sum();
            let run = RunInfo {
                incremental: false,
                dirty_channels: 0,
                replayed_ops: committed,
                total_ops,
            };
            self.info.replayed_ops += committed;
            self.info.total_ops += total_ops;
            out.push((self.lane_outcome(&trace, l), run));
        }
        out
    }

    /// Drain the (node, lane) worklist: each pop commits one node in one
    /// lane with the scalar backends' exact formulas, then decrements that
    /// lane's successors. Program-order successors chain-follow when they
    /// were only waiting on us, so long compute runs commit without any
    /// queue traffic — per lane.
    fn propagate_lanes(&mut self) {
        let k = self.lanes;
        while let Some(e) = self.queue.pop() {
            let l = (e & 0xFFFF_FFFF) as usize;
            let mut n = (e >> 32) as usize;
            loop {
                let p = self.graph.node_proc[n] as usize;
                let code = self.graph.node_code[n];
                let is_write = code & WRITE_FLAG != 0;
                let ch = (code & !WRITE_FLAG) as usize;
                let j = self.graph.node_ord[n] as usize;
                let delay = self.graph.node_delay[n] as u64;
                let start = if n == self.graph.base[p] as usize {
                    delay
                } else {
                    self.time[(n - 1) * k + l] + 1 + delay
                };
                let t = if is_write {
                    let d = self.depth[ch * k + l] as usize;
                    if j >= d {
                        start.max(self.time[self.graph.rd_node[ch][j - d] as usize * k + l] + 1)
                    } else {
                        start
                    }
                } else {
                    start.max(
                        self.time[self.graph.wr_node[ch][j] as usize * k + l]
                            + self.rd_lat[ch * k + l],
                    )
                };
                self.time[n * k + l] = t;
                self.done[p * k + l] += 1;
                // Cross-process successor in the same lane: the read this
                // write feeds, or the write whose slot this read frees
                // (the lane-parameterized edge endpoint).
                if is_write {
                    if j < self.graph.rd_node[ch].len() {
                        let r = self.graph.rd_node[ch][j] as usize;
                        self.dec_lane(r, l);
                    }
                } else {
                    let w = j as u64 + self.depth[ch * k + l] as u64;
                    if (w as usize as u64) == w && (w as usize) < self.graph.wr_node[ch].len() {
                        let wn = self.graph.wr_node[ch][w as usize] as usize;
                        self.dec_lane(wn, l);
                    }
                }
                let nx = n + 1;
                if nx < self.graph.pend[p] as usize {
                    let slot = nx * k + l;
                    self.indeg[slot] -= 1;
                    if self.indeg[slot] == 0 {
                        n = nx;
                        continue;
                    }
                }
                break;
            }
        }
    }

    /// Decrement node `m`'s in-degree in lane `l`, queueing the (node,
    /// lane) event when it drains.
    #[inline]
    fn dec_lane(&mut self, m: usize, l: usize) {
        let slot = m * self.lanes + l;
        self.indeg[slot] -= 1;
        if self.indeg[slot] == 0 {
            self.queue.push((m as u64) << 32 | l as u64);
        }
    }

    /// Outcome extraction for one lane from its committed counters and
    /// time row (identical formulas and blocked-set order to the scalar
    /// backends).
    fn lane_outcome(&self, trace: &Trace, l: usize) -> SimOutcome {
        let k = self.lanes;
        let nproc = trace.ops.len();
        let mut blocked = Vec::new();
        for p in 0..nproc {
            let done = self.done[p * k + l] as usize;
            if done < trace.ops[p].len() {
                let op = trace.ops[p][done];
                blocked.push(BlockInfo {
                    process: p,
                    channel: op.chan(),
                    on_write: op.is_write(),
                });
            }
        }
        if !blocked.is_empty() {
            return SimOutcome::Deadlock { blocked };
        }
        let mut latency = 0u64;
        for p in 0..nproc {
            let done_t = if trace.ops[p].is_empty() {
                trace.tail_delays[p]
            } else {
                self.time[(self.graph.pend[p] as usize - 1) * k + l] + 1 + trace.tail_delays[p]
            };
            latency = latency.max(done_t);
        }
        SimOutcome::Done { latency }
    }

    /// Evaluate with per-channel occupancy/stall statistics (allocating
    /// convenience over
    /// [`simulate_with_stats_into`](Self::simulate_with_stats_into)).
    pub fn simulate_with_stats(&mut self, depths: &[u32]) -> (SimOutcome, ChannelStats) {
        let mut stats = ChannelStats::new();
        let out = self.simulate_with_stats_into(depths, &mut stats);
        (out, stats)
    }

    /// Evaluate one configuration (a K = 1 batch) and collect statistics
    /// into a caller-owned buffer. With one lane the lane-major arrays
    /// collapse to the scalar layout, so the post-passes mirror
    /// [`CompiledSim`](super::CompiledSim)'s (and therefore
    /// [`FastSim`](super::fast::FastSim)'s) exactly.
    pub fn simulate_with_stats_into(
        &mut self,
        depths: &[u32],
        stats: &mut ChannelStats,
    ) -> SimOutcome {
        let outcome = self.simulate(depths);
        debug_assert_eq!(self.lanes, 1);
        let trace = self.trace.clone();
        let index = self.index.clone();
        let nch = trace.channels.len();
        stats.max_occupancy.clear();
        stats.max_occupancy.resize(nch, 0);
        stats.write_stall.clear();
        stats.write_stall.resize(nch, 0);
        stats.read_stall.clear();
        stats.read_stall.resize(nch, 0);
        // Occupancy: per channel, committed writes/reads each commit in
        // nondecreasing ordinal time, so a sorted merge tracks occupancy
        // (writes before reads at equal times, as in FastSim).
        for ch in 0..nch {
            let w = index.writer[ch];
            let wrc = if w == NONE {
                0
            } else {
                index.wr_ops[ch].partition_point(|&i| i < self.done[w as usize])
            };
            let r = index.reader[ch];
            let rdc = if r == NONE {
                0
            } else {
                index.rd_ops[ch].partition_point(|&i| i < self.done[r as usize])
            };
            let (mut wi, mut ri) = (0usize, 0usize);
            let mut occ: i64 = 0;
            let mut max_occ: i64 = 0;
            while wi < wrc || ri < rdc {
                let take_write = wi < wrc
                    && (ri >= rdc
                        || self.time[self.graph.wr_node[ch][wi] as usize]
                            <= self.time[self.graph.rd_node[ch][ri] as usize]);
                if take_write {
                    occ += 1;
                    max_occ = max_occ.max(occ);
                    wi += 1;
                } else {
                    occ -= 1;
                    ri += 1;
                }
            }
            stats.max_occupancy[ch] = max_occ.max(0) as u32;
        }
        // Stalls: unconstrained start vs committed time, per process.
        for (pid, ops) in trace.ops.iter().enumerate() {
            let committed = self.done[pid] as usize;
            let b = self.graph.base[pid] as usize;
            let mut prev: u64 = NO_TIME;
            for (k, op) in ops[..committed].iter().enumerate() {
                let ch = op.chan();
                let start = if prev == NO_TIME {
                    op.delay as u64
                } else {
                    prev + 1 + op.delay as u64
                };
                let commit = self.time[b + k];
                let stall = commit.saturating_sub(start);
                if op.is_write() {
                    stats.write_stall[ch] += stall;
                } else {
                    stats.read_stall[ch] += stall;
                }
                prev = commit;
            }
        }
        outcome
    }
}

impl SimBackend for BatchedSim {
    fn name(&self) -> &'static str {
        "batched"
    }
    fn trace(&self) -> &Arc<Trace> {
        BatchedSim::trace(self)
    }
    fn simulate(&mut self, depths: &[u32]) -> SimOutcome {
        BatchedSim::simulate(self, depths)
    }
    fn simulate_with_stats_into(&mut self, depths: &[u32], stats: &mut ChannelStats) -> SimOutcome {
        BatchedSim::simulate_with_stats_into(self, depths, stats)
    }
    fn eval_batch(&mut self, configs: &[Box<[u32]>]) -> Vec<(SimOutcome, RunInfo)> {
        BatchedSim::eval_batch(self, configs)
    }
    fn last_run(&self) -> RunInfo {
        BatchedSim::last_run(self)
    }
    fn set_incremental(&mut self, _on: bool) {
        // Lane batching replaces delta reuse: every batch is evaluated
        // cold, so there is no retained schedule to toggle.
    }
    fn clone_box(&self) -> Box<dyn SimBackend> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DesignBuilder, Expr};
    use crate::sim::fast::FastSim;
    use crate::trace::collect_trace;

    fn pipe_design(n: u64) -> crate::ir::Design {
        let mut b = DesignBuilder::new("pipe", 0);
        let c = b.channel("c", 32);
        b.process("prod", move |p| {
            p.for_n(n, |p, _| p.write(c, Expr::c(1)));
        });
        b.process("cons", move |p| {
            p.for_n(n, |p, _| {
                let _ = p.read(c);
            });
        });
        b.build()
    }

    fn fig2_design() -> crate::ir::Design {
        let mut b = DesignBuilder::new("mult_by_2", 1);
        let x = b.channel("x", 32);
        let y = b.channel("y", 32);
        b.process("producer", |p| {
            p.for_expr(Expr::arg(0), |p, _| p.write(x, Expr::c(1)));
            p.for_expr(Expr::arg(0), |p, _| p.write(y, Expr::c(1)));
        });
        b.process("consumer", |p| {
            p.for_expr(Expr::arg(0), |p, _| {
                let _ = p.read(x);
                let _ = p.read(y);
            });
        });
        b.build()
    }

    #[test]
    fn pipe_latency_formula() {
        let d = pipe_design(8);
        let t = Arc::new(collect_trace(&d, &[]).unwrap());
        let mut s = BatchedSim::new(t);
        assert_eq!(s.simulate(&[8]), SimOutcome::Done { latency: 9 });
        assert_eq!(s.simulate(&[2]).latency(), Some(9));
        assert_eq!(s.simulate(&[1]).latency(), Some(16));
    }

    #[test]
    fn mixed_batch_matches_fast_per_lane() {
        let design = fig2_design();
        let t = Arc::new(collect_trace(&design, &[16]).unwrap());
        let mut batched = BatchedSim::new(t.clone());
        let mut fast = FastSim::new(t);
        // One batch mixing feasible lanes, deadlocked lanes (with distinct
        // blocked sets) and an exact duplicate lane.
        let cfgs: Vec<Box<[u32]>> = [
            [2u32, 2],
            [15, 2],
            [16, 2],
            [14, 16],
            [16, 16],
            [2, 2], // duplicate of lane 0
        ]
        .iter()
        .map(|c| c.to_vec().into_boxed_slice())
        .collect();
        let outs = batched.eval_batch(&cfgs);
        assert_eq!(outs.len(), cfgs.len());
        for (l, (cfg, (out, run))) in cfgs.iter().zip(&outs).enumerate() {
            assert_eq!(
                *out,
                fast.simulate(cfg),
                "lane {l} cfg {cfg:?} (full outcome incl. blocked set)"
            );
            assert!(!run.incremental);
            assert_eq!(run.total_ops, 64);
        }
        assert_eq!(outs[0].0, outs[5].0, "duplicate lanes must agree");
        assert!(outs[0].0.is_deadlock() && !outs[2].0.is_deadlock());
    }

    #[test]
    fn ragged_batches_reuse_scratch() {
        // Successive batches of different widths on one instance: the
        // lane-major scratch must resize without leaking stale state.
        let d = pipe_design(32);
        let t = Arc::new(collect_trace(&d, &[]).unwrap());
        let mut batched = BatchedSim::new(t.clone());
        let mut fast = FastSim::new(t);
        for k in [5usize, 2, 7, 1, 3] {
            let cfgs: Vec<Box<[u32]>> = (0..k)
                .map(|i| vec![(1 + i as u32 * 3) % 33 + 1].into_boxed_slice())
                .collect();
            let outs = batched.eval_batch(&cfgs);
            for (cfg, (out, _)) in cfgs.iter().zip(&outs) {
                assert_eq!(*out, fast.simulate(cfg), "k={k} cfg {cfg:?}");
            }
        }
        assert!(batched.eval_batch(&[]).is_empty());
    }

    #[test]
    fn lane_telemetry_counts_committed_ops() {
        let design = fig2_design();
        let t = Arc::new(collect_trace(&design, &[8]).unwrap());
        let mut s = BatchedSim::new(t.clone());
        let total = t.total_ops() as u64;
        let cfgs: Vec<Box<[u32]>> = vec![
            vec![8u32, 2].into_boxed_slice(),
            vec![2u32, 2].into_boxed_slice(),
        ];
        let outs = s.eval_batch(&cfgs);
        // Feasible lane commits every op; the deadlocked lane fewer.
        assert_eq!(outs[0].1.replayed_ops, total);
        assert!(outs[1].1.replayed_ops < total);
        assert_eq!(s.last_run().total_ops, 2 * total);
    }

    #[test]
    fn stats_match_fast_exactly() {
        let mut b = DesignBuilder::new("slow", 0);
        let c = b.channel("c", 32);
        b.process("p", |p| {
            p.for_n(8, |p, _| p.write(c, Expr::c(0)));
        });
        b.process("q", |p| {
            p.for_n(8, |p, _| {
                p.delay(3);
                let _ = p.read(c);
            });
        });
        let d = b.build();
        let t = Arc::new(collect_trace(&d, &[]).unwrap());
        let mut batched = BatchedSim::new(t.clone());
        let mut fast = FastSim::new(t);
        for cfg in [[8u32], [2], [1]] {
            let (bo, bs) = batched.simulate_with_stats(&cfg);
            let (fo, fs) = fast.simulate_with_stats(&cfg);
            assert_eq!(bo, fo, "cfg {cfg:?}");
            assert_eq!(bs.max_occupancy, fs.max_occupancy, "cfg {cfg:?}");
            assert_eq!(bs.write_stall, fs.write_stall, "cfg {cfg:?}");
            assert_eq!(bs.read_stall, fs.read_stall, "cfg {cfg:?}");
        }
    }
}
