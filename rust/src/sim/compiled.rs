//! The graph-compiled trace simulator — the LightningSimV2 analog.
//!
//! Where [`FastSim`](super::fast::FastSim) *interprets* the trace on every
//! evaluation (event-driven replay with process cursors, parking and
//! wake-ups), `CompiledSim` **compiles the trace once** into a static
//! event graph and evaluates each FIFO configuration as a longest-path
//! propagation over it:
//!
//! - **Nodes** are channel op commits — one node per trace op, numbered
//!   contiguously per process (node `base[p] + k` is op `k` of process
//!   `p`), each carrying its channel, ordinal, delay and direction.
//! - **Edges** are the cycle-semantics constraints:
//!   - *program order*: op `k` starts no earlier than
//!     `commit(k−1) + 1 + delay(k)` — a static edge to the previous node;
//!   - *read-after-write*: read ordinal `j` on channel `c` waits
//!     `rl(c)` cycles on write `j` — statically known endpoints, with a
//!     per-channel weight that depends only on the depth's SRL↔BRAM
//!     class;
//!   - *full-FIFO*: write ordinal `j` on a depth-`d` channel waits one
//!     cycle on read `j − d` — the only **depth-parameterized** edges,
//!     re-derived per configuration from the compiled per-channel
//!     ordinal→node tables.
//!
//! A configuration is evaluated by Kahn propagation: static in-degrees
//! (program order + read-after-write) are restored with one `memcpy`,
//! the depth edges mark each channel's write tail, and a worklist commits
//! nodes whose predecessors are all committed, taking the `max` of their
//! arrival times — the same unique least fixpoint the event-driven and
//! golden simulators compute, so outcomes (latency, deadlock verdict
//! *and* blocked sets) are bit-identical to [`FastSim`]
//! (`tests/backend_conformance.rs` enforces this). A deadlock is simply a
//! node whose in-degree never reaches zero; the blocked set falls out of
//! the per-process committed counters.
//!
//! # Depth-edge-only incremental re-evaluation
//!
//! Between evaluations only the depth-parameterized edges (and the
//! per-channel read-latency weights) can change, so `CompiledSim` retains
//! the node commit times and re-evaluates a delta by invalidating exactly
//! the region a depth change can reach: the same per-process checkpoint
//! fixpoint as [`FastSim`]'s delta replay (seeded from dirty channels,
//! propagated over [`ChanOpIndex`]), then a Kahn pass restricted to the
//! invalid node suffixes, reading retained times across the
//! valid/invalid boundary. This composes with the engine's locality-aware
//! dispatch and PR 2's delta semantics: the same [`RunInfo`] telemetry
//! (incremental flag, dirty channels, replayed vs total ops) feeds the
//! same engine counters, whichever backend is selected.
//!
//! [`FastSim`]: super::fast::FastSim

use super::fast::{BlockInfo, ChannelStats, RunInfo, SimOutcome};
use super::{SimBackend, SimOptions};
use crate::trace::{ChanOpIndex, Trace};
use std::sync::Arc;

pub(crate) const WRITE_FLAG: u32 = 1 << 31;
pub(crate) const NONE: u32 = u32::MAX;
pub(crate) const NO_TIME: u64 = u64::MAX;

/// Fall back to a full evaluation when the checkpoint fixpoint shows at
/// least this percentage of nodes must be recomputed anyway (same gate as
/// [`FastSim`](super::fast::FastSim)'s delta replay).
const INCR_FALLBACK_PCT: u64 = 90;

/// The static event-graph lowering of a trace — the compile product both
/// [`CompiledSim`] (one depth vector per walk) and
/// [`BatchedSim`](super::batched::BatchedSim) (K depth-vector lanes per
/// walk) evaluate. Keeping the lowering in ONE place is deliberate: a
/// divergence in node numbering, ordinals or static in-degrees between
/// the two graph backends would break their bit-identity in ways only
/// the conformance fuzzers could expose. All tables are `Arc`-shared, so
/// cloning an `EventGraph` (or a simulator holding its tables) duplicates
/// pointers, never the compiled graph.
#[derive(Clone)]
pub(crate) struct EventGraph {
    /// First node id of each process (node = base[p] + op index).
    pub(crate) base: Arc<[u32]>,
    /// One-past-last node id of each process.
    pub(crate) pend: Arc<[u32]>,
    /// Per node: channel | WRITE_FLAG.
    pub(crate) node_code: Arc<[u32]>,
    /// Per node: compute delay before the op.
    pub(crate) node_delay: Arc<[u32]>,
    /// Per node: ordinal among its channel's same-kind ops.
    pub(crate) node_ord: Arc<[u32]>,
    /// Per node: owning process.
    pub(crate) node_proc: Arc<[u32]>,
    /// Per channel: node ids of its writes/reads, by ordinal.
    pub(crate) wr_node: Arc<[Box<[u32]>]>,
    pub(crate) rd_node: Arc<[Box<[u32]>]>,
    /// Static in-degrees: program order + read-after-write edges only
    /// (the depth edges are added per evaluation).
    pub(crate) indeg0: Arc<[u8]>,
    /// Nodes that can have in-degree 0: process-first writes.
    pub(crate) roots: Arc<[u32]>,
}

impl EventGraph {
    /// Lower a trace into the static event graph (see the module docs
    /// for the node/edge semantics).
    pub(crate) fn compile(trace: &Trace, index: &ChanOpIndex) -> EventGraph {
        let nch = trace.channels.len();
        let nproc = trace.ops.len();
        let mut base = Vec::with_capacity(nproc);
        let mut pend = Vec::with_capacity(nproc);
        let mut n_nodes = 0usize;
        for ops in &trace.ops {
            base.push(n_nodes as u32);
            n_nodes += ops.len();
            pend.push(n_nodes as u32);
        }
        let mut node_code = Vec::with_capacity(n_nodes);
        let mut node_delay = Vec::with_capacity(n_nodes);
        let mut node_ord = Vec::with_capacity(n_nodes);
        let mut node_proc = Vec::with_capacity(n_nodes);
        let mut indeg0 = Vec::with_capacity(n_nodes);
        let mut roots = Vec::new();
        for (p, ops) in trace.ops.iter().enumerate() {
            for (k, op) in ops.iter().enumerate() {
                let flag = if op.is_write() { WRITE_FLAG } else { 0 };
                node_code.push(op.chan() as u32 | flag);
                node_delay.push(op.delay);
                node_ord.push(index.op_ord[p][k]);
                node_proc.push(p as u32);
                // Static in-degree: the program-order edge (k > 0) plus,
                // for reads, the read-after-write edge (write `j` always
                // exists — trace collection only records matched reads).
                indeg0.push(u8::from(k > 0) + u8::from(!op.is_write()));
                if k == 0 && op.is_write() {
                    // A process-first write has channel ordinal 0 (SPSC:
                    // all writes on its channel come from this process),
                    // so it carries no depth edge for any depth ≥ 1 —
                    // the only way a node starts at in-degree 0.
                    roots.push(base[p]);
                }
            }
        }
        let wr_node: Vec<Box<[u32]>> = (0..nch)
            .map(|c| {
                index.wr_ops[c]
                    .iter()
                    .map(|&op_i| base[index.writer[c] as usize] + op_i)
                    .collect()
            })
            .collect();
        let rd_node: Vec<Box<[u32]>> = (0..nch)
            .map(|c| {
                index.rd_ops[c]
                    .iter()
                    .map(|&op_i| base[index.reader[c] as usize] + op_i)
                    .collect()
            })
            .collect();
        EventGraph {
            base: base.into(),
            pend: pend.into(),
            node_code: node_code.into(),
            node_delay: node_delay.into(),
            node_ord: node_ord.into(),
            node_proc: node_proc.into(),
            wr_node: wr_node.into(),
            rd_node: rd_node.into(),
            indeg0: indeg0.into(),
            roots: roots.into(),
        }
    }

    /// Total node count (one node per trace op).
    pub(crate) fn n_nodes(&self) -> usize {
        self.node_code.len()
    }

    /// One topological order of the **unconstrained** (infinite-depth)
    /// event DAG — program-order and read-after-write edges only. The
    /// unconstrained run always completes (writes never block, and every
    /// recorded read has its matching write), so the walk covers every
    /// node. This is the substrate for the analytic depth-bounds pass.
    pub(crate) fn topo_order(&self) -> Vec<u32> {
        let n = self.n_nodes();
        let mut topo = Vec::with_capacity(n);
        let mut indeg: Vec<u8> = self.indeg0.to_vec();
        let mut queue: Vec<u32> = self.roots.to_vec();
        while let Some(start) = queue.pop() {
            let mut v = start as usize;
            loop {
                topo.push(v as u32);
                let code = self.node_code[v];
                if code & WRITE_FLAG != 0 {
                    let ch = (code & !WRITE_FLAG) as usize;
                    let j = self.node_ord[v] as usize;
                    if j < self.rd_node[ch].len() {
                        let r = self.rd_node[ch][j] as usize;
                        indeg[r] -= 1;
                        if indeg[r] == 0 {
                            queue.push(r as u32);
                        }
                    }
                }
                // Program-order successor: chain-follow when it was only
                // waiting on us (mirrors the evaluation walk).
                let p = self.node_proc[v] as usize;
                let nx = v + 1;
                if nx < self.pend[p] as usize {
                    indeg[nx] -= 1;
                    if indeg[nx] == 0 {
                        v = nx;
                        continue;
                    }
                }
                break;
            }
        }
        debug_assert_eq!(topo.len(), n, "unconstrained DAG walk must cover all nodes");
        topo
    }

    /// Analytic per-channel depth bounds mined from the unconstrained
    /// event DAG. Returns `(floors, caps)`:
    ///
    /// - `floors[c]`: every configuration with `depth[c] < floors[c]`
    ///   deadlocks, **regardless of every other channel's depth**. Write
    ///   ordinal `w` at depth `d` carries a full-FIFO edge from read
    ///   `w − d`; if some write `w ≥ j + d` is already an *ancestor* of
    ///   read `j` in the unconstrained DAG, that edge closes a cycle
    ///   (the write needs a later read of its own channel committed
    ///   first, and reads are program-ordered in the single reader), so
    ///   `d` must satisfy `d ≥ W_anc(j) − j` for every read ordinal `j`,
    ///   where `W_anc(j)` is one past the largest `c`-write ordinal among
    ///   read `j`'s ancestors. Writes past the recorded read count add
    ///   the trailing term `n_wr − n_rd` (they wait on reads that never
    ///   happen). Unwritten channels get floor 0 (any depth, even 0, is
    ///   trivially fine).
    /// - `caps[c]`: for every `d ≥ caps[c]` the schedule is identical to
    ///   the unconstrained one **on this channel's edges**, again for any
    ///   other depths and either SRL/BRAM read-latency class: with
    ///   `W_free(j)` the first `c`-write ordinal that *depends on* read
    ///   `j` (or `n_wr` if none), `d ≥ min(W_free(j)+1, n_wr) − j` makes
    ///   the full-FIFO edge of every write `w = j + d` either absent
    ///   (`w ≥ n_wr`) or implied through a ≥ 2-edge DAG path (each edge
    ///   costs ≥ 1 cycle, covering the BRAM-class weight-2 edge), so the
    ///   edge can never move the fixpoint. The trailing term keeps the
    ///   never-satisfied edges of a write-heavy channel out of the
    ///   capped region. `floors[c] ≤ caps[c]` always (a write cannot be
    ///   both an ancestor and a strict dependant of the same read).
    pub(crate) fn analytic_depth_bounds(&self) -> (Vec<u32>, Vec<u32>) {
        let n = self.n_nodes();
        let nch = self.wr_node.len();
        let topo = self.topo_order();
        let mut floors = vec![0u32; nch];
        let mut caps = vec![0u32; nch];
        // Reused per-channel DP tables: 1 + the largest ch-write (`anc`) /
        // ch-read (`ranc`) ordinal among a node's ancestors (self
        // included), 0 if none. Every slot is overwritten on every pass,
        // so no clearing between channels.
        let mut anc: Vec<u32> = vec![0; n];
        let mut ranc: Vec<u32> = vec![0; n];
        for ch in 0..nch {
            let n_wr = self.wr_node[ch].len() as u32;
            let n_rd = self.rd_node[ch].len() as u32;
            let trailing = n_wr.saturating_sub(n_rd);
            if n_wr == 0 {
                continue; // never written: floor 0, cap 0
            }
            if n_rd == 0 {
                // Every write past the depth waits forever.
                floors[ch] = n_wr;
                caps[ch] = n_wr;
                continue;
            }
            let mut floor_core = 0u32;
            for &tn in &topo {
                let v = tn as usize;
                let p = self.node_proc[v] as usize;
                let code = self.node_code[v];
                let is_write = code & WRITE_FLAG != 0;
                let c2 = (code & !WRITE_FLAG) as usize;
                let j = self.node_ord[v] as u32;
                let (mut a, mut r) = if v > self.base[p] as usize {
                    (anc[v - 1], ranc[v - 1])
                } else {
                    (0, 0)
                };
                if !is_write {
                    let w = self.wr_node[c2][j as usize] as usize;
                    a = a.max(anc[w]);
                    r = r.max(ranc[w]);
                }
                if c2 == ch {
                    if is_write {
                        a = a.max(j + 1);
                    } else {
                        // `a` here is W_anc(j); the RAW edge from write
                        // `j` guarantees a ≥ j + 1.
                        floor_core = floor_core.max(a - j);
                        r = r.max(j + 1);
                    }
                }
                anc[v] = a;
                ranc[v] = r;
            }
            // Two-pointer over the writer's program order: ranc at the
            // ch-writes is nondecreasing in ordinal, so W_free(j) only
            // moves forward as j grows.
            let wr = &self.wr_node[ch];
            let mut w = 0usize;
            let mut cap_core = 0u32;
            for j in 0..n_rd {
                while w < wr.len() && ranc[wr[w] as usize] < j + 1 {
                    w += 1;
                }
                let lim = if w < wr.len() {
                    (w as u32 + 1).min(n_wr)
                } else {
                    n_wr
                };
                cap_core = cap_core.max(lim - j);
                if w == wr.len() {
                    break; // lim − j only shrinks from here on
                }
            }
            floors[ch] = floor_core.max(trailing).max(1);
            caps[ch] = cap_core.max(trailing);
            debug_assert!(floors[ch] <= caps[ch], "floor must not exceed cap");
        }
        (floors, caps)
    }
}

/// The graph-compiled simulator. Construction compiles the trace;
/// [`simulate`](CompiledSim::simulate) evaluates one depth vector per
/// call with zero heap allocation. `Clone` duplicates the per-eval
/// scratch and retained times; the trace, the op-index maps and the
/// compiled graph tables are shared.
#[derive(Clone)]
pub struct CompiledSim {
    trace: Arc<Trace>,
    opts: SimOptions,
    index: Arc<ChanOpIndex>,
    widths: Vec<u32>,
    /// First node id of each process (node = base[p] + op index).
    base: Arc<[u32]>,
    /// One-past-last node id of each process.
    pend: Arc<[u32]>,
    /// Per node: channel | WRITE_FLAG.
    node_code: Arc<[u32]>,
    /// Per node: compute delay before the op.
    node_delay: Arc<[u32]>,
    /// Per node: ordinal among its channel's same-kind ops.
    node_ord: Arc<[u32]>,
    /// Per node: owning process.
    node_proc: Arc<[u32]>,
    /// Per channel: node ids of its writes/reads, by ordinal.
    wr_node: Arc<[Box<[u32]>]>,
    rd_node: Arc<[Box<[u32]>]>,
    /// Static in-degrees: program order + read-after-write edges only
    /// (the depth edges are added per evaluation).
    indeg0: Arc<[u8]>,
    /// Nodes that can have in-degree 0: process-first writes.
    roots: Arc<[u32]>,
    // --- per-eval scratch / retained state ---
    /// Node commit times (retained between runs for delta re-evaluation).
    time: Vec<u64>,
    indeg: Vec<u8>,
    queue: Vec<u32>,
    /// Per process: ops committed by the most recent evaluation.
    done: Vec<u32>,
    /// Per process: first op index recomputed by the current delta pass
    /// (0 on cold evaluations — everything is recomputed).
    restart: Vec<u32>,
    rd_lat: Vec<u64>,
    incremental: bool,
    last_depths: Vec<u32>,
    last_outcome: Option<SimOutcome>,
    info: RunInfo,
    /// Scratch: per-process invalidation checkpoint (op index).
    ckpt: Vec<u32>,
    wl: Vec<u32>,
    in_wl: Vec<bool>,
}

impl CompiledSim {
    /// Compile a trace into the static event graph.
    pub fn new(trace: Arc<Trace>) -> CompiledSim {
        Self::with_options(trace, SimOptions::default())
    }

    /// [`new`](Self::new) with explicit [`SimOptions`].
    pub fn with_options(trace: Arc<Trace>, opts: SimOptions) -> CompiledSim {
        let nch = trace.channels.len();
        let nproc = trace.ops.len();
        let widths: Vec<u32> = trace.channels.iter().map(|c| c.width_bits).collect();
        let index = Arc::new(ChanOpIndex::build(&trace));
        let g = EventGraph::compile(&trace, &index);
        let n_nodes = g.n_nodes();
        CompiledSim {
            trace,
            opts,
            index,
            widths,
            base: g.base,
            pend: g.pend,
            node_code: g.node_code,
            node_delay: g.node_delay,
            node_ord: g.node_ord,
            node_proc: g.node_proc,
            wr_node: g.wr_node,
            rd_node: g.rd_node,
            indeg0: g.indeg0,
            roots: g.roots,
            time: vec![0; n_nodes],
            indeg: vec![0; n_nodes],
            queue: Vec::with_capacity(nproc.max(16)),
            done: vec![0; nproc],
            restart: vec![0; nproc],
            rd_lat: vec![0; nch],
            incremental: true,
            last_depths: Vec::with_capacity(nch),
            last_outcome: None,
            info: RunInfo::default(),
            ckpt: vec![0; nproc],
            wl: Vec::with_capacity(nproc),
            in_wl: vec![false; nproc],
        }
    }

    /// The trace this simulator evaluates.
    pub fn trace(&self) -> &Arc<Trace> {
        &self.trace
    }

    /// Enable/disable retained-time delta re-evaluation (on by default).
    pub fn set_incremental(&mut self, on: bool) {
        self.incremental = on;
        if !on {
            self.last_outcome = None;
            self.last_depths.clear();
        }
    }

    /// Telemetry of the most recent evaluation (same semantics as
    /// [`FastSim::last_run`](super::fast::FastSim::last_run)).
    pub fn last_run(&self) -> RunInfo {
        self.info
    }

    /// Evaluate one FIFO depth configuration.
    pub fn simulate(&mut self, depths: &[u32]) -> SimOutcome {
        self.run(depths)
    }

    /// Evaluate with per-channel occupancy/stall statistics (allocating
    /// convenience over
    /// [`simulate_with_stats_into`](Self::simulate_with_stats_into)).
    pub fn simulate_with_stats(&mut self, depths: &[u32]) -> (SimOutcome, ChannelStats) {
        let mut stats = ChannelStats::new();
        let out = self.simulate_with_stats_into(depths, &mut stats);
        (out, stats)
    }

    /// Evaluate and collect statistics into a caller-owned buffer. The
    /// post-passes read the retained node times through the compiled
    /// ordinal→node tables, mirroring [`FastSim`]'s exactly.
    ///
    /// [`FastSim`]: super::fast::FastSim
    pub fn simulate_with_stats_into(
        &mut self,
        depths: &[u32],
        stats: &mut ChannelStats,
    ) -> SimOutcome {
        let outcome = self.run(depths);
        let trace = self.trace.clone();
        let index = self.index.clone();
        let nch = trace.channels.len();
        stats.max_occupancy.clear();
        stats.max_occupancy.resize(nch, 0);
        stats.write_stall.clear();
        stats.write_stall.resize(nch, 0);
        stats.read_stall.clear();
        stats.read_stall.resize(nch, 0);
        // Occupancy: per channel, committed writes/reads each commit in
        // nondecreasing ordinal time, so a sorted merge tracks occupancy
        // (writes before reads at equal times, as in FastSim).
        for ch in 0..nch {
            let w = index.writer[ch];
            let wrc = if w == NONE {
                0
            } else {
                index.wr_ops[ch].partition_point(|&i| i < self.done[w as usize])
            };
            let r = index.reader[ch];
            let rdc = if r == NONE {
                0
            } else {
                index.rd_ops[ch].partition_point(|&i| i < self.done[r as usize])
            };
            let (mut wi, mut ri) = (0usize, 0usize);
            let mut occ: i64 = 0;
            let mut max_occ: i64 = 0;
            while wi < wrc || ri < rdc {
                let take_write = wi < wrc
                    && (ri >= rdc
                        || self.time[self.wr_node[ch][wi] as usize]
                            <= self.time[self.rd_node[ch][ri] as usize]);
                if take_write {
                    occ += 1;
                    max_occ = max_occ.max(occ);
                    wi += 1;
                } else {
                    occ -= 1;
                    ri += 1;
                }
            }
            stats.max_occupancy[ch] = max_occ.max(0) as u32;
        }
        // Stalls: unconstrained start vs committed time, per process.
        for (pid, ops) in trace.ops.iter().enumerate() {
            let committed = self.done[pid] as usize;
            let b = self.base[pid] as usize;
            let mut prev: u64 = NO_TIME;
            for (k, op) in ops[..committed].iter().enumerate() {
                let ch = op.chan();
                let start = if prev == NO_TIME {
                    op.delay as u64
                } else {
                    prev + 1 + op.delay as u64
                };
                let commit = self.time[b + k];
                let stall = commit.saturating_sub(start);
                if op.is_write() {
                    stats.write_stall[ch] += stall;
                } else {
                    stats.read_stall[ch] += stall;
                }
                prev = commit;
            }
        }
        outcome
    }

    /// Dispatch one evaluation: delta pass against the retained times
    /// when possible, full graph pass otherwise.
    fn run(&mut self, depths: &[u32]) -> SimOutcome {
        let nch = self.trace.channels.len();
        assert_eq!(
            depths.len(),
            nch,
            "configuration has {} depths, design has {} FIFOs",
            depths.len(),
            nch
        );
        self.info = RunInfo {
            total_ops: self.trace.total_ops() as u64,
            ..RunInfo::default()
        };
        let attempt = if self.incremental && self.last_outcome.is_some() {
            self.try_incremental(depths)
        } else {
            None
        };
        let out = match attempt {
            Some(out) => out,
            None => self.eval_cold(depths),
        };
        if self.incremental {
            self.last_depths.clear();
            self.last_depths.extend_from_slice(depths);
            self.last_outcome = Some(out.clone());
        }
        out
    }

    /// Cold path: restore static in-degrees, add the depth edges, and
    /// propagate the whole graph.
    fn eval_cold(&mut self, depths: &[u32]) -> SimOutcome {
        let trace = self.trace.clone();
        let nch = trace.channels.len();
        for ch in 0..nch {
            self.rd_lat[ch] =
                super::read_latency(depths[ch], self.widths[ch], self.opts.uniform_read_latency);
        }
        self.indeg.copy_from_slice(&self.indeg0);
        // Depth edges: write ordinal j ≥ d waits on read j − d. Ordinals
        // past the read count wait on a read that never happens — their
        // in-degree contribution is simply never decremented.
        for ch in 0..nch {
            let d = depths[ch] as usize;
            let wr = &self.wr_node[ch];
            if d < wr.len() {
                for &n in &wr[d..] {
                    self.indeg[n as usize] += 1;
                }
            }
        }
        for v in &mut self.done {
            *v = 0;
        }
        for v in &mut self.restart {
            *v = 0;
        }
        self.queue.clear();
        let roots = self.roots.clone();
        for &r in roots.iter() {
            // `indeg == 0` guards the degenerate depth-0 case, where even
            // ordinal-0 writes carry a (cyclic) depth edge.
            if self.indeg[r as usize] == 0 {
                self.queue.push(r);
            }
        }
        let pops = self.propagate(depths);
        self.info.replayed_ops = pops;
        self.outcome(&trace)
    }

    /// Delta path: seed invalidation from the dirty channels, run the
    /// per-process checkpoint fixpoint (identical rules to `FastSim`'s
    /// delta replay), then propagate only the invalid node suffixes,
    /// reading retained times across the boundary. Returns `None` when a
    /// full pass is the better choice.
    fn try_incremental(&mut self, depths: &[u32]) -> Option<SimOutcome> {
        let trace = self.trace.clone();
        let index = self.index.clone();
        let nch = trace.channels.len();
        let nproc = trace.ops.len();

        // Shared delta-invalidation core (the SAME implementation FastSim
        // runs — see [`super::delta_checkpoints`]): dirty-channel seeding
        // against the retained `rd_lat`, then the checkpoint fixpoint
        // over [`ChanOpIndex`].
        let n_dirty = super::delta_checkpoints(
            &trace,
            &index,
            &self.last_depths,
            depths,
            &self.rd_lat,
            &self.widths,
            self.opts.uniform_read_latency,
            &mut self.ckpt,
            &mut self.wl,
            &mut self.in_wl,
        );
        self.info.dirty_channels = n_dirty;
        if n_dirty == 0 {
            self.info.incremental = true;
            return self.last_outcome.clone();
        }

        // Cost gate: fall back to the plain full pass when (almost)
        // everything is invalid anyway.
        let total = self.info.total_ops;
        let invalid = super::invalid_ops(&trace, &self.ckpt);
        if invalid * 100 >= total * INCR_FALLBACK_PCT {
            self.info.dirty_channels = 0;
            return None;
        }

        // Invalid region: everything from min(checkpoint, committed) —
        // previously-uncommitted nodes are always re-attempted, since a
        // depth change elsewhere may have unblocked them.
        for ch in 0..nch {
            self.rd_lat[ch] =
                super::read_latency(depths[ch], self.widths[ch], self.opts.uniform_read_latency);
        }
        for p in 0..nproc {
            self.restart[p] = self.ckpt[p].min(self.done[p]);
        }
        self.queue.clear();
        for p in 0..nproc {
            let restart = self.restart[p] as usize;
            let len = trace.ops[p].len();
            let b = self.base[p] as usize;
            for k in restart..len {
                let n = b + k;
                let code = self.node_code[n];
                let is_write = code & WRITE_FLAG != 0;
                let ch = (code & !WRITE_FLAG) as usize;
                let j = self.node_ord[n] as usize;
                // In-degree counts only *invalid* predecessors; valid
                // ones keep their retained times and are read directly.
                let mut dg: u8 = u8::from(k > restart);
                if is_write {
                    let d = depths[ch] as u64;
                    if j as u64 >= d {
                        let need = (j as u64 - d) as usize;
                        if need >= self.rd_node[ch].len() {
                            dg += 1; // unsatisfiable: waits forever
                        } else {
                            let rn = self.rd_node[ch][need] as usize;
                            let rp = self.node_proc[rn] as usize;
                            if rn - self.base[rp] as usize >= self.restart[rp] as usize {
                                dg += 1;
                            }
                        }
                    }
                } else {
                    let wn = self.wr_node[ch][j] as usize;
                    let wp = self.node_proc[wn] as usize;
                    if wn - self.base[wp] as usize >= self.restart[wp] as usize {
                        dg += 1;
                    }
                }
                self.indeg[n] = dg;
                if dg == 0 {
                    self.queue.push(n as u32);
                }
            }
            self.done[p] = self.restart[p];
        }

        self.info.incremental = true;
        let pops = self.propagate(depths);
        self.info.replayed_ops = pops;
        Some(self.outcome(&trace))
    }

    /// Kahn propagation from the current queue/in-degree state. Nodes
    /// below their process's `restart` index are the valid retained
    /// prefix — their times are read, never recomputed, and they receive
    /// no decrements. Returns the number of nodes committed.
    fn propagate(&mut self, depths: &[u32]) -> u64 {
        let mut pops = 0u64;
        while let Some(start_node) = self.queue.pop() {
            let mut n = start_node as usize;
            loop {
                let p = self.node_proc[n] as usize;
                let code = self.node_code[n];
                let is_write = code & WRITE_FLAG != 0;
                let ch = (code & !WRITE_FLAG) as usize;
                let j = self.node_ord[n] as usize;
                let delay = self.node_delay[n] as u64;
                let start = if n == self.base[p] as usize {
                    delay
                } else {
                    self.time[n - 1] + 1 + delay
                };
                let t = if is_write {
                    let d = depths[ch] as usize;
                    if j >= d {
                        start.max(self.time[self.rd_node[ch][j - d] as usize] + 1)
                    } else {
                        start
                    }
                } else {
                    start.max(self.time[self.wr_node[ch][j] as usize] + self.rd_lat[ch])
                };
                self.time[n] = t;
                self.done[p] += 1;
                pops += 1;
                // Cross-process successor: the read this write feeds, or
                // the write whose slot this read frees.
                if is_write {
                    if j < self.rd_node[ch].len() {
                        let r = self.rd_node[ch][j];
                        self.dec_if_pending(r);
                    }
                } else {
                    let w = j as u64 + depths[ch] as u64;
                    if (w as usize as u64) == w && (w as usize) < self.wr_node[ch].len() {
                        let wn = self.wr_node[ch][w as usize];
                        self.dec_if_pending(wn);
                    }
                }
                // Program-order successor: chain-follow when it was only
                // waiting on us (long compute runs commit without any
                // queue traffic).
                let nx = n + 1;
                if nx < self.pend[p] as usize {
                    self.indeg[nx] -= 1;
                    if self.indeg[nx] == 0 {
                        n = nx;
                        continue;
                    }
                }
                break;
            }
        }
        pops
    }

    /// Decrement a pending node's in-degree (valid retained-prefix nodes
    /// counted no such predecessor and are skipped).
    #[inline]
    fn dec_if_pending(&mut self, m: u32) {
        let mu = m as usize;
        let p = self.node_proc[mu] as usize;
        if mu - self.base[p] as usize < self.restart[p] as usize {
            return;
        }
        self.indeg[mu] -= 1;
        if self.indeg[mu] == 0 {
            self.queue.push(m);
        }
    }

    /// Outcome extraction from the committed counters and node times
    /// (identical formulas and blocked-set order to `FastSim`).
    fn outcome(&mut self, trace: &Trace) -> SimOutcome {
        let nproc = trace.ops.len();
        let mut blocked = Vec::new();
        for p in 0..nproc {
            let done = self.done[p] as usize;
            if done < trace.ops[p].len() {
                let op = trace.ops[p][done];
                blocked.push(BlockInfo {
                    process: p,
                    channel: op.chan(),
                    on_write: op.is_write(),
                });
            }
        }
        if !blocked.is_empty() {
            return SimOutcome::Deadlock { blocked };
        }
        let mut latency = 0u64;
        for p in 0..nproc {
            let done_t = if trace.ops[p].is_empty() {
                trace.tail_delays[p]
            } else {
                self.time[self.pend[p] as usize - 1] + 1 + trace.tail_delays[p]
            };
            latency = latency.max(done_t);
        }
        SimOutcome::Done { latency }
    }
}

impl SimBackend for CompiledSim {
    fn name(&self) -> &'static str {
        "compiled"
    }
    fn trace(&self) -> &Arc<Trace> {
        CompiledSim::trace(self)
    }
    fn simulate(&mut self, depths: &[u32]) -> SimOutcome {
        CompiledSim::simulate(self, depths)
    }
    fn simulate_with_stats_into(&mut self, depths: &[u32], stats: &mut ChannelStats) -> SimOutcome {
        CompiledSim::simulate_with_stats_into(self, depths, stats)
    }
    fn last_run(&self) -> RunInfo {
        CompiledSim::last_run(self)
    }
    fn set_incremental(&mut self, on: bool) {
        CompiledSim::set_incremental(self, on)
    }
    fn clone_box(&self) -> Box<dyn SimBackend> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DesignBuilder, Expr};
    use crate::sim::fast::FastSim;
    use crate::trace::collect_trace;

    fn compiled_for(design: &crate::ir::Design, args: &[i64]) -> CompiledSim {
        let t = collect_trace(design, args).unwrap();
        CompiledSim::new(Arc::new(t))
    }

    fn pipe_design(n: u64) -> crate::ir::Design {
        let mut b = DesignBuilder::new("pipe", 0);
        let c = b.channel("c", 32);
        b.process("prod", move |p| {
            p.for_n(n, |p, _| p.write(c, Expr::c(1)));
        });
        b.process("cons", move |p| {
            p.for_n(n, |p, _| {
                let _ = p.read(c);
            });
        });
        b.build()
    }

    #[test]
    fn pipe_latency_formula() {
        let d = pipe_design(8);
        let mut s = compiled_for(&d, &[]);
        assert_eq!(s.simulate(&[8]), SimOutcome::Done { latency: 9 });
        assert_eq!(s.simulate(&[2]).latency(), Some(9));
    }

    #[test]
    fn depth_one_throttles() {
        let d = pipe_design(4);
        let mut s = compiled_for(&d, &[]);
        assert_eq!(s.simulate(&[1]).latency(), Some(8));
    }

    #[test]
    fn fig2_deadlock_blocked_set_matches_fast() {
        let mut b = DesignBuilder::new("mult_by_2", 1);
        let x = b.channel("x", 32);
        let y = b.channel("y", 32);
        b.process("producer", |p| {
            p.for_expr(Expr::arg(0), |p, _| p.write(x, Expr::c(1)));
            p.for_expr(Expr::arg(0), |p, _| p.write(y, Expr::c(1)));
        });
        b.process("consumer", |p| {
            p.for_expr(Expr::arg(0), |p, _| {
                let _ = p.read(x);
                let _ = p.read(y);
            });
        });
        let design = b.build();
        let t = Arc::new(collect_trace(&design, &[16]).unwrap());
        let mut compiled = CompiledSim::new(t.clone());
        let mut fast = FastSim::new(t);
        for cfg in [[2u32, 2], [15, 2], [16, 2], [14, 16], [16, 16]] {
            assert_eq!(
                compiled.simulate(&cfg),
                fast.simulate(&cfg),
                "cfg {cfg:?} (full outcome incl. blocked set)"
            );
        }
    }

    #[test]
    fn incremental_matches_cold_on_mutation_chain() {
        let d = pipe_design(64);
        let t = Arc::new(collect_trace(&d, &[]).unwrap());
        let mut warm = CompiledSim::new(t.clone());
        let mut cold = CompiledSim::new(t.clone());
        cold.set_incremental(false);
        for cfg in [[4u32], [3], [4], [64], [1], [2], [2]] {
            let w = warm.simulate(&cfg);
            let c = cold.simulate(&cfg);
            assert_eq!(w, c, "cfg {cfg:?}");
            assert!(!cold.last_run().incremental);
        }
        // Identical configuration short-circuits with zero replay.
        let a = warm.simulate(&[2]);
        assert_eq!(a, warm.simulate(&[2]));
        assert!(warm.last_run().incremental);
        assert_eq!(warm.last_run().replayed_ops, 0);
    }

    #[test]
    fn srl_bram_flip_invalidates_reads() {
        // 600-bit channel: depth 1 SRL (rl 1), depth ≥ 3 BRAM (rl 2).
        let mut b = DesignBuilder::new("flip", 0);
        let w = b.channel("w", 600);
        let n = b.channel("n", 8);
        b.process("p", |p| {
            p.for_n(32, |p, _| {
                p.write(w, Expr::c(0));
                p.write(n, Expr::c(0));
            });
        });
        b.process("q", |p| {
            p.for_n(32, |p, _| {
                let _ = p.read(w);
                let _ = p.read(n);
            });
        });
        let d = b.build();
        let t = Arc::new(collect_trace(&d, &[]).unwrap());
        let mut warm = CompiledSim::new(t.clone());
        let mut fast = FastSim::new(t);
        for cfg in [[2u32, 8], [4, 8], [2, 8], [32, 8], [1, 8]] {
            assert_eq!(warm.simulate(&cfg), fast.simulate(&cfg), "cfg {cfg:?}");
        }
    }

    #[test]
    fn stats_match_fast_exactly() {
        let mut b = DesignBuilder::new("slow", 0);
        let c = b.channel("c", 32);
        b.process("p", |p| {
            p.for_n(8, |p, _| p.write(c, Expr::c(0)));
        });
        b.process("q", |p| {
            p.for_n(8, |p, _| {
                p.delay(3);
                let _ = p.read(c);
            });
        });
        let d = b.build();
        let t = Arc::new(collect_trace(&d, &[]).unwrap());
        let mut compiled = CompiledSim::new(t.clone());
        let mut fast = FastSim::new(t);
        for cfg in [[8u32], [2], [1]] {
            let (co, cs) = compiled.simulate_with_stats(&cfg);
            let (fo, fs) = fast.simulate_with_stats(&cfg);
            assert_eq!(co, fo, "cfg {cfg:?}");
            assert_eq!(cs.max_occupancy, fs.max_occupancy, "cfg {cfg:?}");
            assert_eq!(cs.write_stall, fs.write_stall, "cfg {cfg:?}");
            assert_eq!(cs.read_stall, fs.read_stall, "cfg {cfg:?}");
        }
    }

    fn graph_of(design: &crate::ir::Design, args: &[i64]) -> EventGraph {
        let t = collect_trace(design, args).unwrap();
        let index = ChanOpIndex::build(&t);
        EventGraph::compile(&t, &index)
    }

    #[test]
    fn analytic_bounds_on_pipe_are_trivial() {
        // Feed-forward pipe: no write depends on any read, so the cap is
        // the write count and the floor is 1.
        let d = pipe_design(8);
        let (floors, caps) = graph_of(&d, &[]).analytic_depth_bounds();
        assert_eq!(floors, vec![1]);
        assert_eq!(caps, vec![8]);
    }

    #[test]
    fn analytic_floor_finds_fig2_deadlock_threshold() {
        // The Fig. 2 shape: the producer writes ALL n x-tokens before any
        // y-token, while the consumer alternates reads. Read x_j (j ≥ 1)
        // has write y_{j−1} among its ancestors, which in producer
        // program order follows every x-write — so x needs depth ≥ n − 1.
        let mut b = DesignBuilder::new("mult_by_2", 1);
        let x = b.channel("x", 32);
        let y = b.channel("y", 32);
        b.process("producer", |p| {
            p.for_expr(Expr::arg(0), |p, _| p.write(x, Expr::c(1)));
            p.for_expr(Expr::arg(0), |p, _| p.write(y, Expr::c(1)));
        });
        b.process("consumer", |p| {
            p.for_expr(Expr::arg(0), |p, _| {
                let _ = p.read(x);
                let _ = p.read(y);
            });
        });
        let design = b.build();
        let (floors, caps) = graph_of(&design, &[16]).analytic_depth_bounds();
        assert_eq!(floors, vec![15, 1]);
        assert_eq!(caps, vec![16, 16]);
        // The floor is exact: one below deadlocks, the floor itself runs
        // (with the sibling channel relaxed).
        let t = Arc::new(collect_trace(&design, &[16]).unwrap());
        let mut s = FastSim::new(t);
        assert!(s.simulate(&[14, 16]).is_deadlock());
        assert!(!s.simulate(&[15, 2]).is_deadlock());
    }

    #[test]
    fn analytic_floor_is_sound_on_every_channel() {
        // Differential check on a reconvergent design: for each channel,
        // one-below-floor with everything else relaxed must deadlock.
        let mut b = DesignBuilder::new("reconv", 0);
        let direct = b.channel("direct", 32);
        let via = b.channel("via", 32);
        let out = b.channel("out", 32);
        b.process("split", move |p| {
            p.for_n(12, |p, _| p.write(direct, Expr::c(1)));
            p.for_n(12, |p, _| p.write(via, Expr::c(2)));
        });
        b.process("relay", move |p| {
            p.for_n(12, |p, _| {
                let v = p.read(via);
                p.write(out, Expr::var(v));
            });
        });
        b.process("join", move |p| {
            p.for_n(12, |p, _| {
                let _ = p.read(out);
                let _ = p.read(direct);
            });
        });
        let design = b.build();
        let t = Arc::new(collect_trace(&design, &[]).unwrap());
        let index = ChanOpIndex::build(&t);
        let (floors, caps) = EventGraph::compile(&t, &index).analytic_depth_bounds();
        let relaxed: Vec<u32> = t.channels.iter().map(|c| c.writes.max(2) as u32).collect();
        let mut s = FastSim::new(t.clone());
        for (ch, &f) in floors.iter().enumerate() {
            assert!(f <= caps[ch], "channel {ch}: floor {f} > cap {}", caps[ch]);
            if f > 1 {
                let mut cfg = relaxed.clone();
                cfg[ch] = f - 1;
                assert!(
                    s.simulate(&cfg).is_deadlock(),
                    "channel {ch}: depth {} below floor {f} must deadlock",
                    f - 1
                );
            }
            let mut cfg = relaxed.clone();
            cfg[ch] = f.max(1);
            assert!(
                !s.simulate(&cfg).is_deadlock(),
                "channel {ch}: floor {f} with others relaxed must run"
            );
        }
    }

    #[test]
    fn analytic_cap_pins_schedule_above_it() {
        // Raising any single channel above its cap never changes the
        // outcome (checked within one read-latency class: the caps keep
        // +1 slack so this holds for BRAM-class weights too).
        let d = pipe_design(16);
        let t = Arc::new(collect_trace(&d, &[]).unwrap());
        let index = ChanOpIndex::build(&t);
        let (_, caps) = EventGraph::compile(&t, &index).analytic_depth_bounds();
        let mut s = FastSim::new(t);
        let at_cap = s.simulate(&caps).latency();
        for extra in [1u32, 5, 100] {
            let cfg: Vec<u32> = caps.iter().map(|&c| c + extra).collect();
            assert_eq!(s.simulate(&cfg).latency(), at_cap);
        }
    }

    #[test]
    fn analytic_bounds_edge_cases() {
        // A channel that is written but never read floors at its write
        // count (the writer can only finish once every write has a slot).
        let mut b = DesignBuilder::new("unread", 0);
        let dead = b.channel("dead", 32);
        let live = b.channel("live", 32);
        b.process("p", move |p| {
            p.for_n(5, |p, _| p.write(dead, Expr::c(0)));
            p.for_n(3, |p, _| p.write(live, Expr::c(0)));
        });
        b.process("q", move |p| {
            p.for_n(3, |p, _| {
                let _ = p.read(live);
            });
        });
        let design = b.build();
        let (floors, caps) = graph_of(&design, &[]).analytic_depth_bounds();
        assert_eq!(floors, vec![5, 1]);
        assert_eq!(caps, vec![5, 3]);
    }

    #[test]
    fn telemetry_counts_replayed_nodes() {
        let d = pipe_design(32);
        let t = Arc::new(collect_trace(&d, &[]).unwrap());
        let mut s = CompiledSim::new(t);
        s.simulate(&[32]);
        let info = s.last_run();
        assert!(!info.incremental);
        assert_eq!(info.total_ops, 64);
        assert_eq!(info.replayed_ops, 64, "cold pass commits every node");
    }
}
