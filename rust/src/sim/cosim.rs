//! Runtime cost model for traditional HLS C/RTL co-simulation — the
//! baseline FIFOAdvisor is compared against in Table III.
//!
//! The paper estimates co-simulation-based search runtime as (best-case
//! per-run co-sim time) × (number of configurations), optionally divided
//! by 32 for perfectly-parallel workers. We reproduce that estimator with
//! a cost model calibrated to the published numbers: Vitis RTL co-sim
//! spends a roughly fixed setup (xsim elaboration) plus per-cycle
//! simulation effort that grows with design size (number of FIFOs is our
//! size proxy; the RTL netlist grows with it).
//!
//! Calibration sanity (paper Table III, 1000 samples, PAR=32): designs
//! with 10³–10⁶ cycles and 25–850 FIFOs land between ~0.4 and ~16 days —
//! our model reproduces that range; the headline claim it supports is
//! only "co-sim search takes days, FIFOAdvisor takes seconds" (≥10⁵×).

/// Fixed per-run setup cost (seconds): C-synthesis reuse + xsim RTL
/// elaboration + testbench launch. Calibrated so that an atax-class
/// design (175 FIFOs, ~2.2k cycles) costs ~1.7 ks per run — the per-run
/// time Table III's "0.61 days @ PAR=32 for 1000 samples" implies.
pub const SETUP_SECS: f64 = 1500.0;

/// Per-cycle, per-FIFO simulation cost (seconds). RTL co-sim throughput
/// of a dataflow design degrades with the number of live FIFO handshake
/// signals; 0.5 ms/cycle/FIFO puts a 100-FIFO design at ~20 Hz — the
/// regime Table III's large-design rows imply.
pub const SECS_PER_CYCLE_PER_FIFO: f64 = 5.0e-4;

/// Baseline per-cycle cost independent of design size.
pub const SECS_PER_CYCLE_BASE: f64 = 1.0e-3;

/// Estimated wall-clock seconds for ONE co-simulation run of a design
/// with `cycles` simulated cycles and `num_fifos` FIFOs.
pub fn cosim_run_secs(cycles: u64, num_fifos: usize) -> f64 {
    SETUP_SECS
        + cycles as f64 * (SECS_PER_CYCLE_BASE + SECS_PER_CYCLE_PER_FIFO * num_fifos as f64)
}

/// Estimated wall-clock seconds for a co-simulation-based search of
/// `samples` configurations with `parallel` perfectly-scaling workers
/// (paper uses PAR=32 and zero distribution overhead — a deliberately
/// optimistic lower bound for the baseline).
pub fn cosim_search_secs(cycles: u64, num_fifos: usize, samples: u64, parallel: u64) -> f64 {
    cosim_run_secs(cycles, num_fifos) * samples as f64 / parallel.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_run_dominated_by_cycles_for_big_designs() {
        let small = cosim_run_secs(1_000, 100);
        let big = cosim_run_secs(1_000_000, 100);
        assert!(big > 20.0 * small);
        assert!(small >= SETUP_SECS);
    }

    #[test]
    fn search_scales_linearly_and_parallelizes() {
        let one = cosim_search_secs(10_000, 200, 1, 1);
        let thousand = cosim_search_secs(10_000, 200, 1000, 1);
        assert!((thousand / one - 1000.0).abs() < 1e-6);
        let par32 = cosim_search_secs(10_000, 200, 1000, 32);
        assert!((thousand / par32 - 32.0).abs() < 1e-6);
    }

    #[test]
    fn table3_range_shape() {
        // Paper-scale designs should land in the fractional-day to
        // tens-of-days range for 1000 samples at PAR=32.
        let lo = cosim_search_secs(667, 25, 1000, 32); // mvt/bicg-like
        let hi = cosim_search_secs(2_092_531, 64, 1000, 32); // ResidualBlock-like
        let day = 86_400.0;
        assert!(lo > 0.02 * day, "lo = {lo}");
        assert!(hi > 3.0 * day, "hi = {hi}");
        assert!(hi < 60.0 * day, "hi = {hi}");
    }
}
