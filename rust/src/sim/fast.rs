//! The fast trace simulator — LightningSim phase-2 analog.
//!
//! Construction ([`FastSim::new`]) preallocates per-channel commit-time
//! vectors sized from the trace; [`FastSim::simulate`] then evaluates any
//! FIFO depth configuration with zero heap allocation, in one
//! event-driven pass over the trace (O(total ops)). This is what makes
//! "incremental simulation in under 1 ms per FIFO size change" (paper
//! §III-A) achievable.

use super::SimOptions;
use crate::trace::Trace;
use std::sync::Arc;

/// Result of simulating one FIFO configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimOutcome {
    /// The design ran to completion in `latency` cycles.
    Done { latency: u64 },
    /// The design deadlocked; `blocked` describes each stuck process.
    Deadlock { blocked: Vec<BlockInfo> },
}

impl SimOutcome {
    /// Latency if the run completed, `None` on deadlock.
    pub fn latency(&self) -> Option<u64> {
        match self {
            SimOutcome::Done { latency } => Some(*latency),
            SimOutcome::Deadlock { .. } => None,
        }
    }

    pub fn is_deadlock(&self) -> bool {
        matches!(self, SimOutcome::Deadlock { .. })
    }
}

/// Description of one process stuck at deadlock.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockInfo {
    /// Index of the blocked process.
    pub process: usize,
    /// Channel it is blocked on.
    pub channel: usize,
    /// True if blocked writing (FIFO full), false if blocked reading
    /// (FIFO empty).
    pub on_write: bool,
}

/// Per-channel occupancy statistics from a completed run (used by the
/// greedy optimizer's ranking and by diagnostics).
#[derive(Debug, Clone)]
pub struct ChannelStats {
    /// Maximum number of simultaneously-buffered tokens observed.
    pub max_occupancy: Vec<u32>,
    /// Total cycles writers spent stalled on a full FIFO.
    pub write_stall: Vec<u64>,
    /// Total cycles readers spent stalled on an empty FIFO.
    pub read_stall: Vec<u64>,
}

/// The reusable fast simulator. Construct once per trace; call
/// [`simulate`](FastSim::simulate) once per candidate configuration.
/// `Clone` is cheap-ish (scratch vectors are duplicated, the trace is
/// shared) and gives each DSE worker thread its own engine.
#[derive(Clone)]
pub struct FastSim {
    trace: Arc<Trace>,
    opts: SimOptions,
    widths: Vec<u32>,
    /// Per-channel committed-write times, indexed by write ordinal.
    wr_times: Vec<Box<[u64]>>,
    /// Per-channel committed-read times, indexed by read ordinal.
    rd_times: Vec<Box<[u64]>>,
    /// Per-channel commit counters (reset each run).
    wr_done: Vec<u32>,
    rd_done: Vec<u32>,
    /// Per-channel single reader/writer process parked on it (SPSC).
    wait_reader: Vec<u32>,
    wait_writer: Vec<u32>,
    /// Per-process cursor: next op index.
    pc: Vec<u32>,
    /// Per-process commit time of the previous op (or NO_TIME before the
    /// first op).
    last_commit: Vec<u64>,
    /// Worklist of runnable processes + membership flags.
    ready: Vec<u32>,
    in_ready: Vec<bool>,
    /// Per-channel read latency for the configuration being simulated.
    rd_lat: Vec<u64>,
    /// §Perf burst fast path: `run_len[p][k]` = length of the maximal
    /// homogeneous run starting at op `k` of process `p` (same channel,
    /// same kind, zero delay on all ops after the first). Loader bursts,
    /// PE loops and sink drains dominate real traces, so most ops are
    /// committed by the branch-free burst loops instead of the generic
    /// per-op path. Computed once per trace at construction.
    run_len: Vec<Box<[u32]>>,
    /// §Perf pair-burst fast path: `pair_run[p][k]` = number of
    /// consecutive alternating read *pairs* `(A,B),(A,B),…` starting at
    /// op `k` (distinct channels, zero delay after the first op) — the
    /// matmul PE access pattern, which single-channel RLE cannot catch.
    pair_run: Vec<Box<[u32]>>,
}

const NONE: u32 = u32::MAX;
const NO_TIME: u64 = u64::MAX;

impl FastSim {
    /// Build a simulator for a trace. Preallocates all per-run scratch.
    pub fn new(trace: Arc<Trace>) -> FastSim {
        Self::with_options(trace, SimOptions::default())
    }

    /// Build with explicit [`SimOptions`].
    pub fn with_options(trace: Arc<Trace>, opts: SimOptions) -> FastSim {
        let nch = trace.channels.len();
        let nproc = trace.ops.len();
        let widths: Vec<u32> = trace.channels.iter().map(|c| c.width_bits).collect();
        let wr_times = trace
            .channels
            .iter()
            .map(|c| vec![0u64; c.writes as usize].into_boxed_slice())
            .collect();
        let rd_times = trace
            .channels
            .iter()
            .map(|c| vec![0u64; c.reads as usize].into_boxed_slice())
            .collect();
        // Run-length encode homogeneous op bursts (suffix scan).
        let run_len = trace
            .ops
            .iter()
            .map(|ops| {
                let n = ops.len();
                let mut rl = vec![1u32; n].into_boxed_slice();
                for k in (0..n.saturating_sub(1)).rev() {
                    if ops[k + 1].delay == 0
                        && ops[k + 1].chan() == ops[k].chan()
                        && ops[k + 1].is_write() == ops[k].is_write()
                    {
                        rl[k] = rl[k + 1] + 1;
                    }
                }
                rl
            })
            .collect();
        let pair_run = trace
            .ops
            .iter()
            .map(|ops| {
                let n = ops.len();
                let mut pr = vec![0u32; n].into_boxed_slice();
                for k in (0..n.saturating_sub(1)).rev() {
                    let (a, b) = (ops[k], ops[k + 1]);
                    if !a.is_write() && !b.is_write() && a.chan() != b.chan() && b.delay == 0 {
                        let cont = if k + 3 < n
                            && ops[k + 2].delay == 0
                            && !ops[k + 2].is_write()
                            && ops[k + 2].chan() == a.chan()
                            && ops[k + 3].delay == 0
                            && !ops[k + 3].is_write()
                            && ops[k + 3].chan() == b.chan()
                        {
                            pr[k + 2]
                        } else {
                            0
                        };
                        pr[k] = 1 + cont;
                    }
                }
                pr
            })
            .collect();
        FastSim {
            trace,
            opts,
            widths,
            wr_times,
            rd_times,
            wr_done: vec![0; nch],
            rd_done: vec![0; nch],
            wait_reader: vec![NONE; nch],
            wait_writer: vec![NONE; nch],
            pc: vec![0; nproc],
            last_commit: vec![NO_TIME; nproc],
            ready: Vec::with_capacity(nproc),
            in_ready: vec![false; nproc],
            rd_lat: vec![0; nch],
            run_len,
            pair_run,
        }
    }

    /// The trace this simulator evaluates.
    pub fn trace(&self) -> &Arc<Trace> {
        &self.trace
    }

    /// Evaluate one FIFO depth configuration. `depths.len()` must equal
    /// the number of channels. Zero heap allocation on this path.
    pub fn simulate(&mut self, depths: &[u32]) -> SimOutcome {
        self.run(depths)
    }

    /// Evaluate a configuration and also collect per-channel occupancy and
    /// stall statistics (used by the greedy optimizer; somewhat slower).
    pub fn simulate_with_stats(&mut self, depths: &[u32]) -> (SimOutcome, ChannelStats) {
        let outcome = self.run(depths);
        let nch = self.trace.channels.len();
        let mut stats = ChannelStats {
            max_occupancy: vec![0; nch],
            write_stall: vec![0; nch],
            read_stall: vec![0; nch],
        };
        // Occupancy post-pass: per channel, writes and reads each commit in
        // nondecreasing time order, so a sorted merge tracks occupancy.
        for ch in 0..nch {
            let w = &self.wr_times[ch][..self.wr_done[ch] as usize];
            let r = &self.rd_times[ch][..self.rd_done[ch] as usize];
            let (mut wi, mut ri) = (0usize, 0usize);
            let mut occ: i64 = 0;
            let mut max_occ: i64 = 0;
            while wi < w.len() || ri < r.len() {
                // A read at time t removes a token written at time ≤ t;
                // process the event with the smaller time first, writes
                // before reads at equal time (a token cannot be read out
                // the same cycle its slot frees for occupancy purposes —
                // consistent with rl ≥ 1 meaning wr[j] < rd[j] always).
                if wi < w.len() && (ri >= r.len() || w[wi] <= r[ri]) {
                    occ += 1;
                    max_occ = max_occ.max(occ);
                    wi += 1;
                } else {
                    occ -= 1;
                    ri += 1;
                }
            }
            stats.max_occupancy[ch] = max_occ.max(0) as u32;
        }
        // Stall post-pass: replay each process's schedule, comparing
        // unconstrained start vs commit.
        for (pid, ops) in self.trace.ops.iter().enumerate() {
            let committed = self.pc[pid] as usize;
            let mut prev: u64 = NO_TIME;
            let mut wr_seen = vec![0u32; nch];
            let mut rd_seen = vec![0u32; nch];
            for op in &ops[..committed] {
                let ch = op.chan();
                let k = if op.is_write() {
                    let k = wr_seen[ch];
                    wr_seen[ch] += 1;
                    k
                } else {
                    let k = rd_seen[ch];
                    rd_seen[ch] += 1;
                    k
                };
                let start = if prev == NO_TIME {
                    op.delay as u64
                } else {
                    prev + 1 + op.delay as u64
                };
                let commit = if op.is_write() {
                    self.wr_times[ch][k as usize]
                } else {
                    self.rd_times[ch][k as usize]
                };
                let stall = commit.saturating_sub(start);
                if op.is_write() {
                    stats.write_stall[ch] += stall;
                } else {
                    stats.read_stall[ch] += stall;
                }
                prev = commit;
            }
        }
        (outcome, stats)
    }

    fn run(&mut self, depths: &[u32]) -> SimOutcome {
        let trace = self.trace.clone();
        let nch = trace.channels.len();
        let nproc = trace.ops.len();
        assert_eq!(
            depths.len(),
            nch,
            "configuration has {} depths, design has {} FIFOs",
            depths.len(),
            nch
        );

        // Reset scratch.
        for v in &mut self.wr_done {
            *v = 0;
        }
        for v in &mut self.rd_done {
            *v = 0;
        }
        for v in &mut self.wait_reader {
            *v = NONE;
        }
        for v in &mut self.wait_writer {
            *v = NONE;
        }
        for v in &mut self.pc {
            *v = 0;
        }
        for v in &mut self.last_commit {
            *v = NO_TIME;
        }
        self.ready.clear();
        for p in 0..nproc {
            self.ready.push(p as u32);
            self.in_ready[p] = true;
        }
        for ch in 0..nch {
            self.rd_lat[ch] =
                super::read_latency(depths[ch], self.widths[ch], self.opts.uniform_read_latency);
        }

        // Event-driven commit propagation.
        while let Some(pid) = self.ready.pop() {
            let pid = pid as usize;
            self.in_ready[pid] = false;
            let ops = &trace.ops[pid];
            let mut pc = self.pc[pid] as usize;
            let mut prev = self.last_commit[pid];

            while pc < ops.len() {
                let op = ops[pc];
                let ch = op.chan();
                let start = if prev == NO_TIME {
                    op.delay as u64
                } else {
                    prev + 1 + op.delay as u64
                };
                if op.is_write() {
                    let j = self.wr_done[ch];
                    let d = depths[ch];
                    let commit = if j >= d {
                        let need = (j - d) as usize;
                        if self.rd_done[ch] as usize <= need {
                            // FIFO full and the freeing read hasn't
                            // committed: park as the channel's writer.
                            self.wait_writer[ch] = pid as u32;
                            break;
                        }
                        start.max(self.rd_times[ch][need] + 1)
                    } else {
                        start
                    };
                    self.wr_times[ch][j as usize] = commit;
                    self.wr_done[ch] = j + 1;
                    prev = commit;
                    pc += 1;
                    // Burst fast path for the rest of a homogeneous
                    // zero-delay write run. Phase A: ordinals below the
                    // depth are wholly unconstrained (commit = prev + 1).
                    // Phase B: ordinals in [d, rd_done + d) have a
                    // committed freeing read, so commit =
                    // max(prev + 1, rd[k-d] + 1) — still branch-free.
                    let run = self.run_len[pid][pc - 1];
                    if run > 1 {
                        let end_of_run = self.wr_done[ch] as u64 + run as u64 - 1;
                        // Phase A.
                        let a_end = end_of_run.min(d as u64);
                        let base = self.wr_done[ch] as u64;
                        if a_end > base {
                            let m = (a_end - base) as u32;
                            let times =
                                &mut self.wr_times[ch][base as usize..(base + m as u64) as usize];
                            for (i, slot) in times.iter_mut().enumerate() {
                                *slot = prev + 1 + i as u64;
                            }
                            prev += m as u64;
                            self.wr_done[ch] += m;
                            pc += m as usize;
                        }
                        // Phase B.
                        let base = self.wr_done[ch] as u64;
                        let b_end = end_of_run.min(self.rd_done[ch] as u64 + d as u64);
                        if b_end > base && base >= d as u64 {
                            let m = (b_end - base) as usize;
                            let need0 = (base - d as u64) as usize;
                            // Split borrows: read times are immutable here.
                            let (rd_all, wr_all) =
                                (&self.rd_times[ch], &mut self.wr_times[ch]);
                            let rd = &rd_all[need0..need0 + m];
                            let wr = &mut wr_all[base as usize..base as usize + m];
                            for (r_t, w_t) in rd.iter().zip(wr.iter_mut()) {
                                let commit = (prev + 1).max(r_t + 1);
                                *w_t = commit;
                                prev = commit;
                            }
                            self.wr_done[ch] += m as u32;
                            pc += m;
                        }
                    }
                    // Wake the reader parked on this channel, if any.
                    let w = self.wait_reader[ch];
                    if w != NONE {
                        self.wait_reader[ch] = NONE;
                        if !self.in_ready[w as usize] {
                            self.in_ready[w as usize] = true;
                            self.ready.push(w);
                        }
                    }
                } else {
                    // Alternating-pair burst (matmul PE pattern): commit
                    // whole (A,B) read pairs while both channels have
                    // committed writes available.
                    let pairs = self.pair_run[pid][pc];
                    if pairs > 1 {
                        let b_ch = trace.ops[pid][pc + 1].chan();
                        let m = pairs
                            .min(self.wr_done[ch] - self.rd_done[ch])
                            .min(self.wr_done[b_ch] - self.rd_done[b_ch]);
                        if m >= 1 {
                            let (la, lb) = (self.rd_lat[ch], self.rd_lat[b_ch]);
                            let ja = self.rd_done[ch] as usize;
                            let jb = self.rd_done[b_ch] as usize;
                            let mut p = prev;
                            for i in 0..m as usize {
                                let s = if p == NO_TIME {
                                    op.delay as u64
                                } else if i == 0 {
                                    p + 1 + op.delay as u64
                                } else {
                                    p + 1
                                };
                                let ca = s.max(self.wr_times[ch][ja + i] + la);
                                self.rd_times[ch][ja + i] = ca;
                                let cb = (ca + 1).max(self.wr_times[b_ch][jb + i] + lb);
                                self.rd_times[b_ch][jb + i] = cb;
                                p = cb;
                            }
                            self.rd_done[ch] += m;
                            self.rd_done[b_ch] += m;
                            prev = p;
                            pc += 2 * m as usize;
                            for chx in [ch, b_ch] {
                                let w = self.wait_writer[chx];
                                if w != NONE {
                                    self.wait_writer[chx] = NONE;
                                    if !self.in_ready[w as usize] {
                                        self.in_ready[w as usize] = true;
                                        self.ready.push(w);
                                    }
                                }
                            }
                            continue;
                        }
                    }
                    let j = self.rd_done[ch];
                    if self.wr_done[ch] <= j {
                        self.wait_reader[ch] = pid as u32;
                        break;
                    }
                    let commit = start.max(self.wr_times[ch][j as usize] + self.rd_lat[ch]);
                    self.rd_times[ch][j as usize] = commit;
                    self.rd_done[ch] = j + 1;
                    prev = commit;
                    pc += 1;
                    // Burst fast path: drain a homogeneous zero-delay read
                    // run against already-committed writes.
                    let run = self.run_len[pid][pc - 1];
                    if run > 1 {
                        let m = (run - 1).min(self.wr_done[ch] - self.rd_done[ch]);
                        if m > 0 {
                            let base = self.rd_done[ch] as usize;
                            let lat = self.rd_lat[ch];
                            let wr = &self.wr_times[ch][base..base + m as usize];
                            let rd = &mut self.rd_times[ch][base..base + m as usize];
                            for (w_t, r_t) in wr.iter().zip(rd.iter_mut()) {
                                let commit = (prev + 1).max(w_t + lat);
                                *r_t = commit;
                                prev = commit;
                            }
                            self.rd_done[ch] += m;
                            pc += m as usize;
                        }
                    }
                    let w = self.wait_writer[ch];
                    if w != NONE {
                        self.wait_writer[ch] = NONE;
                        if !self.in_ready[w as usize] {
                            self.in_ready[w as usize] = true;
                            self.ready.push(w);
                        }
                    }
                }
            }
            self.pc[pid] = pc as u32;
            self.last_commit[pid] = prev;
        }

        // Fixpoint reached: all done, or deadlock.
        let mut blocked = Vec::new();
        for pid in 0..nproc {
            let pc = self.pc[pid] as usize;
            if pc < trace.ops[pid].len() {
                let op = trace.ops[pid][pc];
                blocked.push(BlockInfo {
                    process: pid,
                    channel: op.chan(),
                    on_write: op.is_write(),
                });
            }
        }
        if !blocked.is_empty() {
            return SimOutcome::Deadlock { blocked };
        }

        let mut latency = 0u64;
        for pid in 0..nproc {
            let done = if self.last_commit[pid] == NO_TIME {
                // No FIFO ops: the process is pure compute.
                trace.tail_delays[pid]
            } else {
                self.last_commit[pid] + 1 + trace.tail_delays[pid]
            };
            latency = latency.max(done);
        }
        SimOutcome::Done { latency }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DesignBuilder, Expr};
    use crate::trace::collect_trace;

    fn sim_for(design: &crate::ir::Design, args: &[i64]) -> FastSim {
        let t = collect_trace(design, args).unwrap();
        FastSim::new(Arc::new(t))
    }

    /// producer → consumer through one FIFO, fully rate-matched.
    fn pipe_design(n: u64) -> crate::ir::Design {
        let mut b = DesignBuilder::new("pipe", 0);
        let c = b.channel("c", 32);
        b.process("prod", move |p| {
            p.for_n(n, |p, _| p.write(c, Expr::c(1)));
        });
        b.process("cons", move |p| {
            p.for_n(n, |p, _| {
                let _ = p.read(c);
            });
        });
        b.build()
    }

    #[test]
    fn pipe_latency_formula() {
        // writes commit at 0,1,..,n-1; reads at wr+rl (SRL: rl=1) →
        // reads commit 1..n → latency = n+1.
        let d = pipe_design(8);
        let mut s = sim_for(&d, &[]);
        let out = s.simulate(&[8]);
        assert_eq!(out, SimOutcome::Done { latency: 9 });
        // Depth 2 is enough: reader keeps pace with writer.
        assert_eq!(s.simulate(&[2]).latency(), Some(9));
    }

    #[test]
    fn depth_one_throttles() {
        // depth 1: write j+1 must wait for read j to commit + 1.
        // w0=0, r0=1, w1=max(1, r0+1)=2, r1=3, w2=4 ... latency 2n-1+1.
        let d = pipe_design(4);
        let mut s = sim_for(&d, &[]);
        assert_eq!(s.simulate(&[1]).latency(), Some(8));
    }

    #[test]
    fn bram_fifo_adds_read_cycle() {
        // Wide channel so depth > 2 crosses the SRL bit threshold:
        // width 1024 → any depth > 1 is BRAM (d*w > 1024) unless d ≤ 2.
        let mut b = DesignBuilder::new("wide", 0);
        let c = b.channel("wide", 1024);
        b.process("p", |p| {
            p.for_n(4, |p, _| p.write(c, Expr::c(0)));
        });
        b.process("q", |p| {
            p.for_n(4, |p, _| {
                let _ = p.read(c);
            });
        });
        let d = b.build();
        let mut s = sim_for(&d, &[]);
        let srl = s.simulate(&[2]).latency().unwrap();
        let bram = s.simulate(&[4]).latency().unwrap();
        // Same pipeline but BRAM read latency 2 instead of 1 → one cycle
        // slower end-to-end (footnote 2 of the paper, in reverse).
        assert_eq!(bram, srl + 1);
    }

    #[test]
    fn fig2_deadlock_threshold() {
        // Paper Fig. 2: producer writes n to x then n to y; consumer
        // alternates x,y reads. x must buffer n-1 leftovers while the
        // consumer waits for y; depth(x) < n-1 deadlocks.
        let mut b = DesignBuilder::new("mult_by_2", 1);
        let x = b.channel("x", 32);
        let y = b.channel("y", 32);
        b.process("producer", |p| {
            p.for_expr(Expr::arg(0), |p, _| p.write(x, Expr::c(1)));
            p.for_expr(Expr::arg(0), |p, _| p.write(y, Expr::c(1)));
        });
        b.process("consumer", |p| {
            p.for_expr(Expr::arg(0), |p, _| {
                let _ = p.read(x);
                let _ = p.read(y);
            });
        });
        let design = b.build();
        let n = 16i64;
        let mut s = sim_for(&design, &[n]);
        // Ample depths: no deadlock.
        assert!(!s.simulate(&[n as u32, 2]).is_deadlock());
        assert!(!s.simulate(&[n as u32 - 1, 2]).is_deadlock());
        // Too small: deadlock, blocked writer on y? producer stuck on x.
        let out = s.simulate(&[2, 2]);
        match &out {
            SimOutcome::Deadlock { blocked } => {
                assert!(blocked.iter().any(|b| b.on_write && b.channel == 0));
                assert!(blocked.iter().any(|b| !b.on_write && b.channel == 1));
            }
            other => panic!("expected deadlock, got {other:?}"),
        }
    }

    #[test]
    fn delays_shift_schedule() {
        let mut b = DesignBuilder::new("dly", 0);
        let c = b.channel("c", 32);
        b.process("p", |p| {
            p.delay(100);
            p.write(c, Expr::c(0));
        });
        b.process("q", |p| {
            let _ = p.read(c);
        });
        let d = b.build();
        let mut s = sim_for(&d, &[]);
        // write at 100, read at 101, latency 102.
        assert_eq!(s.simulate(&[2]).latency(), Some(102));
    }

    #[test]
    fn tail_delay_counts() {
        let mut b = DesignBuilder::new("tail", 0);
        let c = b.channel("c", 32);
        b.process("p", |p| {
            p.write(c, Expr::c(0));
        });
        b.process("q", |p| {
            let _ = p.read(c);
            p.delay(50);
        });
        let d = b.build();
        let mut s = sim_for(&d, &[]);
        // write 0, read 1, +1 +50 → 52.
        assert_eq!(s.simulate(&[2]).latency(), Some(52));
    }

    #[test]
    fn stats_occupancy_and_stalls() {
        // Slow reader: delay 3 between reads → FIFO backs up.
        let mut b = DesignBuilder::new("slow", 0);
        let c = b.channel("c", 32);
        b.process("p", |p| {
            p.for_n(8, |p, _| p.write(c, Expr::c(0)));
        });
        b.process("q", |p| {
            p.for_n(8, |p, _| {
                p.delay(3);
                let _ = p.read(c);
            });
        });
        let d = b.build();
        let mut s = sim_for(&d, &[]);
        let (out, stats) = s.simulate_with_stats(&[8]);
        assert!(!out.is_deadlock());
        assert!(stats.max_occupancy[0] >= 2, "{:?}", stats.max_occupancy);
        assert_eq!(stats.write_stall[0], 0);
        // With depth 2 the writer must stall.
        let (_, stats2) = s.simulate_with_stats(&[2]);
        assert!(stats2.write_stall[0] > 0);
        assert!(stats2.max_occupancy[0] <= 2);
    }

    #[test]
    fn monotone_latency_in_depth_uniform_latency() {
        let mut b = DesignBuilder::new("mono", 0);
        let c = b.channel("c", 32);
        let e = b.channel("e", 32);
        b.process("p", |p| {
            p.for_n(32, |p, _| {
                p.write(c, Expr::c(0));
            });
        });
        b.process("mid", |p| {
            p.for_n(32, |p, _| {
                let _ = p.read(c);
                p.delay(2);
                p.write(e, Expr::c(0));
            });
        });
        b.process("q", |p| {
            p.for_n(32, |p, _| {
                p.delay(1);
                let _ = p.read(e);
            });
        });
        let d = b.build();
        let t = Arc::new(collect_trace(&d, &[]).unwrap());
        let mut s = FastSim::with_options(
            t,
            SimOptions {
                uniform_read_latency: true,
            },
        );
        let mut prev = u64::MAX;
        for depth in [1u32, 2, 4, 8, 16, 32] {
            let lat = s.simulate(&[depth, depth]).latency().unwrap();
            assert!(lat <= prev, "depth {depth}: {lat} > {prev}");
            prev = lat;
        }
    }

    #[test]
    fn repeated_simulation_is_stable() {
        let d = pipe_design(100);
        let mut s = sim_for(&d, &[]);
        let a = s.simulate(&[7]);
        let b = s.simulate(&[2]);
        let a2 = s.simulate(&[7]);
        let b2 = s.simulate(&[2]);
        assert_eq!(a, a2);
        assert_eq!(b, b2);
    }
}
