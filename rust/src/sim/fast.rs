//! The fast trace simulator — LightningSim phase-2 analog, now with
//! **delta-incremental re-simulation**.
//!
//! Construction ([`FastSim::new`]) preallocates per-channel commit-time
//! vectors sized from the trace; [`FastSim::simulate`] then evaluates any
//! FIFO depth configuration in one event-driven pass over the trace
//! (O(total ops)), with zero heap allocation on the hot path.
//!
//! # Incremental re-simulation
//!
//! After every run the simulator *retains* the committed schedule — the
//! per-channel `wr_times`/`rd_times` arrays, per-process cursors and the
//! configuration that produced them. The next [`simulate`](FastSim::simulate)
//! call diffs the new configuration against the retained one and replays
//! only the part of the trace whose commit times can actually change; DSE
//! proposals that mutate one or two FIFO depths (SA β-chain moves, greedy
//! collapses, the Vitis hunter's doublings) re-simulate in a fraction of a
//! full pass — the paper's "incremental simulation in under 1 ms per FIFO
//! size change" (§III-A).
//!
//! **Invalidation rules.** A channel is *dirty* when its depth changed.
//! For a dirty channel with depths `d0 → d1`:
//!
//! - writes from ordinal `min(d0, d1)` are invalid (the full-FIFO
//!   constraint `commit ≥ rd[j − d] + 1` exists/indexes differently);
//! - if the depth change crosses the SRL↔BRAM boundary
//!   ([`read_latency`](super::read_latency) changes), every read on the
//!   channel is invalid.
//!
//! Invalidation then propagates through the constraint graph to a
//! fixpoint over per-process *checkpoints* (the earliest op index that
//! must be replayed), using a once-per-trace channel↔process op-index map
//! ([`ChanOpIndex`]): invalid writes on `c` from ordinal `j` invalidate
//! the reader of `c` from its op committing read `j` (reads wait on their
//! write); invalid reads from ordinal `j` invalidate the writer from its
//! op committing write `j + d1` (writes wait on the read that frees their
//! slot). The scratch state is then *rewound* — cursors and per-channel
//! commit counters are reset to each process's checkpoint, every process
//! with remaining ops seeds the ready worklist — and the ordinary
//! event-driven propagation loop finishes the job. Commit times form the
//! unique least fixpoint of the constraint system, so the result is
//! **bit-identical** to a cold full replay (enforced by
//! `tests/incremental_fuzz.rs`). When the checkpoint fixpoint shows the
//! dirty frontier covers (almost) the whole trace, the simulator falls
//! back to a plain full replay, so incremental mode is never slower than
//! the old behaviour by more than the checkpoint computation itself
//! (O(dirty region) with binary searches).
//!
//! Per-run telemetry (dirty channels, ops replayed vs total) is exposed
//! through [`FastSim::last_run`] and aggregated by the DSE engine into
//! its incremental-hit-rate statistics.

use super::SimOptions;
use crate::trace::{ChanOpIndex, Trace};
use std::sync::Arc;

/// Result of simulating one FIFO configuration.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SimOutcome {
    /// The design ran to completion in `latency` cycles.
    Done { latency: u64 },
    /// The design deadlocked; `blocked` describes each stuck process.
    Deadlock { blocked: Vec<BlockInfo> },
}

impl SimOutcome {
    /// Latency if the run completed, `None` on deadlock.
    pub fn latency(&self) -> Option<u64> {
        match self {
            SimOutcome::Done { latency } => Some(*latency),
            SimOutcome::Deadlock { .. } => None,
        }
    }

    pub fn is_deadlock(&self) -> bool {
        matches!(self, SimOutcome::Deadlock { .. })
    }
}

/// Description of one process stuck at deadlock.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockInfo {
    /// Index of the blocked process.
    pub process: usize,
    /// Channel it is blocked on.
    pub channel: usize,
    /// True if blocked writing (FIFO full), false if blocked reading
    /// (FIFO empty).
    pub on_write: bool,
}

/// Per-channel occupancy statistics from a completed run (used by the
/// greedy optimizer's ranking and by diagnostics).
#[derive(Debug, Clone)]
pub struct ChannelStats {
    /// Maximum number of simultaneously-buffered tokens observed.
    pub max_occupancy: Vec<u32>,
    /// Total cycles writers spent stalled on a full FIFO.
    pub write_stall: Vec<u64>,
    /// Total cycles readers spent stalled on an empty FIFO.
    pub read_stall: Vec<u64>,
}

impl ChannelStats {
    /// An empty buffer; [`FastSim::simulate_with_stats_into`] sizes it.
    pub fn new() -> ChannelStats {
        ChannelStats {
            max_occupancy: Vec::new(),
            write_stall: Vec::new(),
            read_stall: Vec::new(),
        }
    }
}

impl Default for ChannelStats {
    fn default() -> Self {
        Self::new()
    }
}

/// Telemetry for one `simulate` call (see [`FastSim::last_run`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RunInfo {
    /// True when the call reused the retained schedule (delta replay or
    /// identical-configuration short-circuit).
    pub incremental: bool,
    /// Channels whose depth differed from the retained configuration
    /// (0 for full replays and identical configurations).
    pub dirty_channels: u32,
    /// Trace ops this call actually committed (0 when the configuration
    /// was identical to the retained one).
    pub replayed_ops: u64,
    /// Total trace ops — the cost of a full replay.
    pub total_ops: u64,
}

/// Fall back to a full replay when the checkpoint fixpoint shows at
/// least this percentage of trace ops must be re-propagated anyway.
const INCR_FALLBACK_PCT: u64 = 90;

/// The reusable fast simulator. Construct once per trace; call
/// [`simulate`](FastSim::simulate) once per candidate configuration.
/// `Clone` is cheap-ish (scratch vectors are duplicated; the trace and
/// the op-index maps are shared) and gives each DSE worker thread its own
/// engine — including its own retained schedule, which is what makes the
/// engine's sticky locality-aware dispatch pay off.
#[derive(Clone)]
pub struct FastSim {
    trace: Arc<Trace>,
    opts: SimOptions,
    widths: Vec<u32>,
    /// Per-channel committed-write times, indexed by write ordinal.
    wr_times: Vec<Box<[u64]>>,
    /// Per-channel committed-read times, indexed by read ordinal.
    rd_times: Vec<Box<[u64]>>,
    /// Per-channel commit counters (reset or rewound each run).
    wr_done: Vec<u32>,
    rd_done: Vec<u32>,
    /// Per-channel single reader/writer process parked on it (SPSC).
    wait_reader: Vec<u32>,
    wait_writer: Vec<u32>,
    /// Per-process cursor: next op index.
    pc: Vec<u32>,
    /// Per-process commit time of the previous op (or NO_TIME before the
    /// first op).
    last_commit: Vec<u64>,
    /// Worklist of runnable processes + membership flags.
    ready: Vec<u32>,
    in_ready: Vec<bool>,
    /// Per-channel read latency for the configuration being simulated.
    rd_lat: Vec<u64>,
    /// §Perf burst fast path: `run_len[p][k]` = length of the maximal
    /// homogeneous run starting at op `k` of process `p` (same channel,
    /// same kind, zero delay on all ops after the first). Loader bursts,
    /// PE loops and sink drains dominate real traces, so most ops are
    /// committed by the branch-free burst loops instead of the generic
    /// per-op path. Computed once per trace at construction.
    run_len: Vec<Box<[u32]>>,
    /// §Perf pair-burst fast path: `pair_run[p][k]` = number of
    /// consecutive alternating read *pairs* `(A,B),(A,B),…` starting at
    /// op `k` (distinct channels, zero delay after the first op) — the
    /// matmul PE access pattern, which single-channel RLE cannot catch.
    pair_run: Vec<Box<[u32]>>,
    /// Channel↔process op-index maps (shared by clones; drives
    /// incremental invalidation and the zero-alloc stats post-pass).
    index: Arc<ChanOpIndex>,
    /// Master switch for schedule retention/reuse (on by default).
    incremental: bool,
    /// Configuration of the retained schedule (valid iff `last_outcome`
    /// is `Some`).
    last_depths: Vec<u32>,
    /// Outcome of the retained run.
    last_outcome: Option<SimOutcome>,
    /// Telemetry of the most recent `simulate` call.
    info: RunInfo,
    /// Scratch: per-process replay checkpoint (op index).
    ckpt: Vec<u32>,
    /// Scratch: checkpoint-fixpoint worklist + membership flags.
    wl: Vec<u32>,
    in_wl: Vec<bool>,
}

const NONE: u32 = u32::MAX;
const NO_TIME: u64 = u64::MAX;

impl FastSim {
    /// Build a simulator for a trace. Preallocates all per-run scratch.
    pub fn new(trace: Arc<Trace>) -> FastSim {
        Self::with_options(trace, SimOptions::default())
    }

    /// Build with explicit [`SimOptions`].
    pub fn with_options(trace: Arc<Trace>, opts: SimOptions) -> FastSim {
        let nch = trace.channels.len();
        let nproc = trace.ops.len();
        let widths: Vec<u32> = trace.channels.iter().map(|c| c.width_bits).collect();
        let wr_times = trace
            .channels
            .iter()
            .map(|c| vec![0u64; c.writes as usize].into_boxed_slice())
            .collect();
        let rd_times = trace
            .channels
            .iter()
            .map(|c| vec![0u64; c.reads as usize].into_boxed_slice())
            .collect();
        // Run-length encode homogeneous op bursts (suffix scan).
        let run_len = trace
            .ops
            .iter()
            .map(|ops| {
                let n = ops.len();
                let mut rl = vec![1u32; n].into_boxed_slice();
                for k in (0..n.saturating_sub(1)).rev() {
                    if ops[k + 1].delay == 0
                        && ops[k + 1].chan() == ops[k].chan()
                        && ops[k + 1].is_write() == ops[k].is_write()
                    {
                        rl[k] = rl[k + 1] + 1;
                    }
                }
                rl
            })
            .collect();
        let pair_run = trace
            .ops
            .iter()
            .map(|ops| {
                let n = ops.len();
                let mut pr = vec![0u32; n].into_boxed_slice();
                for k in (0..n.saturating_sub(1)).rev() {
                    let (a, b) = (ops[k], ops[k + 1]);
                    if !a.is_write() && !b.is_write() && a.chan() != b.chan() && b.delay == 0 {
                        let cont = if k + 3 < n
                            && ops[k + 2].delay == 0
                            && !ops[k + 2].is_write()
                            && ops[k + 2].chan() == a.chan()
                            && ops[k + 3].delay == 0
                            && !ops[k + 3].is_write()
                            && ops[k + 3].chan() == b.chan()
                        {
                            pr[k + 2]
                        } else {
                            0
                        };
                        pr[k] = 1 + cont;
                    }
                }
                pr
            })
            .collect();
        let index = Arc::new(ChanOpIndex::build(&trace));
        FastSim {
            trace,
            opts,
            widths,
            wr_times,
            rd_times,
            wr_done: vec![0; nch],
            rd_done: vec![0; nch],
            wait_reader: vec![NONE; nch],
            wait_writer: vec![NONE; nch],
            pc: vec![0; nproc],
            last_commit: vec![NO_TIME; nproc],
            ready: Vec::with_capacity(nproc),
            in_ready: vec![false; nproc],
            rd_lat: vec![0; nch],
            run_len,
            pair_run,
            index,
            incremental: true,
            last_depths: Vec::with_capacity(nch),
            last_outcome: None,
            info: RunInfo::default(),
            ckpt: vec![0; nproc],
            wl: Vec::with_capacity(nproc),
            in_wl: vec![false; nproc],
        }
    }

    /// The trace this simulator evaluates.
    pub fn trace(&self) -> &Arc<Trace> {
        &self.trace
    }

    /// Enable/disable schedule retention and delta replay (on by
    /// default). Disabling drops the retained schedule, so every
    /// subsequent `simulate` is a cold full replay — used by the
    /// differential fuzz tests and the §Perf 6 bench as the reference.
    pub fn set_incremental(&mut self, on: bool) {
        self.incremental = on;
        if !on {
            self.last_outcome = None;
            self.last_depths.clear();
        }
    }

    /// Telemetry of the most recent `simulate`/`simulate_with_stats`
    /// call: whether the retained schedule was reused, how many channels
    /// were dirty, and how many trace ops were re-propagated.
    pub fn last_run(&self) -> RunInfo {
        self.info
    }

    /// Evaluate one FIFO depth configuration. `depths.len()` must equal
    /// the number of channels. Zero heap allocation on this path.
    pub fn simulate(&mut self, depths: &[u32]) -> SimOutcome {
        self.run(depths)
    }

    /// Evaluate a configuration and also collect per-channel occupancy
    /// and stall statistics (used by the greedy optimizer; somewhat
    /// slower). Allocates one [`ChannelStats`]; use
    /// [`simulate_with_stats_into`](Self::simulate_with_stats_into) to
    /// reuse a caller-owned buffer instead.
    pub fn simulate_with_stats(&mut self, depths: &[u32]) -> (SimOutcome, ChannelStats) {
        let mut stats = ChannelStats::new();
        let outcome = self.simulate_with_stats_into(depths, &mut stats);
        (outcome, stats)
    }

    /// [`simulate_with_stats`](Self::simulate_with_stats) writing into a
    /// reusable buffer: zero heap allocation once `stats` has been sized
    /// by a first call. The per-op channel ordinals come from the static
    /// [`ChanOpIndex`], so the stall post-pass needs no per-process
    /// counter vectors either.
    pub fn simulate_with_stats_into(
        &mut self,
        depths: &[u32],
        stats: &mut ChannelStats,
    ) -> SimOutcome {
        let outcome = self.run(depths);
        let trace = self.trace.clone();
        let nch = trace.channels.len();
        stats.max_occupancy.clear();
        stats.max_occupancy.resize(nch, 0);
        stats.write_stall.clear();
        stats.write_stall.resize(nch, 0);
        stats.read_stall.clear();
        stats.read_stall.resize(nch, 0);
        // Occupancy post-pass: per channel, writes and reads each commit in
        // nondecreasing time order, so a sorted merge tracks occupancy.
        for ch in 0..nch {
            let w = &self.wr_times[ch][..self.wr_done[ch] as usize];
            let r = &self.rd_times[ch][..self.rd_done[ch] as usize];
            let (mut wi, mut ri) = (0usize, 0usize);
            let mut occ: i64 = 0;
            let mut max_occ: i64 = 0;
            while wi < w.len() || ri < r.len() {
                // A read at time t removes a token written at time ≤ t;
                // process the event with the smaller time first, writes
                // before reads at equal time (a token cannot be read out
                // the same cycle its slot frees for occupancy purposes —
                // consistent with rl ≥ 1 meaning wr[j] < rd[j] always).
                if wi < w.len() && (ri >= r.len() || w[wi] <= r[ri]) {
                    occ += 1;
                    max_occ = max_occ.max(occ);
                    wi += 1;
                } else {
                    occ -= 1;
                    ri += 1;
                }
            }
            stats.max_occupancy[ch] = max_occ.max(0) as u32;
        }
        // Stall post-pass: replay each process's schedule, comparing
        // unconstrained start vs commit. The op's channel ordinal comes
        // from the trace index.
        for (pid, ops) in trace.ops.iter().enumerate() {
            let committed = self.pc[pid] as usize;
            let ord = &self.index.op_ord[pid];
            let mut prev: u64 = NO_TIME;
            for (k, op) in ops[..committed].iter().enumerate() {
                let ch = op.chan();
                let j = ord[k] as usize;
                let start = if prev == NO_TIME {
                    op.delay as u64
                } else {
                    prev + 1 + op.delay as u64
                };
                let commit = if op.is_write() {
                    self.wr_times[ch][j]
                } else {
                    self.rd_times[ch][j]
                };
                let stall = commit.saturating_sub(start);
                if op.is_write() {
                    stats.write_stall[ch] += stall;
                } else {
                    stats.read_stall[ch] += stall;
                }
                prev = commit;
            }
        }
        outcome
    }

    /// Dispatch one evaluation: delta replay against the retained
    /// schedule when possible, full replay otherwise.
    fn run(&mut self, depths: &[u32]) -> SimOutcome {
        let nch = self.trace.channels.len();
        assert_eq!(
            depths.len(),
            nch,
            "configuration has {} depths, design has {} FIFOs",
            depths.len(),
            nch
        );
        self.info = RunInfo {
            total_ops: self.trace.total_ops() as u64,
            ..RunInfo::default()
        };
        let attempt = if self.incremental && self.last_outcome.is_some() {
            self.try_incremental(depths)
        } else {
            None
        };
        let out = match attempt {
            Some(out) => out,
            None => {
                let out = self.run_full(depths);
                self.info.replayed_ops = self.pc.iter().map(|&p| p as u64).sum();
                out
            }
        };
        if self.incremental {
            self.last_depths.clear();
            self.last_depths.extend_from_slice(depths);
            self.last_outcome = Some(out.clone());
        }
        out
    }

    /// Cold path: reset all scratch, then propagate from the beginning.
    fn run_full(&mut self, depths: &[u32]) -> SimOutcome {
        let nch = self.trace.channels.len();
        let nproc = self.trace.ops.len();
        for v in &mut self.wr_done {
            *v = 0;
        }
        for v in &mut self.rd_done {
            *v = 0;
        }
        for v in &mut self.wait_reader {
            *v = NONE;
        }
        for v in &mut self.wait_writer {
            *v = NONE;
        }
        for v in &mut self.pc {
            *v = 0;
        }
        for v in &mut self.last_commit {
            *v = NO_TIME;
        }
        self.ready.clear();
        for p in 0..nproc {
            self.ready.push(p as u32);
            self.in_ready[p] = true;
        }
        for ch in 0..nch {
            self.rd_lat[ch] =
                super::read_latency(depths[ch], self.widths[ch], self.opts.uniform_read_latency);
        }
        self.propagate(depths)
    }

    /// Delta path: diff against the retained configuration, compute the
    /// per-process replay checkpoints, rewind, and propagate only the
    /// invalidated suffix. Returns `None` when a full replay is the
    /// better (or only safe) choice.
    fn try_incremental(&mut self, depths: &[u32]) -> Option<SimOutcome> {
        let trace = self.trace.clone();
        let index = self.index.clone();
        let nch = trace.channels.len();
        let nproc = trace.ops.len();

        // Shared delta-invalidation core (see [`super::delta_checkpoints`]):
        // seed from the dirty channel set — `rd_lat` still holds the
        // retained run's latencies, so an SRL↔BRAM crossing shows up as a
        // latency mismatch — then run the checkpoint fixpoint. One
        // implementation serves both backends, so the invalidation rule
        // cannot silently diverge between them.
        let n_dirty = super::delta_checkpoints(
            &trace,
            &index,
            &self.last_depths,
            depths,
            &self.rd_lat,
            &self.widths,
            self.opts.uniform_read_latency,
            &mut self.ckpt,
            &mut self.wl,
            &mut self.in_wl,
        );
        self.info.dirty_channels = n_dirty;
        if n_dirty == 0 {
            // Identical configuration: the retained schedule *is* the
            // answer, and all scratch already holds its fixpoint.
            self.info.incremental = true;
            return self.last_outcome.clone();
        }

        // Cost gate: when (almost) everything must be replayed, the
        // bookkeeping below is pure overhead — do a plain full replay.
        let total = self.info.total_ops;
        let invalid = super::invalid_ops(&trace, &self.ckpt);
        if invalid * 100 >= total * INCR_FALLBACK_PCT {
            // Full replay: keep the documented contract that telemetry
            // reports zero dirty channels for non-incremental runs.
            self.info.dirty_channels = 0;
            return None;
        }

        // Rewind. A process restarts at min(checkpoint, committed pc):
        // ops before that point keep their retained commit times (they
        // are the fixpoint prefix); everything after is recomputed.
        // Previously-blocked processes restart at their blocked position
        // even when nothing invalidated them — a depth change elsewhere
        // may have unblocked them, and re-parking is O(1) if not.
        self.ready.clear();
        let mut replay_base: u64 = 0;
        for p in 0..nproc {
            let restart = self.ckpt[p].min(self.pc[p]);
            self.pc[p] = restart;
            self.last_commit[p] = if restart == 0 {
                NO_TIME
            } else {
                let op = trace.ops[p][restart as usize - 1];
                let j = index.op_ord[p][restart as usize - 1] as usize;
                if op.is_write() {
                    self.wr_times[op.chan()][j]
                } else {
                    self.rd_times[op.chan()][j]
                }
            };
            if (restart as usize) < trace.ops[p].len() {
                self.ready.push(p as u32);
                self.in_ready[p] = true;
            } else {
                self.in_ready[p] = false;
            }
            replay_base += restart as u64;
        }
        // Channel rewind: commit counters fall back to the number of ops
        // each endpoint committed before its restart point (every op
        // before a restart point was committed in the retained run).
        for ch in 0..nch {
            self.wait_reader[ch] = NONE;
            self.wait_writer[ch] = NONE;
            let w = index.writer[ch];
            if w != NONE {
                self.wr_done[ch] =
                    index.wr_ops[ch].partition_point(|&i| i < self.pc[w as usize]) as u32;
            }
            let r = index.reader[ch];
            if r != NONE {
                self.rd_done[ch] =
                    index.rd_ops[ch].partition_point(|&i| i < self.pc[r as usize]) as u32;
            }
            self.rd_lat[ch] =
                super::read_latency(depths[ch], self.widths[ch], self.opts.uniform_read_latency);
        }

        self.info.incremental = true;
        let out = self.propagate(depths);
        self.info.replayed_ops = self.pc.iter().map(|&p| p as u64).sum::<u64>() - replay_base;
        Some(out)
    }

    /// Event-driven commit propagation from the current scratch state
    /// (shared by the full and delta paths), then outcome extraction.
    fn propagate(&mut self, depths: &[u32]) -> SimOutcome {
        let trace = self.trace.clone();
        let nproc = trace.ops.len();

        while let Some(pid) = self.ready.pop() {
            let pid = pid as usize;
            self.in_ready[pid] = false;
            let ops = &trace.ops[pid];
            let mut pc = self.pc[pid] as usize;
            let mut prev = self.last_commit[pid];

            while pc < ops.len() {
                let op = ops[pc];
                let ch = op.chan();
                let start = if prev == NO_TIME {
                    op.delay as u64
                } else {
                    prev + 1 + op.delay as u64
                };
                if op.is_write() {
                    let j = self.wr_done[ch];
                    let d = depths[ch];
                    let commit = if j >= d {
                        let need = (j - d) as usize;
                        if self.rd_done[ch] as usize <= need {
                            // FIFO full and the freeing read hasn't
                            // committed: park as the channel's writer.
                            self.wait_writer[ch] = pid as u32;
                            break;
                        }
                        start.max(self.rd_times[ch][need] + 1)
                    } else {
                        start
                    };
                    self.wr_times[ch][j as usize] = commit;
                    self.wr_done[ch] = j + 1;
                    prev = commit;
                    pc += 1;
                    // Burst fast path for the rest of a homogeneous
                    // zero-delay write run. Phase A: ordinals below the
                    // depth are wholly unconstrained (commit = prev + 1).
                    // Phase B: ordinals in [d, rd_done + d) have a
                    // committed freeing read, so commit =
                    // max(prev + 1, rd[k-d] + 1) — still branch-free.
                    let run = self.run_len[pid][pc - 1];
                    if run > 1 {
                        let end_of_run = self.wr_done[ch] as u64 + run as u64 - 1;
                        // Phase A.
                        let a_end = end_of_run.min(d as u64);
                        let base = self.wr_done[ch] as u64;
                        if a_end > base {
                            let m = (a_end - base) as u32;
                            let times =
                                &mut self.wr_times[ch][base as usize..(base + m as u64) as usize];
                            for (i, slot) in times.iter_mut().enumerate() {
                                *slot = prev + 1 + i as u64;
                            }
                            prev += m as u64;
                            self.wr_done[ch] += m;
                            pc += m as usize;
                        }
                        // Phase B.
                        let base = self.wr_done[ch] as u64;
                        let b_end = end_of_run.min(self.rd_done[ch] as u64 + d as u64);
                        if b_end > base && base >= d as u64 {
                            let m = (b_end - base) as usize;
                            let need0 = (base - d as u64) as usize;
                            // Split borrows: read times are immutable here.
                            let (rd_all, wr_all) =
                                (&self.rd_times[ch], &mut self.wr_times[ch]);
                            let rd = &rd_all[need0..need0 + m];
                            let wr = &mut wr_all[base as usize..base as usize + m];
                            for (r_t, w_t) in rd.iter().zip(wr.iter_mut()) {
                                let commit = (prev + 1).max(r_t + 1);
                                *w_t = commit;
                                prev = commit;
                            }
                            self.wr_done[ch] += m as u32;
                            pc += m;
                        }
                    }
                    // Wake the reader parked on this channel, if any.
                    let w = self.wait_reader[ch];
                    if w != NONE {
                        self.wait_reader[ch] = NONE;
                        if !self.in_ready[w as usize] {
                            self.in_ready[w as usize] = true;
                            self.ready.push(w);
                        }
                    }
                } else {
                    // Alternating-pair burst (matmul PE pattern): commit
                    // whole (A,B) read pairs while both channels have
                    // committed writes available.
                    let pairs = self.pair_run[pid][pc];
                    if pairs > 1 {
                        let b_ch = trace.ops[pid][pc + 1].chan();
                        let m = pairs
                            .min(self.wr_done[ch] - self.rd_done[ch])
                            .min(self.wr_done[b_ch] - self.rd_done[b_ch]);
                        if m >= 1 {
                            let (la, lb) = (self.rd_lat[ch], self.rd_lat[b_ch]);
                            let ja = self.rd_done[ch] as usize;
                            let jb = self.rd_done[b_ch] as usize;
                            let mut p = prev;
                            for i in 0..m as usize {
                                let s = if p == NO_TIME {
                                    op.delay as u64
                                } else if i == 0 {
                                    p + 1 + op.delay as u64
                                } else {
                                    p + 1
                                };
                                let ca = s.max(self.wr_times[ch][ja + i] + la);
                                self.rd_times[ch][ja + i] = ca;
                                let cb = (ca + 1).max(self.wr_times[b_ch][jb + i] + lb);
                                self.rd_times[b_ch][jb + i] = cb;
                                p = cb;
                            }
                            self.rd_done[ch] += m;
                            self.rd_done[b_ch] += m;
                            prev = p;
                            pc += 2 * m as usize;
                            for chx in [ch, b_ch] {
                                let w = self.wait_writer[chx];
                                if w != NONE {
                                    self.wait_writer[chx] = NONE;
                                    if !self.in_ready[w as usize] {
                                        self.in_ready[w as usize] = true;
                                        self.ready.push(w);
                                    }
                                }
                            }
                            continue;
                        }
                    }
                    let j = self.rd_done[ch];
                    if self.wr_done[ch] <= j {
                        self.wait_reader[ch] = pid as u32;
                        break;
                    }
                    let commit = start.max(self.wr_times[ch][j as usize] + self.rd_lat[ch]);
                    self.rd_times[ch][j as usize] = commit;
                    self.rd_done[ch] = j + 1;
                    prev = commit;
                    pc += 1;
                    // Burst fast path: drain a homogeneous zero-delay read
                    // run against already-committed writes.
                    let run = self.run_len[pid][pc - 1];
                    if run > 1 {
                        let m = (run - 1).min(self.wr_done[ch] - self.rd_done[ch]);
                        if m > 0 {
                            let base = self.rd_done[ch] as usize;
                            let lat = self.rd_lat[ch];
                            let wr = &self.wr_times[ch][base..base + m as usize];
                            let rd = &mut self.rd_times[ch][base..base + m as usize];
                            for (w_t, r_t) in wr.iter().zip(rd.iter_mut()) {
                                let commit = (prev + 1).max(w_t + lat);
                                *r_t = commit;
                                prev = commit;
                            }
                            self.rd_done[ch] += m;
                            pc += m as usize;
                        }
                    }
                    let w = self.wait_writer[ch];
                    if w != NONE {
                        self.wait_writer[ch] = NONE;
                        if !self.in_ready[w as usize] {
                            self.in_ready[w as usize] = true;
                            self.ready.push(w);
                        }
                    }
                }
            }
            self.pc[pid] = pc as u32;
            self.last_commit[pid] = prev;
        }

        // Fixpoint reached: all done, or deadlock.
        let mut blocked = Vec::new();
        for pid in 0..nproc {
            let pc = self.pc[pid] as usize;
            if pc < trace.ops[pid].len() {
                let op = trace.ops[pid][pc];
                blocked.push(BlockInfo {
                    process: pid,
                    channel: op.chan(),
                    on_write: op.is_write(),
                });
            }
        }
        if !blocked.is_empty() {
            return SimOutcome::Deadlock { blocked };
        }

        let mut latency = 0u64;
        for pid in 0..nproc {
            let done = if self.last_commit[pid] == NO_TIME {
                // No FIFO ops: the process is pure compute.
                trace.tail_delays[pid]
            } else {
                self.last_commit[pid] + 1 + trace.tail_delays[pid]
            };
            latency = latency.max(done);
        }
        SimOutcome::Done { latency }
    }
}

impl super::SimBackend for FastSim {
    fn name(&self) -> &'static str {
        "fast"
    }
    fn trace(&self) -> &Arc<Trace> {
        FastSim::trace(self)
    }
    fn simulate(&mut self, depths: &[u32]) -> SimOutcome {
        FastSim::simulate(self, depths)
    }
    fn simulate_with_stats_into(&mut self, depths: &[u32], stats: &mut ChannelStats) -> SimOutcome {
        FastSim::simulate_with_stats_into(self, depths, stats)
    }
    fn last_run(&self) -> RunInfo {
        FastSim::last_run(self)
    }
    fn set_incremental(&mut self, on: bool) {
        FastSim::set_incremental(self, on)
    }
    fn clone_box(&self) -> Box<dyn super::SimBackend> {
        Box::new(self.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DesignBuilder, Expr};
    use crate::trace::collect_trace;

    fn sim_for(design: &crate::ir::Design, args: &[i64]) -> FastSim {
        let t = collect_trace(design, args).unwrap();
        FastSim::new(Arc::new(t))
    }

    /// producer → consumer through one FIFO, fully rate-matched.
    fn pipe_design(n: u64) -> crate::ir::Design {
        let mut b = DesignBuilder::new("pipe", 0);
        let c = b.channel("c", 32);
        b.process("prod", move |p| {
            p.for_n(n, |p, _| p.write(c, Expr::c(1)));
        });
        b.process("cons", move |p| {
            p.for_n(n, |p, _| {
                let _ = p.read(c);
            });
        });
        b.build()
    }

    #[test]
    fn pipe_latency_formula() {
        // writes commit at 0,1,..,n-1; reads at wr+rl (SRL: rl=1) →
        // reads commit 1..n → latency = n+1.
        let d = pipe_design(8);
        let mut s = sim_for(&d, &[]);
        let out = s.simulate(&[8]);
        assert_eq!(out, SimOutcome::Done { latency: 9 });
        // Depth 2 is enough: reader keeps pace with writer.
        assert_eq!(s.simulate(&[2]).latency(), Some(9));
    }

    #[test]
    fn depth_one_throttles() {
        // depth 1: write j+1 must wait for read j to commit + 1.
        // w0=0, r0=1, w1=max(1, r0+1)=2, r1=3, w2=4 ... latency 2n-1+1.
        let d = pipe_design(4);
        let mut s = sim_for(&d, &[]);
        assert_eq!(s.simulate(&[1]).latency(), Some(8));
    }

    #[test]
    fn bram_fifo_adds_read_cycle() {
        // Wide channel so depth > 2 crosses the SRL bit threshold:
        // width 1024 → any depth > 1 is BRAM (d*w > 1024) unless d ≤ 2.
        let mut b = DesignBuilder::new("wide", 0);
        let c = b.channel("wide", 1024);
        b.process("p", |p| {
            p.for_n(4, |p, _| p.write(c, Expr::c(0)));
        });
        b.process("q", |p| {
            p.for_n(4, |p, _| {
                let _ = p.read(c);
            });
        });
        let d = b.build();
        let mut s = sim_for(&d, &[]);
        let srl = s.simulate(&[2]).latency().unwrap();
        let bram = s.simulate(&[4]).latency().unwrap();
        // Same pipeline but BRAM read latency 2 instead of 1 → one cycle
        // slower end-to-end (footnote 2 of the paper, in reverse).
        assert_eq!(bram, srl + 1);
    }

    #[test]
    fn fig2_deadlock_threshold() {
        // Paper Fig. 2: producer writes n to x then n to y; consumer
        // alternates x,y reads. x must buffer n-1 leftovers while the
        // consumer waits for y; depth(x) < n-1 deadlocks.
        let mut b = DesignBuilder::new("mult_by_2", 1);
        let x = b.channel("x", 32);
        let y = b.channel("y", 32);
        b.process("producer", |p| {
            p.for_expr(Expr::arg(0), |p, _| p.write(x, Expr::c(1)));
            p.for_expr(Expr::arg(0), |p, _| p.write(y, Expr::c(1)));
        });
        b.process("consumer", |p| {
            p.for_expr(Expr::arg(0), |p, _| {
                let _ = p.read(x);
                let _ = p.read(y);
            });
        });
        let design = b.build();
        let n = 16i64;
        let mut s = sim_for(&design, &[n]);
        // Ample depths: no deadlock.
        assert!(!s.simulate(&[n as u32, 2]).is_deadlock());
        assert!(!s.simulate(&[n as u32 - 1, 2]).is_deadlock());
        // Too small: deadlock, blocked writer on y? producer stuck on x.
        let out = s.simulate(&[2, 2]);
        match &out {
            SimOutcome::Deadlock { blocked } => {
                assert!(blocked.iter().any(|b| b.on_write && b.channel == 0));
                assert!(blocked.iter().any(|b| !b.on_write && b.channel == 1));
            }
            other => panic!("expected deadlock, got {other:?}"),
        }
    }

    #[test]
    fn delays_shift_schedule() {
        let mut b = DesignBuilder::new("dly", 0);
        let c = b.channel("c", 32);
        b.process("p", |p| {
            p.delay(100);
            p.write(c, Expr::c(0));
        });
        b.process("q", |p| {
            let _ = p.read(c);
        });
        let d = b.build();
        let mut s = sim_for(&d, &[]);
        // write at 100, read at 101, latency 102.
        assert_eq!(s.simulate(&[2]).latency(), Some(102));
    }

    #[test]
    fn tail_delay_counts() {
        let mut b = DesignBuilder::new("tail", 0);
        let c = b.channel("c", 32);
        b.process("p", |p| {
            p.write(c, Expr::c(0));
        });
        b.process("q", |p| {
            let _ = p.read(c);
            p.delay(50);
        });
        let d = b.build();
        let mut s = sim_for(&d, &[]);
        // write 0, read 1, +1 +50 → 52.
        assert_eq!(s.simulate(&[2]).latency(), Some(52));
    }

    #[test]
    fn stats_occupancy_and_stalls() {
        // Slow reader: delay 3 between reads → FIFO backs up.
        let mut b = DesignBuilder::new("slow", 0);
        let c = b.channel("c", 32);
        b.process("p", |p| {
            p.for_n(8, |p, _| p.write(c, Expr::c(0)));
        });
        b.process("q", |p| {
            p.for_n(8, |p, _| {
                p.delay(3);
                let _ = p.read(c);
            });
        });
        let d = b.build();
        let mut s = sim_for(&d, &[]);
        let (out, stats) = s.simulate_with_stats(&[8]);
        assert!(!out.is_deadlock());
        assert!(stats.max_occupancy[0] >= 2, "{:?}", stats.max_occupancy);
        assert_eq!(stats.write_stall[0], 0);
        // With depth 2 the writer must stall.
        let (_, stats2) = s.simulate_with_stats(&[2]);
        assert!(stats2.write_stall[0] > 0);
        assert!(stats2.max_occupancy[0] <= 2);
    }

    #[test]
    fn stats_into_reuses_buffer() {
        let d = pipe_design(16);
        let mut s = sim_for(&d, &[]);
        let mut buf = ChannelStats::new();
        let a = s.simulate_with_stats_into(&[4], &mut buf);
        let occ_a = buf.max_occupancy.clone();
        // Second call with a different config must fully overwrite.
        let b = s.simulate_with_stats_into(&[1], &mut buf);
        assert!(!a.is_deadlock() && !b.is_deadlock());
        let (_, fresh) = sim_for(&d, &[]).simulate_with_stats(&[1]);
        assert_eq!(buf.max_occupancy, fresh.max_occupancy);
        assert_eq!(buf.write_stall, fresh.write_stall);
        assert_eq!(buf.read_stall, fresh.read_stall);
        // And the first call matched a fresh run too.
        let (_, fresh_a) = sim_for(&d, &[]).simulate_with_stats(&[4]);
        assert_eq!(occ_a, fresh_a.max_occupancy);
    }

    #[test]
    fn monotone_latency_in_depth_uniform_latency() {
        let mut b = DesignBuilder::new("mono", 0);
        let c = b.channel("c", 32);
        let e = b.channel("e", 32);
        b.process("p", |p| {
            p.for_n(32, |p, _| {
                p.write(c, Expr::c(0));
            });
        });
        b.process("mid", |p| {
            p.for_n(32, |p, _| {
                let _ = p.read(c);
                p.delay(2);
                p.write(e, Expr::c(0));
            });
        });
        b.process("q", |p| {
            p.for_n(32, |p, _| {
                p.delay(1);
                let _ = p.read(e);
            });
        });
        let d = b.build();
        let t = Arc::new(collect_trace(&d, &[]).unwrap());
        let mut s = FastSim::with_options(
            t,
            SimOptions {
                uniform_read_latency: true,
            },
        );
        let mut prev = u64::MAX;
        for depth in [1u32, 2, 4, 8, 16, 32] {
            let lat = s.simulate(&[depth, depth]).latency().unwrap();
            assert!(lat <= prev, "depth {depth}: {lat} > {prev}");
            prev = lat;
        }
    }

    #[test]
    fn repeated_simulation_is_stable() {
        let d = pipe_design(100);
        let mut s = sim_for(&d, &[]);
        let a = s.simulate(&[7]);
        let b = s.simulate(&[2]);
        let a2 = s.simulate(&[7]);
        let b2 = s.simulate(&[2]);
        assert_eq!(a, a2);
        assert_eq!(b, b2);
    }

    // -----------------------------------------------------------------
    // Delta-incremental re-simulation
    // -----------------------------------------------------------------

    /// split → two parallel branches → join; enough parallel structure
    /// that a single-channel delta leaves part of the trace valid.
    fn diamond_design(n: u64) -> crate::ir::Design {
        let mut b = DesignBuilder::new("diamond", 0);
        let a1 = b.channel("a1", 32);
        let a2 = b.channel("a2", 32);
        let b1 = b.channel("b1", 32);
        let b2 = b.channel("b2", 32);
        b.process("src", move |p| {
            p.for_n(n, |p, _| {
                p.write(a1, Expr::c(0));
                p.write(a2, Expr::c(0));
            })
        });
        b.process("slow", move |p| {
            p.for_n(n, |p, _| {
                let _ = p.read(a1);
                p.delay(7);
                p.write(b1, Expr::c(0));
            })
        });
        b.process("fastbr", move |p| {
            p.for_n(n, |p, _| {
                let _ = p.read(a2);
                p.write(b2, Expr::c(0));
            })
        });
        b.process("join", move |p| {
            p.for_n(n, |p, _| {
                let _ = p.read(b1);
                let _ = p.read(b2);
            })
        });
        b.build()
    }

    #[test]
    fn incremental_identical_config_short_circuits() {
        let d = pipe_design(64);
        let mut s = sim_for(&d, &[]);
        let a = s.simulate(&[4]);
        assert!(!s.last_run().incremental, "first run must be cold");
        let b = s.simulate(&[4]);
        assert_eq!(a, b);
        let info = s.last_run();
        assert!(info.incremental);
        assert_eq!(info.dirty_channels, 0);
        assert_eq!(info.replayed_ops, 0);
    }

    #[test]
    fn incremental_single_channel_delta_matches_cold_replay() {
        let d = diamond_design(64);
        let mut warm = sim_for(&d, &[]);
        let mut cold = sim_for(&d, &[]);
        cold.set_incremental(false);
        let mut incremental_hits = 0;
        // A DSE-like walk: start ample, then mutate one channel at a time.
        let configs: [[u32; 4]; 7] = [
            [64, 64, 64, 64],
            [64, 64, 64, 2],
            [64, 64, 64, 64],
            [64, 64, 2, 64],
            [64, 64, 2, 2],
            [2, 64, 2, 2],
            [64, 64, 63, 2],
        ];
        for cfg in &configs {
            let w = warm.simulate(cfg);
            let c = cold.simulate(cfg);
            assert_eq!(w, c, "cfg {cfg:?}");
            assert!(!cold.last_run().incremental);
            if warm.last_run().incremental {
                incremental_hits += 1;
                assert!(
                    warm.last_run().replayed_ops <= warm.last_run().total_ops,
                    "replayed more than the trace holds"
                );
            }
        }
        assert!(
            incremental_hits >= 2,
            "expected some delta replays on single-channel mutations, got {incremental_hits}"
        );
    }

    #[test]
    fn incremental_srl_bram_flip_matches_cold_replay() {
        // Width 600: depth 1 → SRL (rl 1), depth ≥ 3 → BRAM (rl 2);
        // crossing must invalidate every read on the channel.
        let mut b = DesignBuilder::new("flip", 0);
        let w = b.channel("w", 600);
        let n = b.channel("n", 8);
        b.process("p", |p| {
            p.for_n(32, |p, _| {
                p.write(w, Expr::c(0));
                p.write(n, Expr::c(0));
            });
        });
        b.process("q", |p| {
            p.for_n(32, |p, _| {
                let _ = p.read(w);
                let _ = p.read(n);
            });
        });
        let d = b.build();
        let mut warm = sim_for(&d, &[]);
        let mut cold = sim_for(&d, &[]);
        cold.set_incremental(false);
        for cfg in [[2u32, 8], [4, 8], [2, 8], [32, 8], [1, 8]] {
            assert_eq!(warm.simulate(&cfg), cold.simulate(&cfg), "cfg {cfg:?}");
        }
    }

    #[test]
    fn incremental_deadlock_transitions_match_cold_replay() {
        // fig2-style: feasibility flips as the x depth crosses n-1.
        let mut b = DesignBuilder::new("fig2ish", 1);
        let x = b.channel("x", 32);
        let y = b.channel("y", 32);
        b.process("producer", |p| {
            p.for_expr(Expr::arg(0), |p, _| p.write(x, Expr::c(1)));
            p.for_expr(Expr::arg(0), |p, _| p.write(y, Expr::c(1)));
        });
        b.process("consumer", |p| {
            p.for_expr(Expr::arg(0), |p, _| {
                let _ = p.read(x);
                let _ = p.read(y);
            });
        });
        let design = b.build();
        let t = Arc::new(collect_trace(&design, &[16]).unwrap());
        let mut warm = FastSim::new(t.clone());
        let mut cold = FastSim::new(t);
        cold.set_incremental(false);
        for cfg in [
            [2u32, 2],
            [16, 2],
            [15, 2],
            [14, 2],
            [15, 2],
            [2, 2],
            [16, 16],
            [2, 2],
        ] {
            let w = warm.simulate(&cfg);
            let c = cold.simulate(&cfg);
            assert_eq!(w, c, "cfg {cfg:?} (full outcome incl. blocked set)");
        }
    }

    #[test]
    fn incremental_stats_match_cold_replay() {
        let d = diamond_design(32);
        let mut warm = sim_for(&d, &[]);
        let mut cold = sim_for(&d, &[]);
        cold.set_incremental(false);
        for cfg in [[32u32, 32, 32, 32], [32, 32, 32, 4], [32, 32, 32, 3]] {
            let (wo, ws) = warm.simulate_with_stats(&cfg);
            let (co, cs) = cold.simulate_with_stats(&cfg);
            assert_eq!(wo, co, "cfg {cfg:?}");
            assert_eq!(ws.max_occupancy, cs.max_occupancy, "cfg {cfg:?}");
            assert_eq!(ws.write_stall, cs.write_stall, "cfg {cfg:?}");
            assert_eq!(ws.read_stall, cs.read_stall, "cfg {cfg:?}");
        }
    }

    #[test]
    fn incremental_disabled_never_reuses() {
        let d = pipe_design(32);
        let mut s = sim_for(&d, &[]);
        s.set_incremental(false);
        s.simulate(&[4]);
        s.simulate(&[4]);
        assert!(!s.last_run().incremental);
        assert_eq!(s.last_run().replayed_ops, s.last_run().total_ops);
        // Re-enabling starts cold (no stale retained schedule).
        s.set_incremental(true);
        s.simulate(&[4]);
        assert!(!s.last_run().incremental);
        s.simulate(&[4]);
        assert!(s.last_run().incremental);
    }
}
