//! The golden reference simulator — the role C/RTL co-simulation plays in
//! the paper's Table II accuracy study.
//!
//! Implements exactly the cycle semantics documented in [`super`] but with
//! a deliberately different algorithm: a global clock advanced
//! cycle-by-cycle (with idle-gap skipping), where every process re-checks
//! its pending operation against the current cycle. No event lists, no
//! wake bookkeeping — simple enough to be audited by eye, and
//! structurally independent from [`super::fast`] so that implementation
//! bugs in either show up as divergence in the equivalence tests and the
//! Table II bench.

use super::SimOptions;
use crate::trace::Trace;

/// Outcome of a golden-model run (mirrors [`super::fast::SimOutcome`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum GoldenOutcome {
    Done { latency: u64 },
    Deadlock,
}

impl GoldenOutcome {
    pub fn latency(&self) -> Option<u64> {
        match self {
            GoldenOutcome::Done { latency } => Some(*latency),
            GoldenOutcome::Deadlock => None,
        }
    }
}

/// Simulate `trace` under `depths` with a global-clock algorithm.
pub fn simulate_golden(trace: &Trace, depths: &[u32], opts: SimOptions) -> GoldenOutcome {
    let nch = trace.channels.len();
    let nproc = trace.ops.len();
    assert_eq!(depths.len(), nch);

    let rd_lat: Vec<u64> = (0..nch)
        .map(|c| super::read_latency(depths[c], trace.channels[c].width_bits, opts.uniform_read_latency))
        .collect();

    // Full commit-time history per channel (golden model keeps it simple:
    // allocate everything, every run).
    let mut wr_times: Vec<Vec<u64>> = trace
        .channels
        .iter()
        .map(|c| Vec::with_capacity(c.writes as usize))
        .collect();
    let mut rd_times: Vec<Vec<u64>> = trace
        .channels
        .iter()
        .map(|c| Vec::with_capacity(c.reads as usize))
        .collect();

    let mut pc = vec![0usize; nproc];
    let mut last_commit: Vec<Option<u64>> = vec![None; nproc];

    let mut t: u64 = 0;
    loop {
        // Try to commit at cycle t. Each process commits at most one op per
        // cycle (II = 1). Iterate until no further commits happen at t
        // (same-cycle commits never enable one another given the +1 / rl≥1
        // margins, but a single pass in process order is not guaranteed to
        // attempt ops in dependency order, so fixpoint within the cycle —
        // bounded by one commit per process — keeps it order-independent).
        let mut committed_this_cycle = vec![false; nproc];
        let mut progressed = true;
        while progressed {
            progressed = false;
            for p in 0..nproc {
                if committed_this_cycle[p] || pc[p] >= trace.ops[p].len() {
                    continue;
                }
                let op = trace.ops[p][pc[p]];
                let ch = op.chan();
                let start = match last_commit[p] {
                    None => op.delay as u64,
                    Some(prev) => prev + 1 + op.delay as u64,
                };
                if start > t {
                    continue;
                }
                let can_commit = if op.is_write() {
                    let j = wr_times[ch].len() as u32;
                    let d = depths[ch];
                    if j >= d {
                        let need = (j - d) as usize;
                        rd_times[ch].len() > need && rd_times[ch][need] + 1 <= t
                    } else {
                        true
                    }
                } else {
                    let j = rd_times[ch].len();
                    wr_times[ch].len() > j && wr_times[ch][j] + rd_lat[ch] <= t
                };
                if can_commit {
                    if op.is_write() {
                        wr_times[ch].push(t);
                    } else {
                        rd_times[ch].push(t);
                    }
                    last_commit[p] = Some(t);
                    pc[p] += 1;
                    committed_this_cycle[p] = true;
                    progressed = true;
                }
            }
        }

        // All processes finished?
        if pc.iter().enumerate().all(|(p, &c)| c >= trace.ops[p].len()) {
            let mut latency = 0u64;
            for p in 0..nproc {
                let done = match last_commit[p] {
                    None => trace.tail_delays[p],
                    Some(c) => c + 1 + trace.tail_delays[p],
                };
                latency = latency.max(done);
            }
            return GoldenOutcome::Done { latency };
        }

        // Advance the clock to the next cycle at which anything could
        // possibly commit; if no pending op has a finite enabling time,
        // the design is deadlocked.
        let mut next: Option<u64> = None;
        for p in 0..nproc {
            if pc[p] >= trace.ops[p].len() {
                continue;
            }
            let op = trace.ops[p][pc[p]];
            let ch = op.chan();
            let start = match last_commit[p] {
                None => op.delay as u64,
                Some(prev) => prev + 1 + op.delay as u64,
            };
            let enable: Option<u64> = if op.is_write() {
                let j = wr_times[ch].len() as u32;
                let d = depths[ch];
                if j >= d {
                    let need = (j - d) as usize;
                    if rd_times[ch].len() > need {
                        Some(start.max(rd_times[ch][need] + 1))
                    } else {
                        None // waiting on a read that has not happened
                    }
                } else {
                    Some(start)
                }
            } else {
                let j = rd_times[ch].len();
                if wr_times[ch].len() > j {
                    Some(start.max(wr_times[ch][j] + rd_lat[ch]))
                } else {
                    None // waiting on a write that has not happened
                }
            };
            if let Some(e) = enable {
                debug_assert!(e > t, "enabled op not committed at t={t}");
                next = Some(next.map_or(e, |n: u64| n.min(e)));
            }
        }
        match next {
            Some(n) => t = n,
            None => return GoldenOutcome::Deadlock,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DesignBuilder, Expr};
    use crate::sim::fast::FastSim;
    use crate::trace::collect_trace;
    use std::sync::Arc;

    fn check_match(design: &crate::ir::Design, args: &[i64], depths: &[u32]) {
        let t = Arc::new(collect_trace(design, args).unwrap());
        let mut fast = FastSim::new(t.clone());
        let f = fast.simulate(depths);
        let g = simulate_golden(&t, depths, SimOptions::default());
        assert_eq!(
            f.latency(),
            g.latency(),
            "fast {f:?} vs golden {g:?} at depths {depths:?}"
        );
    }

    #[test]
    fn matches_fast_on_pipe() {
        let mut b = DesignBuilder::new("pipe", 0);
        let c = b.channel("c", 32);
        b.process("p", |p| p.for_n(16, |p, _| p.write(c, Expr::c(0))));
        b.process("q", |p| {
            p.for_n(16, |p, _| {
                let _ = p.read(c);
            })
        });
        let d = b.build();
        for depth in [1u32, 2, 3, 5, 16, 100] {
            check_match(&d, &[], &[depth]);
        }
    }

    #[test]
    fn matches_fast_on_fig2_including_deadlock() {
        let mut b = DesignBuilder::new("fig2", 1);
        let x = b.channel("x", 32);
        let y = b.channel("y", 32);
        b.process("prod", |p| {
            p.for_expr(Expr::arg(0), |p, _| p.write(x, Expr::c(1)));
            p.for_expr(Expr::arg(0), |p, _| p.write(y, Expr::c(1)));
        });
        b.process("cons", |p| {
            p.for_expr(Expr::arg(0), |p, _| {
                let _ = p.read(x);
                let _ = p.read(y);
            });
        });
        let d = b.build();
        let t = Arc::new(collect_trace(&d, &[8]).unwrap());
        let mut fast = FastSim::new(t.clone());
        for dx in [2u32, 4, 6, 7, 8, 16] {
            for dy in [2u32, 4] {
                let depths = [dx, dy];
                let f = fast.simulate(&depths);
                let g = simulate_golden(&t, &depths, SimOptions::default());
                assert_eq!(f.latency(), g.latency(), "depths {depths:?}");
                assert_eq!(f.is_deadlock(), g.latency().is_none());
            }
        }
    }

    #[test]
    fn diamond_topology_matches() {
        // split → two parallel branches with different delays → join
        let mut b = DesignBuilder::new("diamond", 0);
        let a1 = b.channel("a1", 32);
        let a2 = b.channel("a2", 32);
        let b1 = b.channel("b1", 32);
        let b2 = b.channel("b2", 32);
        b.process("src", |p| {
            p.for_n(24, |p, _| {
                p.write(a1, Expr::c(0));
                p.write(a2, Expr::c(0));
            })
        });
        b.process("slow", |p| {
            p.for_n(24, |p, _| {
                let _ = p.read(a1);
                p.delay(7);
                p.write(b1, Expr::c(0));
            })
        });
        b.process("fastbr", |p| {
            p.for_n(24, |p, _| {
                let _ = p.read(a2);
                p.write(b2, Expr::c(0));
            })
        });
        b.process("join", |p| {
            p.for_n(24, |p, _| {
                let _ = p.read(b1);
                let _ = p.read(b2);
            })
        });
        let d = b.build();
        for depths in [[2u32, 2, 2, 2], [4, 2, 2, 8], [2, 2, 2, 24], [1, 1, 1, 1]] {
            check_match(&d, &[], &depths);
        }
    }

    /// Latency *and* deadlock-verdict agreement on a depth walk.
    fn check_walk(design: &crate::ir::Design, args: &[i64], configs: &[Vec<u32>]) {
        let t = Arc::new(collect_trace(design, args).unwrap());
        let mut fast = FastSim::new(t.clone());
        for depths in configs {
            let f = fast.simulate(depths);
            let g = simulate_golden(&t, depths, SimOptions::default());
            assert_eq!(f.latency(), g.latency(), "depths {depths:?}");
            assert_eq!(f.is_deadlock(), g.latency().is_none(), "depths {depths:?}");
        }
    }

    #[test]
    fn flowgnn_topology_matches_including_data_dependent_deadlocks() {
        // A reduced PNA instance (16 nodes / 96 edges) keeps the
        // cycle-stepped golden run cheap while preserving the family's
        // defining property: per-lane message bursts whose sizes are a
        // runtime input, so all-minimum FIFOs deadlock and the exact
        // per-lane write counts un-deadlock.
        let bd = crate::bench_suite::flowgnn::pna(16, 96, 7);
        let t = Arc::new(collect_trace(&bd.design, &bd.args).unwrap());
        let mut burst_sized = t.baseline_min();
        for lane in 0..crate::bench_suite::flowgnn::LANES {
            burst_sized[lane] = (t.channels[lane].writes as u32).max(2);
        }
        let mut mid = t.baseline_max();
        for d in mid.iter_mut() {
            *d = (*d / 2).max(1);
        }
        let configs = vec![t.baseline_max(), t.baseline_min(), burst_sized, mid];
        check_walk(&bd.design, &bd.args, &configs);
        // A second graph (different seed → different lane bursts) so the
        // data-dependent routing itself is golden-checked.
        let bd8 = crate::bench_suite::flowgnn::pna(16, 96, 8);
        let t8 = Arc::new(collect_trace(&bd8.design, &bd8.args).unwrap());
        check_walk(&bd8.design, &bd8.args, &[t8.baseline_max(), t8.baseline_min()]);
    }

    #[test]
    fn dnn_topology_matches() {
        // A miniature dnn-family pipeline from the same `stages` library
        // the Table II generators use (loader → matmul PE array → map →
        // replay → matmul → map → sink), small enough for golden: the
        // family's FIFO pressure comes from replay tasks buffering whole
        // intermediate tensors.
        use crate::bench_suite::stages::{self, F32, W8};
        let p = 2;
        let mut b = crate::ir::DesignBuilder::new("mini_dnn", 0);
        let ws = stages::port_sources(&mut b, "W", &[("w1", p, 16), ("w2", p, 16)], W8);
        let x = stages::source(&mut b, "x", p, 16, F32);
        let h = stages::matmul(&mut b, "h", &x, &ws[0], 4, 4, 0);
        let g = stages::map(&mut b, "gelu", &h, 2);
        let rep = stages::replay(&mut b, "rep", &g, 4);
        let y = stages::matmul(&mut b, "y", &rep, &ws[1], 4, 4, 0);
        let out = stages::map(&mut b, "bias", &y, 1);
        stages::sink(&mut b, "store", &out, 0);
        let d = b.build();
        let t = Arc::new(collect_trace(&d, &[]).unwrap());
        let nch = t.num_fifos();
        let mut configs = vec![t.baseline_max(), t.baseline_min(), vec![1u32; nch]];
        let mut mixed = t.baseline_max();
        for (i, dep) in mixed.iter_mut().enumerate() {
            if i % 2 == 0 {
                *dep = 2;
            }
        }
        configs.push(mixed);
        check_walk(&d, &[], &configs);
    }
}
